//! Quickstart: construct the paper's SFC algorithms, inspect their
//! properties, and run a fast convolution through the public API.
//!
//!     cargo run --release --example quickstart

use sfc::algo::{catalog, direct_conv2d, sfc, winograd};
use sfc::linalg::Mat;
use sfc::nn::conv::{conv2d_direct, conv2d_fast, FastConvPlan};
use sfc::nn::Tensor;
use sfc::util::Pcg32;

fn main() {
    // 1) Build the flagship algorithm: SFC-6(7×7, 3×3).
    let algo = sfc(6, 7, 3);
    println!("algorithm       : {}", algo.name);
    println!("output tile     : {}×{}", algo.m, algo.m);
    println!("input tile      : {0}×{0}", algo.input_len());
    println!("multiplications : {} 1-D, {} 2-D ({} with Hermitian symmetry)",
        algo.t, algo.mults_2d(), algo.mults_2d_hermitian());
    println!("speedup vs direct: {:.2}× (Winograd F(4,3): {:.2}×)",
        algo.speedup_2d(), winograd(4, 3).speedup_2d());
    println!("κ(Aᵀ)           : {:.2} (Winograd F(4,3): {:.2})\n",
        algo.kappa_at(), winograd(4, 3).kappa_at());

    // 2) The transforms are pure addition networks (the paper's §4.1).
    assert!(algo.bt.is_integral() && algo.g.is_integral());
    println!("Bᵀ and G are integer ±1/0 matrices — transform = additions only ✓");

    // 3) One 2-D tile through the bilinear form, checked against naive conv.
    let mut rng = Pcg32::seeded(1);
    let l = algo.input_len();
    let x = Mat::from_vec(l, l, (0..l * l).map(|_| rng.next_gaussian()).collect());
    let f = Mat::from_vec(3, 3, (0..9).map(|_| rng.next_gaussian()).collect());
    let y = algo.apply2d_f64(&x, &f);
    let want = direct_conv2d(&x, &f);
    let err: f64 = y.data.iter().zip(&want.data).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
    println!("tile check: max |err| = {err:.2e} (float roundoff only) ✓\n");

    // 4) A full NCHW conv layer through the tiled engine.
    let plan = FastConvPlan::new(sfc(6, 7, 3));
    let mut x = Tensor::zeros(&[1, 16, 28, 28]);
    rng.fill_gaussian(&mut x.data, 1.0);
    let mut w = Tensor::zeros(&[32, 16, 3, 3]);
    rng.fill_gaussian(&mut w.data, 0.2);
    let fast = conv2d_fast(&x, &w, &[], &plan, 1);
    let direct = conv2d_direct(&x, &w, &[], 1, 1);
    println!("conv2d [1,16,28,28]→[1,32,28,28]: engine MSE vs direct = {:.2e} ✓\n", fast.mse(&direct));

    // 5) The whole Table-1 catalog is one call away.
    println!("{:<18} {:>8} {:>8} {:>10}", "algorithm", "mults2D", "κ(Aᵀ)", "complexity");
    for spec in catalog() {
        // FFT/NTT catalog rows have no bilinear error/complexity model
        let Some(a) = spec.bilinear() else { continue };
        println!("{:<18} {:>8} {:>8.1} {:>9.1}%", spec.name, a.mults_2d_hermitian(), a.kappa_at(), 100.0 * a.complexity_2d());
    }
}
