//! FPGA design-space exploration: sweep accelerator parallelism and
//! algorithm choices, print the resource/throughput Pareto frontier —
//! the kind of study a hardware team would run on top of Table 3's model.
//!
//!     cargo run --release --example fpga_explore

use sfc::algo::{sfc, winograd};
use sfc::fpga::{pipeline::simulate, Accel};
use sfc::nn::model::vgg16_conv_shapes;

fn main() {
    let shapes = vgg16_conv_shapes();
    println!(
        "{:<26} {:>7} {:>9} {:>10} {:>9} {:>14}",
        "config", "DSPs", "LUTs(K)", "GOPs", "util", "GOPs/DSP/GHz"
    );
    println!("{}", "-".repeat(80));
    let mut best: Option<(f64, String)> = None;
    for (algo_name, algo, bits) in [
        ("SFC-6(7x7,3x3)", sfc(6, 7, 3), 8u32),
        ("SFC-6(6x6,3x3)", sfc(6, 6, 3), 8),
        ("SFC-4(4x4,3x3)", sfc(4, 4, 3), 8),
        ("Wino(4x4,3x3) int8", winograd(4, 3), 8),
        ("Wino(4x4,3x3) int16", winograd(4, 3), 16),
    ] {
        for (p_ic, p_oc) in [(2usize, 2usize), (4, 4), (8, 8)] {
            let acc = Accel::from_bilinear(algo_name, &algo, p_ic, p_oc, bits);
            let res = acc.resources();
            let sim = simulate(&acc, &shapes);
            let eff = acc.gops_per_dsp_per_ghz(sim.achieved_gops);
            println!(
                "{:<26} {:>7} {:>9.0} {:>10.0} {:>8.0}% {:>14.2}",
                format!("{algo_name} [{p_ic}x{p_oc}]"),
                res.dsps,
                res.luts_k,
                sim.achieved_gops,
                100.0 * sim.utilization,
                eff
            );
            if best.as_ref().map_or(true, |(b, _)| eff > *b) {
                best = Some((eff, format!("{algo_name} [{p_ic}x{p_oc}]")));
            }
        }
    }
    let (eff, name) = best.unwrap();
    println!("\nbest efficiency: {name} at {eff:.2} GOPs/DSP/GHz");
    println!("(paper Table 3: SFC achieves 10.08 vs Winograd 5.64, NTT 3.48, direct 1.96)");
}
