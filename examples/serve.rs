//! E2E serving driver (the DESIGN.md §6 "E2E" row): load the AOT-compiled
//! JAX model (direct and Pallas-SFC variants), serve the SynthImage test
//! stream through the dynamic batcher, and report accuracy + latency +
//! throughput. Requires `make artifacts`.
//!
//!     cargo run --release --example serve

use sfc::coordinator::{LatencyStats, Server, ServerConfig};
use sfc::exp;
use sfc::runtime::Executor;
use std::path::PathBuf;

fn serve_one(hlo: PathBuf, batch: usize, images: &sfc::nn::Tensor, labels: &[u8]) -> anyhow::Result<()> {
    let n = labels.len();
    let dims = vec![batch, 3, 32, 32];
    let server = Server::start(move || Executor::load(&hlo, &dims, 10), ServerConfig {
        batch_size: batch,
        queue_depth: 64,
        batch_timeout_ms: 2,
    })?;
    let sample = 3 * 32 * 32;
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for i in 0..n {
        handles.push(server.submit(images.data[i * sample..(i + 1) * sample].to_vec())?);
    }
    let mut correct = 0usize;
    let mut lats = Vec::new();
    for (i, h) in handles.into_iter().enumerate() {
        let r = h.wait()?;
        lats.push(r.latency_s);
        correct += (r.argmax == labels[i] as usize) as usize;
    }
    let wall = t0.elapsed().as_secs_f64();
    let s = LatencyStats::from_samples(&lats);
    println!(
        "  batch {batch}: acc {:>6.2}% · {:>7.1} img/s · p50 {:>6.2} ms · p95 {:>6.2} ms · {} batches",
        100.0 * correct as f64 / n as f64,
        n as f64 / wall,
        s.p50 * 1e3,
        s.p95 * 1e3,
        server.batches_executed()
    );
    server.shutdown();
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let data_dir = "artifacts";
    let (images, labels) = exp::load_split(data_dir, "test", 256)?;
    for variant in ["resnet18", "resnet18_sfc"] {
        println!("{variant}:");
        for batch in [1usize, 8] {
            let hlo = PathBuf::from(format!("{data_dir}/{variant}_b{batch}.hlo.txt"));
            if !hlo.exists() {
                println!("  (skipping batch {batch}: {} missing — run `make artifacts`)", hlo.display());
                continue;
            }
            serve_one(hlo, batch, &images, &labels)?;
        }
    }
    Ok(())
}
