//! Large-kernel convolution, two ways.
//!
//! Part 1 (Appendix B): iterative SFC convolution for very large
//! kernels (13×13…37×37) — multiplication counts vs direct, with the
//! transform stage kept addition-only.
//!
//! Part 2: the overlap-save tiled frequency-domain engine. On a
//! 192×192 image with an 11×11 kernel the whole-image FFT/NTT engines
//! decline (their kernel planes would blow the workspace cap), the
//! selector picks the tiled engine, and the steady-state datapath runs
//! through a reused [`Workspace`] without a single heap allocation.
//! This example is run by CI (`tiling-sweep`) and asserts all three.
//!
//!     cargo run --release --example large_kernel

use sfc::algo::iterative::{iterative_conv2d, iterative_cost};
use sfc::algo::{direct_conv2d, sfc};
use sfc::engine::{default_selector, ConvDesc, Workspace};
use sfc::linalg::Mat;
use sfc::nn::conv::conv2d_direct;
use sfc::nn::Tensor;
use sfc::util::{Pcg32, Timer};

fn rel_mse(got: &Tensor, want: &Tensor) -> f64 {
    let denom =
        want.data.iter().map(|v| (*v as f64).powi(2)).sum::<f64>() / want.len().max(1) as f64;
    got.mse(want) / denom.max(1e-30)
}

fn iterative_sfc_section() {
    let inner = sfc(6, 6, 5);
    let outer = sfc(6, 5, 6);
    println!("inner algorithm: {} ({} mults 2-D)", inner.name, inner.mults_2d_hermitian());
    println!("outer algorithm: {} ({} mults 2-D)\n", outer.name, outer.mults_2d_hermitian());

    println!(
        "{:>8} {:>12} {:>12} {:>12} {:>9}",
        "kernel", "direct", "iterative", "reduction", "max err"
    );
    let mut rng = Pcg32::seeded(11);
    for r_big in [13usize, 21, 29, 37] {
        let feat = r_big + 11; // map a bit larger than the kernel
        let c = iterative_cost(r_big, feat - r_big + 1, &inner, &outer);
        let x = Mat::from_vec(feat, feat, (0..feat * feat).map(|_| rng.next_gaussian()).collect());
        let k =
            Mat::from_vec(r_big, r_big, (0..r_big * r_big).map(|_| rng.next_gaussian()).collect());
        let t = Timer::start();
        let got = iterative_conv2d(&x, &k, &inner);
        let _ms = t.elapsed_ms();
        let want = direct_conv2d(&x, &k);
        let err =
            got.data.iter().zip(&want.data).map(|(a, b)| (a - b).abs()).fold(0.0f64, f64::max);
        println!(
            "{:>5}×{:<2} {:>12} {:>12} {:>11.1}× {:>9.1e}",
            r_big,
            r_big,
            c.direct_mults,
            c.two_iter_mults,
            c.direct_mults as f64 / c.two_iter_mults as f64,
            err
        );
    }
    println!(
        "\npaper (29×29): 17,424 mults quoted (3.1% of direct); our exact accounting: 33,856 (6.0%)."
    );
    println!(
        "Either way the transform stage stays addition-only — the property FFT lacks (App. B).\n"
    );
}

fn tiled_engine_section() {
    // 192×192, 8→8 channels, 11×11 kernel, same-padded. The padded
    // image rounds to 256², so whole-image FFT/NTT kernel planes would
    // be 8·8·256² elements — over the workspace cap; both decline.
    let d = ConvDesc::new(1, 8, 8, 192, 192, 11, 1, 5);
    let sel = default_selector();
    assert!(sel.plan_named("FFT", &d).is_err(), "whole-image FFT must decline this image");
    assert!(sel.plan_named("NTT", &d).is_err(), "whole-image NTT must decline this image");
    let plan = sel.plan(&d).expect("the selector must still find an engine");
    println!("selected engine for 192×192 r11: {}", plan.engine);
    assert_eq!(plan.engine, "FFT-tiled", "the tiled engine must win the large-kernel image");
    println!(
        "tiled workspace bound: {:.1} MiB (kernel-derived, image-independent)",
        plan.workspace_bytes() as f64 / (1024.0 * 1024.0)
    );

    let mut rng = Pcg32::seeded(0x11AE);
    let mut x = Tensor::zeros(&[1, 8, 192, 192]);
    rng.fill_gaussian(&mut x.data, 1.0);
    let mut w = Tensor::zeros(&[8, 8, 11, 11]);
    rng.fill_gaussian(&mut w.data, 0.1);
    let want = conv2d_direct(&x, &w, &[], 1, 5);

    let mut ws = Workspace::new();
    let mut out = Tensor::zeros(&plan.out_dims(&x, &w));
    plan.run_into(&x, &w, &[], &mut ws, &mut out); // warmup sizes the arena
    let warm = ws.heap_allocs();
    let t = Timer::start();
    plan.run_into(&x, &w, &[], &mut ws, &mut out);
    let ms = t.elapsed_ms();
    let steady_allocs = ws.heap_allocs() - warm;
    let err = rel_mse(&out, &want);
    println!("steady-state run: {ms:.1} ms, rel mse vs direct {err:.2e}, {steady_allocs} allocs");
    assert!(err < 1e-10, "tiled FFT must match direct: rel mse {err}");
    assert_eq!(steady_allocs, 0, "steady state must not touch the heap");
    println!("ok: tiled engine selected, exact vs direct, zero steady-state allocations");
}

fn main() {
    iterative_sfc_section();
    tiled_engine_section();
}
