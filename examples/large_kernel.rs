//! Appendix B: iterative SFC convolution for large kernels (7×7…51×51).
//!
//!     cargo run --release --example large_kernel

use sfc::algo::iterative::{iterative_conv2d, iterative_cost};
use sfc::algo::{direct_conv2d, sfc};
use sfc::linalg::Mat;
use sfc::util::{Pcg32, Timer};

fn main() {
    let inner = sfc(6, 6, 5);
    let outer = sfc(6, 5, 6);
    println!("inner algorithm: {} ({} mults 2-D)", inner.name, inner.mults_2d_hermitian());
    println!("outer algorithm: {} ({} mults 2-D)\n", outer.name, outer.mults_2d_hermitian());

    println!(
        "{:>8} {:>12} {:>12} {:>12} {:>9}",
        "kernel", "direct", "iterative", "reduction", "max err"
    );
    let mut rng = Pcg32::seeded(11);
    for r_big in [13usize, 21, 29, 37] {
        let feat = r_big + 11; // map a bit larger than the kernel
        let c = iterative_cost(r_big, feat - r_big + 1, &inner, &outer);
        let x = Mat::from_vec(feat, feat, (0..feat * feat).map(|_| rng.next_gaussian()).collect());
        let k = Mat::from_vec(r_big, r_big, (0..r_big * r_big).map(|_| rng.next_gaussian()).collect());
        let t = Timer::start();
        let got = iterative_conv2d(&x, &k, &inner);
        let _ms = t.elapsed_ms();
        let want = direct_conv2d(&x, &k);
        let err = got
            .data
            .iter()
            .zip(&want.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        println!(
            "{:>5}×{:<2} {:>12} {:>12} {:>11.1}× {:>9.1e}",
            r_big,
            r_big,
            c.direct_mults,
            c.two_iter_mults,
            c.direct_mults as f64 / c.two_iter_mults as f64,
            err
        );
    }
    println!("\npaper (29×29): 17,424 mults quoted (3.1% of direct); our exact accounting: 33,856 (6.0%).");
    println!("Either way the transform stage stays addition-only — the property FFT lacks (App. B).");
}
