//! The PTQ pipeline end-to-end on a mini-ResNet: quantize the same model
//! with direct-int8, Winograd-int8 and SFC-int8 and compare accuracy —
//! a self-contained miniature of Table 2 (runs on trained weights when
//! `make artifacts` has been run, else on a random-weight network with
//! MSE as the metric).
//!
//!     cargo run --release --example ptq_pipeline

use sfc::data::synth;
use sfc::exp;
use sfc::nn::model::{resnet18_cfg, resnet_random};
use sfc::nn::Tensor;
use sfc::quant::calib::{dequantize_model, quantize_model, QuantConfig};

fn main() -> anyhow::Result<()> {
    let data_dir = "artifacts";
    let have_artifacts = std::path::Path::new(data_dir).join("resnet18.w32").exists();

    let (mut model, images, labels) = if have_artifacts {
        let (imgs, labels) = exp::load_split(data_dir, "test", 128)?;
        (exp::load_model(data_dir, "resnet18")?, imgs, labels)
    } else {
        println!("(no artifacts — using a random-weight resnet18; run `make artifacts` for the real thing)\n");
        let ds = synth::generate(128, 42);
        let mut t = Tensor::zeros(&[ds.n, ds.c, ds.h, ds.w]);
        t.data.copy_from_slice(&ds.images);
        (resnet_random(&resnet18_cfg(), 7, 10), t, ds.labels)
    };

    let calib_dims = [64, images.dims[1], images.dims[2], images.dims[3]];
    let calib = Tensor::from_vec(&calib_dims, images.data[..calib_dims.iter().product()].to_vec());

    let fp32_logits = model.forward(&images);
    let fp32_acc = model.accuracy(&images, &labels);
    println!("fp32: top-1 {:.2}%\n", fp32_acc * 100.0);

    for (label, cfg) in [
        ("direct int8", QuantConfig::direct_default(8)),
        ("Wino(4,3) int8", QuantConfig::winograd_default(8)),
        ("SFC-6(7,3) int8", QuantConfig::sfc_default(8)),
        ("Wino(4,3) int6", QuantConfig::winograd_default(6)),
        ("SFC-6(7,3) int6", QuantConfig::sfc_default(6)),
    ] {
        let n = quantize_model(&mut model, &calib, &cfg);
        let acc = model.accuracy(&images, &labels);
        let logits = model.forward(&images);
        let mse = logits.mse(&fp32_logits);
        println!(
            "{label:<16} quantized {} convs · top-1 {:>6.2}% (Δ {:+.2}%) · logit MSE {mse:.3e}",
            n.len(),
            acc * 100.0,
            (acc - fp32_acc) * 100.0
        );
        dequantize_model(&mut model);
    }
    println!("\nExpected shape (paper Table 2): SFC ≈ direct ≫ Winograd, gap widening at int6.");
    Ok(())
}
