//! Persistent executor-pool property tests (cross-layer).
//!
//! PR contract: migrating every parallel region onto the work-stealing
//! pool must change *where* tasks execute, never *what* they compute.
//! The decomposition (chunk boundaries, state→chunk mapping, per-element
//! k-ascending GEMM chains) is fixed before submission; work-stealing
//! only reassigns whole tasks, so outputs stay bit-identical across
//! `SFC_THREADS` and dispatch arms — float 0 ULP, int8 exact — from the
//! raw GEMM entry points up through a whole-model `forward_ws`. On top
//! of that, the pool itself must isolate task panics to the submitting
//! call, keep its worker set bounded across `MultiServer` lifecycles,
//! and keep its gauges (tasks/steals/spawn-avoided) consistent under a
//! multi-model burst.
//!
//! The thread/kernel overrides and the pool gauges are process-global,
//! so every test here serializes behind one lock (mirrors
//! `tests/threads.rs`).

use sfc::coordinator::sched::{MultiServer, Response, SchedConfig};
use sfc::engine::{default_selector, ConvDesc, Workspace};
use sfc::linalg::gemm::{
    self, gemm_packed_f32, gemm_packed_i8_i32, pack_b_f32, pack_b_i8, packed_b_f32_len,
    packed_b_i8_len,
};
use sfc::linalg::simd::{self, Kernel};
use sfc::nn::Tensor;
use sfc::util::par;
use sfc::util::pool;
use sfc::util::Pcg32;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Serializes tests that toggle the process-wide thread / kernel
/// overrides or compare pool-gauge deltas.
static OVERRIDE_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    OVERRIDE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Serial, even split, and a prime count that never divides the row
/// counts (remainder partitions + stale-ticket coverage).
const THREADS: [usize; 3] = [1, 2, 7];

fn with_threads<T>(t: usize, f: impl FnOnce() -> T) -> T {
    par::set_thread_override(Some(t));
    let r = f();
    par::set_thread_override(None);
    r
}

fn with_kernel<T>(k: Option<Kernel>, f: impl FnOnce() -> T) -> T {
    simd::set_kernel_override(k);
    let r = f();
    simd::set_kernel_override(None);
    r
}

fn rand_tensor(dims: &[usize], rng: &mut Pcg32, sigma: f64) -> Tensor {
    let mut t = Tensor::zeros(dims);
    rng.fill_gaussian(&mut t.data, sigma);
    t
}

fn rand_f32(n: usize, rng: &mut Pcg32) -> Vec<f32> {
    let mut v = vec![0f32; n];
    rng.fill_gaussian(&mut v, 1.0);
    v
}

fn rand_i8(n: usize, rng: &mut Pcg32) -> Vec<i8> {
    (0..n).map(|_| (rng.next_u32() & 0xff) as u8 as i8).collect()
}

/// Raw packed GEMM on the pool, float and int8, on a shape above
/// `PAR_MIN_MACS`: every (thread count × dispatch arm) combination must
/// reproduce the serial scalar result to the bit.
#[test]
fn pooled_gemm_bit_identical_to_serial() {
    let _g = lock();
    let mut rng = Pcg32::seeded(0x9001);
    let (m, n, k) = (64usize, 256usize, 130usize);
    assert!((m * n * k) as u64 >= gemm::PAR_MIN_MACS, "shape must clear the threading gate");
    let a = rand_f32(m * k, &mut rng);
    let b = rand_f32(n * k, &mut rng);
    let mut bp = vec![0f32; packed_b_f32_len(n, k)];
    pack_b_f32(n, k, &b, &mut bp);
    let ai = rand_i8(m * k, &mut rng);
    let bi = rand_i8(n * k, &mut rng);
    let mut bpi = vec![0i8; packed_b_i8_len(n, k)];
    pack_b_i8(n, k, &bi, &mut bpi);

    let (rf, ri) = with_threads(1, || {
        with_kernel(Some(Kernel::Scalar), || {
            let mut c = vec![0f32; m * n];
            gemm_packed_f32(m, n, k, &a, &bp, &mut c);
            let mut ci = vec![0i32; m * n];
            gemm_packed_i8_i32(m, n, k, &ai, &bpi, &mut ci);
            (c, ci)
        })
    });
    for t in THREADS {
        for arm in [None, Some(Kernel::Scalar)] {
            let (c, ci) = with_threads(t, || {
                with_kernel(arm, || {
                    let mut c = vec![0f32; m * n];
                    gemm_packed_f32(m, n, k, &a, &bp, &mut c);
                    let mut ci = vec![0i32; m * n];
                    gemm_packed_i8_i32(m, n, k, &ai, &bpi, &mut ci);
                    (c, ci)
                })
            });
            assert_eq!(c, rf, "f32 threads={t} arm={arm:?}");
            assert_eq!(ci, ri, "i8 threads={t} arm={arm:?}");
        }
    }
}

/// The pool-task sweep paths above the GEMM: a conv plan whose
/// per-(freq, group) GEMM sweep runs as stealable tasks, and the tiled
/// frequency-domain engine whose per-block loop does. Bit-identical
/// across thread counts and arms (FFT-tiled is float — still 0 ULP,
/// because each block's arithmetic is independent of its executor).
#[test]
fn pooled_sweeps_bit_identical_across_thread_counts() {
    let _g = lock();
    let sel = default_selector();
    let mut rng = Pcg32::seeded(0x9002);
    let d = ConvDesc::new(2, 6, 8, 20, 20, 3, 1, 1);
    let x = rand_tensor(&[2, 6, 20, 20], &mut rng, 1.0);
    let wt = rand_tensor(&[8, 6, 3, 3], &mut rng, 0.3);
    let bias: Vec<f32> = (0..8).map(|i| i as f32 * 0.05 - 0.1).collect();
    for name in ["SFC-6(6x6,3x3)", "FFT-tiled", "NTT-tiled"] {
        let plan = sel.plan_named(name, &d).unwrap();
        let want =
            with_threads(1, || with_kernel(Some(Kernel::Scalar), || plan.run(&x, &wt, &bias)));
        for t in THREADS {
            for arm in [None, Some(Kernel::Scalar)] {
                let got = with_threads(t, || with_kernel(arm, || plan.run(&x, &wt, &bias)));
                assert_eq!(got.data, want.data, "{name} threads={t} arm={arm:?}");
            }
        }
    }
}

/// Whole-model `forward_ws` (pre-packed, compiled-style datapath) over
/// the pool: 1 vs 2 vs 7 threads, both arms, bit-identical.
#[test]
fn whole_model_forward_bit_identical_on_the_pool() {
    let _g = lock();
    use sfc::nn::model::{mobilenet_cfg, mobilenet_random};
    let mut m = mobilenet_random(&mobilenet_cfg(), 41, 10);
    m.prepack_weights();
    let mut rng = Pcg32::seeded(0x9003);
    let x = rand_tensor(&[2, 3, 32, 32], &mut rng, 1.0);
    let want = with_threads(1, || {
        with_kernel(Some(Kernel::Scalar), || {
            let mut ws = Workspace::new();
            m.forward_ws(&x, &mut ws)
        })
    });
    for t in THREADS {
        for arm in [None, Some(Kernel::Scalar)] {
            let got = with_threads(t, || {
                with_kernel(arm, || {
                    let mut ws = Workspace::new();
                    m.forward_ws(&x, &mut ws)
                })
            });
            assert_eq!(got.data, want.data, "forward_ws threads={t} arm={arm:?}");
        }
    }
}

/// A panicking task unwinds the *submitting* `pool::run` call and
/// nothing else: sibling tasks still execute, the workers survive, and
/// the pool keeps serving subsequent batches.
#[test]
fn task_panic_is_isolated_to_the_submitting_call() {
    let _g = lock();
    let hits: Vec<AtomicUsize> = (0..64).map(|_| AtomicUsize::new(0)).collect();
    let r = std::panic::catch_unwind(|| {
        pool::run(64, 4, |i| {
            if i == 13 {
                panic!("task boom");
            }
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
    });
    let err = r.expect_err("the task panic must reach the submitter");
    let msg = err.downcast_ref::<&str>().copied().unwrap_or("");
    assert!(msg.contains("task boom"), "payload preserved, got {msg:?}");
    // every non-panicking sibling ran exactly once (the batch drains
    // fully before the panic is re-thrown — no abandoned tasks)
    for (i, h) in hits.iter().enumerate() {
        let want = usize::from(i != 13);
        assert_eq!(h.load(Ordering::Relaxed), want, "task {i}");
    }
    // the pool still works: workers survived the unwind
    let count = AtomicUsize::new(0);
    pool::run(97, 4, |_| {
        count.fetch_add(1, Ordering::Relaxed);
    });
    assert_eq!(count.load(Ordering::Relaxed), 97, "pool serves batches after a panic");
}

/// Pool workers are process-lived and bounded: repeated
/// `MultiServer` build → burst → `shutdown` cycles must not grow the
/// worker set (no thread leak), because model workers lease lanes while
/// the pool reuses its resident threads.
#[test]
fn worker_set_stays_bounded_across_server_lifecycles() {
    let _g = lock();
    let mut rng = Pcg32::seeded(0x9004);
    let mut workers_after_cycle = Vec::new();
    for cycle in 0..3 {
        let server = MultiServer::new(SchedConfig {
            queue_depth: 16,
            default_deadline_ms: 60_000,
            linger_ms: 1,
            packed_budget_bytes: 0,
            dispatch: sfc::coordinator::DispatchMode::Worker,
        });
        server
            .add_model("m", move || {
                use sfc::nn::model::{mobilenet_cfg, mobilenet_random};
                let m = mobilenet_random(&mobilenet_cfg(), 51, 10);
                Ok(sfc::runtime::EngineExecutor::from_model(m, vec![2, 3, 32, 32], 10))
            })
            .unwrap();
        let mut handles = Vec::new();
        for _ in 0..4 {
            let mut img = vec![0f32; 3 * 32 * 32];
            rng.fill_gaussian(&mut img, 1.0);
            handles.push(server.submit_blocking("m", img).unwrap());
        }
        for h in handles {
            match h.wait().unwrap() {
                Response::Done(_) => {}
                other => panic!("cycle {cycle}: request did not complete: {other:?}"),
            }
        }
        server.shutdown();
        workers_after_cycle.push(pool::gauges().workers);
    }
    assert!(
        workers_after_cycle[2] <= 64,
        "worker set bounded, got {}",
        workers_after_cycle[2]
    );
    assert_eq!(
        workers_after_cycle[1], workers_after_cycle[2],
        "steady state: later lifecycles reuse the resident workers instead of spawning"
    );
}

/// Gauge consistency under a 2-model burst with intra-op threading
/// forced on: tasks are executed (the sweeps actually ran as pool
/// tasks), spawn-avoided grows (submits reused resident workers), and
/// the counters never contradict each other (steals ≤ tasks; all
/// monotone).
#[test]
fn gauges_consistent_under_two_model_burst() {
    let _g = lock();
    let before = pool::gauges();
    with_threads(4, || {
        let server = MultiServer::new(SchedConfig {
            queue_depth: 32,
            default_deadline_ms: 60_000,
            linger_ms: 1,
            packed_budget_bytes: 0,
            dispatch: sfc::coordinator::DispatchMode::Worker,
        });
        for name in ["a", "b"] {
            server
                .add_model(name, move || {
                    use sfc::nn::model::{mobilenet_cfg, mobilenet_random};
                    let m = mobilenet_random(&mobilenet_cfg(), 61, 10);
                    Ok(sfc::runtime::EngineExecutor::from_model(m, vec![2, 3, 32, 32], 10))
                })
                .unwrap();
        }
        let mut rng = Pcg32::seeded(0x9005);
        let mut handles = Vec::new();
        for i in 0..8 {
            let mut img = vec![0f32; 3 * 32 * 32];
            rng.fill_gaussian(&mut img, 1.0);
            let name = if i % 2 == 0 { "a" } else { "b" };
            handles.push(server.submit_blocking(name, img).unwrap());
        }
        for h in handles {
            match h.wait().unwrap() {
                Response::Done(_) => {}
                other => panic!("request did not complete: {other:?}"),
            }
        }
        server.shutdown();
    });
    let after = pool::gauges();
    assert!(after.tasks > before.tasks, "the burst must execute pool tasks");
    assert!(after.steals >= before.steals && after.steals <= after.tasks);
    assert!(after.spawn_avoided >= before.spawn_avoided);
    assert!(after.unparks >= before.unparks && after.parks >= before.parks);
    assert!(after.workers <= 64, "worker set bounded: {}", after.workers);
    // once the worker set is warm, at least some submits of the burst
    // must have found their helpers resident instead of spawning
    assert!(
        after.spawn_avoided > before.spawn_avoided,
        "a multi-layer burst re-submits constantly; spawns must be amortized"
    );
}
