//! Global-dispatch scheduler properties: cost-aware EDF never inverts
//! priority outcomes, speculative batch splitting never starves the
//! requeued tail, the shared WorkspacePool's byte accounting stays
//! exact under concurrent lease/return, global-vs-worker dispatch is
//! bit-identical on identical request streams, and shutdown drains
//! with typed errors in global mode too.

use sfc::coordinator::sched::{
    DispatchMode, MultiServer, Priority, Response, SchedConfig, ServerStopped, ShedReason,
    SubmitOpts,
};
use sfc::coordinator::ModelRunner;
use sfc::engine::WorkspacePool;
use sfc::nn::model::{resnet18_cfg, resnet_random};
use sfc::runtime::EngineExecutor;
use sfc::util::Pcg32;
use anyhow::Result;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

fn global_cfg(queue_depth: usize) -> SchedConfig {
    SchedConfig {
        queue_depth,
        default_deadline_ms: 60_000,
        linger_ms: 2_000, // only partial batches linger; full batches fire
        packed_budget_bytes: 0,
        dispatch: DispatchMode::Global,
    }
}

/// Mock whose `run` blocks at a gate until the test opens it — parks
/// the executor mid-batch (holding its run slot) so the test can
/// manipulate the queue with no timing races. Class = image[0].
struct Gate {
    open: Mutex<bool>,
    cv: Condvar,
    entered: AtomicUsize,
}

struct GatedMock {
    dims: Vec<usize>,
    gate: Arc<Gate>,
}

impl ModelRunner for GatedMock {
    fn input_dims(&self) -> &[usize] {
        &self.dims
    }
    fn out_classes(&self) -> usize {
        10
    }
    fn run(&self, batch: &[f32]) -> Result<Vec<f32>> {
        self.gate.entered.fetch_add(1, Ordering::SeqCst);
        let mut open = self.gate.open.lock().unwrap();
        while !*open {
            open = self.gate.cv.wait(open).unwrap();
        }
        drop(open);
        mock_logits(&self.dims, batch)
    }
}

/// Mock with a small fixed execution time, for contention scenarios.
struct SleepMock {
    dims: Vec<usize>,
    delay: Duration,
}

impl ModelRunner for SleepMock {
    fn input_dims(&self) -> &[usize] {
        &self.dims
    }
    fn out_classes(&self) -> usize {
        10
    }
    fn run(&self, batch: &[f32]) -> Result<Vec<f32>> {
        std::thread::sleep(self.delay);
        mock_logits(&self.dims, batch)
    }
}

/// Instant mock (no gate, no delay) for shutdown tests.
struct InstantMock {
    dims: Vec<usize>,
}

impl ModelRunner for InstantMock {
    fn input_dims(&self) -> &[usize] {
        &self.dims
    }
    fn out_classes(&self) -> usize {
        10
    }
    fn run(&self, batch: &[f32]) -> Result<Vec<f32>> {
        mock_logits(&self.dims, batch)
    }
}

fn mock_logits(dims: &[usize], batch: &[f32]) -> Result<Vec<f32>> {
    let sample: usize = dims[1..].iter().product();
    let n = dims[0];
    let mut out = vec![0f32; n * 10];
    for i in 0..n {
        let cls = (batch[i * sample] as usize).min(9);
        out[i * 10 + cls] = 1.0;
    }
    Ok(out)
}

fn img(cls: usize) -> Vec<f32> {
    let mut v = vec![0f32; 4];
    v[0] = (cls % 10) as f32;
    v
}

fn opts(priority: Priority, deadline_s: u64) -> SubmitOpts {
    SubmitOpts { priority, deadline: Some(Duration::from_secs(deadline_s)) }
}

#[test]
fn dispatch_mode_parses_and_names() {
    assert_eq!(DispatchMode::parse("worker").unwrap(), DispatchMode::Worker);
    assert_eq!(DispatchMode::parse("global").unwrap(), DispatchMode::Global);
    assert!(DispatchMode::parse("both").is_err());
    assert_eq!(DispatchMode::Worker.name(), "worker");
    assert_eq!(DispatchMode::Global.name(), "global");
    assert_eq!(DispatchMode::default(), DispatchMode::Worker);
}

/// Cost-aware EDF must never invert priority outcomes: with the
/// executor parked mid-batch behind the gate, Low fillers are displaced
/// by later High arrivals (earlier deadlines), and once the gate opens
/// every High request completes while only Low work was sacrificed.
#[test]
fn global_edf_never_inverts_priority_outcomes() {
    let server = MultiServer::new(global_cfg(8));
    let gate = Arc::new(Gate {
        open: Mutex::new(false),
        cv: Condvar::new(),
        entered: AtomicUsize::new(0),
    });
    let g2 = gate.clone();
    server
        .add_model("m", move || Ok(GatedMock { dims: vec![4, 1, 2, 2], gate: g2 }))
        .unwrap();

    // park the executor on a full High batch
    let mut first = Vec::new();
    for c in 0..4 {
        first.push(server.submit("m", img(c), opts(Priority::High, 60)).unwrap());
    }
    while gate.entered.load(Ordering::SeqCst) == 0 {
        std::thread::sleep(Duration::from_millis(1));
    }
    // fill the queue with Low work (later deadlines) ...
    let mut lows = Vec::new();
    for c in 0..8 {
        lows.push(server.submit("m", img(c), opts(Priority::Low, 60)).unwrap());
    }
    // ... then High work with earlier deadlines displaces Low entries
    let mut highs = Vec::new();
    for c in 0..4 {
        highs.push(server.submit("m", img(c), opts(Priority::High, 30)).unwrap());
    }
    {
        let mut open = gate.open.lock().unwrap();
        *open = true;
        gate.cv.notify_all();
    }

    for t in first.into_iter().chain(highs) {
        match t.wait().unwrap() {
            Response::Done(_) => {}
            Response::Shed(s) => panic!("High request shed: {s:?} — priority inverted"),
        }
    }
    let mut low_done = 0;
    let mut low_displaced = 0;
    for t in lows {
        match t.wait().unwrap() {
            Response::Done(_) => low_done += 1,
            Response::Shed(s) => {
                assert_eq!(s.reason, ShedReason::Displaced);
                assert_eq!(s.priority, Priority::Low);
                low_displaced += 1;
            }
        }
    }
    assert_eq!(low_done, 4, "Lows surviving displacement must execute");
    assert_eq!(low_displaced, 4, "each High newcomer displaces one Low");
    server.shutdown();
}

/// Speculative splitting must never starve the requeued tail: under a
/// rival model flooding tight-deadline traffic (which makes the plan
/// contended and split-prone), every generous-deadline request on the
/// victim model still completes.
#[test]
fn global_splitting_never_starves_the_tail() {
    let server = MultiServer::new(global_cfg(64));
    server
        .add_model("slow", || {
            Ok(SleepMock { dims: vec![8, 1, 2, 2], delay: Duration::from_millis(2) })
        })
        .unwrap();
    server
        .add_model("urgent", || {
            Ok(SleepMock { dims: vec![4, 1, 2, 2], delay: Duration::from_millis(1) })
        })
        .unwrap();

    // the batch that may be split: generous deadlines, must all finish
    let mut tail = Vec::new();
    for c in 0..24 {
        tail.push(server.submit("slow", img(c), opts(Priority::Normal, 60)).unwrap());
    }
    // rival pressure: tight deadlines keep the plan contended
    let mut rush = Vec::new();
    for c in 0..40 {
        rush.push(server.submit(
            "urgent",
            img(c),
            SubmitOpts { priority: Priority::High, deadline: Some(Duration::from_millis(5)) },
        ).unwrap());
        std::thread::sleep(Duration::from_micros(200));
    }
    for (c, t) in tail.into_iter().enumerate() {
        match t.wait().unwrap() {
            Response::Done(done) => assert_eq!(done.argmax, c % 10),
            Response::Shed(s) => panic!("tail request {c} starved/shed: {s:?}"),
        }
    }
    for t in rush {
        let _ = t.wait().unwrap(); // done or shed, never hung
    }
    let snap = server.snapshot("slow").unwrap();
    assert_eq!(snap.completed, 24);
    assert_eq!(snap.failed, 0);
    server.shutdown();
}

/// WorkspacePool byte accounting stays exact under concurrent
/// lease/return: after the storm, nothing is leased, and the resident
/// byte gauge equals the sum of pooled bytes across the parked arenas.
#[test]
fn workspace_pool_accounting_exact_under_concurrency() {
    let pool = Arc::new(WorkspacePool::new(0));
    let threads = 4;
    let iters = 50;
    let mut joins = Vec::new();
    for tid in 0..threads {
        let p = pool.clone();
        joins.push(std::thread::spawn(move || {
            for i in 0..iters {
                let mut ws = p.lease(tid);
                let buf = ws.take_f32(1024 + 256 * tid + i);
                ws.give_f32(buf);
                p.give(tid, ws);
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let g = pool.gauges();
    assert_eq!(g.leases, (threads * iters) as u64);
    assert_eq!(g.leased, 0, "every lease was returned");
    assert!(g.resident_ws >= 1 && g.resident_ws <= g.peak_leased);
    assert!(g.peak_leased <= threads as u64);
    assert!(g.affinity_hits + g.misses <= g.leases);
    assert!(g.peak_resident_bytes >= g.resident_bytes);
    // exactness: drain the free list and re-add the parked arenas' bytes
    let mut drained = 0u64;
    for _ in 0..g.resident_ws {
        let ws = pool.lease(usize::MAX); // no affinity: pops the free list
        drained += ws.pooled_bytes() as u64;
    }
    assert_eq!(drained, g.resident_bytes, "resident byte gauge must be exact");
    assert_eq!(pool.gauges().resident_bytes, 0);
    assert_eq!(pool.gauges().resident_ws, 0);
}

/// Identical request streams produce bit-identical logits under worker
/// and global dispatch: convolution is per-sample independent and the
/// batch tail is zero-padded, so the dispatch policy (batch sizes,
/// splits, workspace source) must never leak into the numbers.
#[test]
fn global_vs_worker_dispatch_is_bit_identical() {
    let requests = 12;
    let sample = 3 * 32 * 32;
    let mut images = Vec::with_capacity(requests);
    for i in 0..requests {
        let mut img = vec![0f32; sample];
        Pcg32::seeded(1000 + i as u64).fill_gaussian(&mut img, 0.5);
        images.push(img);
    }
    let mut arms: Vec<Vec<Vec<f32>>> = Vec::new();
    for dispatch in [DispatchMode::Worker, DispatchMode::Global] {
        let server = MultiServer::new(SchedConfig {
            queue_depth: 64,
            default_deadline_ms: 60_000,
            linger_ms: 2,
            packed_budget_bytes: 0,
            dispatch,
        });
        server
            .add_model("resnet18", || {
                let m = resnet_random(&resnet18_cfg(), 1, 10);
                Ok(EngineExecutor::from_model(m, vec![4, 3, 32, 32], 10))
            })
            .unwrap();
        let mut tickets = Vec::new();
        for img in &images {
            tickets.push(
                server.submit("resnet18", img.clone(), opts(Priority::Normal, 60)).unwrap(),
            );
        }
        let mut logits = Vec::new();
        for t in tickets {
            match t.wait().unwrap() {
                Response::Done(c) => logits.push(c.logits),
                Response::Shed(s) => panic!("unexpected shed with 60 s deadlines: {s:?}"),
            }
        }
        server.shutdown();
        arms.push(logits);
    }
    for i in 0..requests {
        assert_eq!(
            arms[0][i], arms[1][i],
            "request {i}: worker and global dispatch disagree bit-for-bit"
        );
    }
}

/// Shutdown under global dispatch drains queued work (waiters complete)
/// and late submits fail with the typed [`ServerStopped`] error.
#[test]
fn global_shutdown_drains_then_fails_typed() {
    let server = MultiServer::new(global_cfg(64));
    server.add_model("m", || Ok(InstantMock { dims: vec![4, 1, 2, 2] })).unwrap();
    let mut tickets = Vec::new();
    for c in 0..20 {
        tickets.push(server.submit("m", img(c), opts(Priority::Normal, 60)).unwrap());
    }
    server.shutdown();
    let mut done = 0;
    for t in tickets {
        match t.wait() {
            Ok(Response::Done(_)) => done += 1,
            Ok(Response::Shed(_)) => {}
            Err(e) => {
                assert!(e.is::<ServerStopped>(), "non-typed shutdown error: {e:#}");
            }
        }
    }
    assert!(done > 0, "shutdown must drain queued work, not drop it");
    let err = server.submit("m", img(0), opts(Priority::Normal, 60)).unwrap_err();
    assert!(err.is::<ServerStopped>());
    let snap = server.snapshot("m").unwrap();
    assert_eq!(snap.queue_depth, 0, "clean drain leaves an empty queue");
    assert_eq!(snap.failed, 0);
}
