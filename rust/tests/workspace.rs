//! Workspace-reuse property tests (cross-layer): running any plan
//! through one reused `Workspace` — including interleaving two different
//! shapes — must be bit-identical to fresh-allocation runs, for the
//! float and int8 paths; and once warm, execution must be heap-alloc
//! free. Also covers `Model::forward_ws` against `Model::forward_all`.

use sfc::engine::{default_selector, ConvDesc, ConvPlan, QuantSpec, Workspace};
use sfc::nn::graph::ConvParams;
use sfc::nn::{Model, Op, Tensor};
use sfc::quant::qconv::{collect_act_maxima, QCalib, QConvLayer};
use sfc::util::Pcg32;
use std::sync::Arc;

fn rand_tensor(dims: &[usize], rng: &mut Pcg32, sigma: f64) -> Tensor {
    let mut t = Tensor::zeros(dims);
    rng.fill_gaussian(&mut t.data, sigma);
    t
}

const ENGINES: [&str; 7] =
    ["direct", "im2col-gemm", "Wino(4x4,3x3)", "SFC-6(6x6,3x3)", "SFC-6(7x7,3x3)", "FFT", "NTT"];

#[test]
fn float_paths_bit_identical_under_workspace_reuse() {
    let sel = default_selector();
    let mut rng = Pcg32::seeded(71);
    let d1 = ConvDesc::new(2, 3, 4, 12, 12, 3, 1, 1);
    let d2 = ConvDesc::new(1, 2, 3, 9, 7, 3, 1, 1);
    let x1 = rand_tensor(&[2, 3, 12, 12], &mut rng, 1.0);
    let w1 = rand_tensor(&[4, 3, 3, 3], &mut rng, 0.3);
    let bias1 = vec![0.2, -0.1, 0.0, 0.4];
    let x2 = rand_tensor(&[1, 2, 9, 7], &mut rng, 1.0);
    let w2 = rand_tensor(&[3, 2, 3, 3], &mut rng, 0.3);
    for name in ENGINES {
        let p1 = sel.plan_named(name, &d1).unwrap();
        let p2 = sel.plan_named(name, &d2).unwrap();
        // fresh-allocation reference
        let want1 = p1.run(&x1, &w1, &bias1);
        let want2 = p2.run(&x2, &w2, &[]);
        // one reused workspace, shapes interleaved, first shape repeated
        let mut ws = Workspace::new();
        let a = p1.run_with(&x1, &w1, &bias1, &mut ws);
        let b = p2.run_with(&x2, &w2, &[], &mut ws);
        let c = p1.run_with(&x1, &w1, &bias1, &mut ws);
        assert_eq!(a.data, want1.data, "{name}: first reused run");
        assert_eq!(b.data, want2.data, "{name}: interleaved second shape");
        assert_eq!(c.data, want1.data, "{name}: repeat after interleave");
    }
}

#[test]
fn int8_paths_bit_identical_under_workspace_reuse() {
    let sel = default_selector();
    let mut rng = Pcg32::seeded(72);
    let x = rand_tensor(&[1, 3, 12, 12], &mut rng, 1.0);
    let w = rand_tensor(&[4, 3, 3, 3], &mut rng, 0.3);
    let bias = vec![0.1, 0.0, -0.2, 0.3];
    let dt = ConvDesc::new(1, 3, 4, 12, 12, 3, 1, 1).with_quant(QuantSpec::transform_default(8));
    let ds = ConvDesc::new(1, 3, 4, 12, 12, 3, 1, 1).with_quant(QuantSpec::spatial_default(8));
    let pt = sel.plan_named("SFC-6(6x6,3x3)", &dt).unwrap();
    let maxima = collect_act_maxima(&x, pt.fast_plan().unwrap(), 1);
    let qt = QConvLayer::from_plan(pt, &w, bias.clone(), &QCalib::TransformMaxima(&maxima));
    let calib = QCalib::MaxAbs(x.max_abs());
    let pd = sel.plan_named("direct", &ds).unwrap();
    let qd = QConvLayer::from_plan(pd, &w, bias.clone(), &calib);
    let qn = QConvLayer::from_plan(sel.plan_named("NTT", &ds).unwrap(), &w, bias, &calib);
    // fresh-allocation references
    let want = [qt.forward(&x), qd.forward(&x), qn.forward(&x)];
    // interleave all three layers twice through one workspace
    let mut ws = Workspace::new();
    for round in 0..2 {
        for (layer, want) in [&qt, &qd, &qn].into_iter().zip(&want) {
            let got = layer.forward_with(&x, &mut ws);
            assert_eq!(
                got.data,
                want.data,
                "{} round {round} must be bit-identical under reuse",
                layer.engine()
            );
        }
    }
}

#[test]
fn steady_state_is_alloc_free() {
    let sel = default_selector();
    let mut rng = Pcg32::seeded(73);
    let d = ConvDesc::new(2, 3, 4, 14, 14, 3, 1, 1);
    let x = rand_tensor(&[2, 3, 14, 14], &mut rng, 1.0);
    let w = rand_tensor(&[4, 3, 3, 3], &mut rng, 0.3);
    for name in ENGINES {
        let plan = sel.plan_named(name, &d).unwrap();
        let mut ws = Workspace::new();
        let mut out = Tensor::zeros(&plan.out_dims(&x, &w));
        plan.run_into(&x, &w, &[], &mut ws, &mut out); // warm-up
        let warm = ws.heap_allocs();
        for _ in 0..3 {
            plan.run_into(&x, &w, &[], &mut ws, &mut out);
        }
        assert_eq!(ws.heap_allocs(), warm, "{name}: steady state must not allocate");
        if name != "direct" {
            assert!(ws.peak_bytes() > 0, "{name}: workspace must be exercised");
        }
        assert_eq!(ws.in_use_bytes(), 0, "{name}: all buffers must be returned");
    }
}

#[test]
fn plan_reports_consumable_workspace_bytes() {
    let sel = default_selector();
    let d = ConvDesc::new(1, 8, 8, 16, 16, 3, 1, 1);
    for name in ["im2col-gemm", "SFC-6(6x6,3x3)", "FFT", "NTT"] {
        let plan = sel.plan_named(name, &d).unwrap();
        assert!(plan.workspace_bytes() > 0, "{name} must report scratch demand");
        // pre-warming with the reported size must be legal
        let ws = Workspace::with_capacity(plan.workspace_bytes());
        assert!(ws.pooled_bytes() >= plan.workspace_bytes());
    }
    let direct = sel.plan_named("direct", &d).unwrap();
    assert_eq!(direct.workspace_bytes(), 0, "direct accumulates in the output planes");
}

fn toy_model(rng: &mut Pcg32) -> Model {
    let sel = default_selector();
    let mut m = Model::new("ws-toy");
    let inp = m.push(Op::Input, vec![], "input");
    let w1 = rand_tensor(&[3, 3, 3, 3], rng, 0.3);
    let d1 = ConvDesc::new(2, 3, 3, 12, 12, 3, 1, 1);
    let c1 = m.push(
        Op::Conv {
            params: ConvParams { weight: w1, bias: vec![0.1; 3], stride: 1, pad: 1 },
            plan: sel.plan_named("SFC-6(6x6,3x3)", &d1).unwrap(),
            packed: None,
            quantized: None,
        },
        vec![inp],
        "conv1",
    );
    let r1 = m.push(Op::Relu, vec![c1], "relu1");
    let add = m.push(Op::Add, vec![inp, r1], "res1");
    let w2 = rand_tensor(&[8, 3, 3, 3], rng, 0.3);
    let d2 = ConvDesc::new(2, 3, 8, 12, 12, 3, 1, 1);
    let c2 = m.push(
        Op::Conv {
            params: ConvParams { weight: w2, bias: vec![0.0; 8], stride: 1, pad: 1 },
            plan: Arc::new(ConvPlan::direct(d2)),
            packed: None,
            quantized: None,
        },
        vec![add],
        "conv2",
    );
    let gap = m.push(Op::GlobalAvgPool, vec![c2], "gap");
    let lw = rand_tensor(&[10, 8], rng, 0.5);
    m.push(Op::Linear { weight: lw, bias: vec![0.05; 10] }, vec![gap], "fc");
    m
}

#[test]
fn model_forward_ws_matches_forward_all_and_reuses_buffers() {
    let mut rng = Pcg32::seeded(74);
    let m = toy_model(&mut rng);
    let x = rand_tensor(&[2, 3, 12, 12], &mut rng, 1.0);
    let want = m.forward_all(&x).pop().unwrap();
    let mut ws = Workspace::new();
    let y1 = m.forward_ws(&x, &mut ws);
    assert_eq!(y1.data, want.data, "workspace forward must be bit-identical");
    ws.give_f32(y1.data);
    let warm = ws.heap_allocs();
    let y2 = m.forward_ws(&x, &mut ws);
    assert_eq!(y2.data, want.data, "reused-workspace forward must be bit-identical");
    assert_eq!(ws.heap_allocs(), warm, "second forward must run entirely from the pool");
}
