//! Engine-API integration tests: the equivalence property (every engine
//! that `supports()` a descriptor matches direct convolution on random
//! tensors, float and quantized), plan-cache hit/miss/concurrency
//! behavior, and cache reuse across repeated model construction.

use sfc::engine::{default_selector, ConvDesc, PlanCache, Policy, QuantSpec, Selector};
use sfc::nn::conv::conv2d_direct;
use sfc::nn::Tensor;
use sfc::quant::qconv::{collect_act_maxima, QCalib, QConvLayer};
use sfc::util::Pcg32;
use std::sync::Arc;

fn rand_tensor(dims: &[usize], rng: &mut Pcg32, sigma: f64) -> Tensor {
    let mut t = Tensor::zeros(dims);
    rng.fill_gaussian(&mut t.data, sigma);
    t
}

fn rel_mse(got: &Tensor, want: &Tensor) -> f64 {
    let denom =
        want.data.iter().map(|v| (*v as f64).powi(2)).sum::<f64>() / want.len().max(1) as f64;
    got.mse(want) / denom.max(1e-30)
}

/// Property: every engine that supports a float descriptor agrees with
/// direct convolution within its numerical class (exact-rational and
/// f64-FFT engines at float roundoff; the NTT engine at its documented
/// int8 fixed-point precision).
#[test]
fn property_every_supporting_engine_matches_direct() {
    let sel = default_selector();
    let mut rng = Pcg32::seeded(0xE9);
    let cases: [(usize, usize, usize, usize, usize, usize, usize, usize); 6] = [
        (1, 3, 4, 16, 16, 3, 1, 1),
        (2, 5, 3, 12, 11, 3, 1, 1),
        (1, 2, 2, 14, 14, 3, 1, 0),
        (1, 3, 4, 12, 12, 5, 1, 2),
        (1, 4, 6, 11, 11, 1, 1, 0),
        (2, 3, 5, 12, 12, 3, 2, 1),
    ];
    for (n, ic, oc, h, w, r, stride, pad) in cases {
        let d = ConvDesc::new(n, ic, oc, h, w, r, stride, pad);
        let x = rand_tensor(&[n, ic, h, w], &mut rng, 1.0);
        let wt = rand_tensor(&[oc, ic, r, r], &mut rng, 0.3);
        let bias: Vec<f32> = (0..oc).map(|i| i as f32 * 0.1 - 0.2).collect();
        let want = conv2d_direct(&x, &wt, &bias, stride, pad);
        let mut tested = 0;
        for e in sel.engines() {
            if !e.supports(&d) {
                continue;
            }
            let plan = sel.plan_named(e.name(), &d).unwrap();
            let got = plan.run(&x, &wt, &bias);
            assert_eq!(got.dims, want.dims, "{} on {d:?}", e.name());
            let rel = rel_mse(&got, &want);
            let tol = if e.name() == "NTT" { 5e-2 } else { 1e-6 };
            assert!(rel < tol, "{} on {d:?}: rel mse {rel}", e.name(), );
            tested += 1;
        }
        assert!(tested >= 2, "descriptor {d:?} should have several engines, got {tested}");
    }
}

/// Property: every engine with a quantized datapath stays close to the
/// float reference at int8 with its native granularity.
#[test]
fn property_quantized_engines_agree_with_float_reference() {
    let sel = default_selector();
    let mut rng = Pcg32::seeded(0x51);
    let (n, ic, oc, h, w) = (1usize, 4usize, 4usize, 12usize, 12usize);
    let base = ConvDesc::new(n, ic, oc, h, w, 3, 1, 1);
    let x = rand_tensor(&[n, ic, h, w], &mut rng, 1.0);
    let wt = rand_tensor(&[oc, ic, 3, 3], &mut rng, 0.3);
    let want = conv2d_direct(&x, &wt, &[], 1, 1);
    let t_spec = QuantSpec::transform_default(8);
    let s_spec = QuantSpec::spatial_default(8);
    let mut quantized = 0;
    for e in sel.engines() {
        let d = if e.supports(&base.with_quant(t_spec)) {
            base.with_quant(t_spec)
        } else if e.supports(&base.with_quant(s_spec)) {
            base.with_quant(s_spec)
        } else {
            continue; // float-only engine (im2col, FFT)
        };
        let plan = sel.plan_named(e.name(), &d).unwrap();
        let q = match plan.fast_plan() {
            Some(fast) => {
                let maxima = collect_act_maxima(&x, fast, 1);
                QConvLayer::from_plan(
                    plan.clone(),
                    &wt,
                    vec![],
                    &QCalib::TransformMaxima(&maxima),
                )
            }
            None => QConvLayer::from_plan(plan.clone(), &wt, vec![], &QCalib::MaxAbs(x.max_abs())),
        };
        let got = q.forward(&x);
        assert_eq!(got.dims, want.dims, "{}", e.name());
        let rel = rel_mse(&got, &want);
        assert!(rel < 2e-2, "{}: quantized rel mse {rel}", e.name());
        quantized += 1;
    }
    assert!(quantized >= 4, "expected several quantized engines, got {quantized}");
}

#[test]
fn plan_cache_hit_miss_accounting_through_selector() {
    let cache = Arc::new(PlanCache::new());
    let sel = Selector::with_cache(Policy::Heuristic, cache.clone());
    let d1 = ConvDesc::new(1, 4, 4, 12, 12, 3, 1, 1);
    let d2 = ConvDesc::new(1, 4, 4, 16, 16, 3, 1, 1);
    sel.plan(&d1).unwrap();
    sel.plan(&d1).unwrap();
    sel.plan(&d2).unwrap();
    assert_eq!(cache.misses(), 2, "two distinct descriptors");
    assert_eq!(cache.hits(), 1, "one repeat");
    // pinned plans get their own cache entries, keyed by engine name
    sel.plan_named("direct", &d1).unwrap();
    sel.plan_named("direct", &d1).unwrap();
    assert_eq!(cache.misses(), 3);
    assert_eq!(cache.hits(), 2);
    assert_eq!(cache.len(), 3);
}

#[test]
fn plan_cache_concurrent_requests_plan_once() {
    let cache = Arc::new(PlanCache::new());
    let sel = Selector::with_cache(Policy::Heuristic, cache.clone());
    let d = ConvDesc::new(1, 8, 8, 16, 16, 3, 1, 1);
    std::thread::scope(|s| {
        for _ in 0..8 {
            let sel_ref = &sel;
            s.spawn(move || {
                sel_ref.plan(&d).unwrap();
            });
        }
    });
    assert_eq!(cache.misses(), 1, "one shape must be planned exactly once");
    assert_eq!(cache.hits(), 7);
}

#[test]
fn repeated_model_construction_hits_plan_cache() {
    use sfc::nn::model::{resnet18_cfg, resnet_random};
    // first build warms the global cache (repeated blocks already share)
    let _ = resnet_random(&resnet18_cfg(), 1, 10);
    let (h0, _) = sfc::coordinator::metrics::plan_cache_counters();
    let _ = resnet_random(&resnet18_cfg(), 2, 10);
    let (h1, _) = sfc::coordinator::metrics::plan_cache_counters();
    assert!(h1 > h0, "second construction must hit the plan cache ({h0} -> {h1})");
}

#[test]
fn model_through_selected_plans_matches_reference_numerics() {
    // A small two-conv stack executed through whatever the heuristic
    // picks must match the all-direct reference within float-fast-conv
    // tolerance (the engines are numerically interchangeable).
    use sfc::nn::graph::{ConvParams, Model, Op};
    let mut rng = Pcg32::seeded(0x77);
    let x = rand_tensor(&[2, 3, 16, 16], &mut rng, 1.0);
    let w1 = rand_tensor(&[4, 3, 3, 3], &mut rng, 0.25);
    let w2 = rand_tensor(&[4, 4, 3, 3], &mut rng, 0.2);
    let sel = default_selector();
    let build = |pin_direct: bool| -> Model {
        let mut m = Model::new("t");
        let i = m.push(Op::Input, vec![], "in");
        let mut prev = i;
        for (k, w) in [w1.clone(), w2.clone()].into_iter().enumerate() {
            let (oc, ic, r, _) = w.dims4();
            let d = ConvDesc::new(2, ic, oc, 16, 16, r, 1, 1);
            let plan = if pin_direct {
                sel.plan_named("direct", &d).unwrap()
            } else {
                sel.plan(&d).unwrap()
            };
            let c = m.push(
                Op::Conv {
                    params: ConvParams { weight: w, bias: vec![0.0; oc], stride: 1, pad: 1 },
                    plan,
                    packed: None,
                    quantized: None,
                },
                vec![prev],
                format!("conv{k}"),
            );
            prev = m.push(Op::Relu, vec![c], format!("relu{k}"));
        }
        m
    };
    let reference = build(true).forward(&x);
    let selected = build(false).forward(&x);
    let rel = rel_mse(&selected, &reference);
    assert!(rel < 1e-6, "selected engines drifted from direct: rel mse {rel}");
}
