//! Multi-model scheduler tests: deterministic admission-control /
//! displacement / shedding behavior against a gated mock, the
//! overload-with-real-models e2e (shared plan cache, packed-weight
//! budget, deadline p99, flat workspace allocs), budget-skipped
//! pre-packing staying bit-identical, typed stopped errors, and counter
//! consistency under concurrent submitters.

use sfc::coordinator::sched::{
    DispatchMode, MultiServer, Priority, Response, SchedConfig, ServerStopped, ShedReason,
    SubmitOpts,
};
use sfc::coordinator::ModelRunner;
use sfc::engine::{packed_weight_bytes, PackBudget};
use sfc::nn::model::{mobilenet_cfg, mobilenet_random, resnet18_cfg, resnet_random};
use sfc::nn::Tensor;
use sfc::quant::{quantize_model, QuantConfig};
use sfc::runtime::EngineExecutor;
use sfc::util::Pcg32;
use anyhow::Result;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Mock whose `run` blocks at a gate until the test opens it — lets a
/// test park the worker mid-batch and manipulate the queue with no
/// timing races. Logit round-trip: class = image[0].
struct Gate {
    open: Mutex<bool>,
    cv: Condvar,
    entered: AtomicUsize,
}

struct GatedMock {
    dims: Vec<usize>,
    gate: Arc<Gate>,
}

impl ModelRunner for GatedMock {
    fn input_dims(&self) -> &[usize] {
        &self.dims
    }
    fn out_classes(&self) -> usize {
        10
    }
    fn run(&self, batch: &[f32]) -> Result<Vec<f32>> {
        self.gate.entered.fetch_add(1, Ordering::SeqCst);
        let mut open = self.gate.open.lock().unwrap();
        while !*open {
            open = self.gate.cv.wait(open).unwrap();
        }
        drop(open);
        mock_logits(&self.dims, batch)
    }
}

/// Instant mock (no gate, no delay) for shutdown/concurrency tests.
struct InstantMock {
    dims: Vec<usize>,
}

impl ModelRunner for InstantMock {
    fn input_dims(&self) -> &[usize] {
        &self.dims
    }
    fn out_classes(&self) -> usize {
        10
    }
    fn run(&self, batch: &[f32]) -> Result<Vec<f32>> {
        mock_logits(&self.dims, batch)
    }
}

fn mock_logits(dims: &[usize], batch: &[f32]) -> Result<Vec<f32>> {
    let sample: usize = dims[1..].iter().product();
    let n = dims[0];
    let mut out = vec![0f32; n * 10];
    for i in 0..n {
        let cls = (batch[i * sample] as usize).min(9);
        out[i * 10 + cls] = 1.0;
    }
    Ok(out)
}

fn img(cls: usize) -> Vec<f32> {
    let mut v = vec![0f32; 4];
    v[0] = (cls % 10) as f32;
    v
}

fn opts(priority: Priority, deadline_s: u64) -> SubmitOpts {
    SubmitOpts { priority, deadline: Some(Duration::from_secs(deadline_s)) }
}

/// The deterministic admission-control script: park the worker on a full
/// batch behind the gate, fill the queue with Low work, displace every
/// entry with High work, bounce two more Lows off the all-High queue,
/// then open the gate and check that every ticket resolved with exactly
/// the typed outcome the policy promises.
#[test]
fn overload_sheds_low_priority_with_typed_outcomes() {
    let server = MultiServer::new(SchedConfig {
        queue_depth: 8,
        default_deadline_ms: 60_000,
        linger_ms: 2_000, // only partial batches linger; every batch here is full
        packed_budget_bytes: 0,
        dispatch: DispatchMode::Worker,
    });
    let gate = Arc::new(Gate {
        open: Mutex::new(false),
        cv: Condvar::new(),
        entered: AtomicUsize::new(0),
    });
    let g2 = gate.clone();
    server.add_model("m", move || Ok(GatedMock { dims: vec![4, 1, 2, 2], gate: g2 })).unwrap();

    // 4 High fillers: the worker forms a full batch and parks at the gate
    let fillers: Vec<_> =
        (0..4).map(|i| server.submit("m", img(i), opts(Priority::High, 60)).unwrap()).collect();
    let t0 = Instant::now();
    while gate.entered.load(Ordering::SeqCst) == 0 {
        assert!(t0.elapsed() < Duration::from_secs(10), "worker never reached the gate");
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(
        server.snapshot("m").unwrap().queue_depth,
        0,
        "the parked batch must hold all 4 fillers"
    );

    // 8 Low fill the queue to its depth
    let lows: Vec<_> =
        (0..8).map(|i| server.submit("m", img(i), opts(Priority::Low, 30)).unwrap()).collect();
    assert_eq!(server.snapshot("m").unwrap().queue_depth, 8);
    // 8 High displace every queued Low
    let highs: Vec<_> =
        (0..8).map(|i| server.submit("m", img(i), opts(Priority::High, 60)).unwrap()).collect();
    // 2 more Low bounce off the now all-High queue
    let rejected: Vec<_> =
        (0..2).map(|i| server.submit("m", img(i), opts(Priority::Low, 30)).unwrap()).collect();

    {
        let mut open = gate.open.lock().unwrap();
        *open = true;
        gate.cv.notify_all();
    }

    for (i, t) in fillers.into_iter().enumerate() {
        match t.wait().unwrap() {
            Response::Done(c) => {
                assert_eq!(c.argmax, i % 10, "filler {i}");
                assert!(c.deadline_met, "filler {i} had a 60 s deadline");
            }
            Response::Shed(s) => panic!("filler {i} shed ({})", s.reason.name()),
        }
    }
    for (i, t) in lows.into_iter().enumerate() {
        match t.wait().unwrap() {
            Response::Shed(s) => {
                assert_eq!(s.reason, ShedReason::Displaced, "low {i}");
                assert_eq!(s.priority, Priority::Low, "low {i}");
                assert!(s.waited_s >= 0.0);
            }
            Response::Done(_) => panic!("low {i} should have been displaced"),
        }
    }
    for (i, t) in highs.into_iter().enumerate() {
        match t.wait().unwrap() {
            Response::Done(c) => assert!(c.deadline_met, "high {i}"),
            Response::Shed(s) => panic!("high {i} shed ({})", s.reason.name()),
        }
    }
    for (i, t) in rejected.into_iter().enumerate() {
        match t.wait().unwrap() {
            Response::Shed(s) => {
                assert_eq!(s.reason, ShedReason::QueueFull, "rejected {i}");
                assert_eq!(s.priority, Priority::Low, "rejected {i}");
            }
            Response::Done(_) => panic!("rejected {i} should have bounced off the full queue"),
        }
    }

    let s = server.snapshot("m").unwrap();
    assert_eq!(s.submitted, 22);
    assert_eq!(s.completed, 12);
    assert_eq!(s.shed, 10);
    assert_eq!(s.failed, 0);
    assert_eq!(s.deadline_met, 12, "every completion carried a generous deadline");
    assert_eq!(s.batches, 3, "4 fillers + 8 highs at batch 4 = 3 full batches");
    assert_eq!(s.queue_depth, 0);
    assert_eq!(s.latency.count(), 12, "histogram records completions only, not sheds");
    assert!(s.latency.p99() <= 60.0);
    server.shutdown();
}

/// The acceptance e2e: two real resident models (float MobileNet + int8
/// MobileNet) on one server, overloaded with mixed priorities/deadlines.
/// Low-priority work sheds (typed), every admitted High meets its
/// deadline, the second model's plans come from the shared process-wide
/// PlanCache, live packed weights stay within the configured budget, and
/// steady-state serving adds zero workspace heap allocations.
#[test]
fn two_models_share_cache_and_budget_under_overload() {
    const BUDGET: u64 = 512 * 1024 * 1024;
    let server = MultiServer::new(SchedConfig {
        queue_depth: 16,
        default_deadline_ms: 30_000,
        linger_ms: 2,
        packed_budget_bytes: BUDGET,
        dispatch: DispatchMode::Worker,
    });
    let ma = mobilenet_random(&mobilenet_cfg(), 1, 10);
    let (h0, _) = sfc::coordinator::metrics::plan_cache_counters();
    let mut mb = mobilenet_random(&mobilenet_cfg(), 2, 10);
    let (h1, _) = sfc::coordinator::metrics::plan_cache_counters();
    assert!(h1 > h0, "the second model must plan through the shared PlanCache");
    let mut calib = Tensor::zeros(&[2, 3, 32, 32]);
    Pcg32::seeded(9).fill_gaussian(&mut calib.data, 1.0);
    quantize_model(&mut mb, &calib, &QuantConfig::direct_default(8));

    let budget = PackBudget::new(BUDGET as usize);
    let dims = vec![2usize, 3, 32, 32];
    let (da, db) = (dims.clone(), dims.clone());
    server
        .add_model("mn-f32", move || Ok(EngineExecutor::from_model_budgeted(ma, da, 10, &budget).0))
        .unwrap();
    server
        .add_model("mn-int8", move || {
            Ok(EngineExecutor::from_model_budgeted(mb, db, 10, &budget).0)
        })
        .unwrap();
    assert!(
        packed_weight_bytes() <= BUDGET,
        "live packed weights {} exceed the configured budget {BUDGET}",
        packed_weight_bytes()
    );

    let sample = 3 * 32 * 32;
    let mut image = vec![0f32; sample];
    Pcg32::seeded(17).fill_gaussian(&mut image, 0.5);
    let names = ["mn-f32", "mn-int8"];

    // warm-up: populate each worker's workspace pools before measuring
    let mut warm = Vec::new();
    for m in names {
        for _ in 0..8 {
            warm.push(server.submit(m, image.clone(), opts(Priority::High, 60)).unwrap());
        }
    }
    for t in warm {
        t.wait().unwrap();
    }
    let warm_allocs: Vec<u64> =
        names.iter().map(|m| server.snapshot(m).unwrap().ws_heap_allocs).collect();

    // overload burst: 40 Low with a hopeless 5 ms deadline, then 16 High
    // with a generous one, per model
    let mut low_tickets = Vec::new();
    let mut high_tickets = Vec::new();
    for m in names {
        for _ in 0..40 {
            let o = SubmitOpts {
                priority: Priority::Low,
                deadline: Some(Duration::from_millis(5)),
            };
            low_tickets.push((m, server.submit(m, image.clone(), o).unwrap()));
        }
    }
    for m in names {
        for _ in 0..16 {
            high_tickets.push((m, server.submit(m, image.clone(), opts(Priority::High, 30)).unwrap()));
        }
    }

    let mut sheds = 0u64;
    for (m, t) in low_tickets {
        match t.wait().unwrap() {
            Response::Shed(s) => {
                sheds += 1;
                assert_eq!(s.priority, Priority::Low, "{m}: only Low work may shed here");
                assert_eq!(s.model, m);
            }
            Response::Done(_) => {} // a lucky Low beat its 5 ms deadline window
        }
    }
    assert!(sheds > 0, "overload must shed some low-priority work");
    for (m, t) in high_tickets {
        match t.wait().unwrap() {
            Response::Done(c) => {
                assert!(c.deadline_met, "{m}: admitted High work must meet its deadline");
            }
            Response::Shed(s) => panic!("{m}: High request shed ({})", s.reason.name()),
        }
    }

    for (mi, m) in names.iter().enumerate() {
        let s = server.snapshot(m).unwrap();
        assert_eq!(s.failed, 0, "{m}");
        assert_eq!(s.queue_depth, 0, "{m}: every ticket resolved, queue must be drained");
        assert!(s.latency.count() > 0, "{m}");
        assert!(s.latency.p99() <= 30.0, "{m}: admitted work completes within deadline at p99");
        assert_eq!(
            s.ws_heap_allocs, warm_allocs[mi],
            "{m}: steady-state serving must add zero workspace heap allocations"
        );
    }
    server.shutdown();
}

/// Satellite: a tiny pack budget skips every panel (added_bytes == 0)
/// and the unpacked model still produces bit-identical logits — packing
/// is a perf decision, never a numerics decision.
#[test]
fn prepack_budget_skips_but_stays_bit_identical() {
    let mut a = resnet_random(&resnet18_cfg(), 5, 10);
    let mut b = resnet_random(&resnet18_cfg(), 5, 10);
    a.compile();
    b.compile();
    let full = a.prepack_weights_budgeted(&PackBudget::unlimited());
    assert!(full.packed_layers > 0, "resnet18 must have packable conv layers");
    assert!(full.added_bytes > 0, "resnet18 must have fast-plan panels to pack");
    let none = b.prepack_weights_budgeted(&PackBudget::new(1));
    assert_eq!(none.added_bytes, 0, "a 1-byte budget admits no panel");
    assert!(none.skipped_layers > 0);
    let mut x = Tensor::zeros(&[1, 3, 32, 32]);
    Pcg32::seeded(11).fill_gaussian(&mut x.data, 1.0);
    let ya = a.forward(&x);
    let yb = b.forward(&x);
    assert_eq!(ya.data, yb.data, "budget-skipped serving path must stay bit-identical");
}

/// Registration-time budget backstop: a model whose unbudgeted pre-pack
/// overruns `packed_budget_bytes` is torn down and `add_model` fails.
#[test]
fn add_model_rejects_budget_overrun() {
    let server = MultiServer::new(SchedConfig {
        queue_depth: 4,
        default_deadline_ms: 1_000,
        linger_ms: 1,
        packed_budget_bytes: 1,
        dispatch: DispatchMode::Worker,
    });
    let m = resnet_random(&resnet18_cfg(), 6, 10);
    let err = server
        .add_model("rn", move || Ok(EngineExecutor::from_model(m, vec![1, 3, 32, 32], 10)))
        .unwrap_err();
    assert!(
        format!("{err:#}").contains("packed-weight budget"),
        "expected a budget-overrun error, got: {err:#}"
    );
    assert!(server.models().is_empty(), "the rejected model must not stay registered");
    server.shutdown();
}

/// Satellite: typed stopped errors. `submit` after `shutdown` fails
/// immediately with [`ServerStopped`]; an unknown model is a plain
/// (different) error.
#[test]
fn submit_after_shutdown_is_a_typed_error() {
    let server = MultiServer::new(SchedConfig::default());
    server.add_model("m", || Ok(InstantMock { dims: vec![2, 1, 2, 2] })).unwrap();
    let unknown = server.submit("nope", img(0), opts(Priority::Normal, 60)).unwrap_err();
    assert!(!unknown.is::<ServerStopped>(), "unknown model is not a stopped-server error");
    match server
        .submit("m", img(3), opts(Priority::Normal, 60))
        .unwrap()
        .wait()
        .unwrap()
    {
        Response::Done(c) => assert_eq!(c.argmax, 3),
        Response::Shed(s) => panic!("unexpected shed ({})", s.reason.name()),
    }
    server.shutdown();
    let err = server.submit("m", img(0), opts(Priority::Normal, 60)).unwrap_err();
    assert!(err.is::<ServerStopped>(), "submit after shutdown: {err:#}");
    let err = server.submit_blocking("m", img(0)).unwrap_err();
    assert!(err.is::<ServerStopped>(), "blocking submit after shutdown: {err:#}");
}

/// Counter consistency under concurrent submitters: with 4 threads
/// hammering a tiny queue, every submit is accounted for exactly once —
/// submitted == completed + shed, nothing lost, queue drained.
#[test]
fn counters_consistent_under_concurrent_submitters() {
    let server = Arc::new(MultiServer::new(SchedConfig {
        queue_depth: 4,
        default_deadline_ms: 30_000,
        linger_ms: 1,
        packed_budget_bytes: 0,
        dispatch: DispatchMode::Worker,
    }));
    server.add_model("m", || Ok(InstantMock { dims: vec![4, 1, 2, 2] })).unwrap();
    let mut joins = Vec::new();
    for t in 0..4u64 {
        let srv = server.clone();
        joins.push(std::thread::spawn(move || {
            let mut done = 0u64;
            let mut shed = 0u64;
            for i in 0..50 {
                let pr = if (t + i) % 2 == 0 { Priority::Normal } else { Priority::High };
                let ticket = srv.submit("m", img(i as usize), opts(pr, 30)).unwrap();
                match ticket.wait().unwrap() {
                    Response::Done(_) => done += 1,
                    Response::Shed(_) => shed += 1,
                }
            }
            (done, shed)
        }));
    }
    let (mut done, mut shed) = (0u64, 0u64);
    for j in joins {
        let (d, s) = j.join().unwrap();
        done += d;
        shed += s;
    }
    assert_eq!(done + shed, 200);
    let s = server.snapshot("m").unwrap();
    assert_eq!(s.submitted, 200);
    assert_eq!(s.completed, done);
    assert_eq!(s.shed, shed);
    assert_eq!(s.failed, 0);
    assert_eq!(s.completed + s.shed, 200, "every submit resolves exactly once");
    assert_eq!(s.queue_depth, 0);
    assert_eq!(s.latency.count(), s.completed, "histogram counts completions only");
    server.shutdown();
}
