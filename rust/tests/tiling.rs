//! Overlap-save tiled frequency-domain execution: exactness properties.
//!
//! The tiled NTT arm must be **bit-identical** to the whole-image NTT
//! arm and to a nested-loop integer reference (all three compute exact
//! integers); the tiled FFT arm must agree with the whole-image FFT
//! within f64 roundoff. Boundary geometries (image smaller than the
//! tile, image exactly one tile, a one-pixel overlap remainder) are
//! pinned explicitly, and the engine-level plans are exercised through
//! `Workspace` with zero steady-state heap allocations.

use sfc::engine::exec::{conv2d_fft, conv2d_ntt_int8, ntt_corr2d_i8};
use sfc::engine::tiled::{
    conv2d_fft_tiled, default_tile_len, ntt_corr2d_i8_tiled,
};
use sfc::engine::{default_selector, ConvDesc, QuantSpec, Workspace};
use sfc::nn::conv::conv2d_direct;
use sfc::nn::Tensor;
use sfc::quant::qconv::{QCalib, QConvLayer};
use sfc::util::Pcg32;

fn rand_tensor(dims: &[usize], rng: &mut Pcg32, sigma: f64) -> Tensor {
    let mut t = Tensor::zeros(dims);
    rng.fill_gaussian(&mut t.data, sigma);
    t
}

fn rand_i8(len: usize, rng: &mut Pcg32) -> Vec<i8> {
    (0..len).map(|_| (rng.below(255) as i32 - 127) as i8).collect()
}

fn rel_mse(got: &Tensor, want: &Tensor) -> f64 {
    let denom =
        want.data.iter().map(|v| (*v as f64).powi(2)).sum::<f64>() / want.len().max(1) as f64;
    got.mse(want) / denom.max(1e-30)
}

/// Nested-loop i64 correlation — the ground truth both NTT arms must
/// reproduce exactly while `|y| < p/2`.
#[allow(clippy::too_many_arguments)]
fn naive_corr_i64(
    xq: &[i8],
    n: usize,
    ic: usize,
    h: usize,
    w: usize,
    wq: &[i8],
    oc: usize,
    r: usize,
    pad: usize,
) -> Vec<i64> {
    let oh = h + 2 * pad - r + 1;
    let ow = w + 2 * pad - r + 1;
    let mut out = vec![0i64; n * oc * oh * ow];
    for ni in 0..n {
        for o in 0..oc {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0i64;
                    for c in 0..ic {
                        for ky in 0..r {
                            let yy = oy + ky;
                            if yy < pad || yy >= h + pad {
                                continue;
                            }
                            for kx in 0..r {
                                let xx = ox + kx;
                                if xx < pad || xx >= w + pad {
                                    continue;
                                }
                                acc += xq[((ni * ic + c) * h + (yy - pad)) * w + (xx - pad)]
                                    as i64
                                    * wq[((o * ic + c) * r + ky) * r + kx] as i64;
                            }
                        }
                    }
                    out[((ni * oc + o) * oh + oy) * ow + ox] = acc;
                }
            }
        }
    }
    out
}

/// Property: over a randomized sweep of large kernels, paddings and
/// tile lengths, the tiled NTT arm equals the whole-image NTT arm and
/// the nested-loop reference bit for bit.
#[test]
fn property_tiled_ntt_bit_identical_over_sweep() {
    let mut rng = Pcg32::seeded(0x71D);
    for (h, w, ic, oc, r) in
        [(21usize, 18usize, 3usize, 2usize, 7usize), (17, 17, 2, 3, 11), (24, 13, 4, 2, 7)]
    {
        for pad in [0usize, r / 2] {
            let n = 2;
            let xq = rand_i8(n * ic * h * w, &mut rng);
            let wq = rand_i8(oc * ic * r * r, &mut rng);
            let naive = naive_corr_i64(&xq, n, ic, h, w, &wq, oc, r, pad);
            let whole = ntt_corr2d_i8(&xq, n, ic, h, w, &wq, oc, r, pad);
            assert_eq!(whole, naive, "whole-image NTT vs naive: {h}x{w} r{r} p{pad}");
            for tile in [16usize, 32, 64] {
                if tile < r {
                    continue;
                }
                let tiled = ntt_corr2d_i8_tiled(&xq, n, ic, h, w, &wq, oc, r, pad, tile);
                assert_eq!(tiled, naive, "tiled NTT: {h}x{w} r{r} p{pad} tile{tile}");
            }
        }
    }
}

/// Property: the float-entry tiled NTT arm is bit-identical to the
/// whole-image float-entry arm — both quantize with scales derived from
/// the full tensors, and the integer stage in between is exact.
#[test]
fn tiled_ntt_float_entry_bit_identical_to_whole_image() {
    let mut rng = Pcg32::seeded(0x71E);
    let x = rand_tensor(&[2, 3, 24, 20], &mut rng, 1.0);
    let w = rand_tensor(&[4, 3, 7, 7], &mut rng, 0.3);
    let bias = vec![0.1, -0.2, 0.0, 0.4];
    let want = conv2d_ntt_int8(&x, &w, &bias, 3);
    let sel = default_selector();
    let d = ConvDesc::new(2, 3, 4, 24, 20, 7, 1, 3);
    let plan = sel.plan_named("NTT-tiled", &d).expect("tiled NTT plans the descriptor");
    let got = plan.run(&x, &w, &bias);
    assert_eq!(got.dims, want.dims);
    assert_eq!(got.data, want.data, "tiled float-entry arm must be bit-identical");
}

/// Property: the tiled FFT arm agrees with the whole-image FFT within
/// f64 roundoff, and both agree with direct convolution.
#[test]
fn property_tiled_fft_within_whole_image_tolerance() {
    let mut rng = Pcg32::seeded(0x71F);
    for (h, w, r, pad, tile) in [
        (21usize, 18usize, 7usize, 3usize, 32usize),
        (30, 30, 11, 5, 64),
        (19, 23, 7, 0, 16),
    ] {
        let x = rand_tensor(&[2, 3, h, w], &mut rng, 1.0);
        let wt = rand_tensor(&[2, 3, r, r], &mut rng, 0.3);
        let bias = vec![0.3, -0.1];
        let whole = conv2d_fft(&x, &wt, &bias, pad);
        let tiled = conv2d_fft_tiled(&x, &wt, &bias, pad, tile);
        assert_eq!(tiled.dims, whole.dims);
        assert!(
            tiled.mse(&whole) < 1e-9,
            "{h}x{w} r{r} p{pad} t{tile}: mse vs whole {}",
            tiled.mse(&whole)
        );
        let direct = conv2d_direct(&x, &wt, &bias, 1, pad);
        assert!(rel_mse(&tiled, &direct) < 1e-10, "{h}x{w} r{r}: vs direct");
    }
}

/// Boundary geometries pinned: the padded image smaller than one tile
/// (a single partial block), exactly one tile (a single full block),
/// and a one-pixel valid remainder in the last block row/column.
#[test]
fn boundary_tile_geometries_are_exact() {
    let mut rng = Pcg32::seeded(0xB0);
    let (ic, oc, r) = (2usize, 2usize, 7usize);
    let tile = 16usize;
    let step = tile - r + 1; // 10 valid outputs per block axis
    // (h + 2·pad, oh) per case: smaller than the tile, exactly the
    // tile, and oh = step + 1 so the trailing block keeps one pixel.
    let cases = [
        (9usize, 1usize), // padded 11 < 16: one partial block
        (14, 1),          // padded 16 == tile: one full block, oh == step
        (15, 1),          // oh == step + 1: one-pixel overlap remainder
    ];
    for (h, pad) in cases {
        let oh = h + 2 * pad - r + 1;
        assert!(oh <= step + 1, "case picks at most a one-pixel remainder ({oh})");
        let n = 1;
        let xq = rand_i8(n * ic * h * h, &mut rng);
        let wq = rand_i8(oc * ic * r * r, &mut rng);
        let naive = naive_corr_i64(&xq, n, ic, h, h, &wq, oc, r, pad);
        let tiled = ntt_corr2d_i8_tiled(&xq, n, ic, h, h, &wq, oc, r, pad, tile);
        assert_eq!(tiled, naive, "h{h} pad{pad} tile{tile}");
        let x = rand_tensor(&[n, ic, h, h], &mut rng, 1.0);
        let w = rand_tensor(&[oc, ic, r, r], &mut rng, 0.3);
        let whole = conv2d_fft(&x, &w, &[], pad);
        let ftiled = conv2d_fft_tiled(&x, &w, &[], pad, tile);
        assert!(ftiled.mse(&whole) < 1e-9, "h{h} pad{pad}: {}", ftiled.mse(&whole));
    }
}

/// The tile length is kernel-derived: a power of two covering the
/// kernel with at least half of every block valid.
#[test]
fn default_tile_len_is_kernel_derived() {
    for r in [1usize, 3, 5, 7, 11, 13, 15] {
        let s = default_tile_len(r);
        assert!(s.is_power_of_two() && s >= r);
        assert!(s - r + 1 > s / 2, "r{r}: valid fraction of tile {s} too small");
    }
    assert_eq!(default_tile_len(11), 64);
}

/// Engine level: on a large-image large-kernel descriptor the
/// whole-image engines decline (kernel-plane cap) but the tiled engines
/// plan, run through a reused `Workspace` with zero steady-state heap
/// allocations, and match direct convolution.
#[test]
fn tiled_engines_bound_workspace_where_whole_image_declines() {
    let sel = default_selector();
    // padded 82 rounds to 128² whole-image planes: 16·16·128² > the
    // kernel-plane cap, while the tiled planes are 16·16·64² — inside it
    let d = ConvDesc::new(1, 16, 16, 72, 72, 11, 1, 5);
    assert!(sel.plan_named("FFT", &d).is_err(), "whole-image FFT must decline");
    assert!(sel.plan_named("NTT", &d).is_err(), "whole-image NTT must decline");
    let mut rng = Pcg32::seeded(0xAB);
    let x = rand_tensor(&[1, 16, 72, 72], &mut rng, 1.0);
    let w = rand_tensor(&[16, 16, 11, 11], &mut rng, 0.1);
    let want = conv2d_direct(&x, &w, &[], 1, 5);
    for name in ["FFT-tiled", "NTT-tiled"] {
        let plan = sel.plan_named(name, &d).unwrap_or_else(|e| panic!("{name}: {e}"));
        let mut ws = Workspace::new();
        let mut out = Tensor::zeros(&plan.out_dims(&x, &w));
        plan.run_into(&x, &w, &[], &mut ws, &mut out);
        let tol = if name == "FFT-tiled" { 1e-10 } else { 1e-3 };
        assert!(rel_mse(&out, &want) < tol, "{name}: rel mse {}", rel_mse(&out, &want));
        let warm = ws.heap_allocs();
        out.data.fill(f32::NAN);
        plan.run_into(&x, &w, &[], &mut ws, &mut out);
        assert!(rel_mse(&out, &want) < tol, "{name}: warm rerun");
        assert_eq!(ws.heap_allocs(), warm, "{name}: steady state must not allocate");
        assert_eq!(ws.in_use_bytes(), 0, "{name}: all buffers returned");
    }
}

/// The quantized spatial path dispatches the tiled kernel from the plan
/// and stays bit-identical to the whole-image NTT layer — both are
/// exact integer datapaths under identical calibration.
#[test]
fn quantized_spatial_ntt_tiled_matches_whole_image_layer() {
    let mut rng = Pcg32::seeded(0x51C);
    let spec = QuantSpec::spatial_default(8);
    let d = ConvDesc::new(1, 3, 4, 20, 20, 7, 1, 3).with_quant(spec);
    let x = rand_tensor(&[1, 3, 20, 20], &mut rng, 1.0);
    let w = rand_tensor(&[4, 3, 7, 7], &mut rng, 0.3);
    let sel = default_selector();
    let calib = QCalib::MaxAbs(x.max_abs());
    let qn = QConvLayer::from_plan(
        sel.plan_named("NTT", &d).unwrap(),
        &w,
        vec![0.1; 4],
        &calib,
    );
    let qt = QConvLayer::from_plan(
        sel.plan_named("NTT-tiled", &d).unwrap(),
        &w,
        vec![0.1; 4],
        &calib,
    );
    assert_eq!(qt.engine(), "NTT-tiled");
    let yn = qn.forward(&x);
    let yt = qt.forward(&x);
    assert_eq!(yt.dims, yn.dims);
    assert_eq!(yt.data, yn.data, "tiled quantized spatial arm must be bit-identical");
}
