//! Cross-module integration tests: algorithm engine ⇄ NN engine ⇄
//! quantizer ⇄ error model, plus property-style randomized invariants
//! (proptest is not vendored; we use seeded PCG32 sweeps with explicit
//! case counts, which gives the same coverage deterministically).

use sfc::algo::{catalog, direct_conv2d, sfc, winograd, Bilinear};
use sfc::linalg::{Frac, Mat};
use sfc::nn::conv::{conv2d_direct, conv2d_fast, FastConvPlan};
use sfc::nn::model::{resnet18_cfg, resnet_random};
use sfc::nn::Tensor;
use sfc::quant::calib::{dequantize_model, quantize_model, QuantConfig};
use sfc::util::Pcg32;

fn rand_tensor(dims: &[usize], rng: &mut Pcg32, sigma: f64) -> Tensor {
    let mut t = Tensor::zeros(dims);
    rng.fill_gaussian(&mut t.data, sigma);
    t
}

/// Property: every catalog algorithm is an exact linear-convolution
/// algorithm on random integer inputs (1-D bilinear identity).
#[test]
fn property_all_algorithms_exact_on_integers() {
    for spec in catalog() {
        let Some(a) = spec.bilinear() else { continue }; // FFT/NTT rows
        let mut rng = Pcg32::seeded(0xFEED + a.t as u64);
        for case in 0..25 {
            let x: Vec<Frac> =
                (0..a.input_len()).map(|_| Frac::int(rng.below(41) as i128 - 20)).collect();
            let f: Vec<Frac> = (0..a.r).map(|_| Frac::int(rng.below(41) as i128 - 20)).collect();
            let got = a.apply1d_exact(&x, &f);
            let want = sfc::algo::bilinear::direct_corr1d_exact(&x, &f);
            assert_eq!(got, want, "{} case {case}", spec.name);
        }
    }
}

/// Property: the tiled engine agrees with direct conv for random shapes,
/// channels and paddings (the 2-D nesting + tiling invariant).
#[test]
fn property_tiled_engine_matches_direct() {
    let mut rng = Pcg32::seeded(777);
    let algos = [sfc(6, 7, 3), sfc(6, 6, 3), sfc(4, 4, 3), winograd(4, 3), winograd(2, 3)];
    for case in 0..20 {
        let a = &algos[case % algos.len()];
        let n = 1 + rng.below(2) as usize;
        let ic = 1 + rng.below(5) as usize;
        let oc = 1 + rng.below(5) as usize;
        let h = 7 + rng.below(18) as usize;
        let w = 7 + rng.below(18) as usize;
        let pad = rng.below(2) as usize;
        if h + 2 * pad < a.input_len() || w + 2 * pad < a.input_len() {
            continue;
        }
        let x = rand_tensor(&[n, ic, h, w], &mut rng, 1.0);
        let wt = rand_tensor(&[oc, ic, 3, 3], &mut rng, 0.3);
        let plan = FastConvPlan::new(a.clone());
        let direct = conv2d_direct(&x, &wt, &[], 1, pad);
        let fast = conv2d_fast(&x, &wt, &[], &plan, pad);
        assert_eq!(direct.dims, fast.dims);
        let mse = direct.mse(&fast);
        assert!(mse < 1e-6, "case {case} {} {n}x{ic}x{h}x{w} pad{pad}: mse {mse}", a.name);
    }
}

/// Property: 2-D tile application is linear in both operands.
#[test]
fn property_bilinearity() {
    let a = sfc(6, 6, 3);
    let mut rng = Pcg32::seeded(31);
    let l = a.input_len();
    let mk = |rng: &mut Pcg32, n: usize| -> Mat {
        Mat::from_vec(n, n, (0..n * n).map(|_| rng.next_gaussian()).collect())
    };
    for _ in 0..10 {
        let x1 = mk(&mut rng, l);
        let x2 = mk(&mut rng, l);
        let f = mk(&mut rng, 3);
        let y1 = a.apply2d_f64(&x1, &f);
        let y2 = a.apply2d_f64(&x2, &f);
        let mut xs = x1.clone();
        for (v, w) in xs.data.iter_mut().zip(&x2.data) {
            *v = 2.5 * *v - 0.5 * w;
        }
        let ys = a.apply2d_f64(&xs, &f);
        for i in 0..ys.data.len() {
            let want = 2.5 * y1.data[i] - 0.5 * y2.data[i];
            assert!((ys.data[i] - want).abs() < 1e-9);
        }
    }
}

/// End-to-end PTQ on a real (random-weight) ResNet graph: the full
/// calibrate→quantize→evaluate→dequantize cycle across all three
/// algorithm families, checking the paper's error ordering.
#[test]
fn ptq_pipeline_error_ordering() {
    let mut model = resnet_random(&resnet18_cfg(), 5, 10);
    let mut rng = Pcg32::seeded(9);
    let x = rand_tensor(&[4, 3, 32, 32], &mut rng, 1.0);
    let fp32 = model.forward(&x);

    let mut mses = Vec::new();
    for cfg in [
        QuantConfig::direct_default(8),
        QuantConfig::sfc_default(8),
        QuantConfig::winograd_default(8),
    ] {
        quantize_model(&mut model, &x, &cfg);
        mses.push(model.forward(&x).mse(&fp32));
        dequantize_model(&mut model);
    }
    let (direct, sfc_m, wino) = (mses[0], mses[1], mses[2]);
    // §5/§6 shape: SFC ≈ direct ≤ Winograd (Winograd's κ amplifies error).
    assert!(sfc_m < wino, "SFC {sfc_m} < Winograd {wino}");
    assert!(direct < wino, "direct {direct} < Winograd {wino}");
    // and the model is restored exactly after dequantize
    assert!(model.forward(&x).mse(&fp32) < 1e-12);
}

/// The Fig.-4 trade-off surface: lowering bits lowers BOPs monotonically
/// and (weakly) raises error.
#[test]
fn bops_and_error_move_opposite() {
    use sfc::bops::model_gbops;
    use sfc::nn::model::model_conv_shapes;
    let mut model = resnet_random(&resnet18_cfg(), 6, 10);
    let shapes = model_conv_shapes(&model, 32);
    let algo = sfc(6, 7, 3);
    let mut rng = Pcg32::seeded(10);
    let x = rand_tensor(&[2, 3, 32, 32], &mut rng, 1.0);
    let fp32 = model.forward(&x);
    let mut last_gbops = f64::INFINITY;
    let mut errs = Vec::new();
    for bits in [8u32, 6, 4] {
        let g = model_gbops(&shapes, Some(&algo), bits as u64, bits as u64);
        assert!(g < last_gbops, "GBOPs must fall with bits");
        last_gbops = g;
        let cfg = QuantConfig::sfc_default(bits);
        quantize_model(&mut model, &x, &cfg);
        errs.push(model.forward(&x).mse(&fp32));
        dequantize_model(&mut model);
    }
    assert!(errs[2] > errs[0], "int4 error {} must exceed int8 {}", errs[2], errs[0]);
}

/// Serialization round trip through the on-disk formats used by the
/// build pipeline (weights + dataset), exercising the Python interop
/// boundary from the Rust side.
#[test]
fn artifact_formats_round_trip() {
    use sfc::data::synth;
    use sfc::nn::weights::WeightMap;
    let dir = std::env::temp_dir().join("sfc_integration");
    std::fs::create_dir_all(&dir).unwrap();

    let ds = synth::generate(30, 3);
    let dpath = dir.join("ds.bin");
    ds.save(&dpath).unwrap();
    let ds2 = sfc::data::Dataset::load(&dpath).unwrap();
    assert_eq!(ds.images, ds2.images);

    let mut wm = WeightMap::default();
    let mut rng = Pcg32::seeded(4);
    wm.insert("stem.w", rand_tensor(&[16, 3, 3, 3], &mut rng, 0.1));
    wm.insert("fc.b", rand_tensor(&[10], &mut rng, 0.1));
    let wpath = dir.join("w.w32");
    wm.save(&wpath).unwrap();
    let wm2 = WeightMap::load(&wpath).unwrap();
    assert_eq!(wm.tensors["stem.w"].data, wm2.tensors["stem.w"].data);
    std::fs::remove_dir_all(&dir).ok();
}

/// The engine must agree between a Bilinear built twice (determinism of
/// the constructor — matters because Python loads dumped matrices).
#[test]
fn constructor_is_deterministic() {
    for spec in catalog() {
        if !spec.is_bilinear() {
            continue; // FFT/NTT rows have no bilinear constructor
        }
        let a: Bilinear = spec.build();
        let b: Bilinear = spec.build();
        assert_eq!(a.bt, b.bt, "{}", spec.name);
        assert_eq!(a.g, b.g);
        assert_eq!(a.at, b.at);
    }
}

/// 2-D error harness consistency: fp16 ⊙ on the direct algorithm is tiny
/// relative to signal (sanity anchor for Table 1 normalization).
#[test]
fn direct_fp16_error_scale() {
    let d = sfc::algo::Bilinear::direct(3);
    let mse = sfc::error::measure_mse(&d, sfc::error::OdotFormat::Fp16, 500, 1);
    // products of N(0,1)·N(0,0.5²) rounded at 2^-11 relative
    assert!(mse > 0.0 && mse < 1e-5, "direct fp16 mse {mse}");
}

#[test]
fn iterative_conv_composes_with_engine() {
    // iterative large-kernel conv on a feature map produced by the engine
    let mut rng = Pcg32::seeded(123);
    let x = rand_tensor(&[1, 1, 40, 40], &mut rng, 1.0);
    let w = rand_tensor(&[1, 1, 3, 3], &mut rng, 0.3);
    let plan = FastConvPlan::new(sfc(6, 7, 3));
    let y = conv2d_fast(&x, &w, &[], &plan, 1);
    let feat = Mat::from_vec(40, 40, y.plane(0, 0).iter().map(|&v| v as f64).collect());
    let k = Mat::from_vec(13, 13, (0..169).map(|_| rng.next_gaussian()).collect());
    let got = sfc::algo::iterative::iterative_conv2d(&feat, &k, &sfc(6, 6, 5));
    let want = direct_conv2d(&feat, &k);
    for (a, b) in got.data.iter().zip(&want.data) {
        assert!((a - b).abs() < 1e-5);
    }
}

/// With trained weights (artifacts present), the Rust engine must be far
/// above chance on the held-out split — guards weight-format and layer
/// semantics drift against the JAX trainer.
#[test]
fn trained_model_accuracy_through_rust_engine() {
    if !std::path::Path::new("artifacts/resnet18.w32").exists() {
        eprintln!("(skipped: run `make artifacts`)");
        return;
    }
    let model = sfc::exp::load_model("artifacts", "resnet18").unwrap();
    let (images, labels) = sfc::exp::load_split("artifacts", "test", 64).unwrap();
    let acc = model.accuracy(&images, &labels);
    assert!(acc > 0.9, "trained resnet18 through the Rust engine: {acc}");
}

/// All three mini-ResNet weight files load and produce the right logit
/// shape (topology parity with the JAX trainer for 34/50 too).
#[test]
fn all_trained_models_load() {
    for name in ["resnet18", "resnet34", "resnet50"] {
        let path = format!("artifacts/{name}.w32");
        if !std::path::Path::new(&path).exists() {
            eprintln!("(skipped {name})");
            continue;
        }
        let model = sfc::exp::load_model("artifacts", name).unwrap();
        let x = Tensor::zeros(&[1, 3, 32, 32]);
        assert_eq!(model.forward(&x).dims, vec![1, 10, 1, 1], "{name}");
    }
}

/// The paper's §4.1 claim in matrix form: the 3-mult degree-1 product
/// matrices of Eq. 8/10 are exactly what the constructor derives.
#[test]
fn eq8_eq10_product_matrices() {
    use sfc::algo::circular::CircularConv;
    // For N=6 the paper's Eq. 8 gives o0 = m0 − m1, o1 = −m0 + m2 where
    // m = (a0w0, a1w1, (a0+a1)(w0+w1)). Verify on the component algebra by
    // multiplying two symbolic numbers both ways.
    let cc = CircularConv::new(6);
    // circular conv of delta with delta = delta (sanity on the full chain)
    let mut x = vec![Frac::ZERO; 6];
    x[0] = Frac::ONE;
    let y = cc.apply_exact(&x, &x);
    assert_eq!(y[0], Frac::ONE);
    for v in &y[1..] {
        assert!(v.is_zero());
    }
    // shift theorem: delta_1 ⊛ delta_1 = delta_2
    let mut d1 = vec![Frac::ZERO; 6];
    d1[1] = Frac::ONE;
    let y = cc.apply_exact(&d1, &d1);
    assert_eq!(y[2], Frac::ONE);
    assert_eq!(y.iter().filter(|v| !v.is_zero()).count(), 1);
}

/// Granularity lookup table resolves every (uv, oc) pair correctly.
#[test]
fn scale_group_resolution() {
    use sfc::quant::qconv::{Granularity, ScaleGroup};
    let t2 = 4;
    let oc = 3;
    let maxima: Vec<f32> = (0..t2 * oc).map(|i| (i + 1) as f32).collect();
    for gran in [Granularity::Tensor, Granularity::Freq, Granularity::Channel, Granularity::ChannelFreq] {
        let sg = ScaleGroup::from_maxima(gran, t2, oc, &maxima, 8);
        for uv in 0..t2 {
            for o in 0..oc {
                let s = sg.scale(uv, o);
                assert!(s > 0.0);
                // scale must cover this group's max value
                assert!(s * 127.0 + 1e-4 >= maxima[uv * oc + o], "{gran:?} uv={uv} o={o}");
            }
        }
    }
}

/// BOPs fall monotonically with bit-width for every algorithm.
#[test]
fn bops_monotonic_in_bits() {
    use sfc::bops::{direct_bops, fast_bops};
    use sfc::nn::model::ConvShape;
    let s = ConvShape { ic: 32, oc: 32, h: 28, w: 28, r: 3, stride: 1 };
    let a = sfc(6, 7, 3);
    let mut last_d = u64::MAX;
    let mut last_f = u64::MAX;
    for bits in [8u64, 6, 5, 4] {
        let d = direct_bops(&s, bits, bits).total();
        let f = fast_bops(&s, &a, bits, bits).total();
        assert!(d < last_d && f < last_f, "bits={bits}");
        last_d = d;
        last_f = f;
    }
}

/// FPGA resource model: DSPs scale linearly with parallelism, LUTs grow.
#[test]
fn fpga_resources_scale_with_parallelism() {
    use sfc::fpga::Accel;
    let a22 = Accel::from_bilinear("s", &sfc(6, 7, 3), 2, 2, 8).resources();
    let a44 = Accel::from_bilinear("s", &sfc(6, 7, 3), 4, 4, 8).resources();
    assert_eq!(a44.dsps, 4 * a22.dsps);
    assert!(a44.luts_k > a22.luts_k);
}

/// fp16 ⊙ rounding inside the 2-D apply matches elementwise rounding of
/// the transform-domain operands (hook-order invariant).
#[test]
fn error_hook_applies_to_transform_domain() {
    use sfc::util::round_fp16;
    let a = sfc(4, 4, 3);
    let mut rng = Pcg32::seeded(77);
    let l = a.input_len();
    let x = Mat::from_vec(l, l, (0..l * l).map(|_| rng.next_gaussian()).collect());
    let f = Mat::from_vec(3, 3, (0..9).map(|_| rng.next_gaussian()).collect());
    // identity hooks == plain apply
    let y1 = a.apply2d_with(&x, &f, &|v| v, &|v| v);
    let y2 = a.apply2d_f64(&x, &f);
    assert_eq!(y1.data, y2.data);
    // fp16 hooks change the result (rounding is actually happening)
    let y3 = a.apply2d_with(&x, &f, &|v| round_fp16(v as f32) as f64, &|v| v);
    assert!(y3.data.iter().zip(&y2.data).any(|(a, b)| a != b));
}
