//! Runtime + coordinator integration over the real AOT artifacts.
//! These tests need `make artifacts`; they skip (pass with a notice)
//! when the artifacts are absent so `cargo test` works at any stage.

use sfc::coordinator::{Server, ServerConfig};
use sfc::exp;
use sfc::runtime::Executor;
use std::path::{Path, PathBuf};

fn artifacts() -> Option<PathBuf> {
    if cfg!(not(feature = "pjrt")) {
        // the stub Executor can't load artifacts even when they exist
        eprintln!("(runtime_e2e skipped: built without the `pjrt` feature)");
        return None;
    }
    let p = PathBuf::from("artifacts");
    if p.join("resnet18_b1.hlo.txt").exists() && p.join("dataset_test.bin").exists() {
        Some(p)
    } else {
        eprintln!("(runtime_e2e skipped: run `make artifacts`)");
        None
    }
}

#[test]
fn pjrt_load_and_execute() {
    let Some(dir) = artifacts() else { return };
    let exe = Executor::load(&dir.join("resnet18_b1.hlo.txt"), &[1, 3, 32, 32], 10).unwrap();
    assert!(["host", "cpu"].contains(&exe.platform().to_lowercase().as_str()));
    let (images, _) = exp::load_split("artifacts", "test", 1).unwrap();
    let logits = exe.run(&images.data).unwrap();
    assert_eq!(logits.len(), 10);
    assert!(logits.iter().all(|v| v.is_finite()));
}

#[test]
fn pjrt_model_matches_rust_engine() {
    // The same trained weights through (a) the AOT JAX model and (b) the
    // Rust NN engine must agree — the strongest cross-layer check.
    let Some(dir) = artifacts() else { return };
    let exe = Executor::load(&dir.join("resnet18_b1.hlo.txt"), &[1, 3, 32, 32], 10).unwrap();
    let model = exp::load_model("artifacts", "resnet18").unwrap();
    let (images, _) = exp::load_split("artifacts", "test", 4).unwrap();
    let sample = 3 * 32 * 32;
    for i in 0..4 {
        let img = &images.data[i * sample..(i + 1) * sample];
        let jax_logits = exe.run(img).unwrap();
        let mut x = sfc::nn::Tensor::zeros(&[1, 3, 32, 32]);
        x.data.copy_from_slice(img);
        let rust_logits = model.forward(&x);
        for (a, b) in jax_logits.iter().zip(&rust_logits.data) {
            assert!((a - b).abs() < 1e-2, "sample {i}: jax {a} vs rust {b}");
        }
        // argmax agreement (what serving accuracy depends on)
        let am = |v: &[f32]| {
            v.iter().enumerate().max_by(|x, y| x.1.partial_cmp(y.1).unwrap()).unwrap().0
        };
        assert_eq!(am(&jax_logits), am(&rust_logits.data), "sample {i}");
    }
}

#[test]
fn pallas_sfc_artifact_matches_direct_artifact() {
    // The L1 proof: the Pallas-SFC model and the XLA-conv model compute
    // the same function.
    let Some(dir) = artifacts() else { return };
    if !dir.join("resnet18_sfc_b1.hlo.txt").exists() {
        eprintln!("(sfc artifact missing, skipped)");
        return;
    }
    let direct = Executor::load(&dir.join("resnet18_b1.hlo.txt"), &[1, 3, 32, 32], 10).unwrap();
    let sfc_exe = Executor::load(&dir.join("resnet18_sfc_b1.hlo.txt"), &[1, 3, 32, 32], 10).unwrap();
    let (images, _) = exp::load_split("artifacts", "test", 3).unwrap();
    let sample = 3 * 32 * 32;
    for i in 0..3 {
        let img = &images.data[i * sample..(i + 1) * sample];
        let a = direct.run(img).unwrap();
        let b = sfc_exe.run(img).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 5e-2, "sample {i}: {x} vs {y}");
        }
    }
}

#[test]
fn server_over_real_model() {
    let Some(dir) = artifacts() else { return };
    let hlo = dir.join("resnet18_b8.hlo.txt");
    if !hlo.exists() {
        return;
    }
    let (images, labels) = exp::load_split("artifacts", "test", 32).unwrap();
    let server = Server::start(
        move || Executor::load(&hlo, &[8, 3, 32, 32], 10),
        ServerConfig { batch_size: 8, queue_depth: 32, batch_timeout_ms: 2 },
    )
    .unwrap();
    let sample = 3 * 32 * 32;
    let handles: Vec<_> = (0..32)
        .map(|i| server.submit(images.data[i * sample..(i + 1) * sample].to_vec()).unwrap())
        .collect();
    let mut correct = 0;
    for (i, h) in handles.into_iter().enumerate() {
        let r = h.wait().unwrap();
        correct += (r.argmax == labels[i] as usize) as usize;
    }
    // trained model must be far above chance through the whole stack
    assert!(correct >= 16, "served accuracy {correct}/32 too low");
    server.shutdown();
}

#[test]
fn missing_artifact_path_errors() {
    let e = Executor::load(Path::new("artifacts/definitely_missing.hlo.txt"), &[1, 3, 32, 32], 10);
    assert!(e.is_err());
}
