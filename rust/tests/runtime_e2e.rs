//! Runtime + coordinator integration over the real AOT artifacts.
//! These tests need `make artifacts`; they skip (pass with a notice)
//! when the artifacts are absent so `cargo test` works at any stage.

use sfc::coordinator::{Server, ServerConfig};
use sfc::exp;
use sfc::runtime::Executor;
use std::path::{Path, PathBuf};

fn artifacts() -> Option<PathBuf> {
    if cfg!(not(feature = "pjrt")) {
        // the stub Executor can't load artifacts even when they exist
        eprintln!("(runtime_e2e skipped: built without the `pjrt` feature)");
        return None;
    }
    let p = PathBuf::from("artifacts");
    if p.join("resnet18_b1.hlo.txt").exists() && p.join("dataset_test.bin").exists() {
        Some(p)
    } else {
        eprintln!("(runtime_e2e skipped: run `make artifacts`)");
        None
    }
}

#[test]
fn pjrt_load_and_execute() {
    let Some(dir) = artifacts() else { return };
    let exe = Executor::load(&dir.join("resnet18_b1.hlo.txt"), &[1, 3, 32, 32], 10).unwrap();
    assert!(["host", "cpu"].contains(&exe.platform().to_lowercase().as_str()));
    let (images, _) = exp::load_split("artifacts", "test", 1).unwrap();
    let logits = exe.run(&images.data).unwrap();
    assert_eq!(logits.len(), 10);
    assert!(logits.iter().all(|v| v.is_finite()));
}

#[test]
fn pjrt_model_matches_rust_engine() {
    // The same trained weights through (a) the AOT JAX model and (b) the
    // Rust NN engine must agree — the strongest cross-layer check.
    let Some(dir) = artifacts() else { return };
    let exe = Executor::load(&dir.join("resnet18_b1.hlo.txt"), &[1, 3, 32, 32], 10).unwrap();
    let model = exp::load_model("artifacts", "resnet18").unwrap();
    let (images, _) = exp::load_split("artifacts", "test", 4).unwrap();
    let sample = 3 * 32 * 32;
    for i in 0..4 {
        let img = &images.data[i * sample..(i + 1) * sample];
        let jax_logits = exe.run(img).unwrap();
        let mut x = sfc::nn::Tensor::zeros(&[1, 3, 32, 32]);
        x.data.copy_from_slice(img);
        let rust_logits = model.forward(&x);
        for (a, b) in jax_logits.iter().zip(&rust_logits.data) {
            assert!((a - b).abs() < 1e-2, "sample {i}: jax {a} vs rust {b}");
        }
        // argmax agreement (what serving accuracy depends on)
        let am = |v: &[f32]| {
            v.iter().enumerate().max_by(|x, y| x.1.partial_cmp(y.1).unwrap()).unwrap().0
        };
        assert_eq!(am(&jax_logits), am(&rust_logits.data), "sample {i}");
    }
}

#[test]
fn pallas_sfc_artifact_matches_direct_artifact() {
    // The L1 proof: the Pallas-SFC model and the XLA-conv model compute
    // the same function.
    let Some(dir) = artifacts() else { return };
    if !dir.join("resnet18_sfc_b1.hlo.txt").exists() {
        eprintln!("(sfc artifact missing, skipped)");
        return;
    }
    let direct = Executor::load(&dir.join("resnet18_b1.hlo.txt"), &[1, 3, 32, 32], 10).unwrap();
    let sfc_exe = Executor::load(&dir.join("resnet18_sfc_b1.hlo.txt"), &[1, 3, 32, 32], 10).unwrap();
    let (images, _) = exp::load_split("artifacts", "test", 3).unwrap();
    let sample = 3 * 32 * 32;
    for i in 0..3 {
        let img = &images.data[i * sample..(i + 1) * sample];
        let a = direct.run(img).unwrap();
        let b = sfc_exe.run(img).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 5e-2, "sample {i}: {x} vs {y}");
        }
    }
}

#[test]
fn server_over_real_model() {
    let Some(dir) = artifacts() else { return };
    let hlo = dir.join("resnet18_b8.hlo.txt");
    if !hlo.exists() {
        return;
    }
    let (images, labels) = exp::load_split("artifacts", "test", 32).unwrap();
    let server = Server::start(
        move || Executor::load(&hlo, &[8, 3, 32, 32], 10),
        ServerConfig { batch_size: 8, queue_depth: 32, batch_timeout_ms: 2 },
    )
    .unwrap();
    let sample = 3 * 32 * 32;
    let handles: Vec<_> = (0..32)
        .map(|i| server.submit(images.data[i * sample..(i + 1) * sample].to_vec()).unwrap())
        .collect();
    let mut correct = 0;
    for (i, h) in handles.into_iter().enumerate() {
        let r = h.wait().unwrap();
        correct += (r.argmax == labels[i] as usize) as usize;
    }
    // trained model must be far above chance through the whole stack
    assert!(correct >= 16, "served accuracy {correct}/32 too low");
    server.shutdown();
}

#[test]
fn missing_artifact_path_errors() {
    let e = Executor::load(Path::new("artifacts/definitely_missing.hlo.txt"), &[1, 3, 32, 32], 10);
    assert!(e.is_err());
}

/// The workspace e2e property (needs no artifacts and no `pjrt`): a
/// server over the pure-Rust `EngineExecutor` keeps one `Workspace` per
/// worker, so once the first batch has warmed the pools, serving does
/// zero workspace heap allocations per request.
#[test]
fn engine_server_steady_state_is_alloc_free() {
    use sfc::engine::{default_selector, ConvDesc};
    use sfc::nn::graph::ConvParams;
    use sfc::nn::{Model, Op, Tensor};
    use sfc::runtime::EngineExecutor;
    use sfc::util::Pcg32;

    let mut rng = Pcg32::seeded(81);
    let mut rand_t = |dims: &[usize], sigma: f64| {
        let mut t = Tensor::zeros(dims);
        rng.fill_gaussian(&mut t.data, sigma);
        t
    };
    let mut m = Model::new("serve-toy");
    let inp = m.push(Op::Input, vec![], "input");
    let desc = ConvDesc::new(4, 3, 8, 8, 8, 3, 1, 1);
    let c1 = m.push(
        Op::Conv {
            params: ConvParams {
                weight: rand_t(&[8, 3, 3, 3], 0.3),
                bias: vec![0.1; 8],
                stride: 1,
                pad: 1,
            },
            plan: default_selector().plan(&desc).unwrap(),
            packed: None,
            quantized: None,
        },
        vec![inp],
        "conv1",
    );
    let r1 = m.push(Op::Relu, vec![c1], "relu1");
    let gap = m.push(Op::GlobalAvgPool, vec![r1], "gap");
    m.push(Op::Linear { weight: rand_t(&[10, 8], 0.5), bias: vec![0.0; 10] }, vec![gap], "fc");

    let exe = EngineExecutor::from_model(m, vec![4, 3, 8, 8], 10);
    let server = Server::start(
        move || Ok(exe),
        ServerConfig { batch_size: 4, queue_depth: 32, batch_timeout_ms: 1 },
    )
    .unwrap();
    let sample = 3 * 8 * 8;
    let submit_wait = |k: usize| {
        let handles: Vec<_> =
            (0..k).map(|_| server.submit(vec![0.5f32; sample]).unwrap()).collect();
        for h in handles {
            let r = h.wait().unwrap();
            assert_eq!(r.logits.len(), 10);
            assert!(r.logits.iter().all(|v| v.is_finite()));
        }
    };
    // warm-up: every batch has identical shapes, so one wave fills the pools
    submit_wait(8);
    let warm_allocs = server.ws_heap_allocs();
    assert!(warm_allocs > 0, "warm-up must have populated the workspace");
    assert!(server.ws_peak_bytes() > 0);
    // steady state: no new heap fallbacks across many more requests
    submit_wait(16);
    assert_eq!(
        server.ws_heap_allocs(),
        warm_allocs,
        "steady-state serving must perform zero workspace heap allocations"
    );
    // the process-wide mirror in coordinator::metrics saw the same traffic
    let (peak, allocs) = sfc::coordinator::metrics::workspace_counters();
    assert!(peak > 0 && allocs >= warm_allocs);
    server.shutdown();
}
