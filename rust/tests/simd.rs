//! SIMD↔scalar kernel-dispatch property tests (cross-layer).
//!
//! The kernel layer's contract is strong: every dispatch arm (AVX2 /
//! NEON / scalar) computes the identical float sequence — one
//! accumulator per output element, k ascending, no FMA contraction —
//! so executor outputs must be **bit-identical** across arms (0 ULP;
//! the int8 paths are exact integer arithmetic either way). These tests
//! pin that equivalence end-to-end on odd shapes that exercise every
//! remainder lane (panel width 8, micro-tile 4, int8 k-pairs), plus the
//! dispatch controls themselves (`set_kernel_override`,
//! `SFC_FORCE_SCALAR=1`).
//!
//! The override is process-global, and equality assertions hold under
//! any arm, so a mutex only guards the tests that *assert which* kernel
//! is active while they toggle it.

use sfc::engine::{default_selector, ConvDesc, PackedWeights, QuantSpec, Workspace};
use sfc::linalg::simd::{self, Kernel};
use sfc::nn::Tensor;
use sfc::quant::qconv::{collect_act_maxima, QCalib, QConvLayer};
use sfc::util::Pcg32;
use std::sync::Mutex;

/// Serializes tests that toggle the process-wide kernel override.
static OVERRIDE_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    OVERRIDE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn rand_tensor(dims: &[usize], rng: &mut Pcg32, sigma: f64) -> Tensor {
    let mut t = Tensor::zeros(dims);
    rng.fill_gaussian(&mut t.data, sigma);
    t
}

/// Run `f` once under the detected kernel and once with dispatch
/// pinned to scalar, returning both results.
fn both_arms<T>(mut f: impl FnMut() -> T) -> (T, T) {
    simd::set_kernel_override(None);
    let native = f();
    simd::set_kernel_override(Some(Kernel::Scalar));
    let scalar = f();
    simd::set_kernel_override(None);
    (native, scalar)
}

#[test]
fn override_controls_dispatch_and_env_pins_scalar() {
    let _g = lock();
    simd::set_kernel_override(Some(Kernel::Scalar));
    assert_eq!(simd::active_kernel(), Kernel::Scalar, "override must pin scalar");
    simd::set_kernel_override(None);
    assert_eq!(simd::active_kernel(), simd::detect(), "no override ⇒ detection");
    // the CI scalar arm runs the whole suite under SFC_FORCE_SCALAR=1;
    // detection (and therefore dispatch) must honor it
    if std::env::var("SFC_FORCE_SCALAR").map(|v| v == "1").unwrap_or(false) {
        assert_eq!(simd::detect(), Kernel::Scalar);
        assert_eq!(simd::active_kernel(), Kernel::Scalar);
        assert_eq!(simd::kernel_name(), "scalar");
    }
}

#[test]
fn fast_conv_bit_identical_across_dispatch_arms() {
    let _g = lock();
    let sel = default_selector();
    let mut rng = Pcg32::seeded(0x5151);
    // odd spatial sizes + odd channel counts: tile-group remainders
    // (n_tiles % 8 ≠ 0), panel remainders (ocg % 8 ≠ 0) and k
    // remainders all exercised; dense and grouped.
    for (ic, oc, groups, h, w) in
        [(3usize, 5usize, 1usize, 11usize, 13usize), (6, 9, 3, 9, 7), (5, 5, 5, 14, 10)]
    {
        let d = ConvDesc::new(2, ic, oc, h, w, 3, 1, 1).with_groups(groups);
        let x = rand_tensor(&[2, ic, h, w], &mut rng, 1.0);
        let wt = rand_tensor(&[oc, ic / groups, 3, 3], &mut rng, 0.3);
        let bias: Vec<f32> = (0..oc).map(|i| i as f32 * 0.05 - 0.1).collect();
        for name in ["SFC-6(6x6,3x3)", "Wino(4x4,3x3)", "im2col-gemm"] {
            let plan = sel.plan_named(name, &d).unwrap();
            let (native, scalar) = both_arms(|| plan.run(&x, &wt, &bias));
            assert_eq!(
                native.data, scalar.data,
                "{name} ic{ic} oc{oc} g{groups}: SIMD and scalar arms must be bit-identical"
            );
            // the pre-packed datapath agrees too, on both arms
            let packed = PackedWeights::pack(&plan, &wt);
            let (pn, ps) = both_arms(|| {
                let mut ws = Workspace::new();
                let mut out = Tensor::zeros(&plan.out_dims(&x, &wt));
                plan.run_packed_into(&x, &wt, &packed, &bias, &mut ws, &mut out);
                out
            });
            assert_eq!(pn.data, native.data, "{name}: packed vs per-call path");
            assert_eq!(ps.data, native.data, "{name}: packed scalar arm");
        }
    }
}

#[test]
fn int8_transform_path_bit_identical_across_dispatch_arms() {
    let _g = lock();
    let sel = default_selector();
    let mut rng = Pcg32::seeded(0x5152);
    // icg = 3 (odd k ⇒ the zero-padded k-pair tail), ocg = 5 (panel
    // remainder), 13×11 (tile-group remainder)
    for (ic, oc, groups) in [(3usize, 5usize, 1usize), (6, 4, 2)] {
        let d = ConvDesc::new(1, ic, oc, 13, 11, 3, 1, 1)
            .with_groups(groups)
            .with_quant(QuantSpec::transform_default(8));
        let x = rand_tensor(&[1, ic, 13, 11], &mut rng, 1.0);
        let wt = rand_tensor(&[oc, ic / groups, 3, 3], &mut rng, 0.3);
        let plan = sel.plan_named("SFC-6(6x6,3x3)", &d).unwrap();
        let maxima = collect_act_maxima(&x, plan.fast_plan().unwrap(), 1);
        let q = QConvLayer::from_plan(plan, &wt, vec![0.1; oc], &QCalib::TransformMaxima(&maxima));
        let (native, scalar) = both_arms(|| q.forward(&x));
        assert_eq!(
            native.data, scalar.data,
            "int8 ⊙ is exact integer arithmetic: arms must agree to the bit (g={groups})"
        );
    }
}

#[test]
fn spatial_int8_quantize_bit_identical_across_dispatch_arms() {
    let _g = lock();
    let sel = default_selector();
    let mut rng = Pcg32::seeded(0x5153);
    let d = ConvDesc::new(1, 3, 4, 10, 10, 3, 1, 1).with_quant(QuantSpec::spatial_default(8));
    let x = rand_tensor(&[1, 3, 10, 10], &mut rng, 1.0);
    let wt = rand_tensor(&[4, 3, 3, 3], &mut rng, 0.3);
    let plan = sel.plan_named("direct", &d).unwrap();
    let q = QConvLayer::from_plan(plan, &wt, vec![], &QCalib::MaxAbs(x.max_abs()));
    let (native, scalar) = both_arms(|| q.forward(&x));
    assert_eq!(native.data, scalar.data, "vectorized input quantize must match scalar");
}

#[test]
fn quantizer_matches_scalar_on_rounding_edges() {
    let _g = lock();
    // half-way points, sign flips, clamp range and a long random tail —
    // the exact cases where a round-to-nearest-even shortcut would
    // diverge from f32::round (half away from zero)
    let mut vals: Vec<f32> = vec![
        0.0, 0.5, -0.5, 1.5, -1.5, 2.5, -2.5, 126.5, -126.5, 127.49, -127.49, 200.0, -200.0,
        0.49999997, -0.49999997, 63.5, -63.5,
    ];
    let mut rng = Pcg32::seeded(0x5154);
    let mut tail = vec![0f32; 997]; // odd length: SIMD tail path runs
    rng.fill_gaussian(&mut tail, 40.0);
    vals.extend(tail);
    for scale in [1.0f32, 0.37, 0.013] {
        let scaled: Vec<f32> = vals.iter().map(|v| v * scale).collect();
        let mut want = vec![0i8; scaled.len()];
        simd::quantize_i8_slice_scalar(&scaled, scale, 127, &mut want);
        let (native, scalar) = both_arms(|| {
            let mut got = vec![0i8; scaled.len()];
            simd::quantize_i8_slice(&scaled, scale, 127, &mut got);
            got
        });
        assert_eq!(native, want, "scale {scale}: dispatched quantize drifted from scalar");
        assert_eq!(scalar, want, "scale {scale}: scalar arm must be the reference");
    }
}

#[test]
fn model_forward_identical_across_dispatch_arms_with_prepack() {
    let _g = lock();
    use sfc::nn::model::{mobilenet_cfg, mobilenet_random};
    let mut m = mobilenet_random(&mobilenet_cfg(), 21, 10);
    let added = m.prepack_weights();
    assert!(added > 0, "the depthwise model has fast-conv layers to pre-pack");
    assert_eq!(m.prepack_weights(), 0, "prepack must be idempotent");
    let mut rng = Pcg32::seeded(22);
    let x = rand_tensor(&[2, 3, 32, 32], &mut rng, 1.0);
    let (native, scalar) = both_arms(|| {
        let mut ws = Workspace::new();
        m.forward_ws(&x, &mut ws)
    });
    assert_eq!(native.data, scalar.data, "whole-model forward must not depend on the arm");
    // and the packed forward matches the unpacked forward_all reference
    let want = m.forward_all(&x).pop().unwrap();
    assert_eq!(native.data, want.data, "pre-packed forward_ws vs forward_all");
}
