//! Thread-count invariance property tests (cross-layer).
//!
//! The threaded GEMM macro-kernel partitions C by rows; every worker
//! runs the same per-element k-ascending accumulation the serial kernel
//! runs, so outputs must be **bit-identical** for any `SFC_THREADS`
//! (float: 0 ULP; int8: exact integers) on every dispatch arm. These
//! tests pin that contract from the raw GEMM entry points up through
//! conv plans, the quantized executor, a whole-model forward and a
//! `MultiServer` batch under a constrained `CoreBudget`.
//!
//! The thread/kernel/budget overrides are process-global, so every test
//! here serializes behind one lock (mirrors `tests/simd.rs`).

use sfc::coordinator::metrics;
use sfc::coordinator::sched::{MultiServer, Response, SchedConfig};
use sfc::engine::{default_selector, ConvDesc, QuantSpec, Workspace};
use sfc::linalg::gemm::{
    self, gemm_nt_f32, gemm_nt_i8_i32, gemm_packed_f32, gemm_packed_i8_i32, pack_b_f32,
    pack_b_i8, packed_b_f32_len, packed_b_i8_len,
};
use sfc::linalg::simd::{self, Kernel};
use sfc::nn::Tensor;
use sfc::quant::qconv::{collect_act_maxima, QCalib, QConvLayer};
use sfc::util::par::{self, CoreBudget};
use sfc::util::Pcg32;
use std::sync::Mutex;

/// Serializes tests that toggle the process-wide thread / kernel /
/// budget overrides.
static OVERRIDE_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    OVERRIDE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// The thread counts the suite sweeps: serial, even split, and a prime
/// count that never divides the row counts (remainder partitions).
const THREADS: [usize; 3] = [1, 2, 7];

fn with_threads<T>(t: usize, f: impl FnOnce() -> T) -> T {
    par::set_thread_override(Some(t));
    let r = f();
    par::set_thread_override(None);
    r
}

fn with_kernel<T>(k: Option<Kernel>, f: impl FnOnce() -> T) -> T {
    simd::set_kernel_override(k);
    let r = f();
    simd::set_kernel_override(None);
    r
}

fn rand_tensor(dims: &[usize], rng: &mut Pcg32, sigma: f64) -> Tensor {
    let mut t = Tensor::zeros(dims);
    rng.fill_gaussian(&mut t.data, sigma);
    t
}

fn rand_f32(n: usize, rng: &mut Pcg32) -> Vec<f32> {
    let mut v = vec![0f32; n];
    rng.fill_gaussian(&mut v, 1.0);
    v
}

fn rand_i8(n: usize, rng: &mut Pcg32) -> Vec<i8> {
    (0..n).map(|_| (rng.next_u32() & 0xff) as u8 as i8).collect()
}

/// Raw GEMM entries: every (thread count × dispatch arm) combination
/// must reproduce the serial scalar result to the bit. The shape list
/// mixes remainder-heavy sizes (m,n,k not multiples of MR/NR/panel
/// width, k odd and k = 1 for the int8 pair tail) with one shape above
/// `PAR_MIN_MACS` so the row-parallel path actually runs.
#[test]
fn gemm_entries_bit_identical_across_thread_counts_and_arms() {
    let _g = lock();
    let mut rng = Pcg32::seeded(0x7E57);
    let big = (64usize, 256usize, 130usize);
    assert!(
        (big.0 * big.1 * big.2) as u64 >= gemm::PAR_MIN_MACS,
        "the big shape must clear the threading gate"
    );
    for (m, n, k) in [(5usize, 7usize, 9usize), (13, 6, 1), (33, 17, 23), big] {
        let a = rand_f32(m * k, &mut rng);
        let b = rand_f32(n * k, &mut rng);
        let mut bp = vec![0f32; packed_b_f32_len(n, k)];
        pack_b_f32(n, k, &b, &mut bp);
        let ai = rand_i8(m * k, &mut rng);
        let bi = rand_i8(n * k, &mut rng);
        let mut bpi = vec![0i8; packed_b_i8_len(n, k)];
        pack_b_i8(n, k, &bi, &mut bpi);

        // reference: one thread, scalar kernels
        let (rf, rpf, ri, rpi) = with_threads(1, || {
            with_kernel(Some(Kernel::Scalar), || {
                let mut c = vec![0f32; m * n];
                gemm_nt_f32(m, n, k, &a, &b, &mut c);
                let mut cp = vec![0f32; m * n];
                gemm_packed_f32(m, n, k, &a, &bp, &mut cp);
                let mut ci = vec![0i32; m * n];
                gemm_nt_i8_i32(m, n, k, &ai, &bi, &mut ci);
                let mut cpi = vec![0i32; m * n];
                gemm_packed_i8_i32(m, n, k, &ai, &bpi, &mut cpi);
                (c, cp, ci, cpi)
            })
        });
        assert_eq!(rf, rpf, "({m},{n},{k}): packed f32 vs nt f32 reference");

        for t in THREADS {
            for arm in [None, Some(Kernel::Scalar)] {
                let (c, cp, ci, cpi) = with_threads(t, || {
                    with_kernel(arm, || {
                        let mut c = vec![0f32; m * n];
                        gemm_nt_f32(m, n, k, &a, &b, &mut c);
                        let mut cp = vec![0f32; m * n];
                        gemm_packed_f32(m, n, k, &a, &bp, &mut cp);
                        let mut ci = vec![0i32; m * n];
                        gemm_nt_i8_i32(m, n, k, &ai, &bi, &mut ci);
                        let mut cpi = vec![0i32; m * n];
                        gemm_packed_i8_i32(m, n, k, &ai, &bpi, &mut cpi);
                        (c, cp, ci, cpi)
                    })
                });
                let tag = format!("({m},{n},{k}) threads={t} arm={arm:?}");
                assert_eq!(c, rf, "{tag}: nt f32");
                assert_eq!(cp, rpf, "{tag}: packed f32");
                assert_eq!(ci, ri, "{tag}: nt i8");
                assert_eq!(cpi, rpi, "{tag}: packed i8");
            }
        }
    }
}

/// Blocking overrides compose with threading: sweeping the Mc/Kc/Nc
/// candidates under 7 threads still reproduces the default-blocking
/// serial result bit-for-bit (kc splits continue the same add chain).
#[test]
fn blocking_candidates_bit_identical_under_threads() {
    let _g = lock();
    let mut rng = Pcg32::seeded(0x7E58);
    let (m, n, k) = (65usize, 34usize, 77usize);
    let a = rand_f32(m * k, &mut rng);
    let b = rand_f32(n * k, &mut rng);
    let mut bp = vec![0f32; packed_b_f32_len(n, k)];
    pack_b_f32(n, k, &b, &mut bp);
    let want = with_threads(1, || {
        let mut c = vec![0f32; m * n];
        gemm_packed_f32(m, n, k, &a, &bp, &mut c);
        c
    });
    for blk in gemm::Blocking::candidates() {
        gemm::set_blocking_override(Some(blk));
        let got = with_threads(7, || {
            let mut c = vec![0f32; m * n];
            gemm_packed_f32(m, n, k, &a, &bp, &mut c);
            c
        });
        gemm::set_blocking_override(None);
        assert_eq!(got, want, "blocking {blk:?} under 7 threads drifted");
    }
}

/// Conv plans (im2col, Winograd, SFC — the GEMM-backed engines) on
/// remainder-heavy shapes plus one shape big enough to thread its
/// GEMM: bitwise identical across thread counts on both dispatch arms.
#[test]
fn conv_plans_bit_identical_across_thread_counts() {
    let _g = lock();
    let sel = default_selector();
    let mut rng = Pcg32::seeded(0x7E59);
    // (batch, ic, oc, groups, h, w): odd channels/sizes exercise panel
    // and tile remainders; the last shape's im2col GEMM (32×784×288 ≈
    // 7.2 MMACs) clears the threading gate.
    for (batch, ic, oc, groups, h, w) in
        [(2usize, 3usize, 5usize, 1usize, 11, 13), (1, 6, 9, 3, 9, 7), (1, 32, 32, 1, 28, 28)]
    {
        let d = ConvDesc::new(batch, ic, oc, h, w, 3, 1, 1).with_groups(groups);
        let x = rand_tensor(&[batch, ic, h, w], &mut rng, 1.0);
        let wt = rand_tensor(&[oc, ic / groups, 3, 3], &mut rng, 0.3);
        let bias: Vec<f32> = (0..oc).map(|i| i as f32 * 0.05 - 0.1).collect();
        for name in ["im2col-gemm", "SFC-6(6x6,3x3)", "Wino(4x4,3x3)"] {
            let plan = sel.plan_named(name, &d).unwrap();
            let want = with_threads(1, || {
                with_kernel(Some(Kernel::Scalar), || plan.run(&x, &wt, &bias))
            });
            for t in THREADS {
                for arm in [None, Some(Kernel::Scalar)] {
                    let got =
                        with_threads(t, || with_kernel(arm, || plan.run(&x, &wt, &bias)));
                    assert_eq!(
                        got.data, want.data,
                        "{name} {h}x{w} g{groups} threads={t} arm={arm:?}"
                    );
                }
            }
        }
    }
}

/// The int8 transform-domain executor: exact integer GEMM cores, so the
/// outputs are identical (not merely close) for any thread count × arm.
#[test]
fn int8_qconv_bit_identical_across_thread_counts() {
    let _g = lock();
    let sel = default_selector();
    let mut rng = Pcg32::seeded(0x7E5A);
    for (ic, oc, groups) in [(3usize, 5usize, 1usize), (6, 4, 2)] {
        let d = ConvDesc::new(1, ic, oc, 13, 11, 3, 1, 1)
            .with_groups(groups)
            .with_quant(QuantSpec::transform_default(8));
        let x = rand_tensor(&[1, ic, 13, 11], &mut rng, 1.0);
        let wt = rand_tensor(&[oc, ic / groups, 3, 3], &mut rng, 0.3);
        let plan = sel.plan_named("SFC-6(6x6,3x3)", &d).unwrap();
        let maxima = collect_act_maxima(&x, plan.fast_plan().unwrap(), 1);
        let q = QConvLayer::from_plan(plan, &wt, vec![0.1; oc], &QCalib::TransformMaxima(&maxima));
        let want = with_threads(1, || with_kernel(Some(Kernel::Scalar), || q.forward(&x)));
        for t in THREADS {
            for arm in [None, Some(Kernel::Scalar)] {
                let got = with_threads(t, || with_kernel(arm, || q.forward(&x)));
                assert_eq!(got.data, want.data, "int8 g{groups} threads={t} arm={arm:?}");
            }
        }
    }
}

/// Whole-model `forward_ws` (pre-packed weights, compiled-style
/// datapath): 1 vs 7 threads, both dispatch arms, bit-identical.
#[test]
fn whole_model_forward_thread_invariant() {
    let _g = lock();
    use sfc::nn::model::{mobilenet_cfg, mobilenet_random};
    let mut m = mobilenet_random(&mobilenet_cfg(), 21, 10);
    m.prepack_weights();
    let mut rng = Pcg32::seeded(23);
    let x = rand_tensor(&[2, 3, 32, 32], &mut rng, 1.0);
    let want = with_threads(1, || {
        with_kernel(Some(Kernel::Scalar), || {
            let mut ws = Workspace::new();
            m.forward_ws(&x, &mut ws)
        })
    });
    for t in [1usize, 7] {
        for arm in [None, Some(Kernel::Scalar)] {
            let got = with_threads(t, || {
                with_kernel(arm, || {
                    let mut ws = Workspace::new();
                    m.forward_ws(&x, &mut ws)
                })
            });
            assert_eq!(got.data, want.data, "forward_ws threads={t} arm={arm:?}");
        }
    }
}

/// Exact CoreBudget accounting: under the suite lock nothing else
/// leases concurrently (GEMM teams are scoped and joined), so leased
/// counts are deterministic relative to the starting level.
#[test]
fn core_budget_exact_accounting() {
    let _g = lock();
    let (_, before, _) = CoreBudget::snapshot();
    CoreBudget::set_total(Some(before + 3));
    {
        let l = CoreBudget::lease(8);
        assert_eq!(l.threads(), 3, "grant capped by the remaining headroom");
        let (_, leased, peak) = CoreBudget::snapshot();
        assert_eq!(leased, before + 3);
        assert!(peak >= before + 3);
        // nested lease on the counted thread: no headroom, no re-count
        let inner = CoreBudget::lease(4);
        assert_eq!(inner.threads(), 1, "exhausted budget degrades to serial");
        drop(inner);
    }
    let (_, leased, _) = CoreBudget::snapshot();
    assert_eq!(leased, before, "lanes returned on drop");
    CoreBudget::set_total(None);
}

/// `MultiServer` with 2 resident models and intra-op threading enabled
/// under `CoreBudget::set_total(2)`: each worker holds one lane for its
/// lifetime and the GEMM teams may only lease the remainder, so the
/// peak concurrent-lane count never exceeds the budget — observable
/// through `metrics::core_budget()` (the acceptance metric).
#[test]
fn multiserver_stays_within_core_budget() {
    let _g = lock();
    let (_, before, _) = CoreBudget::snapshot();
    let total = before + 2;
    CoreBudget::set_total(Some(total));
    let server = MultiServer::new(SchedConfig {
        queue_depth: 16,
        default_deadline_ms: 60_000,
        linger_ms: 1,
        packed_budget_bytes: 0,
        dispatch: sfc::coordinator::DispatchMode::Worker,
    });
    for name in ["a", "b"] {
        server
            .add_model(name, move || {
                use sfc::nn::model::{mobilenet_cfg, mobilenet_random};
                let m = mobilenet_random(&mobilenet_cfg(), 31, 10);
                Ok(sfc::runtime::EngineExecutor::from_model(m, vec![2, 3, 32, 32], 10))
            })
            .unwrap();
    }
    CoreBudget::reset_peak();
    let mut rng = Pcg32::seeded(0x7E5B);
    let mut handles = Vec::new();
    for i in 0..8 {
        let mut img = vec![0f32; 3 * 32 * 32];
        rng.fill_gaussian(&mut img, 1.0);
        let name = if i % 2 == 0 { "a" } else { "b" };
        handles.push(server.submit_blocking(name, img).unwrap());
    }
    for h in handles {
        match h.wait().unwrap() {
            Response::Done(_) => {}
            other => panic!("request did not complete: {other:?}"),
        }
    }
    let (t, leased, peak) = metrics::core_budget();
    assert_eq!(t, total);
    assert!(
        leased >= before + 2,
        "both resident workers hold their lifetime lanes ({leased})"
    );
    assert!(peak <= total, "peak {peak} lanes exceeded the budget of {total}");
    server.shutdown();
    CoreBudget::set_total(None);
}
