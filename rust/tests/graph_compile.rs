//! Graph-compiler property tests: epilogue fusion bit-identity (float
//! and int8, dense/grouped/depthwise, every engine), the int8 requant
//! chain's fixed-point rounding contract (and scalar-vs-SIMD
//! bit-identity), compiled-vs-uncompiled model agreement on the
//! ResNet/MobileNet configs, and the compiled int8 MobileNet through
//! the server path with zero steady-state workspace allocations.
//!
//! Several tests read process-global state (the dequantize counter,
//! the kernel-dispatch override); `GLOBAL_LOCK` serializes them within
//! this binary.

use sfc::engine::{
    all_engines, default_selector, ConvDesc, ConvEngine, Epilogue, QuantSpec, Workspace,
};
use sfc::nn::model::{mobilenet_cfg, mobilenet_random, resnet18_cfg, resnet_random};
use sfc::nn::Tensor;
use sfc::quant::qconv::{collect_act_maxima, QCalib, QConvLayer};
use sfc::quant::{dequant_materializations, quantize_model, QParams, QTensor, QuantConfig};
use sfc::util::Pcg32;
use std::sync::Mutex;

static GLOBAL_LOCK: Mutex<()> = Mutex::new(());

fn rand_tensor(dims: &[usize], rng: &mut Pcg32, sigma: f64) -> Tensor {
    let mut t = Tensor::zeros(dims);
    rng.fill_gaussian(&mut t.data, sigma);
    t
}

/// Standalone ReLU with the graph kernel's exact comparison.
fn relu_ref(t: &mut Tensor) {
    for v in t.data.iter_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// (a), float arm: for every engine × dense/grouped/depthwise geometry
/// it supports, the fused conv+ReLU epilogue is bit-identical to the
/// unfused conv followed by a standalone ReLU pass.
#[test]
fn fused_conv_relu_bit_identical_float_all_engines() {
    let mut rng = Pcg32::seeded(0xF0);
    let geoms = [
        ("dense", ConvDesc::new(1, 8, 8, 12, 12, 3, 1, 1)),
        ("groups=2", ConvDesc::new(1, 8, 8, 12, 12, 3, 1, 1).with_groups(2)),
        ("depthwise", ConvDesc::new(1, 8, 8, 12, 12, 3, 1, 1).with_groups(8)),
    ];
    for (label, d) in geoms {
        let x = rand_tensor(&[1, d.ic, d.h, d.w], &mut rng, 1.0);
        let w = rand_tensor(&[d.oc, d.ic / d.groups, d.r, d.r], &mut rng, 0.3);
        // negative biases guarantee the ReLU actually clamps something
        let bias: Vec<f32> = (0..d.oc).map(|i| -0.4 + 0.05 * i as f32).collect();
        for e in all_engines() {
            if !e.supports(&d) {
                continue;
            }
            let plain = e.plan(&d).unwrap();
            let fused = e.plan(&d.with_epilogue(Epilogue::Relu)).unwrap();
            let mut want = plain.run(&x, &w, &bias);
            assert!(want.data.iter().any(|v| *v < 0.0), "{label} {}: nothing to clamp", e.name());
            relu_ref(&mut want);
            let got = fused.run(&x, &w, &bias);
            assert_eq!(got.data, want.data, "{label} {}: fused epilogue drifted", e.name());
        }
    }
}

/// (a), int8 arm: the fused epilogue on quantized executors (spatial
/// direct, spatial NTT, transform-domain SFC; dense/grouped/depthwise
/// where supported) is bit-identical to the unfused quantized conv
/// followed by a standalone ReLU.
#[test]
fn fused_conv_relu_bit_identical_int8() {
    let _g = GLOBAL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let mut rng = Pcg32::seeded(0xF1);
    let sel = default_selector();
    // spatial scheme on direct (all geometries) + NTT (dense)
    let spatial = QuantSpec::spatial_default(8);
    let cases = [
        ("direct", ConvDesc::new(1, 8, 8, 12, 12, 3, 1, 1).with_quant(spatial)),
        ("direct", ConvDesc::new(1, 8, 8, 12, 12, 3, 1, 1).with_groups(2).with_quant(spatial)),
        ("direct", ConvDesc::new(1, 8, 8, 12, 12, 3, 1, 1).with_groups(8).with_quant(spatial)),
        ("NTT", ConvDesc::new(1, 8, 8, 12, 12, 3, 1, 1).with_quant(spatial)),
        (
            "SFC-6(6x6,3x3)",
            ConvDesc::new(1, 8, 8, 12, 12, 3, 1, 1)
                .with_quant(QuantSpec::transform_default(8)),
        ),
        (
            "SFC-6(6x6,3x3)",
            ConvDesc::new(1, 8, 8, 12, 12, 3, 1, 1)
                .with_groups(8)
                .with_quant(QuantSpec::transform_default(8)),
        ),
    ];
    for (engine, d) in cases {
        let x = rand_tensor(&[1, d.ic, d.h, d.w], &mut rng, 1.0);
        let w = rand_tensor(&[d.oc, d.ic / d.groups, d.r, d.r], &mut rng, 0.3);
        let bias: Vec<f32> = (0..d.oc).map(|i| -0.3 + 0.04 * i as f32).collect();
        let plain = sel.plan_named(engine, &d).unwrap();
        let fused = sel.plan_named(engine, &d.with_epilogue(Epilogue::Relu)).unwrap();
        let build = |plan: std::sync::Arc<sfc::engine::ConvPlan>| -> QConvLayer {
            match plan.fast_plan() {
                Some(fast) => {
                    let maxima = collect_act_maxima(&x, fast, d.pad);
                    QConvLayer::from_plan(plan, &w, bias.clone(), &QCalib::TransformMaxima(&maxima))
                }
                None => QConvLayer::from_plan(plan, &w, bias.clone(), &QCalib::MaxAbs(x.max_abs())),
            }
        };
        let q_plain = build(plain);
        let q_fused = build(fused);
        let mut want = q_plain.forward(&x);
        assert!(want.data.iter().any(|v| *v < 0.0), "{engine} g{}: nothing to clamp", d.groups);
        relu_ref(&mut want);
        let got = q_fused.forward(&x);
        assert_eq!(got.data, want.data, "{engine} g{}: fused int8 epilogue drifted", d.groups);
    }
}

/// Build a calibrated spatial int8 layer + the output quantizer of a
/// hypothetical consumer, for the requant-contract tests.
fn spatial_layer(
    engine: &str,
    d: ConvDesc,
    x: &Tensor,
    w: &Tensor,
    bias: Vec<f32>,
) -> (QConvLayer, QParams) {
    let plan = default_selector().plan_named(engine, &d).unwrap();
    let q = QConvLayer::from_plan(plan, w, bias, &QCalib::MaxAbs(x.max_abs()));
    // consumer input quantizer calibrated on the layer's own output
    let y = q.forward(x);
    let out_qp = QParams::from_max_abs(y.max_abs(), 8);
    (q, out_qp)
}

/// (b): the integer requant chain matches the dequantize→quantize
/// reference within one output code (the ≤1-ulp fixed-point rounding
/// contract), with and without the fused ReLU, and the NTT spatial
/// path produces bit-identical int8 codes to the direct path.
#[test]
fn requant_chain_matches_dequant_quantize_reference() {
    let _g = GLOBAL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let mut rng = Pcg32::seeded(0xB2);
    for ep in [Epilogue::None, Epilogue::Relu] {
        let d = ConvDesc::new(2, 4, 6, 10, 10, 3, 1, 1)
            .with_epilogue(ep)
            .with_quant(QuantSpec::spatial_default(8));
        let x = rand_tensor(&[2, 4, 10, 10], &mut rng, 1.0);
        let w = rand_tensor(&[6, 4, 3, 3], &mut rng, 0.3);
        let bias: Vec<f32> = (0..6).map(|i| 0.1 * i as f32 - 0.2).collect();
        let (mut q, out_qp) = spatial_layer("direct", d, &x, &w, bias.clone());
        let yf = q.forward(&x); // f32 reference (epilogue applied)
        assert!(q.install_requant(out_qp));
        let mut ws = Workspace::new();
        let mut qt = QTensor {
            dims: q.out_dims(&x),
            data: vec![0i8; yf.len()],
            scale: 0.0,
        };
        q.forward_into_q(&x, &mut ws, &mut qt);
        assert_eq!(qt.scale, out_qp.scale);
        for (i, (&code, &yv)) in qt.data.iter().zip(&yf.data).enumerate() {
            let want = out_qp.quantize(yv);
            assert!(
                (code as i32 - want).abs() <= 1,
                "elem {i} ({ep:?}): int8 chain {code} vs reference {want} (y {yv})"
            );
        }
        // the NTT spatial path shares the exact accumulators and the
        // same requant sweep, so its codes must match to the bit
        let dn = ConvDesc::new(2, 4, 6, 10, 10, 3, 1, 1)
            .with_epilogue(ep)
            .with_quant(QuantSpec::spatial_default(8));
        let (mut qn, _) = spatial_layer("NTT", dn, &x, &w, bias);
        assert!(qn.install_requant(out_qp));
        let mut qt2 = QTensor {
            dims: qn.out_dims(&x),
            data: vec![0i8; yf.len()],
            scale: 0.0,
        };
        qn.forward_into_q(&x, &mut ws, &mut qt2);
        assert_eq!(qt.data, qt2.data, "NTT vs direct int8 codes ({ep:?})");
    }
}

/// (b), dispatch arms: the whole int8-producing layer is bit-identical
/// between the scalar and dispatched (SIMD, where present) kernels.
#[test]
fn requant_chain_bit_identical_across_dispatch_arms() {
    let _g = GLOBAL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    use sfc::linalg::simd::{self, Kernel};
    let mut rng = Pcg32::seeded(0xB3);
    let d = ConvDesc::new(1, 4, 4, 11, 9, 3, 1, 1).with_quant(QuantSpec::spatial_default(8));
    let x = rand_tensor(&[1, 4, 11, 9], &mut rng, 1.0);
    let w = rand_tensor(&[4, 4, 3, 3], &mut rng, 0.3);
    let (mut q, out_qp) = spatial_layer("direct", d, &x, &w, vec![0.05, -0.1, 0.2, 0.0]);
    assert!(q.install_requant(out_qp));
    let run = || {
        let mut ws2 = Workspace::new();
        let mut qt = QTensor {
            dims: q.out_dims(&x),
            data: vec![0i8; q.out_dims(&x).iter().product()],
            scale: 0.0,
        };
        q.forward_into_q(&x, &mut ws2, &mut qt);
        qt.data
    };
    let dispatched = run();
    simd::set_kernel_override(Some(Kernel::Scalar));
    let scalar = run();
    simd::set_kernel_override(None);
    assert_eq!(dispatched, scalar, "requant output depends on the dispatch arm");
}

/// (c), float arm: compiling (epilogue fusion + AddRelu + DCE) is
/// bit-identical end-to-end on the ResNet-18 and MobileNet configs,
/// and fuses the expected node counts.
#[test]
fn compiled_equals_uncompiled_float_models() {
    let mut rng = Pcg32::seeded(0xC0);
    let x = rand_tensor(&[2, 3, 32, 32], &mut rng, 1.0);

    let mut resnet = resnet_random(&resnet18_cfg(), 21, 10);
    let want = resnet.forward(&x);
    let report = resnet.compile();
    // stem + one relu1 per basic block fuse into convs; every residual
    // relu2 fuses into its Add
    assert_eq!(report.conv_relu_fused, 9, "{report:?}");
    assert_eq!(report.add_relu_fused, 8, "{report:?}");
    assert_eq!(report.dead_removed, 0, "{report:?}");
    resnet.prepack_weights();
    assert_eq!(resnet.forward(&x).data, want.data, "resnet18 compiled forward drifted");

    let mut mobilenet = mobilenet_random(&mobilenet_cfg(), 22, 10);
    let want = mobilenet.forward(&x);
    let report = mobilenet.compile();
    assert_eq!(report.conv_relu_fused, 7, "{report:?}");
    assert_eq!(report.add_relu_fused, 0, "{report:?}");
    mobilenet.prepack_weights();
    assert_eq!(mobilenet.forward(&x).data, want.data, "mobilenet compiled forward drifted");
}

/// (c), int8 arm + the acceptance criterion: the compiled int8
/// MobileNet keeps every conv→conv edge in int8 — a full forward
/// materializes exactly ONE f32 activation from a quantized conv (the
/// graph exit) — and the compiled model agrees with the uncompiled
/// quantized reference.
#[test]
fn compiled_int8_mobilenet_zero_f32_between_convs() {
    let _g = GLOBAL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let mut rng = Pcg32::seeded(0xC1);
    let x = rand_tensor(&[2, 3, 32, 32], &mut rng, 1.0);
    let mut m = mobilenet_random(&mobilenet_cfg(), 23, 10);
    let mut cfg = QuantConfig::direct_default(8);
    cfg.adaquant = false;
    let done = quantize_model(&mut m, &x, &cfg);
    assert_eq!(done.len(), 7, "direct PTQ must take every conv");
    let want = m.forward(&x); // uncompiled quantized reference
    let report = m.compile();
    // stem→dw→pw→dw→pw→dw→pw: 6 interior edges carry int8
    assert_eq!(report.int8_links, 6, "{report:?}");
    assert_eq!(report.conv_relu_fused, 7, "{report:?}");
    let before = dequant_materializations();
    let got = m.forward(&x);
    let delta = dequant_materializations() - before;
    assert_eq!(
        delta, 1,
        "exactly one f32 materialization (the graph exit); interior conv→conv edges stay int8"
    );
    // the integer requant chain is within 1 code per activation of the
    // dequantize→quantize reference; after 7 layers the logits stay
    // close and the ranking is stable
    let denom = want.data.iter().map(|v| (*v as f64).powi(2)).sum::<f64>() / want.len() as f64;
    let rel = got.mse(&want) / denom.max(1e-30);
    assert!(rel < 5e-2, "compiled int8 vs uncompiled quantized rel MSE {rel}");
    // compile is report-idempotent on the quantized graph too: the
    // second run finds the requant stages already installed
    let report2 = m.compile();
    assert_eq!(report2.int8_links, 0, "{report2:?}");
    assert_eq!(report2.conv_relu_fused, 0, "{report2:?}");
}

/// (c), int8 arm on the residual topology: ResNet-18 under the spatial
/// scheme compiles with int8 links on every conv1→conv2 edge and stays
/// close to the uncompiled quantized model.
#[test]
fn compiled_int8_resnet_links_and_agreement() {
    let _g = GLOBAL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let mut rng = Pcg32::seeded(0xC2);
    let x = rand_tensor(&[2, 3, 32, 32], &mut rng, 1.0);
    let mut m = resnet_random(&resnet18_cfg(), 24, 10);
    let mut cfg = QuantConfig::direct_default(8);
    cfg.adaquant = false;
    let done = quantize_model(&mut m, &x, &cfg);
    assert_eq!(done.len(), 20, "direct PTQ must take every conv");
    let want = m.forward(&x);
    let report = m.compile();
    // one conv1→conv2 link per basic block; convs feeding the residual
    // Add (conv2, proj, fused stem) stay f32-producing
    assert_eq!(report.int8_links, 8, "{report:?}");
    let got = m.forward(&x);
    let denom = want.data.iter().map(|v| (*v as f64).powi(2)).sum::<f64>() / want.len() as f64;
    let rel = got.mse(&want) / denom.max(1e-30);
    assert!(rel < 5e-2, "compiled int8 resnet vs uncompiled rel MSE {rel}");
}

/// The quantized-e2e serving smoke (run by CI in both dispatch arms):
/// the compiled int8 MobileNet through the server batcher keeps the
/// zero-steady-state-allocation workspace guarantee.
#[test]
fn compiled_int8_mobilenet_server_steady_state_alloc_free() {
    let _g = GLOBAL_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    use sfc::coordinator::{Server, ServerConfig};
    use sfc::runtime::EngineExecutor;
    let mut rng = Pcg32::seeded(0xC3);
    let calib = rand_tensor(&[4, 3, 32, 32], &mut rng, 1.0);
    let mut m = mobilenet_random(&mobilenet_cfg(), 25, 10);
    let mut cfg = QuantConfig::direct_default(8);
    cfg.adaquant = false;
    quantize_model(&mut m, &calib, &cfg);
    // from_model runs the graph compiler (fusion + int8 dataflow)
    let exe = EngineExecutor::from_model(m, vec![4, 3, 32, 32], 10);
    let server = Server::start(
        move || Ok(exe),
        ServerConfig { batch_size: 4, queue_depth: 32, batch_timeout_ms: 1 },
    )
    .unwrap();
    let sample = 3 * 32 * 32;
    let submit_wait = |k: usize| {
        let handles: Vec<_> =
            (0..k).map(|_| server.submit(vec![0.25f32; sample]).unwrap()).collect();
        for h in handles {
            let r = h.wait().unwrap();
            assert_eq!(r.logits.len(), 10);
            assert!(r.logits.iter().all(|v| v.is_finite()));
        }
    };
    submit_wait(8); // warm-up fills the pools (f32 + int8 + i32 buffers)
    let warm_allocs = server.ws_heap_allocs();
    assert!(warm_allocs > 0 && server.ws_peak_bytes() > 0);
    submit_wait(16);
    assert_eq!(
        server.ws_heap_allocs(),
        warm_allocs,
        "compiled int8 serving must perform zero steady-state workspace heap allocations"
    );
    server.shutdown();
}
