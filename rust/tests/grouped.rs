//! Grouped/depthwise convolution across the engine stack: per-group
//! equivalence against dense execution (float + int8), depthwise
//! bit-identity where the arithmetic is exact, plan-cache key
//! distinctness over `groups`, the ENGINE.md support matrix vs
//! `supports()`, and the depthwise-separable model end to end — float
//! and int8, `Model::forward_ws` and the server path, with zero
//! steady-state workspace heap allocations.

use sfc::coordinator::{Server, ServerConfig};
use sfc::engine::{default_selector, ConvDesc, ConvPlan, PlanCache, Policy, Selector, Workspace};
use sfc::nn::model::{mobilenet_cfg, mobilenet_random};
use sfc::nn::{Op, Tensor};
use sfc::quant::calib::{dequantize_model, quantize_model, QuantConfig};
use sfc::runtime::EngineExecutor;
use sfc::util::Pcg32;
use std::sync::Arc;

fn rand_tensor(dims: &[usize], rng: &mut Pcg32, sigma: f64) -> Tensor {
    let mut t = Tensor::zeros(dims);
    rng.fill_gaussian(&mut t.data, sigma);
    t
}

fn rel_mse(got: &Tensor, want: &Tensor) -> f64 {
    let denom =
        want.data.iter().map(|v| (*v as f64).powi(2)).sum::<f64>() / want.len().max(1) as f64;
    got.mse(want) / denom.max(1e-30)
}

/// Property: every engine that supports a grouped descriptor agrees
/// with grouped direct convolution, for groups ∈ {2, ic} (depthwise).
#[test]
fn property_grouped_engines_match_grouped_direct() {
    use sfc::nn::conv::conv2d_direct_grouped;
    let sel = default_selector();
    let mut rng = Pcg32::seeded(0x6047);
    for (ic, oc, groups) in [(8usize, 8usize, 2usize), (6, 9, 3), (8, 8, 8), (5, 10, 5)] {
        let d = ConvDesc::new(2, ic, oc, 13, 11, 3, 1, 1).with_groups(groups);
        let x = rand_tensor(&[2, ic, 13, 11], &mut rng, 1.0);
        let w = rand_tensor(&[oc, ic / groups, 3, 3], &mut rng, 0.3);
        let bias: Vec<f32> = (0..oc).map(|i| i as f32 * 0.1 - 0.3).collect();
        let want = conv2d_direct_grouped(&x, &w, &bias, 1, 1, groups);
        let mut tested = 0;
        for e in sel.engines() {
            if !e.supports(&d) {
                continue;
            }
            let plan = sel.plan_named(e.name(), &d).unwrap();
            let got = plan.run(&x, &w, &bias);
            assert_eq!(got.dims, want.dims, "{} on {d:?}", e.name());
            let rel = rel_mse(&got, &want);
            assert!(rel < 1e-6, "{} groups {groups}: rel mse {rel}", e.name());
            tested += 1;
        }
        assert!(tested >= 3, "groups {groups}: expected several engines, got {tested}");
    }
}

/// Depthwise direct and im2col run the same additions in the same
/// order (a single-channel reduction), so their outputs are exactly
/// equal — the strongest cross-engine check grouped execution allows
/// in float.
#[test]
fn depthwise_direct_and_im2col_exactly_equal() {
    let sel = default_selector();
    let mut rng = Pcg32::seeded(0x9A);
    let d = ConvDesc::new(2, 8, 8, 12, 12, 3, 1, 1).with_groups(8);
    let x = rand_tensor(&[2, 8, 12, 12], &mut rng, 1.0);
    let w = rand_tensor(&[8, 1, 3, 3], &mut rng, 0.3);
    let bias = vec![0.1f32; 8];
    let yd = sel.plan_named("direct", &d).unwrap().run(&x, &w, &bias);
    let yi = sel.plan_named("im2col-gemm", &d).unwrap().run(&x, &w, &bias);
    assert_eq!(yd.dims, yi.dims);
    assert_eq!(yd.data, yi.data, "depthwise direct vs im2col must agree exactly");
}

/// Grouped plans are bit-identical between fresh and reused workspaces
/// (the zero-alloc contract extends to the new descriptor axis).
#[test]
fn grouped_plans_bit_identical_under_workspace_reuse() {
    let sel = default_selector();
    let mut rng = Pcg32::seeded(0x6E);
    let d = ConvDesc::new(2, 8, 8, 14, 14, 3, 1, 1).with_groups(4);
    let x = rand_tensor(&[2, 8, 14, 14], &mut rng, 1.0);
    let w = rand_tensor(&[8, 2, 3, 3], &mut rng, 0.3);
    for e in sel.engines() {
        if !e.supports(&d) {
            continue;
        }
        let plan = sel.plan_named(e.name(), &d).unwrap();
        let want = plan.run(&x, &w, &[]);
        let mut ws = Workspace::new();
        let mut out = Tensor::zeros(&plan.out_dims(&x, &w));
        plan.run_into(&x, &w, &[], &mut ws, &mut out);
        assert_eq!(out.data, want.data, "{}: fresh workspace", e.name());
        let warm = ws.heap_allocs();
        out.data.fill(f32::NAN);
        plan.run_into(&x, &w, &[], &mut ws, &mut out);
        assert_eq!(out.data, want.data, "{}: reused workspace", e.name());
        assert_eq!(ws.heap_allocs(), warm, "{}: steady state must not allocate", e.name());
        assert_eq!(ws.in_use_bytes(), 0, "{}: all buffers returned", e.name());
    }
}

/// `groups` is part of the plan-cache key: one shape at groups ∈
/// {1, 2, ic} plans three distinct entries, and repeats hit.
#[test]
fn plan_cache_keys_distinguish_groups() {
    let cache = Arc::new(PlanCache::new());
    let sel = Selector::with_cache(Policy::Heuristic, cache.clone());
    let base = ConvDesc::new(1, 8, 8, 16, 16, 3, 1, 1);
    for g in [1usize, 2, 8] {
        sel.plan(&base.with_groups(g)).unwrap();
    }
    assert_eq!(cache.misses(), 3, "each group count is its own cache entry");
    assert_eq!(cache.len(), 3);
    for g in [1usize, 2, 8] {
        sel.plan(&base.with_groups(g)).unwrap();
    }
    assert_eq!(cache.hits(), 3, "repeats must hit");
    assert_eq!(cache.misses(), 3);
}

/// The ENGINE.md "Engine × scenario support matrix" is generated from
/// `all_engines()` + `supports()`; the committed docs must contain the
/// generated table verbatim, so they cannot silently drift.
#[test]
fn engine_md_support_matrix_matches_supports() {
    let md_path = concat!(env!("CARGO_MANIFEST_DIR"), "/../ENGINE.md");
    let md = std::fs::read_to_string(md_path).expect("ENGINE.md at the repo root");
    let table = sfc::engine::support_matrix_markdown();
    assert!(
        md.contains(&table),
        "ENGINE.md support matrix drifted from supports(); regenerate it from \
         sfc::engine::support_matrix_markdown():\n{table}"
    );
}

/// The depthwise-separable model through `Model::forward_ws`: bit-
/// identical to `forward_all`, and alloc-free once the workspace is
/// warm.
#[test]
fn depthwise_model_forward_ws_bit_identical_and_alloc_free() {
    let m = mobilenet_random(&mobilenet_cfg(), 11, 10);
    let mut rng = Pcg32::seeded(12);
    let x = rand_tensor(&[2, 3, 32, 32], &mut rng, 1.0);
    let want = m.forward_all(&x).pop().unwrap();
    let mut ws = Workspace::new();
    let y1 = m.forward_ws(&x, &mut ws);
    assert_eq!(y1.data, want.data, "workspace forward must be bit-identical");
    ws.give_f32(y1.data);
    let warm = ws.heap_allocs();
    let y2 = m.forward_ws(&x, &mut ws);
    assert_eq!(y2.data, want.data, "reused-workspace forward must be bit-identical");
    assert_eq!(ws.heap_allocs(), warm, "steady-state depthwise forward must be alloc-free");
}

/// The engines the selector picks for the depthwise model agree with
/// an all-direct pin of the same graph (same descriptors, groups kept)
/// within float fast-conv tolerance.
#[test]
fn depthwise_model_selected_engines_agree_with_direct() {
    let mut m = mobilenet_random(&mobilenet_cfg(), 13, 10);
    let mut rng = Pcg32::seeded(14);
    let x = rand_tensor(&[2, 3, 32, 32], &mut rng, 1.0);
    let selected = m.forward(&x);
    for i in m.conv_nodes() {
        if let Op::Conv { plan, .. } = &mut m.nodes[i].op {
            *plan = Arc::new(ConvPlan::direct(plan.desc));
        }
    }
    let reference = m.forward(&x);
    let rel = rel_mse(&selected, &reference);
    assert!(rel < 1e-5, "selected engines drifted from direct: rel mse {rel}");
}

/// int8 PTQ over the depthwise model: the spatial scheme quantizes
/// every conv (depthwise included), the transform scheme takes the
/// 3×3 stride-1 layers through the SFC engine per-group.
#[test]
fn depthwise_model_int8_ptq_close_to_float() {
    let cfg = mobilenet_cfg();
    let mut m = mobilenet_random(&cfg, 15, 10);
    let mut rng = Pcg32::seeded(16);
    let calib = rand_tensor(&[8, 3, 32, 32], &mut rng, 1.0);
    let fp32 = m.forward(&calib);

    let done = quantize_model(&mut m, &calib, &QuantConfig::direct_default(8));
    assert_eq!(done.len(), 1 + 2 * cfg.blocks.len(), "spatial int8 must take every conv");
    let q = m.forward(&calib);
    let rel = rel_mse(&q, &fp32);
    assert!(rel < 1e-1, "spatial int8 depthwise model rel err {rel}");
    dequantize_model(&mut m);

    let done = quantize_model(&mut m, &calib, &QuantConfig::sfc_default(8));
    // stem (dense 3×3 s1) + the stride-1 depthwise layer; strided dw
    // and pointwise 1×1 layers stay float, per supports()
    assert_eq!(done.len(), 2, "SFC engine takes exactly the 3×3 stride-1 layers");
    let q = m.forward(&calib);
    let rel = rel_mse(&q, &fp32);
    assert!(rel < 1e-1, "transform int8 depthwise model rel err {rel}");
}

/// The server path over the depthwise model, float and int8: logits
/// bit-identical to direct executor calls, zero steady-state workspace
/// heap allocations.
#[test]
fn depthwise_model_serves_float_and_int8_alloc_free() {
    let mut rng = Pcg32::seeded(17);
    let images: Vec<Vec<f32>> = (0..12)
        .map(|_| {
            let mut v = vec![0f32; 3 * 32 * 32];
            rng.fill_gaussian(&mut v, 1.0);
            v
        })
        .collect();
    for int8 in [false, true] {
        let mut m = mobilenet_random(&mobilenet_cfg(), 18, 10);
        if int8 {
            let calib = rand_tensor(&[4, 3, 32, 32], &mut rng, 1.0);
            let done = quantize_model(&mut m, &calib, &QuantConfig::direct_default(8));
            assert!(!done.is_empty());
        }
        let exe = EngineExecutor::from_model(m, vec![4, 3, 32, 32], 10);
        // expected logits straight through the executor (per-image rows
        // are independent of batch packing, so serving must match them)
        let mut expected: Vec<Vec<f32>> = Vec::new();
        for chunk in images.chunks(4) {
            let mut batch = vec![0f32; 4 * 3 * 32 * 32];
            for (i, img) in chunk.iter().enumerate() {
                batch[i * 3 * 32 * 32..(i + 1) * 3 * 32 * 32].copy_from_slice(img);
            }
            let logits = exe.run(&batch).unwrap();
            for i in 0..chunk.len() {
                expected.push(logits[i * 10..(i + 1) * 10].to_vec());
            }
        }
        let server = Server::start(
            move || Ok(exe),
            ServerConfig { batch_size: 4, queue_depth: 32, batch_timeout_ms: 1 },
        )
        .unwrap();
        // warm-up wave fills the worker's workspace pools
        let handles: Vec<_> =
            images.iter().map(|img| server.submit(img.clone()).unwrap()).collect();
        for (i, h) in handles.into_iter().enumerate() {
            let r = h.wait().unwrap();
            assert_eq!(r.logits, expected[i], "int8={int8} request {i}");
        }
        let warm_allocs = server.ws_heap_allocs();
        assert!(warm_allocs > 0, "warm-up must populate the workspace");
        // steady state: more traffic, no new heap fallbacks
        let handles: Vec<_> =
            images.iter().map(|img| server.submit(img.clone()).unwrap()).collect();
        for (i, h) in handles.into_iter().enumerate() {
            let r = h.wait().unwrap();
            assert_eq!(r.logits, expected[i], "int8={int8} steady request {i}");
        }
        assert_eq!(
            server.ws_heap_allocs(),
            warm_allocs,
            "int8={int8}: steady-state depthwise serving must be alloc-free"
        );
        server.shutdown();
    }
}
