//! Dilated convolution across the engine stack: exactness against a
//! loop-order-matched naive reference, direct vs im2col agreement over
//! dense/grouped/depthwise × stride × pad sweeps, `supports()` honesty
//! for every engine (plan or decline — never a panic), plan-cache key
//! distinctness over the dilation rate, and the dilated backbone end to
//! end through `Model::forward_ws`.

use sfc::engine::{default_selector, ConvDesc, PlanCache, Policy, Selector, Workspace};
use sfc::nn::conv::conv2d_direct_grouped;
use sfc::nn::model::{dilatednet_cfg, dilatednet_random};
use sfc::nn::Tensor;
use sfc::util::Pcg32;
use std::sync::Arc;

fn rand_tensor(dims: &[usize], rng: &mut Pcg32, sigma: f64) -> Tensor {
    let mut t = Tensor::zeros(dims);
    rng.fill_gaussian(&mut t.data, sigma);
    t
}

fn rel_mse(got: &Tensor, want: &Tensor) -> f64 {
    let denom =
        want.data.iter().map(|v| (*v as f64).powi(2)).sum::<f64>() / want.len().max(1) as f64;
    got.mse(want) / denom.max(1e-30)
}

/// Naive dilated grouped correlation with the same loop order and f32
/// accumulation structure as the direct kernel (per-channel register
/// accumulator added into the plane), so direct must match it bit for
/// bit.
fn naive_dilated(
    x: &Tensor,
    w: &Tensor,
    bias: &[f32],
    stride: usize,
    pad: usize,
    groups: usize,
    dilation: usize,
) -> Tensor {
    let (n, ic, h, wid) = x.dims4();
    let (oc, icg, r, _) = w.dims4();
    let ocg = oc / groups;
    let er = (r - 1) * dilation + 1;
    let oh = (h + 2 * pad - er) / stride + 1;
    let ow = (wid + 2 * pad - er) / stride + 1;
    let mut out = Tensor::zeros(&[n, oc, oh, ow]);
    for ni in 0..n {
        for o in 0..oc {
            let gi = o / ocg;
            let plane = out.plane_mut(ni, o);
            for il in 0..icg {
                let xp = x.plane(ni, gi * icg + il);
                let wp = w.plane(o, il);
                for oy in 0..oh {
                    for ox in 0..ow {
                        let mut acc = 0f32;
                        for ky in 0..r {
                            let yy = oy * stride + ky * dilation;
                            if yy < pad || yy >= h + pad {
                                continue;
                            }
                            let yy = yy - pad;
                            for kx in 0..r {
                                let xx = ox * stride + kx * dilation;
                                if xx < pad || xx >= wid + pad {
                                    continue;
                                }
                                acc += wp[ky * r + kx] * xp[yy * wid + (xx - pad)];
                            }
                        }
                        plane[oy * ow + ox] += acc;
                    }
                }
            }
            let b = if bias.is_empty() { 0.0 } else { bias[o] };
            for v in plane.iter_mut() {
                *v += b;
            }
        }
    }
    out
}

fn sweep() -> Vec<(ConvDesc, &'static str)> {
    let mut cases = Vec::new();
    for (ic, oc, groups, tag) in
        [(6usize, 8usize, 1usize, "dense"), (6, 8, 2, "grouped"), (8, 8, 8, "depthwise")]
    {
        for stride in [1usize, 2] {
            for dilation in [2usize, 3] {
                for r in [3usize, 5] {
                    let pad = dilation * (r - 1) / 2;
                    let d = ConvDesc::builder(ic, oc)
                        .batch(2)
                        .hw(17)
                        .kernel(r)
                        .stride(stride)
                        .pad(pad)
                        .groups(groups)
                        .dilation(dilation)
                        .build();
                    cases.push((d, tag));
                }
            }
        }
    }
    cases
}

/// Property: the dilated direct kernel equals the loop-order-matched
/// naive reference bit for bit, and at `dilation == 1` it reduces to
/// the historical undilated kernel exactly.
#[test]
fn property_dilated_direct_is_exact() {
    let mut rng = Pcg32::seeded(0xD11);
    let sel = default_selector();
    for (d, tag) in sweep() {
        let x = rand_tensor(&[d.batch, d.ic, d.h, d.w], &mut rng, 1.0);
        let w = rand_tensor(&[d.oc, d.ic / d.groups, d.r, d.r], &mut rng, 0.3);
        let bias: Vec<f32> = (0..d.oc).map(|o| o as f32 * 0.05 - 0.1).collect();
        let want = naive_dilated(&x, &w, &bias, d.stride, d.pad, d.groups, d.dilation);
        let plan = sel.plan_named("direct", &d).expect("direct plans every dilated desc");
        let got = plan.run(&x, &w, &bias);
        assert_eq!(got.dims, want.dims, "{tag} {d:?}");
        assert_eq!(got.data, want.data, "{tag} d{} must be exact", d.dilation);
    }
    // dilation 1 delegation: the dilated kernel IS the historical kernel
    let d1 = ConvDesc::new(2, 6, 8, 17, 17, 3, 1, 1).with_groups(2);
    let x = rand_tensor(&[2, 6, 17, 17], &mut rng, 1.0);
    let w = rand_tensor(&[8, 3, 3, 3], &mut rng, 0.3);
    let undilated = conv2d_direct_grouped(&x, &w, &[], 1, 1, 2);
    let got = default_selector().plan_named("direct", &d1).unwrap().run(&x, &w, &[]);
    assert_eq!(got.data, undilated.data, "dilation 1 reduces to the undilated kernel");
}

/// Property: the dilated im2col lowering agrees with direct everywhere
/// in the sweep (float GEMM reassociates the channel reduction, so the
/// comparison is tolerance-based — at f64-roundoff scale).
#[test]
fn property_dilated_im2col_matches_direct() {
    let mut rng = Pcg32::seeded(0xD12);
    let sel = default_selector();
    for (d, tag) in sweep() {
        let x = rand_tensor(&[d.batch, d.ic, d.h, d.w], &mut rng, 1.0);
        let w = rand_tensor(&[d.oc, d.ic / d.groups, d.r, d.r], &mut rng, 0.3);
        let bias: Vec<f32> = (0..d.oc).map(|o| o as f32 * 0.05 - 0.1).collect();
        let want = sel.plan_named("direct", &d).unwrap().run(&x, &w, &bias);
        let plan = sel.plan_named("im2col-gemm", &d).expect("im2col plans every dilated desc");
        let got = plan.run(&x, &w, &bias);
        assert_eq!(got.dims, want.dims, "{tag} {d:?}");
        assert!(
            rel_mse(&got, &want) < 1e-11,
            "{tag} d{}: rel mse {}",
            d.dilation,
            rel_mse(&got, &want)
        );
    }
}

/// Honesty: every engine either plans a dilated descriptor (and then
/// its execution matches direct) or declines it via `supports()` —
/// `plan()` never succeeds where `supports()` said no, and vice versa.
#[test]
fn every_engine_plans_or_declines_dilation_honestly() {
    let mut rng = Pcg32::seeded(0xD13);
    let sel = default_selector();
    let descs = [
        ConvDesc::new(1, 4, 4, 16, 16, 3, 1, 2).with_dilation(2),
        ConvDesc::new(1, 4, 4, 16, 16, 3, 1, 4).with_dilation(4),
        ConvDesc::new(1, 8, 8, 16, 16, 3, 1, 2).with_groups(8).with_dilation(2),
    ];
    for d in descs {
        let x = rand_tensor(&[d.batch, d.ic, d.h, d.w], &mut rng, 1.0);
        let w = rand_tensor(&[d.oc, d.ic / d.groups, d.r, d.r], &mut rng, 0.3);
        let want = sel.plan_named("direct", &d).unwrap().run(&x, &w, &[]);
        let mut planned = 0usize;
        for e in sel.engines() {
            let plan = e.plan(&d);
            assert_eq!(
                plan.is_ok(),
                e.supports(&d),
                "{}: plan() and supports() disagree on {d:?}",
                e.name()
            );
            let Ok(plan) = plan else { continue };
            planned += 1;
            let got = plan.run(&x, &w, &[]);
            assert_eq!(got.dims, want.dims, "{}", e.name());
            assert!(rel_mse(&got, &want) < 1e-11, "{}: {}", e.name(), rel_mse(&got, &want));
        }
        assert!(planned >= 2, "direct and im2col must both take {d:?}");
        // transform engines must all have declined
        for name in ["FFT", "NTT", "FFT-tiled", "NTT-tiled", "Wino(4x4,3x3)", "SFC-6(7x7,3x3)"] {
            let e = sel.engine_named(name).unwrap();
            assert!(!e.supports(&d), "{name} must decline dilation {}", d.dilation);
        }
    }
}

/// The plan cache must key on the dilation rate: equal geometry at
/// rates 1/2/3 yields three distinct cache entries, and re-planning
/// hits instead of rebuilding.
#[test]
fn plan_cache_distinguishes_dilation_rates() {
    let cache = Arc::new(PlanCache::new());
    let sel = Selector::with_cache(Policy::Heuristic, cache.clone());
    let base = ConvDesc::builder(8, 8).hw(24).kernel(3).pad(2).build();
    for dilation in [1usize, 2, 3] {
        let d = base.with_dilation(dilation);
        sel.plan(&d).unwrap();
    }
    assert_eq!(cache.len(), 3, "one entry per dilation rate");
    let misses = cache.misses();
    for dilation in [1usize, 2, 3] {
        sel.plan(&base.with_dilation(dilation)).unwrap();
    }
    assert_eq!(cache.misses(), misses, "re-planning the same rates must hit");
    assert!(cache.hits() >= 3);
}

/// The support-matrix generator carries the dilation scenario, with the
/// spatial engines accepting and every transform engine declining.
#[test]
fn support_matrix_carries_the_dilation_column() {
    let md = sfc::engine::support_matrix_markdown();
    let header = md.lines().next().unwrap();
    assert!(header.contains("3x3 d2"), "dilation scenario in the header: {header}");
    let (_, d2) = sfc::engine::support_matrix_scenarios()
        .into_iter()
        .find(|(n, _)| *n == "3x3 d2")
        .expect("3x3 d2 scenario");
    assert_eq!(d2.dilation, 2);
    for e in default_selector().engines() {
        let want = matches!(e.name(), "direct" | "im2col-gemm");
        assert_eq!(e.supports(&d2), want, "{} on the d2 scenario", e.name());
    }
}

/// The dilated backbone runs end to end through `Model::forward_ws`,
/// bit-identical to the allocating forward and alloc-free once warm.
#[test]
fn dilated_backbone_forward_ws_is_stable() {
    let m = dilatednet_random(&dilatednet_cfg(), 11, 10);
    let mut rng = Pcg32::seeded(0xD14);
    let x = rand_tensor(&[2, 3, 32, 32], &mut rng, 1.0);
    let want = m.forward(&x);
    assert_eq!(want.dims, vec![2, 10, 1, 1]);
    let mut ws = Workspace::new();
    let y = m.forward_ws(&x, &mut ws);
    assert_eq!(y.data, want.data);
    let warm = ws.heap_allocs();
    let y2 = m.forward_ws(&x, &mut ws);
    assert_eq!(y2.data, want.data);
    assert_eq!(ws.heap_allocs(), warm, "warm dilated forward must not allocate");
}
