//! Minimal, dependency-free shim of the `anyhow` error-handling API.
//!
//! The build image for this repository vendors no registry crates, so the
//! subset of `anyhow` the SFC crate uses is reimplemented here: the
//! string-backed [`Error`], the [`Result`] alias, the [`Context`]
//! extension trait and the `anyhow!` / `bail!` / `ensure!` macros.
//! Context is flattened into the message eagerly ("outer: inner"), which
//! is what the CLI prints anyway; downcasting and backtraces are not
//! supported.

use std::fmt;

/// A string-backed error value. Like `anyhow::Error` it deliberately does
/// NOT implement `std::error::Error`, which is what makes the blanket
/// `From<E: std::error::Error>` impl coherent.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend a context layer ("context: cause").
    pub fn wrap<C: fmt::Display>(self, ctx: C) -> Error {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>` with the usual overridable error parameter.
pub type Result<T, E = Error> = core::result::Result<T, E>;

/// Conversion into [`Error`] for context chaining. Implemented for every
/// std error type and for [`Error`] itself (the same split that lets real
/// anyhow attach context to both).
#[doc(hidden)]
pub trait StdErrorLike {
    fn into_error(self) -> Error;
}

impl<E: std::error::Error + Send + Sync + 'static> StdErrorLike for E {
    fn into_error(self) -> Error {
        Error::msg(self)
    }
}

impl StdErrorLike for Error {
    fn into_error(self) -> Error {
        self
    }
}

mod private {
    pub trait Sealed {}
    impl<T, E> Sealed for core::result::Result<T, E> {}
    impl<T> Sealed for Option<T> {}
}

/// Attach human context to an error as it crosses a layer boundary.
pub trait Context<T>: private::Sealed {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: StdErrorLike> Context<T> for core::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into_error().wrap(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into_error().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message, a formatted message, or any
/// displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> core::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))
    }

    #[test]
    fn question_mark_from_std_error() {
        fn inner() -> Result<()> {
            io_err()?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn context_chains_on_std_and_anyhow_results() {
        let e = io_err().context("open file").unwrap_err();
        assert_eq!(e.to_string(), "open file: gone");
        let r: Result<()> = Err(e);
        let e2 = r.with_context(|| "loading model").unwrap_err();
        assert_eq!(e2.to_string(), "loading model: open file: gone");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
        assert_eq!(Some(3u32).context("x").unwrap(), 3);
    }

    #[test]
    fn macros() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let e = anyhow!("x = {}", 42);
        assert_eq!(e.to_string(), "x = 42");
        let e = anyhow!(String::from("owned"));
        assert_eq!(e.to_string(), "owned");
        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {}", flag);
            if !flag {
                bail!("unreachable");
            }
            Ok(1)
        }
        assert!(f(true).is_ok());
        assert!(f(false).unwrap_err().to_string().contains("false"));
    }
}
