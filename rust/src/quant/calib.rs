//! AdaQuant-lite post-training quantization (§6.1), descriptor-driven.
//!
//! Like AdaQuant (Hubara et al., 2020) the objective is layer-wise: pick
//! quantization parameters minimizing ‖Q(layer)(x) − layer(x)‖² on a small
//! calibration set. Our gradient-free variant searches a grid of scale
//! multipliers for the activation scales (clipping vs resolution
//! trade-off) per layer — the dominant effect at these bit-widths — and
//! keeps max-abs weight scales (per the chosen granularity).
//!
//! The pass builds one [`ConvDesc`] per conv node from its calibrated
//! activation shape, asks the engine [`Selector`](crate::engine::Selector)
//! for the configured engine's plan (plans are shared through the
//! [`PlanCache`](crate::engine::PlanCache) across layers and repeated
//! quantization runs) and installs a [`QConvLayer`] built from that plan.

use super::qconv::{collect_act_maxima, Granularity, QCalib, QConvLayer};
use crate::engine::{default_selector, ConvDesc, ConvPlan, QuantSpec};
use crate::nn::graph::{ConvParams, Model, Op};
use crate::nn::tensor::Tensor;
use std::sync::Arc;

/// PTQ configuration: which engine executes quantized layers plus the §5
/// quantization scheme.
#[derive(Clone, Debug)]
pub struct QuantConfig {
    /// Engine installed on supporting conv layers (a Table-1 catalog
    /// name). `None` = spatially-quantized direct conv on every layer.
    pub engine: Option<&'static str>,
    /// weight bit-width
    pub w_bits: u32,
    /// activation bit-width
    pub a_bits: u32,
    /// weight scale-group granularity
    pub w_gran: Granularity,
    /// activation scale-group granularity
    pub a_gran: Granularity,
    /// AdaQuant-lite scale search (off = plain max-abs calibration)
    pub adaquant: bool,
}

impl QuantConfig {
    /// The paper's SFC scheme: SFC-6(7x7,3x3) + Freq/Chan×Freq scales.
    pub fn sfc_default(bits: u32) -> QuantConfig {
        QuantConfig {
            engine: Some("SFC-6(7x7,3x3)"),
            w_bits: bits,
            a_bits: bits,
            w_gran: Granularity::ChannelFreq,
            a_gran: Granularity::Freq,
            adaquant: true,
        }
    }

    /// The Winograd baseline: Wino(4x4,3x3) + Freq/Chan×Freq scales.
    pub fn winograd_default(bits: u32) -> QuantConfig {
        QuantConfig {
            engine: Some("Wino(4x4,3x3)"),
            w_bits: bits,
            a_bits: bits,
            w_gran: Granularity::ChannelFreq,
            a_gran: Granularity::Freq,
            adaquant: true,
        }
    }

    /// The spatial baseline: direct conv + Tensor/Channel scales.
    pub fn direct_default(bits: u32) -> QuantConfig {
        QuantConfig {
            engine: None,
            w_bits: bits,
            a_bits: bits,
            w_gran: Granularity::Channel,
            a_gran: Granularity::Tensor,
            adaquant: true,
        }
    }

    /// The descriptor-level quantization scheme.
    pub fn spec(&self) -> QuantSpec {
        QuantSpec { w_bits: self.w_bits, a_bits: self.a_bits, w_gran: self.w_gran, a_gran: self.a_gran }
    }
}

/// Run PTQ over the model in place. Returns the list of quantized node
/// indices. `calib` is a small batch of input images (NCHW). Layers the
/// configured engine cannot take (e.g. 1×1 or strided convs under a fast
/// engine — the paper replaces 3×3 stride-1 convolutions) are left in
/// float.
pub fn quantize_model(model: &mut Model, calib: &Tensor, cfg: &QuantConfig) -> Vec<usize> {
    // fp32 reference activations for every node
    let acts = model.forward_all(calib);
    let sel = default_selector();
    let engine_name = cfg.engine.unwrap_or("direct");
    // A typo'd engine name must fail loudly, not return an all-float
    // model that masquerades as a quantized result.
    assert!(
        sel.engine_named(engine_name).is_some(),
        "unknown engine '{engine_name}' in QuantConfig (see `sfc autotune` for the catalog)"
    );
    let conv_nodes = model.conv_nodes();
    let mut done = Vec::new();
    for idx in conv_nodes {
        // borrow bookkeeping: compute inputs first
        let input_idx = model.nodes[idx].inputs[0];
        let layer_in = &acts[input_idx];
        let layer_ref = &acts[idx];
        let node = &model.nodes[idx];
        let Op::Conv { params, plan: float_plan, .. } = &node.op else { unreachable!() };
        let (n, ic, h, w) = layer_in.dims4();
        let (oc, icg, r, _) = params.weight.dims4();
        // grouping comes from the node's float plan (the authoritative
        // descriptor); the weight shape must agree with it
        let groups = float_plan.desc.groups;
        assert_eq!(
            icg * groups,
            ic,
            "weight channels {icg}×{groups} groups vs activation channels {ic} at {}",
            node.name
        );
        // the epilogue rides along: quantizing a compiled graph (fused
        // conv+ReLU) must not silently drop the fused activation
        let desc = ConvDesc::new(n, ic, oc, h, w, r, params.stride, params.pad)
            .with_groups(groups)
            .with_epilogue(float_plan.desc.epilogue)
            .with_quant(cfg.spec());
        let Ok(plan) = sel.plan_named(engine_name, &desc) else {
            continue; // engine unknown or unsupported for this layer
        };
        let q = build_quantized(plan, layer_in, layer_ref, params, cfg);
        if let Op::Conv { quantized, packed, .. } = &mut model.nodes[idx].op {
            *quantized = Some(q);
            // the quantized executor owns its own packed panels; drop
            // the float pre-pack so its bytes are released
            *packed = None;
        }
        done.push(idx);
    }
    done
}

fn build_quantized(
    plan: Arc<ConvPlan>,
    layer_in: &Tensor,
    layer_ref: &Tensor,
    params: &ConvParams,
    cfg: &QuantConfig,
) -> QConvLayer {
    if let Some(fast) = plan.fast_plan() {
        let maxima = collect_act_maxima(layer_in, fast, params.pad);
        if cfg.adaquant {
            search_transform(plan, layer_in, layer_ref, params, &maxima)
        } else {
            QConvLayer::from_plan(
                plan.clone(),
                &params.weight,
                params.bias.clone(),
                &QCalib::TransformMaxima(&maxima),
            )
        }
    } else {
        let max_abs = layer_in.max_abs();
        if cfg.adaquant {
            search_spatial(plan, layer_in, layer_ref, params, max_abs)
        } else {
            QConvLayer::from_plan(
                plan.clone(),
                &params.weight,
                params.bias.clone(),
                &QCalib::MaxAbs(max_abs),
            )
        }
    }
}

/// Remove quantization (restore fp32 execution). Pre-packed float
/// weights are **not** rebuilt here (quantization dropped them) — a
/// serving caller that wants the pre-packed steady-state datapath back
/// should run [`Model::prepack_weights`] afterwards (idempotent); the
/// per-call path the layers fall back to is bit-identical, just slower.
pub fn dequantize_model(model: &mut Model) {
    for node in &mut model.nodes {
        if let Op::Conv { quantized, .. } = &mut node.op {
            *quantized = None;
        }
    }
}

const SEARCH_GRID: [f32; 6] = [0.6, 0.7, 0.8, 0.9, 1.0, 1.1];

/// §Perf (L3): the scale search only needs a *relative* MSE ranking, so
/// it runs on the first `SEARCH_N` calibration images instead of the full
/// batch — a ~(N/SEARCH_N)× speedup of the PTQ pipeline measured in
/// EXPERIMENTS.md §Perf with no observed accuracy change (the final
/// quantizer is always built from full-batch statistics).
const SEARCH_N: usize = 24;

fn search_n() -> usize {
    std::env::var("SFC_SEARCH_N").ok().and_then(|v| v.parse().ok()).unwrap_or(SEARCH_N)
}

fn subsample(t: &Tensor, k: usize) -> Tensor {
    let n = t.dims[0].min(k);
    let per = t.len() / t.dims[0];
    let mut dims = t.dims.clone();
    dims[0] = n;
    Tensor::from_vec(&dims, t.data[..n * per].to_vec())
}

fn search_transform(
    plan: Arc<ConvPlan>,
    layer_in: &Tensor,
    layer_ref: &Tensor,
    params: &ConvParams,
    maxima: &[f32],
) -> QConvLayer {
    let search_in = subsample(layer_in, search_n());
    let search_ref = subsample(layer_ref, search_n());
    let mut best: Option<(f64, QConvLayer)> = None;
    for &f in &SEARCH_GRID {
        let scaled: Vec<f32> = maxima.iter().map(|m| m * f).collect();
        let cand = QConvLayer::from_plan(
            plan.clone(),
            &params.weight,
            params.bias.clone(),
            &QCalib::TransformMaxima(&scaled),
        );
        let mse = cand.forward(&search_in).mse(&search_ref);
        if best.as_ref().map_or(true, |(b, _)| mse < *b) {
            best = Some((mse, cand));
        }
    }
    best.unwrap().1
}

fn search_spatial(
    plan: Arc<ConvPlan>,
    layer_in: &Tensor,
    layer_ref: &Tensor,
    params: &ConvParams,
    max_abs: f32,
) -> QConvLayer {
    let search_in = subsample(layer_in, search_n());
    let search_ref = subsample(layer_ref, search_n());
    let mut best: Option<(f64, QConvLayer)> = None;
    for &f in &SEARCH_GRID {
        let cand = QConvLayer::from_plan(
            plan.clone(),
            &params.weight,
            params.bias.clone(),
            &QCalib::MaxAbs(max_abs * f),
        );
        let mse = cand.forward(&search_in).mse(&search_ref);
        if best.as_ref().map_or(true, |(b, _)| mse < *b) {
            best = Some((mse, cand));
        }
    }
    best.unwrap().1
}

/// Per-quantized-layer output MSE against the fp32 model on a batch —
/// the Fig. 5 probe.
pub fn layer_mse(model: &Model, fp32_acts: &[Tensor], batch: &Tensor) -> Vec<(String, f64)> {
    let q_acts = model.forward_all(batch);
    model
        .nodes
        .iter()
        .enumerate()
        .filter(|(_, n)| matches!(&n.op, Op::Conv { quantized: Some(_), .. }))
        .map(|(i, n)| (n.name.clone(), q_acts[i].mse(&fp32_acts[i])))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::graph::ConvParams;
    use crate::util::Pcg32;

    fn push_direct_conv(m: &mut Model, input: usize, w: Tensor, bias: Vec<f32>, name: &str) -> usize {
        let (oc, ic, r, _) = w.dims4();
        let desc = ConvDesc::new(1, ic, oc, 14, 14, r, 1, 1);
        m.push(
            Op::Conv {
                params: ConvParams { weight: w, bias, stride: 1, pad: 1 },
                plan: Arc::new(ConvPlan::direct(desc)),
                packed: None,
                quantized: None,
            },
            vec![input],
            name,
        )
    }

    fn small_model(rng: &mut Pcg32) -> Model {
        let mut m = Model::new("t");
        let i = m.push(Op::Input, vec![], "in");
        let mut w1 = Tensor::zeros(&[8, 3, 3, 3]);
        rng.fill_gaussian(&mut w1.data, 0.25);
        let c1 = push_direct_conv(&mut m, i, w1, vec![0.01; 8], "conv1");
        let r1 = m.push(Op::Relu, vec![c1], "relu1");
        let mut w2 = Tensor::zeros(&[8, 8, 3, 3]);
        rng.fill_gaussian(&mut w2.data, 0.2);
        push_direct_conv(&mut m, r1, w2, vec![0.0; 8], "conv2");
        m
    }

    #[test]
    fn ptq_int8_sfc_small_error() {
        let mut rng = Pcg32::seeded(7);
        let mut m = small_model(&mut rng);
        let mut x = Tensor::zeros(&[2, 3, 14, 14]);
        rng.fill_gaussian(&mut x.data, 1.0);
        let fp32 = m.forward(&x);
        let done = quantize_model(&mut m, &x, &QuantConfig::sfc_default(8));
        assert_eq!(done.len(), 2);
        let q = m.forward(&x);
        let denom = fp32.data.iter().map(|v| (*v as f64).powi(2)).sum::<f64>() / fp32.len() as f64;
        let rel = q.mse(&fp32) / denom;
        assert!(rel < 5e-3, "relative PTQ error {rel}");
        dequantize_model(&mut m);
        assert!(m.forward(&x).mse(&fp32) < 1e-12);
    }

    #[test]
    fn adaquant_no_worse_than_maxabs() {
        let mut rng = Pcg32::seeded(8);
        let mut x = Tensor::zeros(&[2, 3, 14, 14]);
        rng.fill_gaussian(&mut x.data, 1.0);
        let mut errs = Vec::new();
        for ada in [false, true] {
            let mut m = small_model(&mut Pcg32::seeded(8)); // same weights
            let mut cfg = QuantConfig::sfc_default(4);
            cfg.adaquant = ada;
            let fp32 = m.forward(&x);
            quantize_model(&mut m, &x, &cfg);
            errs.push(m.forward(&x).mse(&fp32));
        }
        assert!(errs[1] <= errs[0] * 1.001, "adaquant {} vs maxabs {}", errs[1], errs[0]);
    }

    #[test]
    fn direct_config_quantizes_all_convs() {
        let mut rng = Pcg32::seeded(9);
        let mut m = small_model(&mut rng);
        let mut x = Tensor::zeros(&[1, 3, 10, 10]);
        rng.fill_gaussian(&mut x.data, 1.0);
        let done = quantize_model(&mut m, &x, &QuantConfig::direct_default(8));
        assert_eq!(done.len(), 2);
    }

    #[test]
    #[should_panic(expected = "unknown engine")]
    fn unknown_engine_fails_loudly() {
        let mut rng = Pcg32::seeded(10);
        let mut m = small_model(&mut rng);
        let mut x = Tensor::zeros(&[1, 3, 10, 10]);
        rng.fill_gaussian(&mut x.data, 1.0);
        let mut cfg = QuantConfig::sfc_default(8);
        cfg.engine = Some("not-a-real-engine");
        quantize_model(&mut m, &x, &cfg);
    }
}
