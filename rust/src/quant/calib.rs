//! AdaQuant-lite post-training quantization (§6.1).
//!
//! Like AdaQuant (Hubara et al., 2020) the objective is layer-wise: pick
//! quantization parameters minimizing ‖Q(layer)(x) − layer(x)‖² on a small
//! calibration set. Our gradient-free variant searches a grid of scale
//! multipliers for the activation scales (clipping vs resolution
//! trade-off) per layer — the dominant effect at these bit-widths — and
//! keeps max-abs weight scales (per the chosen granularity). It converges
//! for all three algorithm families, mirroring the paper's use of a
//! different calibrator for Winograd (Scaling Gradient Backward) than for
//! SFC/direct (AdaQuant).

use super::qconv::{collect_act_maxima, Granularity, QConvLayer};
use crate::algo::registry::AlgoSpec;
use crate::nn::conv::FastConvPlan;
use crate::nn::graph::{Model, Op};
use crate::nn::tensor::Tensor;
use std::sync::Arc;

/// Which executor the PTQ pass installs.
#[derive(Clone, Debug)]
pub enum QAlgoChoice {
    Direct,
    Fast(AlgoSpec),
}

#[derive(Clone, Debug)]
pub struct QuantConfig {
    pub algo: QAlgoChoice,
    pub w_bits: u32,
    pub a_bits: u32,
    pub w_gran: Granularity,
    pub a_gran: Granularity,
    /// AdaQuant-lite scale search (off = plain max-abs calibration)
    pub adaquant: bool,
}

impl QuantConfig {
    pub fn sfc_default(bits: u32) -> QuantConfig {
        QuantConfig {
            algo: QAlgoChoice::Fast(crate::algo::registry::by_name("SFC-6(7x7,3x3)").unwrap()),
            w_bits: bits,
            a_bits: bits,
            w_gran: Granularity::ChannelFreq,
            a_gran: Granularity::Freq,
            adaquant: true,
        }
    }

    pub fn winograd_default(bits: u32) -> QuantConfig {
        QuantConfig {
            algo: QAlgoChoice::Fast(crate::algo::registry::by_name("Wino(4x4,3x3)").unwrap()),
            w_bits: bits,
            a_bits: bits,
            w_gran: Granularity::ChannelFreq,
            a_gran: Granularity::Freq,
            adaquant: true,
        }
    }

    pub fn direct_default(bits: u32) -> QuantConfig {
        QuantConfig {
            algo: QAlgoChoice::Direct,
            w_bits: bits,
            a_bits: bits,
            w_gran: Granularity::Channel,
            a_gran: Granularity::Tensor,
            adaquant: true,
        }
    }
}

/// Eligibility: the paper replaces all 3×3 stride-1 convolutions.
fn eligible(params: &crate::nn::graph::ConvParams, fast: bool) -> bool {
    let r = params.weight.dims[2];
    if fast {
        r == 3 && params.stride == 1
    } else {
        // direct quantization applies to every conv
        true
    }
}

/// Run PTQ over the model in place. Returns the list of quantized node
/// indices. `calib` is a small batch of input images (NCHW).
pub fn quantize_model(model: &mut Model, calib: &Tensor, cfg: &QuantConfig) -> Vec<usize> {
    // fp32 reference activations for every node
    let acts = model.forward_all(calib);
    let conv_nodes = model.conv_nodes();
    let mut done = Vec::new();
    for idx in conv_nodes {
        // borrow bookkeeping: compute inputs first
        let input_idx = model.nodes[idx].inputs[0];
        let layer_in = &acts[input_idx];
        let layer_ref = &acts[idx];
        let node = &model.nodes[idx];
        let Op::Conv { params, .. } = &node.op else { unreachable!() };
        let is_fast = matches!(cfg.algo, QAlgoChoice::Fast(_));
        if !eligible(params, is_fast) {
            continue;
        }
        let q = match &cfg.algo {
            QAlgoChoice::Direct => {
                let base = QConvLayer::direct(
                    &params.weight,
                    params.bias.clone(),
                    params.stride,
                    params.pad,
                    cfg.w_bits,
                    cfg.a_bits,
                    layer_in.max_abs(),
                );
                if cfg.adaquant {
                    search_direct(layer_in, layer_ref, params, cfg)
                } else {
                    base
                }
            }
            QAlgoChoice::Fast(spec) => {
                let plan = Arc::new(FastConvPlan::new(spec.build()));
                let maxima = collect_act_maxima(layer_in, &plan, params.pad);
                if cfg.adaquant {
                    search_fast(layer_in, layer_ref, params, cfg, plan, &maxima)
                } else {
                    QConvLayer::fast(
                        plan,
                        &params.weight,
                        params.bias.clone(),
                        params.pad,
                        cfg.w_bits,
                        cfg.a_bits,
                        cfg.w_gran,
                        cfg.a_gran,
                        &maxima,
                    )
                }
            }
        };
        if let Op::Conv { quantized, .. } = &mut model.nodes[idx].op {
            *quantized = Some(q);
        }
        done.push(idx);
    }
    done
}

/// Remove quantization (restore fp32 execution).
pub fn dequantize_model(model: &mut Model) {
    for node in &mut model.nodes {
        if let Op::Conv { quantized, .. } = &mut node.op {
            *quantized = None;
        }
    }
}

const SEARCH_GRID: [f32; 6] = [0.6, 0.7, 0.8, 0.9, 1.0, 1.1];

/// §Perf (L3): the scale search only needs a *relative* MSE ranking, so
/// it runs on the first `SEARCH_N` calibration images instead of the full
/// batch — a ~(N/SEARCH_N)× speedup of the PTQ pipeline measured in
/// EXPERIMENTS.md §Perf with no observed accuracy change (the final
/// quantizer is always built from full-batch statistics).
const SEARCH_N: usize = 24;

fn search_n() -> usize {
    std::env::var("SFC_SEARCH_N").ok().and_then(|v| v.parse().ok()).unwrap_or(SEARCH_N)
}

fn subsample(t: &Tensor, k: usize) -> Tensor {
    let n = t.dims[0].min(k);
    let per = t.len() / t.dims[0];
    let mut dims = t.dims.clone();
    dims[0] = n;
    Tensor::from_vec(&dims, t.data[..n * per].to_vec())
}

fn search_fast(
    layer_in: &Tensor,
    layer_ref: &Tensor,
    params: &crate::nn::graph::ConvParams,
    cfg: &QuantConfig,
    plan: Arc<FastConvPlan>,
    maxima: &[f32],
) -> QConvLayer {
    let search_in = subsample(layer_in, search_n());
    let search_ref = subsample(layer_ref, search_n());
    let mut best: Option<(f64, QConvLayer)> = None;
    for &f in &SEARCH_GRID {
        let scaled: Vec<f32> = maxima.iter().map(|m| m * f).collect();
        let cand = QConvLayer::fast(
            plan.clone(),
            &params.weight,
            params.bias.clone(),
            params.pad,
            cfg.w_bits,
            cfg.a_bits,
            cfg.w_gran,
            cfg.a_gran,
            &scaled,
        );
        let mse = cand.forward(&search_in).mse(&search_ref);
        if best.as_ref().map_or(true, |(b, _)| mse < *b) {
            best = Some((mse, cand));
        }
    }
    best.unwrap().1
}

fn search_direct(
    layer_in: &Tensor,
    layer_ref: &Tensor,
    params: &crate::nn::graph::ConvParams,
    cfg: &QuantConfig,
) -> QConvLayer {
    let max_abs = layer_in.max_abs();
    let search_in = subsample(layer_in, search_n());
    let search_ref = subsample(layer_ref, search_n());
    let mut best: Option<(f64, QConvLayer)> = None;
    for &f in &SEARCH_GRID {
        let cand = QConvLayer::direct(
            &params.weight,
            params.bias.clone(),
            params.stride,
            params.pad,
            cfg.w_bits,
            cfg.a_bits,
            max_abs * f,
        );
        let mse = cand.forward(&search_in).mse(&search_ref);
        if best.as_ref().map_or(true, |(b, _)| mse < *b) {
            best = Some((mse, cand));
        }
    }
    best.unwrap().1
}

/// Per-quantized-layer output MSE against the fp32 model on a batch —
/// the Fig. 5 probe.
pub fn layer_mse(model: &Model, fp32_acts: &[Tensor], batch: &Tensor) -> Vec<(String, f64)> {
    let q_acts = model.forward_all(batch);
    model
        .nodes
        .iter()
        .enumerate()
        .filter(|(_, n)| matches!(&n.op, Op::Conv { quantized: Some(_), .. }))
        .map(|(i, n)| (n.name.clone(), q_acts[i].mse(&fp32_acts[i])))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::graph::ConvParams;
    use crate::nn::ConvAlgo;
    use crate::util::Pcg32;

    fn small_model(rng: &mut Pcg32) -> Model {
        let mut m = Model::new("t");
        let i = m.push(Op::Input, vec![], "in");
        let mut w1 = Tensor::zeros(&[8, 3, 3, 3]);
        rng.fill_gaussian(&mut w1.data, 0.25);
        let c1 = m.push(
            Op::Conv {
                params: ConvParams { weight: w1, bias: vec![0.01; 8], stride: 1, pad: 1 },
                algo: ConvAlgo::Direct,
                quantized: None,
            },
            vec![i],
            "conv1",
        );
        let r1 = m.push(Op::Relu, vec![c1], "relu1");
        let mut w2 = Tensor::zeros(&[8, 8, 3, 3]);
        rng.fill_gaussian(&mut w2.data, 0.2);
        m.push(
            Op::Conv {
                params: ConvParams { weight: w2, bias: vec![0.0; 8], stride: 1, pad: 1 },
                algo: ConvAlgo::Direct,
                quantized: None,
            },
            vec![r1],
            "conv2",
        );
        m
    }

    #[test]
    fn ptq_int8_sfc_small_error() {
        let mut rng = Pcg32::seeded(7);
        let mut m = small_model(&mut rng);
        let mut x = Tensor::zeros(&[2, 3, 14, 14]);
        rng.fill_gaussian(&mut x.data, 1.0);
        let fp32 = m.forward(&x);
        let done = quantize_model(&mut m, &x, &QuantConfig::sfc_default(8));
        assert_eq!(done.len(), 2);
        let q = m.forward(&x);
        let denom = fp32.data.iter().map(|v| (*v as f64).powi(2)).sum::<f64>() / fp32.len() as f64;
        let rel = q.mse(&fp32) / denom;
        assert!(rel < 5e-3, "relative PTQ error {rel}");
        dequantize_model(&mut m);
        assert!(m.forward(&x).mse(&fp32) < 1e-12);
    }

    #[test]
    fn adaquant_no_worse_than_maxabs() {
        let mut rng = Pcg32::seeded(8);
        let mut x = Tensor::zeros(&[2, 3, 14, 14]);
        rng.fill_gaussian(&mut x.data, 1.0);
        let mut errs = Vec::new();
        for ada in [false, true] {
            let mut m = small_model(&mut Pcg32::seeded(8)); // same weights
            let mut cfg = QuantConfig::sfc_default(4);
            cfg.adaquant = ada;
            let fp32 = m.forward(&x);
            quantize_model(&mut m, &x, &cfg);
            errs.push(m.forward(&x).mse(&fp32));
        }
        assert!(errs[1] <= errs[0] * 1.001, "adaquant {} vs maxabs {}", errs[1], errs[0]);
    }

    #[test]
    fn direct_config_quantizes_all_convs() {
        let mut rng = Pcg32::seeded(9);
        let mut m = small_model(&mut rng);
        let mut x = Tensor::zeros(&[1, 3, 10, 10]);
        rng.fill_gaussian(&mut x.data, 1.0);
        let done = quantize_model(&mut m, &x, &QuantConfig::direct_default(8));
        assert_eq!(done.len(), 2);
    }
}
