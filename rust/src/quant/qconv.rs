//! Transform-domain-quantized convolution (Eq. 17) and the quantized
//! direct-conv baseline.
//!
//! The fast path executes
//!   y = Σ_Cin  s_Tx·⌈BᵀxB/s_Tx⌋ ⊙ s_Tf·⌈GfGᵀ/s_Tf⌋
//! with integer products accumulated exactly in i32 and the inverse
//! transform applied in f32 afterwards. Scale-group granularity follows
//! §5: per-tensor or per-frequency for activations; per-channel,
//! per-frequency or channel×frequency for weights (s_Tf of size
//! [OC×T×T]).

use super::QParams;
use crate::nn::conv::{gather_tile, FastConvPlan};
use crate::nn::tensor::Tensor;
use crate::util::par::par_for;
use std::sync::{Arc, Mutex};

/// Scale-group granularity for one operand (Table 4/5 axes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Granularity {
    /// one scale for the whole tensor
    Tensor,
    /// one scale per transform-domain point (T×T)
    Freq,
    /// one scale per output channel (weights only)
    Channel,
    /// per output channel × per frequency (weights only; s_Tf [OC×T×T])
    ChannelFreq,
}

/// A conv layer after PTQ: either transform-domain-quantized fast conv or
/// the spatially-quantized direct baseline.
pub struct QConvLayer {
    pub kind: QConvKind,
    pub bias: Vec<f32>,
    pub stride: usize,
    pub pad: usize,
}

pub enum QConvKind {
    Fast {
        plan: Arc<FastConvPlan>,
        oc: usize,
        ic: usize,
        /// quantized transformed weights, freq-major [T²][OC][IC]
        wq: Vec<i8>,
        /// weight scale per (uv, oc) resolved from granularity
        w_scales: ScaleGroup,
        /// activation scale per uv resolved from granularity
        a_scales: ScaleGroup,
        a_bits: u32,
    },
    Direct {
        /// quantized weights [OC][IC·R·R]
        wq: Vec<i8>,
        oc: usize,
        ic: usize,
        r: usize,
        /// per-channel weight scales
        w_scales: Vec<f32>,
        /// per-tensor input scale
        a_scale: QParams,
    },
}

/// Resolved scale lookup: maps (uv, oc) → scale.
#[derive(Clone, Debug)]
pub struct ScaleGroup {
    pub gran: Granularity,
    pub t2: usize,
    pub oc: usize,
    pub scales: Vec<f32>,
}

impl ScaleGroup {
    #[inline]
    pub fn scale(&self, uv: usize, oc: usize) -> f32 {
        match self.gran {
            Granularity::Tensor => self.scales[0],
            Granularity::Freq => self.scales[uv],
            Granularity::Channel => self.scales[oc],
            Granularity::ChannelFreq => self.scales[oc * self.t2 + uv],
        }
    }

    /// Build from per-(uv, oc) maxima.
    pub fn from_maxima(gran: Granularity, t2: usize, oc: usize, maxima: &[f32], bits: u32) -> ScaleGroup {
        assert_eq!(maxima.len(), t2 * oc);
        let qmax = ((1i32 << (bits - 1)) - 1) as f32;
        let reduce = |pred: &dyn Fn(usize, usize) -> bool| -> f32 {
            let mut m = 0f32;
            for uv in 0..t2 {
                for o in 0..oc {
                    if pred(uv, o) {
                        m = m.max(maxima[uv * oc + o]);
                    }
                }
            }
            if m > 0.0 {
                m / qmax
            } else {
                1.0
            }
        };
        let scales = match gran {
            Granularity::Tensor => vec![reduce(&|_, _| true)],
            Granularity::Freq => (0..t2).map(|u| reduce(&|uv, _| uv == u)).collect(),
            Granularity::Channel => (0..oc).map(|c| reduce(&|_, o| o == c)).collect(),
            Granularity::ChannelFreq => {
                let mut s = vec![0f32; oc * t2];
                for o in 0..oc {
                    for uv in 0..t2 {
                        let m = maxima[uv * oc + o];
                        s[o * t2 + uv] = if m > 0.0 { m / qmax } else { 1.0 };
                    }
                }
                s
            }
        };
        ScaleGroup { gran, t2, oc, scales }
    }

    pub fn scaled(&self, factor: f32) -> ScaleGroup {
        let mut s = self.clone();
        for v in s.scales.iter_mut() {
            *v *= factor;
        }
        s
    }
}

impl QConvLayer {
    /// Build the transform-domain-quantized layer (Eq. 17).
    ///
    /// `act_maxima` are per-frequency max |BᵀxB| statistics collected on
    /// the calibration set (uv-major, single pseudo-channel).
    #[allow(clippy::too_many_arguments)]
    pub fn fast(
        plan: Arc<FastConvPlan>,
        weight: &Tensor,
        bias: Vec<f32>,
        pad: usize,
        w_bits: u32,
        a_bits: u32,
        w_gran: Granularity,
        a_gran: Granularity,
        act_maxima: &[f32],
    ) -> QConvLayer {
        let (oc, ic, r, _) = weight.dims4();
        assert_eq!(r, plan.r());
        let t2 = plan.t() * plan.t();
        assert_eq!(act_maxima.len(), t2);
        // transform weights (f32, freq-major [T²][OC][IC])
        let u = plan.transform_weights(&weight.data, oc, ic);
        // per (uv, oc) maxima over ic
        let mut w_maxima = vec![0f32; t2 * oc];
        for uv in 0..t2 {
            for o in 0..oc {
                let mut m = 0f32;
                for i in 0..ic {
                    m = m.max(u[(uv * oc + o) * ic + i].abs());
                }
                w_maxima[uv * oc + o] = m;
            }
        }
        let w_scales = ScaleGroup::from_maxima(w_gran, t2, oc, &w_maxima, w_bits);
        assert!(
            matches!(a_gran, Granularity::Tensor | Granularity::Freq),
            "activation granularity must be Tensor or Freq"
        );
        let a_scales = ScaleGroup::from_maxima(a_gran, t2, 1, act_maxima, a_bits);
        let wq = quantize_weights(&u, t2, oc, ic, &w_scales, w_bits);
        QConvLayer {
            kind: QConvKind::Fast { plan, oc, ic, wq, w_scales, a_scales, a_bits },
            bias,
            stride: 1,
            pad,
        }
    }

    /// Quantized direct convolution (the "quantization-alone" baseline):
    /// int8 per-tensor activations × per-channel weights.
    pub fn direct(
        weight: &Tensor,
        bias: Vec<f32>,
        stride: usize,
        pad: usize,
        w_bits: u32,
        a_bits: u32,
        act_max_abs: f32,
    ) -> QConvLayer {
        let (oc, ic, r, _) = weight.dims4();
        let qmax = ((1i32 << (w_bits - 1)) - 1) as f32;
        let mut w_scales = vec![1f32; oc];
        let mut wq = vec![0i8; oc * ic * r * r];
        for o in 0..oc {
            let row = &weight.data[o * ic * r * r..(o + 1) * ic * r * r];
            let m = super::max_abs(row);
            let s = if m > 0.0 { m / qmax } else { 1.0 };
            w_scales[o] = s;
            for (dst, &v) in wq[o * ic * r * r..(o + 1) * ic * r * r].iter_mut().zip(row) {
                *dst = ((v / s).round() as i32).clamp(-(qmax as i32), qmax as i32) as i8;
            }
        }
        QConvLayer {
            kind: QConvKind::Direct {
                wq,
                oc,
                ic,
                r,
                w_scales,
                a_scale: QParams::from_max_abs(act_max_abs, a_bits),
            },
            bias,
            stride,
            pad,
        }
    }

    pub fn forward(&self, x: &Tensor) -> Tensor {
        match &self.kind {
            QConvKind::Fast { plan, oc, ic, wq, w_scales, a_scales, a_bits } => {
                forward_fast_q(x, self, plan, *oc, *ic, wq, w_scales, a_scales, *a_bits)
            }
            QConvKind::Direct { wq, oc, ic, r, w_scales, a_scale } => {
                forward_direct_q(x, self, wq, *oc, *ic, *r, w_scales, *a_scale)
            }
        }
    }
}

fn quantize_weights(u: &[f32], t2: usize, oc: usize, ic: usize, scales: &ScaleGroup, bits: u32) -> Vec<i8> {
    let qmax = ((1i32 << (bits - 1)) - 1) as f32;
    let mut wq = vec![0i8; t2 * oc * ic];
    for uv in 0..t2 {
        for o in 0..oc {
            let s = scales.scale(uv, o);
            for i in 0..ic {
                let v = u[(uv * oc + o) * ic + i];
                wq[(uv * oc + o) * ic + i] =
                    ((v / s).round() as i32).clamp(-(qmax as i32), qmax as i32) as i8;
            }
        }
    }
    wq
}

#[allow(clippy::too_many_arguments)]
fn forward_fast_q(
    x: &Tensor,
    layer: &QConvLayer,
    plan: &FastConvPlan,
    oc: usize,
    ic: usize,
    wq: &[i8],
    w_scales: &ScaleGroup,
    a_scales: &ScaleGroup,
    a_bits: u32,
) -> Tensor {
    let (n, ic2, h, wid) = x.dims4();
    assert_eq!(ic, ic2);
    let (m, l, t) = (plan.m(), plan.l(), plan.t());
    let r = plan.r();
    let pad = layer.pad;
    let oh = h + 2 * pad - r + 1;
    let ow = wid + 2 * pad - r + 1;
    let tiles_y = oh.div_ceil(m);
    let tiles_x = ow.div_ceil(m);
    let n_tiles = tiles_y * tiles_x;
    let tt = t * t;
    let a_qmax = (1i32 << (a_bits - 1)) - 1;

    let mut out = Tensor::zeros(&[n, oc, oh, ow]);
    let out_mutex = Mutex::new(&mut out);
    par_for(n, |ni| {
        // 1) gather + transform + QUANTIZE tiles: Vq freq-major [T²][tiles][IC]
        let mut vq = vec![0i8; tt * n_tiles * ic];
        let mut tile = vec![0f32; l * l];
        let mut scratch = vec![0f32; t * l];
        let mut tv = vec![0f32; tt];
        for ty in 0..tiles_y {
            for tx in 0..tiles_x {
                let tile_idx = ty * tiles_x + tx;
                for c in 0..ic {
                    gather_tile(x, ni, c, ty, tx, m, l, pad, &mut tile);
                    plan.transform_tile(&tile, &mut scratch, &mut tv);
                    for uv in 0..tt {
                        let s = a_scales.scale(uv, 0);
                        let q = (tv[uv] / s).round() as i32;
                        vq[(uv * n_tiles + tile_idx) * ic + c] = q.clamp(-a_qmax, a_qmax) as i8;
                    }
                }
            }
        }
        // 2) integer per-frequency GEMM, i32 accumulation (exact).
        let mut p = vec![0f32; tt * n_tiles * oc];
        for uv in 0..tt {
            let vblk = &vq[uv * n_tiles * ic..(uv + 1) * n_tiles * ic];
            let ublk = &wq[uv * oc * ic..(uv + 1) * oc * ic];
            let pblk = &mut p[uv * n_tiles * oc..(uv + 1) * n_tiles * oc];
            let sa = a_scales.scale(uv, 0);
            for ti in 0..n_tiles {
                let vrow = &vblk[ti * ic..(ti + 1) * ic];
                let prow = &mut pblk[ti * oc..(ti + 1) * oc];
                for (o, pv) in prow.iter_mut().enumerate() {
                    let urow = &ublk[o * ic..(o + 1) * ic];
                    let mut acc: i32 = 0;
                    for (a, b) in vrow.iter().zip(urow) {
                        acc += (*a as i32) * (*b as i32);
                    }
                    // dequantize: both operand scales
                    *pv = acc as f32 * sa * w_scales.scale(uv, o);
                }
            }
        }
        // 3) inverse transform + bias + scatter
        let mut prod = vec![0f32; tt];
        let mut iscratch = vec![0f32; m * t];
        let mut ytile = vec![0f32; m * m];
        let mut guard = out_mutex.lock().unwrap();
        for o in 0..oc {
            let b = if layer.bias.is_empty() { 0.0 } else { layer.bias[o] };
            for ty in 0..tiles_y {
                for tx in 0..tiles_x {
                    let tile_idx = ty * tiles_x + tx;
                    for uv in 0..tt {
                        prod[uv] = p[(uv * n_tiles + tile_idx) * oc + o];
                    }
                    plan.inverse_tile(&prod, &mut iscratch, &mut ytile);
                    let plane = guard.plane_mut(ni, o);
                    for i in 0..m.min(oh - ty * m) {
                        for j in 0..m.min(ow - tx * m) {
                            plane[(ty * m + i) * ow + tx * m + j] = ytile[i * m + j] + b;
                        }
                    }
                }
            }
        }
    });
    out
}

#[allow(clippy::too_many_arguments)]
fn forward_direct_q(
    x: &Tensor,
    layer: &QConvLayer,
    wq: &[i8],
    oc: usize,
    ic: usize,
    r: usize,
    w_scales: &[f32],
    a_scale: QParams,
) -> Tensor {
    let (n, ic2, h, wid) = x.dims4();
    assert_eq!(ic, ic2);
    let (stride, pad) = (layer.stride, layer.pad);
    let oh = (h + 2 * pad - r) / stride + 1;
    let ow = (wid + 2 * pad - r) / stride + 1;
    // quantize input per-tensor
    let xq: Vec<i8> = x.data.iter().map(|&v| a_scale.quantize(v) as i8).collect();
    let mut out = Tensor::zeros(&[n, oc, oh, ow]);
    let out_mutex = Mutex::new(&mut out);
    par_for(n * oc, |job| {
        let (ni, o) = (job / oc, job % oc);
        let deq = a_scale.scale * w_scales[o];
        let b = if layer.bias.is_empty() { 0.0 } else { layer.bias[o] };
        let mut local = vec![0f32; oh * ow];
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc: i32 = 0;
                for i in 0..ic {
                    let xplane = &xq[(ni * ic + i) * h * wid..(ni * ic + i + 1) * h * wid];
                    let wplane = &wq[(o * ic + i) * r * r..(o * ic + i + 1) * r * r];
                    for ky in 0..r {
                        let yy = oy * stride + ky;
                        if yy < pad || yy >= h + pad {
                            continue;
                        }
                        let yy = yy - pad;
                        for kx in 0..r {
                            let xx = ox * stride + kx;
                            if xx < pad || xx >= wid + pad {
                                continue;
                            }
                            acc += (wplane[ky * r + kx] as i32)
                                * (xplane[yy * wid + xx - pad] as i32);
                        }
                    }
                }
                local[oy * ow + ox] = acc as f32 * deq + b;
            }
        }
        let mut guard = out_mutex.lock().unwrap();
        guard.plane_mut(ni, o).copy_from_slice(&local);
    });
    out
}

/// Collect per-frequency max |BᵀxB| statistics over a batch (calibration).
pub fn collect_act_maxima(x: &Tensor, plan: &FastConvPlan, pad: usize) -> Vec<f32> {
    let (n, ic, h, wid) = x.dims4();
    let (m, l, t) = (plan.m(), plan.l(), plan.t());
    let r = plan.r();
    let oh = h + 2 * pad - r + 1;
    let ow = wid + 2 * pad - r + 1;
    let tiles_y = oh.div_ceil(m);
    let tiles_x = ow.div_ceil(m);
    let tt = t * t;
    let mut maxima = vec![0f32; tt];
    let mut tile = vec![0f32; l * l];
    let mut scratch = vec![0f32; t * l];
    let mut tv = vec![0f32; tt];
    for ni in 0..n {
        for c in 0..ic {
            for ty in 0..tiles_y {
                for tx in 0..tiles_x {
                    gather_tile(x, ni, c, ty, tx, m, l, pad, &mut tile);
                    plan.transform_tile(&tile, &mut scratch, &mut tv);
                    for uv in 0..tt {
                        maxima[uv] = maxima[uv].max(tv[uv].abs());
                    }
                }
            }
        }
    }
    maxima
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{sfc, winograd};
    use crate::nn::conv::conv2d_direct;
    use crate::util::Pcg32;

    fn rand_tensor(dims: &[usize], rng: &mut Pcg32, sigma: f64) -> Tensor {
        let mut t = Tensor::zeros(dims);
        rng.fill_gaussian(&mut t.data, sigma);
        t
    }

    #[test]
    fn int8_fast_close_to_fp32() {
        let mut rng = Pcg32::seeded(42);
        let x = rand_tensor(&[1, 4, 14, 14], &mut rng, 1.0);
        let w = rand_tensor(&[4, 4, 3, 3], &mut rng, 0.3);
        let plan = Arc::new(FastConvPlan::new(sfc(6, 7, 3)));
        let maxima = collect_act_maxima(&x, &plan, 1);
        let q = QConvLayer::fast(
            plan, &w, vec![0.0; 4], 1, 8, 8,
            Granularity::ChannelFreq, Granularity::Freq, &maxima,
        );
        let want = conv2d_direct(&x, &w, &[0.0; 4], 1, 1);
        let got = q.forward(&x);
        let rel = got.mse(&want) / want.data.iter().map(|v| (*v as f64).powi(2)).sum::<f64>()
            * want.len() as f64;
        assert!(rel < 1e-3, "relative error {rel}");
    }

    #[test]
    fn int4_worse_than_int8() {
        let mut rng = Pcg32::seeded(43);
        let x = rand_tensor(&[1, 4, 12, 12], &mut rng, 1.0);
        let w = rand_tensor(&[4, 4, 3, 3], &mut rng, 0.3);
        let plan = Arc::new(FastConvPlan::new(sfc(6, 6, 3)));
        let maxima = collect_act_maxima(&x, &plan, 1);
        let want = conv2d_direct(&x, &w, &[], 1, 1);
        let mut errs = Vec::new();
        for bits in [8u32, 4] {
            let q = QConvLayer::fast(
                plan.clone(), &w, vec![], 1, bits, bits,
                Granularity::ChannelFreq, Granularity::Freq, &maxima,
            );
            errs.push(q.forward(&x).mse(&want));
        }
        assert!(errs[1] > errs[0] * 4.0, "int4 {} vs int8 {}", errs[1], errs[0]);
    }

    #[test]
    fn freq_granularity_beats_tensor_for_winograd() {
        // Table 4's core claim: Winograd needs frequency-wise scales.
        let mut rng = Pcg32::seeded(44);
        let x = rand_tensor(&[1, 8, 12, 12], &mut rng, 1.0);
        let w = rand_tensor(&[8, 8, 3, 3], &mut rng, 0.3);
        let plan = Arc::new(FastConvPlan::new(winograd(4, 3)));
        let maxima = collect_act_maxima(&x, &plan, 1);
        let want = conv2d_direct(&x, &w, &[], 1, 1);
        let q_tensor = QConvLayer::fast(
            plan.clone(), &w, vec![], 1, 8, 8,
            Granularity::Channel, Granularity::Tensor, &maxima,
        );
        let q_freq = QConvLayer::fast(
            plan.clone(), &w, vec![], 1, 8, 8,
            Granularity::ChannelFreq, Granularity::Freq, &maxima,
        );
        let e_tensor = q_tensor.forward(&x).mse(&want);
        let e_freq = q_freq.forward(&x).mse(&want);
        assert!(e_freq < e_tensor, "freq {e_freq} must beat tensor {e_tensor}");
    }

    #[test]
    fn direct_quantized_close() {
        let mut rng = Pcg32::seeded(45);
        let x = rand_tensor(&[2, 3, 9, 9], &mut rng, 1.0);
        let w = rand_tensor(&[5, 3, 3, 3], &mut rng, 0.3);
        let q = QConvLayer::direct(&w, vec![0.0; 5], 1, 1, 8, 8, x.max_abs());
        let want = conv2d_direct(&x, &w, &[0.0; 5], 1, 1);
        let got = q.forward(&x);
        let denom = want.data.iter().map(|v| (*v as f64).powi(2)).sum::<f64>() / want.len() as f64;
        assert!(got.mse(&want) / denom < 1e-3);
    }

    #[test]
    fn direct_q_respects_stride() {
        let mut rng = Pcg32::seeded(46);
        let x = rand_tensor(&[1, 2, 8, 8], &mut rng, 1.0);
        let w = rand_tensor(&[2, 2, 3, 3], &mut rng, 0.3);
        let q = QConvLayer::direct(&w, vec![], 2, 1, 8, 8, x.max_abs());
        let got = q.forward(&x);
        assert_eq!(got.dims, vec![1, 2, 4, 4]);
    }
}
