//! Quantized conv executors built on engine plans: transform-domain
//! quantization (Eq. 17) for the bilinear engines, and the spatially
//! quantized baseline for the direct/NTT engines.
//!
//! A [`QConvLayer`] is constructed from an engine [`ConvPlan`] plus the
//! quantization scheme carried by the descriptor ([`QuantSpec`]): the
//! plan decides the datapath, the spec decides bit-widths and scale-group
//! granularity (§5: per-tensor or per-frequency for activations;
//! per-channel, per-frequency or channel×frequency for weights).

use super::{QParams, QTensor, Requant};
use crate::engine::exec::ntt_corr2d_i8_into;
use crate::engine::tiled::ntt_corr2d_i8_tiled_into;
use crate::engine::{ConvPlan, Epilogue, PackedBytesGuard, PlanKernel, QuantSpec, Workspace};
use crate::linalg::gemm::{gemm_packed_i8_i32, packed_b_i8_len};
use crate::linalg::simd::{quantize_i8_slice, requant_i8_slice};
use crate::nn::conv::{gather_tile, gather_tiles8, pack_fast_weights_i8, FastConvPlan, TILE_LANES};
use crate::nn::tensor::Tensor;
use crate::util::par::{num_threads, par_chunks_mut, par_chunks_states};
use std::sync::Arc;

/// Scale-group granularity for one operand (Table 4/5 axes).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Granularity {
    /// one scale for the whole tensor
    Tensor,
    /// one scale per transform-domain point (T×T)
    Freq,
    /// one scale per output channel (weights only)
    Channel,
    /// per output channel × per frequency (weights only; s_Tf [OC×T×T])
    ChannelFreq,
}

/// Resolved scale lookup: maps (uv, oc) → scale.
#[derive(Clone, Debug)]
pub struct ScaleGroup {
    /// the granularity the scales were reduced at
    pub gran: Granularity,
    /// transform points (T²) the frequency axis indexes
    pub t2: usize,
    /// output channels the channel axis indexes
    pub oc: usize,
    /// resolved scales, laid out per `gran`
    pub scales: Vec<f32>,
}

impl ScaleGroup {
    #[inline]
    /// The scale for transform point `uv` and output channel `oc`.
    pub fn scale(&self, uv: usize, oc: usize) -> f32 {
        match self.gran {
            Granularity::Tensor => self.scales[0],
            Granularity::Freq => self.scales[uv],
            Granularity::Channel => self.scales[oc],
            Granularity::ChannelFreq => self.scales[oc * self.t2 + uv],
        }
    }

    /// Build from per-(uv, oc) maxima.
    pub fn from_maxima(gran: Granularity, t2: usize, oc: usize, maxima: &[f32], bits: u32) -> ScaleGroup {
        assert_eq!(maxima.len(), t2 * oc);
        let qmax = ((1i32 << (bits - 1)) - 1) as f32;
        let reduce = |pred: &dyn Fn(usize, usize) -> bool| -> f32 {
            let mut m = 0f32;
            for uv in 0..t2 {
                for o in 0..oc {
                    if pred(uv, o) {
                        m = m.max(maxima[uv * oc + o]);
                    }
                }
            }
            if m > 0.0 {
                m / qmax
            } else {
                1.0
            }
        };
        let scales = match gran {
            Granularity::Tensor => vec![reduce(&|_, _| true)],
            Granularity::Freq => (0..t2).map(|u| reduce(&|uv, _| uv == u)).collect(),
            Granularity::Channel => (0..oc).map(|c| reduce(&|_, o| o == c)).collect(),
            Granularity::ChannelFreq => {
                let mut s = vec![0f32; oc * t2];
                for o in 0..oc {
                    for uv in 0..t2 {
                        let m = maxima[uv * oc + o];
                        s[o * t2 + uv] = if m > 0.0 { m / qmax } else { 1.0 };
                    }
                }
                s
            }
        };
        ScaleGroup { gran, t2, oc, scales }
    }

    /// Copy with every scale multiplied by `factor` (AdaQuant search).
    pub fn scaled(&self, factor: f32) -> ScaleGroup {
        let mut s = self.clone();
        for v in s.scales.iter_mut() {
            *v *= factor;
        }
        s
    }
}

/// Activation calibration statistics for building a quantized layer:
/// what [`crate::quant::calib`] collects depends on the plan's datapath.
pub enum QCalib<'a> {
    /// per-frequency max |BᵀxB| over the calibration set (bilinear plans)
    TransformMaxima(&'a [f32]),
    /// max |x| over the calibration set (spatial plans: direct/NTT)
    MaxAbs(f32),
}

/// A conv layer after PTQ. The engine plan decides the datapath
/// (transform-domain int GEMM vs spatial int conv, optionally through
/// the NTT); the layer owns the quantized weights and resolved scales.
pub struct QConvLayer {
    /// the engine plan the layer was built from
    pub plan: Arc<ConvPlan>,
    /// float bias added after dequantization
    pub bias: Vec<f32>,
    kernel: QKernel,
    /// integer output stage installed by the graph compiler's
    /// int8-dataflow pass (spatial kernels only); `Some` makes the
    /// layer emit int8 activations directly
    requant: Option<RequantStage>,
}

/// Integer-only output stage for a quantized conv whose consumers are
/// all quantized convs: per-output-channel fixed-point multipliers
/// `(s_a·s_w[o]) / s_out` (see [`Requant`]), the bias pre-quantized at
/// the accumulator scale, and the output quantizer — which is exactly
/// the consumer's calibrated input quantizer, so the producer's int8
/// codes feed the next conv without any f32 round trip.
pub struct RequantStage {
    /// per-output-channel fixed-point multiplier
    mults: Vec<Requant>,
    /// bias at the accumulator scale: `round(b[o] / (s_a·s_w[o]))`
    bias_q: Vec<i32>,
    /// the output quantizer (the consumer's input scale)
    out: QParams,
}

enum QKernel {
    /// Eq. 17: quantize BᵀxB and GfGᵀ, exact i32 ⊙-accumulation,
    /// float inverse transform. Grouped descriptors run one
    /// `[tiles×IC/g]·[IC/g×OC/g]` integer GEMM per (frequency, group).
    TransformDomain {
        oc: usize,
        /// per-group input channels (`desc.ic / desc.groups`)
        icg: usize,
        /// quantized transformed weights pre-packed at build time into
        /// the dispatched GEMM's panel layout: one
        /// `packed_b_i8_len(OC/g, IC/g)` block per (frequency, group),
        /// group-major — steady-state forwards touch only this
        wqp: Vec<i8>,
        /// weight scale per (uv, oc) resolved from granularity
        w_scales: ScaleGroup,
        /// activation scale per uv resolved from granularity
        a_scales: ScaleGroup,
        a_bits: u32,
        /// byte accounting for the packed panels (plan + process-wide)
        _packed: PackedBytesGuard,
    },
    /// Spatially quantized conv: int8 per-tensor activations ×
    /// per-channel weights, executed by (grouped) nested loops or the
    /// exact NTT (dense only).
    Spatial {
        /// quantized weights [OC][(IC/g)·R·R]
        wq: Vec<i8>,
        oc: usize,
        /// per-group input channels (`desc.ic / desc.groups`)
        icg: usize,
        r: usize,
        w_scales: Vec<f32>,
        a_scale: QParams,
        via_ntt: bool,
    },
}

impl QConvLayer {
    /// Build the quantized executor for an engine plan. The quantization
    /// scheme comes from the plan's own descriptor (build the plan from
    /// `desc.with_quant(..)`), so plan and quantizer can never disagree.
    /// The calibration statistic must match the plan's datapath
    /// (per-frequency maxima for bilinear plans, max-abs for spatial).
    pub fn from_plan(
        plan: Arc<ConvPlan>,
        weight: &Tensor,
        bias: Vec<f32>,
        calib: &QCalib,
    ) -> QConvLayer {
        let spec = plan
            .desc
            .quant
            .expect("plan descriptor lacks a QuantSpec — build it from desc.with_quant(..)");
        match calib {
            QCalib::TransformMaxima(maxima) => {
                assert!(
                    matches!(plan.kernel, PlanKernel::Fast(_)),
                    "transform-domain calibration requires a bilinear plan, got {}",
                    plan.engine
                );
                QConvLayer::transform_domain(plan, weight, bias, spec, maxima)
            }
            QCalib::MaxAbs(max_abs) => {
                let via_ntt = match plan.kernel {
                    PlanKernel::Direct | PlanKernel::Im2col => false,
                    PlanKernel::Ntt | PlanKernel::NttTiled { .. } => true,
                    _ => panic!("{} plan has no spatial quantized path", plan.engine),
                };
                QConvLayer::spatial(plan, weight, bias, spec, *max_abs, via_ntt)
            }
        }
    }

    fn transform_domain(
        plan: Arc<ConvPlan>,
        weight: &Tensor,
        bias: Vec<f32>,
        spec: QuantSpec,
        act_maxima: &[f32],
    ) -> QConvLayer {
        let fast = plan.fast_plan().expect("bilinear plan").clone();
        let (oc, icg, r, _) = weight.dims4();
        assert_eq!(r, fast.r());
        assert_eq!(plan.desc.stride, 1, "fast conv requires stride 1");
        assert_eq!(
            icg * plan.desc.groups,
            plan.desc.ic,
            "weight channels {icg}×{} groups vs descriptor ic {}",
            plan.desc.groups,
            plan.desc.ic
        );
        let t2 = fast.t() * fast.t();
        assert_eq!(act_maxima.len(), t2);
        // transform weights (f32, freq-major [T²][OC][IC/g])
        let u = fast.transform_weights(&weight.data, oc, icg);
        // per (uv, oc) maxima over the group's input channels
        let mut w_maxima = vec![0f32; t2 * oc];
        for uv in 0..t2 {
            for o in 0..oc {
                let mut m = 0f32;
                for i in 0..icg {
                    m = m.max(u[(uv * oc + o) * icg + i].abs());
                }
                w_maxima[uv * oc + o] = m;
            }
        }
        let w_scales = ScaleGroup::from_maxima(spec.w_gran, t2, oc, &w_maxima, spec.w_bits);
        assert!(
            matches!(spec.a_gran, Granularity::Tensor | Granularity::Freq),
            "activation granularity must be Tensor or Freq"
        );
        let a_scales = ScaleGroup::from_maxima(spec.a_gran, t2, 1, act_maxima, spec.a_bits);
        let wq = quantize_weights(&u, t2, oc, icg, &w_scales, spec.w_bits);
        // pre-pack each (frequency, group) block into the dispatched
        // integer GEMM's panel layout (plan-time, not per forward)
        let groups = plan.desc.groups;
        let blk = packed_b_i8_len(oc / groups, icg);
        let mut wqp = vec![0i8; t2 * groups * blk];
        pack_fast_weights_i8(&wq, oc, icg, groups, t2, &mut wqp);
        let packed = PackedBytesGuard::register(&plan, wqp.len());
        QConvLayer {
            plan,
            bias,
            kernel: QKernel::TransformDomain {
                oc,
                icg,
                wqp,
                w_scales,
                a_scales,
                a_bits: spec.a_bits,
                _packed: packed,
            },
            requant: None,
        }
    }

    fn spatial(
        plan: Arc<ConvPlan>,
        weight: &Tensor,
        bias: Vec<f32>,
        spec: QuantSpec,
        act_max_abs: f32,
        via_ntt: bool,
    ) -> QConvLayer {
        let (oc, icg, r, _) = weight.dims4();
        assert_eq!(
            icg * plan.desc.groups,
            plan.desc.ic,
            "weight channels {icg}×{} groups vs descriptor ic {}",
            plan.desc.groups,
            plan.desc.ic
        );
        assert!(!via_ntt || plan.desc.groups == 1, "the NTT spatial path is dense-only");
        let qmax = ((1i32 << (spec.w_bits - 1)) - 1) as f32;
        let mut w_scales = vec![1f32; oc];
        let mut wq = vec![0i8; oc * icg * r * r];
        for o in 0..oc {
            let row = &weight.data[o * icg * r * r..(o + 1) * icg * r * r];
            let m = super::max_abs(row);
            let s = if m > 0.0 { m / qmax } else { 1.0 };
            w_scales[o] = s;
            for (dst, &v) in wq[o * icg * r * r..(o + 1) * icg * r * r].iter_mut().zip(row) {
                *dst = ((v / s).round() as i32).clamp(-(qmax as i32), qmax as i32) as i8;
            }
        }
        let a_scale = QParams::from_max_abs(act_max_abs, spec.a_bits);
        QConvLayer {
            plan,
            bias,
            kernel: QKernel::Spatial { wq, oc, icg, r, w_scales, a_scale, via_ntt },
            requant: None,
        }
    }

    /// Which engine executes this layer.
    pub fn engine(&self) -> &'static str {
        self.plan.engine
    }

    /// The fused output epilogue carried by the plan descriptor (set by
    /// the graph compiler's conv+ReLU fusion).
    pub fn epilogue(&self) -> Epilogue {
        self.plan.desc.epilogue
    }

    /// The calibrated input quantizer of the spatial datapath (`None`
    /// for transform-domain layers, which quantize per-frequency after
    /// the input transform and therefore cannot consume raw int8
    /// activations).
    pub fn spatial_in_qparams(&self) -> Option<QParams> {
        match &self.kernel {
            QKernel::Spatial { a_scale, .. } => Some(*a_scale),
            QKernel::TransformDomain { .. } => None,
        }
    }

    /// Install the integer requantization output stage: the layer then
    /// emits int8 activations quantized at `out` (the consumer's
    /// calibrated input quantizer) through per-channel fixed-point
    /// multipliers — no f32 in the output path. Returns `false` — and
    /// installs nothing, keeping the f32 output path — for
    /// transform-domain layers (their per-frequency scale structure
    /// requires the float inverse transform, Eq. 17) and for
    /// degenerate scale ratios the fixed-point scheme cannot encode
    /// faithfully (a per-channel multiplier outside [`Requant`]'s q31
    /// range or ≥ 1 — `M < 1` is what keeps the i32 requant result
    /// wrap-free before the clamp — or a quantized bias overflowing the
    /// i32 accumulator headroom, as with near-dead channels' tiny
    /// weight scales). A refused installation also clears any
    /// previously-installed stage. The f32 fallback is always correct,
    /// just not integer-only.
    pub fn install_requant(&mut self, out: QParams) -> bool {
        // a refused (re-)installation must leave the layer on the f32
        // path, not on a stale stage for some earlier consumer scale
        self.requant = None;
        let QKernel::Spatial { oc, w_scales, a_scale, .. } = &self.kernel else {
            return false;
        };
        let mut mults = Vec::with_capacity(*oc);
        let mut bias_q = Vec::with_capacity(*oc);
        for o in 0..*oc {
            let acc_scale = a_scale.scale as f64 * w_scales[o] as f64;
            let Some(m) = Requant::try_from_real(acc_scale / out.scale as f64) else {
                return false;
            };
            // M < 1 (shift ≥ 0) guarantees |requant(acc)| ≤ |acc| + 1,
            // so the i32 result can never wrap before the clamp — a
            // multiplier ≥ 1 means a degenerately small output scale;
            // refuse the link rather than risk overflow on
            // out-of-calibration accumulators
            if m.shift < 0 {
                return false;
            }
            mults.push(m);
            let b = if self.bias.is_empty() { 0.0 } else { self.bias[o] } as f64;
            let bq = (b / acc_scale).round();
            // half the i32 range, so `acc + bias_q` cannot wrap either
            if bq.abs() > (i32::MAX / 2) as f64 {
                return false;
            }
            bias_q.push(bq as i32);
        }
        self.requant = Some(RequantStage { mults, bias_q, out });
        true
    }

    /// Remove the integer output stage (back to f32 outputs).
    pub fn clear_requant(&mut self) {
        self.requant = None;
    }

    /// True when the layer emits int8 activations (a requant stage is
    /// installed).
    pub fn produces_q(&self) -> bool {
        self.requant.is_some()
    }

    /// The output quantizer, when the layer is int8-producing.
    pub fn out_qparams(&self) -> Option<QParams> {
        self.requant.as_ref().map(|r| r.out)
    }

    /// Convenience wrapper over [`QConvLayer::forward_into`] with a
    /// throwaway workspace.
    pub fn forward(&self, x: &Tensor) -> Tensor {
        let mut ws = Workspace::new();
        self.forward_with(x, &mut ws)
    }

    /// Execute out of a caller workspace, allocating only the output.
    pub fn forward_with(&self, x: &Tensor, ws: &mut Workspace) -> Tensor {
        let mut out = Tensor::zeros(&self.out_dims(x));
        self.forward_into(x, ws, &mut out);
        out
    }

    /// Output shape for an actual input batch.
    pub fn out_dims(&self, x: &Tensor) -> Vec<usize> {
        self.out_dims_for(&x.dims)
    }

    /// Output shape from input dimensions (NCHW).
    pub fn out_dims_for(&self, in_dims: &[usize]) -> Vec<usize> {
        assert_eq!(in_dims.len(), 4, "expected NCHW, got {in_dims:?}");
        let (n, h, wid) = (in_dims[0], in_dims[2], in_dims[3]);
        let (stride, pad) = (self.plan.desc.stride, self.plan.desc.pad);
        let (oc, r) = match &self.kernel {
            QKernel::TransformDomain { oc, .. } => (*oc, self.plan.desc.r),
            QKernel::Spatial { oc, r, .. } => (*oc, *r),
        };
        let oh = (h + 2 * pad - r) / stride + 1;
        let ow = (wid + 2 * pad - r) / stride + 1;
        vec![n, oc, oh, ow]
    }

    /// The zero-alloc quantized entry point: execute out of `ws` straight
    /// into `out`. Bit-identical to [`QConvLayer::forward`] whether `ws`
    /// is fresh or reused. Any installed requant stage is ignored — this
    /// is the f32-producing path (and counts as one f32 activation
    /// materialization in [`crate::quant::dequant_materializations`]).
    pub fn forward_into(&self, x: &Tensor, ws: &mut Workspace, out: &mut Tensor) {
        let dil = self.plan.desc.dilation;
        assert_eq!(dil, 1, "quantized executors are undilated; plan dilated convs float-side");
        super::record_dequant_materialization();
        match &self.kernel {
            QKernel::TransformDomain { oc, icg, wqp, w_scales, a_scales, a_bits, .. } => {
                forward_transform_q(x, self, *oc, *icg, wqp, w_scales, a_scales, *a_bits, ws, out)
            }
            QKernel::Spatial { wq, oc, icg, r, w_scales, a_scale, via_ntt } => {
                if *via_ntt {
                    forward_spatial_ntt(
                        SpatialIn::F32(x),
                        self,
                        wq,
                        *oc,
                        *icg,
                        *r,
                        w_scales,
                        *a_scale,
                        ws,
                        SpatialOut::F32(out),
                    )
                } else {
                    forward_spatial_q(
                        SpatialIn::F32(x),
                        self,
                        wq,
                        *oc,
                        *icg,
                        *r,
                        w_scales,
                        *a_scale,
                        ws,
                        SpatialOut::F32(out),
                    )
                }
            }
        }
    }

    /// int8 in → f32 out: consume a producer's int8 activation directly
    /// (the tail of a compiled int8 chain). Spatial kernels only; the
    /// producer's scale must equal this layer's calibrated input scale
    /// to the bit.
    pub fn forward_q_into(&self, xq: &QTensor, ws: &mut Workspace, out: &mut Tensor) {
        super::record_dequant_materialization();
        self.run_spatial(SpatialIn::I8(xq), ws, SpatialOut::F32(out));
    }

    /// f32 in → int8 out: quantize the input with the calibrated
    /// quantizer, run the exact integer conv, requantize straight onto
    /// the consumer's grid (the head of a compiled int8 chain). Panics
    /// unless [`QConvLayer::install_requant`] ran.
    pub fn forward_into_q(&self, x: &Tensor, ws: &mut Workspace, out: &mut QTensor) {
        self.run_spatial(SpatialIn::F32(x), ws, SpatialOut::I8(out));
    }

    /// int8 in → int8 out: an interior link of a compiled int8 chain —
    /// no floating point anywhere between the producer's codes and the
    /// consumer's. Panics unless [`QConvLayer::install_requant`] ran.
    pub fn forward_q_into_q(&self, xq: &QTensor, ws: &mut Workspace, out: &mut QTensor) {
        self.run_spatial(SpatialIn::I8(xq), ws, SpatialOut::I8(out));
    }

    fn run_spatial(&self, input: SpatialIn, ws: &mut Workspace, out: SpatialOut) {
        let dil = self.plan.desc.dilation;
        assert_eq!(dil, 1, "quantized executors are undilated; plan dilated convs float-side");
        let QKernel::Spatial { wq, oc, icg, r, w_scales, a_scale, via_ntt } = &self.kernel else {
            panic!(
                "{}: transform-domain layers have no int8 dataflow entry (Eq. 17 needs the \
                 float inverse transform)",
                self.plan.engine
            );
        };
        if matches!(out, SpatialOut::I8(_)) {
            assert!(
                self.requant.is_some(),
                "int8 output requested but no requant stage installed (run the graph \
                 compiler's int8-dataflow pass / install_requant first)"
            );
        }
        if *via_ntt {
            forward_spatial_ntt(input, self, wq, *oc, *icg, *r, w_scales, *a_scale, ws, out)
        } else {
            forward_spatial_q(input, self, wq, *oc, *icg, *r, w_scales, *a_scale, ws, out)
        }
    }
}

/// The spatial executors' input operand: a float tensor to quantize, or
/// a producer's int8 codes to consume directly.
enum SpatialIn<'a> {
    /// float activation (quantized with the layer's calibrated scale)
    F32(&'a Tensor),
    /// int8 activation from an upstream requantizing conv
    I8(&'a QTensor),
}

impl SpatialIn<'_> {
    fn dims4(&self) -> (usize, usize, usize, usize) {
        match self {
            SpatialIn::F32(t) => t.dims4(),
            SpatialIn::I8(q) => q.dims4(),
        }
    }
}

/// The spatial executors' output operand: dequantize to f32, or
/// requantize onto the consumer's int8 grid.
enum SpatialOut<'a> {
    /// f32 output (dequantize + bias + epilogue)
    F32(&'a mut Tensor),
    /// int8 output (integer bias + fixed-point requant + clamp)
    I8(&'a mut QTensor),
}

/// Input codes for the spatial integer conv: owned (freshly quantized
/// into a workspace buffer) or borrowed from the producer's [`QTensor`].
enum Codes<'a> {
    Owned(Vec<i8>),
    Borrowed(&'a [i8]),
}

impl Codes<'_> {
    fn slice(&self) -> &[i8] {
        match self {
            Codes::Owned(v) => v,
            Codes::Borrowed(s) => s,
        }
    }

    fn give(self, ws: &mut Workspace) {
        if let Codes::Owned(v) = self {
            ws.give_i8(v);
        }
    }
}

/// Resolve the input codes: quantize a float input with the calibrated
/// quantizer (dispatched SIMD), or borrow the producer's codes after
/// asserting the int8-dataflow scale contract bit-exactly.
fn take_codes<'a>(input: &SpatialIn<'a>, a_scale: QParams, ws: &mut Workspace) -> Codes<'a> {
    match input {
        SpatialIn::F32(x) => {
            let mut xq = ws.take_i8(x.data.len());
            quantize_i8_slice(&x.data, a_scale.scale, a_scale.qmax, &mut xq);
            Codes::Owned(xq)
        }
        SpatialIn::I8(q) => {
            assert_eq!(
                q.scale.to_bits(),
                a_scale.scale.to_bits(),
                "int8 dataflow scale contract violated: producer scale {} vs calibrated \
                 input scale {}",
                q.scale,
                a_scale.scale
            );
            Codes::Borrowed(&q.data)
        }
    }
}

fn quantize_weights(u: &[f32], t2: usize, oc: usize, ic: usize, scales: &ScaleGroup, bits: u32) -> Vec<i8> {
    let qmax = ((1i32 << (bits - 1)) - 1) as f32;
    let mut wq = vec![0i8; t2 * oc * ic];
    for uv in 0..t2 {
        for o in 0..oc {
            let s = scales.scale(uv, o);
            for i in 0..ic {
                let v = u[(uv * oc + o) * ic + i];
                wq[(uv * oc + o) * ic + i] =
                    ((v / s).round() as i32).clamp(-(qmax as i32), qmax as i32) as i8;
            }
        }
    }
    wq
}

/// Per-worker scratch for the quantized transform-domain path (tile
/// buffers lane-batched, [`TILE_LANES`] wide).
struct QFastScratch {
    /// quantized V blocks, freq-major [T²][tiles][IC]
    vq: Vec<i8>,
    /// exact i32 ⊙ accumulators, freq-major [T²][tiles][OC]
    pi: Vec<i32>,
    tile: Vec<f32>,
    tscr: Vec<f32>,
    tv: Vec<f32>,
    prod: Vec<f32>,
    iscr: Vec<f32>,
    ytile: Vec<f32>,
}

#[allow(clippy::too_many_arguments)]
fn forward_transform_q(
    x: &Tensor,
    layer: &QConvLayer,
    oc: usize,
    icg: usize,
    wqp: &[i8],
    w_scales: &ScaleGroup,
    a_scales: &ScaleGroup,
    a_bits: u32,
    ws: &mut Workspace,
    out: &mut Tensor,
) {
    let plan = layer.plan.fast_plan().expect("bilinear plan");
    let groups = layer.plan.desc.groups;
    let ic = icg * groups;
    let ocg = oc / groups;
    let (n, ic2, h, wid) = x.dims4();
    assert_eq!(ic, ic2);
    let (m, l, t) = (plan.m(), plan.l(), plan.t());
    let r = plan.r();
    let pad = layer.plan.desc.pad;
    let oh = h + 2 * pad - r + 1;
    let ow = wid + 2 * pad - r + 1;
    assert_eq!(out.dims, [n, oc, oh, ow], "output shape mismatch: {:?}", out.dims);
    let tiles_y = oh.div_ceil(m);
    let tiles_x = ow.div_ceil(m);
    let n_tiles = tiles_y * tiles_x;
    let ntg = n_tiles.div_ceil(TILE_LANES);
    let tt = t * t;
    let a_qmax = (1i32 << (a_bits - 1)) - 1;
    let ep = layer.epilogue();
    let blk = packed_b_i8_len(ocg, icg);
    assert!(wqp.len() >= tt * groups * blk, "packed quantized weights too small");

    // Per-image workers; the int8 per-(freq,group) GEMMs below may also
    // thread over rows under the same CoreBudget (nested parallelism
    // degrades to serial inner GEMMs when the batch uses every lane).
    let workers = num_threads().min(n).max(1);
    let mut states: Vec<QFastScratch> = (0..workers)
        .map(|_| QFastScratch {
            vq: ws.take_i8(tt * n_tiles * ic),
            pi: ws.take_i32(tt * n_tiles * oc),
            tile: ws.take_f32(l * l * TILE_LANES),
            tscr: ws.take_f32(t * l * TILE_LANES),
            tv: ws.take_f32(tt * TILE_LANES),
            prod: ws.take_f32(tt * TILE_LANES),
            iscr: ws.take_f32(m * t * TILE_LANES),
            ytile: ws.take_f32(m * m * TILE_LANES),
        })
        .collect();
    par_chunks_states(&mut out.data, oc * oh * ow, &mut states, |st, ni, out_img| {
        // 1) lane-batched gather + transform + QUANTIZE tile groups:
        //    Vq group-major [T²][G][tiles][IC/g]
        //    (== [T²][tiles][IC] when groups == 1)
        for tg in 0..ntg {
            let base = tg * TILE_LANES;
            let lanes = (n_tiles - base).min(TILE_LANES);
            for c in 0..ic {
                let (gi, il) = (c / icg, c % icg);
                gather_tiles8(x, ni, c, base, lanes, tiles_x, m, l, pad, &mut st.tile);
                plan.transform_tiles8(&st.tile, &mut st.tscr, &mut st.tv);
                for uv in 0..tt {
                    let s = a_scales.scale(uv, 0);
                    let row = ((uv * groups + gi) * n_tiles + base) * icg + il;
                    for lane in 0..lanes {
                        let q = (st.tv[uv * TILE_LANES + lane] / s).round() as i32;
                        st.vq[row + lane * icg] = q.clamp(-a_qmax, a_qmax) as i8;
                    }
                }
            }
        }
        // 2) dispatched integer per-(frequency, group) packed GEMM,
        //    i32 accumulation (exact): PI[uv][g] = Vq[uv][g] · Wq[uv][g]ᵀ
        //    ([tiles×IC/g]·[IC/g×OC/g]). The tt·groups products are
        //    independent (disjoint PI blocks, job = uv·groups + gi), so
        //    they are submitted as one batch of stealable pool tasks;
        //    integer accumulation is exact under any schedule.
        let vq = &st.vq;
        let piblocks = &mut st.pi[..tt * groups * n_tiles * ocg];
        par_chunks_mut(piblocks, n_tiles * ocg, |job, pblk| {
            let vb = job * n_tiles * icg;
            let ub = job * blk;
            let vblk = &vq[vb..vb + n_tiles * icg];
            let ublk = &wqp[ub..ub + blk];
            gemm_packed_i8_i32(n_tiles, ocg, icg, vblk, ublk, pblk);
        });
        // 3) lane-batched dequantize + inverse transform + bias + scatter
        for o in 0..oc {
            let (gi, ol) = (o / ocg, o % ocg);
            let b = if layer.bias.is_empty() { 0.0 } else { layer.bias[o] };
            let plane = &mut out_img[o * oh * ow..(o + 1) * oh * ow];
            for tg in 0..ntg {
                let base = tg * TILE_LANES;
                let lanes = (n_tiles - base).min(TILE_LANES);
                for uv in 0..tt {
                    // dequantize: both operand scales
                    let sa = a_scales.scale(uv, 0);
                    let sw = w_scales.scale(uv, o);
                    let row = ((uv * groups + gi) * n_tiles + base) * ocg + ol;
                    for lane in 0..lanes {
                        st.prod[uv * TILE_LANES + lane] =
                            st.pi[row + lane * ocg] as f32 * sa * sw;
                    }
                }
                plan.inverse_tiles8(&st.prod, &mut st.iscr, &mut st.ytile);
                for lane in 0..lanes {
                    let tile_idx = base + lane;
                    let (ty, tx) = (tile_idx / tiles_x, tile_idx % tiles_x);
                    for i in 0..m.min(oh - ty * m) {
                        for j in 0..m.min(ow - tx * m) {
                            plane[(ty * m + i) * ow + tx * m + j] =
                                ep.apply(st.ytile[(i * m + j) * TILE_LANES + lane] + b);
                        }
                    }
                }
            }
        }
    });
    for st in states {
        ws.give_i8(st.vq);
        ws.give_i32(st.pi);
        ws.give_f32(st.tile);
        ws.give_f32(st.tscr);
        ws.give_f32(st.tv);
        ws.give_f32(st.prod);
        ws.give_f32(st.iscr);
        ws.give_f32(st.ytile);
    }
}

/// One output plane of the exact integer spatial conv: accumulate
/// `acc[idx]` in i32 (the shared core of the f32- and int8-producing
/// output stages — identical accumulators, so the two stages differ
/// only in how the plane is written).
#[allow(clippy::too_many_arguments)]
fn spatial_plane_acc(
    xq: &[i8],
    ic: usize,
    h: usize,
    wid: usize,
    ni: usize,
    o: usize,
    wq: &[i8],
    icg: usize,
    ocg: usize,
    r: usize,
    stride: usize,
    pad: usize,
    oh: usize,
    ow: usize,
    mut emit: impl FnMut(usize, i32),
) {
    let gi = o / ocg;
    for oy in 0..oh {
        for ox in 0..ow {
            let mut acc: i32 = 0;
            for il in 0..icg {
                let ci = gi * icg + il;
                let xplane = &xq[(ni * ic + ci) * h * wid..(ni * ic + ci + 1) * h * wid];
                let wplane = &wq[(o * icg + il) * r * r..(o * icg + il + 1) * r * r];
                for ky in 0..r {
                    let yy = oy * stride + ky;
                    if yy < pad || yy >= h + pad {
                        continue;
                    }
                    let yy = yy - pad;
                    for kx in 0..r {
                        let xx = ox * stride + kx;
                        if xx < pad || xx >= wid + pad {
                            continue;
                        }
                        acc += (wplane[ky * r + kx] as i32) * (xplane[yy * wid + xx - pad] as i32);
                    }
                }
            }
            emit(oy * ow + ox, acc);
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn forward_spatial_q(
    input: SpatialIn,
    layer: &QConvLayer,
    wq: &[i8],
    oc: usize,
    icg: usize,
    r: usize,
    w_scales: &[f32],
    a_scale: QParams,
    ws: &mut Workspace,
    out: SpatialOut,
) {
    let groups = layer.plan.desc.groups;
    let ic = icg * groups;
    let ocg = oc / groups;
    let (n, ic2, h, wid) = input.dims4();
    assert_eq!(ic, ic2);
    let (stride, pad) = (layer.plan.desc.stride, layer.plan.desc.pad);
    let oh = (h + 2 * pad - r) / stride + 1;
    let ow = (wid + 2 * pad - r) / stride + 1;
    let ep = layer.epilogue();
    let xq = take_codes(&input, a_scale, ws);
    let codes = xq.slice();
    match out {
        SpatialOut::F32(out) => {
            assert_eq!(out.dims, [n, oc, oh, ow], "output shape mismatch: {:?}", out.dims);
            par_chunks_mut(&mut out.data, oh * ow, |job, plane| {
                let (ni, o) = (job / oc, job % oc);
                let deq = a_scale.scale * w_scales[o];
                let b = if layer.bias.is_empty() { 0.0 } else { layer.bias[o] };
                spatial_plane_acc(
                    codes,
                    ic,
                    h,
                    wid,
                    ni,
                    o,
                    wq,
                    icg,
                    ocg,
                    r,
                    stride,
                    pad,
                    oh,
                    ow,
                    |idx, acc| plane[idx] = ep.apply(acc as f32 * deq + b),
                );
            });
        }
        SpatialOut::I8(outq) => {
            let rq = layer.requant.as_ref().expect("run_spatial checked the requant stage");
            assert_eq!(
                outq.dims,
                [n, oc, oh, ow],
                "output shape mismatch: {:?}",
                outq.dims
            );
            outq.scale = rq.out.scale;
            // the int8-domain fused ReLU is a clamp floor at code 0
            let lo = if ep == Epilogue::Relu { 0 } else { -rq.out.qmax };
            let hi = rq.out.qmax;
            // per-worker i32 accumulator planes, then one dispatched
            // requant sweep per plane (SIMD on AVX2 hosts)
            let workers = num_threads().min(n * oc).max(1);
            let mut states: Vec<Vec<i32>> = (0..workers).map(|_| ws.take_i32(oh * ow)).collect();
            par_chunks_states(&mut outq.data, oh * ow, &mut states, |accp, job, plane| {
                let (ni, o) = (job / oc, job % oc);
                spatial_plane_acc(
                    codes,
                    ic,
                    h,
                    wid,
                    ni,
                    o,
                    wq,
                    icg,
                    ocg,
                    r,
                    stride,
                    pad,
                    oh,
                    ow,
                    |idx, acc| accp[idx] = acc,
                );
                let m = rq.mults[o];
                requant_i8_slice(accp, rq.bias_q[o], m.m0, m.shift, lo, hi, plane);
            });
            for st in states {
                ws.give_i32(st);
            }
        }
    }
    xq.give(ws);
}

/// The NTT-backed spatial path: bit-identical accumulators to
/// [`forward_spatial_q`] (both are exact integer arithmetic), computed
/// through the frequency domain — the Table-3 NTT accelerator datapath.
/// Dense only (the NTT engine's `supports` rejects grouped
/// descriptors).
#[allow(clippy::too_many_arguments)]
fn forward_spatial_ntt(
    input: SpatialIn,
    layer: &QConvLayer,
    wq: &[i8],
    oc: usize,
    ic: usize,
    r: usize,
    w_scales: &[f32],
    a_scale: QParams,
    ws: &mut Workspace,
    out: SpatialOut,
) {
    let (n, ic2, h, wid) = input.dims4();
    assert_eq!(ic, ic2);
    assert_eq!(layer.plan.desc.groups, 1, "NTT path is dense-only");
    let pad = layer.plan.desc.pad;
    assert_eq!(layer.plan.desc.stride, 1, "NTT path is stride-1");
    let oh = h + 2 * pad - r + 1;
    let ow = wid + 2 * pad - r + 1;
    let ep = layer.epilogue();
    let xq = take_codes(&input, a_scale, ws);
    let mut acc = ws.take_i64(n * oc * oh * ow);
    // both arms are exact integer arithmetic, so they are bit-identical;
    // the tiled arm just bounds transform workspace by the tile length
    match layer.plan.kernel {
        PlanKernel::NttTiled { tile } => {
            ntt_corr2d_i8_tiled_into(xq.slice(), n, ic, h, wid, wq, oc, r, pad, tile, ws, &mut acc)
        }
        _ => ntt_corr2d_i8_into(xq.slice(), n, ic, h, wid, wq, oc, r, pad, ws, &mut acc),
    }
    match out {
        SpatialOut::F32(out) => {
            assert_eq!(out.dims, [n, oc, oh, ow], "output shape mismatch: {:?}", out.dims);
            for ni in 0..n {
                for o in 0..oc {
                    let deq = a_scale.scale * w_scales[o];
                    let b = if layer.bias.is_empty() { 0.0 } else { layer.bias[o] };
                    let src = &acc[(ni * oc + o) * oh * ow..(ni * oc + o + 1) * oh * ow];
                    let dst = out.plane_mut(ni, o);
                    for (d, &a) in dst.iter_mut().zip(src) {
                        *d = ep.apply(a as f32 * deq + b);
                    }
                }
            }
        }
        SpatialOut::I8(outq) => {
            let rq = layer.requant.as_ref().expect("run_spatial checked the requant stage");
            assert_eq!(outq.dims, [n, oc, oh, ow], "output shape mismatch: {:?}", outq.dims);
            outq.scale = rq.out.scale;
            let lo = if ep == Epilogue::Relu { 0 } else { -rq.out.qmax };
            let hi = rq.out.qmax;
            // the NTT engine's accumulator bound (supports(): IC·R² ≤
            // 16384) keeps |acc| < 2³¹, so the i64 → i32 narrowing is
            // exact and the output stage is the same dispatched requant
            // sweep as the direct path — the two stay bit-identical.
            let mut acc32 = ws.take_i32(oh * ow);
            for ni in 0..n {
                for o in 0..oc {
                    let src = &acc[(ni * oc + o) * oh * ow..(ni * oc + o + 1) * oh * ow];
                    for (d, &a) in acc32.iter_mut().zip(src) {
                        *d = a as i32;
                    }
                    let base = (ni * oc + o) * oh * ow;
                    let m = rq.mults[o];
                    requant_i8_slice(
                        &acc32,
                        rq.bias_q[o],
                        m.m0,
                        m.shift,
                        lo,
                        hi,
                        &mut outq.data[base..base + oh * ow],
                    );
                }
            }
            ws.give_i32(acc32);
        }
    }
    xq.give(ws);
    ws.give_i64(acc);
}

/// Collect per-frequency max |BᵀxB| statistics over a batch (calibration).
pub fn collect_act_maxima(x: &Tensor, plan: &FastConvPlan, pad: usize) -> Vec<f32> {
    let (n, ic, h, wid) = x.dims4();
    let (m, l, t) = (plan.m(), plan.l(), plan.t());
    let r = plan.r();
    let oh = h + 2 * pad - r + 1;
    let ow = wid + 2 * pad - r + 1;
    let tiles_y = oh.div_ceil(m);
    let tiles_x = ow.div_ceil(m);
    let tt = t * t;
    let mut maxima = vec![0f32; tt];
    let mut tile = vec![0f32; l * l];
    let mut scratch = vec![0f32; t * l];
    let mut tv = vec![0f32; tt];
    for ni in 0..n {
        for c in 0..ic {
            for ty in 0..tiles_y {
                for tx in 0..tiles_x {
                    gather_tile(x, ni, c, ty, tx, m, l, pad, &mut tile);
                    plan.transform_tile(&tile, &mut scratch, &mut tv);
                    for uv in 0..tt {
                        maxima[uv] = maxima[uv].max(tv[uv].abs());
                    }
                }
            }
        }
    }
    maxima
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{default_selector, ConvDesc};
    use crate::nn::conv::conv2d_direct;
    use crate::util::Pcg32;

    fn rand_tensor(dims: &[usize], rng: &mut Pcg32, sigma: f64) -> Tensor {
        let mut t = Tensor::zeros(dims);
        rng.fill_gaussian(&mut t.data, sigma);
        t
    }

    fn transform_spec(w_bits: u32, a_bits: u32, w_gran: Granularity, a_gran: Granularity) -> QuantSpec {
        QuantSpec { w_bits, a_bits, w_gran, a_gran }
    }

    fn named_plan(name: &str, desc: ConvDesc) -> Arc<ConvPlan> {
        default_selector().plan_named(name, &desc).unwrap()
    }

    #[test]
    fn int8_fast_close_to_fp32() {
        let mut rng = Pcg32::seeded(42);
        let x = rand_tensor(&[1, 4, 14, 14], &mut rng, 1.0);
        let w = rand_tensor(&[4, 4, 3, 3], &mut rng, 0.3);
        let spec = transform_spec(8, 8, Granularity::ChannelFreq, Granularity::Freq);
        let desc = ConvDesc::new(1, 4, 4, 14, 14, 3, 1, 1).with_quant(spec);
        let plan = named_plan("SFC-6(7x7,3x3)", desc);
        let maxima = collect_act_maxima(&x, plan.fast_plan().unwrap(), 1);
        let q = QConvLayer::from_plan(plan, &w, vec![0.0; 4], &QCalib::TransformMaxima(&maxima));
        let want = conv2d_direct(&x, &w, &[0.0; 4], 1, 1);
        let got = q.forward(&x);
        let rel = got.mse(&want) / want.data.iter().map(|v| (*v as f64).powi(2)).sum::<f64>()
            * want.len() as f64;
        assert!(rel < 1e-3, "relative error {rel}");
    }

    #[test]
    fn int4_worse_than_int8() {
        let mut rng = Pcg32::seeded(43);
        let x = rand_tensor(&[1, 4, 12, 12], &mut rng, 1.0);
        let w = rand_tensor(&[4, 4, 3, 3], &mut rng, 0.3);
        let want = conv2d_direct(&x, &w, &[], 1, 1);
        let mut errs = Vec::new();
        for bits in [8u32, 4] {
            let spec = transform_spec(bits, bits, Granularity::ChannelFreq, Granularity::Freq);
            let desc = ConvDesc::new(1, 4, 4, 12, 12, 3, 1, 1).with_quant(spec);
            let plan = named_plan("SFC-6(6x6,3x3)", desc);
            let maxima = collect_act_maxima(&x, plan.fast_plan().unwrap(), 1);
            let q = QConvLayer::from_plan(plan, &w, vec![], &QCalib::TransformMaxima(&maxima));
            errs.push(q.forward(&x).mse(&want));
        }
        assert!(errs[1] > errs[0] * 4.0, "int4 {} vs int8 {}", errs[1], errs[0]);
    }

    #[test]
    fn freq_granularity_beats_tensor_for_winograd() {
        // Table 4's core claim: Winograd needs frequency-wise scales.
        let mut rng = Pcg32::seeded(44);
        let x = rand_tensor(&[1, 8, 12, 12], &mut rng, 1.0);
        let w = rand_tensor(&[8, 8, 3, 3], &mut rng, 0.3);
        let want = conv2d_direct(&x, &w, &[], 1, 1);
        let mut errs = Vec::new();
        for (w_gran, a_gran) in [
            (Granularity::Channel, Granularity::Tensor),
            (Granularity::ChannelFreq, Granularity::Freq),
        ] {
            let spec = transform_spec(8, 8, w_gran, a_gran);
            let desc = ConvDesc::new(1, 8, 8, 12, 12, 3, 1, 1).with_quant(spec);
            let plan = named_plan("Wino(4x4,3x3)", desc);
            let maxima = collect_act_maxima(&x, plan.fast_plan().unwrap(), 1);
            let q = QConvLayer::from_plan(plan, &w, vec![], &QCalib::TransformMaxima(&maxima));
            errs.push(q.forward(&x).mse(&want));
        }
        let (e_tensor, e_freq) = (errs[0], errs[1]);
        assert!(e_freq < e_tensor, "freq {e_freq} must beat tensor {e_tensor}");
    }

    #[test]
    fn direct_quantized_close() {
        let mut rng = Pcg32::seeded(45);
        let x = rand_tensor(&[2, 3, 9, 9], &mut rng, 1.0);
        let w = rand_tensor(&[5, 3, 3, 3], &mut rng, 0.3);
        let spec = QuantSpec::spatial_default(8);
        let desc = ConvDesc::new(2, 3, 5, 9, 9, 3, 1, 1).with_quant(spec);
        let plan = named_plan("direct", desc);
        let q = QConvLayer::from_plan(plan, &w, vec![0.0; 5], &QCalib::MaxAbs(x.max_abs()));
        let want = conv2d_direct(&x, &w, &[0.0; 5], 1, 1);
        let got = q.forward(&x);
        let denom = want.data.iter().map(|v| (*v as f64).powi(2)).sum::<f64>() / want.len() as f64;
        assert!(got.mse(&want) / denom < 1e-3);
    }

    #[test]
    fn direct_q_respects_stride() {
        let mut rng = Pcg32::seeded(46);
        let x = rand_tensor(&[1, 2, 8, 8], &mut rng, 1.0);
        let w = rand_tensor(&[2, 2, 3, 3], &mut rng, 0.3);
        let spec = QuantSpec::spatial_default(8);
        let desc = ConvDesc::new(1, 2, 2, 8, 8, 3, 2, 1).with_quant(spec);
        let plan = named_plan("direct", desc);
        let q = QConvLayer::from_plan(plan, &w, vec![], &QCalib::MaxAbs(x.max_abs()));
        let got = q.forward(&x);
        assert_eq!(got.dims, vec![1, 2, 4, 4]);
    }

    #[test]
    fn grouped_int8_spatial_matches_per_group_dense_exactly() {
        // The grouped direct int8 path vs slicing each group into its
        // own dense layer under identical calibration: both are exact
        // integer arithmetic over the same quantizers → equal to the bit.
        let mut rng = Pcg32::seeded(48);
        let (n, ic, oc, hw, groups) = (1usize, 4usize, 4usize, 8usize, 2usize);
        let (icg, ocg) = (ic / groups, oc / groups);
        let x = rand_tensor(&[n, ic, hw, hw], &mut rng, 1.0);
        let w = rand_tensor(&[oc, icg, 3, 3], &mut rng, 0.3);
        let spec = QuantSpec::spatial_default(8);
        let desc = ConvDesc::new(n, ic, oc, hw, hw, 3, 1, 1).with_groups(groups).with_quant(spec);
        let plan = named_plan("direct", desc);
        let calib = QCalib::MaxAbs(x.max_abs());
        let q = QConvLayer::from_plan(plan, &w, vec![], &calib);
        let got = q.forward(&x);
        for gi in 0..groups {
            let mut xg = Tensor::zeros(&[n, icg, hw, hw]);
            for ni in 0..n {
                for il in 0..icg {
                    xg.plane_mut(ni, il).copy_from_slice(x.plane(ni, gi * icg + il));
                }
            }
            let mut wg = Tensor::zeros(&[ocg, icg, 3, 3]);
            wg.data.copy_from_slice(&w.data[gi * ocg * icg * 9..(gi + 1) * ocg * icg * 9]);
            let dg = ConvDesc::new(n, icg, ocg, hw, hw, 3, 1, 1).with_quant(spec);
            let qg = QConvLayer::from_plan(named_plan("direct", dg), &wg, vec![], &calib);
            let want = qg.forward(&xg);
            for ni in 0..n {
                for ol in 0..ocg {
                    assert_eq!(
                        got.plane(ni, gi * ocg + ol),
                        want.plane(ni, ol),
                        "group {gi} out-channel {ol}"
                    );
                }
            }
        }
    }

    #[test]
    fn depthwise_int8_transform_close_to_float() {
        let mut rng = Pcg32::seeded(49);
        let (ic, hw) = (8usize, 14usize);
        let x = rand_tensor(&[1, ic, hw, hw], &mut rng, 1.0);
        let w = rand_tensor(&[ic, 1, 3, 3], &mut rng, 0.3);
        let spec = transform_spec(8, 8, Granularity::ChannelFreq, Granularity::Freq);
        let desc = ConvDesc::new(1, ic, ic, hw, hw, 3, 1, 1).with_groups(ic).with_quant(spec);
        let plan = named_plan("SFC-6(7x7,3x3)", desc);
        let maxima = collect_act_maxima(&x, plan.fast_plan().unwrap(), 1);
        let q = QConvLayer::from_plan(plan, &w, vec![0.0; ic], &QCalib::TransformMaxima(&maxima));
        let want = crate::nn::conv::conv2d_direct_grouped(&x, &w, &[0.0; ic], 1, 1, ic);
        let got = q.forward(&x);
        assert_eq!(got.dims, want.dims);
        let denom = want.data.iter().map(|v| (*v as f64).powi(2)).sum::<f64>() / want.len() as f64;
        let rel = got.mse(&want) / denom;
        assert!(rel < 5e-3, "depthwise int8 transform rel error {rel}");
    }

    #[test]
    fn ntt_spatial_matches_direct_spatial_exactly() {
        // Both paths run exact integer arithmetic on identical quantizers,
        // so their outputs must agree to the last bit.
        let mut rng = Pcg32::seeded(47);
        let x = rand_tensor(&[2, 3, 10, 10], &mut rng, 1.0);
        let w = rand_tensor(&[4, 3, 3, 3], &mut rng, 0.3);
        let spec = QuantSpec::spatial_default(8);
        let desc = ConvDesc::new(2, 3, 4, 10, 10, 3, 1, 1).with_quant(spec);
        let pd = named_plan("direct", desc);
        let pn = named_plan("NTT", desc);
        let calib = QCalib::MaxAbs(x.max_abs());
        let qd = QConvLayer::from_plan(pd, &w, vec![0.1; 4], &calib);
        let qn = QConvLayer::from_plan(pn, &w, vec![0.1; 4], &calib);
        assert_eq!(qn.engine(), "NTT");
        let yd = qd.forward(&x);
        let yn = qn.forward(&x);
        assert_eq!(yd.dims, yn.dims);
        assert!(yd.mse(&yn) < 1e-12, "NTT vs direct int path mse {}", yd.mse(&yn));
    }
}
