//! Quantization library: symmetric affine quantizers, the paper's scale
//! granularities (§5, Eq. 17), the transform-domain-quantized conv
//! executor and the AdaQuant-lite PTQ calibrator (§6.1).

pub mod calib;
pub mod qconv;

pub use calib::{quantize_model, QuantConfig};
pub use qconv::{Granularity, QConvLayer};

/// Symmetric intN quantization parameters for one scale group.
#[derive(Clone, Copy, Debug)]
pub struct QParams {
    /// float value of one integer step
    pub scale: f32,
    /// top code (2^(bits−1) − 1)
    pub qmax: i32,
}

impl QParams {
    /// Scale chosen so `max_abs` maps to the top code.
    pub fn from_max_abs(max_abs: f32, bits: u32) -> QParams {
        let qmax = (1i32 << (bits - 1)) - 1;
        let scale = if max_abs > 0.0 { max_abs / qmax as f32 } else { 1.0 };
        QParams { scale, qmax }
    }

    #[inline]
    /// Round to the integer grid, clamped to ±qmax.
    pub fn quantize(&self, v: f32) -> i32 {
        let q = (v / self.scale).round() as i32;
        q.clamp(-self.qmax, self.qmax)
    }

    #[inline]
    /// Map an integer code back to float.
    pub fn dequantize(&self, q: i32) -> f32 {
        q as f32 * self.scale
    }

    /// Round-trip a value through the integer grid.
    #[inline]
    pub fn fake_quant(&self, v: f32) -> f32 {
        self.dequantize(self.quantize(v))
    }
}

/// Max |v| over a slice.
pub fn max_abs(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |m, v| m.max(v.abs()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn int8_round_trip_error() {
        let q = QParams::from_max_abs(2.0, 8);
        assert_eq!(q.qmax, 127);
        for i in 0..100 {
            let v = -2.0 + 4.0 * i as f32 / 99.0;
            let e = (q.fake_quant(v) - v).abs();
            assert!(e <= q.scale * 0.5 + 1e-6, "v={v} err={e}");
        }
    }

    #[test]
    fn clamping() {
        let q = QParams::from_max_abs(1.0, 4);
        assert_eq!(q.qmax, 7);
        assert_eq!(q.quantize(10.0), 7);
        assert_eq!(q.quantize(-10.0), -7);
    }

    #[test]
    fn zero_range_safe() {
        let q = QParams::from_max_abs(0.0, 8);
        assert_eq!(q.quantize(0.0), 0);
        assert_eq!(q.fake_quant(0.0), 0.0);
    }

    #[test]
    fn lower_bits_coarser() {
        let v = 0.73f32;
        let e8 = (QParams::from_max_abs(1.0, 8).fake_quant(v) - v).abs();
        let e4 = (QParams::from_max_abs(1.0, 4).fake_quant(v) - v).abs();
        assert!(e4 > e8);
    }
}
