//! Quantization library: symmetric affine quantizers, the paper's scale
//! granularities (§5, Eq. 17), the transform-domain-quantized conv
//! executor and the AdaQuant-lite PTQ calibrator (§6.1).

pub mod calib;
pub mod qconv;

pub use calib::{quantize_model, QuantConfig};
pub use qconv::{Granularity, QConvLayer};

use crate::nn::tensor::Tensor;
use std::sync::atomic::{AtomicU64, Ordering};

/// Symmetric intN quantization parameters for one scale group.
#[derive(Clone, Copy, Debug)]
pub struct QParams {
    /// float value of one integer step
    pub scale: f32,
    /// top code (2^(bits−1) − 1)
    pub qmax: i32,
}

impl QParams {
    /// Scale chosen so `max_abs` maps to the top code.
    pub fn from_max_abs(max_abs: f32, bits: u32) -> QParams {
        let qmax = (1i32 << (bits - 1)) - 1;
        let scale = if max_abs > 0.0 { max_abs / qmax as f32 } else { 1.0 };
        QParams { scale, qmax }
    }

    #[inline]
    /// Round to the integer grid, clamped to ±qmax.
    pub fn quantize(&self, v: f32) -> i32 {
        let q = (v / self.scale).round() as i32;
        q.clamp(-self.qmax, self.qmax)
    }

    #[inline]
    /// Map an integer code back to float.
    pub fn dequantize(&self, q: i32) -> f32 {
        q as f32 * self.scale
    }

    /// Round-trip a value through the integer grid.
    #[inline]
    pub fn fake_quant(&self, v: f32) -> f32 {
        self.dequantize(self.quantize(v))
    }
}

/// Max |v| over a slice.
pub fn max_abs(xs: &[f32]) -> f32 {
    xs.iter().fold(0.0f32, |m, v| m.max(v.abs()))
}

/// A quantized int8 activation tensor: NCHW codes plus the symmetric
/// scale they were produced at. This is what flows between
/// consecutive quantized convs in a compiled graph — the consumer
/// asserts the producer's `scale` matches its own calibrated input
/// quantizer, so the int8 dataflow can never silently mix scales.
#[derive(Clone, Debug)]
pub struct QTensor {
    /// dimension sizes, outermost first (NCHW)
    pub dims: Vec<usize>,
    /// int8 codes, row-major
    pub data: Vec<i8>,
    /// float value of one integer step
    pub scale: f32,
}

impl QTensor {
    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the tensor has no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// The shape as (N, C, H, W); panics unless 4-D.
    pub fn dims4(&self) -> (usize, usize, usize, usize) {
        assert_eq!(self.dims.len(), 4, "expected NCHW, got {:?}", self.dims);
        (self.dims[0], self.dims[1], self.dims[2], self.dims[3])
    }

    /// One image plane (n, c) as a contiguous slice.
    pub fn plane(&self, n: usize, c: usize) -> &[i8] {
        let (_, cc, hh, ww) = self.dims4();
        let base = (n * cc + c) * hh * ww;
        &self.data[base..base + hh * ww]
    }

    /// Decode to an f32 tensor (`v = q · scale`) — probe/debug use
    /// only; the compiled hot path never materializes this between
    /// quantized convs.
    pub fn dequantize(&self) -> Tensor {
        let data = self.data.iter().map(|&q| q as f32 * self.scale).collect();
        Tensor::from_vec(&self.dims, data)
    }
}

/// Fixed-point requantization multiplier: represents a positive real
/// scale ratio as `m0 · 2^-(31+shift)` with the q31 mantissa `m0` in
/// `[2^30, 2^31)` — the integer-only rescaling scheme of "Efficient
/// Winograd Convolution via Integer Arithmetic" (Meng & Brothers) and
/// gemmlowp. [`Requant::apply`] maps an i32 accumulator to the output
/// integer grid without touching floating point; the rounding is exact
/// half-away-from-zero, matching the crate's float quantizer
/// ([`crate::linalg::simd::quantize_i8_slice`]), so the integer chain
/// stays within 1 code of the dequantize→quantize reference.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Requant {
    /// q31 mantissa in `[2^30, 2^31)` (smaller only for underflowing
    /// scales clamped by the constructor)
    pub m0: i32,
    /// additional right shift (may be negative for multipliers > 1;
    /// `31 + shift` is always in `1..=62`)
    pub shift: i32,
}

impl Requant {
    /// The frexp-style mantissa decomposition shared by both
    /// constructors: `real = frac·2^exp` with `frac ∈ [0.5, 1)`,
    /// returning `(round(frac·2^31), -exp)` with the rounding carry
    /// folded back into the exponent. Caller validated `real` positive
    /// and finite.
    fn decompose(real: f64) -> (i64, i32) {
        let mut exp = 0i32;
        let mut frac = real;
        while frac >= 1.0 {
            frac *= 0.5;
            exp += 1;
        }
        while frac < 0.5 {
            frac *= 2.0;
            exp -= 1;
        }
        let mut m0 = (frac * (1i64 << 31) as f64).round() as i64;
        if m0 == 1i64 << 31 {
            m0 /= 2;
            exp += 1;
        }
        (m0, -exp)
    }

    /// Decompose a positive real multiplier into `(m0, shift)`, or
    /// `None` when the ratio cannot be represented at full q31
    /// precision (`31 + shift` outside `1..=62`, i.e. M outside
    /// roughly `[2^-31, 2^30]`). Degenerately-calibrated scale ratios
    /// land here; callers (the int8-dataflow pass) refuse the link and
    /// keep the edge f32 instead of shipping a corrupted multiplier.
    pub fn try_from_real(real: f64) -> Option<Requant> {
        if !(real.is_finite() && real > 0.0) {
            return None;
        }
        let (m0, shift) = Requant::decompose(real);
        if !(1..=62).contains(&(31 + shift)) {
            return None;
        }
        Some(Requant { m0: m0 as i32, shift })
    }

    /// Like [`Requant::try_from_real`], but clamps underflowing
    /// multipliers toward zero by halving the mantissa (the result
    /// rounds to 0 for any i32 accumulator) and panics on multipliers
    /// ≥ ~2^30. Convenience for tests/tools; production requant
    /// installation goes through the refusing [`Requant::try_from_real`].
    pub fn from_real(real: f64) -> Requant {
        assert!(real.is_finite() && real > 0.0, "requant multiplier must be positive, got {real}");
        if let Some(rq) = Requant::try_from_real(real) {
            return rq;
        }
        let (mut m0, mut shift) = Requant::decompose(real);
        while 31 + shift > 62 {
            m0 = (m0 + 1) / 2;
            shift -= 1;
        }
        assert!(31 + shift >= 1, "requant multiplier {real} too large");
        Requant { m0: m0 as i32, shift }
    }

    /// The real multiplier this fixed-point pair encodes.
    pub fn real(self) -> f64 {
        self.m0 as f64 * (2f64).powi(-(31 + self.shift))
    }

    /// Apply to an i32 accumulator: `round(acc · m0 · 2^-(31+shift))`,
    /// half away from zero, exactly — delegates to the shared scalar
    /// primitive the SIMD arm is tested bit-identical against.
    #[inline]
    pub fn apply(self, acc: i32) -> i32 {
        crate::linalg::simd::requant_one(acc, self.m0, self.shift)
    }
}

/// Process-wide count of f32 activation materializations performed by
/// quantized conv layers (a quantized conv writing a float output
/// tensor). The compiled int8 dataflow exists to drive this to the
/// graph's exits only: between consecutive quantized convs the count
/// must not grow — asserted by the graph-compiler tests.
static DEQUANT_MATERIALIZATIONS: AtomicU64 = AtomicU64::new(0);

/// Snapshot of the f32-materialization counter (bumped by the
/// [`QConvLayer`] float output stages).
pub fn dequant_materializations() -> u64 {
    DEQUANT_MATERIALIZATIONS.load(Ordering::Relaxed)
}

/// Record one quantized-conv f32 output materialization (called by the
/// [`QConvLayer`] float output stages).
pub(crate) fn record_dequant_materialization() {
    DEQUANT_MATERIALIZATIONS.fetch_add(1, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn int8_round_trip_error() {
        let q = QParams::from_max_abs(2.0, 8);
        assert_eq!(q.qmax, 127);
        for i in 0..100 {
            let v = -2.0 + 4.0 * i as f32 / 99.0;
            let e = (q.fake_quant(v) - v).abs();
            assert!(e <= q.scale * 0.5 + 1e-6, "v={v} err={e}");
        }
    }

    #[test]
    fn clamping() {
        let q = QParams::from_max_abs(1.0, 4);
        assert_eq!(q.qmax, 7);
        assert_eq!(q.quantize(10.0), 7);
        assert_eq!(q.quantize(-10.0), -7);
    }

    #[test]
    fn zero_range_safe() {
        let q = QParams::from_max_abs(0.0, 8);
        assert_eq!(q.quantize(0.0), 0);
        assert_eq!(q.fake_quant(0.0), 0.0);
    }

    #[test]
    fn lower_bits_coarser() {
        let v = 0.73f32;
        let e8 = (QParams::from_max_abs(1.0, 8).fake_quant(v) - v).abs();
        let e4 = (QParams::from_max_abs(1.0, 4).fake_quant(v) - v).abs();
        assert!(e4 > e8);
    }

    #[test]
    fn requant_decomposition_is_tight() {
        for real in [0.5f64, 0.9999, 1.0, 1.5, 0.003, 7.25e-5, 3.2] {
            let rq = Requant::from_real(real);
            assert!(
                (rq.m0 as i64) < (1i64 << 31) && rq.m0 > 0,
                "m0 {} out of range for {real}",
                rq.m0
            );
            let rel = (rq.real() - real).abs() / real;
            assert!(rel < 1e-9, "{real}: encoded {} (rel {rel})", rq.real());
            assert!((1..=62).contains(&(31 + rq.shift)), "{real}: shift {}", rq.shift);
        }
    }

    #[test]
    fn requant_apply_matches_float_reference_within_one() {
        // the ≤1-code contract of the fixed-point rounding: for every
        // accumulator, |apply(acc) − round(acc·M)| ≤ 1
        for real in [0.37e-3f64, 0.021, 0.49, 1.0 / 3.0] {
            let rq = Requant::from_real(real);
            for acc in (-200_000i32..200_000).step_by(9973) {
                let want = (acc as f64 * real).round();
                let got = rq.apply(acc) as f64;
                assert!(
                    (got - want).abs() <= 1.0,
                    "M {real} acc {acc}: fixed {got} vs float {want}"
                );
            }
        }
    }

    #[test]
    fn requant_underflow_clamps_to_zero() {
        let rq = Requant::from_real(1e-15);
        assert_eq!(rq.apply(i32::MAX), 0);
        assert_eq!(rq.apply(i32::MIN + 1), 0);
    }

    #[test]
    fn try_from_real_refuses_unencodable_ratios() {
        // the strict constructor (the int8-dataflow pass's gate): None
        // outside the faithful q31 range, Some inside it
        assert!(Requant::try_from_real(1e-15).is_none(), "underflow must be refused");
        assert!(Requant::try_from_real(1e12).is_none(), "overflow must be refused");
        assert!(Requant::try_from_real(0.0).is_none());
        assert!(Requant::try_from_real(f64::NAN).is_none());
        for ok in [1e-6, 0.5, 1.0, 1000.0] {
            let rq = Requant::try_from_real(ok).expect("encodable");
            assert!((rq.real() - ok).abs() / ok < 1e-9);
        }
    }

    #[test]
    fn qtensor_round_trip() {
        let q = QTensor { dims: vec![1, 1, 1, 3], data: vec![-2, 0, 5], scale: 0.5 };
        let t = q.dequantize();
        assert_eq!(t.data, vec![-1.0, 0.0, 2.5]);
        assert_eq!(q.plane(0, 0), &[-2, 0, 5]);
    }
}
