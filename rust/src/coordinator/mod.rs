//! L3 serving coordinator: multi-model scheduler + dynamic batcher +
//! per-model workers, with latency/throughput/shed metrics.
//!
//! Architecture (vLLM-router-like, scaled to this paper's inference-kernel
//! scope): clients submit single-image classification requests to a named
//! resident model on the [`sched::MultiServer`]; a continuous batcher
//! per model forms batches by per-request *deadline* (not fixed size),
//! sheds lowest-priority work first under overload (typed
//! [`sched::Response::Shed`] outcomes), and executes on the engine stack
//! or the AOT-compiled PJRT model; responses flow back through
//! per-request channels. The original single-model [`Server`] API is a
//! shim over one resident model. Everything is std-only (tokio is not
//! vendored in this image).

pub mod batcher;
pub mod metrics;
pub mod sched;

pub use batcher::{ModelRunner, Server, ServerConfig};
pub use metrics::LatencyStats;
pub use sched::{
    DispatchMode, ModelSnapshot, MultiServer, Priority, SchedConfig, ServerStopped, SubmitOpts,
    Ticket,
};

use crate::runtime::Executor;
use anyhow::Result;
use std::collections::HashMap;

/// Parse an optional `--key value` CLI flag with a contextful error:
/// `sfc serve --requests=abc` reports the bad flag instead of panicking.
pub fn parse_opt<T: std::str::FromStr>(
    opts: &HashMap<String, String>,
    key: &str,
    default: T,
) -> Result<T>
where
    T::Err: std::fmt::Display,
{
    match opts.get(key) {
        None => Ok(default),
        Some(v) => v
            .parse::<T>()
            .map_err(|e| anyhow::anyhow!("invalid --{key} value '{v}': {e}")),
    }
}

/// Parse one `--model` spec `name[:intN]` → (model name, quant bits).
fn parse_model_spec(spec: &str) -> Result<(&str, u32)> {
    match spec.split_once(':') {
        None => Ok((spec, 0)),
        Some((name, q)) => {
            let bits: u32 = q
                .strip_prefix("int")
                .and_then(|b| b.parse().ok())
                .ok_or_else(|| anyhow::anyhow!("bad model spec '{spec}' (expected name[:intN])"))?;
            anyhow::ensure!((2..=16).contains(&bits), "bad quant bits in model spec '{spec}'");
            Ok((name, bits))
        }
    }
}

/// Load `--tuning <file>` (if given) and install it process-wide, so
/// every selector pins tuned descriptors to their measured winner
/// instead of re-running heuristics/micro-benchmarks.
fn install_tuning(opts: &HashMap<String, String>) -> Result<()> {
    if let Some(path) = opts.get("tuning") {
        let table = crate::engine::TuningTable::load(std::path::Path::new(path))?;
        println!("tuning: {} descriptors pinned from {path}", table.len());
        crate::engine::tuning::install_global(table)?;
    }
    Ok(())
}

/// Parse `--sched worker|global` (default worker) into the batch
/// dispatch planner the [`MultiServer`] runs under.
fn parse_sched(opts: &HashMap<String, String>) -> Result<DispatchMode> {
    match opts.get("sched") {
        None => Ok(DispatchMode::Worker),
        Some(v) => DispatchMode::parse(v),
    }
}

/// Apply `--cores <N>` (if given): cap the process-wide
/// [`crate::util::par::CoreBudget`] so model workers × intra-op GEMM
/// threads never run more than N concurrent compute lanes. Without the
/// flag the budget follows `SFC_THREADS`/detected parallelism.
fn apply_cores(opts: &HashMap<String, String>) -> Result<()> {
    let cores: usize = parse_opt(opts, "cores", 0)?;
    if cores > 0 {
        crate::util::par::CoreBudget::set_total(Some(cores));
        println!("core budget: capped at {cores} lanes (--cores)");
    }
    Ok(())
}

/// One `key : total/leased/peak` core-budget report line.
fn core_budget_line() -> String {
    let (total, leased, peak) = metrics::core_budget();
    format!("{total} lanes · {leased} leased now · peak {peak} concurrent")
}

/// One executor-pool report line: resident workers plus the lifetime
/// task/steal/spawn-avoided/park counters ([`metrics::pool_gauges`]).
fn pool_line() -> String {
    let g = metrics::pool_gauges();
    format!(
        "{} workers · {} tasks · {} steals · {} spawns avoided · {} parks / {} unparks",
        g.workers, g.tasks, g.steals, g.spawn_avoided, g.parks, g.unparks
    )
}

/// `sfc serve` — the end-to-end demo: load a model (PJRT AOT artifact,
/// or the pure-Rust engine stack with `--runner engine`), serve a stream
/// of requests from the SynthImage test split, report accuracy, latency
/// percentiles, throughput and workspace stats (EXPERIMENTS.md §E2E).
/// `--runner engine --quant 8` serves the compiled int8 model: PTQ over
/// the calibration split (spatial direct scheme on every conv), then
/// the graph compiler fuses epilogues and installs the int8 dataflow —
/// still under the zero-steady-state-alloc workspace guarantee.
/// `--tuning tuning.json` warms engine selection from a committed
/// autotune table. `--model a,b:int8` (comma-separated or repeated
/// `--model` flags, engine runner) serves several resident models from
/// the shared plan cache through the [`sched::MultiServer`], round-robin
/// across the request stream.
pub fn cmd_serve(opts: &HashMap<String, String>) -> Result<()> {
    let data_dir = opts.get("data-dir").map(|s| s.as_str()).unwrap_or("artifacts");
    let default_hlo = format!("{data_dir}/resnet18_b8.hlo.txt");
    let hlo = opts.get("hlo").map(|s| s.as_str()).unwrap_or(&default_hlo);
    let requests: usize = parse_opt(opts, "requests", 256)?;
    let batch: usize = parse_opt(opts, "batch", 8)?;
    let quant_bits: u32 = parse_opt(opts, "quant", 0)?;
    let runner = opts.get("runner").map(|s| s.as_str()).unwrap_or("pjrt");
    anyhow::ensure!(
        quant_bits == 0 || runner == "engine",
        "--quant requires --runner engine (the PJRT artifact is fixed-precision)"
    );
    install_tuning(opts)?;
    apply_cores(opts)?;
    if let Some(models) = opts.get("model") {
        if models.contains(',') {
            anyhow::ensure!(
                runner == "engine",
                "multi-model serving requires --runner engine (one PJRT artifact is one model)"
            );
            return serve_multi(opts, data_dir, models, requests, batch);
        }
    }

    let (images, labels) = crate::exp::load_split(data_dir, "test", requests)?;
    let cfg = ServerConfig { batch_size: batch, queue_depth: 64, batch_timeout_ms: 2 };
    let dims = vec![batch, 3, 32, 32];
    let server = match runner {
        "pjrt" => {
            println!("loading {hlo} (batch {batch}) ...");
            let hlo_path = std::path::PathBuf::from(hlo);
            Server::start(move || Executor::load(&hlo_path, &dims, 10), cfg)?
        }
        "engine" => {
            let model_name =
                opts.get("model").map(|s| s.as_str()).unwrap_or("resnet18").to_string();
            let scheme = if quant_bits > 0 { format!("int{quant_bits}") } else { "f32".into() };
            println!("loading {model_name} weights from {data_dir} (batch {batch}, {scheme}) ...");
            let data_dir = data_dir.to_string();
            Server::start(
                move || {
                    let mut m = crate::exp::load_model(&data_dir, &model_name)?;
                    if quant_bits > 0 {
                        let (calib, _) =
                            crate::exp::load_split(&data_dir, "train", crate::exp::calib_n())?;
                        let cfg = crate::quant::QuantConfig::direct_default(quant_bits);
                        let done = crate::quant::quantize_model(&mut m, &calib, &cfg);
                        println!("quantized {} conv layers (spatial int{quant_bits})", done.len());
                    }
                    // from_model compiles the graph (epilogue fusion +
                    // int8 dataflow) and pre-packs float weights
                    Ok(crate::runtime::EngineExecutor::from_model(m, dims, 10))
                },
                cfg,
            )?
        }
        other => anyhow::bail!("unknown --runner '{other}' (expected pjrt|engine)"),
    };

    let t0 = std::time::Instant::now();
    let sample = images.dims[1] * images.dims[2] * images.dims[3];
    let mut handles = Vec::new();
    for i in 0..requests {
        let img = images.data[i * sample..(i + 1) * sample].to_vec();
        handles.push(server.submit(img)?);
    }
    let mut correct = 0usize;
    let mut latencies = Vec::with_capacity(requests);
    for (i, h) in handles.into_iter().enumerate() {
        let resp = h.wait()?;
        latencies.push(resp.latency_s);
        if resp.argmax == labels[i] as usize {
            correct += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = LatencyStats::from_samples(&latencies);
    println!("\nE2E serving results ({requests} requests, batch {batch}):");
    println!("  accuracy   : {:.2}%", 100.0 * correct as f64 / requests as f64);
    println!("  throughput : {:.1} img/s", requests as f64 / wall);
    println!(
        "  latency    : p50 {:.2} ms · p95 {:.2} ms · p99 {:.2} ms · max {:.2} ms",
        stats.p50 * 1e3,
        stats.p95 * 1e3,
        stats.p99 * 1e3,
        stats.max * 1e3
    );
    println!("  batches    : {}", server.batches_executed());
    println!("  kernel     : {}", metrics::kernel_name());
    println!("  core budget: {}", core_budget_line());
    println!("  pool       : {}", pool_line());
    let (hits, misses) = metrics::plan_cache_counters();
    println!("  plan cache : {hits} hits / {misses} misses");
    println!(
        "  workspace  : peak {:.1} KB · {} heap fallbacks (0 after warm-up = zero-alloc)",
        server.ws_peak_bytes() as f64 / 1024.0,
        server.ws_heap_allocs()
    );
    println!(
        "  packed wts : {:.1} KB pre-packed weight panels (plan-time, live)",
        metrics::packed_weight_bytes() as f64 / 1024.0
    );
    server.shutdown();
    Ok(())
}

/// Split a comma-separated model list into trimmed, non-empty specs.
fn split_specs(csv: &str) -> Vec<String> {
    csv.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect()
}

/// The multi-model arm of `sfc serve`: several engine-backed models
/// resident on one [`MultiServer`], sharing the plan cache and the
/// packed-weight budget, round-robin over the test-split request stream.
fn serve_multi(
    opts: &HashMap<String, String>,
    data_dir: &str,
    specs_csv: &str,
    requests: usize,
    batch: usize,
) -> Result<()> {
    let queue_depth: usize = parse_opt(opts, "queue-depth", 64)?;
    let budget_mb: u64 = parse_opt(opts, "budget-mb", 0)?;
    let linger_ms: u64 = parse_opt(opts, "linger-ms", 2)?;
    let dispatch = parse_sched(opts)?;
    let specs = split_specs(specs_csv);
    let server = MultiServer::new(SchedConfig {
        queue_depth,
        default_deadline_ms: 60_000,
        linger_ms,
        packed_budget_bytes: budget_mb * 1024 * 1024,
        dispatch,
    });
    let budget = crate::engine::PackBudget::new((budget_mb * 1024 * 1024) as usize);
    let dims = vec![batch, 3, 32, 32];
    for spec in &specs {
        let (name, bits) = parse_model_spec(spec)?;
        let name = name.to_string();
        let dir = data_dir.to_string();
        let dims2 = dims.clone();
        let spec2 = spec.clone();
        let platform = server.add_model(spec, move || {
            let mut m = crate::exp::load_model(&dir, &name)?;
            if bits > 0 {
                let (calib, _) = crate::exp::load_split(&dir, "train", crate::exp::calib_n())?;
                let qcfg = crate::quant::QuantConfig::direct_default(bits);
                let done = crate::quant::quantize_model(&mut m, &calib, &qcfg);
                println!("{spec2}: quantized {} conv layers (spatial int{bits})", done.len());
            }
            let (exe, rep) =
                crate::runtime::EngineExecutor::from_model_budgeted(m, dims2, 10, &budget);
            println!(
                "{spec2}: pre-packed {} layers ({} skipped by budget, {:.1} KB)",
                rep.packed_layers,
                rep.skipped_layers,
                rep.added_bytes as f64 / 1024.0
            );
            Ok(exe)
        })?;
        println!("model '{spec}' ready on platform: {platform}");
    }
    let (images, labels) = crate::exp::load_split(data_dir, "test", requests)?;
    let sample = images.dims[1] * images.dims[2] * images.dims[3];
    let t0 = std::time::Instant::now();
    let mut handles = Vec::with_capacity(requests);
    for i in 0..requests {
        let img = images.data[i * sample..(i + 1) * sample].to_vec();
        let spec = &specs[i % specs.len()];
        handles.push((i, server.submit_blocking(spec, img)?));
    }
    let mut correct = vec![0usize; specs.len()];
    let mut served = vec![0usize; specs.len()];
    for (i, h) in handles {
        if let sched::Response::Done(c) = h.wait()? {
            let mi = i % specs.len();
            served[mi] += 1;
            correct[mi] += (c.argmax == labels[i] as usize) as usize;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "\nE2E multi-model serving ({requests} requests, batch {batch}, {} models, {:.1} img/s):",
        specs.len(),
        requests as f64 / wall
    );
    for (mi, spec) in specs.iter().enumerate() {
        let s = server.snapshot(spec).expect("registered model");
        println!(
            "  {spec}: accuracy {:.2}% ({}/{}) · p50 {:.2} ms · p99 {:.2} ms · batches {} · \
             shed {} · ws heap fallbacks {}",
            100.0 * correct[mi] as f64 / served[mi].max(1) as f64,
            correct[mi],
            served[mi],
            s.latency.p50() * 1e3,
            s.latency.p99() * 1e3,
            s.batches,
            s.shed,
            s.ws_heap_allocs
        );
    }
    let (hits, misses) = metrics::plan_cache_counters();
    println!("  plan cache : {hits} hits / {misses} misses (shared across models)");
    println!(
        "  packed wts : {:.1} KB live (budget {})",
        metrics::packed_weight_bytes() as f64 / 1024.0,
        if budget_mb > 0 { format!("{budget_mb} MB") } else { "unlimited".into() }
    );
    println!("  kernel     : {}", metrics::kernel_name());
    println!("  core budget: {}", core_budget_line());
    println!("  pool       : {}", pool_line());
    server.shutdown();
    Ok(())
}

/// `sfc loadgen` — drive a freshly built multi-model server (random
/// weights, no artifacts needed) at a controlled QPS with a mixed
/// model/priority/deadline scenario, and print the goodput/latency/shed
/// report ([`crate::exp::loadgen`]). The measurement harness for the
/// scheduler: overload it (`--qps` beyond capacity) and the report
/// shows load shedding doing its job — low-priority sheds, high-priority
/// goodput, flat workspace allocations, clean drain.
pub fn cmd_loadgen(opts: &HashMap<String, String>) -> Result<()> {
    let models_csv =
        opts.get("models").cloned().unwrap_or_else(|| "resnet18,mobilenet:int8".into());
    let qps: f64 = parse_opt(opts, "qps", 400.0)?;
    let duration_s: f64 = parse_opt(opts, "duration-s", 2.0)?;
    let deadline_ms: u64 = parse_opt(opts, "deadline-ms", 25)?;
    let low_ratio: f64 = parse_opt(opts, "low-ratio", 0.6)?;
    let batch: usize = parse_opt(opts, "batch", 8)?;
    let queue_depth: usize = parse_opt(opts, "queue-depth", 32)?;
    let budget_mb: u64 = parse_opt(opts, "budget-mb", 64)?;
    let linger_ms: u64 = parse_opt(opts, "linger-ms", 2)?;
    let seed: u64 = parse_opt(opts, "seed", 7)?;
    let dispatch = parse_sched(opts)?;
    let json = opts.contains_key("json") || opts.contains_key("out");
    install_tuning(opts)?;
    apply_cores(opts)?;
    let server = MultiServer::new(SchedConfig {
        queue_depth,
        default_deadline_ms: deadline_ms,
        linger_ms,
        packed_budget_bytes: budget_mb * 1024 * 1024,
        dispatch,
    });
    let budget = crate::engine::PackBudget::new((budget_mb * 1024 * 1024) as usize);
    let dims = vec![batch, 3, 32, 32];
    let specs = split_specs(&models_csv);
    anyhow::ensure!(!specs.is_empty(), "--models needs at least one model spec");
    for spec in &specs {
        let (name, bits) = parse_model_spec(spec)?;
        let mut m = match name {
            "resnet18" => crate::nn::model::resnet_random(&crate::nn::model::resnet18_cfg(), 1, 10),
            "resnet34" => crate::nn::model::resnet_random(&crate::nn::model::resnet34_cfg(), 1, 10),
            "resnet50" => crate::nn::model::resnet_random(&crate::nn::model::resnet50_cfg(), 1, 10),
            "mobilenet" => {
                crate::nn::model::mobilenet_random(&crate::nn::model::mobilenet_cfg(), 1, 10)
            }
            other => anyhow::bail!(
                "unknown model '{other}' for loadgen (expected resnet18|resnet34|resnet50|mobilenet)"
            ),
        };
        if bits > 0 {
            let mut calib = crate::nn::Tensor::zeros(&[4, 3, 32, 32]);
            crate::util::Pcg32::seeded(seed).fill_gaussian(&mut calib.data, 1.0);
            let qcfg = crate::quant::QuantConfig::direct_default(bits);
            let done = crate::quant::quantize_model(&mut m, &calib, &qcfg);
            println!("{spec}: quantized {} conv layers (spatial int{bits})", done.len());
        }
        let dims2 = dims.clone();
        let spec2 = spec.clone();
        let platform = server.add_model(spec, move || {
            let (exe, rep) =
                crate::runtime::EngineExecutor::from_model_budgeted(m, dims2, 10, &budget);
            println!(
                "{spec2}: pre-packed {} layers ({} skipped by budget, {:.1} KB)",
                rep.packed_layers,
                rep.skipped_layers,
                rep.added_bytes as f64 / 1024.0
            );
            Ok(exe)
        })?;
        println!("model '{spec}' ready on platform: {platform}");
    }
    let cfg = crate::exp::loadgen::LoadgenCfg { qps, duration_s, deadline_ms, low_ratio, seed };
    let names = server.models();
    println!(
        "loadgen: {} models · {qps} qps offered · {duration_s} s · deadlines {deadline_ms}/{} ms \
         (low/high) · {:.0}% low priority · sched={}",
        names.len(),
        deadline_ms * 4,
        low_ratio * 100.0,
        dispatch.name()
    );
    let reports = crate::exp::loadgen::run(&server, &names, &cfg)?;
    crate::exp::loadgen::print_report(&reports);
    if json {
        let doc = crate::exp::loadgen::report_json(&reports, &server, &cfg);
        match opts.get("out").filter(|v| v.as_str() != "true") {
            Some(path) => {
                std::fs::write(path, &doc)?;
                println!("loadgen: wrote {path}");
            }
            None => print!("{doc}"),
        }
    }
    let (hits, misses) = metrics::plan_cache_counters();
    println!(
        "loadgen: plan_cache_hits={hits} plan_cache_misses={misses} packed_kb={:.1} \
         budget_mb={budget_mb} kernel={}",
        metrics::packed_weight_bytes() as f64 / 1024.0,
        metrics::kernel_name()
    );
    println!("loadgen: core budget {}", core_budget_line());
    println!("loadgen: pool {}", pool_line());
    server.shutdown();
    Ok(())
}
