//! L3 serving coordinator: request router + dynamic batcher + worker over
//! the PJRT executor, with latency/throughput metrics.
//!
//! Architecture (vLLM-router-like, scaled to this paper's inference-kernel
//! scope): clients submit single-image classification requests to a
//! bounded queue (backpressure); a batcher thread drains the queue into
//! fixed-size batches — padding the tail batch — and executes them on the
//! AOT-compiled model; responses flow back through per-request channels.
//! Everything is std-only (tokio is not vendored in this image).

pub mod batcher;
pub mod metrics;

pub use batcher::{Server, ServerConfig};
pub use metrics::LatencyStats;

use crate::runtime::Executor;
use anyhow::Result;
use std::collections::HashMap;

/// Parse an optional `--key value` CLI flag with a contextful error:
/// `sfc serve --requests=abc` reports the bad flag instead of panicking.
pub fn parse_opt<T: std::str::FromStr>(
    opts: &HashMap<String, String>,
    key: &str,
    default: T,
) -> Result<T>
where
    T::Err: std::fmt::Display,
{
    match opts.get(key) {
        None => Ok(default),
        Some(v) => v
            .parse::<T>()
            .map_err(|e| anyhow::anyhow!("invalid --{key} value '{v}': {e}")),
    }
}

/// `sfc serve` — the end-to-end demo: load a model (PJRT AOT artifact,
/// or the pure-Rust engine stack with `--runner engine`), serve a stream
/// of requests from the SynthImage test split, report accuracy, latency
/// percentiles, throughput and workspace stats (EXPERIMENTS.md §E2E).
/// `--runner engine --quant 8` serves the compiled int8 model: PTQ over
/// the calibration split (spatial direct scheme on every conv), then
/// the graph compiler fuses epilogues and installs the int8 dataflow —
/// still under the zero-steady-state-alloc workspace guarantee.
pub fn cmd_serve(opts: &HashMap<String, String>) -> Result<()> {
    let data_dir = opts.get("data-dir").map(|s| s.as_str()).unwrap_or("artifacts");
    let default_hlo = format!("{data_dir}/resnet18_b8.hlo.txt");
    let hlo = opts.get("hlo").map(|s| s.as_str()).unwrap_or(&default_hlo);
    let requests: usize = parse_opt(opts, "requests", 256)?;
    let batch: usize = parse_opt(opts, "batch", 8)?;
    let quant_bits: u32 = parse_opt(opts, "quant", 0)?;
    let runner = opts.get("runner").map(|s| s.as_str()).unwrap_or("pjrt");
    anyhow::ensure!(
        quant_bits == 0 || runner == "engine",
        "--quant requires --runner engine (the PJRT artifact is fixed-precision)"
    );

    let (images, labels) = crate::exp::load_split(data_dir, "test", requests)?;
    let cfg = ServerConfig { batch_size: batch, queue_depth: 64, batch_timeout_ms: 2 };
    let dims = vec![batch, 3, 32, 32];
    let server = match runner {
        "pjrt" => {
            println!("loading {hlo} (batch {batch}) ...");
            let hlo_path = std::path::PathBuf::from(hlo);
            Server::start(move || Executor::load(&hlo_path, &dims, 10), cfg)?
        }
        "engine" => {
            let model_name =
                opts.get("model").map(|s| s.as_str()).unwrap_or("resnet18").to_string();
            let scheme = if quant_bits > 0 { format!("int{quant_bits}") } else { "f32".into() };
            println!("loading {model_name} weights from {data_dir} (batch {batch}, {scheme}) ...");
            let data_dir = data_dir.to_string();
            Server::start(
                move || {
                    let mut m = crate::exp::load_model(&data_dir, &model_name)?;
                    if quant_bits > 0 {
                        let (calib, _) =
                            crate::exp::load_split(&data_dir, "train", crate::exp::calib_n())?;
                        let cfg = crate::quant::QuantConfig::direct_default(quant_bits);
                        let done = crate::quant::quantize_model(&mut m, &calib, &cfg);
                        println!("quantized {} conv layers (spatial int{quant_bits})", done.len());
                    }
                    // from_model compiles the graph (epilogue fusion +
                    // int8 dataflow) and pre-packs float weights
                    Ok(crate::runtime::EngineExecutor::from_model(m, dims, 10))
                },
                cfg,
            )?
        }
        other => anyhow::bail!("unknown --runner '{other}' (expected pjrt|engine)"),
    };

    let t0 = std::time::Instant::now();
    let sample = images.dims[1] * images.dims[2] * images.dims[3];
    let mut handles = Vec::new();
    for i in 0..requests {
        let img = images.data[i * sample..(i + 1) * sample].to_vec();
        handles.push(server.submit(img)?);
    }
    let mut correct = 0usize;
    let mut latencies = Vec::with_capacity(requests);
    for (i, h) in handles.into_iter().enumerate() {
        let resp = h.wait()?;
        latencies.push(resp.latency_s);
        if resp.argmax == labels[i] as usize {
            correct += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats = LatencyStats::from_samples(&latencies);
    println!("\nE2E serving results ({requests} requests, batch {batch}):");
    println!("  accuracy   : {:.2}%", 100.0 * correct as f64 / requests as f64);
    println!("  throughput : {:.1} img/s", requests as f64 / wall);
    println!(
        "  latency    : p50 {:.2} ms · p95 {:.2} ms · p99 {:.2} ms · max {:.2} ms",
        stats.p50 * 1e3,
        stats.p95 * 1e3,
        stats.p99 * 1e3,
        stats.max * 1e3
    );
    println!("  batches    : {}", server.batches_executed());
    println!("  kernel     : {}", metrics::kernel_name());
    let (hits, misses) = metrics::plan_cache_counters();
    println!("  plan cache : {hits} hits / {misses} misses");
    println!(
        "  workspace  : peak {:.1} KB · {} heap fallbacks (0 after warm-up = zero-alloc)",
        server.ws_peak_bytes() as f64 / 1024.0,
        server.ws_heap_allocs()
    );
    println!(
        "  packed wts : {:.1} KB pre-packed weight panels (plan-time, live)",
        metrics::packed_weight_bytes() as f64 / 1024.0
    );
    server.shutdown();
    Ok(())
}
