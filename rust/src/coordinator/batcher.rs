//! Single-model dynamic batcher — now a thin shim over the multi-model
//! scheduler in [`super::sched`].
//!
//! The original `Server` API (blocking bounded-queue submit, fixed batch
//! size with a straggler timeout, padded tail batches, per-worker
//! workspace) is preserved exactly for existing callers and tests, but
//! the batching/queueing machinery lives in [`super::sched::MultiServer`]
//! with this type registering one model named `"default"`. Gained along
//! the way: graceful shutdown now *drains* queued requests (executes
//! them and completes their waiters) and fails anything the worker never
//! reached with the typed [`super::sched::ServerStopped`] error instead
//! of leaving callers blocked.

use super::sched::{self, MultiServer, SchedConfig};
use crate::engine::Workspace;
use crate::runtime::{EngineExecutor, Executor};
use anyhow::Result;

/// What the batcher needs from a model backend. `Executor` (PJRT) and
/// the workspace-backed [`EngineExecutor`] are the production impls;
/// tests inject mocks.
pub trait ModelRunner {
    /// flattened NCHW input dims (index 0 = batch)
    fn input_dims(&self) -> &[usize];
    /// number of classes in each logits row
    fn out_classes(&self) -> usize;
    /// run one padded batch, returning `[batch, classes]` logits
    fn run(&self, batch: &[f32]) -> Result<Vec<f32>>;
    /// Workspace-aware entry point: the batcher worker owns one
    /// [`Workspace`] for its lifetime and passes it to every batch, so
    /// workspace-backed runners serve steady-state traffic without heap
    /// allocation. Backends that manage their own memory (PJRT) ignore
    /// the workspace.
    fn run_with(&self, batch: &[f32], _ws: &mut Workspace) -> Result<Vec<f32>> {
        self.run(batch)
    }
    /// Allocation-free variant of [`ModelRunner::run_with`]: logits are
    /// written into the caller's staging buffer (cleared, then extended
    /// to `[batch, classes]`). The scheduler's batch loops hoist one
    /// buffer per execution slot and reuse it across batches, so the
    /// steady-state-alloc counters stay flat. The default impl routes
    /// through [`ModelRunner::run_with`] (one allocation per batch);
    /// workspace-backed runners override it.
    fn run_with_into(&self, batch: &[f32], ws: &mut Workspace, out: &mut Vec<f32>) -> Result<()> {
        let logits = self.run_with(batch, ws)?;
        out.clear();
        out.extend_from_slice(&logits);
        Ok(())
    }
    /// backend platform name for the startup banner
    fn platform(&self) -> String {
        "mock".into()
    }
}

impl ModelRunner for Executor {
    fn input_dims(&self) -> &[usize] {
        &self.input_dims
    }
    fn out_classes(&self) -> usize {
        self.out_classes
    }
    fn run(&self, batch: &[f32]) -> Result<Vec<f32>> {
        Executor::run(self, batch)
    }
    fn platform(&self) -> String {
        Executor::platform(self)
    }
}

impl ModelRunner for EngineExecutor {
    fn input_dims(&self) -> &[usize] {
        &self.input_dims
    }
    fn out_classes(&self) -> usize {
        self.out_classes
    }
    fn run(&self, batch: &[f32]) -> Result<Vec<f32>> {
        EngineExecutor::run(self, batch)
    }
    fn run_with(&self, batch: &[f32], ws: &mut Workspace) -> Result<Vec<f32>> {
        EngineExecutor::run_with(self, batch, ws)
    }
    fn run_with_into(&self, batch: &[f32], ws: &mut Workspace, out: &mut Vec<f32>) -> Result<()> {
        EngineExecutor::run_with_into(self, batch, ws, out)
    }
    fn platform(&self) -> String {
        EngineExecutor::platform(self)
    }
}

/// Batcher sizing/timing knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// fixed execution batch size (tail batches are zero-padded)
    pub batch_size: usize,
    /// bounded request-queue depth (backpressure)
    pub queue_depth: usize,
    /// max wait for stragglers before executing a partial batch
    pub batch_timeout_ms: u64,
}

/// One request's completed result.
pub struct Response {
    /// the request's logits row
    pub logits: Vec<f32>,
    /// index of the winning class
    pub argmax: usize,
    /// enqueue-to-completion latency in seconds
    pub latency_s: f64,
}

/// Handle for one in-flight request.
pub struct Pending {
    ticket: sched::Ticket,
}

impl Pending {
    /// Block until the batcher completes this request.
    pub fn wait(self) -> Result<Response> {
        match self.ticket.wait()? {
            sched::Response::Done(c) => {
                Ok(Response { logits: c.logits, argmax: c.argmax, latency_s: c.latency_s })
            }
            // unreachable through this shim: blocking submit never sheds
            // and the effectively-infinite deadline never expires
            sched::Response::Shed(s) => {
                anyhow::bail!("request unexpectedly shed ({})", s.reason.name())
            }
        }
    }
}

/// the single resident model registered by the shim
const SHIM_MODEL: &str = "default";

/// Handle to a running batcher: submit requests, read worker stats,
/// shut down. One-model shim over [`MultiServer`].
pub struct Server {
    inner: MultiServer,
}

impl Server {
    /// Start the batcher. The PJRT client/executable are not `Send`
    /// (Rc-based FFI wrappers), so the executor is constructed *inside*
    /// the worker thread from the provided factory; startup errors are
    /// reported back synchronously.
    pub fn start<R, F>(factory: F, cfg: ServerConfig) -> Result<Server>
    where
        R: ModelRunner,
        F: FnOnce() -> Result<R> + Send + 'static,
    {
        let inner = MultiServer::new(SchedConfig {
            queue_depth: cfg.queue_depth,
            // legacy requests carry no deadline: make it unreachable so
            // deadline shedding can never fire through this API
            default_deadline_ms: 3_600_000,
            linger_ms: cfg.batch_timeout_ms,
            packed_budget_bytes: 0,
            dispatch: sched::DispatchMode::Worker,
        });
        let platform = inner.add_model(SHIM_MODEL, factory)?;
        println!("server ready on platform: {platform}");
        Ok(Server { inner })
    }

    /// Submit one image (CHW flattened); returns a wait handle.
    /// Blocks when the queue is full (backpressure).
    pub fn submit(&self, image: Vec<f32>) -> Result<Pending> {
        let ticket = self.inner.submit_blocking(SHIM_MODEL, image)?;
        Ok(Pending { ticket })
    }

    /// Number of batches the worker has executed so far.
    pub fn batches_executed(&self) -> u64 {
        self.inner.snapshot(SHIM_MODEL).map(|s| s.batches).unwrap_or(0)
    }

    /// Peak bytes checked out of the worker's workspace so far.
    pub fn ws_peak_bytes(&self) -> u64 {
        self.inner.snapshot(SHIM_MODEL).map(|s| s.ws_peak_bytes).unwrap_or(0)
    }

    /// Workspace checkouts that fell back to a heap allocation. After
    /// the warm-up batch this must stop growing — the steady-state
    /// zero-alloc property asserted by the runtime e2e test.
    pub fn ws_heap_allocs(&self) -> u64 {
        self.inner.snapshot(SHIM_MODEL).map(|s| s.ws_heap_allocs).unwrap_or(0)
    }

    /// Stop the worker: queued requests are drained (executed, waiters
    /// completed), stragglers fail with the typed
    /// [`sched::ServerStopped`] error, and the worker thread is joined.
    /// Subsequent `submit` calls error immediately.
    pub fn shutdown(self) {
        self.inner.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    /// Mock model: logit k = image[0] for class (image[0] as usize), so
    /// the argmax round-trips the input deterministically.
    struct Mock {
        dims: Vec<usize>,
        calls: Arc<AtomicUsize>,
        fail: bool,
        delay_ms: u64,
    }

    impl ModelRunner for Mock {
        fn input_dims(&self) -> &[usize] {
            &self.dims
        }
        fn out_classes(&self) -> usize {
            10
        }
        fn run(&self, batch: &[f32]) -> Result<Vec<f32>> {
            self.calls.fetch_add(1, Ordering::Relaxed);
            if self.fail {
                anyhow::bail!("injected failure");
            }
            if self.delay_ms > 0 {
                std::thread::sleep(std::time::Duration::from_millis(self.delay_ms));
            }
            let sample: usize = self.dims[1..].iter().product();
            let n = self.dims[0];
            let mut out = vec![0f32; n * 10];
            for i in 0..n {
                let cls = (batch[i * sample] as usize).min(9);
                out[i * 10 + cls] = 1.0;
            }
            Ok(out)
        }
    }

    fn mk_server(batch: usize, fail: bool) -> (Server, Arc<AtomicUsize>) {
        let calls = Arc::new(AtomicUsize::new(0));
        let calls2 = calls.clone();
        let server = Server::start(
            move || Ok(Mock { dims: vec![batch, 1, 2, 2], calls: calls2, fail, delay_ms: 0 }),
            ServerConfig { batch_size: batch, queue_depth: 16, batch_timeout_ms: 1 },
        )
        .unwrap();
        (server, calls)
    }

    #[test]
    fn every_request_gets_its_own_answer() {
        let (server, _) = mk_server(4, false);
        let mut handles = Vec::new();
        for i in 0..17 {
            let mut img = vec![0f32; 4];
            img[0] = (i % 10) as f32;
            handles.push(server.submit(img).unwrap());
        }
        for (i, h) in handles.into_iter().enumerate() {
            let resp = h.wait().unwrap();
            assert_eq!(resp.argmax, i % 10, "request {i} mismatched response");
            assert!(resp.latency_s >= 0.0);
        }
        server.shutdown();
    }

    #[test]
    fn batching_amortizes_calls() {
        let (server, calls) = mk_server(8, false);
        let handles: Vec<_> = (0..32).map(|_| server.submit(vec![0f32; 4]).unwrap()).collect();
        for h in handles {
            h.wait().unwrap();
        }
        let n = calls.load(Ordering::Relaxed);
        assert!(n <= 12, "32 requests at batch 8 should take ~4-12 executes, got {n}");
        server.shutdown();
    }

    #[test]
    fn failures_propagate_to_every_request_in_batch() {
        let (server, _) = mk_server(4, true);
        let handles: Vec<_> = (0..4).map(|_| server.submit(vec![0f32; 4]).unwrap()).collect();
        for h in handles {
            assert!(h.wait().is_err());
        }
        server.shutdown();
    }

    #[test]
    fn shutdown_is_clean_with_no_requests() {
        let (server, _) = mk_server(2, false);
        server.shutdown();
    }

    #[test]
    fn startup_failure_reported() {
        let r = Server::start(
            || -> Result<Mock> { anyhow::bail!("no artifact") },
            ServerConfig { batch_size: 1, queue_depth: 1, batch_timeout_ms: 1 },
        );
        assert!(r.is_err());
    }

    /// The graceful-shutdown satellite: requests queued behind a slow
    /// batch are *drained* by shutdown — executed and answered, not
    /// dropped — so every waiter completes successfully.
    #[test]
    fn shutdown_drains_queued_requests() {
        let calls = Arc::new(AtomicUsize::new(0));
        let calls2 = calls.clone();
        let server = Server::start(
            move || Ok(Mock { dims: vec![2, 1, 2, 2], calls: calls2, fail: false, delay_ms: 5 }),
            ServerConfig { batch_size: 2, queue_depth: 16, batch_timeout_ms: 1 },
        )
        .unwrap();
        let handles: Vec<_> = (0..8).map(|_| server.submit(vec![1f32; 4]).unwrap()).collect();
        // shut down while most of those 8 are still queued behind the
        // 5 ms-per-batch worker
        let waiter = std::thread::spawn(move || {
            handles.into_iter().map(|h| h.wait()).collect::<Vec<_>>()
        });
        server.shutdown();
        for (i, r) in waiter.join().unwrap().into_iter().enumerate() {
            let resp = r.unwrap_or_else(|e| panic!("request {i} lost in shutdown: {e}"));
            assert_eq!(resp.argmax, 1, "request {i}");
        }
        assert!(calls.load(Ordering::Relaxed) >= 4, "all queued batches must have executed");
    }
}
