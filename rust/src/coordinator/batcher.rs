//! Dynamic batcher: bounded request queue → fixed-batch execution.
//!
//! Requests queue into a bounded channel (sync_channel gives natural
//! backpressure); the batcher thread drains up to `batch_size` requests,
//! waiting at most `batch_timeout_ms` for stragglers, pads the final
//! partial batch with zeros, executes on the PJRT model and completes the
//! per-request response channels.

use crate::engine::Workspace;
use crate::runtime::{EngineExecutor, Executor};
use anyhow::Result;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// What the batcher needs from a model backend. `Executor` (PJRT) and
/// the workspace-backed [`EngineExecutor`] are the production impls;
/// tests inject mocks.
pub trait ModelRunner {
    /// flattened NCHW input dims (index 0 = batch)
    fn input_dims(&self) -> &[usize];
    fn out_classes(&self) -> usize;
    fn run(&self, batch: &[f32]) -> Result<Vec<f32>>;
    /// Workspace-aware entry point: the batcher worker owns one
    /// [`Workspace`] for its lifetime and passes it to every batch, so
    /// workspace-backed runners serve steady-state traffic without heap
    /// allocation. Backends that manage their own memory (PJRT) ignore
    /// the workspace.
    fn run_with(&self, batch: &[f32], _ws: &mut Workspace) -> Result<Vec<f32>> {
        self.run(batch)
    }
    fn platform(&self) -> String {
        "mock".into()
    }
}

impl ModelRunner for Executor {
    fn input_dims(&self) -> &[usize] {
        &self.input_dims
    }
    fn out_classes(&self) -> usize {
        self.out_classes
    }
    fn run(&self, batch: &[f32]) -> Result<Vec<f32>> {
        Executor::run(self, batch)
    }
    fn platform(&self) -> String {
        Executor::platform(self)
    }
}

impl ModelRunner for EngineExecutor {
    fn input_dims(&self) -> &[usize] {
        &self.input_dims
    }
    fn out_classes(&self) -> usize {
        self.out_classes
    }
    fn run(&self, batch: &[f32]) -> Result<Vec<f32>> {
        EngineExecutor::run(self, batch)
    }
    fn run_with(&self, batch: &[f32], ws: &mut Workspace) -> Result<Vec<f32>> {
        EngineExecutor::run_with(self, batch, ws)
    }
    fn platform(&self) -> String {
        EngineExecutor::platform(self)
    }
}

/// Batcher sizing/timing knobs.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// fixed execution batch size (tail batches are zero-padded)
    pub batch_size: usize,
    /// bounded request-queue depth (backpressure)
    pub queue_depth: usize,
    /// max wait for stragglers before executing a partial batch
    pub batch_timeout_ms: u64,
}

/// One request's completed result.
pub struct Response {
    /// the request's logits row
    pub logits: Vec<f32>,
    /// index of the winning class
    pub argmax: usize,
    /// enqueue-to-completion latency in seconds
    pub latency_s: f64,
}

struct Request {
    image: Vec<f32>,
    enqueued: Instant,
    reply: Sender<Result<Response, String>>,
}

/// Handle for one in-flight request.
pub struct Pending {
    rx: Receiver<Result<Response, String>>,
}

impl Pending {
    /// Block until the batcher completes this request.
    pub fn wait(self) -> Result<Response> {
        self.rx
            .recv()
            .map_err(|_| anyhow::anyhow!("server dropped request"))?
            .map_err(|e| anyhow::anyhow!(e))
    }
}

/// Worker-side resource counters, published after every batch.
#[derive(Default)]
struct WorkerStats {
    /// peak bytes checked out of the worker's workspace
    ws_peak_bytes: AtomicU64,
    /// workspace checkouts that fell back to the heap (pool misses);
    /// stops growing once serving reaches steady state
    ws_heap_allocs: AtomicU64,
}

/// Handle to a running batcher: submit requests, read worker stats,
/// shut down.
pub struct Server {
    tx: SyncSender<Request>,
    stop: Arc<AtomicBool>,
    batches: Arc<AtomicU64>,
    stats: Arc<WorkerStats>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start the batcher. The PJRT client/executable are not `Send`
    /// (Rc-based FFI wrappers), so the executor is constructed *inside*
    /// the worker thread from the provided factory; startup errors are
    /// reported back synchronously.
    pub fn start<R, F>(factory: F, cfg: ServerConfig) -> Result<Server>
    where
        R: ModelRunner,
        F: FnOnce() -> Result<R> + Send + 'static,
    {
        let (tx, rx) = sync_channel::<Request>(cfg.queue_depth);
        let stop = Arc::new(AtomicBool::new(false));
        let batches = Arc::new(AtomicU64::new(0));
        let stats = Arc::new(WorkerStats::default());
        let stop2 = stop.clone();
        let batches2 = batches.clone();
        let stats2 = stats.clone();
        let (ready_tx, ready_rx) = std::sync::mpsc::channel::<Result<String, String>>();
        let worker = std::thread::spawn(move || {
            let exe = match factory() {
                Ok(e) => {
                    let _ = ready_tx.send(Ok(e.platform()));
                    e
                }
                Err(err) => {
                    let _ = ready_tx.send(Err(format!("{err:#}")));
                    return;
                }
            };
            batch_loop(exe, cfg, rx, stop2, batches2, stats2)
        });
        match ready_rx.recv() {
            Ok(Ok(platform)) => {
                println!("server ready on platform: {platform}");
                Ok(Server { tx, stop, batches, stats, worker: Some(worker) })
            }
            Ok(Err(e)) => {
                let _ = worker.join();
                Err(anyhow::anyhow!(e))
            }
            Err(_) => Err(anyhow::anyhow!("worker died during startup")),
        }
    }

    /// Submit one image (CHW flattened); returns a wait handle.
    /// Blocks when the queue is full (backpressure).
    pub fn submit(&self, image: Vec<f32>) -> Result<Pending> {
        let (reply, rx) = std::sync::mpsc::channel();
        self.tx
            .send(Request { image, enqueued: Instant::now(), reply })
            .map_err(|_| anyhow::anyhow!("server is shut down"))?;
        Ok(Pending { rx })
    }

    /// Number of batches the worker has executed so far.
    pub fn batches_executed(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    /// Peak bytes checked out of the worker's workspace so far.
    pub fn ws_peak_bytes(&self) -> u64 {
        self.stats.ws_peak_bytes.load(Ordering::Relaxed)
    }

    /// Workspace checkouts that fell back to a heap allocation. After
    /// the warm-up batch this must stop growing — the steady-state
    /// zero-alloc property asserted by the runtime e2e test.
    pub fn ws_heap_allocs(&self) -> u64 {
        self.stats.ws_heap_allocs.load(Ordering::Relaxed)
    }

    /// Stop the worker thread and join it.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        drop(self.tx.clone()); // original tx dropped in Drop
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn batch_loop<R: ModelRunner>(
    exe: R,
    cfg: ServerConfig,
    rx: Receiver<Request>,
    stop: Arc<AtomicBool>,
    batches: Arc<AtomicU64>,
    stats: Arc<WorkerStats>,
) {
    let sample: usize = exe.input_dims()[1..].iter().product();
    let classes = exe.out_classes();
    // One workspace and one padded input buffer for the worker's
    // lifetime: after the first batch warms the pools, steady-state
    // serving checks every buffer out of the arena.
    let mut ws = Workspace::new();
    let mut input = vec![0f32; cfg.batch_size * sample];
    loop {
        // collect a batch
        let mut batch: Vec<Request> = Vec::with_capacity(cfg.batch_size);
        match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(r) => batch.push(r),
            Err(RecvTimeoutError::Timeout) => {
                if stop.load(Ordering::Relaxed) {
                    return;
                }
                continue;
            }
            Err(RecvTimeoutError::Disconnected) => return,
        }
        let deadline = Instant::now() + Duration::from_millis(cfg.batch_timeout_ms);
        while batch.len() < cfg.batch_size {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        // pad + execute (the input buffer is reused; zero the tail pad)
        input[batch.len() * sample..].fill(0.0);
        for (i, r) in batch.iter().enumerate() {
            input[i * sample..(i + 1) * sample].copy_from_slice(&r.image);
        }
        let result = exe.run_with(&input, &mut ws);
        batches.fetch_add(1, Ordering::Relaxed);
        stats.ws_peak_bytes.store(ws.peak_bytes() as u64, Ordering::Relaxed);
        stats.ws_heap_allocs.store(ws.heap_allocs(), Ordering::Relaxed);
        match result {
            Ok(logits) => {
                for (i, r) in batch.into_iter().enumerate() {
                    let row = logits[i * classes..(i + 1) * classes].to_vec();
                    let argmax = row
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(k, _)| k)
                        .unwrap_or(0);
                    let _ = r.reply.send(Ok(Response {
                        logits: row,
                        argmax,
                        latency_s: r.enqueued.elapsed().as_secs_f64(),
                    }));
                }
            }
            Err(e) => {
                let msg = format!("execute failed: {e}");
                for r in batch {
                    let _ = r.reply.send(Err(msg.clone()));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    /// Mock model: logit k = image[0] for class (image[0] as usize), so
    /// the argmax round-trips the input deterministically.
    struct Mock {
        dims: Vec<usize>,
        calls: Arc<AtomicUsize>,
        fail: bool,
    }

    impl ModelRunner for Mock {
        fn input_dims(&self) -> &[usize] {
            &self.dims
        }
        fn out_classes(&self) -> usize {
            10
        }
        fn run(&self, batch: &[f32]) -> Result<Vec<f32>> {
            self.calls.fetch_add(1, Ordering::Relaxed);
            if self.fail {
                anyhow::bail!("injected failure");
            }
            let sample: usize = self.dims[1..].iter().product();
            let n = self.dims[0];
            let mut out = vec![0f32; n * 10];
            for i in 0..n {
                let cls = (batch[i * sample] as usize).min(9);
                out[i * 10 + cls] = 1.0;
            }
            Ok(out)
        }
    }

    fn mk_server(batch: usize, fail: bool) -> (Server, Arc<AtomicUsize>) {
        let calls = Arc::new(AtomicUsize::new(0));
        let calls2 = calls.clone();
        let server = Server::start(
            move || {
                Ok(Mock { dims: vec![batch, 1, 2, 2], calls: calls2, fail })
            },
            ServerConfig { batch_size: batch, queue_depth: 16, batch_timeout_ms: 1 },
        )
        .unwrap();
        (server, calls)
    }

    #[test]
    fn every_request_gets_its_own_answer() {
        let (server, _) = mk_server(4, false);
        let mut handles = Vec::new();
        for i in 0..17 {
            let mut img = vec![0f32; 4];
            img[0] = (i % 10) as f32;
            handles.push(server.submit(img).unwrap());
        }
        for (i, h) in handles.into_iter().enumerate() {
            let resp = h.wait().unwrap();
            assert_eq!(resp.argmax, i % 10, "request {i} mismatched response");
            assert!(resp.latency_s >= 0.0);
        }
        server.shutdown();
    }

    #[test]
    fn batching_amortizes_calls() {
        let (server, calls) = mk_server(8, false);
        let handles: Vec<_> = (0..32).map(|_| server.submit(vec![0f32; 4]).unwrap()).collect();
        for h in handles {
            h.wait().unwrap();
        }
        let n = calls.load(Ordering::Relaxed);
        assert!(n <= 12, "32 requests at batch 8 should take ~4-12 executes, got {n}");
        server.shutdown();
    }

    #[test]
    fn failures_propagate_to_every_request_in_batch() {
        let (server, _) = mk_server(4, true);
        let handles: Vec<_> = (0..4).map(|_| server.submit(vec![0f32; 4]).unwrap()).collect();
        for h in handles {
            assert!(h.wait().is_err());
        }
        server.shutdown();
    }

    #[test]
    fn shutdown_is_clean_with_no_requests() {
        let (server, _) = mk_server(2, false);
        server.shutdown();
    }

    #[test]
    fn startup_failure_reported() {
        let r = Server::start(
            || -> Result<Mock> { anyhow::bail!("no artifact") },
            ServerConfig { batch_size: 1, queue_depth: 1, batch_timeout_ms: 1 },
        );
        assert!(r.is_err());
    }
}
