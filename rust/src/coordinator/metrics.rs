//! Serving-layer metrics: latency statistics and engine plan-cache
//! counters.

use std::sync::atomic::{AtomicU64, Ordering};

/// Process-wide plan-cache hit counter (mirrored from every
/// [`crate::engine::PlanCache`] instance).
pub static PLAN_CACHE_HITS: AtomicU64 = AtomicU64::new(0);
/// Process-wide plan-cache miss counter.
pub static PLAN_CACHE_MISSES: AtomicU64 = AtomicU64::new(0);

/// Record one plan-cache lookup outcome.
pub fn record_plan_cache(hit: bool) {
    if hit {
        PLAN_CACHE_HITS.fetch_add(1, Ordering::Relaxed);
    } else {
        PLAN_CACHE_MISSES.fetch_add(1, Ordering::Relaxed);
    }
}

/// (hits, misses) snapshot of the process-wide plan-cache counters.
pub fn plan_cache_counters() -> (u64, u64) {
    (PLAN_CACHE_HITS.load(Ordering::Relaxed), PLAN_CACHE_MISSES.load(Ordering::Relaxed))
}

/// (peak bytes, heap-fallback allocations) snapshot of the process-wide
/// workspace counters. The atomics live with the arena
/// ([`crate::engine::workspace::global_counters`]) so the engine layer
/// stays below the serving layer; this is the serving-side view of
/// them, reported by `sfc serve` next to latency and plan-cache stats.
pub fn workspace_counters() -> (u64, u64) {
    crate::engine::workspace::global_counters()
}

/// Live bytes of pre-packed weight artifacts across the process
/// ([`crate::engine::packed_weight_bytes`]) — the memory cost of
/// plan-time weight pre-packing, reported by `sfc serve` so it stays
/// observable alongside the workspace accounting.
pub fn packed_weight_bytes() -> u64 {
    crate::engine::packed_weight_bytes()
}

/// The active compute-kernel dispatch arm (`"avx2" | "neon" |
/// "scalar"`, see [`crate::linalg::simd`]) — reported by `sfc serve`
/// and recorded in the BENCH_conv.json `kernel` field.
pub fn kernel_name() -> &'static str {
    crate::linalg::simd::kernel_name()
}

/// Latency summary over a set of per-request samples (seconds).
#[derive(Clone, Copy, Debug)]
pub struct LatencyStats {
    /// sample count
    pub n: usize,
    /// arithmetic mean
    pub mean: f64,
    /// median
    pub p50: f64,
    /// 95th percentile
    pub p95: f64,
    /// 99th percentile
    pub p99: f64,
    /// worst sample
    pub max: f64,
}

impl LatencyStats {
    /// Summarize a non-empty sample set.
    pub fn from_samples(samples: &[f64]) -> LatencyStats {
        assert!(!samples.is_empty());
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| -> f64 {
            let idx = ((s.len() as f64 - 1.0) * p).round() as usize;
            s[idx]
        };
        LatencyStats {
            n: s.len(),
            mean: s.iter().sum::<f64>() / s.len() as f64,
            p50: pct(0.50),
            p95: pct(0.95),
            p99: pct(0.99),
            max: *s.last().unwrap(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let samples: Vec<f64> = (1..=100).map(|v| v as f64).collect();
        let st = LatencyStats::from_samples(&samples);
        assert_eq!(st.n, 100);
        assert!(st.p50 <= st.p95 && st.p95 <= st.p99 && st.p99 <= st.max);
        assert_eq!(st.max, 100.0);
        assert!((st.p50 - 50.0).abs() <= 1.0);
    }

    #[test]
    fn single_sample() {
        let st = LatencyStats::from_samples(&[0.5]);
        assert_eq!(st.p99, 0.5);
        assert_eq!(st.mean, 0.5);
    }
}
