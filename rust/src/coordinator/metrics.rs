//! Latency statistics for the serving layer.

#[derive(Clone, Copy, Debug)]
pub struct LatencyStats {
    pub n: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl LatencyStats {
    pub fn from_samples(samples: &[f64]) -> LatencyStats {
        assert!(!samples.is_empty());
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| -> f64 {
            let idx = ((s.len() as f64 - 1.0) * p).round() as usize;
            s[idx]
        };
        LatencyStats {
            n: s.len(),
            mean: s.iter().sum::<f64>() / s.len() as f64,
            p50: pct(0.50),
            p95: pct(0.95),
            p99: pct(0.99),
            max: *s.last().unwrap(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let samples: Vec<f64> = (1..=100).map(|v| v as f64).collect();
        let st = LatencyStats::from_samples(&samples);
        assert_eq!(st.n, 100);
        assert!(st.p50 <= st.p95 && st.p95 <= st.p99 && st.p99 <= st.max);
        assert_eq!(st.max, 100.0);
        assert!((st.p50 - 50.0).abs() <= 1.0);
    }

    #[test]
    fn single_sample() {
        let st = LatencyStats::from_samples(&[0.5]);
        assert_eq!(st.p99, 0.5);
        assert_eq!(st.mean, 0.5);
    }
}
