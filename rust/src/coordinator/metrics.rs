//! Serving-layer metrics: latency statistics (exact and streaming),
//! per-model scheduler gauges, and engine plan-cache counters.

use std::sync::atomic::{AtomicU64, Ordering};

/// Process-wide plan-cache hit counter (mirrored from every
/// [`crate::engine::PlanCache`] instance).
pub static PLAN_CACHE_HITS: AtomicU64 = AtomicU64::new(0);
/// Process-wide plan-cache miss counter.
pub static PLAN_CACHE_MISSES: AtomicU64 = AtomicU64::new(0);

/// Record one plan-cache lookup outcome.
pub fn record_plan_cache(hit: bool) {
    if hit {
        PLAN_CACHE_HITS.fetch_add(1, Ordering::Relaxed);
    } else {
        PLAN_CACHE_MISSES.fetch_add(1, Ordering::Relaxed);
    }
}

/// (hits, misses) snapshot of the process-wide plan-cache counters.
pub fn plan_cache_counters() -> (u64, u64) {
    (PLAN_CACHE_HITS.load(Ordering::Relaxed), PLAN_CACHE_MISSES.load(Ordering::Relaxed))
}

/// (peak bytes, heap-fallback allocations) snapshot of the process-wide
/// workspace counters. The atomics live with the arena
/// ([`crate::engine::workspace::global_counters`]) so the engine layer
/// stays below the serving layer; this is the serving-side view of
/// them, reported by `sfc serve` next to latency and plan-cache stats.
pub fn workspace_counters() -> (u64, u64) {
    crate::engine::workspace::global_counters()
}

/// (peak resident bytes, leases, pool-miss fresh builds) snapshot of the
/// process-wide shared-workspace-pool counters
/// ([`crate::engine::workspace::global_pool_counters`]). Non-zero only
/// under the global batch scheduler (`--sched global`), where models
/// lease arenas from a shared [`crate::engine::WorkspacePool`] instead
/// of each worker owning one; `misses` stopping growth is the pooled
/// form of the zero-steady-state-alloc contract.
pub fn ws_pool_counters() -> (u64, u64, u64) {
    crate::engine::workspace::global_pool_counters()
}

/// Live bytes of pre-packed weight artifacts across the process
/// ([`crate::engine::packed_weight_bytes`]) — the memory cost of
/// plan-time weight pre-packing, reported by `sfc serve` so it stays
/// observable alongside the workspace accounting.
pub fn packed_weight_bytes() -> u64 {
    crate::engine::packed_weight_bytes()
}

/// The active compute-kernel dispatch arm (`"avx2" | "neon" |
/// "scalar"`, see [`crate::linalg::simd`]) — reported by `sfc serve`
/// and recorded in the BENCH_conv.json `kernel` field.
pub fn kernel_name() -> &'static str {
    crate::linalg::simd::kernel_name()
}

/// (total, leased, peak) snapshot of the process-wide compute-lane
/// budget ([`crate::util::par::CoreBudget`]). `peak` is the high-water
/// mark of concurrently leased lanes — the observable proof that model
/// workers × intra-op GEMM threads never oversubscribe the host.
/// Reported by `sfc serve` next to the kernel/workspace stats.
pub fn core_budget() -> (usize, usize, usize) {
    crate::util::par::CoreBudget::snapshot()
}

/// Snapshot of the persistent executor-pool gauges
/// ([`crate::util::pool::gauges`]): resident workers, tasks executed,
/// tasks stolen off another thread's deque, thread spawns avoided by
/// reusing resident workers, and park/unpark transitions. Reported by
/// `sfc serve` / `sfc loadgen` and recorded in the BENCH_conv.json
/// `pool` block (schema ≥ 7).
pub fn pool_gauges() -> crate::util::pool::PoolGauges {
    crate::util::pool::gauges()
}

/// Latency summary over a set of per-request samples (seconds).
#[derive(Clone, Copy, Debug)]
pub struct LatencyStats {
    /// sample count
    pub n: usize,
    /// arithmetic mean
    pub mean: f64,
    /// median
    pub p50: f64,
    /// 95th percentile
    pub p95: f64,
    /// 99th percentile
    pub p99: f64,
    /// worst sample
    pub max: f64,
}

impl LatencyStats {
    /// Summarize a non-empty sample set.
    pub fn from_samples(samples: &[f64]) -> LatencyStats {
        assert!(!samples.is_empty());
        let mut s = samples.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct = |p: f64| -> f64 {
            let idx = ((s.len() as f64 - 1.0) * p).round() as usize;
            s[idx]
        };
        LatencyStats {
            n: s.len(),
            mean: s.iter().sum::<f64>() / s.len() as f64,
            p50: pct(0.50),
            p95: pct(0.95),
            p99: pct(0.99),
            max: *s.last().unwrap(),
        }
    }
}

/// Geometric bucket count of a [`StreamingHistogram`] — fixed, so the
/// memory footprint is bounded no matter how many samples are recorded.
pub const HIST_BUCKETS: usize = 256;
/// Histogram floor (seconds): everything below lands in bucket 0.
const HIST_MIN_S: f64 = 1e-6;
/// Histogram ceiling (seconds): everything above lands in the top bucket.
const HIST_MAX_S: f64 = 1e2;

/// Geometric growth factor between bucket boundaries: `HIST_BUCKETS - 2`
/// log-spaced buckets cover [`HIST_MIN_S`, `HIST_MAX_S`] (plus one
/// underflow and one overflow bucket), giving ≈ 3.7 % worst-case
/// relative quantile error — far below the run-to-run noise of any
/// latency distribution worth a p99.
fn hist_growth() -> f64 {
    (HIST_MAX_S / HIST_MIN_S).powf(1.0 / (HIST_BUCKETS - 2) as f64)
}

/// Streaming latency histogram with bounded memory: a fixed array of
/// geometrically spaced buckets (HDR-histogram style). `record` is O(1)
/// and allocation-free, quantile queries walk the cumulative counts, and
/// two histograms over disjoint sample sets [`StreamingHistogram::merge`]
/// into exactly the histogram of the concatenated set — the properties
/// the scheduler needs to keep per-model p50/p99 under sustained load
/// without retaining per-request samples (which `LatencyStats` does).
#[derive(Clone, Debug)]
pub struct StreamingHistogram {
    counts: Vec<u64>,
    n: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for StreamingHistogram {
    fn default() -> Self {
        StreamingHistogram::new()
    }
}

impl StreamingHistogram {
    /// An empty histogram ([`HIST_BUCKETS`] zeroed buckets).
    pub fn new() -> StreamingHistogram {
        StreamingHistogram {
            counts: vec![0; HIST_BUCKETS],
            n: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: 0.0,
        }
    }

    fn bucket_index(v: f64) -> usize {
        if !(v > HIST_MIN_S) {
            return 0;
        }
        let idx = 1 + ((v / HIST_MIN_S).ln() / hist_growth().ln()).floor() as usize;
        idx.min(HIST_BUCKETS - 1)
    }

    /// Record one sample (seconds). Negative/NaN samples count into the
    /// underflow bucket rather than poisoning the quantiles.
    pub fn record(&mut self, v: f64) {
        let v = if v.is_finite() && v > 0.0 { v } else { 0.0 };
        self.counts[Self::bucket_index(v)] += 1;
        self.n += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Samples recorded so far.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Arithmetic mean of the recorded samples (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    /// Largest recorded sample (exact, tracked outside the buckets).
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Smallest recorded sample (exact; 0 when empty).
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Estimated `q`-quantile (q in [0, 1]) of the recorded samples:
    /// the geometric midpoint of the bucket holding the `ceil(q·n)`-th
    /// sample, clamped to the exact observed [min, max]. Returns 0 on an
    /// empty histogram.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * self.n as f64).ceil() as u64).clamp(1, self.n);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= target {
                let rep = if i == 0 {
                    HIST_MIN_S
                } else {
                    let lower = HIST_MIN_S * hist_growth().powi(i as i32 - 1);
                    lower * hist_growth().sqrt()
                };
                return rep.clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Median estimate.
    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    /// 99th-percentile estimate.
    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    /// Fold `other` into `self`: afterwards `self` is exactly the
    /// histogram that would have resulted from recording both sample
    /// streams into one instance (bucket-wise sum; min/max/mean exact).
    pub fn merge(&mut self, other: &StreamingHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.n += other.n;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Number of buckets held — constant ([`HIST_BUCKETS`]) regardless
    /// of how many samples were recorded (the bounded-memory property).
    pub fn bucket_count(&self) -> usize {
        self.counts.len()
    }
}

/// Per-model serving gauges and counters, maintained by the
/// [`crate::coordinator::sched`] scheduler and snapshotted into
/// [`crate::coordinator::sched::ModelSnapshot`]. All atomics so the
/// submit path and the worker update them without taking the queue lock.
#[derive(Default)]
pub struct ModelGauges {
    /// requests accepted by `submit` (admitted or shed — every outcome
    /// is accounted: `submitted == completed + shed + failed` once the
    /// queue drains)
    pub submitted: AtomicU64,
    /// requests completed with logits
    pub completed: AtomicU64,
    /// requests shed by admission control, displacement or deadline
    /// expiry (the typed `Response::Shed` outcomes)
    pub shed: AtomicU64,
    /// requests whose batch execution failed (error propagated to the
    /// waiter)
    pub failed: AtomicU64,
    /// completed requests that finished at or before their deadline
    pub deadline_met: AtomicU64,
    /// current queue depth (gauge: stored, not accumulated)
    pub queue_depth: AtomicU64,
    /// batches the model's worker has executed
    pub batches: AtomicU64,
    /// batches speculatively split by the global planner because the
    /// cost model predicted the full batch would blow the deadline of
    /// queued later arrivals (the tail was requeued, not dropped)
    pub splits: AtomicU64,
    /// peak bytes checked out of the worker's workspace
    pub ws_peak_bytes: AtomicU64,
    /// workspace checkouts that fell back to the heap; stops growing
    /// once serving reaches steady state (the zero-alloc contract)
    pub ws_heap_allocs: AtomicU64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn percentiles_ordered() {
        let samples: Vec<f64> = (1..=100).map(|v| v as f64).collect();
        let st = LatencyStats::from_samples(&samples);
        assert_eq!(st.n, 100);
        assert!(st.p50 <= st.p95 && st.p95 <= st.p99 && st.p99 <= st.max);
        assert_eq!(st.max, 100.0);
        assert!((st.p50 - 50.0).abs() <= 1.0);
    }

    #[test]
    fn single_sample() {
        let st = LatencyStats::from_samples(&[0.5]);
        assert_eq!(st.p99, 0.5);
        assert_eq!(st.mean, 0.5);
    }

    #[test]
    fn histogram_empty_and_single() {
        let mut h = StreamingHistogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.p50(), 0.0);
        assert_eq!(h.p99(), 0.0);
        h.record(0.025);
        assert_eq!(h.count(), 1);
        // one sample: every quantile is clamped onto it exactly
        assert_eq!(h.p50(), 0.025);
        assert_eq!(h.p99(), 0.025);
        assert_eq!(h.max(), 0.025);
    }

    /// Property: on random sample sets spanning several orders of
    /// magnitude, the streaming p50/p99 stay within the bucket
    /// resolution (< 8 % relative error, see [`hist_growth`]) of the
    /// exact sorted-sample quantiles.
    #[test]
    fn histogram_quantiles_track_exact_quantiles() {
        let mut rng = Pcg32::seeded(0x51A7);
        for trial in 0..20 {
            let n = 200 + (rng.next_u32() % 2000) as usize;
            let mut h = StreamingHistogram::new();
            let mut samples = Vec::with_capacity(n);
            for _ in 0..n {
                // log-uniform over ~[100 µs, 1 s] plus a heavy tail
                let v = 1e-4 * (9.21 * rng.next_f64()).exp();
                let v = if rng.next_f64() < 0.05 { v * 10.0 } else { v };
                h.record(v);
                samples.push(v);
            }
            let exact = LatencyStats::from_samples(&samples);
            for (got, want, name) in
                [(h.p50(), exact.p50, "p50"), (h.p99(), exact.p99, "p99")]
            {
                let rel = (got - want).abs() / want;
                assert!(
                    rel < 0.08,
                    "trial {trial}: {name} streaming {got} vs exact {want} (rel {rel:.3})"
                );
            }
            assert!((h.mean() - exact.mean).abs() / exact.mean < 1e-9, "mean is exact");
            assert_eq!(h.max(), exact.max, "max is exact");
        }
    }

    /// Property: merging two histograms equals recording the
    /// concatenated stream into one — bucket-exact, not approximate.
    #[test]
    fn histogram_merge_is_exact() {
        let mut rng = Pcg32::seeded(0xDEAD);
        let mut a = StreamingHistogram::new();
        let mut b = StreamingHistogram::new();
        let mut both = StreamingHistogram::new();
        for i in 0..3000 {
            let v = 1e-5 * (11.0 * rng.next_f64()).exp();
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            both.record(v);
        }
        a.merge(&b);
        assert_eq!(a.counts, both.counts);
        assert_eq!(a.count(), both.count());
        assert_eq!(a.max(), both.max());
        assert_eq!(a.min(), both.min());
        assert!((a.mean() - both.mean()).abs() < 1e-12);
        assert_eq!(a.p99(), both.p99());
    }

    /// Property: memory is bounded — the bucket array never grows, no
    /// matter how many samples or how extreme their spread.
    #[test]
    fn histogram_memory_is_bounded() {
        let mut h = StreamingHistogram::new();
        assert_eq!(h.bucket_count(), HIST_BUCKETS);
        for i in 0..100_000u64 {
            h.record((i as f64) * 1e-7);
        }
        h.record(1e9); // overflow bucket
        h.record(-3.0); // underflow bucket
        h.record(f64::NAN); // must not poison anything
        assert_eq!(h.bucket_count(), HIST_BUCKETS);
        assert_eq!(h.count(), 100_003);
        assert!(h.p99().is_finite() && h.p50() <= h.p99());
    }

    #[test]
    fn histogram_out_of_range_samples_clamp() {
        let mut h = StreamingHistogram::new();
        h.record(1e-9); // below the floor
        h.record(1e4); // above the ceiling
        assert_eq!(h.count(), 2);
        // quantiles stay within the exact observed range
        assert!(h.quantile(0.0) >= h.min() && h.quantile(1.0) <= h.max());
    }
}
