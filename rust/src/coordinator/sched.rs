//! Multi-model serving scheduler: continuous batching with deadlines,
//! admission control, and load shedding.
//!
//! [`MultiServer`] hosts several resident models (registered by name via
//! [`MultiServer::add_model`], e.g. a float ResNet next to an int8
//! MobileNet). All models share the process-wide
//! [`crate::engine::PlanCache`] and a global packed-weight byte budget
//! ([`SchedConfig::packed_budget_bytes`], enforced against
//! [`crate::engine::packed_weight_bytes`] at registration time); each
//! model gets one worker thread, one long-lived
//! [`crate::engine::Workspace`] and one reusable padded input buffer, so
//! the zero-steady-state-alloc contract of the single-model batcher
//! carries over unchanged.
//!
//! ## Scheduler state machine (per model)
//!
//! ```text
//!             submit(model, image, opts)
//!                     │
//!       queue full?───┼──────────────┐
//!           │no       │yes           │
//!           ▼         ▼              ▼
//!       [QUEUED]   newcomer out-  lowest-priority victim displaced
//!           │      ranks victim?  (typed Response::Shed, Displaced)
//!           │      no → newcomer shed (QueueFull)
//!           ▼
//!   worker: WAIT ──arrival/timeout──▶ FORM ──fire──▶ EXECUTE ──▶ COMPLETE
//!           ▲        (deadline-driven linger)            │
//!           │  expired entries shed (DeadlineExpired)    │
//!           └────────────────────────────────────────────┘
//! ```
//!
//! **Batch formation is deadline-driven, not size-driven.** The worker
//! lingers for stragglers only while it can afford to: it fires as soon
//! as the batch is full, or when `earliest_deadline − 2·exec_ewma` (a
//! running estimate of batch execution time) arrives, or when the oldest
//! request has lingered [`SchedConfig::linger_ms`] — whichever comes
//! first. Requests whose deadline has already passed are shed from the
//! queue (never executed — executing doomed work is how overload turns
//! into collapse), ordered most-expired first.
//!
//! **Admission control** is displacement-based: a full queue sheds the
//! lowest-priority / closest-to-expiry entry to admit a higher-priority
//! newcomer, and sheds the newcomer itself otherwise. Shedding is a
//! first-class outcome — the waiter gets [`Response::Shed`] with a typed
//! [`ShedReason`], not an error string — so load tests can assert *what*
//! was sacrificed, and callers can retry or degrade deliberately.
//!
//! ## Global batch planning (`--sched global`)
//!
//! Under [`DispatchMode::Worker`] each model's thread batches and fires
//! autonomously: N resident models race N batches onto the CPU at once
//! regardless of deadlines, and a hot model queues behind its own
//! thread. [`DispatchMode::Global`] keeps the per-model executor
//! threads (runners may be `!Send`, so each stays resident where its
//! factory built it) but moves the *fire decision* into one shared
//! `GlobalPlan`: an executor with a formed batch publishes a candidate
//! (earliest deadline, predicted execution time from the cost model)
//! and runs only when (a) a run slot is free — at most
//! [`crate::util::par::num_threads`] batches execute concurrently, so
//! inter-batch parallelism and intra-op GEMM teams share one core
//! budget — and (b) its candidate has the least slack
//! (`deadline − predicted`, i.e. cost-aware EDF) among all published
//! candidates. Execution leases a [`crate::engine::WorkspacePool`]
//! arena (model-affine, so the zero-steady-state-alloc contract holds
//! across models) and submits its intra-op work through
//! [`crate::util::pool::urgent`] so the selected batch jumps the
//! executor pool's FIFO backlog.
//!
//! The **cost model** is a per-(model, batch-size) predicted ns table:
//! seeded from the installed tuning table's measured ns/call
//! ([`crate::engine::tuning::global_exec_ns`], written by `sfc autotune
//! --out`, schema v4), refined online from each executed batch, with a
//! 500 µs last-resort default. **Speculative batch splitting**: when
//! the plan is contended and the cost model predicts a full batch would
//! hold its run slot past the instant a rival model's candidate must
//! start to meet its deadline, the batch is trimmed to the
//! predicted-feasible prefix and the tail is requeued at the *front* of
//! the model queue (it keeps its deadlines, so EDF re-selects it next —
//! splitting can never starve the tail).
//!
//! Shutdown drains: queued work is executed, in-flight waiters complete,
//! and only then do late `submit` calls and orphaned tickets fail with
//! the typed [`ServerStopped`] error. Both dispatch modes drain
//! identically, and both produce bit-identical logits for identical
//! request streams (convolution is per-sample independent and tail
//! padding is zeroed, so batch composition never changes a row).

use super::batcher::ModelRunner;
use super::metrics::{ModelGauges, StreamingHistogram};
use crate::engine::Workspace;
use anyhow::Result;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Request priority: under overload, lower priorities are shed first.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Shed first — best-effort traffic.
    Low,
    /// The default tier.
    #[default]
    Normal,
    /// Shed last — displaces queued lower-priority work when the queue
    /// is full.
    High,
}

impl Priority {
    /// Lower-case tier name (for reports).
    pub fn name(self) -> &'static str {
        match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }
}

/// Per-request scheduling knobs for [`MultiServer::submit`].
#[derive(Clone, Copy, Debug, Default)]
pub struct SubmitOpts {
    /// shed ordering tier (default [`Priority::Normal`])
    pub priority: Priority,
    /// completion deadline measured from submit; `None` uses
    /// [`SchedConfig::default_deadline_ms`]
    pub deadline: Option<Duration>,
}

/// Why a request was shed instead of executed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedReason {
    /// queue full at admission and the newcomer did not outrank any
    /// queued entry
    QueueFull,
    /// evicted from the queue by a higher-priority newcomer
    Displaced,
    /// deadline passed while still queued; executing it would waste a
    /// batch slot on an answer nobody is waiting for
    DeadlineExpired,
}

impl ShedReason {
    /// Snake-case reason name (for reports).
    pub fn name(self) -> &'static str {
        match self {
            ShedReason::QueueFull => "queue_full",
            ShedReason::Displaced => "displaced",
            ShedReason::DeadlineExpired => "expired",
        }
    }
}

/// A shed outcome: the request was sacrificed by admission control or
/// deadline policy, and this records the circumstances.
#[derive(Clone, Debug)]
pub struct Shed {
    /// model the request targeted
    pub model: String,
    /// why it was shed
    pub reason: ShedReason,
    /// priority it carried
    pub priority: Priority,
    /// seconds it waited in the queue before being shed
    pub waited_s: f64,
}

/// A completed inference.
#[derive(Clone, Debug)]
pub struct Completion {
    /// the request's logits row
    pub logits: Vec<f32>,
    /// index of the winning class
    pub argmax: usize,
    /// submit-to-completion latency in seconds
    pub latency_s: f64,
    /// whether completion beat the request's deadline
    pub deadline_met: bool,
}

/// Outcome of one scheduled request: either a completed inference or a
/// typed shed. Shedding is *not* an error — [`Ticket::wait`] returns
/// `Ok(Response::Shed(..))` so callers distinguish policy (shed) from
/// failure (execution error, stopped server).
#[derive(Clone, Debug)]
pub enum Response {
    /// executed; logits attached
    Done(Completion),
    /// sacrificed by admission control or deadline policy
    Shed(Shed),
}

/// Typed error for requests that hit a stopped (or stopping) server:
/// `submit` after shutdown, and tickets orphaned by a dead worker.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServerStopped;

impl std::fmt::Display for ServerStopped {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "server stopped")
    }
}

impl std::error::Error for ServerStopped {}

/// Reply-channel payload error (internal): distinguishes "server went
/// away" from "the batch execution itself failed".
enum ReplyErr {
    Stopped,
    Exec(String),
}

struct SchedRequest {
    image: Vec<f32>,
    enqueued: Instant,
    deadline: Instant,
    priority: Priority,
    reply: Sender<Result<Response, ReplyErr>>,
}

/// Handle for one scheduled request.
pub struct Ticket {
    rx: Receiver<Result<Response, ReplyErr>>,
}

impl Ticket {
    /// Block until the scheduler resolves this request: a completion, a
    /// typed shed, an execution error, or [`ServerStopped`].
    pub fn wait(self) -> Result<Response> {
        match self.rx.recv() {
            Ok(Ok(resp)) => Ok(resp),
            Ok(Err(ReplyErr::Exec(e))) => Err(anyhow::anyhow!(e)),
            Ok(Err(ReplyErr::Stopped)) | Err(_) => Err(anyhow::Error::new(ServerStopped)),
        }
    }
}

/// Which planner decides when a formed batch executes (`--sched`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum DispatchMode {
    /// the PR 6 baseline: every model's thread batches and fires
    /// autonomously, one resident workspace per worker
    #[default]
    Worker,
    /// the global execution planner: candidate batches from all models
    /// are ordered by cost-aware EDF, at most
    /// [`crate::util::par::num_threads`] run at once, and workspaces
    /// come from one shared byte-accounted pool (see the module docs)
    Global,
}

impl DispatchMode {
    /// Lower-case mode name (CLI value, reports).
    pub fn name(self) -> &'static str {
        match self {
            DispatchMode::Worker => "worker",
            DispatchMode::Global => "global",
        }
    }

    /// Parse a `--sched` CLI value.
    pub fn parse(s: &str) -> Result<DispatchMode> {
        match s {
            "worker" => Ok(DispatchMode::Worker),
            "global" => Ok(DispatchMode::Global),
            other => anyhow::bail!("unknown --sched mode '{other}' (expected worker|global)"),
        }
    }
}

/// Scheduler sizing/policy knobs, shared by every resident model.
#[derive(Clone, Copy, Debug)]
pub struct SchedConfig {
    /// per-model bounded queue depth; admission control kicks in beyond it
    pub queue_depth: usize,
    /// deadline applied when [`SubmitOpts::deadline`] is `None`
    pub default_deadline_ms: u64,
    /// max time the oldest queued request lingers waiting for batch
    /// stragglers before a partial batch fires
    pub linger_ms: u64,
    /// global budget for plan-time packed weights
    /// ([`crate::engine::packed_weight_bytes`] across *all* models);
    /// `0` = unlimited. `add_model` fails if registering a model
    /// overruns it.
    pub packed_budget_bytes: u64,
    /// batch dispatch planner (`--sched worker|global`)
    pub dispatch: DispatchMode,
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig {
            queue_depth: 64,
            default_deadline_ms: 50,
            linger_ms: 2,
            packed_budget_bytes: 0,
            dispatch: DispatchMode::Worker,
        }
    }
}

struct QueueState {
    q: VecDeque<SchedRequest>,
    stopping: bool,
    dead: bool,
}

/// State shared between a model's submitters and its worker thread.
struct ModelShared {
    name: String,
    state: Mutex<QueueState>,
    /// worker sleeps here between arrivals
    arrivals: Condvar,
    /// legacy blocking submitters sleep here when the queue is full
    space: Condvar,
    gauges: ModelGauges,
    latency: Mutex<StreamingHistogram>,
    /// per-request flattened sample length (set by the worker from the
    /// runner's dims before it signals ready)
    sample_len: AtomicUsize,
    /// execution batch size (runner dims\[0\])
    max_batch: AtomicUsize,
}

struct ModelEntry {
    shared: Arc<ModelShared>,
    worker: Option<std::thread::JoinHandle<()>>,
}

/// Point-in-time per-model scheduler statistics
/// ([`MultiServer::snapshot`]).
#[derive(Clone, Debug)]
pub struct ModelSnapshot {
    /// model name
    pub model: String,
    /// requests accepted by `submit`
    pub submitted: u64,
    /// requests completed with logits
    pub completed: u64,
    /// requests shed (all [`ShedReason`]s)
    pub shed: u64,
    /// requests whose batch execution failed
    pub failed: u64,
    /// completed requests that beat their deadline
    pub deadline_met: u64,
    /// current queue depth
    pub queue_depth: u64,
    /// batches executed by the worker
    pub batches: u64,
    /// batches speculatively split by the global planner (always 0
    /// under [`DispatchMode::Worker`])
    pub splits: u64,
    /// peak bytes checked out of the worker's workspace
    pub ws_peak_bytes: u64,
    /// workspace heap fallbacks (flat after warm-up = zero-alloc)
    pub ws_heap_allocs: u64,
    /// streaming completion-latency histogram (seconds)
    pub latency: StreamingHistogram,
}

/// Multi-model continuous-batching server. See the module docs for the
/// scheduling policy; see [`super::batcher::Server`] for the single-model
/// shim over this type that preserves the original API.
pub struct MultiServer {
    cfg: SchedConfig,
    /// registration-ordered so reports are deterministic
    models: Mutex<Vec<(String, ModelEntry)>>,
    /// shared execution plan, used by executors when
    /// `cfg.dispatch == DispatchMode::Global`
    plan: Arc<GlobalPlan>,
    stopping: AtomicBool,
}

impl MultiServer {
    /// An empty server; register models with [`MultiServer::add_model`].
    pub fn new(cfg: SchedConfig) -> MultiServer {
        MultiServer {
            cfg,
            models: Mutex::new(Vec::new()),
            plan: Arc::new(GlobalPlan::new()),
            stopping: AtomicBool::new(false),
        }
    }

    /// The configuration every resident model runs under.
    pub fn config(&self) -> SchedConfig {
        self.cfg
    }

    /// Byte-accounting gauges of the shared workspace pool (all zero
    /// under [`DispatchMode::Worker`], where each worker owns its
    /// workspace outright).
    pub fn ws_pool_gauges(&self) -> crate::engine::WsPoolGauges {
        self.plan.ws_pool.gauges()
    }

    /// Register a model under `name` and start its worker thread. The
    /// runner is constructed *inside* the worker from `factory` (PJRT
    /// executors are not `Send`); construction errors are returned
    /// synchronously. After a successful build, the global packed-weight
    /// budget is checked: if [`crate::engine::packed_weight_bytes`] now
    /// exceeds [`SchedConfig::packed_budget_bytes`], the worker is torn
    /// down and registration fails — budget admission happens here, at
    /// plan/pack time, not per request. Returns the runner's platform
    /// name.
    pub fn add_model<R, F>(&self, name: &str, factory: F) -> Result<String>
    where
        R: ModelRunner,
        F: FnOnce() -> Result<R> + Send + 'static,
    {
        if self.stopping.load(Ordering::SeqCst) {
            return Err(anyhow::Error::new(ServerStopped));
        }
        {
            let models = self.models.lock().unwrap();
            anyhow::ensure!(
                !models.iter().any(|(n, _)| n == name),
                "model '{name}' is already registered"
            );
        }
        let shared = Arc::new(ModelShared {
            name: name.to_string(),
            state: Mutex::new(QueueState { q: VecDeque::new(), stopping: false, dead: false }),
            arrivals: Condvar::new(),
            space: Condvar::new(),
            gauges: ModelGauges::default(),
            latency: Mutex::new(StreamingHistogram::new()),
            sample_len: AtomicUsize::new(0),
            max_batch: AtomicUsize::new(0),
        });
        let shared2 = shared.clone();
        let cfg = self.cfg;
        let plan = self.plan.clone();
        let plan_idx =
            if cfg.dispatch == DispatchMode::Global { plan.register() } else { usize::MAX };
        let (ready_tx, ready_rx) = channel::<Result<String, String>>();
        let worker = std::thread::Builder::new()
            .name(format!("sfc-sched-{name}"))
            .spawn(move || {
                let exe = match factory() {
                    Ok(e) => {
                        let dims = e.input_dims();
                        shared2
                            .sample_len
                            .store(dims[1..].iter().product(), Ordering::SeqCst);
                        shared2.max_batch.store(dims[0].max(1), Ordering::SeqCst);
                        let _ = ready_tx.send(Ok(e.platform()));
                        e
                    }
                    Err(err) => {
                        let _ = ready_tx.send(Err(format!("{err:#}")));
                        if cfg.dispatch == DispatchMode::Global {
                            plan.retire(plan_idx);
                        }
                        return;
                    }
                };
                match cfg.dispatch {
                    DispatchMode::Worker => worker_loop(exe, shared2, cfg),
                    DispatchMode::Global => global_loop(exe, shared2, cfg, plan, plan_idx),
                }
            })
            .expect("spawn scheduler worker");
        let platform = match ready_rx.recv() {
            Ok(Ok(platform)) => platform,
            Ok(Err(e)) => {
                let _ = worker.join();
                return Err(anyhow::anyhow!(e));
            }
            Err(_) => return Err(anyhow::anyhow!("worker died during startup")),
        };
        if self.cfg.packed_budget_bytes > 0 {
            let live = crate::engine::packed_weight_bytes();
            if live > self.cfg.packed_budget_bytes {
                stop_model(&shared);
                let _ = worker.join();
                fail_queue(&shared);
                anyhow::bail!(
                    "registering '{name}' overruns the packed-weight budget: {live} B live > \
                     {} B budget (pre-pack fewer layers or raise --budget-mb)",
                    self.cfg.packed_budget_bytes
                );
            }
        }
        let mut models = self.models.lock().unwrap();
        models.push((name.to_string(), ModelEntry { shared, worker: Some(worker) }));
        Ok(platform)
    }

    /// Registered model names, in registration order.
    pub fn models(&self) -> Vec<String> {
        self.models.lock().unwrap().iter().map(|(n, _)| n.clone()).collect()
    }

    /// Flattened per-request input length a model expects (`None` for an
    /// unknown model) — what load generators size their images to.
    pub fn input_len(&self, model: &str) -> Option<usize> {
        let models = self.models.lock().unwrap();
        models
            .iter()
            .find(|(n, _)| n == model)
            .map(|(_, e)| e.shared.sample_len.load(Ordering::SeqCst))
    }

    fn shared_for(&self, model: &str) -> Result<Arc<ModelShared>> {
        let models = self.models.lock().unwrap();
        models
            .iter()
            .find(|(n, _)| n == model)
            .map(|(_, e)| e.shared.clone())
            .ok_or_else(|| {
                let known: Vec<String> = models.iter().map(|(n, _)| n.clone()).collect();
                anyhow::anyhow!("unknown model '{model}' (registered: {known:?})")
            })
    }

    /// Submit one image (CHW flattened) to a resident model. Never
    /// blocks on a full queue — admission control resolves overload
    /// immediately by displacement or shedding, and a shed newcomer
    /// still gets an `Ok` ticket that resolves to [`Response::Shed`].
    /// Errors: stopped server (typed [`ServerStopped`]), unknown model,
    /// wrong image length.
    pub fn submit(&self, model: &str, image: Vec<f32>, opts: SubmitOpts) -> Result<Ticket> {
        if self.stopping.load(Ordering::SeqCst) {
            return Err(anyhow::Error::new(ServerStopped));
        }
        let shared = self.shared_for(model)?;
        let sample = shared.sample_len.load(Ordering::SeqCst);
        anyhow::ensure!(
            image.len() == sample,
            "image for '{model}' has {} values, expected {sample}",
            image.len()
        );
        let now = Instant::now();
        let deadline =
            now + opts.deadline.unwrap_or(Duration::from_millis(self.cfg.default_deadline_ms));
        let (reply, rx) = channel();
        let req =
            SchedRequest { image, enqueued: now, deadline, priority: opts.priority, reply };
        let mut st = shared.state.lock().unwrap();
        if st.stopping || st.dead {
            return Err(anyhow::Error::new(ServerStopped));
        }
        shared.gauges.submitted.fetch_add(1, Ordering::Relaxed);
        if st.q.len() >= self.cfg.queue_depth {
            // admission control: displace the weakest queued entry if the
            // newcomer outranks it, else shed the newcomer
            let victim = st
                .q
                .iter()
                .enumerate()
                .min_by_key(|(_, r)| (r.priority, r.deadline))
                .map(|(i, r)| (i, r.priority));
            match victim {
                Some((i, vp)) if vp < req.priority => {
                    let evicted = st.q.remove(i).unwrap();
                    shed_request(&shared, evicted, ShedReason::Displaced, now);
                    st.q.push_back(req);
                }
                _ => {
                    shed_request(&shared, req, ShedReason::QueueFull, now);
                }
            }
        } else {
            st.q.push_back(req);
        }
        shared.gauges.queue_depth.store(st.q.len() as u64, Ordering::Relaxed);
        drop(st);
        shared.arrivals.notify_all();
        Ok(Ticket { rx })
    }

    /// Legacy blocking submit (the single-model [`super::batcher::Server`]
    /// contract): [`Priority::Normal`], effectively no deadline, and a
    /// full queue *blocks* instead of shedding. Errors with
    /// [`ServerStopped`] if the server stops while waiting.
    pub fn submit_blocking(&self, model: &str, image: Vec<f32>) -> Result<Ticket> {
        if self.stopping.load(Ordering::SeqCst) {
            return Err(anyhow::Error::new(ServerStopped));
        }
        let shared = self.shared_for(model)?;
        let sample = shared.sample_len.load(Ordering::SeqCst);
        anyhow::ensure!(
            image.len() == sample,
            "image for '{model}' has {} values, expected {sample}",
            image.len()
        );
        let mut st = shared.state.lock().unwrap();
        loop {
            if st.stopping || st.dead {
                return Err(anyhow::Error::new(ServerStopped));
            }
            if st.q.len() < self.cfg.queue_depth {
                break;
            }
            let (g, _) = shared.space.wait_timeout(st, Duration::from_millis(100)).unwrap();
            st = g;
        }
        let now = Instant::now();
        let (reply, rx) = channel();
        shared.gauges.submitted.fetch_add(1, Ordering::Relaxed);
        st.q.push_back(SchedRequest {
            image,
            enqueued: now,
            deadline: now + Duration::from_secs(3600),
            priority: Priority::Normal,
            reply,
        });
        shared.gauges.queue_depth.store(st.q.len() as u64, Ordering::Relaxed);
        drop(st);
        shared.arrivals.notify_all();
        Ok(Ticket { rx })
    }

    /// Per-model statistics snapshot (`None` for an unknown model).
    pub fn snapshot(&self, model: &str) -> Option<ModelSnapshot> {
        let models = self.models.lock().unwrap();
        let (_, e) = models.iter().find(|(n, _)| n == model)?;
        let g = &e.shared.gauges;
        Some(ModelSnapshot {
            model: model.to_string(),
            submitted: g.submitted.load(Ordering::Relaxed),
            completed: g.completed.load(Ordering::Relaxed),
            shed: g.shed.load(Ordering::Relaxed),
            failed: g.failed.load(Ordering::Relaxed),
            deadline_met: g.deadline_met.load(Ordering::Relaxed),
            queue_depth: g.queue_depth.load(Ordering::Relaxed),
            batches: g.batches.load(Ordering::Relaxed),
            splits: g.splits.load(Ordering::Relaxed),
            ws_peak_bytes: g.ws_peak_bytes.load(Ordering::Relaxed),
            ws_heap_allocs: g.ws_heap_allocs.load(Ordering::Relaxed),
            latency: e.shared.latency.lock().unwrap().clone(),
        })
    }

    /// Stop every model: workers drain their queues (queued requests
    /// execute, their waiters complete), then any stragglers fail with
    /// the typed [`ServerStopped`] error, and all worker threads are
    /// joined. Idempotent; `Drop` calls it too.
    pub fn shutdown(&self) {
        if self.stopping.swap(true, Ordering::SeqCst) {
            return;
        }
        let mut models = self.models.lock().unwrap();
        for (_, entry) in models.iter_mut() {
            {
                let mut st = entry.shared.state.lock().unwrap();
                st.stopping = true;
            }
            entry.shared.arrivals.notify_all();
            entry.shared.space.notify_all();
            if let Some(w) = entry.worker.take() {
                let _ = w.join();
            }
            fail_queue(&entry.shared);
        }
    }
}

impl Drop for MultiServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Ask one model's worker to stop and wake everything waiting on it
/// (used when budget admission rejects a freshly built model).
fn stop_model(shared: &Arc<ModelShared>) {
    let mut st = shared.state.lock().unwrap();
    st.stopping = true;
    drop(st);
    shared.arrivals.notify_all();
    shared.space.notify_all();
}

/// Fail every still-queued request with the typed stopped error and mark
/// the queue dead. Only reachable for requests the (exited) worker never
/// drained — normal shutdown executes the queue first.
fn fail_queue(shared: &Arc<ModelShared>) {
    let mut st = shared.state.lock().unwrap();
    st.dead = true;
    while let Some(r) = st.q.pop_front() {
        let _ = r.reply.send(Err(ReplyErr::Stopped));
    }
    shared.gauges.queue_depth.store(0, Ordering::Relaxed);
    drop(st);
    shared.space.notify_all();
}

/// Resolve one request as shed: bump the gauge and complete its ticket
/// with the typed [`Response::Shed`] outcome.
fn shed_request(shared: &ModelShared, r: SchedRequest, reason: ShedReason, now: Instant) {
    shared.gauges.shed.fetch_add(1, Ordering::Relaxed);
    let _ = r.reply.send(Ok(Response::Shed(Shed {
        model: shared.name.clone(),
        reason,
        priority: r.priority,
        waited_s: now.duration_since(r.enqueued).as_secs_f64(),
    })));
}

/// Shed every queued request whose deadline has passed (most-expired
/// first is implied: they all go). Caller holds the state lock.
fn shed_expired(shared: &ModelShared, st: &mut QueueState, now: Instant) {
    let mut i = 0;
    while i < st.q.len() {
        if st.q[i].deadline <= now {
            let r = st.q.remove(i).unwrap();
            shed_request(shared, r, ShedReason::DeadlineExpired, now);
        } else {
            i += 1;
        }
    }
    shared.gauges.queue_depth.store(st.q.len() as u64, Ordering::Relaxed);
}

fn worker_loop<R: ModelRunner>(exe: R, shared: Arc<ModelShared>, cfg: SchedConfig) {
    // This worker thread occupies one process-wide CoreBudget lane for
    // its whole lifetime (it IS a live thread whether batching or
    // waiting). Intra-op GEMM teams inside `run_with` lease only the
    // lanes that remain, so N resident models × M gemm threads can
    // never oversubscribe the host — `metrics::core_budget()` exposes
    // the peak as proof.
    let _lane = crate::util::par::CoreBudget::lease(1);
    let sample: usize = exe.input_dims()[1..].iter().product();
    let classes = exe.out_classes();
    let max_batch = exe.input_dims()[0].max(1);
    let linger = Duration::from_millis(cfg.linger_ms);
    // One workspace and one padded input buffer for the worker's
    // lifetime: after the first batch warms the pools, steady-state
    // serving checks every buffer out of the arena.
    let mut ws = Workspace::new();
    let mut input = vec![0f32; max_batch * sample];
    let mut logits: Vec<f32> = Vec::new();
    let mut batch: Vec<SchedRequest> = Vec::with_capacity(max_batch);
    // running batch-execution-time estimate for the deadline margin,
    // cold-started from the tuning table's measured ns/call when one is
    // installed (`sfc autotune --out`, schema v4)
    let mut exec_ewma = Duration::from_nanos(seeded_exec_ns(&shared.name, max_batch) as u64);
    loop {
        let mut st = shared.state.lock().unwrap();
        // WAIT: sleep until work arrives (or drain-and-exit on stop)
        loop {
            shed_expired(&shared, &mut st, Instant::now());
            if !st.q.is_empty() {
                break;
            }
            if st.stopping {
                st.dead = true;
                drop(st);
                shared.space.notify_all();
                return;
            }
            let (g, _) = shared.arrivals.wait_timeout(st, Duration::from_millis(20)).unwrap();
            st = g;
        }
        // FORM: linger for stragglers while the earliest deadline and the
        // oldest arrival still allow it
        loop {
            if st.q.len() >= max_batch || st.stopping {
                break;
            }
            shed_expired(&shared, &mut st, Instant::now());
            if st.q.is_empty() {
                break;
            }
            let earliest = st.q.iter().map(|r| r.deadline).min().unwrap();
            let oldest = st.q.iter().map(|r| r.enqueued).min().unwrap();
            let now = Instant::now();
            // fire early enough that execution can still beat the
            // earliest deadline (2x the EWMA leaves copy/complete slack)
            let fire_by = earliest.checked_sub(exec_ewma * 2).unwrap_or(now);
            let wait_until = fire_by.min(oldest + linger);
            if now >= wait_until {
                break;
            }
            let dur = (wait_until - now).min(Duration::from_millis(5));
            let (g, _) = shared.arrivals.wait_timeout(st, dur).unwrap();
            st = g;
        }
        if st.q.is_empty() {
            continue; // everything expired while forming
        }
        // SELECT: earliest deadline first, higher priority breaking ties
        st.q.make_contiguous()
            .sort_by(|a, b| a.deadline.cmp(&b.deadline).then(b.priority.cmp(&a.priority)));
        while batch.len() < max_batch {
            match st.q.pop_front() {
                Some(r) => batch.push(r),
                None => break,
            }
        }
        shared.gauges.queue_depth.store(st.q.len() as u64, Ordering::Relaxed);
        drop(st);
        shared.space.notify_all();
        // EXECUTE: pad + run (the input and logits staging buffers are
        // reused across batches; zero the input tail)
        fill_input(&mut input, &batch, sample);
        let t0 = Instant::now();
        let result = exe.run_with_into(&input, &mut ws, &mut logits);
        exec_ewma = (t0.elapsed() + exec_ewma * 3) / 4;
        shared.gauges.batches.fetch_add(1, Ordering::Relaxed);
        shared.gauges.ws_peak_bytes.store(ws.peak_bytes() as u64, Ordering::Relaxed);
        shared.gauges.ws_heap_allocs.store(ws.heap_allocs(), Ordering::Relaxed);
        // COMPLETE
        complete_batch(&shared, &mut batch, result, &logits, classes);
    }
}

/// Copy each request's image into its batch row and zero the padded
/// tail, so batch composition never changes a row's logits.
fn fill_input(input: &mut [f32], batch: &[SchedRequest], sample: usize) {
    input[batch.len() * sample..].fill(0.0);
    for (i, r) in batch.iter().enumerate() {
        input[i * sample..(i + 1) * sample].copy_from_slice(&r.image);
    }
}

/// Resolve every request in an executed batch: per-row argmax + latency
/// accounting on success, the typed exec error for all waiters on
/// failure. Drains `batch`; `logits` is the batch-major staging buffer.
fn complete_batch(
    shared: &ModelShared,
    batch: &mut Vec<SchedRequest>,
    result: Result<()>,
    logits: &[f32],
    classes: usize,
) {
    match result {
        Ok(()) => {
            let finish = Instant::now();
            let mut hist = shared.latency.lock().unwrap();
            for (i, r) in batch.drain(..).enumerate() {
                let row = logits[i * classes..(i + 1) * classes].to_vec();
                let argmax = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(k, _)| k)
                    .unwrap_or(0);
                let latency_s = finish.duration_since(r.enqueued).as_secs_f64();
                let deadline_met = finish <= r.deadline;
                shared.gauges.completed.fetch_add(1, Ordering::Relaxed);
                if deadline_met {
                    shared.gauges.deadline_met.fetch_add(1, Ordering::Relaxed);
                }
                hist.record(latency_s);
                let _ = r.reply.send(Ok(Response::Done(Completion {
                    logits: row,
                    argmax,
                    latency_s,
                    deadline_met,
                })));
            }
        }
        Err(e) => {
            let msg = format!("execute failed: {e}");
            for r in batch.drain(..) {
                shared.gauges.failed.fetch_add(1, Ordering::Relaxed);
                let _ = r.reply.send(Err(ReplyErr::Exec(msg.clone())));
            }
        }
    }
}

/// Cold-start execution estimate (ns) for one batch of `n` samples:
/// the installed tuning table's measured ns/call scaled by batch size
/// when available ([`crate::engine::tuning::global_exec_ns`]), else a
/// 500 µs default.
fn seeded_exec_ns(model: &str, n: usize) -> f64 {
    crate::engine::tuning::global_exec_ns(model, n).unwrap_or(500_000.0)
}

/// Per-(model, batch-size) predicted execution cost in ns: seeded from
/// the tuning table, refined online with the same 1/4 EWMA the worker
/// path uses for its deadline margin.
struct CostModel {
    model: String,
    /// observed EWMA ns indexed by batch size (slot 0 unused; 0.0 = no
    /// observation yet)
    observed: Vec<f64>,
}

impl CostModel {
    fn new(model: &str, max_batch: usize) -> CostModel {
        CostModel { model: model.to_string(), observed: vec![0.0; max_batch + 1] }
    }

    /// Predicted ns for a batch of `n`: exact observation → nearest
    /// observed batch size linearly scaled → tuning-table seed → 500 µs.
    fn predict_ns(&self, n: usize) -> f64 {
        let n = n.clamp(1, self.observed.len() - 1);
        if self.observed[n] > 0.0 {
            return self.observed[n];
        }
        let nearest = self
            .observed
            .iter()
            .enumerate()
            .skip(1)
            .filter(|(_, &ns)| ns > 0.0)
            .min_by_key(|(b, _)| b.abs_diff(n));
        if let Some((b, &ns)) = nearest {
            return ns * n as f64 / b as f64;
        }
        seeded_exec_ns(&self.model, n)
    }

    /// Predicted execution time for a batch of `n` as a [`Duration`].
    fn predict(&self, n: usize) -> Duration {
        Duration::from_nanos(self.predict_ns(n) as u64)
    }

    /// Fold one measured batch execution into the table.
    fn observe(&mut self, n: usize, elapsed: Duration) {
        let n = n.clamp(1, self.observed.len() - 1);
        let ns = elapsed.as_nanos() as f64;
        let slot = &mut self.observed[n];
        *slot = if *slot > 0.0 { (ns + 3.0 * *slot) / 4.0 } else { ns };
    }
}

/// One model's published candidate batch: what its executor would run
/// if granted a slot right now.
#[derive(Clone, Copy, Debug)]
struct Candidate {
    /// latest instant execution must *start* for the candidate's
    /// earliest deadline to be met (`deadline − predicted`). This is
    /// the cost-aware-EDF key — at any instant, ordering by slack
    /// `deadline − now − predicted` is ordering by `start_by` — and
    /// doubles as the victim threshold for speculative splitting.
    start_by: Instant,
}

struct PlanState {
    /// per-model candidate slot, indexed by [`GlobalPlan::register`]
    /// order; `None` = that model has nothing ready (or is executing)
    candidates: Vec<Option<Candidate>>,
    /// batches currently holding a run slot
    running: usize,
}

/// The shared execution plan for [`DispatchMode::Global`]: candidate
/// batches from every model, the run-slot counter, and the shared
/// workspace pool. See the module docs for the protocol.
struct GlobalPlan {
    state: Mutex<PlanState>,
    /// claim-waiters sleep here; notified on claim/release/retire
    cv: Condvar,
    /// model-affine workspace arenas shared by all executors
    ws_pool: crate::engine::WorkspacePool,
    /// max batches executing concurrently — one run slot per core-budget
    /// lane, so inter-batch and intra-op parallelism share one budget
    limit: usize,
}

impl GlobalPlan {
    fn new() -> GlobalPlan {
        GlobalPlan {
            state: Mutex::new(PlanState { candidates: Vec::new(), running: 0 }),
            cv: Condvar::new(),
            ws_pool: crate::engine::WorkspacePool::new(0),
            limit: crate::util::par::num_threads().max(1),
        }
    }

    /// Allocate a candidate slot for a new model; returns its index.
    fn register(&self) -> usize {
        let mut st = self.state.lock().unwrap();
        st.candidates.push(None);
        st.candidates.len() - 1
    }

    /// Publish `cand` for model `idx` and block until this model may
    /// execute: a run slot is free AND no other published candidate has
    /// an earlier `start_by` (less slack). Returns the earliest
    /// `start_by` still published by a *rival* model if the claim took
    /// the last free slot — the threshold for speculative splitting —
    /// else `None`.
    fn claim(&self, idx: usize, cand: Candidate) -> Option<Instant> {
        let mut st = self.state.lock().unwrap();
        st.candidates[idx] = Some(cand);
        loop {
            if st.running < self.limit {
                let most_urgent = st
                    .candidates
                    .iter()
                    .enumerate()
                    .filter_map(|(i, c)| c.map(|c| (c.start_by, i)))
                    .min()
                    .map(|(_, i)| i);
                if most_urgent == Some(idx) {
                    st.candidates[idx] = None;
                    st.running += 1;
                    let contended = st.running >= self.limit;
                    let victim = st.candidates.iter().filter_map(|c| c.map(|c| c.start_by)).min();
                    drop(st);
                    // the next-most-urgent candidate may now be claimable
                    self.cv.notify_all();
                    return if contended { victim } else { None };
                }
            }
            // timed wait: claims/releases notify, but the timeout also
            // bounds staleness (rival candidates expire, queues drain)
            let (g, _) = self.cv.wait_timeout(st, Duration::from_millis(1)).unwrap();
            st = g;
        }
    }

    /// Return a run slot after execution.
    fn release(&self) {
        let mut st = self.state.lock().unwrap();
        st.running -= 1;
        drop(st);
        self.cv.notify_all();
    }

    /// Clear a model's candidate slot on executor exit so a ghost entry
    /// can never outrank live candidates.
    fn retire(&self, idx: usize) {
        let mut st = self.state.lock().unwrap();
        if idx < st.candidates.len() {
            st.candidates[idx] = None;
        }
        drop(st);
        self.cv.notify_all();
    }
}

/// Per-model executor under [`DispatchMode::Global`]: same WAIT/FORM
/// policy as [`worker_loop`] (so shed/drain semantics are identical),
/// but the fire decision goes through [`GlobalPlan::claim`], execution
/// leases a pooled workspace, and an over-long batch is speculatively
/// split when it would blow a rival candidate's deadline.
fn global_loop<R: ModelRunner>(
    exe: R,
    shared: Arc<ModelShared>,
    cfg: SchedConfig,
    plan: Arc<GlobalPlan>,
    idx: usize,
) {
    let sample: usize = exe.input_dims()[1..].iter().product();
    let classes = exe.out_classes();
    let max_batch = exe.input_dims()[0].max(1);
    let linger = Duration::from_millis(cfg.linger_ms);
    let mut cost = CostModel::new(&shared.name, max_batch);
    let mut input = vec![0f32; max_batch * sample];
    let mut logits: Vec<f32> = Vec::new();
    let mut batch: Vec<SchedRequest> = Vec::with_capacity(max_batch);
    'serve: loop {
        // WAIT + FORM: identical policy to worker_loop, with the cost
        // model supplying the deadline margin
        let cand = {
            let mut st = shared.state.lock().unwrap();
            loop {
                shed_expired(&shared, &mut st, Instant::now());
                if !st.q.is_empty() {
                    break;
                }
                if st.stopping {
                    st.dead = true;
                    drop(st);
                    shared.space.notify_all();
                    plan.retire(idx);
                    return;
                }
                let (g, _) =
                    shared.arrivals.wait_timeout(st, Duration::from_millis(20)).unwrap();
                st = g;
            }
            loop {
                if st.q.len() >= max_batch || st.stopping {
                    break;
                }
                shed_expired(&shared, &mut st, Instant::now());
                if st.q.is_empty() {
                    break;
                }
                let earliest = st.q.iter().map(|r| r.deadline).min().unwrap();
                let oldest = st.q.iter().map(|r| r.enqueued).min().unwrap();
                let now = Instant::now();
                let margin = cost.predict(st.q.len().min(max_batch)) * 2;
                let fire_by = earliest.checked_sub(margin).unwrap_or(now);
                let wait_until = fire_by.min(oldest + linger);
                if now >= wait_until {
                    break;
                }
                let dur = (wait_until - now).min(Duration::from_millis(5));
                let (g, _) = shared.arrivals.wait_timeout(st, dur).unwrap();
                st = g;
            }
            if st.q.is_empty() {
                continue 'serve; // everything expired while forming
            }
            let size = st.q.len().min(max_batch);
            let earliest = st.q.iter().map(|r| r.deadline).min().unwrap();
            let predicted = cost.predict(size);
            Candidate {
                start_by: earliest.checked_sub(predicted).unwrap_or_else(Instant::now),
            }
        };
        // CLAIM: publish the candidate, run when least-slack + slot free
        let victim_start_by = plan.claim(idx, cand);
        // SELECT under the queue lock (the queue may have changed while
        // waiting for the claim — re-shed and re-sort)
        {
            let mut st = shared.state.lock().unwrap();
            let now = Instant::now();
            shed_expired(&shared, &mut st, now);
            if st.q.is_empty() {
                drop(st);
                plan.release();
                continue 'serve;
            }
            st.q.make_contiguous()
                .sort_by(|a, b| a.deadline.cmp(&b.deadline).then(b.priority.cmp(&a.priority)));
            while batch.len() < max_batch {
                match st.q.pop_front() {
                    Some(r) => batch.push(r),
                    None => break,
                }
            }
            // SPLIT: if the plan is contended and running the full batch
            // would hold the slot past the instant the most urgent rival
            // candidate must start, trim to the largest predicted-feasible
            // prefix and requeue the tail at the *front* (it keeps its
            // deadlines, so EDF re-selects it next round — never starved).
            if let Some(start_by) = victim_start_by {
                if batch.len() > 1 && now + cost.predict(batch.len()) > start_by {
                    let feasible =
                        (1..batch.len()).rev().find(|&k| now + cost.predict(k) <= start_by);
                    if let Some(k) = feasible {
                        for r in batch.drain(k..).rev() {
                            st.q.push_front(r);
                        }
                        shared.gauges.splits.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            shared.gauges.queue_depth.store(st.q.len() as u64, Ordering::Relaxed);
        }
        shared.space.notify_all();
        // EXECUTE: lease a model-affine pooled workspace and one core
        // lane; intra-op work jumps the executor pool's FIFO backlog
        let result;
        {
            let _lane = crate::util::par::CoreBudget::lease(1);
            let mut ws = plan.ws_pool.lease(idx);
            fill_input(&mut input, &batch, sample);
            let t0 = Instant::now();
            result =
                crate::util::pool::urgent(|| exe.run_with_into(&input, &mut ws, &mut logits));
            cost.observe(batch.len(), t0.elapsed());
            shared.gauges.batches.fetch_add(1, Ordering::Relaxed);
            shared.gauges.ws_peak_bytes.store(ws.peak_bytes() as u64, Ordering::Relaxed);
            shared.gauges.ws_heap_allocs.store(ws.heap_allocs(), Ordering::Relaxed);
            plan.ws_pool.give(idx, ws);
        }
        plan.release();
        // COMPLETE: identical to the worker path
        complete_batch(&shared, &mut batch, result, &logits, classes);
    }
}
