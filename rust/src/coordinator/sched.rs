//! Multi-model serving scheduler: continuous batching with deadlines,
//! admission control, and load shedding.
//!
//! [`MultiServer`] hosts several resident models (registered by name via
//! [`MultiServer::add_model`], e.g. a float ResNet next to an int8
//! MobileNet). All models share the process-wide
//! [`crate::engine::PlanCache`] and a global packed-weight byte budget
//! ([`SchedConfig::packed_budget_bytes`], enforced against
//! [`crate::engine::packed_weight_bytes`] at registration time); each
//! model gets one worker thread, one long-lived
//! [`crate::engine::Workspace`] and one reusable padded input buffer, so
//! the zero-steady-state-alloc contract of the single-model batcher
//! carries over unchanged.
//!
//! ## Scheduler state machine (per model)
//!
//! ```text
//!             submit(model, image, opts)
//!                     │
//!       queue full?───┼──────────────┐
//!           │no       │yes           │
//!           ▼         ▼              ▼
//!       [QUEUED]   newcomer out-  lowest-priority victim displaced
//!           │      ranks victim?  (typed Response::Shed, Displaced)
//!           │      no → newcomer shed (QueueFull)
//!           ▼
//!   worker: WAIT ──arrival/timeout──▶ FORM ──fire──▶ EXECUTE ──▶ COMPLETE
//!           ▲        (deadline-driven linger)            │
//!           │  expired entries shed (DeadlineExpired)    │
//!           └────────────────────────────────────────────┘
//! ```
//!
//! **Batch formation is deadline-driven, not size-driven.** The worker
//! lingers for stragglers only while it can afford to: it fires as soon
//! as the batch is full, or when `earliest_deadline − 2·exec_ewma` (a
//! running estimate of batch execution time) arrives, or when the oldest
//! request has lingered [`SchedConfig::linger_ms`] — whichever comes
//! first. Requests whose deadline has already passed are shed from the
//! queue (never executed — executing doomed work is how overload turns
//! into collapse), ordered most-expired first.
//!
//! **Admission control** is displacement-based: a full queue sheds the
//! lowest-priority / closest-to-expiry entry to admit a higher-priority
//! newcomer, and sheds the newcomer itself otherwise. Shedding is a
//! first-class outcome — the waiter gets [`Response::Shed`] with a typed
//! [`ShedReason`], not an error string — so load tests can assert *what*
//! was sacrificed, and callers can retry or degrade deliberately.
//!
//! Shutdown drains: queued work is executed, in-flight waiters complete,
//! and only then do late `submit` calls and orphaned tickets fail with
//! the typed [`ServerStopped`] error.

use super::batcher::ModelRunner;
use super::metrics::{ModelGauges, StreamingHistogram};
use crate::engine::Workspace;
use anyhow::Result;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Request priority: under overload, lower priorities are shed first.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Shed first — best-effort traffic.
    Low,
    /// The default tier.
    #[default]
    Normal,
    /// Shed last — displaces queued lower-priority work when the queue
    /// is full.
    High,
}

impl Priority {
    /// Lower-case tier name (for reports).
    pub fn name(self) -> &'static str {
        match self {
            Priority::Low => "low",
            Priority::Normal => "normal",
            Priority::High => "high",
        }
    }
}

/// Per-request scheduling knobs for [`MultiServer::submit`].
#[derive(Clone, Copy, Debug, Default)]
pub struct SubmitOpts {
    /// shed ordering tier (default [`Priority::Normal`])
    pub priority: Priority,
    /// completion deadline measured from submit; `None` uses
    /// [`SchedConfig::default_deadline_ms`]
    pub deadline: Option<Duration>,
}

/// Why a request was shed instead of executed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedReason {
    /// queue full at admission and the newcomer did not outrank any
    /// queued entry
    QueueFull,
    /// evicted from the queue by a higher-priority newcomer
    Displaced,
    /// deadline passed while still queued; executing it would waste a
    /// batch slot on an answer nobody is waiting for
    DeadlineExpired,
}

impl ShedReason {
    /// Snake-case reason name (for reports).
    pub fn name(self) -> &'static str {
        match self {
            ShedReason::QueueFull => "queue_full",
            ShedReason::Displaced => "displaced",
            ShedReason::DeadlineExpired => "expired",
        }
    }
}

/// A shed outcome: the request was sacrificed by admission control or
/// deadline policy, and this records the circumstances.
#[derive(Clone, Debug)]
pub struct Shed {
    /// model the request targeted
    pub model: String,
    /// why it was shed
    pub reason: ShedReason,
    /// priority it carried
    pub priority: Priority,
    /// seconds it waited in the queue before being shed
    pub waited_s: f64,
}

/// A completed inference.
#[derive(Clone, Debug)]
pub struct Completion {
    /// the request's logits row
    pub logits: Vec<f32>,
    /// index of the winning class
    pub argmax: usize,
    /// submit-to-completion latency in seconds
    pub latency_s: f64,
    /// whether completion beat the request's deadline
    pub deadline_met: bool,
}

/// Outcome of one scheduled request: either a completed inference or a
/// typed shed. Shedding is *not* an error — [`Ticket::wait`] returns
/// `Ok(Response::Shed(..))` so callers distinguish policy (shed) from
/// failure (execution error, stopped server).
#[derive(Clone, Debug)]
pub enum Response {
    /// executed; logits attached
    Done(Completion),
    /// sacrificed by admission control or deadline policy
    Shed(Shed),
}

/// Typed error for requests that hit a stopped (or stopping) server:
/// `submit` after shutdown, and tickets orphaned by a dead worker.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServerStopped;

impl std::fmt::Display for ServerStopped {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "server stopped")
    }
}

impl std::error::Error for ServerStopped {}

/// Reply-channel payload error (internal): distinguishes "server went
/// away" from "the batch execution itself failed".
enum ReplyErr {
    Stopped,
    Exec(String),
}

struct SchedRequest {
    image: Vec<f32>,
    enqueued: Instant,
    deadline: Instant,
    priority: Priority,
    reply: Sender<Result<Response, ReplyErr>>,
}

/// Handle for one scheduled request.
pub struct Ticket {
    rx: Receiver<Result<Response, ReplyErr>>,
}

impl Ticket {
    /// Block until the scheduler resolves this request: a completion, a
    /// typed shed, an execution error, or [`ServerStopped`].
    pub fn wait(self) -> Result<Response> {
        match self.rx.recv() {
            Ok(Ok(resp)) => Ok(resp),
            Ok(Err(ReplyErr::Exec(e))) => Err(anyhow::anyhow!(e)),
            Ok(Err(ReplyErr::Stopped)) | Err(_) => Err(anyhow::Error::new(ServerStopped)),
        }
    }
}

/// Scheduler sizing/policy knobs, shared by every resident model.
#[derive(Clone, Copy, Debug)]
pub struct SchedConfig {
    /// per-model bounded queue depth; admission control kicks in beyond it
    pub queue_depth: usize,
    /// deadline applied when [`SubmitOpts::deadline`] is `None`
    pub default_deadline_ms: u64,
    /// max time the oldest queued request lingers waiting for batch
    /// stragglers before a partial batch fires
    pub linger_ms: u64,
    /// global budget for plan-time packed weights
    /// ([`crate::engine::packed_weight_bytes`] across *all* models);
    /// `0` = unlimited. `add_model` fails if registering a model
    /// overruns it.
    pub packed_budget_bytes: u64,
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig {
            queue_depth: 64,
            default_deadline_ms: 50,
            linger_ms: 2,
            packed_budget_bytes: 0,
        }
    }
}

struct QueueState {
    q: VecDeque<SchedRequest>,
    stopping: bool,
    dead: bool,
}

/// State shared between a model's submitters and its worker thread.
struct ModelShared {
    name: String,
    state: Mutex<QueueState>,
    /// worker sleeps here between arrivals
    arrivals: Condvar,
    /// legacy blocking submitters sleep here when the queue is full
    space: Condvar,
    gauges: ModelGauges,
    latency: Mutex<StreamingHistogram>,
    /// per-request flattened sample length (set by the worker from the
    /// runner's dims before it signals ready)
    sample_len: AtomicUsize,
    /// execution batch size (runner dims\[0\])
    max_batch: AtomicUsize,
}

struct ModelEntry {
    shared: Arc<ModelShared>,
    worker: Option<std::thread::JoinHandle<()>>,
}

/// Point-in-time per-model scheduler statistics
/// ([`MultiServer::snapshot`]).
#[derive(Clone, Debug)]
pub struct ModelSnapshot {
    /// model name
    pub model: String,
    /// requests accepted by `submit`
    pub submitted: u64,
    /// requests completed with logits
    pub completed: u64,
    /// requests shed (all [`ShedReason`]s)
    pub shed: u64,
    /// requests whose batch execution failed
    pub failed: u64,
    /// completed requests that beat their deadline
    pub deadline_met: u64,
    /// current queue depth
    pub queue_depth: u64,
    /// batches executed by the worker
    pub batches: u64,
    /// peak bytes checked out of the worker's workspace
    pub ws_peak_bytes: u64,
    /// workspace heap fallbacks (flat after warm-up = zero-alloc)
    pub ws_heap_allocs: u64,
    /// streaming completion-latency histogram (seconds)
    pub latency: StreamingHistogram,
}

/// Multi-model continuous-batching server. See the module docs for the
/// scheduling policy; see [`super::batcher::Server`] for the single-model
/// shim over this type that preserves the original API.
pub struct MultiServer {
    cfg: SchedConfig,
    /// registration-ordered so reports are deterministic
    models: Mutex<Vec<(String, ModelEntry)>>,
    stopping: AtomicBool,
}

impl MultiServer {
    /// An empty server; register models with [`MultiServer::add_model`].
    pub fn new(cfg: SchedConfig) -> MultiServer {
        MultiServer { cfg, models: Mutex::new(Vec::new()), stopping: AtomicBool::new(false) }
    }

    /// The configuration every resident model runs under.
    pub fn config(&self) -> SchedConfig {
        self.cfg
    }

    /// Register a model under `name` and start its worker thread. The
    /// runner is constructed *inside* the worker from `factory` (PJRT
    /// executors are not `Send`); construction errors are returned
    /// synchronously. After a successful build, the global packed-weight
    /// budget is checked: if [`crate::engine::packed_weight_bytes`] now
    /// exceeds [`SchedConfig::packed_budget_bytes`], the worker is torn
    /// down and registration fails — budget admission happens here, at
    /// plan/pack time, not per request. Returns the runner's platform
    /// name.
    pub fn add_model<R, F>(&self, name: &str, factory: F) -> Result<String>
    where
        R: ModelRunner,
        F: FnOnce() -> Result<R> + Send + 'static,
    {
        if self.stopping.load(Ordering::SeqCst) {
            return Err(anyhow::Error::new(ServerStopped));
        }
        {
            let models = self.models.lock().unwrap();
            anyhow::ensure!(
                !models.iter().any(|(n, _)| n == name),
                "model '{name}' is already registered"
            );
        }
        let shared = Arc::new(ModelShared {
            name: name.to_string(),
            state: Mutex::new(QueueState { q: VecDeque::new(), stopping: false, dead: false }),
            arrivals: Condvar::new(),
            space: Condvar::new(),
            gauges: ModelGauges::default(),
            latency: Mutex::new(StreamingHistogram::new()),
            sample_len: AtomicUsize::new(0),
            max_batch: AtomicUsize::new(0),
        });
        let shared2 = shared.clone();
        let cfg = self.cfg;
        let (ready_tx, ready_rx) = channel::<Result<String, String>>();
        let worker = std::thread::Builder::new()
            .name(format!("sfc-sched-{name}"))
            .spawn(move || {
                let exe = match factory() {
                    Ok(e) => {
                        let dims = e.input_dims();
                        shared2
                            .sample_len
                            .store(dims[1..].iter().product(), Ordering::SeqCst);
                        shared2.max_batch.store(dims[0].max(1), Ordering::SeqCst);
                        let _ = ready_tx.send(Ok(e.platform()));
                        e
                    }
                    Err(err) => {
                        let _ = ready_tx.send(Err(format!("{err:#}")));
                        return;
                    }
                };
                worker_loop(exe, shared2, cfg);
            })
            .expect("spawn scheduler worker");
        let platform = match ready_rx.recv() {
            Ok(Ok(platform)) => platform,
            Ok(Err(e)) => {
                let _ = worker.join();
                return Err(anyhow::anyhow!(e));
            }
            Err(_) => return Err(anyhow::anyhow!("worker died during startup")),
        };
        if self.cfg.packed_budget_bytes > 0 {
            let live = crate::engine::packed_weight_bytes();
            if live > self.cfg.packed_budget_bytes {
                stop_model(&shared);
                let _ = worker.join();
                fail_queue(&shared);
                anyhow::bail!(
                    "registering '{name}' overruns the packed-weight budget: {live} B live > \
                     {} B budget (pre-pack fewer layers or raise --budget-mb)",
                    self.cfg.packed_budget_bytes
                );
            }
        }
        let mut models = self.models.lock().unwrap();
        models.push((name.to_string(), ModelEntry { shared, worker: Some(worker) }));
        Ok(platform)
    }

    /// Registered model names, in registration order.
    pub fn models(&self) -> Vec<String> {
        self.models.lock().unwrap().iter().map(|(n, _)| n.clone()).collect()
    }

    /// Flattened per-request input length a model expects (`None` for an
    /// unknown model) — what load generators size their images to.
    pub fn input_len(&self, model: &str) -> Option<usize> {
        let models = self.models.lock().unwrap();
        models
            .iter()
            .find(|(n, _)| n == model)
            .map(|(_, e)| e.shared.sample_len.load(Ordering::SeqCst))
    }

    fn shared_for(&self, model: &str) -> Result<Arc<ModelShared>> {
        let models = self.models.lock().unwrap();
        models
            .iter()
            .find(|(n, _)| n == model)
            .map(|(_, e)| e.shared.clone())
            .ok_or_else(|| {
                let known: Vec<String> = models.iter().map(|(n, _)| n.clone()).collect();
                anyhow::anyhow!("unknown model '{model}' (registered: {known:?})")
            })
    }

    /// Submit one image (CHW flattened) to a resident model. Never
    /// blocks on a full queue — admission control resolves overload
    /// immediately by displacement or shedding, and a shed newcomer
    /// still gets an `Ok` ticket that resolves to [`Response::Shed`].
    /// Errors: stopped server (typed [`ServerStopped`]), unknown model,
    /// wrong image length.
    pub fn submit(&self, model: &str, image: Vec<f32>, opts: SubmitOpts) -> Result<Ticket> {
        if self.stopping.load(Ordering::SeqCst) {
            return Err(anyhow::Error::new(ServerStopped));
        }
        let shared = self.shared_for(model)?;
        let sample = shared.sample_len.load(Ordering::SeqCst);
        anyhow::ensure!(
            image.len() == sample,
            "image for '{model}' has {} values, expected {sample}",
            image.len()
        );
        let now = Instant::now();
        let deadline =
            now + opts.deadline.unwrap_or(Duration::from_millis(self.cfg.default_deadline_ms));
        let (reply, rx) = channel();
        let req =
            SchedRequest { image, enqueued: now, deadline, priority: opts.priority, reply };
        let mut st = shared.state.lock().unwrap();
        if st.stopping || st.dead {
            return Err(anyhow::Error::new(ServerStopped));
        }
        shared.gauges.submitted.fetch_add(1, Ordering::Relaxed);
        if st.q.len() >= self.cfg.queue_depth {
            // admission control: displace the weakest queued entry if the
            // newcomer outranks it, else shed the newcomer
            let victim = st
                .q
                .iter()
                .enumerate()
                .min_by_key(|(_, r)| (r.priority, r.deadline))
                .map(|(i, r)| (i, r.priority));
            match victim {
                Some((i, vp)) if vp < req.priority => {
                    let evicted = st.q.remove(i).unwrap();
                    shed_request(&shared, evicted, ShedReason::Displaced, now);
                    st.q.push_back(req);
                }
                _ => {
                    shed_request(&shared, req, ShedReason::QueueFull, now);
                }
            }
        } else {
            st.q.push_back(req);
        }
        shared.gauges.queue_depth.store(st.q.len() as u64, Ordering::Relaxed);
        drop(st);
        shared.arrivals.notify_all();
        Ok(Ticket { rx })
    }

    /// Legacy blocking submit (the single-model [`super::batcher::Server`]
    /// contract): [`Priority::Normal`], effectively no deadline, and a
    /// full queue *blocks* instead of shedding. Errors with
    /// [`ServerStopped`] if the server stops while waiting.
    pub fn submit_blocking(&self, model: &str, image: Vec<f32>) -> Result<Ticket> {
        if self.stopping.load(Ordering::SeqCst) {
            return Err(anyhow::Error::new(ServerStopped));
        }
        let shared = self.shared_for(model)?;
        let sample = shared.sample_len.load(Ordering::SeqCst);
        anyhow::ensure!(
            image.len() == sample,
            "image for '{model}' has {} values, expected {sample}",
            image.len()
        );
        let mut st = shared.state.lock().unwrap();
        loop {
            if st.stopping || st.dead {
                return Err(anyhow::Error::new(ServerStopped));
            }
            if st.q.len() < self.cfg.queue_depth {
                break;
            }
            let (g, _) = shared.space.wait_timeout(st, Duration::from_millis(100)).unwrap();
            st = g;
        }
        let now = Instant::now();
        let (reply, rx) = channel();
        shared.gauges.submitted.fetch_add(1, Ordering::Relaxed);
        st.q.push_back(SchedRequest {
            image,
            enqueued: now,
            deadline: now + Duration::from_secs(3600),
            priority: Priority::Normal,
            reply,
        });
        shared.gauges.queue_depth.store(st.q.len() as u64, Ordering::Relaxed);
        drop(st);
        shared.arrivals.notify_all();
        Ok(Ticket { rx })
    }

    /// Per-model statistics snapshot (`None` for an unknown model).
    pub fn snapshot(&self, model: &str) -> Option<ModelSnapshot> {
        let models = self.models.lock().unwrap();
        let (_, e) = models.iter().find(|(n, _)| n == model)?;
        let g = &e.shared.gauges;
        Some(ModelSnapshot {
            model: model.to_string(),
            submitted: g.submitted.load(Ordering::Relaxed),
            completed: g.completed.load(Ordering::Relaxed),
            shed: g.shed.load(Ordering::Relaxed),
            failed: g.failed.load(Ordering::Relaxed),
            deadline_met: g.deadline_met.load(Ordering::Relaxed),
            queue_depth: g.queue_depth.load(Ordering::Relaxed),
            batches: g.batches.load(Ordering::Relaxed),
            ws_peak_bytes: g.ws_peak_bytes.load(Ordering::Relaxed),
            ws_heap_allocs: g.ws_heap_allocs.load(Ordering::Relaxed),
            latency: e.shared.latency.lock().unwrap().clone(),
        })
    }

    /// Stop every model: workers drain their queues (queued requests
    /// execute, their waiters complete), then any stragglers fail with
    /// the typed [`ServerStopped`] error, and all worker threads are
    /// joined. Idempotent; `Drop` calls it too.
    pub fn shutdown(&self) {
        if self.stopping.swap(true, Ordering::SeqCst) {
            return;
        }
        let mut models = self.models.lock().unwrap();
        for (_, entry) in models.iter_mut() {
            {
                let mut st = entry.shared.state.lock().unwrap();
                st.stopping = true;
            }
            entry.shared.arrivals.notify_all();
            entry.shared.space.notify_all();
            if let Some(w) = entry.worker.take() {
                let _ = w.join();
            }
            fail_queue(&entry.shared);
        }
    }
}

impl Drop for MultiServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Ask one model's worker to stop and wake everything waiting on it
/// (used when budget admission rejects a freshly built model).
fn stop_model(shared: &Arc<ModelShared>) {
    let mut st = shared.state.lock().unwrap();
    st.stopping = true;
    drop(st);
    shared.arrivals.notify_all();
    shared.space.notify_all();
}

/// Fail every still-queued request with the typed stopped error and mark
/// the queue dead. Only reachable for requests the (exited) worker never
/// drained — normal shutdown executes the queue first.
fn fail_queue(shared: &Arc<ModelShared>) {
    let mut st = shared.state.lock().unwrap();
    st.dead = true;
    while let Some(r) = st.q.pop_front() {
        let _ = r.reply.send(Err(ReplyErr::Stopped));
    }
    shared.gauges.queue_depth.store(0, Ordering::Relaxed);
    drop(st);
    shared.space.notify_all();
}

/// Resolve one request as shed: bump the gauge and complete its ticket
/// with the typed [`Response::Shed`] outcome.
fn shed_request(shared: &ModelShared, r: SchedRequest, reason: ShedReason, now: Instant) {
    shared.gauges.shed.fetch_add(1, Ordering::Relaxed);
    let _ = r.reply.send(Ok(Response::Shed(Shed {
        model: shared.name.clone(),
        reason,
        priority: r.priority,
        waited_s: now.duration_since(r.enqueued).as_secs_f64(),
    })));
}

/// Shed every queued request whose deadline has passed (most-expired
/// first is implied: they all go). Caller holds the state lock.
fn shed_expired(shared: &ModelShared, st: &mut QueueState, now: Instant) {
    let mut i = 0;
    while i < st.q.len() {
        if st.q[i].deadline <= now {
            let r = st.q.remove(i).unwrap();
            shed_request(shared, r, ShedReason::DeadlineExpired, now);
        } else {
            i += 1;
        }
    }
    shared.gauges.queue_depth.store(st.q.len() as u64, Ordering::Relaxed);
}

fn worker_loop<R: ModelRunner>(exe: R, shared: Arc<ModelShared>, cfg: SchedConfig) {
    // This worker thread occupies one process-wide CoreBudget lane for
    // its whole lifetime (it IS a live thread whether batching or
    // waiting). Intra-op GEMM teams inside `run_with` lease only the
    // lanes that remain, so N resident models × M gemm threads can
    // never oversubscribe the host — `metrics::core_budget()` exposes
    // the peak as proof.
    let _lane = crate::util::par::CoreBudget::lease(1);
    let sample: usize = exe.input_dims()[1..].iter().product();
    let classes = exe.out_classes();
    let max_batch = exe.input_dims()[0].max(1);
    let linger = Duration::from_millis(cfg.linger_ms);
    // One workspace and one padded input buffer for the worker's
    // lifetime: after the first batch warms the pools, steady-state
    // serving checks every buffer out of the arena.
    let mut ws = Workspace::new();
    let mut input = vec![0f32; max_batch * sample];
    let mut batch: Vec<SchedRequest> = Vec::with_capacity(max_batch);
    // running batch-execution-time estimate, for the deadline margin
    let mut exec_ewma = Duration::from_micros(500);
    loop {
        let mut st = shared.state.lock().unwrap();
        // WAIT: sleep until work arrives (or drain-and-exit on stop)
        loop {
            shed_expired(&shared, &mut st, Instant::now());
            if !st.q.is_empty() {
                break;
            }
            if st.stopping {
                st.dead = true;
                drop(st);
                shared.space.notify_all();
                return;
            }
            let (g, _) = shared.arrivals.wait_timeout(st, Duration::from_millis(20)).unwrap();
            st = g;
        }
        // FORM: linger for stragglers while the earliest deadline and the
        // oldest arrival still allow it
        loop {
            if st.q.len() >= max_batch || st.stopping {
                break;
            }
            shed_expired(&shared, &mut st, Instant::now());
            if st.q.is_empty() {
                break;
            }
            let earliest = st.q.iter().map(|r| r.deadline).min().unwrap();
            let oldest = st.q.iter().map(|r| r.enqueued).min().unwrap();
            let now = Instant::now();
            // fire early enough that execution can still beat the
            // earliest deadline (2x the EWMA leaves copy/complete slack)
            let fire_by = earliest.checked_sub(exec_ewma * 2).unwrap_or(now);
            let wait_until = fire_by.min(oldest + linger);
            if now >= wait_until {
                break;
            }
            let dur = (wait_until - now).min(Duration::from_millis(5));
            let (g, _) = shared.arrivals.wait_timeout(st, dur).unwrap();
            st = g;
        }
        if st.q.is_empty() {
            continue; // everything expired while forming
        }
        // SELECT: earliest deadline first, higher priority breaking ties
        st.q.make_contiguous()
            .sort_by(|a, b| a.deadline.cmp(&b.deadline).then(b.priority.cmp(&a.priority)));
        while batch.len() < max_batch {
            match st.q.pop_front() {
                Some(r) => batch.push(r),
                None => break,
            }
        }
        shared.gauges.queue_depth.store(st.q.len() as u64, Ordering::Relaxed);
        drop(st);
        shared.space.notify_all();
        // EXECUTE: pad + run (the input buffer is reused; zero the tail)
        input[batch.len() * sample..].fill(0.0);
        for (i, r) in batch.iter().enumerate() {
            input[i * sample..(i + 1) * sample].copy_from_slice(&r.image);
        }
        let t0 = Instant::now();
        let result = exe.run_with(&input, &mut ws);
        exec_ewma = (t0.elapsed() + exec_ewma * 3) / 4;
        shared.gauges.batches.fetch_add(1, Ordering::Relaxed);
        shared.gauges.ws_peak_bytes.store(ws.peak_bytes() as u64, Ordering::Relaxed);
        shared.gauges.ws_heap_allocs.store(ws.heap_allocs(), Ordering::Relaxed);
        // COMPLETE
        match result {
            Ok(logits) => {
                let finish = Instant::now();
                let mut hist = shared.latency.lock().unwrap();
                for (i, r) in batch.drain(..).enumerate() {
                    let row = logits[i * classes..(i + 1) * classes].to_vec();
                    let argmax = row
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(k, _)| k)
                        .unwrap_or(0);
                    let latency_s = finish.duration_since(r.enqueued).as_secs_f64();
                    let deadline_met = finish <= r.deadline;
                    shared.gauges.completed.fetch_add(1, Ordering::Relaxed);
                    if deadline_met {
                        shared.gauges.deadline_met.fetch_add(1, Ordering::Relaxed);
                    }
                    hist.record(latency_s);
                    let _ = r.reply.send(Ok(Response::Done(Completion {
                        logits: row,
                        argmax,
                        latency_s,
                        deadline_met,
                    })));
                }
            }
            Err(e) => {
                let msg = format!("execute failed: {e}");
                for r in batch.drain(..) {
                    shared.gauges.failed.fetch_add(1, Ordering::Relaxed);
                    let _ = r.reply.send(Err(ReplyErr::Exec(msg.clone())));
                }
            }
        }
    }
}
