//! §5 numerical-error analysis harness (Table 1, Fig. 5 substrate).
//!
//! Measures the output MSE of each fast-convolution algorithm when the
//! element-wise multiply operands are rounded to a low-precision format
//! (fp16 as in Table 1, or intN to match the PTQ setting), on random
//! N(0,1) data, normalized so direct convolution = 1.0. Also reports the
//! κ(Aᵀ) condition numbers the analysis predicts the MSE tracks.

use crate::algo::bilinear::{direct_conv2d, Bilinear};
use crate::linalg::Mat;
use crate::util::{round_fp16, Pcg32};

/// Operand rounding applied inside ⊙ (the paper's ⊙_Q).
#[derive(Clone, Copy, Debug)]
pub enum OdotFormat {
    /// IEEE half precision (Table 1's ⊙ format)
    Fp16,
    /// symmetric intN with per-tensor max-abs scaling per trial
    Int(u32),
    /// no rounding (sanity)
    Exact,
}

/// One Table-1 style measurement for a single algorithm.
#[derive(Clone, Debug)]
pub struct ErrorRow {
    /// algorithm name (Table-1 row)
    pub name: String,
    /// output MSE normalized to direct convolution = 1.0
    pub mse: f64,
    /// κ(Aᵀ) condition number of the overlapped output transform
    pub kappa: f64,
    /// multiplication count relative to direct convolution
    pub complexity: f64,
}

/// Measure raw (un-normalized) mean squared output error for `algo` under
/// the given ⊙ format, averaged over `trials` random 2-D tiles.
pub fn measure_mse(algo: &Bilinear, fmt: OdotFormat, trials: usize, seed: u64) -> f64 {
    let mut rng = Pcg32::seeded(seed);
    let l = algo.input_len();
    let r = algo.r;
    let mut total = 0.0f64;
    let mut count = 0usize;
    for _ in 0..trials {
        let x = Mat::from_vec(l, l, (0..l * l).map(|_| rng.next_gaussian()).collect());
        let f = Mat::from_vec(r, r, (0..r * r).map(|_| rng.next_gaussian() * 0.5).collect());
        let exact = algo.apply2d_f64(&x, &f);
        let quantized = match fmt {
            OdotFormat::Exact => exact.clone(),
            OdotFormat::Fp16 => {
                algo.apply2d_with(&x, &f, &|v| round_fp16(v as f32) as f64, &|v| {
                    round_fp16(v as f32) as f64
                })
            }
            OdotFormat::Int(bits) => {
                // per-trial max-abs scaling of each transformed operand
                // (per-tensor granularity, the Table-1 baseline setting)
                let bt = algo.bt.to_f64();
                let g = algo.g.to_f64();
                let tx = bt.matmul(&x).matmul(&bt.transpose());
                let tf = g.matmul(&f).matmul(&g.transpose());
                let qmax = ((1i64 << (bits - 1)) - 1) as f64;
                let sx = tx.data.iter().fold(0.0f64, |m, v| m.max(v.abs())) / qmax;
                let sf = tf.data.iter().fold(0.0f64, |m, v| m.max(v.abs())) / qmax;
                let quant = move |s: f64| move |v: f64| (v / s).round().clamp(-qmax, qmax) * s;
                algo.apply2d_with(&x, &f, &quant(sx.max(1e-30)), &quant(sf.max(1e-30)))
            }
        };
        // reference: the true convolution (catches algorithm error too)
        let truth = direct_conv2d(&x, &f);
        for i in 0..algo.m {
            for j in 0..algo.m {
                let d = quantized[(i, j)] - truth[(i, j)];
                total += d * d;
                count += 1;
            }
        }
        let _ = exact;
    }
    total / count as f64
}

/// Produce the full Table-1 row set: MSE normalized to direct conv = 1.0,
/// κ(Aᵀ) and arithmetic complexity.
pub fn table1(fmt: OdotFormat, trials: usize) -> Vec<ErrorRow> {
    let specs = crate::algo::catalog();
    let direct_mse = {
        let d = Bilinear::direct(3);
        measure_mse(&d, fmt, trials, 0xD1EC7)
    };
    specs
        .iter()
        .filter_map(|spec| {
            // Table 1 covers the bilinear rows; the FFT/NTT catalog
            // baselines have no (G, Bᵀ, Aᵀ) error model here.
            let a = spec.bilinear()?.balanced();
            // fp16 measurement uses the range-balanced presentation (see
            // Bilinear::balanced); κ and complexity are scale-invariant.
            let mse = measure_mse(&a, fmt, trials, 0xD1EC7) / direct_mse;
            Some(ErrorRow {
                name: spec.name.to_string(),
                mse,
                kappa: a.kappa_at(),
                complexity: a.complexity_2d(),
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{sfc, winograd};

    #[test]
    fn exact_format_has_tiny_error() {
        let a = sfc(6, 6, 3);
        let mse = measure_mse(&a, OdotFormat::Exact, 50, 1);
        assert!(mse < 1e-22, "algorithm itself must be exact: {mse}");
    }

    #[test]
    fn fp16_error_ordering_matches_table1() {
        // direct < Wino(2,3) ≈ SFC < Wino(4,3): the paper's key ordering.
        let t = 400;
        let direct = measure_mse(&Bilinear::direct(3), OdotFormat::Fp16, t, 2);
        let w23 = measure_mse(&winograd(2, 3), OdotFormat::Fp16, t, 2);
        let w43 = measure_mse(&winograd(4, 3), OdotFormat::Fp16, t, 2);
        let s63 = measure_mse(&sfc(6, 6, 3), OdotFormat::Fp16, t, 2);
        assert!(direct < w23, "direct {direct} < wino23 {w23}");
        assert!(w23 < w43, "wino23 {w23} < wino43 {w43}");
        assert!(s63 < w43 / 2.0, "SFC {s63} must be far below Wino(4,3) {w43}");
    }

    #[test]
    fn int8_error_ordering_holds_too() {
        let t = 300;
        let w43 = measure_mse(&winograd(4, 3), OdotFormat::Int(8), t, 3);
        let s73 = measure_mse(&sfc(6, 7, 3), OdotFormat::Int(8), t, 3);
        assert!(s73 < w43, "SFC int8 {s73} < Winograd int8 {w43}");
    }

    #[test]
    fn table1_normalization() {
        let rows = table1(OdotFormat::Fp16, 150);
        assert_eq!(rows.len(), 11);
        let direct = rows.iter().find(|r| r.name == "direct").unwrap();
        assert!((direct.mse - 1.0).abs() < 0.25, "direct row ≈ 1.0, got {}", direct.mse);
        // SFC rows must all be closer to direct than Wino(4,3)
        let w43 = rows.iter().find(|r| r.name == "Wino(4x4,3x3)").unwrap().mse;
        for r in rows.iter().filter(|r| r.name.starts_with("SFC")) {
            assert!(r.mse < w43, "{} mse {} < wino43 {}", r.name, r.mse, w43);
        }
    }
}
