//! Runtime CPU-kernel dispatch and the SIMD microkernels behind the
//! packed-panel GEMM and (de)quantize hot loops.
//!
//! The engine's ⊙-reduction kernels come in three flavours selected
//! once per process by [`active_kernel`]:
//!
//! * **`avx2`** (`x86_64` with AVX2 detected at runtime) — 8-lane f32
//!   and 16-lane int8 microkernels over the packed-B panel layout of
//!   [`crate::linalg::gemm`], plus a vectorized int8 quantizer;
//! * **`neon`** (`aarch64`, where NEON is architectural) — 4-lane
//!   equivalents of the GEMM kernels (the int8 quantizer currently has
//!   only an AVX2 variant; NEON dispatch falls back to scalar there);
//! * **`scalar`** — the portable reference kernels, always compiled and
//!   always correct. `SFC_FORCE_SCALAR=1` pins dispatch here.
//!
//! **Numerics contract.** Every SIMD kernel computes *exactly* the same
//! float sequence as its scalar reference: one accumulator per output
//! element, `k` ascending, separate multiply and add (no FMA
//! contraction, which Rust also never applies to the scalar code), and
//! the int8 path is exact integer arithmetic. SIMD and scalar results
//! are therefore **bit-identical** (0 ULP) — the property tests in
//! `rust/tests/simd.rs` assert exact equality, and the workspace
//! bit-identity suite remains valid under either dispatch arm. The
//! lanes vectorize across *output columns*, not across `k`, which is
//! what makes the no-reassociation guarantee possible. Threading lives
//! *above* this layer: `linalg::gemm` partitions row spans across
//! workers and calls these kernels on sub-problems (each kernel call is
//! single-threaded), and its `kc` blocking calls them over ascending
//! k-ranges that continue each element's add chain from the stored
//! partial sum — both preserve the contract by construction.
//!
//! Dispatch is observable: [`kernel_name`] is reported by
//! `coordinator::metrics`, printed by `sfc serve` and recorded in the
//! BENCH_conv.json `kernel` field; [`set_kernel_override`] lets the
//! bench harness measure the scalar arm from the same process.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Which microkernel family executes the hot loops.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// x86-64 AVX2: 8-lane f32, 16-lane int8 (`_mm256_madd_epi16`)
    Avx2,
    /// AArch64 NEON: 4-lane f32, 8-lane int8 (`vmull_s16`)
    Neon,
    /// portable reference kernels (also the `SFC_FORCE_SCALAR=1` arm)
    Scalar,
}

impl Kernel {
    /// Stable lower-case name (`"avx2" | "neon" | "scalar"`), used in
    /// metrics and the BENCH_conv.json `kernel` field.
    pub fn name(&self) -> &'static str {
        match self {
            Kernel::Avx2 => "avx2",
            Kernel::Neon => "neon",
            Kernel::Scalar => "scalar",
        }
    }
}

/// Detect the best kernel for this process: the `SFC_FORCE_SCALAR=1`
/// env override wins, then runtime CPU-feature detection.
pub fn detect() -> Kernel {
    if std::env::var("SFC_FORCE_SCALAR").map(|v| v == "1").unwrap_or(false) {
        return Kernel::Scalar;
    }
    detect_arch()
}

#[cfg(target_arch = "x86_64")]
fn detect_arch() -> Kernel {
    if std::is_x86_feature_detected!("avx2") {
        Kernel::Avx2
    } else {
        Kernel::Scalar
    }
}

#[cfg(target_arch = "aarch64")]
fn detect_arch() -> Kernel {
    // NEON (ASIMD) is architecturally mandatory on AArch64.
    Kernel::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect_arch() -> Kernel {
    Kernel::Scalar
}

/// 0 = no override; otherwise the forced kernel + 1.
static OVERRIDE: AtomicU8 = AtomicU8::new(0);

/// Can this process actually execute `k`'s microkernels? (`Scalar`
/// always; SIMD arms only on their architecture with the feature
/// present.)
pub fn is_supported(k: Kernel) -> bool {
    match k {
        Kernel::Scalar => true,
        _ => detect_arch() == k,
    }
}

/// Force dispatch to a specific kernel (`None` restores detection).
/// Used by `sfc bench` to measure the scalar arm in-process and by the
/// dispatch tests; takes effect on the next [`active_kernel`] call.
/// Requesting a kernel this CPU cannot execute pins `Scalar` instead —
/// dispatch must never route into microkernels whose instructions the
/// host lacks (that would be undefined behavior reachable from safe
/// code).
pub fn set_kernel_override(k: Option<Kernel>) {
    let v = match k {
        None => 0,
        Some(k) if !is_supported(k) => 3,
        Some(Kernel::Avx2) => 1,
        Some(Kernel::Neon) => 2,
        Some(Kernel::Scalar) => 3,
    };
    OVERRIDE.store(v, Ordering::Relaxed);
}

/// The kernel the dispatched entry points run right now: the
/// [`set_kernel_override`] pin if set, else the one-time [`detect`]
/// result (env + CPUID), cached for the process lifetime.
pub fn active_kernel() -> Kernel {
    match OVERRIDE.load(Ordering::Relaxed) {
        1 => Kernel::Avx2,
        2 => Kernel::Neon,
        3 => Kernel::Scalar,
        _ => {
            static DETECTED: OnceLock<Kernel> = OnceLock::new();
            *DETECTED.get_or_init(detect)
        }
    }
}

/// [`active_kernel`]`().name()` — the metrics / bench spelling.
pub fn kernel_name() -> &'static str {
    active_kernel().name()
}

/// Serializes in-crate unit tests that toggle (or assert) the
/// process-global kernel override — `cargo test` runs tests in threads,
/// and the override is process-wide. Integration tests keep their own
/// lock per binary.
#[cfg(test)]
pub(crate) static TEST_OVERRIDE_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

// ---------------------------------------------------------------------
// Quantize: dst[i] = clamp(round(src[i] / scale), ±qmax) as i8
// ---------------------------------------------------------------------

/// Scalar int8 quantizer — the same formula as
/// [`crate::quant::QParams::quantize`], shared by every spatial
/// quantize loop.
pub fn quantize_i8_slice_scalar(src: &[f32], scale: f32, qmax: i32, dst: &mut [i8]) {
    assert_eq!(src.len(), dst.len());
    for (d, &v) in dst.iter_mut().zip(src) {
        *d = ((v / scale).round() as i32).clamp(-qmax, qmax) as i8;
    }
}

/// Dispatched int8 quantizer: divide, round half-away-from-zero, clamp
/// to ±`qmax`. Bit-identical to [`quantize_i8_slice_scalar`] for finite
/// inputs under every dispatch arm.
pub fn quantize_i8_slice(src: &[f32], scale: f32, qmax: i32, dst: &mut [i8]) {
    assert_eq!(src.len(), dst.len());
    match active_kernel() {
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 => unsafe { avx2::quantize_i8(src, scale, qmax, dst) },
        _ => quantize_i8_slice_scalar(src, scale, qmax, dst),
    }
}

// ---------------------------------------------------------------------
// Requantize: dst[i] = clamp(fixedpoint((acc[i] + bias_q) · m0 · 2^-(31+shift)), lo, hi)
// ---------------------------------------------------------------------

/// The exact fixed-point requantization primitive shared by the scalar
/// and SIMD arms (and by [`crate::quant::Requant::apply`]): multiply
/// the i32 accumulator by the q31 mantissa `m0`, then shift right by
/// `31 + shift` rounding half away from zero — the same rounding
/// convention as [`quantize_i8_slice`]. `31 + shift` must be in
/// `1..=62` (the [`crate::quant::Requant`] constructor guarantees it).
#[inline]
pub fn requant_one(acc: i32, m0: i32, shift: i32) -> i32 {
    debug_assert!((1..=62).contains(&(31 + shift)), "requant shift out of range");
    let prod = acc as i64 * m0 as i64;
    let ts = (31 + shift) as u32;
    let round = 1i64 << (ts - 1);
    let v = if prod >= 0 { (prod + round) >> ts } else { -((-prod + round) >> ts) };
    v as i32
}

/// Scalar int8 requantizer: add the accumulator-scale quantized bias,
/// apply the per-channel fixed-point multiplier ([`requant_one`]) and
/// clamp to `[lo, hi]` (`lo == 0` is the int8-domain fused ReLU).
#[allow(clippy::too_many_arguments)]
pub fn requant_i8_slice_scalar(
    acc: &[i32],
    bias_q: i32,
    m0: i32,
    shift: i32,
    lo: i32,
    hi: i32,
    dst: &mut [i8],
) {
    assert_eq!(acc.len(), dst.len());
    assert!((i8::MIN as i32..=i8::MAX as i32).contains(&lo) && hi <= i8::MAX as i32 && lo <= hi);
    for (d, &a) in dst.iter_mut().zip(acc) {
        *d = requant_one(a.wrapping_add(bias_q), m0, shift).clamp(lo, hi) as i8;
    }
}

/// Dispatched int8 requantizer — the integer output stage of the
/// compiled int8 dataflow (see ENGINE.md §Graph compilation). Bit-
/// identical to [`requant_i8_slice_scalar`] under every dispatch arm
/// (the AVX2 arm computes the same 64-bit products and the same
/// round-half-away-from-zero shift; NEON currently falls back to
/// scalar, like the quantizer).
#[allow(clippy::too_many_arguments)]
pub fn requant_i8_slice(
    acc: &[i32],
    bias_q: i32,
    m0: i32,
    shift: i32,
    lo: i32,
    hi: i32,
    dst: &mut [i8],
) {
    assert_eq!(acc.len(), dst.len());
    assert!((i8::MIN as i32..=i8::MAX as i32).contains(&lo) && hi <= i8::MAX as i32 && lo <= hi);
    match active_kernel() {
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 => unsafe { avx2::requant_i8(acc, bias_q, m0, shift, lo, hi, dst) },
        _ => requant_i8_slice_scalar(acc, bias_q, m0, shift, lo, hi, dst),
    }
}

// ---------------------------------------------------------------------
// AVX2 microkernels (x86_64)
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
pub(crate) mod avx2 {
    //! AVX2 implementations of the packed-panel GEMM microkernels and
    //! the int8 quantizer. Panel layouts are defined in
    //! [`crate::linalg::gemm`] (`pack_b_f32` / `pack_b_i8`). All
    //! functions here require AVX2 at runtime — callers dispatch via
    //! [`super::active_kernel`].

    use std::arch::x86_64::*;

    /// `C[m×n] = A[m×k]·Bᵀ` over the k-range `[l0, l1)` with B in
    /// 8-column packed panels (`[panel][k][8]`). Per-element k-ascending
    /// multiply+add — bit-identical to the scalar packed kernel. The
    /// first k-block (`l0 == 0`) starts accumulators at zero; later
    /// blocks continue each element's add chain from the stored partial
    /// sum (the caller's `kc` macro-loop).
    ///
    /// # Safety
    /// Requires AVX2. Slice bounds are asserted by the dispatching
    /// wrapper in `linalg::gemm`.
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn gemm_packed_f32(
        m: usize,
        n: usize,
        k: usize,
        l0: usize,
        l1: usize,
        a: &[f32],
        bp: &[f32],
        c: &mut [f32],
    ) {
        let npan = n.div_ceil(8);
        for jp in 0..npan {
            let pb = bp.as_ptr().add(jp * k * 8);
            let j0 = jp * 8;
            let lanes = (n - j0).min(8);
            let mut i = 0usize;
            while i + 4 <= m {
                let a0 = a.as_ptr().add(i * k);
                let a1 = a.as_ptr().add((i + 1) * k);
                let a2 = a.as_ptr().add((i + 2) * k);
                let a3 = a.as_ptr().add((i + 3) * k);
                let mut acc0 = load_f32(c, i * n + j0, lanes, l0);
                let mut acc1 = load_f32(c, (i + 1) * n + j0, lanes, l0);
                let mut acc2 = load_f32(c, (i + 2) * n + j0, lanes, l0);
                let mut acc3 = load_f32(c, (i + 3) * n + j0, lanes, l0);
                for l in l0..l1 {
                    let bv = _mm256_loadu_ps(pb.add(l * 8));
                    acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(_mm256_set1_ps(*a0.add(l)), bv));
                    acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(_mm256_set1_ps(*a1.add(l)), bv));
                    acc2 = _mm256_add_ps(acc2, _mm256_mul_ps(_mm256_set1_ps(*a2.add(l)), bv));
                    acc3 = _mm256_add_ps(acc3, _mm256_mul_ps(_mm256_set1_ps(*a3.add(l)), bv));
                }
                store_f32(c, i * n + j0, acc0, lanes);
                store_f32(c, (i + 1) * n + j0, acc1, lanes);
                store_f32(c, (i + 2) * n + j0, acc2, lanes);
                store_f32(c, (i + 3) * n + j0, acc3, lanes);
                i += 4;
            }
            // m-remainder: same microkernel blocking, one row at a time
            while i < m {
                let ar = a.as_ptr().add(i * k);
                let mut acc = load_f32(c, i * n + j0, lanes, l0);
                for l in l0..l1 {
                    let bv = _mm256_loadu_ps(pb.add(l * 8));
                    acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(*ar.add(l)), bv));
                }
                store_f32(c, i * n + j0, acc, lanes);
                i += 1;
            }
        }
    }

    /// Accumulator init for one output row: zero on the first k-block,
    /// else the stored partial sums (tail lanes stay zero — they are
    /// never stored back).
    #[target_feature(enable = "avx2")]
    unsafe fn load_f32(c: &[f32], off: usize, lanes: usize, l0: usize) -> __m256 {
        if l0 == 0 {
            _mm256_setzero_ps()
        } else if lanes == 8 {
            _mm256_loadu_ps(c.as_ptr().add(off))
        } else {
            let mut tmp = [0f32; 8];
            tmp[..lanes].copy_from_slice(&c[off..off + lanes]);
            _mm256_loadu_ps(tmp.as_ptr())
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn store_f32(c: &mut [f32], off: usize, acc: __m256, lanes: usize) {
        if lanes == 8 {
            _mm256_storeu_ps(c.as_mut_ptr().add(off), acc);
        } else {
            let mut tmp = [0f32; 8];
            _mm256_storeu_ps(tmp.as_mut_ptr(), acc);
            c[off..off + lanes].copy_from_slice(&tmp[..lanes]);
        }
    }

    /// Int8 packed GEMM over the pair-range `[p0, p1)`: `C[m×n] (i32) =
    /// A[m×k]·Bᵀ` with B in 8-column panels of interleaved k-pairs
    /// (`[panel][k/2][8][2]`, odd k zero-padded). Exact i32 accumulation
    /// via `_mm256_madd_epi16` (i8 operands ⇒ the pairwise i16 dot
    /// cannot overflow). `p0 > 0` continues from the stored partials.
    ///
    /// # Safety
    /// Requires AVX2. Slice bounds are asserted by the dispatching
    /// wrapper in `linalg::gemm`.
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn gemm_packed_i8_i32(
        m: usize,
        n: usize,
        k: usize,
        p0: usize,
        p1: usize,
        a: &[i8],
        bp: &[i8],
        c: &mut [i32],
    ) {
        let k2 = k.div_ceil(2);
        let npan = n.div_ceil(8);
        for jp in 0..npan {
            let pb = bp.as_ptr().add(jp * k2 * 16);
            let j0 = jp * 8;
            let lanes = (n - j0).min(8);
            let mut i = 0usize;
            while i + 4 <= m {
                let rows = [
                    std::slice::from_raw_parts(a.as_ptr().add(i * k), k),
                    std::slice::from_raw_parts(a.as_ptr().add((i + 1) * k), k),
                    std::slice::from_raw_parts(a.as_ptr().add((i + 2) * k), k),
                    std::slice::from_raw_parts(a.as_ptr().add((i + 3) * k), k),
                ];
                let mut acc = [
                    load_i32(c, i * n + j0, lanes, p0),
                    load_i32(c, (i + 1) * n + j0, lanes, p0),
                    load_i32(c, (i + 2) * n + j0, lanes, p0),
                    load_i32(c, (i + 3) * n + j0, lanes, p0),
                ];
                for l2 in p0..p1 {
                    let b16 =
                        _mm256_cvtepi8_epi16(_mm_loadu_si128(pb.add(l2 * 16) as *const __m128i));
                    for (r, row) in rows.iter().enumerate() {
                        let av = _mm256_set1_epi32(apair(row, l2));
                        acc[r] = _mm256_add_epi32(acc[r], _mm256_madd_epi16(av, b16));
                    }
                }
                for (r, accv) in acc.iter().enumerate() {
                    store_i32(c, (i + r) * n + j0, *accv, lanes);
                }
                i += 4;
            }
            while i < m {
                let row = std::slice::from_raw_parts(a.as_ptr().add(i * k), k);
                let mut acc = load_i32(c, i * n + j0, lanes, p0);
                for l2 in p0..p1 {
                    let b16 =
                        _mm256_cvtepi8_epi16(_mm_loadu_si128(pb.add(l2 * 16) as *const __m128i));
                    let av = _mm256_set1_epi32(apair(row, l2));
                    acc = _mm256_add_epi32(acc, _mm256_madd_epi16(av, b16));
                }
                store_i32(c, i * n + j0, acc, lanes);
                i += 1;
            }
        }
    }

    /// The A-side operand for one k-pair, via the shared tail rule in
    /// `linalg::gemm` (`i8_kpair` zero-pads the odd-k tail, exactly as
    /// `pack_b_i8` does on the B side).
    #[inline(always)]
    fn apair(row: &[i8], l2: usize) -> i32 {
        use crate::linalg::gemm::{i8_kpair, i8_pair_word};
        i8_pair_word(i8_kpair(row, l2))
    }

    /// Accumulator init: zero on the first pair-block, else the stored
    /// partial sums (tail lanes stay zero — never stored back).
    #[target_feature(enable = "avx2")]
    unsafe fn load_i32(c: &[i32], off: usize, lanes: usize, p0: usize) -> __m256i {
        if p0 == 0 {
            _mm256_setzero_si256()
        } else if lanes == 8 {
            _mm256_loadu_si256(c.as_ptr().add(off) as *const __m256i)
        } else {
            let mut tmp = [0i32; 8];
            tmp[..lanes].copy_from_slice(&c[off..off + lanes]);
            _mm256_loadu_si256(tmp.as_ptr() as *const __m256i)
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn store_i32(c: &mut [i32], off: usize, acc: __m256i, lanes: usize) {
        if lanes == 8 {
            _mm256_storeu_si256(c.as_mut_ptr().add(off) as *mut __m256i, acc);
        } else {
            let mut tmp = [0i32; 8];
            _mm256_storeu_si256(tmp.as_mut_ptr() as *mut __m256i, acc);
            c[off..off + lanes].copy_from_slice(&tmp[..lanes]);
        }
    }

    /// Vectorized int8 quantizer: `clamp(round(v / scale), ±qmax)`.
    /// Round is exact half-away-from-zero (trunc + |frac| ≥ ½ step), so
    /// the result matches `f32::round` bit-for-bit on finite inputs.
    ///
    /// # Safety
    /// Requires AVX2. `src.len() == dst.len()` is asserted by the
    /// dispatching wrapper.
    #[target_feature(enable = "avx2")]
    pub unsafe fn quantize_i8(src: &[f32], scale: f32, qmax: i32, dst: &mut [i8]) {
        let n = src.len();
        let vs = _mm256_set1_ps(scale);
        let qf = _mm256_set1_ps(qmax as f32);
        let fix = _mm256_setr_epi32(0, 4, 1, 5, 2, 6, 3, 7);
        let mut i = 0usize;
        while i + 32 <= n {
            let p = src.as_ptr().add(i);
            let q0 = quantize8(p, vs, qf);
            let q1 = quantize8(p.add(8), vs, qf);
            let q2 = quantize8(p.add(16), vs, qf);
            let q3 = quantize8(p.add(24), vs, qf);
            // 4×8 i32 → 32 i8; packs interleaves 128-bit lanes, the
            // permute restores element order
            let p01 = _mm256_packs_epi32(q0, q1);
            let p23 = _mm256_packs_epi32(q2, q3);
            let packed = _mm256_permutevar8x32_epi32(_mm256_packs_epi16(p01, p23), fix);
            _mm256_storeu_si256(dst.as_mut_ptr().add(i) as *mut __m256i, packed);
            i += 32;
        }
        super::quantize_i8_slice_scalar(&src[i..], scale, qmax, &mut dst[i..]);
    }

    /// Vectorized int8 requantizer: per lane, exactly the scalar
    /// sequence of [`super::requant_i8_slice_scalar`] — wrap-add the
    /// quantized bias, 64-bit product with the q31 mantissa, rounding
    /// shift right half-away-from-zero, truncate to i32, clamp — so
    /// SIMD and scalar arms are bit-identical.
    ///
    /// # Safety
    /// Requires AVX2. Slice lengths and clamp bounds are asserted by
    /// the dispatching wrapper.
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn requant_i8(
        acc: &[i32],
        bias_q: i32,
        m0: i32,
        shift: i32,
        lo: i32,
        hi: i32,
        dst: &mut [i8],
    ) {
        let n = acc.len();
        let ts = 31 + shift;
        debug_assert!((1..=62).contains(&ts));
        let vb = _mm256_set1_epi32(bias_q);
        let vm = _mm256_set1_epi32(m0);
        let vround = _mm256_set1_epi64x(1i64 << (ts - 1));
        let vts = _mm_cvtsi32_si128(ts);
        let vlo = _mm256_set1_epi32(lo);
        let vhi = _mm256_set1_epi32(hi);
        let lowmask = _mm256_set1_epi64x(0xffff_ffff);
        let fix = _mm256_setr_epi32(0, 4, 1, 5, 2, 6, 3, 7);
        let mut i = 0usize;
        while i + 32 <= n {
            let p = acc.as_ptr().add(i);
            let q0 = requant8(p, vb, vm, vround, vts, vlo, vhi, lowmask);
            let q1 = requant8(p.add(8), vb, vm, vround, vts, vlo, vhi, lowmask);
            let q2 = requant8(p.add(16), vb, vm, vround, vts, vlo, vhi, lowmask);
            let q3 = requant8(p.add(24), vb, vm, vround, vts, vlo, vhi, lowmask);
            // clamped to [lo, hi] ⊆ i8 range ⇒ the saturating packs are inert
            let p01 = _mm256_packs_epi32(q0, q1);
            let p23 = _mm256_packs_epi32(q2, q3);
            let packed = _mm256_permutevar8x32_epi32(_mm256_packs_epi16(p01, p23), fix);
            _mm256_storeu_si256(dst.as_mut_ptr().add(i) as *mut __m256i, packed);
            i += 32;
        }
        super::requant_i8_slice_scalar(&acc[i..], bias_q, m0, shift, lo, hi, &mut dst[i..]);
    }

    /// One 8-lane requant step: returns 8 clamped i32 results.
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    unsafe fn requant8(
        p: *const i32,
        vb: __m256i,
        vm: __m256i,
        vround: __m256i,
        vts: __m128i,
        vlo: __m256i,
        vhi: __m256i,
        lowmask: __m256i,
    ) -> __m256i {
        let x = _mm256_add_epi32(_mm256_loadu_si256(p as *const __m256i), vb);
        // 64-bit products of the even / odd i32 lanes (sign-extended)
        let pe = _mm256_mul_epi32(x, vm);
        let po = _mm256_mul_epi32(_mm256_srli_epi64(x, 32), vm);
        let re = rshift_round_i64(pe, vround, vts);
        let ro = rshift_round_i64(po, vround, vts);
        // interleave the truncated low-32 results back into lane order
        let comb = _mm256_or_si256(_mm256_and_si256(re, lowmask), _mm256_slli_epi64(ro, 32));
        _mm256_min_epi32(_mm256_max_epi32(comb, vlo), vhi)
    }

    /// 4×i64 rounding shift right, half away from zero (the scalar
    /// `±((|p| + round) >> ts)` sequence, lane-parallel).
    #[target_feature(enable = "avx2")]
    unsafe fn rshift_round_i64(p: __m256i, vround: __m256i, vts: __m128i) -> __m256i {
        let zero = _mm256_setzero_si256();
        let isneg = _mm256_cmpgt_epi64(zero, p);
        let absp = _mm256_blendv_epi8(p, _mm256_sub_epi64(zero, p), isneg);
        let r = _mm256_srl_epi64(_mm256_add_epi64(absp, vround), vts);
        _mm256_blendv_epi8(r, _mm256_sub_epi64(zero, r), isneg)
    }

    /// One 8-lane quantize step: divide, round half-away-from-zero
    /// (trunc + step when the exactly-representable fraction reaches
    /// 0.5), clamp to ±qmax, convert (integral input ⇒ exact).
    #[target_feature(enable = "avx2")]
    unsafe fn quantize8(p: *const f32, vs: __m256, qf: __m256) -> __m256i {
        let half = _mm256_set1_ps(0.5);
        let one = _mm256_set1_ps(1.0);
        let sign = _mm256_set1_ps(-0.0);
        let d = _mm256_div_ps(_mm256_loadu_ps(p), vs);
        let t = _mm256_round_ps::<{ _MM_FROUND_TO_ZERO | _MM_FROUND_NO_EXC }>(d);
        let frac = _mm256_sub_ps(d, t);
        let ge = _mm256_cmp_ps::<_CMP_GE_OQ>(_mm256_andnot_ps(sign, frac), half);
        let step = _mm256_and_ps(_mm256_or_ps(one, _mm256_and_ps(d, sign)), ge);
        let r = _mm256_add_ps(t, step);
        let nqf = _mm256_sub_ps(_mm256_setzero_ps(), qf);
        _mm256_cvtps_epi32(_mm256_max_ps(_mm256_min_ps(r, qf), nqf))
    }
}

// ---------------------------------------------------------------------
// NEON microkernels (aarch64)
// ---------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
pub(crate) mod neon {
    //! NEON implementations of the packed-panel GEMM microkernels.
    //! Same panel layouts and the same exact numerics contract as the
    //! AVX2 module (separate multiply/add, k ascending, one accumulator
    //! per output element). NEON is architecturally mandatory on
    //! AArch64, so these are plain `unsafe fn`s without a
    //! `target_feature` gate.

    use std::arch::aarch64::*;

    /// Packed f32 GEMM over the k-range `[l0, l1)` (see the AVX2 twin
    /// for the layout and k-block continuation contract).
    ///
    /// # Safety
    /// Slice bounds are asserted by the dispatching wrapper.
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn gemm_packed_f32(
        m: usize,
        n: usize,
        k: usize,
        l0: usize,
        l1: usize,
        a: &[f32],
        bp: &[f32],
        c: &mut [f32],
    ) {
        let npan = n.div_ceil(8);
        for jp in 0..npan {
            let pb = bp.as_ptr().add(jp * k * 8);
            let j0 = jp * 8;
            let lanes = (n - j0).min(8);
            for i in 0..m {
                let ar = a.as_ptr().add(i * k);
                // acc init: zero on the first k-block, stored partials
                // after (tail lanes stay zero — never stored back)
                let mut tmp = [0f32; 8];
                if l0 > 0 {
                    tmp[..lanes].copy_from_slice(&c[i * n + j0..i * n + j0 + lanes]);
                }
                let mut acc0 = vld1q_f32(tmp.as_ptr());
                let mut acc1 = vld1q_f32(tmp.as_ptr().add(4));
                for l in l0..l1 {
                    let av = vdupq_n_f32(*ar.add(l));
                    let b0 = vld1q_f32(pb.add(l * 8));
                    let b1 = vld1q_f32(pb.add(l * 8 + 4));
                    acc0 = vaddq_f32(acc0, vmulq_f32(av, b0));
                    acc1 = vaddq_f32(acc1, vmulq_f32(av, b1));
                }
                vst1q_f32(tmp.as_mut_ptr(), acc0);
                vst1q_f32(tmp.as_mut_ptr().add(4), acc1);
                c[i * n + j0..i * n + j0 + lanes].copy_from_slice(&tmp[..lanes]);
            }
        }
    }

    /// Packed int8 GEMM over the pair-range `[p0, p1)` with exact i32
    /// accumulation (see the AVX2 twin for the interleaved k-pair
    /// layout and the pair-block continuation contract).
    ///
    /// # Safety
    /// Slice bounds are asserted by the dispatching wrapper.
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn gemm_packed_i8_i32(
        m: usize,
        n: usize,
        k: usize,
        p0: usize,
        p1: usize,
        a: &[i8],
        bp: &[i8],
        c: &mut [i32],
    ) {
        use crate::linalg::gemm::{i8_kpair, i8_pair_word};
        let k2 = k.div_ceil(2);
        let npan = n.div_ceil(8);
        for jp in 0..npan {
            let pb = bp.as_ptr().add(jp * k2 * 16);
            let j0 = jp * 8;
            let lanes = (n - j0).min(8);
            for i in 0..m {
                let row = std::slice::from_raw_parts(a.as_ptr().add(i * k), k);
                // acc init: zero on the first pair-block, stored
                // partials after (tail lanes stay zero)
                let mut tmp = [0i32; 8];
                if p0 > 0 {
                    tmp[..lanes].copy_from_slice(&c[i * n + j0..i * n + j0 + lanes]);
                }
                let mut acc_lo = vld1q_s32(tmp.as_ptr()); // columns j0..j0+4
                let mut acc_hi = vld1q_s32(tmp.as_ptr().add(4)); // columns j0+4..j0+8
                for l2 in p0..p1 {
                    // shared odd-k tail rule (matches pack_b_i8)
                    let pair = i8_pair_word(i8_kpair(row, l2));
                    let apair = vreinterpretq_s16_s32(vdupq_n_s32(pair));
                    let b = vld1q_s8(pb.add(l2 * 16));
                    let blo = vmovl_s8(vget_low_s8(b)); // cols j0..j0+4, pairs
                    let bhi = vmovl_s8(vget_high_s8(b));
                    let q0 = vmull_s16(vget_low_s16(blo), vget_low_s16(apair));
                    let q1 = vmull_s16(vget_high_s16(blo), vget_high_s16(apair));
                    acc_lo = vaddq_s32(acc_lo, vpaddq_s32(q0, q1));
                    let q2 = vmull_s16(vget_low_s16(bhi), vget_low_s16(apair));
                    let q3 = vmull_s16(vget_high_s16(bhi), vget_high_s16(apair));
                    acc_hi = vaddq_s32(acc_hi, vpaddq_s32(q2, q3));
                }
                vst1q_s32(tmp.as_mut_ptr(), acc_lo);
                vst1q_s32(tmp.as_mut_ptr().add(4), acc_hi);
                c[i * n + j0..i * n + j0 + lanes].copy_from_slice(&tmp[..lanes]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_names_are_stable() {
        assert_eq!(Kernel::Avx2.name(), "avx2");
        assert_eq!(Kernel::Neon.name(), "neon");
        assert_eq!(Kernel::Scalar.name(), "scalar");
    }

    #[test]
    fn env_force_scalar_is_honored_by_detection() {
        let _g = TEST_OVERRIDE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        // When the suite runs under SFC_FORCE_SCALAR=1 (the CI scalar
        // arm), detection — and therefore dispatch — must pin scalar.
        if std::env::var("SFC_FORCE_SCALAR").map(|v| v == "1").unwrap_or(false) {
            assert_eq!(detect(), Kernel::Scalar);
            assert_eq!(active_kernel(), Kernel::Scalar);
        }
    }

    #[test]
    fn quantize_matches_qparams_formula() {
        let src: Vec<f32> = (0..100).map(|i| (i as f32 - 50.0) * 0.37).collect();
        let mut a = vec![0i8; src.len()];
        let mut b = vec![0i8; src.len()];
        quantize_i8_slice(&src, 0.21, 127, &mut a);
        quantize_i8_slice_scalar(&src, 0.21, 127, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn requant_one_rounds_half_away_from_zero() {
        // m0 = 2^30, shift = 0 → multiply by exactly 0.5
        let (m0, shift) = (1i32 << 30, 0);
        assert_eq!(requant_one(4, m0, shift), 2);
        assert_eq!(requant_one(3, m0, shift), 2, "1.5 rounds away from zero");
        assert_eq!(requant_one(-3, m0, shift), -2, "-1.5 rounds away from zero");
        assert_eq!(requant_one(-4, m0, shift), -2);
        assert_eq!(requant_one(0, m0, shift), 0);
    }

    #[test]
    fn requant_slice_simd_bit_identical_to_scalar() {
        // odd length exercises every remainder lane; values span signs
        // and magnitudes around the clamp bounds
        let acc: Vec<i32> = (0..1001i64)
            .map(|i| (i * 2654435761 % 600_000_007 - 300_000_000) as i32)
            .collect();
        for (m0, shift, bias_q, lo) in
            [(1_687_194_767i32, 12, 17, -127), (1_073_741_824, 0, -5, 0), (2_000_000_011, 25, 0, 0)]
        {
            let mut a = vec![0i8; acc.len()];
            let mut b = vec![0i8; acc.len()];
            requant_i8_slice(&acc, bias_q, m0, shift, lo, 127, &mut a);
            requant_i8_slice_scalar(&acc, bias_q, m0, shift, lo, 127, &mut b);
            assert_eq!(a, b, "m0 {m0} shift {shift}");
        }
    }
}
