//! Dense row-major matrices: exact (`FracMat`) and floating (`Mat`).

use super::Frac;
use std::fmt;

/// Exact rational dense matrix, row-major.
#[derive(Clone, PartialEq, Eq)]
pub struct FracMat {
    /// row count
    pub rows: usize,
    /// column count
    pub cols: usize,
    /// row-major entries
    pub data: Vec<Frac>,
}

impl FracMat {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        FracMat { rows, cols, data: vec![Frac::ZERO; rows * cols] }
    }

    /// The n×n identity.
    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = Frac::ONE;
        }
        m
    }

    /// Matrix from row-major integer entries.
    pub fn from_i128(rows: usize, cols: usize, vals: &[i128]) -> Self {
        assert_eq!(vals.len(), rows * cols);
        FracMat { rows, cols, data: vals.iter().map(|&v| Frac::int(v)).collect() }
    }

    /// One row as a slice.
    pub fn row(&self, r: usize) -> &[Frac] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Exact matrix product.
    pub fn matmul(&self, other: &FracMat) -> FracMat {
        assert_eq!(self.cols, other.rows, "dim mismatch {}x{} * {}x{}", self.rows, self.cols, other.rows, other.cols);
        let mut out = FracMat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a.is_zero() {
                    continue;
                }
                for j in 0..other.cols {
                    let b = other[(k, j)];
                    if !b.is_zero() {
                        out[(i, j)] += a * b;
                    }
                }
            }
        }
        out
    }

    /// Transposed copy.
    pub fn transpose(&self) -> FracMat {
        let mut out = FracMat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// Exact matrix–vector product.
    pub fn matvec(&self, v: &[Frac]) -> Vec<Frac> {
        assert_eq!(v.len(), self.cols);
        (0..self.rows)
            .map(|i| {
                let mut acc = Frac::ZERO;
                for j in 0..self.cols {
                    if !self[(i, j)].is_zero() && !v[j].is_zero() {
                        acc += self[(i, j)] * v[j];
                    }
                }
                acc
            })
            .collect()
    }

    /// Lower every entry to f64.
    pub fn to_f64(&self) -> Mat {
        Mat { rows: self.rows, cols: self.cols, data: self.data.iter().map(|f| f.to_f64()).collect() }
    }

    /// Lower every entry to f32, row-major.
    pub fn to_f32_vec(&self) -> Vec<f32> {
        self.data.iter().map(|f| f.to_f64() as f32).collect()
    }

    /// True if every entry is an integer.
    pub fn is_integral(&self) -> bool {
        self.data.iter().all(|f| f.is_integer())
    }

    /// Least common multiple of all denominators.
    pub fn den_lcm(&self) -> i128 {
        let mut l: i128 = 1;
        for f in &self.data {
            let g = {
                let (mut a, mut b) = (l, f.den);
                while b != 0 {
                    let t = a % b;
                    a = b;
                    b = t;
                }
                a
            };
            l = l / g * f.den;
        }
        l
    }

    /// Multiply every entry by an integer scalar.
    pub fn scale_int(&self, s: i128) -> FracMat {
        let mut out = self.clone();
        for f in out.data.iter_mut() {
            *f = *f * Frac::int(s);
        }
        out
    }

    /// Number of addition/subtraction ops to apply this matrix to a vector
    /// (nonzeros minus nonzero rows; ±1 entries need no multiplies). Used by
    /// the BOPs model for transform cost.
    pub fn add_count(&self) -> usize {
        let mut adds = 0;
        for i in 0..self.rows {
            let nnz = self.row(i).iter().filter(|f| !f.is_zero()).count();
            adds += nnz.saturating_sub(1);
        }
        adds
    }

    /// Max absolute row sum (L_inf operator norm) — bounds bit growth of the
    /// transform when applied to integer data.
    pub fn linf_norm(&self) -> f64 {
        (0..self.rows)
            .map(|i| self.row(i).iter().map(|f| f.to_f64().abs()).sum::<f64>())
            .fold(0.0, f64::max)
    }

    /// Exact inverse via Gauss–Jordan elimination with partial pivoting.
    /// Returns None if the matrix is singular.
    pub fn inverse(&self) -> Option<FracMat> {
        assert_eq!(self.rows, self.cols, "inverse of non-square matrix");
        let n = self.rows;
        let mut a = self.clone();
        let mut inv = FracMat::identity(n);
        for col in 0..n {
            // pivot: any nonzero entry (exact arithmetic, no scaling concern)
            let pivot = (col..n).find(|&r| !a[(r, col)].is_zero())?;
            if pivot != col {
                for j in 0..n {
                    let (x, y) = (a[(pivot, j)], a[(col, j)]);
                    a[(pivot, j)] = y;
                    a[(col, j)] = x;
                    let (x, y) = (inv[(pivot, j)], inv[(col, j)]);
                    inv[(pivot, j)] = y;
                    inv[(col, j)] = x;
                }
            }
            let p = a[(col, col)].recip();
            for j in 0..n {
                a[(col, j)] = a[(col, j)] * p;
                inv[(col, j)] = inv[(col, j)] * p;
            }
            for r in 0..n {
                if r != col && !a[(r, col)].is_zero() {
                    let factor = a[(r, col)];
                    for j in 0..n {
                        let s = a[(col, j)] * factor;
                        a[(r, j)] = a[(r, j)] - s;
                        let s = inv[(col, j)] * factor;
                        inv[(r, j)] = inv[(r, j)] - s;
                    }
                }
            }
        }
        Some(inv)
    }
}

impl std::ops::Index<(usize, usize)> for FracMat {
    type Output = Frac;
    fn index(&self, (r, c): (usize, usize)) -> &Frac {
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for FracMat {
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut Frac {
        &mut self.data[r * self.cols + c]
    }
}

impl fmt::Debug for FracMat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "FracMat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows {
            write!(f, "  [")?;
            for j in 0..self.cols {
                write!(f, "{:>6}", format!("{:?}", self[(i, j)]))?;
                if j + 1 < self.cols {
                    write!(f, ", ")?;
                }
            }
            writeln!(f, "]")?;
        }
        write!(f, "]")
    }
}

/// f64 dense matrix, row-major.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    /// row count
    pub rows: usize,
    /// column count
    pub cols: usize,
    /// row-major entries
    pub data: Vec<f64>,
}

impl Mat {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix from row-major entries.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    /// One row as a slice.
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Matrix product.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows);
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.data[i * self.cols + k];
                if a == 0.0 {
                    continue;
                }
                let orow = &other.data[k * other.cols..(k + 1) * other.cols];
                let dst = &mut out.data[i * other.cols..(i + 1) * other.cols];
                for (d, &b) in dst.iter_mut().zip(orow) {
                    *d += a * b;
                }
            }
        }
        out
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// Matrix–vector product.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(v.len(), self.cols);
        (0..self.rows).map(|i| self.row(i).iter().zip(v).map(|(a, b)| a * b).sum()).collect()
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    fn index(&self, (r, c): (usize, usize)) -> &f64 {
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f64 {
        &mut self.data[r * self.cols + c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frac_matmul_identity() {
        let m = FracMat::from_i128(2, 3, &[1, 2, 3, 4, 5, 6]);
        let i3 = FracMat::identity(3);
        assert_eq!(m.matmul(&i3), m);
    }

    #[test]
    fn frac_matvec() {
        let m = FracMat::from_i128(2, 2, &[1, -1, 2, 0]);
        let v = vec![Frac::int(3), Frac::int(5)];
        assert_eq!(m.matvec(&v), vec![Frac::int(-2), Frac::int(6)]);
    }

    #[test]
    fn add_count_skips_zero_rows() {
        // row [1,1,1] -> 2 adds; row [0,1,0] -> 0 adds
        let m = FracMat::from_i128(2, 3, &[1, 1, 1, 0, 1, 0]);
        assert_eq!(m.add_count(), 2);
    }

    #[test]
    fn den_lcm_and_scale() {
        let m = FracMat {
            rows: 1,
            cols: 3,
            data: vec![Frac::new(1, 2), Frac::new(1, 3), Frac::new(5, 6)],
        };
        assert_eq!(m.den_lcm(), 6);
        assert!(m.scale_int(6).is_integral());
    }

    #[test]
    fn f64_matmul_matches_manual() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn transpose_round_trip() {
        let m = FracMat::from_i128(2, 3, &[1, 2, 3, 4, 5, 6]);
        assert_eq!(m.transpose().transpose(), m);
    }
}
