//! Exact and floating small-matrix linear algebra, plus the blocked GEMM
//! core every conv executor reduces onto.
//!
//! The algorithm constructor (`crate::algo`) builds every transformation
//! matrix over exact rationals so the reproduced SFC / Winograd algorithms
//! are bit-identical to their mathematical definition; condition numbers
//! for Table 1 come from the Jacobi SVD here. [`gemm`] holds the
//! register-tiled `f32` / `i8→i32` kernels shared by im2col, the tiled
//! bilinear fast path and the quantized Eq.-17 datapath.

pub mod frac;
pub mod gemm;
pub mod mat;
pub mod svd;

pub use frac::Frac;
pub use gemm::{gemm_nt_f32, gemm_nt_i8_i32};
pub use mat::{FracMat, Mat};
pub use svd::{condition_number, singular_values};
