//! Exact and floating small-matrix linear algebra, plus the blocked GEMM
//! core every conv executor reduces onto.
//!
//! The algorithm constructor (`crate::algo`) builds every transformation
//! matrix over exact rationals so the reproduced SFC / Winograd algorithms
//! are bit-identical to their mathematical definition; condition numbers
//! for Table 1 come from the Jacobi SVD here. [`gemm`] holds the
//! register-tiled `f32` / `i8→i32` kernels shared by im2col, the tiled
//! bilinear fast path and the quantized Eq.-17 datapath; [`simd`] is the
//! runtime-dispatched kernel layer (AVX2 / NEON / scalar) behind the
//! packed-panel variants those executors actually run.

pub mod frac;
pub mod gemm;
pub mod mat;
pub mod simd;
pub mod svd;

pub use frac::Frac;
pub use gemm::{gemm_nt_f32, gemm_nt_i8_i32, gemm_packed_f32, gemm_packed_i8_i32};
pub use mat::{FracMat, Mat};
pub use simd::{active_kernel, kernel_name, Kernel};
pub use svd::{condition_number, singular_values};
