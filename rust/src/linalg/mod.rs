//! Exact and floating small-matrix linear algebra.
//!
//! The algorithm constructor (`crate::algo`) builds every transformation
//! matrix over exact rationals so the reproduced SFC / Winograd algorithms
//! are bit-identical to their mathematical definition; condition numbers
//! for Table 1 come from the Jacobi SVD here.

pub mod frac;
pub mod mat;
pub mod svd;

pub use frac::Frac;
pub use mat::{FracMat, Mat};
pub use svd::{condition_number, singular_values};
