//! Blocked, register-tiled GEMM kernels — the shared ⊙-reduction core.
//!
//! Every conv executor in this crate ultimately reduces to the same
//! matrix shape: `C[m×n] = A[m×k] · B[n×k]ᵀ` with both operands row-major
//! along `k` (a batch of dot products). That layout is what im2col
//! lowering, the per-frequency channel reduction of tiled Winograd/SFC
//! (`[tiles×Cin]·[Cin×Cout]`, Eq. 1's ⊙ stage) and the quantized Eq.-17
//! datapath all produce, so one pair of kernels serves them all:
//!
//! * [`gemm_nt_f32`] — float path;
//! * [`gemm_nt_i8_i32`] — int8 operands, exact i32 accumulation.
//!
//! The kernels are blocked (`MB×NB` panels keep the B panel hot in L1/L2)
//! and register-tiled (a 4×4 micro-kernel reuses every loaded operand
//! four times). The `k` loop runs in index order inside each micro-tile,
//! so float results are bit-identical to the naive scalar dot product —
//! a property the workspace-reuse tests rely on.

/// Panel height (rows of A per block).
const MB: usize = 64;
/// Panel width (rows of B per block).
const NB: usize = 64;
/// Register tile edge: the micro-kernel computes MR×NR outputs at once.
const MR: usize = 4;
const NR: usize = 4;

/// `C[m×n] = A[m×k] · B[n×k]ᵀ` (all row-major). `C` is overwritten.
pub fn gemm_nt_f32(m: usize, n: usize, k: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert!(a.len() >= m * k, "A too small: {} < {}", a.len(), m * k);
    assert!(b.len() >= n * k, "B too small: {} < {}", b.len(), n * k);
    assert!(c.len() >= m * n, "C too small: {} < {}", c.len(), m * n);
    for i0 in (0..m).step_by(MB) {
        let i1 = (i0 + MB).min(m);
        for j0 in (0..n).step_by(NB) {
            let j1 = (j0 + NB).min(n);
            block_nt_f32(i0, i1, j0, j1, n, k, a, b, c);
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn block_nt_f32(
    i0: usize,
    i1: usize,
    j0: usize,
    j1: usize,
    n: usize,
    k: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
) {
    let mut i = i0;
    while i + MR <= i1 {
        let a0 = &a[i * k..i * k + k];
        let a1 = &a[(i + 1) * k..(i + 1) * k + k];
        let a2 = &a[(i + 2) * k..(i + 2) * k + k];
        let a3 = &a[(i + 3) * k..(i + 3) * k + k];
        let mut j = j0;
        while j + NR <= j1 {
            let b0 = &b[j * k..j * k + k];
            let b1 = &b[(j + 1) * k..(j + 1) * k + k];
            let b2 = &b[(j + 2) * k..(j + 2) * k + k];
            let b3 = &b[(j + 3) * k..(j + 3) * k + k];
            let mut acc = [[0f32; NR]; MR];
            for l in 0..k {
                let av = [a0[l], a1[l], a2[l], a3[l]];
                let bv = [b0[l], b1[l], b2[l], b3[l]];
                for (accr, &avi) in acc.iter_mut().zip(&av) {
                    for (accv, &bvj) in accr.iter_mut().zip(&bv) {
                        *accv += avi * bvj;
                    }
                }
            }
            for (ii, accr) in acc.iter().enumerate() {
                c[(i + ii) * n + j..(i + ii) * n + j + NR].copy_from_slice(accr);
            }
            j += NR;
        }
        while j < j1 {
            let br = &b[j * k..j * k + k];
            for (ii, ar) in [a0, a1, a2, a3].into_iter().enumerate() {
                c[(i + ii) * n + j] = dot_f32(ar, br);
            }
            j += 1;
        }
        i += MR;
    }
    while i < i1 {
        let ar = &a[i * k..i * k + k];
        for j in j0..j1 {
            c[i * n + j] = dot_f32(ar, &b[j * k..j * k + k]);
        }
        i += 1;
    }
}

#[inline]
fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0f32;
    for (x, y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

/// `C[m×n] = A[m×k] · B[n×k]ᵀ` with int8 operands and exact i32
/// accumulation (the Eq.-17 low-precision ⊙ stage). `C` is overwritten.
pub fn gemm_nt_i8_i32(m: usize, n: usize, k: usize, a: &[i8], b: &[i8], c: &mut [i32]) {
    assert!(a.len() >= m * k, "A too small: {} < {}", a.len(), m * k);
    assert!(b.len() >= n * k, "B too small: {} < {}", b.len(), n * k);
    assert!(c.len() >= m * n, "C too small: {} < {}", c.len(), m * n);
    for i0 in (0..m).step_by(MB) {
        let i1 = (i0 + MB).min(m);
        for j0 in (0..n).step_by(NB) {
            let j1 = (j0 + NB).min(n);
            block_nt_i8(i0, i1, j0, j1, n, k, a, b, c);
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn block_nt_i8(
    i0: usize,
    i1: usize,
    j0: usize,
    j1: usize,
    n: usize,
    k: usize,
    a: &[i8],
    b: &[i8],
    c: &mut [i32],
) {
    let mut i = i0;
    while i + MR <= i1 {
        let a0 = &a[i * k..i * k + k];
        let a1 = &a[(i + 1) * k..(i + 1) * k + k];
        let a2 = &a[(i + 2) * k..(i + 2) * k + k];
        let a3 = &a[(i + 3) * k..(i + 3) * k + k];
        let mut j = j0;
        while j + NR <= j1 {
            let b0 = &b[j * k..j * k + k];
            let b1 = &b[(j + 1) * k..(j + 1) * k + k];
            let b2 = &b[(j + 2) * k..(j + 2) * k + k];
            let b3 = &b[(j + 3) * k..(j + 3) * k + k];
            let mut acc = [[0i32; NR]; MR];
            for l in 0..k {
                let av = [a0[l] as i32, a1[l] as i32, a2[l] as i32, a3[l] as i32];
                let bv = [b0[l] as i32, b1[l] as i32, b2[l] as i32, b3[l] as i32];
                for (accr, &avi) in acc.iter_mut().zip(&av) {
                    for (accv, &bvj) in accr.iter_mut().zip(&bv) {
                        *accv += avi * bvj;
                    }
                }
            }
            for (ii, accr) in acc.iter().enumerate() {
                c[(i + ii) * n + j..(i + ii) * n + j + NR].copy_from_slice(accr);
            }
            j += NR;
        }
        while j < j1 {
            let br = &b[j * k..j * k + k];
            for (ii, ar) in [a0, a1, a2, a3].into_iter().enumerate() {
                c[(i + ii) * n + j] = dot_i8(ar, br);
            }
            j += 1;
        }
        i += MR;
    }
    while i < i1 {
        let ar = &a[i * k..i * k + k];
        for j in j0..j1 {
            c[i * n + j] = dot_i8(ar, &b[j * k..j * k + k]);
        }
        i += 1;
    }
}

#[inline]
fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    let mut acc = 0i32;
    for (x, y) in a.iter().zip(b) {
        acc += (*x as i32) * (*y as i32);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn naive_f32(m: usize, n: usize, k: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0f32;
                for l in 0..k {
                    acc += a[i * k + l] * b[j * k + l];
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    #[test]
    fn matches_naive_bitwise_over_shapes() {
        let mut rng = Pcg32::seeded(5);
        // edge sizes crossing every tile/block boundary
        for (m, n, k) in [
            (1usize, 1usize, 1usize),
            (3, 5, 7),
            (4, 4, 16),
            (5, 9, 3),
            (17, 13, 21),
            (64, 64, 8),
            (65, 67, 33),
            (130, 70, 100),
        ] {
            let mut a = vec![0f32; m * k];
            let mut b = vec![0f32; n * k];
            rng.fill_gaussian(&mut a, 1.0);
            rng.fill_gaussian(&mut b, 1.0);
            let want = naive_f32(m, n, k, &a, &b);
            let mut got = vec![7f32; m * n]; // poison: C must be overwritten
            gemm_nt_f32(m, n, k, &a, &b, &mut got);
            assert_eq!(got, want, "m{m} n{n} k{k} must be bit-identical to scalar order");
        }
    }

    #[test]
    fn zero_k_zeroes_c() {
        let mut c = vec![3f32; 6];
        gemm_nt_f32(2, 3, 0, &[], &[], &mut c);
        assert_eq!(c, vec![0f32; 6]);
    }

    #[test]
    fn i8_matches_naive_exactly() {
        let mut rng = Pcg32::seeded(6);
        for (m, n, k) in [(1usize, 3usize, 4usize), (6, 6, 6), (19, 11, 35), (70, 66, 9)] {
            let a: Vec<i8> = (0..m * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            let b: Vec<i8> = (0..n * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            let mut want = vec![0i32; m * n];
            for i in 0..m {
                for j in 0..n {
                    let mut acc = 0i32;
                    for l in 0..k {
                        acc += a[i * k + l] as i32 * b[j * k + l] as i32;
                    }
                    want[i * n + j] = acc;
                }
            }
            let mut got = vec![-1i32; m * n];
            gemm_nt_i8_i32(m, n, k, &a, &b, &mut got);
            assert_eq!(got, want, "m{m} n{n} k{k}");
        }
    }
}
