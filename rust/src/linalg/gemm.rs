//! Blocked, register-tiled GEMM kernels — the shared ⊙-reduction core.
//!
//! Every conv executor in this crate ultimately reduces to the same
//! matrix shape: `C[m×n] = A[m×k] · B[n×k]ᵀ` with both operands row-major
//! along `k` (a batch of dot products). That layout is what im2col
//! lowering, the per-frequency channel reduction of tiled Winograd/SFC
//! (`[tiles×Cin]·[Cin×Cout]`, Eq. 1's ⊙ stage) and the quantized Eq.-17
//! datapath all produce, so one pair of kernels serves them all:
//!
//! * [`gemm_nt_f32`] — float path;
//! * [`gemm_nt_i8_i32`] — int8 operands, exact i32 accumulation.
//!
//! The kernels are blocked (`MB×NB` panels keep the B panel hot in L1/L2)
//! and register-tiled (a 4×4 micro-kernel reuses every loaded operand
//! four times; `m`/`n` remainders reuse the same blocking through 1×4 and
//! 4×1 micro-kernels instead of falling to per-element loops). The `k`
//! loop runs in index order inside each micro-tile, so float results are
//! bit-identical to the naive scalar dot product — a property the
//! workspace-reuse tests rely on.
//!
//! [`gemm_nt_f32`]/[`gemm_nt_i8_i32`] are the scalar reference kernels.
//! The hot executors run [`gemm_packed_f32`]/[`gemm_packed_i8_i32`]
//! instead: the same computation over a **packed B panel layout**
//! (8-column panels, see [`pack_b_f32`]/[`pack_b_i8`]) dispatched at
//! runtime to the SIMD microkernels in [`crate::linalg::simd`] — AVX2 /
//! NEON when detected, a scalar packed kernel otherwise. Every variant
//! keeps one accumulator per output element with `k` ascending and no
//! FMA contraction, so **all of them are bit-identical** to the scalar
//! reference (int8 is exact integer arithmetic either way).

use super::simd::{self, Kernel};

/// Panel height (rows of A per block).
const MB: usize = 64;
/// Panel width (rows of B per block).
const NB: usize = 64;
/// Register tile edge: the micro-kernel computes MR×NR outputs at once.
const MR: usize = 4;
const NR: usize = 4;

/// `C[m×n] = A[m×k] · B[n×k]ᵀ` (all row-major). `C` is overwritten.
pub fn gemm_nt_f32(m: usize, n: usize, k: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert!(a.len() >= m * k, "A too small: {} < {}", a.len(), m * k);
    assert!(b.len() >= n * k, "B too small: {} < {}", b.len(), n * k);
    assert!(c.len() >= m * n, "C too small: {} < {}", c.len(), m * n);
    for i0 in (0..m).step_by(MB) {
        let i1 = (i0 + MB).min(m);
        for j0 in (0..n).step_by(NB) {
            let j1 = (j0 + NB).min(n);
            block_nt_f32(i0, i1, j0, j1, n, k, a, b, c);
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn block_nt_f32(
    i0: usize,
    i1: usize,
    j0: usize,
    j1: usize,
    n: usize,
    k: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
) {
    let mut i = i0;
    while i + MR <= i1 {
        let a0 = &a[i * k..i * k + k];
        let a1 = &a[(i + 1) * k..(i + 1) * k + k];
        let a2 = &a[(i + 2) * k..(i + 2) * k + k];
        let a3 = &a[(i + 3) * k..(i + 3) * k + k];
        let mut j = j0;
        while j + NR <= j1 {
            let b0 = &b[j * k..j * k + k];
            let b1 = &b[(j + 1) * k..(j + 1) * k + k];
            let b2 = &b[(j + 2) * k..(j + 2) * k + k];
            let b3 = &b[(j + 3) * k..(j + 3) * k + k];
            let mut acc = [[0f32; NR]; MR];
            for l in 0..k {
                let av = [a0[l], a1[l], a2[l], a3[l]];
                let bv = [b0[l], b1[l], b2[l], b3[l]];
                for (accr, &avi) in acc.iter_mut().zip(&av) {
                    for (accv, &bvj) in accr.iter_mut().zip(&bv) {
                        *accv += avi * bvj;
                    }
                }
            }
            for (ii, accr) in acc.iter().enumerate() {
                c[(i + ii) * n + j..(i + ii) * n + j + NR].copy_from_slice(accr);
            }
            j += NR;
        }
        // n-remainder: 4×1 micro-kernel (same k-order per element)
        while j < j1 {
            let br = &b[j * k..j * k + k];
            let mut acc = [0f32; MR];
            for l in 0..k {
                let bv = br[l];
                acc[0] += a0[l] * bv;
                acc[1] += a1[l] * bv;
                acc[2] += a2[l] * bv;
                acc[3] += a3[l] * bv;
            }
            for (ii, &v) in acc.iter().enumerate() {
                c[(i + ii) * n + j] = v;
            }
            j += 1;
        }
        i += MR;
    }
    // m-remainder: 1×4 micro-kernel over the same column blocking
    while i < i1 {
        let ar = &a[i * k..i * k + k];
        let mut j = j0;
        while j + NR <= j1 {
            let b0 = &b[j * k..j * k + k];
            let b1 = &b[(j + 1) * k..(j + 1) * k + k];
            let b2 = &b[(j + 2) * k..(j + 2) * k + k];
            let b3 = &b[(j + 3) * k..(j + 3) * k + k];
            let mut acc = [0f32; NR];
            for l in 0..k {
                let av = ar[l];
                acc[0] += av * b0[l];
                acc[1] += av * b1[l];
                acc[2] += av * b2[l];
                acc[3] += av * b3[l];
            }
            c[i * n + j..i * n + j + NR].copy_from_slice(&acc);
            j += NR;
        }
        while j < j1 {
            c[i * n + j] = dot_f32(ar, &b[j * k..j * k + k]);
            j += 1;
        }
        i += 1;
    }
}

#[inline]
fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0f32;
    for (x, y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

/// `C[m×n] = A[m×k] · B[n×k]ᵀ` with int8 operands and exact i32
/// accumulation (the Eq.-17 low-precision ⊙ stage). `C` is overwritten.
pub fn gemm_nt_i8_i32(m: usize, n: usize, k: usize, a: &[i8], b: &[i8], c: &mut [i32]) {
    assert!(a.len() >= m * k, "A too small: {} < {}", a.len(), m * k);
    assert!(b.len() >= n * k, "B too small: {} < {}", b.len(), n * k);
    assert!(c.len() >= m * n, "C too small: {} < {}", c.len(), m * n);
    for i0 in (0..m).step_by(MB) {
        let i1 = (i0 + MB).min(m);
        for j0 in (0..n).step_by(NB) {
            let j1 = (j0 + NB).min(n);
            block_nt_i8(i0, i1, j0, j1, n, k, a, b, c);
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn block_nt_i8(
    i0: usize,
    i1: usize,
    j0: usize,
    j1: usize,
    n: usize,
    k: usize,
    a: &[i8],
    b: &[i8],
    c: &mut [i32],
) {
    let mut i = i0;
    while i + MR <= i1 {
        let a0 = &a[i * k..i * k + k];
        let a1 = &a[(i + 1) * k..(i + 1) * k + k];
        let a2 = &a[(i + 2) * k..(i + 2) * k + k];
        let a3 = &a[(i + 3) * k..(i + 3) * k + k];
        let mut j = j0;
        while j + NR <= j1 {
            let b0 = &b[j * k..j * k + k];
            let b1 = &b[(j + 1) * k..(j + 1) * k + k];
            let b2 = &b[(j + 2) * k..(j + 2) * k + k];
            let b3 = &b[(j + 3) * k..(j + 3) * k + k];
            let mut acc = [[0i32; NR]; MR];
            for l in 0..k {
                let av = [a0[l] as i32, a1[l] as i32, a2[l] as i32, a3[l] as i32];
                let bv = [b0[l] as i32, b1[l] as i32, b2[l] as i32, b3[l] as i32];
                for (accr, &avi) in acc.iter_mut().zip(&av) {
                    for (accv, &bvj) in accr.iter_mut().zip(&bv) {
                        *accv += avi * bvj;
                    }
                }
            }
            for (ii, accr) in acc.iter().enumerate() {
                c[(i + ii) * n + j..(i + ii) * n + j + NR].copy_from_slice(accr);
            }
            j += NR;
        }
        // n-remainder: 4×1 micro-kernel
        while j < j1 {
            let br = &b[j * k..j * k + k];
            let mut acc = [0i32; MR];
            for l in 0..k {
                let bv = br[l] as i32;
                acc[0] += a0[l] as i32 * bv;
                acc[1] += a1[l] as i32 * bv;
                acc[2] += a2[l] as i32 * bv;
                acc[3] += a3[l] as i32 * bv;
            }
            for (ii, &v) in acc.iter().enumerate() {
                c[(i + ii) * n + j] = v;
            }
            j += 1;
        }
        i += MR;
    }
    // m-remainder: 1×4 micro-kernel over the same column blocking
    while i < i1 {
        let ar = &a[i * k..i * k + k];
        let mut j = j0;
        while j + NR <= j1 {
            let b0 = &b[j * k..j * k + k];
            let b1 = &b[(j + 1) * k..(j + 1) * k + k];
            let b2 = &b[(j + 2) * k..(j + 2) * k + k];
            let b3 = &b[(j + 3) * k..(j + 3) * k + k];
            let mut acc = [0i32; NR];
            for l in 0..k {
                let av = ar[l] as i32;
                acc[0] += av * b0[l] as i32;
                acc[1] += av * b1[l] as i32;
                acc[2] += av * b2[l] as i32;
                acc[3] += av * b3[l] as i32;
            }
            c[i * n + j..i * n + j + NR].copy_from_slice(&acc);
            j += NR;
        }
        while j < j1 {
            c[i * n + j] = dot_i8(ar, &b[j * k..j * k + k]);
            j += 1;
        }
        i += 1;
    }
}

#[inline]
fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    let mut acc = 0i32;
    for (x, y) in a.iter().zip(b) {
        acc += (*x as i32) * (*y as i32);
    }
    acc
}

// ---------------------------------------------------------------------
// Packed B panels + runtime-dispatched microkernels
// ---------------------------------------------------------------------

/// Column-panel width of the packed B layout (one AVX2 f32 vector; the
/// NEON and scalar kernels consume the same layout as 2×4 / 8×1 lanes).
pub const PANEL: usize = 8;

/// Elements of the packed f32 B buffer for an `n×k` operand:
/// `⌈n/8⌉` panels of `[k][8]` (missing columns zero-padded).
pub fn packed_b_f32_len(n: usize, k: usize) -> usize {
    n.div_ceil(PANEL) * k * PANEL
}

/// Bytes/elements of the packed i8 B buffer for an `n×k` operand:
/// `⌈n/8⌉` panels of `[⌈k/2⌉][8][2]` interleaved k-pairs (odd `k` and
/// missing columns zero-padded).
pub fn packed_b_i8_len(n: usize, k: usize) -> usize {
    n.div_ceil(PANEL) * k.div_ceil(2) * PANEL * 2
}

/// Pack a row-major `B[n][k]` operand into 8-column panels
/// (`dst[(panel·k + l)·8 + lane] = B[panel·8+lane][l]`). Every element
/// of `dst[..packed_b_f32_len(n, k)]` is written, so reused workspace
/// buffers need no pre-zeroing.
pub fn pack_b_f32(n: usize, k: usize, rows: &[f32], dst: &mut [f32]) {
    assert!(rows.len() >= n * k, "B too small: {} < {}", rows.len(), n * k);
    let len = packed_b_f32_len(n, k);
    assert!(dst.len() >= len, "packed dst too small: {} < {len}", dst.len());
    let npan = n.div_ceil(PANEL);
    for jp in 0..npan {
        let panel = &mut dst[jp * k * PANEL..(jp + 1) * k * PANEL];
        for l in 0..k {
            for lane in 0..PANEL {
                let j = jp * PANEL + lane;
                panel[l * PANEL + lane] = if j < n { rows[j * k + l] } else { 0.0 };
            }
        }
    }
}

/// Pack a row-major `B[n][k]` i8 operand into 8-column panels of
/// interleaved k-pairs (`dst[((panel·⌈k/2⌉ + l/2)·8 + lane)·2 + l%2]`).
/// Every element of `dst[..packed_b_i8_len(n, k)]` is written.
pub fn pack_b_i8(n: usize, k: usize, rows: &[i8], dst: &mut [i8]) {
    assert!(rows.len() >= n * k, "B too small: {} < {}", rows.len(), n * k);
    let len = packed_b_i8_len(n, k);
    assert!(dst.len() >= len, "packed dst too small: {} < {len}", dst.len());
    let k2 = k.div_ceil(2);
    let npan = n.div_ceil(PANEL);
    for jp in 0..npan {
        let panel = &mut dst[jp * k2 * 16..(jp + 1) * k2 * 16];
        for l2 in 0..k2 {
            for lane in 0..PANEL {
                let j = jp * PANEL + lane;
                for q in 0..2 {
                    let l = 2 * l2 + q;
                    panel[(l2 * PANEL + lane) * 2 + q] =
                        if j < n && l < k { rows[j * k + l] } else { 0 };
                }
            }
        }
    }
}

/// Scalar packed-panel f32 kernel — the dispatch fallback and the
/// bit-exactness reference for the SIMD variants (identical per-element
/// multiply+add sequence, `k` ascending).
pub fn gemm_packed_f32_scalar(m: usize, n: usize, k: usize, a: &[f32], bp: &[f32], c: &mut [f32]) {
    let npan = n.div_ceil(PANEL);
    for jp in 0..npan {
        let panel = &bp[jp * k * PANEL..(jp + 1) * k * PANEL];
        let j0 = jp * PANEL;
        let lanes = (n - j0).min(PANEL);
        for i in 0..m {
            let ar = &a[i * k..i * k + k];
            let mut acc = [0f32; PANEL];
            for (l, &av) in ar.iter().enumerate() {
                let brow = &panel[l * PANEL..(l + 1) * PANEL];
                for (accv, &bv) in acc.iter_mut().zip(brow) {
                    *accv += av * bv;
                }
            }
            c[i * n + j0..i * n + j0 + lanes].copy_from_slice(&acc[..lanes]);
        }
    }
}

/// Scalar packed-panel i8→i32 kernel (exact; the dispatch fallback).
pub fn gemm_packed_i8_i32_scalar(m: usize, n: usize, k: usize, a: &[i8], bp: &[i8], c: &mut [i32]) {
    let k2 = k.div_ceil(2);
    let npan = n.div_ceil(PANEL);
    for jp in 0..npan {
        let panel = &bp[jp * k2 * 16..(jp + 1) * k2 * 16];
        let j0 = jp * PANEL;
        let lanes = (n - j0).min(PANEL);
        for i in 0..m {
            let ar = &a[i * k..i * k + k];
            let mut acc = [0i32; PANEL];
            for l2 in 0..k2 {
                let a0 = ar[2 * l2] as i32;
                let a1 = if 2 * l2 + 1 < k { ar[2 * l2 + 1] as i32 } else { 0 };
                let brow = &panel[l2 * 16..(l2 + 1) * 16];
                for (lane, accv) in acc.iter_mut().enumerate() {
                    *accv += a0 * brow[lane * 2] as i32 + a1 * brow[lane * 2 + 1] as i32;
                }
            }
            c[i * n + j0..i * n + j0 + lanes].copy_from_slice(&acc[..lanes]);
        }
    }
}

/// Runtime-dispatched packed-panel f32 GEMM:
/// `C[m×n] = A[m×k] · Bᵀ` with B pre-packed by [`pack_b_f32`].
/// Bit-identical to [`gemm_nt_f32`] on the unpacked operand under every
/// dispatch arm (AVX2 / NEON / scalar — see [`crate::linalg::simd`]).
pub fn gemm_packed_f32(m: usize, n: usize, k: usize, a: &[f32], bp: &[f32], c: &mut [f32]) {
    assert!(a.len() >= m * k, "A too small: {} < {}", a.len(), m * k);
    assert!(bp.len() >= packed_b_f32_len(n, k), "packed B too small");
    assert!(c.len() >= m * n, "C too small: {} < {}", c.len(), m * n);
    match simd::active_kernel() {
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 => unsafe { simd::avx2::gemm_packed_f32(m, n, k, a, bp, c) },
        #[cfg(target_arch = "aarch64")]
        Kernel::Neon => unsafe { simd::neon::gemm_packed_f32(m, n, k, a, bp, c) },
        _ => gemm_packed_f32_scalar(m, n, k, a, bp, c),
    }
}

/// Runtime-dispatched packed-panel i8→i32 GEMM (exact i32 accumulation;
/// B pre-packed by [`pack_b_i8`]). Bit-identical to [`gemm_nt_i8_i32`]
/// under every dispatch arm.
pub fn gemm_packed_i8_i32(m: usize, n: usize, k: usize, a: &[i8], bp: &[i8], c: &mut [i32]) {
    assert!(a.len() >= m * k, "A too small: {} < {}", a.len(), m * k);
    assert!(bp.len() >= packed_b_i8_len(n, k), "packed B too small");
    assert!(c.len() >= m * n, "C too small: {} < {}", c.len(), m * n);
    match simd::active_kernel() {
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 => unsafe { simd::avx2::gemm_packed_i8_i32(m, n, k, a, bp, c) },
        #[cfg(target_arch = "aarch64")]
        Kernel::Neon => unsafe { simd::neon::gemm_packed_i8_i32(m, n, k, a, bp, c) },
        _ => gemm_packed_i8_i32_scalar(m, n, k, a, bp, c),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn naive_f32(m: usize, n: usize, k: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0f32;
                for l in 0..k {
                    acc += a[i * k + l] * b[j * k + l];
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    #[test]
    fn matches_naive_bitwise_over_shapes() {
        let mut rng = Pcg32::seeded(5);
        // edge sizes crossing every tile/block boundary
        for (m, n, k) in [
            (1usize, 1usize, 1usize),
            (3, 5, 7),
            (4, 4, 16),
            (5, 9, 3),
            (17, 13, 21),
            (64, 64, 8),
            (65, 67, 33),
            (130, 70, 100),
        ] {
            let mut a = vec![0f32; m * k];
            let mut b = vec![0f32; n * k];
            rng.fill_gaussian(&mut a, 1.0);
            rng.fill_gaussian(&mut b, 1.0);
            let want = naive_f32(m, n, k, &a, &b);
            let mut got = vec![7f32; m * n]; // poison: C must be overwritten
            gemm_nt_f32(m, n, k, &a, &b, &mut got);
            assert_eq!(got, want, "m{m} n{n} k{k} must be bit-identical to scalar order");
        }
    }

    #[test]
    fn zero_k_zeroes_c() {
        let mut c = vec![3f32; 6];
        gemm_nt_f32(2, 3, 0, &[], &[], &mut c);
        assert_eq!(c, vec![0f32; 6]);
    }

    #[test]
    fn packed_f32_bit_identical_to_reference_over_remainders() {
        let mut rng = Pcg32::seeded(7);
        for (m, n, k) in [
            (1usize, 1usize, 1usize),
            (3, 5, 7),
            (4, 8, 16),
            (5, 9, 3),
            (6, 7, 1),
            (17, 16, 21),
            (13, 23, 33),
            (33, 41, 40),
        ] {
            let mut a = vec![0f32; m * k];
            let mut b = vec![0f32; n * k];
            rng.fill_gaussian(&mut a, 1.0);
            rng.fill_gaussian(&mut b, 1.0);
            let mut want = vec![0f32; m * n];
            gemm_nt_f32(m, n, k, &a, &b, &mut want);
            let mut bp = vec![f32::NAN; packed_b_f32_len(n, k)]; // poison: pack must overwrite
            pack_b_f32(n, k, &b, &mut bp);
            let mut got = vec![7f32; m * n];
            gemm_packed_f32(m, n, k, &a, &bp, &mut got);
            assert_eq!(got, want, "dispatched packed m{m} n{n} k{k}");
            let mut got_s = vec![7f32; m * n];
            gemm_packed_f32_scalar(m, n, k, &a, &bp, &mut got_s);
            assert_eq!(got_s, want, "scalar packed m{m} n{n} k{k}");
        }
    }

    #[test]
    fn packed_i8_exact_over_remainders_and_odd_k() {
        let mut rng = Pcg32::seeded(8);
        for (m, n, k) in [(1usize, 3usize, 5usize), (4, 8, 9), (6, 6, 6), (19, 11, 35), (9, 17, 2)]
        {
            let a: Vec<i8> = (0..m * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            let b: Vec<i8> = (0..n * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            let mut want = vec![0i32; m * n];
            gemm_nt_i8_i32(m, n, k, &a, &b, &mut want);
            let mut bp = vec![77i8; packed_b_i8_len(n, k)]; // poison: pack must overwrite
            pack_b_i8(n, k, &b, &mut bp);
            let mut got = vec![-1i32; m * n];
            gemm_packed_i8_i32(m, n, k, &a, &bp, &mut got);
            assert_eq!(got, want, "dispatched packed m{m} n{n} k{k}");
            let mut got_s = vec![-1i32; m * n];
            gemm_packed_i8_i32_scalar(m, n, k, &a, &bp, &mut got_s);
            assert_eq!(got_s, want, "scalar packed m{m} n{n} k{k}");
        }
    }

    #[test]
    fn packed_zero_k_zeroes_c() {
        let mut c = vec![3f32; 6];
        gemm_packed_f32(2, 3, 0, &[], &[], &mut c);
        assert_eq!(c, vec![0f32; 6]);
    }

    #[test]
    fn i8_matches_naive_exactly() {
        let mut rng = Pcg32::seeded(6);
        for (m, n, k) in [(1usize, 3usize, 4usize), (6, 6, 6), (19, 11, 35), (70, 66, 9)] {
            let a: Vec<i8> = (0..m * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            let b: Vec<i8> = (0..n * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            let mut want = vec![0i32; m * n];
            for i in 0..m {
                for j in 0..n {
                    let mut acc = 0i32;
                    for l in 0..k {
                        acc += a[i * k + l] as i32 * b[j * k + l] as i32;
                    }
                    want[i * n + j] = acc;
                }
            }
            let mut got = vec![-1i32; m * n];
            gemm_nt_i8_i32(m, n, k, &a, &b, &mut got);
            assert_eq!(got, want, "m{m} n{n} k{k}");
        }
    }
}
