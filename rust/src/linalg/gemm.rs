//! Blocked, register-tiled, intra-op threaded GEMM — the shared
//! ⊙-reduction core.
//!
//! Every conv executor in this crate ultimately reduces to the same
//! matrix shape: `C[m×n] = A[m×k] · B[n×k]ᵀ` with both operands row-major
//! along `k` (a batch of dot products). That layout is what im2col
//! lowering, the per-frequency channel reduction of tiled Winograd/SFC
//! (`[tiles×Cin]·[Cin×Cout]`, Eq. 1's ⊙ stage) and the quantized Eq.-17
//! datapath all produce, so one pair of kernels serves them all:
//!
//! * [`gemm_nt_f32`] — float path;
//! * [`gemm_nt_i8_i32`] — int8 operands, exact i32 accumulation.
//!
//! **Cache blocking.** The macro-kernel's Mc/Kc/Nc blocking is no longer
//! hard-coded: a per-kernel [`Blocking`] (chosen per-CPU by
//! [`Blocking::for_kernel`], overridable process-wide via
//! [`set_blocking_override`] — how `engine::tuning` applies a tuned
//! blocking) drives the loop structure. `kc` splits the reduction into
//! k-blocks accumulated *into C in ascending k order*, which keeps the
//! per-element add chain `((0 + p₀) + p₁) + …` identical for every
//! blocking — blockings are numerically interchangeable, so the
//! autotuner may sweep them freely ([`Blocking::candidates`]).
//!
//! **Threading (BLIS/Goto pack-once/share-across-threads).** The
//! dispatched entry points partition C's *rows* into contiguous spans
//! (multiples of the register tile `MR`) and submit one span per task
//! to the persistent executor pool, sized through the
//! [`crate::util::pool::team`] entry point (a
//! [`crate::util::par::CoreBudget`] lease); all executors consume
//! disjoint M-tiles of the **same packed B buffer** — B is packed once
//! (at plan time for weights, by the im2col lowering for activations)
//! and only read concurrently. Small problems (below [`PAR_MIN_MACS`])
//! stay serial so scheduling cost never dominates, and a nested call
//! (GEMM inside a batch-parallel worker) degrades to serial when the
//! budget has no spare lanes.
//!
//! **Numerics contract.** Each output element is owned by exactly one
//! worker and computed with one accumulator, `k` ascending, separate
//! multiply and add (no FMA) — in the scalar, AVX2 and NEON kernels
//! alike. Results are therefore **bit-identical** across scalar/SIMD ×
//! any thread count × any blocking; the property tests in
//! `rust/tests/threads.rs` assert exact equality.
//!
//! [`gemm_nt_f32`]/[`gemm_nt_i8_i32`] are the scalar reference kernels.
//! The hot executors run [`gemm_packed_f32`]/[`gemm_packed_i8_i32`]
//! instead: the same computation over a **packed B panel layout**
//! (8-column panels, see [`pack_b_f32`]/[`pack_b_i8`]) dispatched at
//! runtime to the SIMD microkernels in [`crate::linalg::simd`].

use super::simd::{self, Kernel};
use std::sync::atomic::{AtomicU64, Ordering};

/// Register tile edge: the micro-kernel computes MR×NR outputs at once,
/// and threaded row partitions are multiples of MR.
const MR: usize = 4;
const NR: usize = 4;

/// Minimum problem size (m·n·k multiply-accumulates) before the
/// dispatched GEMMs consider a multi-thread team. The threshold is
/// pool-aware: enlisting a parked pool worker costs a queue push plus a
/// condvar wake (order 1–2 µs), not the ~20 µs+ of the old
/// spawn-per-call `thread::scope` path, so the floor sits 8× lower
/// than the pre-pool `1 << 21`. At ~4 GMAC/s/core a 2¹⁸-MAC GEMM runs
/// ~65 µs serial — comfortably above the pool's per-task overhead —
/// while anything smaller is better served by the *batched* submit
/// paths (`par_chunks_mut` over the per-(freq, group) sweep), which
/// amortize one submission over many small GEMMs instead of teaming
/// inside each one.
pub const PAR_MIN_MACS: u64 = 1 << 18;

// ---------------------------------------------------------------------
// Cache-blocking parameters
// ---------------------------------------------------------------------

/// Macro-kernel cache-blocking parameters (the BLIS-style Mc/Kc/Nc
/// knobs), lifted out of hard-coded consts so dispatch can pick
/// per-CPU defaults and the autotuner can sweep them.
///
/// * `mc` — rows of A per macro-block (L2 residency of the A slice) in
///   the reference path; also the spirit of the threaded row spans.
/// * `kc` — reduction depth per block. Both the reference and the
///   packed kernels accumulate k-blocks into C in ascending-k order,
///   so any `kc` produces bit-identical results (see module docs). For
///   the int8 kernels `kc` is rounded to the interleaved-pair boundary.
/// * `nc` — columns of B per macro-block in the reference path (the
///   packed layout's 8-wide panels fix the micro-blocking of the hot
///   path, which streams whole panels pack-once/share-across-threads).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Blocking {
    /// rows of A per macro-block (≥ MR)
    pub mc: usize,
    /// reduction depth per k-block (≥ 2, kept even for the int8 pairs)
    pub kc: usize,
    /// columns of B per macro-block (≥ NR)
    pub nc: usize,
}

impl Blocking {
    /// Per-CPU default blocking for a dispatch kernel, sized to its
    /// typical L1/L2 working set (8-lane AVX2 panels want deeper k
    /// blocks than the scalar reference).
    pub fn for_kernel(k: Kernel) -> Blocking {
        match k {
            Kernel::Avx2 => Blocking { mc: 64, kc: 512, nc: 256 },
            Kernel::Neon => Blocking { mc: 48, kc: 512, nc: 128 },
            Kernel::Scalar => Blocking { mc: 64, kc: 256, nc: 128 },
        }
    }

    /// The candidate set the autotuner sweeps (`sfc autotune` measures
    /// each and persists the winner in the tuning table). All
    /// candidates are numerically interchangeable — the sweep is purely
    /// a performance search.
    pub fn candidates() -> [Blocking; 4] {
        [
            Blocking { mc: 32, kc: 256, nc: 128 },
            Blocking { mc: 64, kc: 256, nc: 128 },
            Blocking { mc: 64, kc: 512, nc: 256 },
            Blocking { mc: 128, kc: 1024, nc: 256 },
        ]
    }
}

/// Process-wide blocking override, encoded into one atomic (0 = none;
/// bit 63 set = valid, then 20-bit mc/kc/nc fields). One atomic keeps
/// the three fields consistent without a lock on the hot path.
static BLOCKING_OVERRIDE: AtomicU64 = AtomicU64::new(0);

/// Force the macro-kernel blocking process-wide (`None` restores the
/// per-kernel [`Blocking::for_kernel`] defaults). Safe to flip at any
/// time — every blocking yields bit-identical results — so this is how
/// `engine::tuning::install_global` applies a persisted tuned blocking
/// and how the autotune sweep measures candidates. Values are clamped
/// to the register-tile floors and `kc` is rounded to the int8
/// interleaved-pair boundary.
pub fn set_blocking_override(b: Option<Blocking>) {
    let v = match b {
        None => 0,
        Some(b) => {
            let mc = b.mc.clamp(MR, 65_535) as u64;
            let kc = (b.kc.clamp(2, 65_534) & !1) as u64;
            let nc = b.nc.clamp(NR, 65_535) as u64;
            (1 << 63) | (mc << 40) | (kc << 20) | nc
        }
    };
    BLOCKING_OVERRIDE.store(v, Ordering::Relaxed);
}

/// The blocking the macro-kernels use right now: the
/// [`set_blocking_override`] pin if set, else the per-CPU default for
/// the active dispatch kernel.
pub fn active_blocking() -> Blocking {
    let v = BLOCKING_OVERRIDE.load(Ordering::Relaxed);
    if v == 0 {
        return Blocking::for_kernel(simd::active_kernel());
    }
    Blocking {
        mc: ((v >> 40) & 0xf_ffff) as usize,
        kc: ((v >> 20) & 0xf_ffff) as usize,
        nc: (v & 0xf_ffff) as usize,
    }
}

// ---------------------------------------------------------------------
// Threaded row partitioning (shared by the nt and packed entry points)
// ---------------------------------------------------------------------

/// How many workers this problem wants: 1 when the problem is too small
/// to amortize spawns or has too few rows to split, else the configured
/// thread count capped by the row count.
fn gemm_team(m: usize, n: usize, k: usize) -> usize {
    if m < 2 * MR || n == 0 || k == 0 {
        return 1;
    }
    if (m as u64) * (n as u64) * (k as u64) < PAR_MIN_MACS {
        return 1;
    }
    crate::util::par::num_threads().min(m / MR)
}

/// Split A/C into contiguous row spans of `span` rows (`span` a multiple
/// of MR) and run `f(rows, a_span, c_span)` on each — one pool task per
/// span, span 0 guaranteed on the calling thread, up to `threads`
/// executors under the caller's [`crate::util::pool::team`] lease.
/// Every span is a disjoint sub-slice of C (the decomposition is fixed
/// by `span`, never by which thread runs it — the bit-identity
/// contract's anchor); all spans read the same B.
fn par_rows<TA: Sync, TC: Send>(
    threads: usize,
    k: usize,
    n: usize,
    a: &[TA],
    c: &mut [TC],
    f: impl Fn(usize, &[TA], &mut [TC]) + Sync,
) {
    let m = c.len() / n;
    let span = row_span(m, threads);
    let njobs = m.div_ceil(span);
    let cp = crate::util::pool::SendPtr::new(c.as_mut_ptr());
    crate::util::pool::run(njobs, threads, |t| {
        let lo = t * span;
        let rows = span.min(m - lo);
        let asub = &a[lo * k..(lo + rows) * k];
        // SAFETY: task t exclusively owns C rows [lo, lo + rows) —
        // spans tile 0..m without overlap.
        let csub =
            unsafe { std::slice::from_raw_parts_mut(cp.get().add(lo * n), rows * n) };
        f(rows, asub, csub);
    });
}

/// Rows per worker: even split rounded up to the register tile.
fn row_span(m: usize, threads: usize) -> usize {
    m.div_ceil(threads).next_multiple_of(MR)
}

/// `C[m×n] = A[m×k] · B[n×k]ᵀ` (all row-major). `C` is overwritten.
/// Threads across row spans when the problem is large enough and the
/// [`crate::util::par::CoreBudget`] has spare lanes; bit-identical at
/// every thread count and blocking.
pub fn gemm_nt_f32(m: usize, n: usize, k: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert!(a.len() >= m * k, "A too small: {} < {}", a.len(), m * k);
    assert!(b.len() >= n * k, "B too small: {} < {}", b.len(), n * k);
    assert!(c.len() >= m * n, "C too small: {} < {}", c.len(), m * n);
    if k == 0 {
        c[..m * n].fill(0.0);
        return;
    }
    let want = gemm_team(m, n, k);
    if want > 1 {
        let team = crate::util::pool::team(want);
        let threads = team.threads().min(want);
        if threads > 1 {
            par_rows(threads, k, n, &a[..m * k], &mut c[..m * n], |rows, asub, csub| {
                gemm_nt_f32_serial(rows, n, k, asub, b, csub)
            });
            return;
        }
    }
    gemm_nt_f32_serial(m, n, k, a, b, c);
}

/// Single-thread blocked macro-kernel for the f32 reference path:
/// k-blocks outermost (accumulating into C in ascending-k order), then
/// Mc×Nc panels. Requires `k > 0` (the entry point handles `k == 0`).
fn gemm_nt_f32_serial(m: usize, n: usize, k: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    let bl = active_blocking();
    let (mc, kc, nc) = (bl.mc.max(MR), bl.kc.max(1), bl.nc.max(NR));
    for l0 in (0..k).step_by(kc) {
        let l1 = (l0 + kc).min(k);
        for i0 in (0..m).step_by(mc) {
            let i1 = (i0 + mc).min(m);
            for j0 in (0..n).step_by(nc) {
                let j1 = (j0 + nc).min(n);
                block_nt_f32(i0, i1, j0, j1, n, k, l0, l1, a, b, c);
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn block_nt_f32(
    i0: usize,
    i1: usize,
    j0: usize,
    j1: usize,
    n: usize,
    k: usize,
    l0: usize,
    l1: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
) {
    // first k-block overwrites C, later blocks continue the same
    // per-element add chain from the stored partial sum
    let first = l0 == 0;
    let kk = l1 - l0;
    let mut i = i0;
    while i + MR <= i1 {
        let a0 = &a[i * k + l0..i * k + l1];
        let a1 = &a[(i + 1) * k + l0..(i + 1) * k + l1];
        let a2 = &a[(i + 2) * k + l0..(i + 2) * k + l1];
        let a3 = &a[(i + 3) * k + l0..(i + 3) * k + l1];
        let mut j = j0;
        while j + NR <= j1 {
            let b0 = &b[j * k + l0..j * k + l1];
            let b1 = &b[(j + 1) * k + l0..(j + 1) * k + l1];
            let b2 = &b[(j + 2) * k + l0..(j + 2) * k + l1];
            let b3 = &b[(j + 3) * k + l0..(j + 3) * k + l1];
            let mut acc = [[0f32; NR]; MR];
            if !first {
                for (ii, accr) in acc.iter_mut().enumerate() {
                    accr.copy_from_slice(&c[(i + ii) * n + j..(i + ii) * n + j + NR]);
                }
            }
            for l in 0..kk {
                let av = [a0[l], a1[l], a2[l], a3[l]];
                let bv = [b0[l], b1[l], b2[l], b3[l]];
                for (accr, &avi) in acc.iter_mut().zip(&av) {
                    for (accv, &bvj) in accr.iter_mut().zip(&bv) {
                        *accv += avi * bvj;
                    }
                }
            }
            for (ii, accr) in acc.iter().enumerate() {
                c[(i + ii) * n + j..(i + ii) * n + j + NR].copy_from_slice(accr);
            }
            j += NR;
        }
        // n-remainder: 4×1 micro-kernel (same k-order per element)
        while j < j1 {
            let br = &b[j * k + l0..j * k + l1];
            let mut acc = [0f32; MR];
            if !first {
                for (ii, accv) in acc.iter_mut().enumerate() {
                    *accv = c[(i + ii) * n + j];
                }
            }
            for l in 0..kk {
                let bv = br[l];
                acc[0] += a0[l] * bv;
                acc[1] += a1[l] * bv;
                acc[2] += a2[l] * bv;
                acc[3] += a3[l] * bv;
            }
            for (ii, &v) in acc.iter().enumerate() {
                c[(i + ii) * n + j] = v;
            }
            j += 1;
        }
        i += MR;
    }
    // m-remainder: 1×4 micro-kernel over the same column blocking
    while i < i1 {
        let ar = &a[i * k + l0..i * k + l1];
        let mut j = j0;
        while j + NR <= j1 {
            let b0 = &b[j * k + l0..j * k + l1];
            let b1 = &b[(j + 1) * k + l0..(j + 1) * k + l1];
            let b2 = &b[(j + 2) * k + l0..(j + 2) * k + l1];
            let b3 = &b[(j + 3) * k + l0..(j + 3) * k + l1];
            let mut acc = [0f32; NR];
            if !first {
                acc.copy_from_slice(&c[i * n + j..i * n + j + NR]);
            }
            for l in 0..kk {
                let av = ar[l];
                acc[0] += av * b0[l];
                acc[1] += av * b1[l];
                acc[2] += av * b2[l];
                acc[3] += av * b3[l];
            }
            c[i * n + j..i * n + j + NR].copy_from_slice(&acc);
            j += NR;
        }
        while j < j1 {
            let init = if first { 0.0 } else { c[i * n + j] };
            c[i * n + j] = dot_f32(init, ar, &b[j * k + l0..j * k + l1]);
            j += 1;
        }
        i += 1;
    }
}

#[inline]
fn dot_f32(init: f32, a: &[f32], b: &[f32]) -> f32 {
    let mut acc = init;
    for (x, y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

/// `C[m×n] = A[m×k] · B[n×k]ᵀ` with int8 operands and exact i32
/// accumulation (the Eq.-17 low-precision ⊙ stage). `C` is overwritten.
/// Threads and blocks like [`gemm_nt_f32`] (integer arithmetic is exact
/// under any split).
pub fn gemm_nt_i8_i32(m: usize, n: usize, k: usize, a: &[i8], b: &[i8], c: &mut [i32]) {
    assert!(a.len() >= m * k, "A too small: {} < {}", a.len(), m * k);
    assert!(b.len() >= n * k, "B too small: {} < {}", b.len(), n * k);
    assert!(c.len() >= m * n, "C too small: {} < {}", c.len(), m * n);
    if k == 0 {
        c[..m * n].fill(0);
        return;
    }
    let want = gemm_team(m, n, k);
    if want > 1 {
        let team = crate::util::pool::team(want);
        let threads = team.threads().min(want);
        if threads > 1 {
            par_rows(threads, k, n, &a[..m * k], &mut c[..m * n], |rows, asub, csub| {
                gemm_nt_i8_serial(rows, n, k, asub, b, csub)
            });
            return;
        }
    }
    gemm_nt_i8_serial(m, n, k, a, b, c);
}

/// Single-thread blocked macro-kernel for the int8 reference path.
/// Requires `k > 0`.
fn gemm_nt_i8_serial(m: usize, n: usize, k: usize, a: &[i8], b: &[i8], c: &mut [i32]) {
    let bl = active_blocking();
    let (mc, kc, nc) = (bl.mc.max(MR), bl.kc.max(1), bl.nc.max(NR));
    for l0 in (0..k).step_by(kc) {
        let l1 = (l0 + kc).min(k);
        for i0 in (0..m).step_by(mc) {
            let i1 = (i0 + mc).min(m);
            for j0 in (0..n).step_by(nc) {
                let j1 = (j0 + nc).min(n);
                block_nt_i8(i0, i1, j0, j1, n, k, l0, l1, a, b, c);
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn block_nt_i8(
    i0: usize,
    i1: usize,
    j0: usize,
    j1: usize,
    n: usize,
    k: usize,
    l0: usize,
    l1: usize,
    a: &[i8],
    b: &[i8],
    c: &mut [i32],
) {
    let first = l0 == 0;
    let kk = l1 - l0;
    let mut i = i0;
    while i + MR <= i1 {
        let a0 = &a[i * k + l0..i * k + l1];
        let a1 = &a[(i + 1) * k + l0..(i + 1) * k + l1];
        let a2 = &a[(i + 2) * k + l0..(i + 2) * k + l1];
        let a3 = &a[(i + 3) * k + l0..(i + 3) * k + l1];
        let mut j = j0;
        while j + NR <= j1 {
            let b0 = &b[j * k + l0..j * k + l1];
            let b1 = &b[(j + 1) * k + l0..(j + 1) * k + l1];
            let b2 = &b[(j + 2) * k + l0..(j + 2) * k + l1];
            let b3 = &b[(j + 3) * k + l0..(j + 3) * k + l1];
            let mut acc = [[0i32; NR]; MR];
            if !first {
                for (ii, accr) in acc.iter_mut().enumerate() {
                    accr.copy_from_slice(&c[(i + ii) * n + j..(i + ii) * n + j + NR]);
                }
            }
            for l in 0..kk {
                let av = [a0[l] as i32, a1[l] as i32, a2[l] as i32, a3[l] as i32];
                let bv = [b0[l] as i32, b1[l] as i32, b2[l] as i32, b3[l] as i32];
                for (accr, &avi) in acc.iter_mut().zip(&av) {
                    for (accv, &bvj) in accr.iter_mut().zip(&bv) {
                        *accv += avi * bvj;
                    }
                }
            }
            for (ii, accr) in acc.iter().enumerate() {
                c[(i + ii) * n + j..(i + ii) * n + j + NR].copy_from_slice(accr);
            }
            j += NR;
        }
        // n-remainder: 4×1 micro-kernel
        while j < j1 {
            let br = &b[j * k + l0..j * k + l1];
            let mut acc = [0i32; MR];
            if !first {
                for (ii, accv) in acc.iter_mut().enumerate() {
                    *accv = c[(i + ii) * n + j];
                }
            }
            for l in 0..kk {
                let bv = br[l] as i32;
                acc[0] += a0[l] as i32 * bv;
                acc[1] += a1[l] as i32 * bv;
                acc[2] += a2[l] as i32 * bv;
                acc[3] += a3[l] as i32 * bv;
            }
            for (ii, &v) in acc.iter().enumerate() {
                c[(i + ii) * n + j] = v;
            }
            j += 1;
        }
        i += MR;
    }
    // m-remainder: 1×4 micro-kernel over the same column blocking
    while i < i1 {
        let ar = &a[i * k + l0..i * k + l1];
        let mut j = j0;
        while j + NR <= j1 {
            let b0 = &b[j * k + l0..j * k + l1];
            let b1 = &b[(j + 1) * k + l0..(j + 1) * k + l1];
            let b2 = &b[(j + 2) * k + l0..(j + 2) * k + l1];
            let b3 = &b[(j + 3) * k + l0..(j + 3) * k + l1];
            let mut acc = [0i32; NR];
            if !first {
                acc.copy_from_slice(&c[i * n + j..i * n + j + NR]);
            }
            for l in 0..kk {
                let av = ar[l] as i32;
                acc[0] += av * b0[l] as i32;
                acc[1] += av * b1[l] as i32;
                acc[2] += av * b2[l] as i32;
                acc[3] += av * b3[l] as i32;
            }
            c[i * n + j..i * n + j + NR].copy_from_slice(&acc);
            j += NR;
        }
        while j < j1 {
            let init = if first { 0 } else { c[i * n + j] };
            c[i * n + j] = dot_i8(init, ar, &b[j * k + l0..j * k + l1]);
            j += 1;
        }
        i += 1;
    }
}

#[inline]
fn dot_i8(init: i32, a: &[i8], b: &[i8]) -> i32 {
    let mut acc = init;
    for (x, y) in a.iter().zip(b) {
        acc += (*x as i32) * (*y as i32);
    }
    acc
}

// ---------------------------------------------------------------------
// Packed B panels + runtime-dispatched microkernels
// ---------------------------------------------------------------------

/// Column-panel width of the packed B layout (one AVX2 f32 vector; the
/// NEON and scalar kernels consume the same layout as 2×4 / 8×1 lanes).
pub const PANEL: usize = 8;

/// Elements of the packed f32 B buffer for an `n×k` operand:
/// `⌈n/8⌉` panels of `[k][8]` (missing columns zero-padded).
pub fn packed_b_f32_len(n: usize, k: usize) -> usize {
    n.div_ceil(PANEL) * k * PANEL
}

/// Bytes/elements of the packed i8 B buffer for an `n×k` operand:
/// `⌈n/8⌉` panels of `[⌈k/2⌉][8][2]` interleaved k-pairs (odd `k` and
/// missing columns zero-padded).
pub fn packed_b_i8_len(n: usize, k: usize) -> usize {
    n.div_ceil(PANEL) * k.div_ceil(2) * PANEL * 2
}

/// The interleaved k-pair at pair-index `l2` of one length-`k` operand
/// row: `[row[2·l2], row[2·l2+1]]` with the odd-`k` tail zero-padded.
/// The single definition of the layout's tail rule, shared by the
/// packing ([`pack_b_i8`]), the scalar consume loop and the SIMD
/// A-side loads — so pack and consume can never disagree about the
/// padding again.
#[inline(always)]
pub fn i8_kpair(row: &[i8], l2: usize) -> [i8; 2] {
    [row[2 * l2], row.get(2 * l2 + 1).copied().unwrap_or(0)]
}

/// Sign-extend an interleaved k-pair into two i16 halves packed in one
/// i32 (low half = even-k element) — the A-side operand format of the
/// AVX2 `_mm256_madd_epi16` and NEON `vmull_s16` int8 kernels.
#[inline(always)]
pub fn i8_pair_word(p: [i8; 2]) -> i32 {
    ((p[0] as i32 as u32 & 0xffff) | ((p[1] as i32 as u32 & 0xffff) << 16)) as i32
}

/// Pack a row-major `B[n][k]` operand into 8-column panels
/// (`dst[(panel·k + l)·8 + lane] = B[panel·8+lane][l]`). Every element
/// of `dst[..packed_b_f32_len(n, k)]` is written, so reused workspace
/// buffers need no pre-zeroing.
pub fn pack_b_f32(n: usize, k: usize, rows: &[f32], dst: &mut [f32]) {
    assert!(rows.len() >= n * k, "B too small: {} < {}", rows.len(), n * k);
    let len = packed_b_f32_len(n, k);
    assert!(dst.len() >= len, "packed dst too small: {} < {len}", dst.len());
    let npan = n.div_ceil(PANEL);
    for jp in 0..npan {
        let panel = &mut dst[jp * k * PANEL..(jp + 1) * k * PANEL];
        for l in 0..k {
            for lane in 0..PANEL {
                let j = jp * PANEL + lane;
                panel[l * PANEL + lane] = if j < n { rows[j * k + l] } else { 0.0 };
            }
        }
    }
}

/// Pack a row-major `B[n][k]` i8 operand into 8-column panels of
/// interleaved k-pairs (`dst[((panel·⌈k/2⌉ + l/2)·8 + lane)·2 + l%2]`,
/// tail rule per [`i8_kpair`]). Every element of
/// `dst[..packed_b_i8_len(n, k)]` is written.
pub fn pack_b_i8(n: usize, k: usize, rows: &[i8], dst: &mut [i8]) {
    assert!(rows.len() >= n * k, "B too small: {} < {}", rows.len(), n * k);
    let len = packed_b_i8_len(n, k);
    assert!(dst.len() >= len, "packed dst too small: {} < {len}", dst.len());
    let k2 = k.div_ceil(2);
    let npan = n.div_ceil(PANEL);
    for jp in 0..npan {
        let panel = &mut dst[jp * k2 * 16..(jp + 1) * k2 * 16];
        for l2 in 0..k2 {
            for lane in 0..PANEL {
                let j = jp * PANEL + lane;
                let pair =
                    if j < n { i8_kpair(&rows[j * k..j * k + k], l2) } else { [0, 0] };
                panel[(l2 * PANEL + lane) * 2] = pair[0];
                panel[(l2 * PANEL + lane) * 2 + 1] = pair[1];
            }
        }
    }
}

/// Scalar packed-panel f32 kernel — the dispatch fallback and the
/// bit-exactness reference for the SIMD variants (identical per-element
/// multiply+add sequence, `k` ascending).
pub fn gemm_packed_f32_scalar(m: usize, n: usize, k: usize, a: &[f32], bp: &[f32], c: &mut [f32]) {
    gemm_packed_f32_scalar_range(m, n, k, 0, k, a, bp, c);
}

/// Scalar packed f32 kernel over the k-range `[l0, l1)`: the first
/// block (`l0 == 0`) starts accumulators at zero and overwrites C,
/// later blocks continue each element's add chain from the stored
/// partial sum — the k-blocked macro-loop stays bit-identical to one
/// full-k pass.
fn gemm_packed_f32_scalar_range(
    m: usize,
    n: usize,
    k: usize,
    l0: usize,
    l1: usize,
    a: &[f32],
    bp: &[f32],
    c: &mut [f32],
) {
    let npan = n.div_ceil(PANEL);
    for jp in 0..npan {
        let panel = &bp[jp * k * PANEL..(jp + 1) * k * PANEL];
        let j0 = jp * PANEL;
        let lanes = (n - j0).min(PANEL);
        for i in 0..m {
            let ar = &a[i * k + l0..i * k + l1];
            let mut acc = [0f32; PANEL];
            if l0 > 0 {
                acc[..lanes].copy_from_slice(&c[i * n + j0..i * n + j0 + lanes]);
            }
            for (off, &av) in ar.iter().enumerate() {
                let brow = &panel[(l0 + off) * PANEL..(l0 + off + 1) * PANEL];
                for (accv, &bv) in acc.iter_mut().zip(brow) {
                    *accv += av * bv;
                }
            }
            c[i * n + j0..i * n + j0 + lanes].copy_from_slice(&acc[..lanes]);
        }
    }
}

/// Scalar packed-panel i8→i32 kernel (exact; the dispatch fallback).
pub fn gemm_packed_i8_i32_scalar(m: usize, n: usize, k: usize, a: &[i8], bp: &[i8], c: &mut [i32]) {
    gemm_packed_i8_i32_scalar_range(m, n, k, 0, k.div_ceil(2), a, bp, c);
}

/// Scalar packed int8 kernel over the pair-range `[p0, p1)` (pair index
/// `l2` covers k indices `2·l2, 2·l2+1`). Integer accumulation is exact
/// under any split; `p0 > 0` continues from the stored partials.
#[allow(clippy::too_many_arguments)]
fn gemm_packed_i8_i32_scalar_range(
    m: usize,
    n: usize,
    k: usize,
    p0: usize,
    p1: usize,
    a: &[i8],
    bp: &[i8],
    c: &mut [i32],
) {
    let k2 = k.div_ceil(2);
    let npan = n.div_ceil(PANEL);
    for jp in 0..npan {
        let panel = &bp[jp * k2 * 16..(jp + 1) * k2 * 16];
        let j0 = jp * PANEL;
        let lanes = (n - j0).min(PANEL);
        for i in 0..m {
            let ar = &a[i * k..i * k + k];
            let mut acc = [0i32; PANEL];
            if p0 > 0 {
                acc[..lanes].copy_from_slice(&c[i * n + j0..i * n + j0 + lanes]);
            }
            for l2 in p0..p1 {
                let pair = i8_kpair(ar, l2);
                let (a0, a1) = (pair[0] as i32, pair[1] as i32);
                let brow = &panel[l2 * 16..(l2 + 1) * 16];
                for (lane, accv) in acc.iter_mut().enumerate() {
                    *accv += a0 * brow[lane * 2] as i32 + a1 * brow[lane * 2 + 1] as i32;
                }
            }
            c[i * n + j0..i * n + j0 + lanes].copy_from_slice(&acc[..lanes]);
        }
    }
}

/// One k-range pass of the dispatched packed f32 kernel.
#[allow(clippy::too_many_arguments)]
fn dispatch_packed_f32(
    m: usize,
    n: usize,
    k: usize,
    l0: usize,
    l1: usize,
    a: &[f32],
    bp: &[f32],
    c: &mut [f32],
) {
    match simd::active_kernel() {
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 => unsafe { simd::avx2::gemm_packed_f32(m, n, k, l0, l1, a, bp, c) },
        #[cfg(target_arch = "aarch64")]
        Kernel::Neon => unsafe { simd::neon::gemm_packed_f32(m, n, k, l0, l1, a, bp, c) },
        _ => gemm_packed_f32_scalar_range(m, n, k, l0, l1, a, bp, c),
    }
}

/// One pair-range pass of the dispatched packed int8 kernel.
#[allow(clippy::too_many_arguments)]
fn dispatch_packed_i8(
    m: usize,
    n: usize,
    k: usize,
    p0: usize,
    p1: usize,
    a: &[i8],
    bp: &[i8],
    c: &mut [i32],
) {
    match simd::active_kernel() {
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 => unsafe { simd::avx2::gemm_packed_i8_i32(m, n, k, p0, p1, a, bp, c) },
        #[cfg(target_arch = "aarch64")]
        Kernel::Neon => unsafe { simd::neon::gemm_packed_i8_i32(m, n, k, p0, p1, a, bp, c) },
        _ => gemm_packed_i8_i32_scalar_range(m, n, k, p0, p1, a, bp, c),
    }
}

/// Single-thread packed f32 GEMM: the dispatched kernel over `kc`-deep
/// k-blocks (ascending, accumulating into C — see [`Blocking`]).
fn gemm_packed_f32_single(m: usize, n: usize, k: usize, a: &[f32], bp: &[f32], c: &mut [f32]) {
    if k == 0 {
        // single empty-range pass zero-fills C (acc starts at zero)
        dispatch_packed_f32(m, n, k, 0, 0, a, bp, c);
        return;
    }
    let kc = active_blocking().kc.max(1);
    let mut l0 = 0;
    while l0 < k {
        let l1 = (l0 + kc).min(k);
        dispatch_packed_f32(m, n, k, l0, l1, a, bp, c);
        l0 = l1;
    }
}

/// Single-thread packed int8 GEMM over `kc`-deep pair blocks.
fn gemm_packed_i8_single(m: usize, n: usize, k: usize, a: &[i8], bp: &[i8], c: &mut [i32]) {
    let k2 = k.div_ceil(2);
    if k2 == 0 {
        dispatch_packed_i8(m, n, k, 0, 0, a, bp, c);
        return;
    }
    let kcp = (active_blocking().kc / 2).max(1);
    let mut p0 = 0;
    while p0 < k2 {
        let p1 = (p0 + kcp).min(k2);
        dispatch_packed_i8(m, n, k, p0, p1, a, bp, c);
        p0 = p1;
    }
}

/// Runtime-dispatched packed-panel f32 GEMM:
/// `C[m×n] = A[m×k] · Bᵀ` with B pre-packed by [`pack_b_f32`].
/// Bit-identical to [`gemm_nt_f32`] on the unpacked operand under every
/// dispatch arm, thread count and blocking. Large problems run the
/// macro-kernel across row spans that share the packed B buffer
/// (pack-once/share-across-threads) under a
/// [`crate::util::par::CoreBudget`] lease.
pub fn gemm_packed_f32(m: usize, n: usize, k: usize, a: &[f32], bp: &[f32], c: &mut [f32]) {
    assert!(a.len() >= m * k, "A too small: {} < {}", a.len(), m * k);
    assert!(bp.len() >= packed_b_f32_len(n, k), "packed B too small");
    assert!(c.len() >= m * n, "C too small: {} < {}", c.len(), m * n);
    let want = gemm_team(m, n, k);
    if want > 1 {
        let team = crate::util::pool::team(want);
        let threads = team.threads().min(want);
        if threads > 1 {
            par_rows(threads, k, n, &a[..m * k], &mut c[..m * n], |rows, asub, csub| {
                gemm_packed_f32_single(rows, n, k, asub, bp, csub)
            });
            return;
        }
    }
    gemm_packed_f32_single(m, n, k, a, bp, c);
}

/// Runtime-dispatched packed-panel i8→i32 GEMM (exact i32 accumulation;
/// B pre-packed by [`pack_b_i8`]). Bit-identical to [`gemm_nt_i8_i32`]
/// under every dispatch arm, thread count and blocking; threads like
/// [`gemm_packed_f32`].
pub fn gemm_packed_i8_i32(m: usize, n: usize, k: usize, a: &[i8], bp: &[i8], c: &mut [i32]) {
    assert!(a.len() >= m * k, "A too small: {} < {}", a.len(), m * k);
    assert!(bp.len() >= packed_b_i8_len(n, k), "packed B too small");
    assert!(c.len() >= m * n, "C too small: {} < {}", c.len(), m * n);
    let want = gemm_team(m, n, k);
    if want > 1 {
        let team = crate::util::pool::team(want);
        let threads = team.threads().min(want);
        if threads > 1 {
            par_rows(threads, k, n, &a[..m * k], &mut c[..m * n], |rows, asub, csub| {
                gemm_packed_i8_single(rows, n, k, asub, bp, csub)
            });
            return;
        }
    }
    gemm_packed_i8_single(m, n, k, a, bp, c);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn naive_f32(m: usize, n: usize, k: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0f32;
                for l in 0..k {
                    acc += a[i * k + l] * b[j * k + l];
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    #[test]
    fn matches_naive_bitwise_over_shapes() {
        let mut rng = Pcg32::seeded(5);
        // edge sizes crossing every tile/block boundary
        for (m, n, k) in [
            (1usize, 1usize, 1usize),
            (3, 5, 7),
            (4, 4, 16),
            (5, 9, 3),
            (17, 13, 21),
            (64, 64, 8),
            (65, 67, 33),
            (130, 70, 100),
        ] {
            let mut a = vec![0f32; m * k];
            let mut b = vec![0f32; n * k];
            rng.fill_gaussian(&mut a, 1.0);
            rng.fill_gaussian(&mut b, 1.0);
            let want = naive_f32(m, n, k, &a, &b);
            let mut got = vec![7f32; m * n]; // poison: C must be overwritten
            gemm_nt_f32(m, n, k, &a, &b, &mut got);
            assert_eq!(got, want, "m{m} n{n} k{k} must be bit-identical to scalar order");
        }
    }

    #[test]
    fn zero_k_zeroes_c() {
        let mut c = vec![3f32; 6];
        gemm_nt_f32(2, 3, 0, &[], &[], &mut c);
        assert_eq!(c, vec![0f32; 6]);
    }

    #[test]
    fn packed_f32_bit_identical_to_reference_over_remainders() {
        let mut rng = Pcg32::seeded(7);
        for (m, n, k) in [
            (1usize, 1usize, 1usize),
            (3, 5, 7),
            (4, 8, 16),
            (5, 9, 3),
            (6, 7, 1),
            (17, 16, 21),
            (13, 23, 33),
            (33, 41, 40),
        ] {
            let mut a = vec![0f32; m * k];
            let mut b = vec![0f32; n * k];
            rng.fill_gaussian(&mut a, 1.0);
            rng.fill_gaussian(&mut b, 1.0);
            let mut want = vec![0f32; m * n];
            gemm_nt_f32(m, n, k, &a, &b, &mut want);
            let mut bp = vec![f32::NAN; packed_b_f32_len(n, k)]; // poison: pack must overwrite
            pack_b_f32(n, k, &b, &mut bp);
            let mut got = vec![7f32; m * n];
            gemm_packed_f32(m, n, k, &a, &bp, &mut got);
            assert_eq!(got, want, "dispatched packed m{m} n{n} k{k}");
            let mut got_s = vec![7f32; m * n];
            gemm_packed_f32_scalar(m, n, k, &a, &bp, &mut got_s);
            assert_eq!(got_s, want, "scalar packed m{m} n{n} k{k}");
        }
    }

    #[test]
    fn packed_i8_exact_over_remainders_and_odd_k() {
        let mut rng = Pcg32::seeded(8);
        for (m, n, k) in [
            (1usize, 3usize, 5usize),
            (4, 8, 9),
            (4, 3, 1),
            (6, 6, 6),
            (19, 11, 35),
            (9, 17, 2),
        ] {
            let a: Vec<i8> = (0..m * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            let b: Vec<i8> = (0..n * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            let mut want = vec![0i32; m * n];
            gemm_nt_i8_i32(m, n, k, &a, &b, &mut want);
            let mut bp = vec![77i8; packed_b_i8_len(n, k)]; // poison: pack must overwrite
            pack_b_i8(n, k, &b, &mut bp);
            let mut got = vec![-1i32; m * n];
            gemm_packed_i8_i32(m, n, k, &a, &bp, &mut got);
            assert_eq!(got, want, "dispatched packed m{m} n{n} k{k}");
            let mut got_s = vec![-1i32; m * n];
            gemm_packed_i8_i32_scalar(m, n, k, &a, &bp, &mut got_s);
            assert_eq!(got_s, want, "scalar packed m{m} n{n} k{k}");
        }
    }

    #[test]
    fn packed_zero_k_zeroes_c() {
        let mut c = vec![3f32; 6];
        gemm_packed_f32(2, 3, 0, &[], &[], &mut c);
        assert_eq!(c, vec![0f32; 6]);
    }

    #[test]
    fn i8_matches_naive_exactly() {
        let mut rng = Pcg32::seeded(6);
        for (m, n, k) in [(1usize, 3usize, 4usize), (6, 6, 6), (19, 11, 35), (70, 66, 9)] {
            let a: Vec<i8> = (0..m * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            let b: Vec<i8> = (0..n * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
            let mut want = vec![0i32; m * n];
            for i in 0..m {
                for j in 0..n {
                    let mut acc = 0i32;
                    for l in 0..k {
                        acc += a[i * k + l] as i32 * b[j * k + l] as i32;
                    }
                    want[i * n + j] = acc;
                }
            }
            let mut got = vec![-1i32; m * n];
            gemm_nt_i8_i32(m, n, k, &a, &b, &mut got);
            assert_eq!(got, want, "m{m} n{n} k{k}");
        }
    }

    #[test]
    fn kpair_helper_zero_pads_the_odd_tail() {
        assert_eq!(i8_kpair(&[5], 0), [5, 0], "k = 1");
        assert_eq!(i8_kpair(&[1, 2, 3], 0), [1, 2]);
        assert_eq!(i8_kpair(&[1, 2, 3], 1), [3, 0], "k = odd tail");
        assert_eq!(i8_kpair(&[1, 2, 3, 4], 1), [3, 4], "k = even, no pad");
        // sign extension survives the i16-halves packing
        let w = i8_pair_word([-1, -2]);
        assert_eq!(w as u32, 0xfffe_ffff);
        assert_eq!(i8_pair_word([3, 0]), 3);
    }

    #[test]
    fn blocking_override_is_bit_identical() {
        let _g = crate::linalg::simd::TEST_OVERRIDE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let (m, n, k) = (33, 41, 40);
        let mut rng = Pcg32::seeded(11);
        let mut a = vec![0f32; m * k];
        let mut b = vec![0f32; n * k];
        rng.fill_gaussian(&mut a, 1.0);
        rng.fill_gaussian(&mut b, 1.0);
        set_blocking_override(None);
        let mut want = vec![0f32; m * n];
        gemm_nt_f32(m, n, k, &a, &b, &mut want);
        let mut bp = vec![0f32; packed_b_f32_len(n, k)];
        pack_b_f32(n, k, &b, &mut bp);
        let mut candidates = Blocking::candidates().to_vec();
        candidates.push(Blocking { mc: 4, kc: 2, nc: 4 }); // degenerate: every block is a remainder
        candidates.push(Blocking { mc: 7, kc: 3, nc: 9 }); // odd kc rounds to the pair boundary
        for bl in candidates {
            set_blocking_override(Some(bl));
            let mut got = vec![7f32; m * n];
            gemm_nt_f32(m, n, k, &a, &b, &mut got);
            assert_eq!(got, want, "nt under {bl:?}");
            let mut gotp = vec![7f32; m * n];
            gemm_packed_f32(m, n, k, &a, &bp, &mut gotp);
            assert_eq!(gotp, want, "packed under {bl:?}");
        }
        set_blocking_override(None);
    }

    #[test]
    fn threaded_matches_single_thread_bitwise() {
        let _g = crate::linalg::simd::TEST_OVERRIDE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        // above PAR_MIN_MACS (65·256·130 ≈ 2.16M) with every remainder in play
        let (m, n, k) = (65, 256, 130);
        assert!((m * n * k) as u64 >= PAR_MIN_MACS, "shape must take the threaded path");
        let mut rng = Pcg32::seeded(12);
        let mut a = vec![0f32; m * k];
        let mut b = vec![0f32; n * k];
        rng.fill_gaussian(&mut a, 1.0);
        rng.fill_gaussian(&mut b, 1.0);
        let mut bp = vec![0f32; packed_b_f32_len(n, k)];
        pack_b_f32(n, k, &b, &mut bp);
        let ai: Vec<i8> = (0..m * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let bi: Vec<i8> = (0..n * k).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let mut bpi = vec![0i8; packed_b_i8_len(n, k)];
        pack_b_i8(n, k, &bi, &mut bpi);
        crate::util::par::set_thread_override(Some(1));
        let mut want = vec![0f32; m * n];
        gemm_packed_f32(m, n, k, &a, &bp, &mut want);
        let mut want_nt = vec![0f32; m * n];
        gemm_nt_f32(m, n, k, &a, &b, &mut want_nt);
        let mut want_i = vec![0i32; m * n];
        gemm_packed_i8_i32(m, n, k, &ai, &bpi, &mut want_i);
        for t in [2usize, 7] {
            crate::util::par::set_thread_override(Some(t));
            let mut got = vec![7f32; m * n];
            gemm_packed_f32(m, n, k, &a, &bp, &mut got);
            assert_eq!(got, want, "packed f32 at {t} threads");
            let mut got_nt = vec![7f32; m * n];
            gemm_nt_f32(m, n, k, &a, &b, &mut got_nt);
            assert_eq!(got_nt, want_nt, "nt f32 at {t} threads");
            let mut got_i = vec![-1i32; m * n];
            gemm_packed_i8_i32(m, n, k, &ai, &bpi, &mut got_i);
            assert_eq!(got_i, want_i, "packed i8 at {t} threads");
        }
        crate::util::par::set_thread_override(None);
        assert_eq!(want, want_nt, "packed and nt agree bitwise");
    }
}
