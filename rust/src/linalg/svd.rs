//! Singular values and condition numbers via one-sided Jacobi SVD.
//!
//! Table 1 reports κ(Aᵀ) = σ_max/σ_min for the output-transform matrix of
//! each fast-convolution algorithm; matrices are tiny (≤ ~16×16), so the
//! quadratically-convergent one-sided Jacobi method is exact enough at f64.

use super::Mat;

/// All singular values of `m` (descending).
pub fn singular_values(m: &Mat) -> Vec<f64> {
    // Work on A (rows>=cols makes the one-sided iteration cheaper).
    let a = if m.rows >= m.cols { m.clone() } else { m.transpose() };
    let (rows, cols) = (a.rows, a.cols);
    let mut u = a.data.clone(); // column-updated in place (row-major)

    let col = |u: &Vec<f64>, j: usize| -> Vec<f64> { (0..rows).map(|i| u[i * cols + j]).collect() };
    let _ = col;

    let max_sweeps = 60;
    let eps = 1e-15;
    for _ in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..cols {
            for q in (p + 1)..cols {
                // Compute [app apq; apq aqq] of A^T A for columns p,q.
                let (mut app, mut aqq, mut apq) = (0.0, 0.0, 0.0);
                for i in 0..rows {
                    let x = u[i * cols + p];
                    let y = u[i * cols + q];
                    app += x * x;
                    aqq += y * y;
                    apq += x * y;
                }
                off = off.max(apq.abs() / (app * aqq).sqrt().max(1e-300));
                if apq.abs() <= eps * (app * aqq).sqrt() {
                    continue;
                }
                // Jacobi rotation zeroing apq.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..rows {
                    let x = u[i * cols + p];
                    let y = u[i * cols + q];
                    u[i * cols + p] = c * x - s * y;
                    u[i * cols + q] = s * x + c * y;
                }
            }
        }
        if off < 1e-14 {
            break;
        }
    }
    let mut sv: Vec<f64> = (0..cols)
        .map(|j| (0..rows).map(|i| u[i * cols + j].powi(2)).sum::<f64>().sqrt())
        .collect();
    sv.sort_by(|a, b| b.partial_cmp(a).unwrap());
    sv
}

/// κ(m) = σ_max / σ_min over the nonzero singular spectrum of a (possibly
/// rectangular) matrix. For a rank-deficient matrix returns f64::INFINITY.
pub fn condition_number(m: &Mat) -> f64 {
    let sv = singular_values(m);
    let smax = sv[0];
    let smin = *sv.last().unwrap();
    if smin <= smax * 1e-13 {
        f64::INFINITY
    } else {
        smax / smin
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_kappa_one() {
        let mut m = Mat::zeros(4, 4);
        for i in 0..4 {
            m[(i, i)] = 1.0;
        }
        let sv = singular_values(&m);
        for s in &sv {
            assert!((s - 1.0).abs() < 1e-12);
        }
        assert!((condition_number(&m) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn diagonal_matrix() {
        let mut m = Mat::zeros(3, 3);
        m[(0, 0)] = 3.0;
        m[(1, 1)] = -2.0;
        m[(2, 2)] = 0.5;
        let sv = singular_values(&m);
        assert!((sv[0] - 3.0).abs() < 1e-12);
        assert!((sv[1] - 2.0).abs() < 1e-12);
        assert!((sv[2] - 0.5).abs() < 1e-12);
        assert!((condition_number(&m) - 6.0).abs() < 1e-10);
    }

    #[test]
    fn known_2x2() {
        // A = [[1, 1], [0, 1]] has singular values sqrt((3±sqrt5)/2).
        let m = Mat::from_vec(2, 2, vec![1.0, 1.0, 0.0, 1.0]);
        let sv = singular_values(&m);
        let s1 = ((3.0 + 5f64.sqrt()) / 2.0).sqrt();
        let s2 = ((3.0 - 5f64.sqrt()) / 2.0).sqrt();
        assert!((sv[0] - s1).abs() < 1e-12, "{sv:?}");
        assert!((sv[1] - s2).abs() < 1e-12);
    }

    #[test]
    fn rectangular_matches_transpose() {
        let m = Mat::from_vec(2, 4, vec![1.0, 2.0, 3.0, 4.0, -1.0, 0.5, 2.0, 1.0]);
        let a = singular_values(&m);
        let b = singular_values(&m.transpose());
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-10);
        }
    }

    #[test]
    fn orthogonal_blocks() {
        // Rotation matrix: both singular values 1.
        let th = 0.7f64;
        let m = Mat::from_vec(2, 2, vec![th.cos(), -th.sin(), th.sin(), th.cos()]);
        assert!((condition_number(&m) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn singular_matrix_infinite_kappa() {
        let m = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 4.0]);
        assert!(condition_number(&m).is_infinite());
    }
}
