//! Exact rational numbers over i128.
//!
//! All fast-convolution transformation matrices have small rational entries
//! (denominators divide N for SFC, products of point differences for
//! Toom-Cook), so i128 never comes close to overflow; we still check with
//! debug assertions.

use std::cmp::Ordering;
use std::fmt;
use std::ops::{Add, AddAssign, Div, Mul, Neg, Sub};

/// A reduced fraction `num/den`, `den > 0`.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct Frac {
    /// numerator (sign carrier)
    pub num: i128,
    /// denominator, always positive
    pub den: i128,
}

fn gcd(a: i128, b: i128) -> i128 {
    let (mut a, mut b) = (a.abs(), b.abs());
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

impl Frac {
    /// The fraction 0/1.
    pub const ZERO: Frac = Frac { num: 0, den: 1 };
    /// The fraction 1/1.
    pub const ONE: Frac = Frac { num: 1, den: 1 };

    /// Reduced fraction num/den (panics on zero denominator).
    pub fn new(num: i128, den: i128) -> Self {
        assert!(den != 0, "zero denominator");
        let g = gcd(num, den).max(1);
        let sign = if den < 0 { -1 } else { 1 };
        Frac { num: sign * num / g, den: sign * den / g }
    }

    /// The integer v as a fraction.
    pub fn int(v: i128) -> Self {
        Frac { num: v, den: 1 }
    }

    /// True for 0.
    pub fn is_zero(&self) -> bool {
        self.num == 0
    }

    /// True when the denominator is 1.
    pub fn is_integer(&self) -> bool {
        self.den == 1
    }

    /// Nearest f64 value.
    pub fn to_f64(&self) -> f64 {
        self.num as f64 / self.den as f64
    }

    /// Absolute value.
    pub fn abs(&self) -> Frac {
        Frac { num: self.num.abs(), den: self.den }
    }

    /// Reciprocal (panics on zero).
    pub fn recip(&self) -> Frac {
        assert!(self.num != 0, "reciprocal of zero");
        Frac::new(self.den, self.num)
    }

    /// Non-negative integer power.
    pub fn pow(&self, e: u32) -> Frac {
        let mut out = Frac::ONE;
        for _ in 0..e {
            out = out * *self;
        }
        out
    }
}

impl Add for Frac {
    type Output = Frac;
    fn add(self, o: Frac) -> Frac {
        Frac::new(self.num * o.den + o.num * self.den, self.den * o.den)
    }
}

impl AddAssign for Frac {
    fn add_assign(&mut self, o: Frac) {
        *self = *self + o;
    }
}

impl Sub for Frac {
    type Output = Frac;
    fn sub(self, o: Frac) -> Frac {
        Frac::new(self.num * o.den - o.num * self.den, self.den * o.den)
    }
}

impl Mul for Frac {
    type Output = Frac;
    fn mul(self, o: Frac) -> Frac {
        Frac::new(self.num * o.num, self.den * o.den)
    }
}

impl Div for Frac {
    type Output = Frac;
    fn div(self, o: Frac) -> Frac {
        assert!(o.num != 0, "division by zero");
        Frac::new(self.num * o.den, self.den * o.num)
    }
}

impl Neg for Frac {
    type Output = Frac;
    fn neg(self) -> Frac {
        Frac { num: -self.num, den: self.den }
    }
}

impl PartialOrd for Frac {
    fn partial_cmp(&self, o: &Frac) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}

impl Ord for Frac {
    fn cmp(&self, o: &Frac) -> Ordering {
        (self.num * o.den).cmp(&(o.num * self.den))
    }
}

impl fmt::Debug for Frac {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.den == 1 {
            write!(f, "{}", self.num)
        } else {
            write!(f, "{}/{}", self.num, self.den)
        }
    }
}

impl fmt::Display for Frac {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(self, f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic() {
        let a = Frac::new(1, 2);
        let b = Frac::new(1, 3);
        assert_eq!(a + b, Frac::new(5, 6));
        assert_eq!(a - b, Frac::new(1, 6));
        assert_eq!(a * b, Frac::new(1, 6));
        assert_eq!(a / b, Frac::new(3, 2));
        assert_eq!(-a, Frac::new(-1, 2));
    }

    #[test]
    fn reduction_and_sign() {
        assert_eq!(Frac::new(2, 4), Frac::new(1, 2));
        assert_eq!(Frac::new(1, -2), Frac::new(-1, 2));
        assert_eq!(Frac::new(-3, -6), Frac::new(1, 2));
        assert_eq!(Frac::new(0, -5), Frac::ZERO);
    }

    #[test]
    fn ordering() {
        assert!(Frac::new(1, 3) < Frac::new(1, 2));
        assert!(Frac::new(-1, 2) < Frac::ZERO);
        assert_eq!(Frac::new(2, 6).cmp(&Frac::new(1, 3)), Ordering::Equal);
    }

    #[test]
    fn pow_and_recip() {
        assert_eq!(Frac::new(2, 3).pow(3), Frac::new(8, 27));
        assert_eq!(Frac::new(2, 3).recip(), Frac::new(3, 2));
        assert_eq!(Frac::new(-5, 4).recip(), Frac::new(-4, 5));
    }

    #[test]
    fn to_f64_exact_halves() {
        assert_eq!(Frac::new(3, 4).to_f64(), 0.75);
        assert_eq!(Frac::new(-7, 2).to_f64(), -3.5);
    }
}
