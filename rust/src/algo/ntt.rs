//! Number-theoretic transform convolution — the second related-work
//! baseline (§2/§3, Table 3's NTT accelerator column).
//!
//! Exact integer circular/linear convolution in F_p with
//! p = 998244353 = 119·2²³ + 1 (primitive root 3). Demonstrates the
//! paper's criticism: bit-exact results, but operands in the ⊙ stage carry
//! full output bit-width (mod-p words), so quantized datapaths gain
//! nothing from int8 inputs.

/// The NTT prime p = 119·2²³ + 1.
pub const P: u64 = 998_244_353;
/// A primitive root of F_p (generates the 2²³-th roots of unity).
pub const PRIMITIVE_ROOT: u64 = 3;

#[inline]
fn pow_mod(mut base: u64, mut exp: u64, p: u64) -> u64 {
    let mut acc = 1u64;
    base %= p;
    while exp > 0 {
        if exp & 1 == 1 {
            acc = acc * base % p;
        }
        base = base * base % p;
        exp >>= 1;
    }
    acc
}

#[inline]
fn inv_mod(a: u64, p: u64) -> u64 {
    pow_mod(a, p - 2, p)
}

/// In-place NTT (length must be a power of two dividing 2^23).
pub fn ntt_inplace(a: &mut [u64], inverse: bool) {
    let n = a.len();
    assert!(n.is_power_of_two() && n <= (1 << 23), "bad NTT length {n}");
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            a.swap(i, j);
        }
    }
    let mut len = 2;
    while len <= n {
        let mut w = pow_mod(PRIMITIVE_ROOT, (P - 1) / len as u64, P);
        if inverse {
            w = inv_mod(w, P);
        }
        let mut i = 0;
        while i < n {
            let mut cur = 1u64;
            for k in 0..len / 2 {
                let u = a[i + k];
                let v = a[i + k + len / 2] * cur % P;
                a[i + k] = (u + v) % P;
                a[i + k + len / 2] = (u + P - v) % P;
                cur = cur * w % P;
            }
            i += len;
        }
        len <<= 1;
    }
    if inverse {
        let ninv = inv_mod(n as u64, P);
        for v in a.iter_mut() {
            *v = *v * ninv % P;
        }
    }
}

/// Exact linear convolution (full) of two i64 sequences through the NTT.
/// Outputs must satisfy |Σ products| < p/2 (true for int8/int16 CNN
/// workloads); negatives map into F_p symmetrically.
pub fn ntt_conv_full(x: &[i64], f: &[i64]) -> Vec<i64> {
    let out_len = x.len() + f.len() - 1;
    let n = out_len.next_power_of_two();
    let enc = |v: i64| -> u64 { v.rem_euclid(P as i64) as u64 };
    let mut a: Vec<u64> = x.iter().map(|&v| enc(v)).chain(std::iter::repeat(0)).take(n).collect();
    let mut b: Vec<u64> = f.iter().map(|&v| enc(v)).chain(std::iter::repeat(0)).take(n).collect();
    ntt_inplace(&mut a, false);
    ntt_inplace(&mut b, false);
    for i in 0..n {
        a[i] = a[i] * b[i] % P;
    }
    ntt_inplace(&mut a, true);
    a.truncate(out_len);
    a.into_iter()
        .map(|v| if v > P / 2 { v as i64 - P as i64 } else { v as i64 })
        .collect()
}

/// "Valid" correlation through the NTT.
pub fn ntt_corr_valid(x: &[i64], f: &[i64]) -> Vec<i64> {
    let flipped: Vec<i64> = f.iter().rev().copied().collect();
    let full = ntt_conv_full(x, &flipped);
    full[f.len() - 1..x.len()].to_vec()
}

/// The paper's §3 point: to convolve N-bit inputs the NTT transform-domain
/// operands carry the full output width (mod-p words ≈ 30 bit here, or
/// ≥ 2N bits in the minimal-prime setting). Returns the ⊙-operand width.
pub fn ntt_odot_bits(input_bits: u32, acc_len: usize) -> u32 {
    let needed = 2 * input_bits + (acc_len as f64).log2().ceil() as u32;
    needed.max(2 * input_bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn ntt_round_trip() {
        let mut a: Vec<u64> = (0..32).map(|i| (i * 7 + 3) % 97).collect();
        let orig = a.clone();
        ntt_inplace(&mut a, false);
        ntt_inplace(&mut a, true);
        assert_eq!(a, orig);
    }

    #[test]
    fn conv_is_bit_exact() {
        let mut rng = Pcg32::seeded(123);
        for (lx, lf) in [(8, 3), (16, 5), (30, 7)] {
            let x: Vec<i64> = (0..lx).map(|_| rng.below(255) as i64 - 127).collect();
            let f: Vec<i64> = (0..lf).map(|_| rng.below(255) as i64 - 127).collect();
            let got = ntt_corr_valid(&x, &f);
            let want: Vec<i64> = (0..lx - lf + 1)
                .map(|k| f.iter().enumerate().map(|(r, &fv)| fv * x[k + r]).sum())
                .collect();
            assert_eq!(got, want, "{lx}x{lf}");
        }
    }

    #[test]
    fn negative_values_handled() {
        let x = [-100i64, 50, -3, 7, 90, -128];
        let f = [-1i64, 2, -3];
        let got = ntt_corr_valid(&x, &f);
        let want: Vec<i64> = (0..4).map(|k| -x[k] + 2 * x[k + 1] - 3 * x[k + 2]).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn odot_width_is_wide() {
        // int8 inputs still need ≥20-bit multipliers in the NTT domain —
        // the efficiency argument of §3 ("Precision Requirement").
        assert!(ntt_odot_bits(8, 9) >= 20);
        assert!(ntt_odot_bits(8, 9) > 2 * 8);
    }
}
