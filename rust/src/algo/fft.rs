//! Classic floating-point FFT convolution — the related-work baseline the
//! paper's §3/§4 argues against (irrational twiddle factors, circular-only
//! outputs, complex arithmetic overhead).
//!
//! Radix-2 iterative Cooley–Tukey over f64 complex pairs; linear
//! convolution via zero-padding to the next power of two. The arithmetic
//! model (`fft_real_mults`) counts the 1.5-real-mult-per-complex-product
//! cost the paper quotes after Hermitian symmetry + fast complex multiply.

/// In-place radix-2 DIT FFT. `re`/`im` length must be a power of two.
/// `inverse` applies the conjugate transform (caller divides by n).
pub fn fft_inplace(re: &mut [f64], im: &mut [f64], inverse: bool) {
    let n = re.len();
    assert!(n.is_power_of_two(), "fft length must be a power of two");
    assert_eq!(im.len(), n);
    // bit reversal
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            re.swap(i, j);
            im.swap(i, j);
        }
    }
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * std::f64::consts::PI / len as f64;
        let (wr, wi) = (ang.cos(), ang.sin());
        let mut i = 0;
        while i < n {
            let (mut cr, mut ci) = (1.0f64, 0.0f64);
            for k in 0..len / 2 {
                let (ur, ui) = (re[i + k], im[i + k]);
                let (vr, vi) = (
                    re[i + k + len / 2] * cr - im[i + k + len / 2] * ci,
                    re[i + k + len / 2] * ci + im[i + k + len / 2] * cr,
                );
                re[i + k] = ur + vr;
                im[i + k] = ui + vi;
                re[i + k + len / 2] = ur - vr;
                im[i + k + len / 2] = ui - vi;
                let ncr = cr * wr - ci * wi;
                ci = cr * wi + ci * wr;
                cr = ncr;
            }
            i += len;
        }
        len <<= 1;
    }
}

/// Linear convolution (full) of two real sequences via zero-padded FFT.
pub fn fft_conv_full(x: &[f64], f: &[f64]) -> Vec<f64> {
    let out_len = x.len() + f.len() - 1;
    let n = out_len.next_power_of_two();
    let mut xr = vec![0.0; n];
    let mut xi = vec![0.0; n];
    let mut fr = vec![0.0; n];
    let mut fi = vec![0.0; n];
    xr[..x.len()].copy_from_slice(x);
    fr[..f.len()].copy_from_slice(f);
    fft_inplace(&mut xr, &mut xi, false);
    fft_inplace(&mut fr, &mut fi, false);
    for i in 0..n {
        let (ar, ai) = (xr[i], xi[i]);
        xr[i] = ar * fr[i] - ai * fi[i];
        xi[i] = ar * fi[i] + ai * fr[i];
    }
    fft_inplace(&mut xr, &mut xi, true);
    (0..out_len).map(|i| xr[i] / n as f64).collect()
}

/// "Valid" correlation via FFT (flip the filter, take the interior).
pub fn fft_corr_valid(x: &[f64], f: &[f64]) -> Vec<f64> {
    let flipped: Vec<f64> = f.iter().rev().copied().collect();
    let full = fft_conv_full(x, &flipped);
    full[f.len() - 1..x.len()].to_vec()
}

/// Real multiplications for an N-point real-sequence FFT convolution tile
/// in the paper's accounting: Hermitian symmetry keeps ~N/2 complex bins
/// and each complex product costs 3 real mults ("1.5 per complex value").
pub fn fft_real_mults(n: usize) -> usize {
    // bins 0 and N/2 are real (1 mult); remaining N/2−1 bins complex (3).
    2 + 3 * (n / 2 - 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::bilinear::direct_conv1d;
    use crate::util::Pcg32;

    #[test]
    fn round_trip() {
        let mut re: Vec<f64> = (0..16).map(|i| (i as f64 * 0.37).sin()).collect();
        let orig = re.clone();
        let mut im = vec![0.0; 16];
        fft_inplace(&mut re, &mut im, false);
        fft_inplace(&mut re, &mut im, true);
        for (a, b) in re.iter().zip(&orig) {
            assert!((a / 16.0 - b).abs() < 1e-12);
        }
    }

    #[test]
    fn conv_matches_naive() {
        let mut rng = Pcg32::seeded(77);
        for (lx, lf) in [(8, 3), (13, 5), (29, 7), (6, 6)] {
            let x: Vec<f64> = (0..lx).map(|_| rng.next_gaussian()).collect();
            let f: Vec<f64> = (0..lf).map(|_| rng.next_gaussian()).collect();
            let got = fft_corr_valid(&x, &f);
            let want = direct_conv1d(&x, &f);
            assert_eq!(got.len(), want.len());
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-9, "{lx}x{lf}");
            }
        }
    }

    #[test]
    fn parseval_energy() {
        let mut rng = Pcg32::seeded(3);
        let mut re: Vec<f64> = (0..64).map(|_| rng.next_gaussian()).collect();
        let mut im = vec![0.0; 64];
        let e_time: f64 = re.iter().map(|v| v * v).sum();
        fft_inplace(&mut re, &mut im, false);
        let e_freq: f64 = re.iter().zip(&im).map(|(r, i)| r * r + i * i).sum::<f64>() / 64.0;
        assert!((e_time - e_freq).abs() < 1e-9 * e_time.max(1.0));
    }

    #[test]
    fn mult_model() {
        // DFT-6-as-FFT costs 8 real mults per tile — identical to the
        // symbolic form; the difference is the transform arithmetic, not ⊙.
        assert_eq!(fft_real_mults(6), 8);
        assert_eq!(fft_real_mults(4), 5);
        assert_eq!(fft_real_mults(8), 11);
    }
}
