//! The symbolic ring ℚ[s]/(s² − c₁·s − c₀).
//!
//! The paper's key observation (§4.1): at N ∈ {3, 4, 6} DFT points every
//! twiddle factor e^{±2πjk/N} is expressible as a + b·s with *integer*
//! a, b, where s is a primitive root satisfying a monic quadratic:
//!
//!   N = 3:  s = e^{2πj/3},  s² = −1 − s      (c₀ = −1, c₁ = −1)
//!   N = 4:  s = j,          s² = −1          (c₀ = −1, c₁ =  0)
//!   N = 6:  s = e^{πj/3},   s² = s − 1       (c₀ = −1, c₁ =  1)
//!
//! Arithmetic in this ring never leaves integer (rational) coefficients, so
//! "irrational" Fourier transforms become exact addition networks.

use crate::linalg::Frac;
use std::ops::{Add, Mul, Neg, Sub};

/// Reduction rule s² = c0 + c1·s for the symbol s, plus the expression of
/// conj(s) = k0 + k1·s in the same basis (needed for the inverse DFT of
/// real sequences via Hermitian symmetry).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Rule {
    /// DFT length N this rule belongs to
    pub n: usize,
    /// s² = c0 + c1·s: constant coefficient
    pub c0: i128,
    /// s² = c0 + c1·s: linear coefficient
    pub c1: i128,
    /// conj(s) = k0 + k1·s: constant coefficient
    pub k0: i128,
    /// conj(s) = k0 + k1·s: linear coefficient
    pub k1: i128,
}

impl Rule {
    /// The ring rule for the N-point symbolic DFT. Panics for N that need
    /// higher-degree minimal polynomials (the paper restricts to 3, 4, 6;
    /// N = 2 is trivially rational and uses s = −1 with s² = 1).
    pub fn for_points(n: usize) -> Rule {
        match n {
            // s = -1 (the only non-trivial 2nd root); s^2 = 1, conj(s) = s.
            2 => Rule { n, c0: 1, c1: 0, k0: 0, k1: 1 },
            // s = e^{2πj/3}: s^2 + s + 1 = 0; conj(s) = s^2 = -1 - s.
            3 => Rule { n, c0: -1, c1: -1, k0: -1, k1: -1 },
            // s = j: s^2 = -1; conj(j) = -j.
            4 => Rule { n, c0: -1, c1: 0, k0: 0, k1: -1 },
            // s = e^{πj/3}: s^2 - s + 1 = 0 => s^2 = s - 1; conj(s) = 1 - s.
            6 => Rule { n, c0: -1, c1: 1, k0: 1, k1: -1 },
            _ => panic!("symbolic DFT supports N in {{2,3,4,6}}, got {n}"),
        }
    }

    /// Numeric value of s for verification: the primitive root used above.
    pub fn s_complex(&self) -> (f64, f64) {
        use std::f64::consts::PI;
        match self.n {
            2 => (-1.0, 0.0),
            3 => ((2.0 * PI / 3.0).cos(), (2.0 * PI / 3.0).sin()),
            4 => (0.0, 1.0),
            6 => ((PI / 3.0).cos(), (PI / 3.0).sin()),
            _ => unreachable!(),
        }
    }
}

/// An element a + b·s of the ring.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Sym {
    /// rational part
    pub a: Frac,
    /// coefficient of the symbol s
    pub b: Frac,
    /// the ring's reduction rule
    pub rule: Rule,
}

impl Sym {
    /// The element a + b·s.
    pub fn new(rule: Rule, a: Frac, b: Frac) -> Sym {
        Sym { a, b, rule }
    }

    /// The additive identity.
    pub fn zero(rule: Rule) -> Sym {
        Sym::new(rule, Frac::ZERO, Frac::ZERO)
    }

    /// The multiplicative identity.
    pub fn one(rule: Rule) -> Sym {
        Sym::new(rule, Frac::ONE, Frac::ZERO)
    }

    /// The symbol s itself.
    pub fn s(rule: Rule) -> Sym {
        Sym::new(rule, Frac::ZERO, Frac::ONE)
    }

    /// The rational integer v.
    pub fn int(rule: Rule, v: i128) -> Sym {
        Sym::new(rule, Frac::int(v), Frac::ZERO)
    }

    /// True if both components are zero.
    pub fn is_zero(&self) -> bool {
        self.a.is_zero() && self.b.is_zero()
    }

    /// True if the element lies in ℚ (no s component).
    pub fn is_rational(&self) -> bool {
        self.b.is_zero()
    }

    /// Complex conjugate, re-expressed in the (1, s) basis via the rule's
    /// conj(s) = k0 + k1 s.
    pub fn conj(&self) -> Sym {
        let k0 = Frac::int(self.rule.k0);
        let k1 = Frac::int(self.rule.k1);
        Sym::new(self.rule, self.a + self.b * k0, self.b * k1)
    }

    /// s^e computed by repeated ring multiplication.
    pub fn s_pow(rule: Rule, e: usize) -> Sym {
        let mut out = Sym::one(rule);
        for _ in 0..e {
            out = out * Sym::s(rule);
        }
        out
    }

    /// Numeric complex value (for cross-checking against a float DFT).
    pub fn to_complex(&self) -> (f64, f64) {
        let (sr, si) = self.rule.s_complex();
        (self.a.to_f64() + self.b.to_f64() * sr, self.b.to_f64() * si)
    }
}

impl Add for Sym {
    type Output = Sym;
    fn add(self, o: Sym) -> Sym {
        debug_assert_eq!(self.rule, o.rule);
        Sym::new(self.rule, self.a + o.a, self.b + o.b)
    }
}

impl Sub for Sym {
    type Output = Sym;
    fn sub(self, o: Sym) -> Sym {
        debug_assert_eq!(self.rule, o.rule);
        Sym::new(self.rule, self.a - o.a, self.b - o.b)
    }
}

impl Neg for Sym {
    type Output = Sym;
    fn neg(self) -> Sym {
        Sym::new(self.rule, -self.a, -self.b)
    }
}

impl Mul for Sym {
    type Output = Sym;
    fn mul(self, o: Sym) -> Sym {
        debug_assert_eq!(self.rule, o.rule);
        // (a0 + b0 s)(a1 + b1 s) = a0a1 + (a0b1 + a1b0)s + b0b1 s^2
        //                        = (a0a1 + c0 b0b1) + (a0b1 + a1b0 + c1 b0b1)s
        let c0 = Frac::int(self.rule.c0);
        let c1 = Frac::int(self.rule.c1);
        let bb = self.b * o.b;
        Sym::new(self.rule, self.a * o.a + c0 * bb, self.a * o.b + o.a * self.b + c1 * bb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(x: (f64, f64), y: (f64, f64)) -> bool {
        (x.0 - y.0).abs() < 1e-12 && (x.1 - y.1).abs() < 1e-12
    }

    #[test]
    fn s_has_order_n() {
        for n in [3usize, 4, 6] {
            let rule = Rule::for_points(n);
            let sn = Sym::s_pow(rule, n);
            assert_eq!(sn, Sym::one(rule), "s^{n} should be 1 for N={n}");
            for e in 1..n {
                assert_ne!(Sym::s_pow(rule, e), Sym::one(rule), "order must be exactly {n}");
            }
        }
    }

    #[test]
    fn reduction_matches_complex_arithmetic() {
        for n in [2usize, 3, 4, 6] {
            let rule = Rule::for_points(n);
            for e in 0..2 * n {
                let sym = Sym::s_pow(rule, e);
                let (sr, si) = rule.s_complex();
                // complex s^e
                let (mut cr, mut ci) = (1.0f64, 0.0f64);
                for _ in 0..e {
                    let nr = cr * sr - ci * si;
                    ci = cr * si + ci * sr;
                    cr = nr;
                }
                assert!(close(sym.to_complex(), (cr, ci)), "N={n} e={e}");
            }
        }
    }

    #[test]
    fn powers_are_first_order_integer() {
        // The paper's premise: all twiddle factors have integer (a, b).
        for n in [3usize, 4, 6] {
            let rule = Rule::for_points(n);
            for e in 0..n {
                let p = Sym::s_pow(rule, e);
                assert!(p.a.is_integer() && p.b.is_integer(), "N={n} s^{e} = {p:?}");
                assert!(p.a.num.abs() <= 1 && p.b.num.abs() <= 1, "coefficients in {{-1,0,1}}");
            }
        }
    }

    #[test]
    fn conj_is_involution_and_matches_complex() {
        for n in [3usize, 4, 6] {
            let rule = Rule::for_points(n);
            for e in 0..n {
                let p = Sym::s_pow(rule, e);
                assert_eq!(p.conj().conj(), p);
                let (re, im) = p.to_complex();
                assert!(close(p.conj().to_complex(), (re, -im)), "N={n} e={e}");
            }
        }
    }

    #[test]
    fn product_distributes() {
        let rule = Rule::for_points(6);
        let x = Sym::new(rule, Frac::int(2), Frac::int(-3));
        let y = Sym::new(rule, Frac::int(-1), Frac::int(5));
        let z = Sym::new(rule, Frac::int(4), Frac::int(1));
        assert_eq!(x * (y + z), x * y + x * z);
        assert_eq!((x * y) * z, x * (y * z));
    }
}
