//! Named catalog of every algorithm evaluated in the paper — Table 1's
//! row set plus the FFT/NTT related-work baselines (Table 3) — so the
//! error harness, BOPs model, engine layer and benches all reference one
//! source of truth. The [`crate::engine`] selector seeds its engine list
//! from this catalog.

use super::bilinear::Bilinear;
use super::{correction, toomcook};

/// Algorithm family of one catalog row.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlgoKind {
    /// nested-loop spatial convolution
    Direct,
    /// Toom-Cook/Winograd minimal filtering
    Winograd,
    /// the paper's symbolic-Fourier algorithm with corrections
    Sfc,
    /// whole-image float FFT convolution (related work, §2)
    Fft,
    /// whole-image exact integer NTT convolution (related work, Table 3)
    Ntt,
}

/// One catalog row: how to build the algorithm plus its Table-1 identity.
/// FFT/NTT rows are not bilinear (no (G, Bᵀ, Aᵀ) triple); their executors
/// live in [`crate::engine::exec`] and `n`/`m` are 0.
#[derive(Clone, Debug)]
pub struct AlgoSpec {
    /// catalog name (also the engine / CLI handle)
    pub name: &'static str,
    /// algorithm family
    pub kind: AlgoKind,
    /// transform points (SFC) — 0 for direct/Winograd/FFT/NTT
    pub n: usize,
    /// output tile — 0 for the whole-image FFT/NTT baselines
    pub m: usize,
    /// kernel size — 0 means "any kernel" (FFT/NTT)
    pub r: usize,
}

impl AlgoSpec {
    /// Does this row have a bilinear (G, Bᵀ, Aᵀ) realization?
    pub fn is_bilinear(&self) -> bool {
        matches!(self.kind, AlgoKind::Direct | AlgoKind::Winograd | AlgoKind::Sfc)
    }

    /// The bilinear realization, when one exists.
    pub fn bilinear(&self) -> Option<Bilinear> {
        match self.kind {
            AlgoKind::Direct => Some(Bilinear::direct(self.r)),
            AlgoKind::Winograd => Some(toomcook::winograd(self.m, self.r)),
            AlgoKind::Sfc => Some(correction::sfc(self.n, self.m, self.r)),
            AlgoKind::Fft | AlgoKind::Ntt => None,
        }
    }

    /// Build the bilinear algorithm; panics for the FFT/NTT rows (use
    /// [`AlgoSpec::bilinear`] when iterating the whole catalog).
    pub fn build(&self) -> Bilinear {
        self.bilinear()
            .unwrap_or_else(|| panic!("{} has no bilinear realization", self.name))
    }
}

/// The Table-1 row set in the paper's order, followed by the Table-3
/// related-work baselines.
pub fn catalog() -> Vec<AlgoSpec> {
    vec![
        AlgoSpec { name: "direct", kind: AlgoKind::Direct, n: 0, m: 1, r: 3 },
        AlgoSpec { name: "Wino(2x2,3x3)", kind: AlgoKind::Winograd, n: 0, m: 2, r: 3 },
        AlgoSpec { name: "Wino(3x3,3x3)", kind: AlgoKind::Winograd, n: 0, m: 3, r: 3 },
        AlgoSpec { name: "Wino(4x4,3x3)", kind: AlgoKind::Winograd, n: 0, m: 4, r: 3 },
        AlgoSpec { name: "SFC-4(4x4,3x3)", kind: AlgoKind::Sfc, n: 4, m: 4, r: 3 },
        AlgoSpec { name: "SFC-6(6x6,3x3)", kind: AlgoKind::Sfc, n: 6, m: 6, r: 3 },
        AlgoSpec { name: "SFC-6(7x7,3x3)", kind: AlgoKind::Sfc, n: 6, m: 7, r: 3 },
        AlgoSpec { name: "Wino(2x2,5x5)", kind: AlgoKind::Winograd, n: 0, m: 2, r: 5 },
        AlgoSpec { name: "SFC-6(6x6,5x5)", kind: AlgoKind::Sfc, n: 6, m: 6, r: 5 },
        AlgoSpec { name: "Wino(2x2,7x7)", kind: AlgoKind::Winograd, n: 0, m: 2, r: 7 },
        AlgoSpec { name: "SFC-6(4x4,7x7)", kind: AlgoKind::Sfc, n: 6, m: 4, r: 7 },
        AlgoSpec { name: "FFT", kind: AlgoKind::Fft, n: 0, m: 0, r: 0 },
        AlgoSpec { name: "NTT", kind: AlgoKind::Ntt, n: 0, m: 0, r: 0 },
    ]
}

/// Look a spec up by (case-insensitive) name.
pub fn by_name(name: &str) -> Option<AlgoSpec> {
    let needle = name.to_ascii_lowercase();
    catalog().into_iter().find(|s| s.name.to_ascii_lowercase() == needle)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_bilinear_entries_build_and_validate() {
        let mut built = 0;
        for spec in catalog() {
            let Some(algo) = spec.bilinear() else { continue };
            // Bilinear::validate runs inside the builders
            assert!(algo.t >= algo.m, "{}", spec.name);
            built += 1;
        }
        assert_eq!(built, 11, "Table 1 has 11 bilinear rows");
    }

    #[test]
    fn lookup_by_name() {
        assert!(by_name("sfc-6(7x7,3x3)").is_some());
        assert!(by_name("Wino(4x4,3x3)").is_some());
        assert!(by_name("fft").is_some());
        assert!(by_name("ntt").is_some());
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn catalog_matches_table1_plus_baselines() {
        let rows = catalog();
        assert_eq!(rows.len(), 13);
        assert_eq!(rows.iter().filter(|s| s.is_bilinear()).count(), 11);
        assert!(rows.iter().any(|s| s.kind == AlgoKind::Fft));
        assert!(rows.iter().any(|s| s.kind == AlgoKind::Ntt));
    }

    #[test]
    fn fft_ntt_rows_have_no_bilinear_form() {
        assert!(by_name("FFT").unwrap().bilinear().is_none());
        assert!(by_name("NTT").unwrap().bilinear().is_none());
    }
}
