//! Named catalog of every algorithm evaluated in the paper, so the error
//! harness, BOPs model, engine and benches all reference one source of
//! truth (Table 1's row set, plus the engine's working set).

use super::bilinear::Bilinear;
use super::{correction, toomcook};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlgoKind {
    Direct,
    Winograd,
    Sfc,
}

/// One catalog row: how to build the algorithm plus its Table-1 identity.
#[derive(Clone, Debug)]
pub struct AlgoSpec {
    pub name: &'static str,
    pub kind: AlgoKind,
    /// transform points (SFC) — 0 for direct/Winograd
    pub n: usize,
    /// output tile
    pub m: usize,
    /// kernel size
    pub r: usize,
}

impl AlgoSpec {
    pub fn build(&self) -> Bilinear {
        match self.kind {
            AlgoKind::Direct => Bilinear::direct(self.r),
            AlgoKind::Winograd => toomcook::winograd(self.m, self.r),
            AlgoKind::Sfc => correction::sfc(self.n, self.m, self.r),
        }
    }
}

/// The Table-1 row set, in the paper's order.
pub fn catalog() -> Vec<AlgoSpec> {
    vec![
        AlgoSpec { name: "direct", kind: AlgoKind::Direct, n: 0, m: 1, r: 3 },
        AlgoSpec { name: "Wino(2x2,3x3)", kind: AlgoKind::Winograd, n: 0, m: 2, r: 3 },
        AlgoSpec { name: "Wino(3x3,3x3)", kind: AlgoKind::Winograd, n: 0, m: 3, r: 3 },
        AlgoSpec { name: "Wino(4x4,3x3)", kind: AlgoKind::Winograd, n: 0, m: 4, r: 3 },
        AlgoSpec { name: "SFC-4(4x4,3x3)", kind: AlgoKind::Sfc, n: 4, m: 4, r: 3 },
        AlgoSpec { name: "SFC-6(6x6,3x3)", kind: AlgoKind::Sfc, n: 6, m: 6, r: 3 },
        AlgoSpec { name: "SFC-6(7x7,3x3)", kind: AlgoKind::Sfc, n: 6, m: 7, r: 3 },
        AlgoSpec { name: "Wino(2x2,5x5)", kind: AlgoKind::Winograd, n: 0, m: 2, r: 5 },
        AlgoSpec { name: "SFC-6(6x6,5x5)", kind: AlgoKind::Sfc, n: 6, m: 6, r: 5 },
        AlgoSpec { name: "Wino(2x2,7x7)", kind: AlgoKind::Winograd, n: 0, m: 2, r: 7 },
        AlgoSpec { name: "SFC-6(4x4,7x7)", kind: AlgoKind::Sfc, n: 6, m: 4, r: 7 },
    ]
}

/// Look a spec up by (case-insensitive) name.
pub fn by_name(name: &str) -> Option<AlgoSpec> {
    let needle = name.to_ascii_lowercase();
    catalog().into_iter().find(|s| s.name.to_ascii_lowercase() == needle)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_catalog_entries_build_and_validate() {
        for spec in catalog() {
            let algo = spec.build(); // Bilinear::validate runs inside builders
            assert!(algo.t >= algo.m, "{}", spec.name);
        }
    }

    #[test]
    fn lookup_by_name() {
        assert!(by_name("sfc-6(7x7,3x3)").is_some());
        assert!(by_name("Wino(4x4,3x3)").is_some());
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn catalog_matches_table1_rows() {
        assert_eq!(catalog().len(), 11);
    }
}
