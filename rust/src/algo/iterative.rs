//! Appendix B — iterative SFC convolution for very large kernels
//! (7×7 … 51×51, the modern large-kernel depthwise regime).
//!
//! The kernel is split into a grid of R_t×R_t sub-kernels; each sub-kernel
//! convolves the feature map with a tiled SFC algorithm and the partial
//! results are shifted and summed (iteration 1, implemented functionally
//! and verified against naive convolution). Iteration 2 — accelerating the
//! shift-and-sum combination itself with a second SFC pass over the tile
//! grid — multiplies the two algorithms' counts; we model it analytically
//! exactly as the paper does: SFC(6,5)∘SFC(5,6) ⇒ 132 × 132 = 17,424
//! multiplications for a 29×29 kernel on a 26×26 map (≈3% of direct).

use super::bilinear::Bilinear;
use super::correction::sfc;
use crate::linalg::Mat;

/// 2-D "same"-ish large-kernel convolution by kernel decomposition:
/// output has size (H−R+1)×(W−R+1) (valid correlation), computed by
/// splitting the R×R kernel into ⌈R/rt⌉² sub-kernels of size ≤ rt×rt and
/// accumulating each sub-kernel's contribution via the supplied tiled
/// algorithm.
pub fn iterative_conv2d(x: &Mat, kernel: &Mat, algo: &Bilinear) -> Mat {
    assert_eq!(kernel.rows, kernel.cols, "square kernels only");
    let r_big = kernel.rows;
    let rt = algo.r;
    let out_h = x.rows + 1 - r_big;
    let out_w = x.cols + 1 - r_big;
    let mut out = Mat::zeros(out_h, out_w);
    let grid = r_big.div_ceil(rt);
    for gi in 0..grid {
        for gj in 0..grid {
            // sub-kernel (padded with zeros at the ragged edge)
            let mut sub = Mat::zeros(rt, rt);
            for i in 0..rt {
                for j in 0..rt {
                    let (ki, kj) = (gi * rt + i, gj * rt + j);
                    if ki < r_big && kj < r_big {
                        sub[(i, j)] = kernel[(ki, kj)];
                    }
                }
            }
            // The sub-kernel at offset (gi·rt, gj·rt) contributes
            // y[p][q] += Σ sub[i][j]·x[p + gi·rt + i][q + gj·rt + j] —
            // a valid correlation over a shifted view of x.
            let part = tiled_conv2d_view(x, gi * rt, gj * rt, out_h, out_w, &sub, algo);
            for k in 0..out.data.len() {
                out.data[k] += part.data[k];
            }
        }
    }
    out
}

/// Valid correlation of `sub` (rt×rt) against the shifted view
/// x[oy.., ox..], producing `out_h`×`out_w` outputs, tiled with `algo`
/// (tile size M, overlap R−1).
fn tiled_conv2d_view(
    x: &Mat,
    oy: usize,
    ox: usize,
    out_h: usize,
    out_w: usize,
    sub: &Mat,
    algo: &Bilinear,
) -> Mat {
    let m = algo.m;
    let l = algo.input_len();
    let mut out = Mat::zeros(out_h, out_w);
    let mut ty = 0;
    while ty < out_h {
        let mut tx = 0;
        while tx < out_w {
            // gather the (possibly zero-padded) input tile
            let mut tile = Mat::zeros(l, l);
            for i in 0..l {
                for j in 0..l {
                    let (yy, xx) = (oy + ty + i, ox + tx + j);
                    if yy < x.rows && xx < x.cols {
                        tile[(i, j)] = x[(yy, xx)];
                    }
                }
            }
            let y = algo.apply2d_f64(&tile, sub);
            for i in 0..m.min(out_h - ty) {
                for j in 0..m.min(out_w - tx) {
                    out[(ty + i, tx + j)] = y[(i, j)];
                }
            }
            tx += m;
        }
        ty += m;
    }
    out
}

/// Multiplication-count model for the paper's two-iteration scheme.
pub struct IterativeCost {
    /// large-kernel size R the model was evaluated for
    pub kernel: usize,
    /// feature-map size the model was evaluated for
    pub feature: usize,
    /// mults for iteration-1 only (tiled SFC per sub-kernel)
    pub one_iter_mults: usize,
    /// mults when the combination is also SFC-accelerated (paper's number)
    pub two_iter_mults: usize,
    /// direct convolution mults for the same outputs
    pub direct_mults: usize,
}

/// Appendix B cost model: kernel R×R split into g² tiles of r_t×r_t,
/// feature map M_f×M_f split into g_f² tiles of m_t×m_t; the two SFC
/// algorithms' Hermitian-optimized counts multiply.
pub fn iterative_cost(r_big: usize, feat: usize, inner: &Bilinear, outer: &Bilinear) -> IterativeCost {
    let g = r_big.div_ceil(inner.r);
    let g_f = feat.div_ceil(outer.r); // feature tiling for iteration 2
    let _ = g_f;
    let out = feat; // paper counts per full output map of the feature size
    let tiles_1 = out.div_ceil(inner.m).pow(2);
    let one_iter = g * g * tiles_1 * inner.mults_2d_hermitian();
    let two_iter = inner.mults_2d_hermitian() * outer.mults_2d_hermitian();
    IterativeCost {
        kernel: r_big,
        feature: feat,
        one_iter_mults: one_iter,
        two_iter_mults: two_iter,
        direct_mults: out * out * r_big * r_big,
    }
}

/// The paper's worked example: 29×29 kernel, 26×26 feature map,
/// SFC-6(6×6,5×5) ∘ SFC-6(5×5,6×6).
pub fn paper_example_cost() -> IterativeCost {
    let inner = sfc(6, 6, 5);
    let outer = sfc(6, 5, 6);
    iterative_cost(29, 26, &inner, &outer)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::bilinear::direct_conv2d;
    use crate::util::Pcg32;

    #[test]
    fn iterative_matches_naive_29x29() {
        let mut rng = Pcg32::seeded(2024);
        let x = Mat::from_vec(40, 40, (0..1600).map(|_| rng.next_gaussian()).collect());
        let k = Mat::from_vec(29, 29, (0..841).map(|_| rng.next_gaussian()).collect());
        let algo = sfc(6, 6, 5);
        let got = iterative_conv2d(&x, &k, &algo);
        let want = direct_conv2d(&x, &k);
        assert_eq!(got.rows, want.rows);
        for i in 0..got.data.len() {
            assert!((got.data[i] - want.data[i]).abs() < 1e-6, "idx {i}");
        }
    }

    #[test]
    fn iterative_matches_naive_ragged() {
        // 13×13 kernel: grid of 5×5 tiles with ragged zero-padded edge.
        let mut rng = Pcg32::seeded(31);
        let x = Mat::from_vec(24, 24, (0..576).map(|_| rng.next_gaussian()).collect());
        let k = Mat::from_vec(13, 13, (0..169).map(|_| rng.next_gaussian()).collect());
        let algo = sfc(6, 6, 5);
        let got = iterative_conv2d(&x, &k, &algo);
        let want = direct_conv2d(&x, &k);
        for i in 0..got.data.len() {
            assert!((got.data[i] - want.data[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn paper_cost_numbers() {
        // Appendix B quotes 132 × 132 = 17,424 multiplications ≈ 3.1% of
        // direct. Our constructor derives T = 14 for both SFC-6(6,5) and
        // SFC-6(5,6) (184 Hermitian-optimized each), giving
        // 184 × 184 = 33,856 ≈ 6.0% — same order of magnitude, above the
        // paper's more aggressive count (EXPERIMENTS.md App-B discusses
        // the gap). Either way the reduction versus direct is ≥16×.
        let c = paper_example_cost();
        assert_eq!(c.two_iter_mults, 184 * 184);
        let ratio = c.two_iter_mults as f64 / c.direct_mults as f64;
        assert!(ratio < 0.07, "two-iteration ratio {ratio}");
        assert!(c.direct_mults / c.two_iter_mults >= 16);
    }

    #[test]
    fn sfc_5_6_exists() {
        // Iteration 2 needs the transposed-shape algorithm SFC-6(5,6).
        let a = sfc(6, 5, 6);
        assert_eq!(a.m, 5);
        assert_eq!(a.r, 6);
        assert_eq!(a.input_len(), 10);
    }
}
