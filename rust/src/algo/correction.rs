//! §4.2 — converting circular outputs into linear ones with correction
//! terms, and extending the tile size M beyond N−R+1 (Fig. 2).
//!
//! The constructor slides an N-point window (offset `o`) over the
//! L = M+R−1 input tile and computes the N-point circular convolution with
//! the symbolic-DFT bilinear algorithm of [`super::circular`]. Each desired
//! linear output z_k = Σ_r f_r·x_{k+r} is then expressed as
//!
//!   z_k = c_{j(k)} + Σ corrections,   correction = f_r · (Σ_i ±x_i)
//!
//! where each correction costs exactly one extra multiplication (one MAC,
//! as in the paper's o₁ = o₁ᶜ + (a₀−a₆)·w₁ example). Corrections shared by
//! several outputs are computed once. The window offset is searched to
//! minimize the total multiplication count T = T_c + #corrections; the
//! paper's counts are recovered exactly:
//!
//!   SFC-4(4,3): T = 7  (49 2-D),   SFC-6(6,3): T = 10 (100 2-D),
//!   SFC-6(7,3): T = 12 (144 2-D),  SFC-6(6,5): T = 14 (196 2-D).

use super::bilinear::Bilinear;
use super::circular::CircularConv;
use crate::linalg::{Frac, FracMat};
use std::collections::BTreeMap;

/// A linear form Σ coeff · f_r · x_i, keyed by (filter tap r, input index i).
type Form = BTreeMap<(usize, usize), i64>;

/// z_k = Σ_r f_r x_{k+r}
fn desired_form(k: usize, r_taps: usize) -> Form {
    (0..r_taps).map(|r| ((r, k + r), 1i64)).collect()
}

/// The j-th circular-convolution output of the window starting at offset
/// `o`, expressed over the original filter taps and input indices:
/// c_j = Σ_t f_t · x_{o + ((j − R + 1 + t) mod N)}.
/// (The circular algorithm is fed the flipped filter, which turns circular
/// convolution into windowed correlation — see `build` below.)
fn circ_form(j: usize, o: usize, n: usize, r_taps: usize) -> Form {
    let mut form = Form::new();
    for t in 0..r_taps {
        let idx = (j as i64 - r_taps as i64 + 1 + t as i64).rem_euclid(n as i64) as usize;
        *form.entry((t, o + idx)).or_insert(0) += 1;
    }
    form.retain(|_, v| *v != 0);
    form
}

fn form_sub(a: &Form, b: &Form) -> Form {
    let mut out = a.clone();
    for (k, v) in b {
        *out.entry(*k).or_insert(0) -= v;
    }
    out.retain(|_, v| *v != 0);
    out
}

/// Split a difference form into per-tap corrections: one multiplication
/// f_r · (Σ_i coeff·x_i) per distinct tap r present in the difference.
fn split_corrections(diff: &Form) -> Vec<(usize, Vec<(usize, i64)>)> {
    let mut by_tap: BTreeMap<usize, Vec<(usize, i64)>> = BTreeMap::new();
    for (&(r, i), &c) in diff {
        by_tap.entry(r).or_default().push((i, c));
    }
    by_tap.into_iter().collect()
}

/// Canonical key for a correction term so identical terms are shared
/// across outputs: sign-normalized (first coefficient positive).
fn canon(r: usize, xs: &[(usize, i64)]) -> ((usize, Vec<(usize, i64)>), i64) {
    let sign = if xs[0].1 < 0 { -1 } else { 1 };
    let norm: Vec<(usize, i64)> = xs.iter().map(|&(i, c)| (i, c * sign)).collect();
    ((r, norm), sign as i64)
}

/// Plan for one output: which circular output it reuses (or none for a
/// fully-direct output) plus its correction terms.
#[derive(Debug, Clone)]
struct OutputPlan {
    circ_j: Option<usize>,
    /// (correction pool index, sign)
    corrections: Vec<(usize, i64)>,
}

/// Construct the SFC-N(M×M, R×R) algorithm (1-D triple; 2-D use is nested).
///
/// Panics if the input tile is shorter than the transform (M+R−1 ≥ N is
/// required; all variants in the paper satisfy it).
pub fn sfc(n: usize, m: usize, r_taps: usize) -> Bilinear {
    let l = m + r_taps - 1;
    assert!(l >= n, "SFC-{n}({m},{r_taps}): input tile {l} shorter than transform {n}");
    let cc = CircularConv::new(n);

    // Search window offsets for the fewest total corrections.
    let mut best: Option<(usize, Vec<OutputPlan>, Vec<(usize, Vec<(usize, i64)>)>)> = None;
    for o in 0..=(l - n) {
        let circ: Vec<Form> = (0..n).map(|j| circ_form(j, o, n, r_taps)).collect();
        let mut pool: Vec<(usize, Vec<(usize, i64)>)> = Vec::new();
        let mut pool_idx: BTreeMap<(usize, Vec<(usize, i64)>), usize> = BTreeMap::new();
        let mut plans = Vec::with_capacity(m);
        for k in 0..m {
            let want = desired_form(k, r_taps);
            // Candidates: every circular output, and "no circular" (direct).
            let mut best_j: Option<usize> = None;
            let mut best_corr: Vec<(usize, Vec<(usize, i64)>)> = split_corrections(&want);
            let mut best_new = usize::MAX;
            for (j, c) in circ.iter().enumerate() {
                let corr = split_corrections(&form_sub(&want, c));
                let new_cost = corr
                    .iter()
                    .filter(|(r, xs)| {
                        let (key, _) = canon(*r, xs);
                        !pool_idx.contains_key(&key)
                    })
                    .count();
                let better = new_cost < best_new
                    || (new_cost == best_new && corr.len() < best_corr.len());
                if better {
                    best_new = new_cost;
                    best_j = Some(j);
                    best_corr = corr;
                }
            }
            // Compare against computing the output directly (R new mults,
            // minus whatever the pool already shares).
            let direct_corr = split_corrections(&want);
            let direct_new = direct_corr
                .iter()
                .filter(|(r, xs)| !pool_idx.contains_key(&canon(*r, xs).0))
                .count();
            if direct_new < best_new {
                best_j = None;
                best_corr = direct_corr;
            }
            let mut refs = Vec::new();
            for (r, xs) in best_corr {
                let (key, sign) = canon(r, &xs);
                let idx = *pool_idx.entry(key.clone()).or_insert_with(|| {
                    pool.push(key.clone());
                    pool.len() - 1
                });
                refs.push((idx, sign));
            }
            plans.push(OutputPlan { circ_j: best_j, corrections: refs });
        }
        // Keep the offset with the fewest correction multiplications.
        let improves = match &best {
            Some((_, _, bpool)) => pool.len() < bpool.len(),
            None => true,
        };
        if improves {
            best = Some((o, plans, pool));
        }
    }
    let (o, plans, pool) = best.unwrap();
    let t = cc.t_c + pool.len();

    // --- Assemble Bᵀ (T×L) ---
    let mut bt = FracMat::zeros(t, l);
    // circular rows: Bc · window-selection
    for row in 0..cc.t_c {
        for i in 0..n {
            bt[(row, o + i)] = cc.bc[(row, i)];
        }
    }
    for (ci, (_r, xs)) in pool.iter().enumerate() {
        for &(i, c) in xs {
            bt[(cc.t_c + ci, i)] = Frac::int(c as i128);
        }
    }

    // --- Assemble G (T×R) ---
    // The circular algorithm computes c_j = Σ f̂_t x_{(j−t) mod N} for the
    // aliased filter f̂; to realize windowed correlation we feed the
    // flipped-and-aliased filter: f̂_i = Σ_{t : (R−1−t) ≡ i (mod N)} f_t.
    let mut pg = FracMat::zeros(n, r_taps);
    for tap in 0..r_taps {
        let i = (r_taps - 1 - tap) % n;
        pg[(i, tap)] += Frac::ONE;
    }
    let gc_full = cc.gc.matmul(&pg);
    let mut g = FracMat::zeros(t, r_taps);
    for row in 0..cc.t_c {
        for tap in 0..r_taps {
            g[(row, tap)] = gc_full[(row, tap)];
        }
    }
    for (ci, (r, _xs)) in pool.iter().enumerate() {
        g[(cc.t_c + ci, *r)] = Frac::ONE;
    }

    // --- Assemble Aᵀ (M×T) ---
    let mut at = FracMat::zeros(m, t);
    for (k, plan) in plans.iter().enumerate() {
        if let Some(j) = plan.circ_j {
            for col in 0..cc.t_c {
                at[(k, col)] = cc.ac[(j, col)];
            }
        }
        for &(ci, sign) in &plan.corrections {
            at[(k, cc.t_c + ci)] += Frac::int(sign as i128);
        }
    }

    // §5 overlapped output form for condition-number analysis: the N
    // circular outputs from the (well-conditioned) inverse SFT, augmented
    // with the correction columns (each a ±1 bump on the circular output
    // row it corrects).
    let mut at_ov = FracMat::zeros(n, t);
    for j in 0..n {
        for col in 0..cc.t_c {
            at_ov[(j, col)] = cc.ac[(j, col)];
        }
    }
    for (k, plan) in plans.iter().enumerate() {
        if let Some(j) = plan.circ_j {
            for &(ci, sign) in &plan.corrections {
                at_ov[(j, cc.t_c + ci)] = Frac::int(sign as i128);
            }
        }
        let _ = k;
    }

    let algo = Bilinear {
        name: format!("SFC-{n}({m}x{m},{r_taps}x{r_taps})"),
        m,
        r: r_taps,
        t,
        bt,
        g,
        at,
        circ_meta: Some((n, cc.t_c)),
        at_ov: Some(at_ov),
    };
    algo.validate();
    algo
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::bilinear::direct_corr1d_exact;
    use crate::linalg::Frac;
    use crate::util::Pcg32;

    #[test]
    fn paper_multiplication_counts() {
        // Appendix A: 49/46, 100/88, 144/132, 196/184 2-D multiplications
        // (nested / Hermitian-symmetry-optimized).
        let a = sfc(4, 4, 3);
        assert_eq!((a.mults_2d(), a.mults_2d_hermitian()), (49, 46), "SFC-4(4x4,3x3)");
        let a = sfc(6, 6, 3);
        assert_eq!((a.mults_2d(), a.mults_2d_hermitian()), (100, 88), "SFC-6(6x6,3x3)");
        let a = sfc(6, 7, 3);
        assert_eq!((a.mults_2d(), a.mults_2d_hermitian()), (144, 132), "SFC-6(7x7,3x3)");
        let a = sfc(6, 6, 5);
        assert_eq!((a.mults_2d(), a.mults_2d_hermitian()), (196, 184), "SFC-6(6x6,5x5)");
    }

    #[test]
    fn table1_complexities() {
        // Table 1 "Arithmetic Complexity" column (multiplication ratio).
        assert!((sfc(4, 4, 3).complexity_2d() - 0.3194).abs() < 0.01);
        assert!((sfc(6, 6, 3).complexity_2d() - 0.2716).abs() < 0.01);
        assert!((sfc(6, 7, 3).complexity_2d() - 0.2993).abs() < 0.01);
        assert!((sfc(6, 6, 5).complexity_2d() - 0.2044).abs() < 0.01);
    }

    #[test]
    fn exact_linear_convolution_all_variants() {
        let variants = [(4, 4, 3), (6, 6, 3), (6, 7, 3), (6, 6, 5), (6, 4, 7), (6, 5, 6), (4, 2, 3), (6, 12, 3)];
        for (n, m, r) in variants {
            let a = sfc(n, m, r);
            let mut rng = Pcg32::seeded(1000 + (n * 100 + m * 10 + r) as u64);
            for _ in 0..10 {
                let x: Vec<Frac> = (0..a.input_len()).map(|_| Frac::int(rng.below(31) as i128 - 15)).collect();
                let f: Vec<Frac> = (0..r).map(|_| Frac::int(rng.below(31) as i128 - 15)).collect();
                assert_eq!(
                    a.apply1d_exact(&x, &f),
                    direct_corr1d_exact(&x, &f),
                    "SFC-{n}({m},{r})"
                );
            }
        }
    }

    #[test]
    fn transforms_are_addition_networks() {
        for (n, m, r) in [(4, 4, 3), (6, 6, 3), (6, 7, 3), (6, 6, 5)] {
            let a = sfc(n, m, r);
            assert!(a.bt.is_integral(), "Bᵀ integral");
            assert!(a.g.is_integral(), "G integral");
            // Bᵀ entries small: pure adds (no shifts needed beyond ±1).
            for v in &a.bt.data {
                assert!(v.num.abs() <= 2, "SFC-{n}({m},{r}) Bᵀ entry {v:?}");
            }
            // Aᵀ denominators divide N (1/N folds into output scale).
            for v in &a.at.data {
                assert!((n as i128) % v.den == 0);
            }
        }
    }

    #[test]
    fn conditioning_close_to_fourier() {
        // Table 1: κ(Aᵀ) = 2.7 / 3.3 / 3.4 / 3.5 — far below Winograd's 20+.
        let k43 = sfc(4, 4, 3).kappa_at();
        let k63 = sfc(6, 6, 3).kappa_at();
        let k73 = sfc(6, 7, 3).kappa_at();
        assert!(k43 < 6.0, "κ SFC-4(4,3) = {k43}");
        assert!(k63 < 6.0, "κ SFC-6(6,3) = {k63}");
        assert!(k73 < 6.0, "κ SFC-6(7,3) = {k73}");
    }

    #[test]
    fn fig2_correction_structure() {
        // The Fig. 2 mechanism: for SFC-6(6,3), exactly 2 corrections, each
        // a single-tap times a two-input difference.
        let a = sfc(6, 6, 3);
        let t_c = 8; // circular mults for N=6
        for row in t_c..a.t {
            let nnz_g = (0..a.r).filter(|&j| !a.g[(row, j)].is_zero()).count();
            assert_eq!(nnz_g, 1, "correction row multiplies a single filter tap");
            let nnz_b = (0..a.bt.cols).filter(|&j| !a.bt[(row, j)].is_zero()).count();
            assert!(nnz_b <= 2, "correction operand is x_a - x_b");
        }
        assert_eq!(a.t - t_c, 2);
    }

    #[test]
    fn tile_size_equals_output_requirement() {
        // SFC-6(7,3) exists specifically so 224-sized feature maps tile by 7.
        let a = sfc(6, 7, 3);
        assert_eq!(a.m, 7);
        assert_eq!(a.input_len(), 9);
    }
}
