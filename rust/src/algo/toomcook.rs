//! Winograd / Toom-Cook minimal-filtering generator (the paper's baseline
//! family, §3).
//!
//! F(M, R) computes M correlation outputs from M+R−1 inputs with
//! α = M+R−1 multiplications, built from polynomial evaluation at α−1
//! finite points plus ∞. Derivation (transpose theorem): if linear
//! convolution is s = C·((V_R·g) ⊙ (V_L·e)) with C the exact interpolation
//! matrix, then correlation is its transpose in the data argument:
//!
//!   y = V_Mᵀ · ((V_R·g) ⊙ (Cᵀ·d))
//!
//! so Aᵀ = V_Mᵀ, G = V_R, Bᵀ = Cᵀ. Aᵀ matches the standard Lavin–Gray
//! matrices exactly (which is what κ(Aᵀ) in Table 1 is computed from);
//! Bᵀ is then normalized to integers with the fractional content folded
//! into G, the conventional presentation.

use super::bilinear::Bilinear;
use crate::linalg::{Frac, FracMat};

/// The canonical interpolation point sequence: 0, 1, −1, 2, −2, ½, −½, …
/// (good points first, per Lavin & Gray and the point-selection papers).
pub fn default_points(count: usize) -> Vec<Frac> {
    let mut pts = vec![Frac::int(0)];
    let mut k = 1i128;
    while pts.len() < count {
        pts.push(Frac::int(k));
        if pts.len() < count {
            pts.push(Frac::int(-k));
        }
        if pts.len() < count {
            pts.push(Frac::new(1, k + 1));
        }
        if pts.len() < count {
            pts.push(Frac::new(-1, k + 1));
        }
        k += 1;
    }
    pts.truncate(count);
    pts
}

/// Vandermonde evaluation matrix at the given finite points plus a final
/// ∞ row (leading coefficient): (points.len()+1) × cols.
fn vandermonde(points: &[Frac], cols: usize) -> FracMat {
    let rows = points.len() + 1;
    let mut v = FracMat::zeros(rows, cols);
    for (i, p) in points.iter().enumerate() {
        for j in 0..cols {
            v[(i, j)] = p.pow(j as u32);
        }
    }
    v[(rows - 1, cols - 1)] = Frac::ONE; // ∞ picks the leading coefficient
    v
}

/// Winograd F(m, r) with the canonical points.
pub fn winograd(m: usize, r: usize) -> Bilinear {
    winograd_with_points(m, r, &default_points(m + r - 2))
}

/// Winograd F(m, r) with caller-chosen finite interpolation points
/// (α−1 = m+r−2 of them; ∞ is always appended).
pub fn winograd_with_points(m: usize, r: usize, points: &[Frac]) -> Bilinear {
    let alpha = m + r - 1;
    assert_eq!(points.len(), alpha - 1, "need {} finite points", alpha - 1);
    // pairwise-distinct check
    for i in 0..points.len() {
        for j in 0..i {
            assert!(points[i] != points[j], "duplicate interpolation point");
        }
    }
    let v_full = vandermonde(points, alpha); // α×α evaluation incl. ∞
    let c = v_full.inverse().expect("Vandermonde at distinct points is invertible");
    let bt = c.transpose(); // α×α
    let g = vandermonde(points, r); // α×r
    let at = vandermonde(points, m).transpose(); // m×α

    let algo = Bilinear {
        name: format!("Wino({m}x{m},{r}x{r})"),
        m,
        r,
        t: alpha,
        bt,
        g,
        at,
        circ_meta: None,
        // §5 overlapped output form: all α outputs of the underlying
        // linear convolution come from the square interpolation matrix C.
        at_ov: Some(c),
    }
    .normalize_bt_integral();
    algo.validate();
    algo
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::bilinear::direct_corr1d_exact;
    use crate::util::Pcg32;

    #[test]
    fn f23_matches_lavin_gray() {
        // The classic F(2,3): Aᵀ = [[1,1,1,0],[0,1,−1,1]] with points 0,1,−1.
        let a = winograd(2, 3);
        assert_eq!(a.t, 4);
        let at: Vec<i128> = a.at.data.iter().map(|f| {
            assert!(f.is_integer());
            f.num
        }).collect();
        assert_eq!(at, vec![1, 1, 1, 0, 0, 1, -1, 1]);
        // Bᵀ integral after normalization (the standard form).
        assert!(a.bt.is_integral());
    }

    #[test]
    fn exact_for_all_baseline_sizes() {
        for (m, r) in [(2, 3), (3, 3), (4, 3), (2, 5), (2, 7), (6, 3), (4, 5)] {
            let a = winograd(m, r);
            let mut rng = Pcg32::seeded((m * 10 + r) as u64);
            for _ in 0..8 {
                let x: Vec<Frac> = (0..a.input_len()).map(|_| Frac::int(rng.below(9) as i128 - 4)).collect();
                let f: Vec<Frac> = (0..r).map(|_| Frac::int(rng.below(9) as i128 - 4)).collect();
                assert_eq!(a.apply1d_exact(&x, &f), direct_corr1d_exact(&x, &f), "F({m},{r})");
            }
        }
    }

    #[test]
    fn complexity_ratios_match_paper() {
        // Table 1: Wino(2,3) 44.4%, Wino(3,3) 30.4% (uses 25/81? no:
        // (3+3-1)^2/(3*3)^2 = 25/81 = 30.9% ≈ 30.4 reported), Wino(4,3) 25%,
        // Wino(2,5) 36%, Wino(2,7) 32.6%.
        assert!((winograd(2, 3).complexity_2d() - 0.444).abs() < 0.01);
        assert!((winograd(4, 3).complexity_2d() - 0.25).abs() < 0.001);
        assert!((winograd(2, 5).complexity_2d() - 0.36).abs() < 0.001);
        assert!((winograd(2, 7).complexity_2d() - 0.3265).abs() < 0.01);
    }

    #[test]
    fn kappa_grows_with_tile_size() {
        // The ill-conditioning story of §3: κ(Aᵀ) explodes as M grows.
        let k23 = winograd(2, 3).kappa_at();
        let k33 = winograd(3, 3).kappa_at();
        let k43 = winograd(4, 3).kappa_at();
        assert!(k23 < k33 && k33 < k43, "κ: {k23} < {k33} < {k43}");
        assert!(k23 < 4.0);
        assert!(k43 > 10.0, "Wino(4,3) must be badly conditioned, κ={k43}");
    }

    #[test]
    fn custom_points_still_exact() {
        let pts = [Frac::int(0), Frac::int(1), Frac::int(-1), Frac::new(1, 2)];
        let a = winograd_with_points(3, 3, &pts);
        let x: Vec<Frac> = (0..5).map(|i| Frac::int(i as i128 + 1)).collect();
        let f: Vec<Frac> = vec![Frac::int(1), Frac::int(-2), Frac::int(3)];
        assert_eq!(a.apply1d_exact(&x, &f), direct_corr1d_exact(&x, &f));
    }

    #[test]
    #[should_panic(expected = "duplicate")]
    fn duplicate_points_rejected() {
        let pts = [Frac::int(0), Frac::int(1), Frac::int(1)];
        winograd_with_points(2, 3, &pts);
    }
}
