//! The bilinear fast-convolution container (G, Bᵀ, Aᵀ) and its appliers.
//!
//! Every algorithm in the paper (direct, Winograd/Toom-Cook, SFC) is an
//! instance of Eq. 1:   y = Aᵀ [(G f Gᵀ) ⊙ (Bᵀ x B)] A   (2-D nested form),
//! so a single container carries the matrices, operation counts and
//! appliers, and the error/BOPs/engine layers treat all algorithms
//! uniformly.

use crate::linalg::{condition_number, Frac, FracMat, Mat};

/// A 1-D bilinear convolution algorithm computing M correlation outputs
/// z_k = Σ_r f_r·x_{k+r} from L = M+R−1 inputs with T multiplications:
///   z = Aᵀ ((G f) ⊙ (Bᵀ x)).
#[derive(Clone, Debug)]
pub struct Bilinear {
    /// Table-1 row name (e.g. "SFC-6(6x6,3x3)").
    pub name: String,
    /// outputs per tile
    pub m: usize,
    /// filter taps
    pub r: usize,
    /// multiplications (rows of Bᵀ and G)
    pub t: usize,
    /// Bᵀ: T×L (integer for SFC; integer after normalization for Winograd)
    pub bt: FracMat,
    /// G: T×R
    pub g: FracMat,
    /// Aᵀ: M×T
    pub at: FracMat,
    /// For SFC algorithms: (N transform points, T_c circular mults) —
    /// enables the 2-D Hermitian-symmetry multiplication count of App. A.
    pub circ_meta: Option<(usize, usize)>,
    /// The §5 "overlapped output form" square/invertible output transform
    /// used for condition-number analysis (C for Toom-Cook, the circular
    /// inverse for SFC). κ(Aᵀ) in Table 1 is computed from this.
    pub at_ov: Option<FracMat>,
}

impl Bilinear {
    /// Inputs per tile: L = M + R − 1.
    pub fn input_len(&self) -> usize {
        self.bt.cols
    }

    /// Verify shapes and exactness on random integer data; panics on error.
    pub fn validate(&self) {
        assert_eq!(self.bt.rows, self.t);
        assert_eq!(self.g.rows, self.t);
        assert_eq!(self.g.cols, self.r);
        assert_eq!(self.at.rows, self.m);
        assert_eq!(self.at.cols, self.t);
        assert_eq!(self.bt.cols, self.m + self.r - 1);
        let mut rng = crate::util::Pcg32::seeded(0xC0FFEE);
        for _ in 0..8 {
            let x: Vec<Frac> = (0..self.input_len()).map(|_| Frac::int(rng.below(17) as i128 - 8)).collect();
            let f: Vec<Frac> = (0..self.r).map(|_| Frac::int(rng.below(17) as i128 - 8)).collect();
            let got = self.apply1d_exact(&x, &f);
            let want = direct_corr1d_exact(&x, &f);
            assert_eq!(got, want, "{}: exact 1-D check failed", self.name);
        }
    }

    /// Exact 1-D application (used by tests and the constructor checks).
    pub fn apply1d_exact(&self, x: &[Frac], f: &[Frac]) -> Vec<Frac> {
        let tx = self.bt.matvec(x);
        let tf = self.g.matvec(f);
        let prod: Vec<Frac> = tx.iter().zip(&tf).map(|(a, b)| *a * *b).collect();
        self.at.matvec(&prod)
    }

    /// f64 1-D application.
    pub fn apply1d_f64(&self, x: &[f64], f: &[f64]) -> Vec<f64> {
        let bt = self.bt.to_f64();
        let g = self.g.to_f64();
        let at = self.at.to_f64();
        let tx = bt.matvec(x);
        let tf = g.matvec(f);
        let prod: Vec<f64> = tx.iter().zip(&tf).map(|(a, b)| a * b).collect();
        at.matvec(&prod)
    }

    /// 2-D nested application on an L×L input tile and R×R filter,
    /// producing an M×M output tile: y = Aᵀ[(G f Gᵀ) ⊙ (Bᵀ x B)]A.
    /// Optional hooks quantize the two transform-domain operands (used by
    /// the Table-1 / Fig-5 error harness).
    pub fn apply2d_with(
        &self,
        x: &Mat,
        f: &Mat,
        qx: &dyn Fn(f64) -> f64,
        qf: &dyn Fn(f64) -> f64,
    ) -> Mat {
        assert_eq!(x.rows, self.input_len());
        assert_eq!(x.cols, self.input_len());
        assert_eq!(f.rows, self.r);
        assert_eq!(f.cols, self.r);
        let bt = self.bt.to_f64();
        let g = self.g.to_f64();
        let at = self.at.to_f64();
        // Bᵀ x B  and  G f Gᵀ
        let mut tx = bt.matmul(x).matmul(&bt.transpose());
        let mut tf = g.matmul(f).matmul(&g.transpose());
        for v in tx.data.iter_mut() {
            *v = qx(*v);
        }
        for v in tf.data.iter_mut() {
            *v = qf(*v);
        }
        let mut prod = Mat::zeros(self.t, self.t);
        for i in 0..self.t * self.t {
            prod.data[i] = tx.data[i] * tf.data[i];
        }
        at.matmul(&prod).matmul(&at.transpose())
    }

    /// 2-D nested application in f64, no quantization hooks.
    pub fn apply2d_f64(&self, x: &Mat, f: &Mat) -> Mat {
        self.apply2d_with(x, f, &|v| v, &|v| v)
    }

    /// Real multiplications for one 2-D tile in the nested (executed) form.
    pub fn mults_2d(&self) -> usize {
        self.t * self.t
    }

    /// 2-D multiplications when Hermitian symmetry is fully exploited
    /// (Appendix A's second numbers: 46/88/132/184). The nested scheme
    /// spends T_c² mults on the circular core, while the true 2-D real
    /// spectrum needs only 4 + 3(N²−4)/2 (4 real bins at m∈{0,N/2}², the
    /// rest in conjugate pairs at 3 real mults each).
    pub fn mults_2d_hermitian(&self) -> usize {
        match self.circ_meta {
            Some((n, t_c)) => {
                let opt_core = 4 + 3 * (n * n - 4) / 2;
                self.t * self.t - (t_c * t_c - opt_core)
            }
            None => self.t * self.t,
        }
    }

    /// Arithmetic-complexity ratio versus direct convolution (2-D) —
    /// Table 1's "Arithmetic Complexity" column (Hermitian-optimized).
    pub fn complexity_2d(&self) -> f64 {
        self.mults_2d_hermitian() as f64 / ((self.m * self.m * self.r * self.r) as f64)
    }

    /// Multiplication reduction factor (the paper quotes 2.25× for
    /// Winograd F(2,3), 3.68× for SFC-6(6,3) incl. transform overhead).
    pub fn speedup_2d(&self) -> f64 {
        1.0 / self.complexity_2d()
    }

    /// Addition counts for the three 2-D transforms (input, filter,
    /// output), counting row-wise then column-wise application.
    pub fn transform_adds_2d(&self) -> (usize, usize, usize) {
        let l = self.input_len();
        let bt_adds = self.bt.add_count() * (l + self.t);
        let g_adds = self.g.add_count() * (self.r + self.t);
        let at_adds = self.at.add_count() * (self.t + self.m);
        (bt_adds, g_adds, at_adds)
    }

    /// κ(Aᵀ) — the error amplification factor of §5 (Table 1 column),
    /// computed on the overlapped square output form when available
    /// (the paper's Eq. 12–16 derivation requires an invertible A).
    pub fn kappa_at(&self) -> f64 {
        match &self.at_ov {
            Some(m) => condition_number(&m.to_f64()),
            None => condition_number(&self.at.to_f64()),
        }
    }

    /// κ of the tile-form Aᵀ (σ_max/σ_min of the rectangular M×T matrix).
    pub fn kappa_at_tile(&self) -> f64 {
        condition_number(&self.at.to_f64())
    }

    /// Move fractional content of Bᵀ rows into G rows (bilinear-invariant
    /// diagonal rescaling) so Bᵀ becomes integral — the standard Winograd
    /// presentation, and what integer hardware implements.
    pub fn normalize_bt_integral(mut self) -> Self {
        for t in 0..self.t {
            // lcm of denominators in Bᵀ row t
            let mut lcm: i128 = 1;
            for j in 0..self.bt.cols {
                let d = self.bt[(t, j)].den;
                let g = gcd(lcm, d);
                lcm = lcm / g * d;
            }
            if lcm != 1 {
                let s = Frac::int(lcm);
                for j in 0..self.bt.cols {
                    self.bt[(t, j)] = self.bt[(t, j)] * s;
                }
                let inv = s.recip();
                for j in 0..self.g.cols {
                    self.g[(t, j)] = self.g[(t, j)] * inv;
                }
            }
        }
        self
    }

    /// Balance the dynamic range between Bᵀ and G by the bilinear-invariant
    /// per-row rescaling α_t = √(‖g_t‖/‖b_t‖): both transformed operands
    /// then live at comparable magnitudes. This is what practical float
    /// Winograd implementations do and what Table 1's fp16 measurement
    /// assumes (without it the α=8 interpolation rows overflow fp16).
    /// Matrices become non-integral; the integer engine keeps the
    /// `normalize_bt_integral` form instead.
    pub fn balanced(&self) -> Self {
        let mut out = self.clone();
        for t in 0..self.t {
            let bnorm: f64 = (0..self.bt.cols)
                .map(|j| self.bt[(t, j)].to_f64().powi(2))
                .sum::<f64>()
                .sqrt();
            let gnorm: f64 =
                (0..self.g.cols).map(|j| self.g[(t, j)].to_f64().powi(2)).sum::<f64>().sqrt();
            if bnorm == 0.0 || gnorm == 0.0 {
                continue;
            }
            // rational approximation of α keeps exactness of the identity
            let alpha = (gnorm / bnorm).sqrt();
            let frac = Frac::new((alpha * 4096.0).round() as i128, 4096);
            if frac.is_zero() {
                continue;
            }
            for j in 0..out.bt.cols {
                out.bt[(t, j)] = out.bt[(t, j)] * frac;
            }
            let inv = frac.recip();
            for j in 0..out.g.cols {
                out.g[(t, j)] = out.g[(t, j)] * inv;
            }
        }
        out
    }

    /// The direct algorithm viewed as a (trivial) bilinear algorithm with
    /// M = 1: Bᵀ = I_R, G = I_R, Aᵀ = 1ᵀ (paper Eq. 12). Baseline row of
    /// Table 1.
    pub fn direct(r: usize) -> Bilinear {
        Bilinear {
            name: "direct".into(),
            m: 1,
            r,
            t: r,
            bt: FracMat::identity(r),
            g: FracMat::identity(r),
            at: FracMat { rows: 1, cols: r, data: vec![Frac::ONE; r] },
            circ_meta: None,
            // Eq. 12: the overlapped direct form has A = I (κ = 1).
            at_ov: Some(FracMat::identity(r)),
        }
    }
}

fn gcd(a: i128, b: i128) -> i128 {
    let (mut a, mut b) = (a.abs(), b.abs());
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a.max(1)
}

/// Exact 1-D "valid" correlation: z_k = Σ_r f_r x_{k+r}.
pub fn direct_corr1d_exact(x: &[Frac], f: &[Frac]) -> Vec<Frac> {
    let m = x.len() + 1 - f.len();
    (0..m)
        .map(|k| {
            let mut acc = Frac::ZERO;
            for (r, fv) in f.iter().enumerate() {
                acc += *fv * x[k + r];
            }
            acc
        })
        .collect()
}

/// f64 1-D "valid" correlation.
pub fn direct_conv1d(x: &[f64], f: &[f64]) -> Vec<f64> {
    let m = x.len() + 1 - f.len();
    (0..m)
        .map(|k| f.iter().enumerate().map(|(r, fv)| fv * x[k + r]).sum())
        .collect()
}

/// f64 2-D "valid" correlation on Mats: y[p][q] = Σ f[i][j]·x[p+i][q+j].
pub fn direct_conv2d(x: &Mat, f: &Mat) -> Mat {
    let m_rows = x.rows + 1 - f.rows;
    let m_cols = x.cols + 1 - f.cols;
    let mut y = Mat::zeros(m_rows, m_cols);
    for p in 0..m_rows {
        for q in 0..m_cols {
            let mut acc = 0.0;
            for i in 0..f.rows {
                for j in 0..f.cols {
                    acc += f[(i, j)] * x[(p + i, q + j)];
                }
            }
            y[(p, q)] = acc;
        }
    }
    y
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn direct_bilinear_is_exact() {
        let d = Bilinear::direct(3);
        d.validate();
        assert_eq!(d.mults_2d(), 9);
        assert!((d.complexity_2d() - 1.0).abs() < 1e-12);
        assert!((d.kappa_at() - 1.0).abs() < 1e-9, "direct conv Aᵀ is perfectly conditioned");
    }

    #[test]
    fn conv1d_reference() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let f = [1.0, -1.0];
        assert_eq!(direct_conv1d(&x, &f), vec![-1.0, -1.0, -1.0]);
    }

    #[test]
    fn conv2d_reference() {
        let x = Mat::from_vec(3, 3, vec![1., 2., 3., 4., 5., 6., 7., 8., 9.]);
        let f = Mat::from_vec(2, 2, vec![1., 0., 0., 1.]);
        let y = direct_conv2d(&x, &f);
        assert_eq!(y.data, vec![6., 8., 12., 14.]);
    }

    #[test]
    fn direct_2d_apply_matches_naive() {
        // The trivial bilinear applied per-tile must equal naive conv for
        // M=1: a 3x3 filter on a 3x3 tile -> 1 output.
        let d = Bilinear::direct(3);
        let mut rng = crate::util::Pcg32::seeded(5);
        let x = Mat::from_vec(3, 3, (0..9).map(|_| rng.next_gaussian()).collect());
        let f = Mat::from_vec(3, 3, (0..9).map(|_| rng.next_gaussian()).collect());
        let y = d.apply2d_f64(&x, &f);
        let want = direct_conv2d(&x, &f);
        assert!((y[(0, 0)] - want[(0, 0)]).abs() < 1e-12);
    }
}
