//! Bilinear circular convolution over the symbolic component space.
//!
//! Composing the SFT pieces of [`super::dft`] gives a bilinear algorithm
//! for N-point circular convolution of real sequences:
//!
//!   c = Ac · ((Gc·f̂) ⊙ (Bc·x)),   Bc = E·F_N,  Gc = E·F_N,  Ac = iF_N·Cmb
//!
//! with T_c real multiplications (8 for N=6, 5 for N=4) — the engine room
//! of every SFC algorithm. `f̂` is the filter circularly aliased (and, for
//! the linear-convolution use in [`super::correction`], pre-flipped).

use super::dft::SymDft;
use crate::linalg::{Frac, FracMat};

/// Bilinear algorithm for N-point circular convolution
/// c_j = Σ_r f̂_r · x_{(j−r) mod N}.
#[derive(Clone, Debug)]
pub struct CircularConv {
    /// circular length N
    pub n: usize,
    /// multiplications
    pub t_c: usize,
    /// Bc: T_c×N (integer)
    pub bc: FracMat,
    /// Gc: T_c×N (integer) — applied to the already-aliased filter f̂
    pub gc: FracMat,
    /// Ac: N×T_c (entries with denominator N)
    pub ac: FracMat,
}

impl CircularConv {
    /// Build the N-point circular convolution from the symbolic DFT.
    pub fn new(n: usize) -> CircularConv {
        let dft = SymDft::new(n);
        let f = dft.f_mat();
        let e = dft.expand_mat();
        let bc = e.matmul(&f);
        let gc = bc.clone();
        let ac = dft.if_mat().matmul(&dft.combine_mat());
        CircularConv { n, t_c: dft.t_mults, bc, gc, ac }
    }

    /// Exact circular convolution through the bilinear algorithm.
    pub fn apply_exact(&self, x: &[Frac], f_hat: &[Frac]) -> Vec<Frac> {
        assert_eq!(x.len(), self.n);
        assert_eq!(f_hat.len(), self.n);
        let tx = self.bc.matvec(x);
        let tf = self.gc.matvec(f_hat);
        let prod: Vec<Frac> = tx.iter().zip(&tf).map(|(a, b)| *a * *b).collect();
        self.ac.matvec(&prod)
    }
}

/// Naive exact circular convolution (reference).
pub fn circular_conv_exact(x: &[Frac], f: &[Frac]) -> Vec<Frac> {
    let n = x.len();
    assert_eq!(f.len(), n);
    (0..n)
        .map(|j| {
            let mut acc = Frac::ZERO;
            for r in 0..n {
                let idx = (j + n - r) % n;
                acc += f[r] * x[idx];
            }
            acc
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn rand_vec(rng: &mut Pcg32, n: usize) -> Vec<Frac> {
        (0..n).map(|_| Frac::int(rng.below(21) as i128 - 10)).collect()
    }

    #[test]
    fn matches_naive_circular() {
        for n in [2usize, 3, 4, 6] {
            let cc = CircularConv::new(n);
            let mut rng = Pcg32::seeded(42 + n as u64);
            for _ in 0..20 {
                let x = rand_vec(&mut rng, n);
                let f = rand_vec(&mut rng, n);
                assert_eq!(cc.apply_exact(&x, &f), circular_conv_exact(&x, &f), "N={n}");
            }
        }
    }

    #[test]
    fn mult_counts() {
        assert_eq!(CircularConv::new(6).t_c, 8);
        assert_eq!(CircularConv::new(4).t_c, 5);
        assert_eq!(CircularConv::new(3).t_c, 4);
    }

    #[test]
    fn transforms_are_integral() {
        for n in [3usize, 4, 6] {
            let cc = CircularConv::new(n);
            assert!(cc.bc.is_integral(), "Bc must be an addition network");
            assert!(cc.gc.is_integral(), "Gc must be an addition network");
            // Ac denominators divide N (1/N folded into the inverse DFT).
            for v in &cc.ac.data {
                assert!(n as i128 % v.den == 0, "N={n}: {v:?}");
            }
        }
    }

    #[test]
    fn bc_entries_are_pm1() {
        // At the paper's chosen point counts (N = 4 and 6) the expanded
        // input transform keeps every entry in {-1,0,1}: implementable with
        // additions only (§4.1 — "6 and 4 are suitable choices").
        for n in [4usize, 6] {
            let cc = CircularConv::new(n);
            for v in &cc.bc.data {
                assert!(v.num.abs() <= 1 && v.den == 1, "N={n}: {v:?}");
            }
        }
    }
}
