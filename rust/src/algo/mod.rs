//! Fast-convolution algorithm engine.
//!
//! Everything the paper's §3–§4 describes is constructed here from first
//! principles with exact rational arithmetic:
//!
//! * [`symbolic`] — the quotient ring ℚ\[s\]/(s² − c₁s − c₀) that lets DFT
//!   twiddle factors at N ∈ {3, 4, 6} points be first-order
//!   integer-coefficient polynomials (paper §4.1).
//! * [`dft`] — symbolic DFT: the SFT component matrices F_N (Eq. 6/9),
//!   exact inverses iF_N (Eq. 7) and the 3-multiplication degree-1
//!   polynomial product (Eq. 8/10).
//! * [`circular`] — the bilinear circular-convolution algorithm over the
//!   symbolic component space (8 real mults for N=6, 5 for N=4).
//! * [`correction`] — §4.2: correction terms converting wrapped circular
//!   outputs into valid linear outputs and extending the tile size M
//!   (Fig. 2), reproducing the paper's multiplication counts.
//! * [`toomcook`] — the Winograd / Toom-Cook F(M,R) generator used for all
//!   baselines.
//! * [`bilinear`] — the common (G, B, A) bilinear-algorithm container with
//!   1-D/2-D appliers, operation counts and the direct-conv reference.
//! * [`fft`] / [`ntt`] — classic float FFT convolution and number-theoretic
//!   transform convolution baselines (related work, Table 3).
//! * [`iterative`] — Appendix B: iterative SFC for very large kernels.

pub mod bilinear;
pub mod circular;
pub mod correction;
pub mod dft;
pub mod fft;
pub mod iterative;
pub mod ntt;
pub mod registry;
pub mod symbolic;
pub mod toomcook;

pub use bilinear::{direct_conv1d, direct_conv2d, Bilinear};
pub use correction::sfc;
pub use registry::{catalog, AlgoKind, AlgoSpec};
pub use toomcook::winograd;
