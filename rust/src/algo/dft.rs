//! Symbolic DFT: component transforms, exact inverses and the 3-mult
//! degree-1 polynomial product (paper §4.1, Eq. 6–10).
//!
//! A real length-N sequence x has DFT values X_m = Σ_n ω^{mn} x_n with
//! ω = e^{-2πj/N} = conj(s). In the ring ℚ[s]/(s²−c₁s−c₀) each X_m is a
//! first-order polynomial u_m + v_m·s whose *components* u_m, v_m are
//! integer ±1/0 combinations of the inputs. Hermitian symmetry
//! (X_{N−m} = conj(X_m)) halves the stored components:
//!
//!   m = 0 or N/2            -> one real component
//!   0 < m < N/2             -> a (u_m, v_m) pair
//!
//! The matrix mapping x to the component vector is the paper's SFT matrix
//! (Eq. 6 for N=6, Eq. 9 for N=4); it contains only −1/0/1.

use super::symbolic::{Rule, Sym};
use crate::linalg::{Frac, FracMat};

/// Which DFT bin a component row describes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Comp {
    /// X_m is real (m = 0 or m = N/2): one component.
    Single {
        /// bin index
        m: usize,
    },
    /// X_m = u + v·s: two components (stored consecutively).
    Pair {
        /// bin index
        m: usize,
    },
}

/// Symbolic DFT plan for N points.
#[derive(Clone, Debug)]
pub struct SymDft {
    /// transform length N
    pub n: usize,
    /// reduction rule of the symbol s
    pub rule: Rule,
    /// real-component layout of the spectrum
    pub comps: Vec<Comp>,
    /// Number of real components (= N for real input).
    pub n_comps: usize,
    /// Number of real multiplications for one element-wise product in the
    /// transform domain: 1 per Single, 3 per Pair (Eq. 8/10).
    pub t_mults: usize,
}

impl SymDft {
    /// Symbolic DFT plan for N points (N ∈ {2, 3, 4, 6}).
    pub fn new(n: usize) -> SymDft {
        let rule = Rule::for_points(n);
        let mut comps = Vec::new();
        let mut n_comps = 0;
        let mut t_mults = 0;
        for m in 0..=n / 2 {
            if (2 * m) % n == 0 {
                comps.push(Comp::Single { m });
                n_comps += 1;
                t_mults += 1;
            } else {
                comps.push(Comp::Pair { m });
                n_comps += 2;
                t_mults += 3;
            }
        }
        assert_eq!(n_comps, n, "component count must equal N for real input");
        SymDft { n, rule, comps, n_comps, t_mults }
    }

    /// ω = e^{-2πj/N} as a ring element (= conj(s)). For N = 2 the root is
    /// the rational −1 and the symbol is unused.
    fn omega(&self) -> Sym {
        if self.n == 2 {
            Sym::int(self.rule, -1)
        } else {
            Sym::s(self.rule).conj()
        }
    }

    /// The forward SFT component matrix F_N (N×N, integer ±1/0):
    /// row layout follows `comps` (u row then v row for pairs).
    pub fn f_mat(&self) -> FracMat {
        let mut rows: Vec<Vec<Frac>> = Vec::new();
        let omega = self.omega();
        for comp in &self.comps {
            let m = match comp {
                Comp::Single { m } | Comp::Pair { m } => *m,
            };
            // X_m = sum_n omega^{m n} x_n
            let mut urow = vec![Frac::ZERO; self.n];
            let mut vrow = vec![Frac::ZERO; self.n];
            for n_idx in 0..self.n {
                let mut w = Sym::one(self.rule);
                for _ in 0..(m * n_idx) % self.n {
                    w = w * omega;
                }
                // note: omega^{mn} = omega^{(mn) mod N} since omega^N = 1
                urow[n_idx] = w.a;
                vrow[n_idx] = w.b;
            }
            match comp {
                Comp::Single { .. } => {
                    assert!(vrow.iter().all(|f| f.is_zero()), "real bin must have no s part");
                    rows.push(urow);
                }
                Comp::Pair { .. } => {
                    rows.push(urow);
                    rows.push(vrow);
                }
            }
        }
        let cols = self.n;
        let data: Vec<Frac> = rows.into_iter().flatten().collect();
        let m = FracMat { rows: data.len() / cols, cols, data };
        assert!(m.is_integral(), "SFT matrix must be integral");
        m
    }

    /// Exact inverse component transform iF_N (N×N, entries k/N): maps the
    /// component vector back to the sequence (Eq. 7 for N=6).
    pub fn if_mat(&self) -> FracMat {
        // y_n = (1/N) Σ_{m=0}^{N-1} ω^{-mn} X_m ; ω^{-1} = s.
        // Express every X_m over the kept components (Hermitian symmetry),
        // accumulate ring coefficients, assert the s part cancels.
        let n = self.n;
        // column index of each component + how X_m reads in components:
        // for each m in 0..N: list of (comp_col, ring coefficient)
        let mut comp_col = Vec::new(); // start column per kept m index
        let mut col = 0;
        for c in &self.comps {
            comp_col.push(col);
            col += match c {
                Comp::Single { .. } => 1,
                Comp::Pair { .. } => 2,
            };
        }
        let kept_index = |m: usize| -> (usize, bool) {
            // (index into comps, conjugated?)
            if m <= n / 2 {
                (m, false)
            } else {
                (n - m, true)
            }
        };
        // ω^{-1}: the inverse root (= s, or the rational −1 for N = 2).
        let omega_inv = if n == 2 { Sym::int(self.rule, -1) } else { Sym::s(self.rule) };
        let mut out = FracMat::zeros(n, n);
        for y_idx in 0..n {
            // coefficient accumulator per component column, as ring elems
            let mut acc = vec![Sym::zero(self.rule); n];
            for m in 0..n {
                // ω^{-mn}
                let mut w = Sym::one(self.rule);
                for _ in 0..(m * y_idx) % n {
                    w = w * omega_inv;
                }
                let (ci, conj) = kept_index(m);
                match self.comps[ci] {
                    Comp::Single { .. } => {
                        acc[comp_col[ci]] = acc[comp_col[ci]] + w;
                    }
                    Comp::Pair { .. } => {
                        // X_m = u + v s  (or conj: u + v conj(s))
                        let s_term = if conj { Sym::s(self.rule).conj() } else { Sym::s(self.rule) };
                        acc[comp_col[ci]] = acc[comp_col[ci]] + w;
                        acc[comp_col[ci] + 1] = acc[comp_col[ci] + 1] + w * s_term;
                    }
                }
            }
            for (c, a) in acc.iter().enumerate() {
                assert!(a.b.is_zero(), "inverse DFT coefficient must be real, got {a:?}");
                out[(y_idx, c)] = a.a / Frac::int(n as i128);
            }
        }
        out
    }

    /// Expansion matrix E (t_mults×N): maps a component vector to the
    /// multiplication operands. Singles pass through; pairs expand to
    /// (u, v, u+v) per the 3-mult product (Eq. 8/10 left factors).
    pub fn expand_mat(&self) -> FracMat {
        let mut out = FracMat::zeros(self.t_mults, self.n);
        let mut row = 0;
        let mut col = 0;
        for c in &self.comps {
            match c {
                Comp::Single { .. } => {
                    out[(row, col)] = Frac::ONE;
                    row += 1;
                    col += 1;
                }
                Comp::Pair { .. } => {
                    out[(row, col)] = Frac::ONE;
                    out[(row + 1, col + 1)] = Frac::ONE;
                    out[(row + 2, col)] = Frac::ONE;
                    out[(row + 2, col + 1)] = Frac::ONE;
                    row += 3;
                    col += 2;
                }
            }
        }
        out
    }

    /// Combination matrix (N×t_mults): maps the element-wise products back
    /// to product components. For a pair with products (m0, m1, m2) =
    /// (u·p, v·q, (u+v)(p+q)) the product components are
    ///   P_a = m0 + c0·m1,   P_b = m2 − m0 + (c1 − 1)·m1
    /// (this is Eq. 8 for N=6 where (c0,c1)=(−1,1), Eq. 10 for N=4).
    pub fn combine_mat(&self) -> FracMat {
        let c0 = Frac::int(self.rule.c0);
        let c1 = Frac::int(self.rule.c1);
        let mut out = FracMat::zeros(self.n_comps, self.t_mults);
        let mut row = 0;
        let mut col = 0;
        for c in &self.comps {
            match c {
                Comp::Single { .. } => {
                    out[(row, col)] = Frac::ONE;
                    row += 1;
                    col += 1;
                }
                Comp::Pair { .. } => {
                    out[(row, col)] = Frac::ONE;
                    out[(row, col + 1)] = c0;
                    out[(row + 1, col)] = -Frac::ONE;
                    out[(row + 1, col + 1)] = c1 - Frac::ONE;
                    out[(row + 1, col + 2)] = Frac::ONE;
                    row += 2;
                    col += 3;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Naive complex DFT for cross-checking.
    fn dft_complex(x: &[f64]) -> Vec<(f64, f64)> {
        let n = x.len();
        (0..n)
            .map(|m| {
                let mut re = 0.0;
                let mut im = 0.0;
                for (k, &v) in x.iter().enumerate() {
                    let ang = -2.0 * std::f64::consts::PI * (m * k) as f64 / n as f64;
                    re += v * ang.cos();
                    im += v * ang.sin();
                }
                (re, im)
            })
            .collect()
    }

    #[test]
    fn f6_matches_paper_eq6() {
        // The middle matrix of Eq. 6 (the SFT-6 matrix).
        let expect: [[i128; 6]; 6] = [
            [1, 1, 1, 1, 1, 1],
            [1, 1, 0, -1, -1, 0],
            [0, -1, -1, 0, 1, 1],
            [1, 0, -1, 1, 0, -1],
            [0, -1, 1, 0, -1, 1],
            [1, -1, 1, -1, 1, -1],
        ];
        let f = SymDft::new(6).f_mat();
        for i in 0..6 {
            for j in 0..6 {
                assert_eq!(f[(i, j)], Frac::int(expect[i][j]), "F6[{i}][{j}]");
            }
        }
    }

    #[test]
    fn f4_matches_paper_eq9() {
        let expect: [[i128; 4]; 4] = [
            [1, 1, 1, 1],
            [1, 0, -1, 0],
            [0, -1, 0, 1],
            [1, -1, 1, -1],
        ];
        let f = SymDft::new(4).f_mat();
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(f[(i, j)], Frac::int(expect[i][j]), "F4[{i}][{j}]");
            }
        }
    }

    #[test]
    fn inverse_times_forward_is_identity() {
        for n in [2usize, 3, 4, 6] {
            let d = SymDft::new(n);
            let prod = d.if_mat().matmul(&d.f_mat());
            assert_eq!(prod, FracMat::identity(n), "iF·F != I for N={n}");
        }
    }

    #[test]
    fn transform_matches_complex_dft() {
        // Components computed by F_N must equal the (u, v) decomposition of
        // the complex DFT in the (1, s) basis.
        for n in [3usize, 4, 6] {
            let d = SymDft::new(n);
            let f = d.f_mat().to_f64();
            let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.7).sin() + 0.3).collect();
            let comps = f.matvec(&x);
            let spectrum = dft_complex(&x);
            let mut row = 0;
            for c in &d.comps {
                match *c {
                    Comp::Single { m } => {
                        assert!((comps[row] - spectrum[m].0).abs() < 1e-9);
                        assert!(spectrum[m].1.abs() < 1e-9);
                        row += 1;
                    }
                    Comp::Pair { m } => {
                        let (sr, si) = d.rule.s_complex();
                        let re = comps[row] + comps[row + 1] * sr;
                        let im = comps[row + 1] * si;
                        assert!((re - spectrum[m].0).abs() < 1e-9, "N={n} m={m}");
                        assert!((im - spectrum[m].1).abs() < 1e-9, "N={n} m={m}");
                        row += 2;
                    }
                }
            }
        }
    }

    #[test]
    fn mult_counts_match_paper() {
        // §4: DFT-6 circular convolution costs 8 real mults, DFT-4 costs 5.
        assert_eq!(SymDft::new(6).t_mults, 8);
        assert_eq!(SymDft::new(4).t_mults, 5);
        assert_eq!(SymDft::new(3).t_mults, 4);
        assert_eq!(SymDft::new(2).t_mults, 2);
    }

    #[test]
    fn if6_has_sixth_denominators() {
        // Eq. 7: iF6 is an integer matrix scaled by 1/6 (the paper folds
        // the 1/6 into the model weights). Our component ordering differs
        // from Eq. 7's (equivalence is established by iF·F = I), but the
        // 1/N structure must hold: every denominator divides 6.
        let ifm = SymDft::new(6).if_mat();
        for v in &ifm.data {
            assert!(6 % v.den == 0, "denominator must divide 6: {v:?}");
        }
        // and it is exactly the inverse of the addition-only SFT.
        let d = SymDft::new(6);
        assert_eq!(d.if_mat().matmul(&d.f_mat()), FracMat::identity(6));
    }
}
