//! The SynthImage generator.
//!
//! Class k ∈ 0..10 is a plane-wave texture with orientation θ_k = kπ/10
//! and spatial frequency f_k ∈ {2.2, 3.4} cycles/image (alternating), with
//! random phase, random amplitude, mild orientation jitter, plus a 1/f
//! power-law noise background and per-channel color cast. Energy is
//! deliberately concentrated at low frequencies (natural-image-like) so
//! the Fig. 3 spectrum observation and the frequency-wise quantization
//! ablations (Tables 4/5) exercise the same mechanism as the paper.

use super::Dataset;
use crate::util::Pcg32;

/// Number of texture classes.
pub const CLASSES: usize = 10;
/// Image height/width in pixels.
pub const SIZE: usize = 32;
/// Color channels per image.
pub const CHANNELS: usize = 3;

/// Generate `n` labelled samples (deterministic in `seed`).
pub fn generate(n: usize, seed: u64) -> Dataset {
    let mut rng = Pcg32::new(seed, 77);
    let mut labels = Vec::with_capacity(n);
    let mut images = Vec::with_capacity(n * CHANNELS * SIZE * SIZE);
    for i in 0..n {
        let label = (i % CLASSES) as u8;
        labels.push(label);
        images.extend(sample(label, &mut rng));
    }
    Dataset { n, c: CHANNELS, h: SIZE, w: SIZE, n_classes: CLASSES, labels, images }
}

/// One CHW sample for the given class.
pub fn sample(label: u8, rng: &mut Pcg32) -> Vec<f32> {
    let k = label as usize;
    let theta = k as f64 * std::f64::consts::PI / CLASSES as f64 + 0.08 * rng.next_gaussian();
    let freq = if k % 2 == 0 { 2.2 } else { 3.4 } + 0.15 * rng.next_gaussian();
    let phase = rng.next_f64() * std::f64::consts::TAU;
    let amp = 0.8 + 0.3 * rng.next_f64();
    // Low-frequency 1/f background built from a handful of random waves.
    let n_waves = 6;
    let bg: Vec<(f64, f64, f64, f64)> = (0..n_waves)
        .map(|w| {
            let f = 0.5 + 1.4 * (w as f64 + rng.next_f64()); // rising freq
            let th = rng.next_f64() * std::f64::consts::PI;
            let ph = rng.next_f64() * std::f64::consts::TAU;
            let a = 0.9 / f; // 1/f amplitude law
            (f, th, ph, a)
        })
        .collect();
    let cast: Vec<f64> = (0..CHANNELS).map(|_| 0.2 * rng.next_gaussian()).collect();
    let chan_gain = [1.0, 0.85, 0.7];

    let mut out = vec![0f32; CHANNELS * SIZE * SIZE];
    for y in 0..SIZE {
        for x in 0..SIZE {
            let (xf, yf) = (x as f64 / SIZE as f64, y as f64 / SIZE as f64);
            let u = xf * theta.cos() + yf * theta.sin();
            let sig = amp * (std::f64::consts::TAU * freq * u + phase).sin();
            let mut noise = 0.0;
            for &(f, th, ph, a) in &bg {
                let v = xf * th.cos() + yf * th.sin();
                noise += a * (std::f64::consts::TAU * f * v + ph).sin();
            }
            for c in 0..CHANNELS {
                let v = chan_gain[c] * sig + noise + cast[c] + 0.05 * rng.next_gaussian();
                out[c * SIZE * SIZE + y * SIZE + x] = v as f32;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let a = generate(20, 5);
        let b = generate(20, 5);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn balanced_labels() {
        let ds = generate(100, 1);
        for k in 0..CLASSES as u8 {
            assert_eq!(ds.labels.iter().filter(|&&l| l == k).count(), 10);
        }
    }

    #[test]
    fn energy_concentrates_at_low_frequency() {
        // The property Fig. 3 depends on: row-wise DFT-8 energy must be
        // dominated by the lowest bins.
        let ds = generate(40, 9);
        let mut low = 0.0f64;
        let mut high = 0.0f64;
        for i in 0..ds.n {
            let img = ds.image(i);
            for row in 0..SIZE {
                // 8-point DFT on the first 8 pixels of each row (channel 0)
                let seg: Vec<f64> = (0..8).map(|x| img[row * SIZE + x] as f64).collect();
                for m in 0..8 {
                    let (mut re, mut im) = (0.0, 0.0);
                    for (t, &v) in seg.iter().enumerate() {
                        let ang = -std::f64::consts::TAU * (m * t) as f64 / 8.0;
                        re += v * ang.cos();
                        im += v * ang.sin();
                    }
                    let e = re * re + im * im;
                    if m <= 1 || m == 7 {
                        low += e;
                    } else if (3..=5).contains(&m) {
                        high += e;
                    }
                }
            }
        }
        assert!(low > 2.0 * high, "low {low} vs high {high}");
    }

    /// Coarse 2-D power spectrum of channel 0 (phase-invariant feature —
    /// the kind of representation a conv+pool network learns).
    fn spectrum_features(img: &[f32]) -> Vec<f64> {
        let bins = 8;
        let mut feats = Vec::with_capacity(bins * bins);
        for fy in 0..bins {
            for fx in 0..bins {
                let (mut re, mut im) = (0.0f64, 0.0f64);
                for y in 0..SIZE {
                    for x in 0..SIZE {
                        let ang = -std::f64::consts::TAU
                            * ((fy * y) as f64 + (fx * x) as f64)
                            / SIZE as f64;
                        let v = img[y * SIZE + x] as f64;
                        re += v * ang.cos();
                        im += v * ang.sin();
                    }
                }
                feats.push((re * re + im * im).sqrt());
            }
        }
        feats
    }

    #[test]
    fn classes_are_distinguishable() {
        // Nearest-centroid on phase-invariant spectral features must beat
        // chance comfortably — sanity that the classification task is
        // learnable by a frequency-selective model (i.e. a CNN). Pixel
        // centroids cannot work by construction (random phases).
        let train = generate(300, 3);
        let test = generate(100, 4);
        let dim = 64;
        let mut centroids = vec![vec![0f64; dim]; CLASSES];
        let mut counts = [0usize; CLASSES];
        for i in 0..train.n {
            let l = train.labels[i] as usize;
            counts[l] += 1;
            for (d, v) in centroids[l].iter_mut().zip(spectrum_features(train.image(i))) {
                *d += v;
            }
        }
        for (c, n) in centroids.iter_mut().zip(counts) {
            for v in c.iter_mut() {
                *v /= n as f64;
            }
        }
        let mut correct = 0;
        for i in 0..test.n {
            let feats = spectrum_features(test.image(i));
            let best = (0..CLASSES)
                .min_by(|&a, &b| {
                    let da: f64 = centroids[a].iter().zip(&feats).map(|(c, v)| (c - v).powi(2)).sum();
                    let db: f64 = centroids[b].iter().zip(&feats).map(|(c, v)| (c - v).powi(2)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == test.labels[i] as usize {
                correct += 1;
            }
        }
        assert!(correct > 40, "spectral nearest centroid got {correct}/100 (chance = 10)");
    }
}
