//! SynthImage — the ImageNet stand-in dataset (see DESIGN.md §2).
//!
//! 10-class 32×32×3 textures: each class is a band-limited oriented
//! pattern (class-specific orientation + spatial frequency) embedded in
//! 1/f "natural image" background noise, so (a) a CNN must learn
//! frequency-selective conv filters, (b) activation spectra concentrate at
//! low frequencies like real images — the property Fig. 3 and the
//! frequency-wise quantization strategy depend on.
//!
//! The generator lives in Rust (canonical, deterministic); `make
//! artifacts` materializes `artifacts/dataset.bin` which the JAX trainer
//! reads, so training, calibration and evaluation share one distribution.

pub mod synth;

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

/// Dataset file magic (`SFCD`).
pub const MAGIC: &[u8; 4] = b"SFCD";

/// An image-classification dataset in CHW f32 layout.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// sample count
    pub n: usize,
    /// channels per image
    pub c: usize,
    /// image height
    pub h: usize,
    /// image width
    pub w: usize,
    /// number of label classes
    pub n_classes: usize,
    /// per-sample class labels
    pub labels: Vec<u8>,
    /// n × c × h × w, sample-major
    pub images: Vec<f32>,
}

impl Dataset {
    /// Floats per image (C·H·W).
    pub fn sample_len(&self) -> usize {
        self.c * self.h * self.w
    }

    /// One image as a CHW slice.
    pub fn image(&self, i: usize) -> &[f32] {
        let s = self.sample_len();
        &self.images[i * s..(i + 1) * s]
    }

    /// First `k` samples as a new dataset (calibration split).
    pub fn take(&self, k: usize) -> Dataset {
        let k = k.min(self.n);
        Dataset {
            n: k,
            c: self.c,
            h: self.h,
            w: self.w,
            n_classes: self.n_classes,
            labels: self.labels[..k].to_vec(),
            images: self.images[..k * self.sample_len()].to_vec(),
        }
    }

    /// Write the dataset in the SFCD binary format.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        for v in [self.n as u32, self.c as u32, self.h as u32, self.w as u32, self.n_classes as u32] {
            f.write_all(&v.to_le_bytes())?;
        }
        f.write_all(&self.labels)?;
        for v in &self.images {
            f.write_all(&v.to_le_bytes())?;
        }
        Ok(())
    }

    /// Read a dataset written by [`Dataset::save`].
    pub fn load(path: &Path) -> Result<Dataset> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
        );
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{} is not a SynthImage dataset", path.display());
        }
        let mut u32buf = [0u8; 4];
        let mut read_u32 = |f: &mut dyn Read| -> Result<u32> {
            f.read_exact(&mut u32buf)?;
            Ok(u32::from_le_bytes(u32buf))
        };
        let n = read_u32(&mut f)? as usize;
        let c = read_u32(&mut f)? as usize;
        let h = read_u32(&mut f)? as usize;
        let w = read_u32(&mut f)? as usize;
        let n_classes = read_u32(&mut f)? as usize;
        let mut labels = vec![0u8; n];
        f.read_exact(&mut labels)?;
        let mut images = vec![0f32; n * c * h * w];
        let mut buf = vec![0u8; 4 * images.len()];
        f.read_exact(&mut buf)?;
        for (i, chunk) in buf.chunks_exact(4).enumerate() {
            images[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        Ok(Dataset { n, c, h, w, n_classes, labels, images })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_load_round_trip() {
        let ds = synth::generate(64, 7);
        let dir = std::env::temp_dir().join("sfc_ds_test.bin");
        ds.save(&dir).unwrap();
        let back = Dataset::load(&dir).unwrap();
        assert_eq!(back.n, ds.n);
        assert_eq!(back.labels, ds.labels);
        assert_eq!(back.images, ds.images);
        std::fs::remove_file(dir).ok();
    }

    #[test]
    fn take_splits() {
        let ds = synth::generate(32, 1);
        let cal = ds.take(10);
        assert_eq!(cal.n, 10);
        assert_eq!(cal.image(3), ds.image(3));
    }
}
