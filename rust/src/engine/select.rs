//! Engine selection: BOPs-model heuristics and measured autotuning.
//!
//! [`Policy::Heuristic`] ranks the engines that support a descriptor by
//! their analytic bit-operation cost (reusing [`crate::bops`]) — fast and
//! deterministic, the right default at model-build time.
//! [`Policy::Autotune`] micro-benchmarks every supporting engine on a
//! synthetic workload of the real layer shape and picks the measured
//! winner — cuDNN `findAlgorithm` style, exposed as `sfc autotune`.
//! Either way the chosen plan lands in the [`PlanCache`] keyed by
//! (descriptor, policy), so selection runs once per shape.

use super::cache::{self, PlanCache, PlanKey};
use super::desc::{ConvDesc, QuantSpec};
use super::tuning::{self, TuningTable};
use super::{all_engines, ConvEngine, ConvPlan};
use crate::nn::model::ConvShape;
use crate::nn::tensor::Tensor;
use crate::quant::qconv::{collect_act_maxima, QCalib, QConvLayer};
use crate::quant::Granularity;
use crate::util::Pcg32;
use anyhow::{bail, Result};
use std::sync::{Arc, OnceLock};
use std::time::Instant;

/// Micro-benchmark budget for [`Policy::Autotune`].
#[derive(Clone, Copy, Debug)]
pub struct AutotuneCfg {
    /// unmeasured warm-up runs per candidate
    pub warmup: usize,
    /// measured runs per candidate (median is kept)
    pub iters: usize,
}

impl Default for AutotuneCfg {
    fn default() -> Self {
        AutotuneCfg { warmup: 1, iters: 3 }
    }
}

/// How the selector picks among supporting engines.
#[derive(Clone, Copy, Debug)]
pub enum Policy {
    /// analytic BOPs-model ranking (deterministic, no execution)
    Heuristic,
    /// measure every candidate on the real shape, pick the fastest
    Autotune(AutotuneCfg),
}

impl Policy {
    fn tag(&self) -> &'static str {
        match self {
            Policy::Heuristic => "heuristic",
            Policy::Autotune(_) => "autotune",
        }
    }
}

/// One row of a cache-blocking sweep report ([`Selector::tune_blocking`]).
#[derive(Clone, Copy, Debug)]
pub struct BlockTuneEntry {
    /// the Mc/Kc/Nc candidate that was measured
    pub blocking: crate::linalg::gemm::Blocking,
    /// measured median seconds per run
    pub median_s: f64,
    /// true on the measured winner
    pub selected: bool,
}

/// One row of a tile-length sweep report ([`Selector::tune_tile_len`]).
#[derive(Clone, Copy, Debug)]
pub struct TileTuneEntry {
    /// the overlap-save transform length that was measured
    pub tile_len: usize,
    /// measured median seconds per run
    pub median_s: f64,
    /// true on the measured winner
    pub selected: bool,
}

/// One row of an autotune report.
#[derive(Clone, Copy, Debug)]
pub struct TuneEntry {
    /// candidate engine name
    pub engine: &'static str,
    /// measured median seconds per run
    pub median_s: f64,
    /// the engine's analytic BOPs cost for the descriptor
    pub cost_bops: f64,
    /// the engine's reported scratch demand
    pub workspace_bytes: usize,
    /// true on the measured winner
    pub selected: bool,
}

/// The algorithm selector: engine list + plan cache + policy, optionally
/// warmed from a persisted [`TuningTable`] so committed measurements
/// replace startup re-tuning.
pub struct Selector {
    engines: Vec<Box<dyn ConvEngine>>,
    cache: Arc<PlanCache>,
    policy: Policy,
    tuning: Option<TuningTable>,
}

impl Selector {
    /// Selector over the full catalog-seeded engine list. Heuristic
    /// selectors share the process-wide plan cache; Autotune selectors
    /// get an isolated cache, because their planning runs multi-second
    /// micro-benchmarks inside the cache's build slot and must never
    /// hold the global lock against concurrent model builders.
    pub fn new(policy: Policy) -> Selector {
        let cache = match policy {
            Policy::Heuristic => cache::global(),
            Policy::Autotune(_) => Arc::new(PlanCache::new()),
        };
        Selector::with_cache(policy, cache)
    }

    /// Selector with an isolated cache (tests, experiments).
    pub fn with_cache(policy: Policy, cache: Arc<PlanCache>) -> Selector {
        Selector { engines: all_engines(), cache, policy, tuning: None }
    }

    /// Attach a persisted tuning table: [`Selector::plan`] pins tuned
    /// descriptors to their measured winner before consulting the
    /// policy. (Selectors without their own table still consult the
    /// process-wide one, see [`tuning::install_global`].)
    pub fn with_tuning(mut self, table: TuningTable) -> Selector {
        self.tuning = Some(table);
        self
    }

    /// The measured winner for a descriptor, if any tuning table (own,
    /// then process-wide) covers it.
    fn tuned_engine(&self, d: &ConvDesc) -> Option<String> {
        if let Some(c) = self.tuning.as_ref().and_then(|t| t.lookup(d)) {
            return Some(c.engine.clone());
        }
        tuning::global_lookup(d).map(|c| c.engine.clone())
    }

    /// The selection policy this selector runs.
    pub fn policy(&self) -> Policy {
        self.policy
    }

    /// The plan cache backing this selector.
    pub fn cache(&self) -> &Arc<PlanCache> {
        &self.cache
    }

    /// Every engine this selector chooses among.
    pub fn engines(&self) -> &[Box<dyn ConvEngine>] {
        &self.engines
    }

    /// Case-insensitive engine lookup by catalog name.
    pub fn engine_named(&self, name: &str) -> Option<&dyn ConvEngine> {
        self.engines.iter().find(|e| e.name().eq_ignore_ascii_case(name)).map(|e| e.as_ref())
    }

    /// Engines able to execute this descriptor.
    pub fn candidates(&self, d: &ConvDesc) -> Vec<&dyn ConvEngine> {
        self.engines.iter().filter(|e| e.supports(d)).map(|e| e.as_ref()).collect()
    }

    /// Policy-driven plan for a descriptor (cached). Descriptors covered
    /// by a tuning table are pinned to the measured winner; if that
    /// engine no longer exists or no longer supports the descriptor
    /// (stale table), selection falls through to the policy rather than
    /// failing.
    pub fn plan(&self, d: &ConvDesc) -> Result<Arc<ConvPlan>> {
        if let Some(name) = self.tuned_engine(d) {
            if let Ok(p) = self.plan_named(&name, d) {
                return Ok(p);
            }
        }
        self.cache.get_or_try_insert(PlanKey::new(*d, self.policy.tag()), || {
            let plan = match self.policy {
                Policy::Heuristic => self.select_heuristic(d)?,
                Policy::Autotune(cfg) => self.select_autotune(d, cfg)?,
            };
            Ok(Arc::new(plan))
        })
    }

    /// Plan pinned to a named engine (cached). The way experiment
    /// harnesses reproduce a specific Table-1 row.
    pub fn plan_named(&self, name: &str, d: &ConvDesc) -> Result<Arc<ConvPlan>> {
        let Some(engine) = self.engine_named(name) else {
            bail!("unknown engine '{name}' (run `sfc autotune` to list engines)")
        };
        self.cache.get_or_try_insert(PlanKey::new(*d, engine.name()), || {
            if !engine.supports(d) {
                bail!("engine '{}' does not support descriptor {:?}", engine.name(), d);
            }
            Ok(Arc::new(engine.plan(d)?))
        })
    }

    fn select_heuristic(&self, d: &ConvDesc) -> Result<ConvPlan> {
        let mut best: Option<(f64, &dyn ConvEngine)> = None;
        for e in self.candidates(d) {
            let c = e.cost_model(d);
            if best.as_ref().map_or(true, |(bc, _)| c < *bc) {
                best = Some((c, e));
            }
        }
        match best {
            Some((_, e)) => e.plan(d),
            None => bail!("no engine supports descriptor {:?}", d),
        }
    }

    fn select_autotune(&self, d: &ConvDesc, cfg: AutotuneCfg) -> Result<ConvPlan> {
        let entries = self.autotune_with(d, cfg)?;
        let winner = entries.iter().find(|t| t.selected).expect("autotune marks a winner");
        self.engine_named(winner.engine).expect("winner is a known engine").plan(d)
    }

    /// Measure every supporting engine on this descriptor's shape and
    /// return the report, fastest first (winner flagged).
    pub fn autotune(&self, d: &ConvDesc) -> Result<Vec<TuneEntry>> {
        let cfg = match self.policy {
            Policy::Autotune(c) => c,
            Policy::Heuristic => AutotuneCfg::default(),
        };
        self.autotune_with(d, cfg)
    }

    /// Deterministic synthetic (input, weight) workload of a descriptor's
    /// shape (grouped descriptors carry [OC, IC/g, R, R] weights).
    fn synthetic_workload(d: &ConvDesc) -> (Tensor, Tensor) {
        let mut rng = Pcg32::seeded(0xA070 ^ d.macs());
        let mut x = Tensor::zeros(&[d.batch.max(1), d.ic, d.h, d.w]);
        rng.fill_gaussian(&mut x.data, 1.0);
        let mut w = Tensor::zeros(&[d.oc, d.ic / d.groups, d.r, d.r]);
        rng.fill_gaussian(&mut w.data, 0.2);
        (x, w)
    }

    /// Median seconds per run of a plan's steady-state (reused-workspace)
    /// datapath over the synthetic workload — the measurement primitive
    /// behind both the engine autotuner and the blocking sweep.
    fn measure_plan(
        d: &ConvDesc,
        plan: &Arc<ConvPlan>,
        x: &Tensor,
        w: &Tensor,
        cfg: AutotuneCfg,
    ) -> f64 {
        // Quantized descriptors are measured on the datapath PTQ will
        // actually install (the quantized executor, calibrated on the
        // synthetic workload) — not the float kernel.
        let qexec = if d.quant.is_some() {
            Some(match plan.fast_plan() {
                Some(fast) => {
                    let maxima = collect_act_maxima(x, fast, d.pad);
                    QConvLayer::from_plan(
                        plan.clone(),
                        w,
                        Vec::new(),
                        &QCalib::TransformMaxima(&maxima),
                    )
                }
                None => {
                    QConvLayer::from_plan(plan.clone(), w, Vec::new(), &QCalib::MaxAbs(x.max_abs()))
                }
            })
        } else {
            None
        };
        // Measure the steady-state (reused-workspace) datapath, like
        // a serving worker would run it.
        let mut ws = super::Workspace::new();
        let mut run_once = || match &qexec {
            Some(q) => q.forward_with(x, &mut ws),
            None => plan.run_with(x, w, &[], &mut ws),
        };
        for _ in 0..cfg.warmup {
            std::hint::black_box(run_once());
        }
        let mut samples = Vec::with_capacity(cfg.iters.max(1));
        for _ in 0..cfg.iters.max(1) {
            let t0 = Instant::now();
            std::hint::black_box(run_once());
            samples.push(t0.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        samples[samples.len() / 2]
    }

    /// Sweep the GEMM cache-blocking candidates for one engine on one
    /// descriptor: measure the engine's plan under each
    /// [`crate::linalg::gemm::Blocking::candidates`] entry and return the
    /// report, fastest first (winner flagged). The process-wide blocking
    /// override is cleared afterwards — committing the winner is the
    /// caller's job (via [`TuningTable::set_blocking`] +
    /// [`tuning::install_global`]).
    pub fn tune_blocking(
        &self,
        engine: &str,
        d: &ConvDesc,
        cfg: AutotuneCfg,
    ) -> Result<Vec<BlockTuneEntry>> {
        use crate::linalg::gemm;
        let Some(e) = self.engine_named(engine) else {
            bail!("unknown engine '{engine}'")
        };
        if !e.supports(d) {
            bail!("engine '{}' does not support descriptor {:?}", e.name(), d);
        }
        let plan = Arc::new(e.plan(d)?);
        let (x, w) = Self::synthetic_workload(d);
        let mut entries = Vec::new();
        for b in gemm::Blocking::candidates() {
            gemm::set_blocking_override(Some(b));
            let median_s = Self::measure_plan(d, &plan, &x, &w, cfg);
            entries.push(BlockTuneEntry { blocking: b, median_s, selected: false });
        }
        gemm::set_blocking_override(None);
        let best = entries
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.median_s.partial_cmp(&b.1.median_s).unwrap())
            .map(|(i, _)| i)
            .expect("non-empty candidate list");
        entries[best].selected = true;
        entries.sort_by(|a, b| a.median_s.partial_cmp(&b.median_s).unwrap());
        Ok(entries)
    }

    /// Sweep overlap-save transform lengths for one (tiled) engine on
    /// one descriptor: install each power-of-two candidate ≥ the kernel
    /// through [`super::tiled::set_tile_len_override`], **re-plan under
    /// it** (workspace bounds depend on the tile), measure, and return
    /// the report fastest first (winner flagged). The override is
    /// cleared afterwards — committing the winner is the caller's job
    /// (via [`TuningTable::set_tile_len`] + [`tuning::install_global`]).
    pub fn tune_tile_len(
        &self,
        engine: &str,
        d: &ConvDesc,
        cfg: AutotuneCfg,
    ) -> Result<Vec<TileTuneEntry>> {
        use super::tiled;
        let Some(e) = self.engine_named(engine) else {
            bail!("unknown engine '{engine}'")
        };
        if !e.supports(d) {
            bail!("engine '{}' does not support descriptor {:?}", e.name(), d);
        }
        let (x, w) = Self::synthetic_workload(d);
        let mut entries = Vec::new();
        for t in [8usize, 16, 32, 64, 128] {
            if t < d.r {
                continue;
            }
            // the override must be live while planning: plans bake the
            // tile into their gather geometry and workspace bounds.
            // Cleared again before measuring — the baked plan carries it.
            tiled::set_tile_len_override(Some(t));
            let planned = e.plan(d);
            tiled::set_tile_len_override(None);
            // a candidate can push the engine past its kernel-plane
            // cap on big-channel shapes — skip it, don't fail the sweep
            let Ok(plan) = planned else { continue };
            let plan = Arc::new(plan);
            let median_s = Self::measure_plan(d, &plan, &x, &w, cfg);
            entries.push(TileTuneEntry { tile_len: t, median_s, selected: false });
        }
        tiled::set_tile_len_override(None);
        anyhow::ensure!(!entries.is_empty(), "no tile candidate covers kernel r={}", d.r);
        let best = entries
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.median_s.partial_cmp(&b.1.median_s).unwrap())
            .map(|(i, _)| i)
            .expect("non-empty candidate list");
        entries[best].selected = true;
        entries.sort_by(|a, b| a.median_s.partial_cmp(&b.median_s).unwrap());
        Ok(entries)
    }

    fn autotune_with(&self, d: &ConvDesc, cfg: AutotuneCfg) -> Result<Vec<TuneEntry>> {
        let cands = self.candidates(d);
        if cands.is_empty() {
            bail!("no engine supports descriptor {:?}", d);
        }
        let (x, w) = Self::synthetic_workload(d);
        let mut entries = Vec::with_capacity(cands.len());
        for e in cands {
            let plan = Arc::new(e.plan(d)?);
            entries.push(TuneEntry {
                engine: e.name(),
                median_s: Self::measure_plan(d, &plan, &x, &w, cfg),
                cost_bops: e.cost_model(d),
                workspace_bytes: e.workspace_bytes(d),
                selected: false,
            });
        }
        let best = entries
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.median_s.partial_cmp(&b.1.median_s).unwrap())
            .map(|(i, _)| i)
            .expect("non-empty candidate list");
        entries[best].selected = true;
        entries.sort_by(|a, b| a.median_s.partial_cmp(&b.median_s).unwrap());
        Ok(entries)
    }

    /// Analytic GBOPs for a conv stack under a named engine at uniform
    /// bit-widths, falling back to spatially-quantized direct conv for
    /// layers the engine can't take — the Fig. 4 x-axis, computed through
    /// the engine cost models instead of ad-hoc registry lookups.
    pub fn model_gbops(
        &self,
        shapes: &[(String, ConvShape)],
        engine: Option<&str>,
        a_bits: u32,
        w_bits: u32,
    ) -> f64 {
        let transform_spec = QuantSpec {
            w_bits,
            a_bits,
            w_gran: Granularity::ChannelFreq,
            a_gran: Granularity::Freq,
        };
        let spatial_spec = QuantSpec {
            w_bits,
            a_bits,
            w_gran: Granularity::Channel,
            a_gran: Granularity::Tensor,
        };
        let direct = self.engine_named("direct").expect("direct engine always present");
        let mut total = 0f64;
        for (_, s) in shapes {
            let base = ConvDesc::from_shape(s, 1);
            let mut cost = None;
            if let Some(e) = engine.and_then(|nm| self.engine_named(nm)) {
                for spec in [transform_spec, spatial_spec] {
                    let d = base.with_quant(spec);
                    if e.supports(&d) {
                        cost = Some(e.cost_model(&d));
                        break;
                    }
                }
            }
            total += cost.unwrap_or_else(|| direct.cost_model(&base.with_quant(spatial_spec)));
        }
        total / 1e9
    }
}

/// The process-wide heuristic selector: what `nn::model` builders and the
/// quantizer use unless handed something else.
pub fn default_selector() -> &'static Selector {
    static SEL: OnceLock<Selector> = OnceLock::new();
    SEL.get_or_init(|| Selector::new(Policy::Heuristic))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn isolated(policy: Policy) -> Selector {
        Selector::with_cache(policy, Arc::new(PlanCache::new()))
    }

    #[test]
    fn heuristic_picks_a_fast_engine_for_3x3_stride1() {
        let sel = isolated(Policy::Heuristic);
        let d = ConvDesc::new(1, 32, 32, 28, 28, 3, 1, 1);
        let plan = sel.plan(&d).unwrap();
        assert!(plan.fast_plan().is_some(), "picked {}", plan.engine);
        // 1×1 stride-2: only direct/im2col apply
        let d11 = ConvDesc::new(1, 32, 64, 28, 28, 1, 2, 0);
        let plan = sel.plan(&d11).unwrap();
        assert!(
            plan.engine == "direct" || plan.engine == "im2col-gemm",
            "picked {}",
            plan.engine
        );
    }

    #[test]
    fn plans_are_cached_per_descriptor() {
        let sel = isolated(Policy::Heuristic);
        let d = ConvDesc::new(1, 4, 4, 12, 12, 3, 1, 1);
        let p1 = sel.plan(&d).unwrap();
        let p2 = sel.plan(&d).unwrap();
        assert!(Arc::ptr_eq(&p1, &p2));
        assert_eq!(sel.cache().misses(), 1);
        assert_eq!(sel.cache().hits(), 1);
    }

    #[test]
    fn unknown_engine_is_a_clean_error() {
        let sel = isolated(Policy::Heuristic);
        let d = ConvDesc::new(1, 4, 4, 12, 12, 3, 1, 1);
        let e = sel.plan_named("definitely-not-an-engine", &d);
        assert!(e.is_err());
        let e = sel.plan_named("FFT", &d.with_quant(QuantSpec::transform_default(8)));
        assert!(e.is_err(), "FFT must refuse quantized descriptors");
    }

    #[test]
    fn autotune_reports_all_candidates_and_flags_one_winner() {
        let sel = isolated(Policy::Autotune(AutotuneCfg { warmup: 0, iters: 1 }));
        let d = ConvDesc::new(1, 3, 4, 10, 10, 3, 1, 1);
        let entries = sel.autotune(&d).unwrap();
        assert!(entries.len() >= 4, "got {}", entries.len());
        assert_eq!(entries.iter().filter(|t| t.selected).count(), 1);
        for t in &entries {
            assert!(t.median_s >= 0.0 && t.cost_bops > 0.0, "{}", t.engine);
        }
        // the policy plan agrees with the report's winner modulo caching
        let plan = sel.plan(&d).unwrap();
        assert!(entries.iter().any(|t| t.engine == plan.engine));
    }

    #[test]
    fn blocking_sweep_reports_all_candidates_and_restores_the_override() {
        use crate::linalg::gemm::Blocking;
        let _guard = crate::linalg::simd::TEST_OVERRIDE_LOCK
            .lock()
            .unwrap_or_else(|p| p.into_inner());
        let sel = isolated(Policy::Heuristic);
        let d = ConvDesc::new(1, 8, 8, 12, 12, 3, 1, 1);
        let cfg = AutotuneCfg { warmup: 0, iters: 1 };
        let entries = sel.tune_blocking("im2col-gemm", &d, cfg).unwrap();
        assert_eq!(entries.len(), Blocking::candidates().len());
        assert_eq!(entries.iter().filter(|t| t.selected).count(), 1);
        assert!(entries.windows(2).all(|w| w[0].median_s <= w[1].median_s));
        // the sweep must not leave a process-wide override behind
        let def = Blocking::for_kernel(crate::linalg::simd::active_kernel());
        assert_eq!(crate::linalg::gemm::active_blocking(), def);
        // unknown engines are a clean error
        assert!(sel.tune_blocking("nope", &d, cfg).is_err());
    }

    #[test]
    fn tile_sweep_reports_candidates_and_restores_the_override() {
        let _guard = crate::linalg::simd::TEST_OVERRIDE_LOCK
            .lock()
            .unwrap_or_else(|p| p.into_inner());
        let sel = isolated(Policy::Heuristic);
        let d = ConvDesc::new(1, 3, 4, 12, 12, 3, 1, 1);
        let cfg = AutotuneCfg { warmup: 0, iters: 1 };
        let entries = sel.tune_tile_len("FFT-tiled", &d, cfg).unwrap();
        // every power-of-two candidate ≥ r=3 fits this tiny shape
        assert_eq!(entries.len(), 5, "got {entries:?}");
        assert_eq!(entries.iter().filter(|t| t.selected).count(), 1);
        assert!(entries.windows(2).all(|w| w[0].median_s <= w[1].median_s));
        for t in &entries {
            assert!(t.tile_len.is_power_of_two() && t.tile_len >= d.r);
        }
        // the sweep must not leave a process-wide override behind
        assert_eq!(crate::engine::tiled::tile_len_override(), None);
        assert!(sel.tune_tile_len("nope", &d, cfg).is_err());
    }

    #[test]
    fn autotune_handles_depthwise_descriptors() {
        let sel = isolated(Policy::Autotune(AutotuneCfg { warmup: 0, iters: 1 }));
        let d = ConvDesc::new(1, 4, 4, 10, 10, 3, 1, 1).with_groups(4);
        let entries = sel.autotune(&d).unwrap();
        assert!(entries.len() >= 3, "direct, im2col and the fast engines take depthwise");
        assert!(entries.iter().all(|t| t.engine != "FFT" && t.engine != "NTT"));
        assert_eq!(entries.iter().filter(|t| t.selected).count(), 1);
        let plan = sel.plan(&d).unwrap();
        assert_eq!(plan.desc.groups, 4);
    }

    #[test]
    fn tuning_table_pins_the_planned_engine() {
        // heuristic would pick a fast engine for 3x3 stride-1; the table
        // pins direct, and the pin must win
        let d = ConvDesc::new(1, 16, 16, 20, 20, 3, 1, 1);
        let mut table = TuningTable::new();
        table.insert(&d, "direct", 1e-3);
        let sel = isolated(Policy::Heuristic).with_tuning(table);
        assert_eq!(sel.plan(&d).unwrap().engine, "direct");
        // untuned descriptors still follow the policy
        let other = ConvDesc::new(1, 32, 32, 28, 28, 3, 1, 1);
        assert!(sel.plan(&other).unwrap().fast_plan().is_some());
    }

    #[test]
    fn stale_tuning_entries_fall_through_to_the_policy() {
        let d = ConvDesc::new(1, 16, 16, 20, 20, 3, 1, 1);
        let mut gone = TuningTable::new();
        gone.insert(&d, "engine-removed-from-catalog", 1e-3);
        let sel = isolated(Policy::Heuristic).with_tuning(gone);
        // unknown engine name: plan() must still succeed via the policy
        assert!(sel.plan(&d).is_ok());
        // unsupported engine (FFT can't take quantized): same fall-through
        let dq = d.with_quant(QuantSpec::transform_default(8));
        let mut unsup = TuningTable::new();
        unsup.insert(&dq, "FFT", 1e-3);
        let sel = isolated(Policy::Heuristic).with_tuning(unsup);
        let plan = sel.plan(&dq).unwrap();
        assert_ne!(plan.engine, "FFT");
    }

    #[test]
    fn model_gbops_orders_like_the_paper() {
        let sel = isolated(Policy::Heuristic);
        let shapes = vec![(
            "l".to_string(),
            ConvShape { ic: 64, oc: 64, h: 56, w: 56, r: 3, stride: 1 },
        )];
        let direct = sel.model_gbops(&shapes, None, 8, 8);
        let sfc = sel.model_gbops(&shapes, Some("SFC-6(7x7,3x3)"), 8, 8);
        assert!(sfc < direct, "SFC {sfc} must beat direct {direct}");
    }
}
