//! The workspace arena: reusable scratch memory for conv executors.
//!
//! cuDNN-style contract: a plan *reports* its scratch need
//! ([`crate::engine::ConvEngine::workspace_bytes`],
//! [`crate::engine::ConvPlan::workspace_bytes`]) and the caller *owns*
//! the memory, checking buffers out of a [`Workspace`] it keeps alive
//! across calls. Executors take typed buffers (`take_f32` …), use them,
//! and give them back (`give_f32` …); the arena pools returned buffers
//! so a steady-state serving loop performs **zero workspace heap
//! allocations** — every checkout is satisfied from the pool after the
//! first call. (Parallel dispatch still makes O(workers) bookkeeping
//! allocations per call in `par_chunks_states`; the arena counters
//! track the data buffers, which dominate by orders of magnitude.)
//!
//! The arena is single-threaded by design (`&mut self` everywhere).
//! Parallel executors check out one buffer set per worker *before*
//! entering `std::thread::scope` and return them after — see
//! [`crate::util::par::par_chunks_states`].
//!
//! Accounting: `in_use_bytes`/`peak_bytes` track checked-out bytes,
//! `heap_allocs` counts pool misses. Both are mirrored into the
//! process-wide counters here ([`global_counters`]), which the serving
//! layer re-exports via `coordinator::metrics::workspace_counters` to
//! assert the zero-alloc property end to end.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Process-wide high-water mark of bytes simultaneously checked out of
/// any [`Workspace`] in this process.
static GLOBAL_PEAK_BYTES: AtomicU64 = AtomicU64::new(0);
/// Process-wide count of checkouts that fell back to a heap allocation.
static GLOBAL_HEAP_ALLOCS: AtomicU64 = AtomicU64::new(0);
/// Process-wide high-water mark of bytes parked in any [`WorkspacePool`]
/// free list.
static GLOBAL_POOL_PEAK_BYTES: AtomicU64 = AtomicU64::new(0);
/// Process-wide [`WorkspacePool::lease`] calls.
static GLOBAL_POOL_LEASES: AtomicU64 = AtomicU64::new(0);
/// Process-wide leases that found the pool empty and built a fresh
/// [`Workspace`] (stops growing once the pool holds the working set).
static GLOBAL_POOL_MISSES: AtomicU64 = AtomicU64::new(0);

/// (peak bytes, heap-fallback allocations) across every workspace in
/// the process.
pub fn global_counters() -> (u64, u64) {
    (GLOBAL_PEAK_BYTES.load(Ordering::Relaxed), GLOBAL_HEAP_ALLOCS.load(Ordering::Relaxed))
}

/// (peak resident bytes, leases, pool-miss fresh builds) across every
/// [`WorkspacePool`] in the process — the serving layer re-exports this
/// via `coordinator::metrics::ws_pool_counters`.
pub fn global_pool_counters() -> (u64, u64, u64) {
    (
        GLOBAL_POOL_PEAK_BYTES.load(Ordering::Relaxed),
        GLOBAL_POOL_LEASES.load(Ordering::Relaxed),
        GLOBAL_POOL_MISSES.load(Ordering::Relaxed),
    )
}

/// Typed free-list of returned buffers.
struct Pool<T> {
    free: Vec<Vec<T>>,
}

impl<T: Clone + Default> Pool<T> {
    fn new() -> Pool<T> {
        Pool { free: Vec::new() }
    }

    /// Best-fit checkout: the smallest pooled buffer with enough
    /// capacity, or a fresh allocation. Returns (buffer, pool_missed).
    /// The buffer comes back zeroed (`T::default()`) at exactly `len` —
    /// a deliberate memset per checkout so padding-dependent consumers
    /// (frequency-domain kernel planes) can never read stale data; the
    /// cost is small against the compute the buffers feed, and callers
    /// that fully overwrite could grow a non-zeroing variant later.
    fn take(&mut self, len: usize) -> (Vec<T>, bool) {
        let mut best: Option<usize> = None;
        let mut best_cap = usize::MAX;
        for (i, v) in self.free.iter().enumerate() {
            let cap = v.capacity();
            if cap >= len && cap < best_cap {
                best = Some(i);
                best_cap = cap;
            }
        }
        match best {
            Some(i) => {
                let mut v = self.free.swap_remove(i);
                v.clear();
                v.resize(len, T::default());
                (v, false)
            }
            None => (vec![T::default(); len], true),
        }
    }

    fn give(&mut self, v: Vec<T>) {
        self.free.push(v);
    }

    fn pooled_bytes(&self) -> usize {
        self.free.iter().map(|v| v.capacity() * std::mem::size_of::<T>()).sum()
    }
}

/// A reusable scratch-memory arena for conv execution.
///
/// ```
/// use sfc::engine::Workspace;
///
/// let mut ws = Workspace::new();
/// let buf = ws.take_f32(1024); // first checkout allocates...
/// ws.give_f32(buf);
/// let buf = ws.take_f32(512); // ...and reuse satisfies later ones
/// assert_eq!(ws.heap_allocs(), 1);
/// ws.give_f32(buf);
/// assert_eq!(ws.in_use_bytes(), 0);
/// ```
pub struct Workspace {
    f32s: Pool<f32>,
    f64s: Pool<f64>,
    i8s: Pool<i8>,
    i32s: Pool<i32>,
    i64s: Pool<i64>,
    u64s: Pool<u64>,
    in_use_bytes: usize,
    peak_bytes: usize,
    heap_allocs: u64,
}

macro_rules! typed_pool {
    ($take:ident, $give:ident, $field:ident, $ty:ty) => {
        /// Check out a zeroed buffer of `len` elements.
        pub fn $take(&mut self, len: usize) -> Vec<$ty> {
            let (v, missed) = self.$field.take(len);
            self.account_take(v.len() * std::mem::size_of::<$ty>(), missed);
            v
        }

        /// Return a buffer to the pool for reuse.
        pub fn $give(&mut self, v: Vec<$ty>) {
            self.in_use_bytes =
                self.in_use_bytes.saturating_sub(v.len() * std::mem::size_of::<$ty>());
            self.$field.give(v);
        }
    };
}

impl Workspace {
    /// An empty arena (pools fill on first use).
    pub fn new() -> Workspace {
        Workspace {
            f32s: Pool::new(),
            f64s: Pool::new(),
            i8s: Pool::new(),
            i32s: Pool::new(),
            i64s: Pool::new(),
            u64s: Pool::new(),
            in_use_bytes: 0,
            peak_bytes: 0,
            heap_allocs: 0,
        }
    }

    /// Arena pre-warmed with one pooled f32 buffer of `bytes` — a coarse
    /// way to reserve address space up front (e.g. from a plan's
    /// [`crate::engine::ConvPlan::workspace_bytes`] report). Pools are
    /// typed and executors check out several buffers, so the first call
    /// still populates the pool with its exact working set; the real
    /// zero-alloc guarantee comes from reusing the workspace across
    /// calls, not from this pre-warm. The warm-up allocation is counted
    /// (it happens before steady state).
    pub fn with_capacity(bytes: usize) -> Workspace {
        let mut ws = Workspace::new();
        let v = ws.take_f32(bytes.div_ceil(std::mem::size_of::<f32>()));
        ws.give_f32(v);
        ws
    }

    typed_pool!(take_f32, give_f32, f32s, f32);
    typed_pool!(take_f64, give_f64, f64s, f64);
    typed_pool!(take_i8, give_i8, i8s, i8);
    typed_pool!(take_i32, give_i32, i32s, i32);
    typed_pool!(take_i64, give_i64, i64s, i64);
    typed_pool!(take_u64, give_u64, u64s, u64);

    fn account_take(&mut self, bytes: usize, missed: bool) {
        if missed {
            self.heap_allocs += 1;
            GLOBAL_HEAP_ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        self.in_use_bytes += bytes;
        self.peak_bytes = self.peak_bytes.max(self.in_use_bytes);
        GLOBAL_PEAK_BYTES.fetch_max(self.in_use_bytes as u64, Ordering::Relaxed);
    }

    /// Bytes currently checked out.
    pub fn in_use_bytes(&self) -> usize {
        self.in_use_bytes
    }

    /// High-water mark of simultaneously checked-out bytes.
    pub fn peak_bytes(&self) -> usize {
        self.peak_bytes
    }

    /// Checkouts that missed the pool and hit the heap. Stops growing
    /// once the arena has seen every buffer shape of its workload.
    pub fn heap_allocs(&self) -> u64 {
        self.heap_allocs
    }

    /// Bytes parked in the pools (capacity retained for reuse).
    pub fn pooled_bytes(&self) -> usize {
        self.f32s.pooled_bytes()
            + self.f64s.pooled_bytes()
            + self.i8s.pooled_bytes()
            + self.i32s.pooled_bytes()
            + self.i64s.pooled_bytes()
            + self.u64s.pooled_bytes()
    }
}

impl Default for Workspace {
    fn default() -> Self {
        Workspace::new()
    }
}

impl std::fmt::Debug for Workspace {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Workspace")
            .field("in_use_bytes", &self.in_use_bytes)
            .field("peak_bytes", &self.peak_bytes)
            .field("heap_allocs", &self.heap_allocs)
            .field("pooled_bytes", &self.pooled_bytes())
            .finish()
    }
}

// ---------------------------------------------------------------------
// WorkspacePool: cross-model shared workspace ownership
// ---------------------------------------------------------------------

/// A snapshot of one [`WorkspacePool`]'s accounting (see
/// [`WorkspacePool::gauges`]). All counters are exact under the pool's
/// mutex; the process-wide mirrors are in [`global_pool_counters`].
#[derive(Clone, Copy, Debug, Default)]
pub struct WsPoolGauges {
    /// bytes parked in the free list right now (capacity retained by
    /// returned workspaces)
    pub resident_bytes: u64,
    /// high-water mark of `resident_bytes` over the pool's lifetime
    pub peak_resident_bytes: u64,
    /// workspaces parked in the free list right now
    pub resident_ws: u64,
    /// workspaces currently leased out
    pub leased: u64,
    /// high-water mark of simultaneously leased workspaces
    pub peak_leased: u64,
    /// total [`WorkspacePool::lease`] calls
    pub leases: u64,
    /// leases satisfied by a workspace last used by the *same* model —
    /// the arena's typed pools hold that model's exact buffer shapes,
    /// so the execution inside stays heap-alloc-free
    pub affinity_hits: u64,
    /// leases that found the free list empty and built a fresh arena
    pub misses: u64,
    /// returns dropped (not pooled) because pooling them would exceed
    /// the configured byte limit
    pub dropped: u64,
}

struct WsPoolState {
    /// (model tag of last use, the parked arena)
    free: Vec<(usize, Workspace)>,
    g: WsPoolGauges,
}

/// A `PackBudget`-style shared pool of whole [`Workspace`] arenas with
/// byte accounting, for serving paths where several models execute on
/// shared threads instead of each worker owning one arena for life.
///
/// Lease/return contract: an executor [`WorkspacePool::lease`]s an
/// arena tagged with its model index, runs, and
/// [`WorkspacePool::give`]s it back. The pool prefers handing a model
/// the arena it used last (*affinity*): the arena's typed free lists
/// then already hold that model's exact buffer shapes, so the
/// zero-steady-state-alloc contract survives models with disjoint
/// workspace profiles sharing one pool. An optional byte limit bounds
/// the capacity parked in the free list — over-limit returns are
/// dropped (correctness is unaffected; the next lease re-warms).
///
/// ```
/// use sfc::engine::WorkspacePool;
///
/// let pool = WorkspacePool::new(0); // unlimited
/// let mut ws = pool.lease(0);
/// let buf = ws.take_f32(256);
/// ws.give_f32(buf);
/// pool.give(0, ws);
/// assert_eq!(pool.gauges().resident_ws, 1);
/// ```
pub struct WorkspacePool {
    limit_bytes: usize,
    inner: Mutex<WsPoolState>,
}

impl WorkspacePool {
    /// A pool whose free list may retain up to `limit_bytes` of parked
    /// capacity (0 = unlimited, the historical per-worker behavior).
    pub fn new(limit_bytes: usize) -> WorkspacePool {
        WorkspacePool {
            limit_bytes,
            inner: Mutex::new(WsPoolState { free: Vec::new(), g: WsPoolGauges::default() }),
        }
    }

    /// The configured cap on parked bytes (0 = unlimited).
    pub fn limit_bytes(&self) -> usize {
        self.limit_bytes
    }

    /// Check an arena out for `model`: the arena this model returned
    /// last if still parked, else any parked arena, else a fresh one.
    pub fn lease(&self, model: usize) -> Workspace {
        let mut st = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        st.g.leases += 1;
        st.g.leased += 1;
        st.g.peak_leased = st.g.peak_leased.max(st.g.leased);
        GLOBAL_POOL_LEASES.fetch_add(1, Ordering::Relaxed);
        let slot = match st.free.iter().position(|(tag, _)| *tag == model) {
            Some(i) => {
                st.g.affinity_hits += 1;
                Some(i)
            }
            None => st.free.len().checked_sub(1),
        };
        match slot {
            Some(i) => {
                let (_, ws) = st.free.swap_remove(i);
                st.g.resident_bytes -= ws.pooled_bytes() as u64;
                st.g.resident_ws -= 1;
                ws
            }
            None => {
                st.g.misses += 1;
                GLOBAL_POOL_MISSES.fetch_add(1, Ordering::Relaxed);
                Workspace::new()
            }
        }
    }

    /// Return a leased arena, tagging it with the model that used it.
    /// Arenas whose parked capacity would push the free list over the
    /// byte limit are dropped instead of pooled.
    pub fn give(&self, model: usize, ws: Workspace) {
        let bytes = ws.pooled_bytes() as u64;
        let mut st = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        st.g.leased = st.g.leased.saturating_sub(1);
        if self.limit_bytes > 0 && st.g.resident_bytes + bytes > self.limit_bytes as u64 {
            st.g.dropped += 1;
            return; // ws drops here, outside the steady-state contract
        }
        st.free.push((model, ws));
        st.g.resident_bytes += bytes;
        st.g.resident_ws += 1;
        st.g.peak_resident_bytes = st.g.peak_resident_bytes.max(st.g.resident_bytes);
        GLOBAL_POOL_PEAK_BYTES.fetch_max(st.g.resident_bytes, Ordering::Relaxed);
    }

    /// Snapshot the pool's accounting.
    pub fn gauges(&self) -> WsPoolGauges {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).g
    }
}

impl std::fmt::Debug for WorkspacePool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let g = self.gauges();
        f.debug_struct("WorkspacePool")
            .field("limit_bytes", &self.limit_bytes)
            .field("resident_bytes", &g.resident_bytes)
            .field("leased", &g.leased)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_zeroed_and_sized() {
        let mut ws = Workspace::new();
        let mut v = ws.take_f32(10);
        assert_eq!(v, vec![0f32; 10]);
        v.iter_mut().for_each(|x| *x = 3.0);
        ws.give_f32(v);
        let v2 = ws.take_f32(8);
        assert_eq!(v2, vec![0f32; 8], "reused buffers are re-zeroed");
    }

    #[test]
    fn pool_reuse_stops_allocating() {
        let mut ws = Workspace::new();
        for round in 0..3 {
            let a = ws.take_f32(100);
            let b = ws.take_f32(50);
            let c = ws.take_i8(64);
            ws.give_f32(a);
            ws.give_f32(b);
            ws.give_i8(c);
            if round == 0 {
                assert_eq!(ws.heap_allocs(), 3);
            }
        }
        assert_eq!(ws.heap_allocs(), 3, "steady state must be alloc-free");
        assert_eq!(ws.in_use_bytes(), 0);
        assert!(ws.peak_bytes() >= 150 * 4 + 64);
    }

    #[test]
    fn best_fit_prefers_smallest_adequate() {
        let mut ws = Workspace::new();
        let small = ws.take_f32(10);
        let big = ws.take_f32(1000);
        ws.give_f32(small);
        ws.give_f32(big);
        let v = ws.take_f32(5);
        assert!(v.capacity() < 1000, "small request must not consume the big buffer");
        let v2 = ws.take_f32(900);
        assert!(v2.capacity() >= 1000);
        assert_eq!(ws.heap_allocs(), 2);
    }

    #[test]
    fn with_capacity_prewarms() {
        let mut ws = Workspace::with_capacity(4096);
        let before = ws.heap_allocs();
        let v = ws.take_f32(1024);
        assert_eq!(ws.heap_allocs(), before, "prewarmed bytes must satisfy the take");
        ws.give_f32(v);
    }

    #[test]
    fn workspace_pool_prefers_affinity_and_accounts_bytes() {
        let pool = WorkspacePool::new(0);
        // model 0 warms a large arena, model 1 a small one
        let mut a = pool.lease(0);
        let buf = a.take_f32(10_000);
        a.give_f32(buf);
        let mut b = pool.lease(1);
        let buf = b.take_f32(16);
        b.give_f32(buf);
        let (ab, bb) = (a.pooled_bytes(), b.pooled_bytes());
        pool.give(0, a);
        pool.give(1, b);
        let g = pool.gauges();
        assert_eq!(g.resident_ws, 2);
        assert_eq!(g.leased, 0);
        assert_eq!(g.misses, 2, "both first leases built fresh arenas");
        assert_eq!(g.resident_bytes, (ab + bb) as u64);
        // model 0 gets its own arena back, not model 1's
        let a2 = pool.lease(0);
        assert_eq!(a2.pooled_bytes(), ab, "affinity must return the same arena");
        assert_eq!(pool.gauges().affinity_hits, 1);
        pool.give(0, a2);
        assert_eq!(pool.gauges().misses, 2, "affinity leases must not miss");
    }

    #[test]
    fn workspace_pool_limit_drops_over_budget_returns() {
        let pool = WorkspacePool::new(1024);
        let mut a = pool.lease(0);
        let buf = a.take_f32(10_000); // 40 KB arena, far over the limit
        a.give_f32(buf);
        pool.give(0, a);
        let g = pool.gauges();
        assert_eq!(g.dropped, 1, "over-budget return must be dropped");
        assert_eq!(g.resident_ws, 0);
        assert_eq!(g.resident_bytes, 0);
    }
}
