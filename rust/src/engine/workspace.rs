//! The workspace arena: reusable scratch memory for conv executors.
//!
//! cuDNN-style contract: a plan *reports* its scratch need
//! ([`crate::engine::ConvEngine::workspace_bytes`],
//! [`crate::engine::ConvPlan::workspace_bytes`]) and the caller *owns*
//! the memory, checking buffers out of a [`Workspace`] it keeps alive
//! across calls. Executors take typed buffers (`take_f32` …), use them,
//! and give them back (`give_f32` …); the arena pools returned buffers
//! so a steady-state serving loop performs **zero workspace heap
//! allocations** — every checkout is satisfied from the pool after the
//! first call. (Parallel dispatch still makes O(workers) bookkeeping
//! allocations per call in `par_chunks_states`; the arena counters
//! track the data buffers, which dominate by orders of magnitude.)
//!
//! The arena is single-threaded by design (`&mut self` everywhere).
//! Parallel executors check out one buffer set per worker *before*
//! entering `std::thread::scope` and return them after — see
//! [`crate::util::par::par_chunks_states`].
//!
//! Accounting: `in_use_bytes`/`peak_bytes` track checked-out bytes,
//! `heap_allocs` counts pool misses. Both are mirrored into the
//! process-wide counters here ([`global_counters`]), which the serving
//! layer re-exports via `coordinator::metrics::workspace_counters` to
//! assert the zero-alloc property end to end.

use std::sync::atomic::{AtomicU64, Ordering};

/// Process-wide high-water mark of bytes simultaneously checked out of
/// any [`Workspace`] in this process.
static GLOBAL_PEAK_BYTES: AtomicU64 = AtomicU64::new(0);
/// Process-wide count of checkouts that fell back to a heap allocation.
static GLOBAL_HEAP_ALLOCS: AtomicU64 = AtomicU64::new(0);

/// (peak bytes, heap-fallback allocations) across every workspace in
/// the process.
pub fn global_counters() -> (u64, u64) {
    (GLOBAL_PEAK_BYTES.load(Ordering::Relaxed), GLOBAL_HEAP_ALLOCS.load(Ordering::Relaxed))
}

/// Typed free-list of returned buffers.
struct Pool<T> {
    free: Vec<Vec<T>>,
}

impl<T: Clone + Default> Pool<T> {
    fn new() -> Pool<T> {
        Pool { free: Vec::new() }
    }

    /// Best-fit checkout: the smallest pooled buffer with enough
    /// capacity, or a fresh allocation. Returns (buffer, pool_missed).
    /// The buffer comes back zeroed (`T::default()`) at exactly `len` —
    /// a deliberate memset per checkout so padding-dependent consumers
    /// (frequency-domain kernel planes) can never read stale data; the
    /// cost is small against the compute the buffers feed, and callers
    /// that fully overwrite could grow a non-zeroing variant later.
    fn take(&mut self, len: usize) -> (Vec<T>, bool) {
        let mut best: Option<usize> = None;
        let mut best_cap = usize::MAX;
        for (i, v) in self.free.iter().enumerate() {
            let cap = v.capacity();
            if cap >= len && cap < best_cap {
                best = Some(i);
                best_cap = cap;
            }
        }
        match best {
            Some(i) => {
                let mut v = self.free.swap_remove(i);
                v.clear();
                v.resize(len, T::default());
                (v, false)
            }
            None => (vec![T::default(); len], true),
        }
    }

    fn give(&mut self, v: Vec<T>) {
        self.free.push(v);
    }

    fn pooled_bytes(&self) -> usize {
        self.free.iter().map(|v| v.capacity() * std::mem::size_of::<T>()).sum()
    }
}

/// A reusable scratch-memory arena for conv execution.
///
/// ```
/// use sfc::engine::Workspace;
///
/// let mut ws = Workspace::new();
/// let buf = ws.take_f32(1024); // first checkout allocates...
/// ws.give_f32(buf);
/// let buf = ws.take_f32(512); // ...and reuse satisfies later ones
/// assert_eq!(ws.heap_allocs(), 1);
/// ws.give_f32(buf);
/// assert_eq!(ws.in_use_bytes(), 0);
/// ```
pub struct Workspace {
    f32s: Pool<f32>,
    f64s: Pool<f64>,
    i8s: Pool<i8>,
    i32s: Pool<i32>,
    i64s: Pool<i64>,
    u64s: Pool<u64>,
    in_use_bytes: usize,
    peak_bytes: usize,
    heap_allocs: u64,
}

macro_rules! typed_pool {
    ($take:ident, $give:ident, $field:ident, $ty:ty) => {
        /// Check out a zeroed buffer of `len` elements.
        pub fn $take(&mut self, len: usize) -> Vec<$ty> {
            let (v, missed) = self.$field.take(len);
            self.account_take(v.len() * std::mem::size_of::<$ty>(), missed);
            v
        }

        /// Return a buffer to the pool for reuse.
        pub fn $give(&mut self, v: Vec<$ty>) {
            self.in_use_bytes =
                self.in_use_bytes.saturating_sub(v.len() * std::mem::size_of::<$ty>());
            self.$field.give(v);
        }
    };
}

impl Workspace {
    /// An empty arena (pools fill on first use).
    pub fn new() -> Workspace {
        Workspace {
            f32s: Pool::new(),
            f64s: Pool::new(),
            i8s: Pool::new(),
            i32s: Pool::new(),
            i64s: Pool::new(),
            u64s: Pool::new(),
            in_use_bytes: 0,
            peak_bytes: 0,
            heap_allocs: 0,
        }
    }

    /// Arena pre-warmed with one pooled f32 buffer of `bytes` — a coarse
    /// way to reserve address space up front (e.g. from a plan's
    /// [`crate::engine::ConvPlan::workspace_bytes`] report). Pools are
    /// typed and executors check out several buffers, so the first call
    /// still populates the pool with its exact working set; the real
    /// zero-alloc guarantee comes from reusing the workspace across
    /// calls, not from this pre-warm. The warm-up allocation is counted
    /// (it happens before steady state).
    pub fn with_capacity(bytes: usize) -> Workspace {
        let mut ws = Workspace::new();
        let v = ws.take_f32(bytes.div_ceil(std::mem::size_of::<f32>()));
        ws.give_f32(v);
        ws
    }

    typed_pool!(take_f32, give_f32, f32s, f32);
    typed_pool!(take_f64, give_f64, f64s, f64);
    typed_pool!(take_i8, give_i8, i8s, i8);
    typed_pool!(take_i32, give_i32, i32s, i32);
    typed_pool!(take_i64, give_i64, i64s, i64);
    typed_pool!(take_u64, give_u64, u64s, u64);

    fn account_take(&mut self, bytes: usize, missed: bool) {
        if missed {
            self.heap_allocs += 1;
            GLOBAL_HEAP_ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        self.in_use_bytes += bytes;
        self.peak_bytes = self.peak_bytes.max(self.in_use_bytes);
        GLOBAL_PEAK_BYTES.fetch_max(self.in_use_bytes as u64, Ordering::Relaxed);
    }

    /// Bytes currently checked out.
    pub fn in_use_bytes(&self) -> usize {
        self.in_use_bytes
    }

    /// High-water mark of simultaneously checked-out bytes.
    pub fn peak_bytes(&self) -> usize {
        self.peak_bytes
    }

    /// Checkouts that missed the pool and hit the heap. Stops growing
    /// once the arena has seen every buffer shape of its workload.
    pub fn heap_allocs(&self) -> u64 {
        self.heap_allocs
    }

    /// Bytes parked in the pools (capacity retained for reuse).
    pub fn pooled_bytes(&self) -> usize {
        self.f32s.pooled_bytes()
            + self.f64s.pooled_bytes()
            + self.i8s.pooled_bytes()
            + self.i32s.pooled_bytes()
            + self.i64s.pooled_bytes()
            + self.u64s.pooled_bytes()
    }
}

impl Default for Workspace {
    fn default() -> Self {
        Workspace::new()
    }
}

impl std::fmt::Debug for Workspace {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Workspace")
            .field("in_use_bytes", &self.in_use_bytes)
            .field("peak_bytes", &self.peak_bytes)
            .field("heap_allocs", &self.heap_allocs)
            .field("pooled_bytes", &self.pooled_bytes())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_zeroed_and_sized() {
        let mut ws = Workspace::new();
        let mut v = ws.take_f32(10);
        assert_eq!(v, vec![0f32; 10]);
        v.iter_mut().for_each(|x| *x = 3.0);
        ws.give_f32(v);
        let v2 = ws.take_f32(8);
        assert_eq!(v2, vec![0f32; 8], "reused buffers are re-zeroed");
    }

    #[test]
    fn pool_reuse_stops_allocating() {
        let mut ws = Workspace::new();
        for round in 0..3 {
            let a = ws.take_f32(100);
            let b = ws.take_f32(50);
            let c = ws.take_i8(64);
            ws.give_f32(a);
            ws.give_f32(b);
            ws.give_i8(c);
            if round == 0 {
                assert_eq!(ws.heap_allocs(), 3);
            }
        }
        assert_eq!(ws.heap_allocs(), 3, "steady state must be alloc-free");
        assert_eq!(ws.in_use_bytes(), 0);
        assert!(ws.peak_bytes() >= 150 * 4 + 64);
    }

    #[test]
    fn best_fit_prefers_smallest_adequate() {
        let mut ws = Workspace::new();
        let small = ws.take_f32(10);
        let big = ws.take_f32(1000);
        ws.give_f32(small);
        ws.give_f32(big);
        let v = ws.take_f32(5);
        assert!(v.capacity() < 1000, "small request must not consume the big buffer");
        let v2 = ws.take_f32(900);
        assert!(v2.capacity() >= 1000);
        assert_eq!(ws.heap_allocs(), 2);
    }

    #[test]
    fn with_capacity_prewarms() {
        let mut ws = Workspace::with_capacity(4096);
        let before = ws.heap_allocs();
        let v = ws.take_f32(1024);
        assert_eq!(ws.heap_allocs(), before, "prewarmed bytes must satisfy the take");
        ws.give_f32(v);
    }
}
