//! Additional conv executors owned by the engine layer: im2col+GEMM
//! lowering, float FFT convolution and exact int8 NTT convolution.
//!
//! The direct and tiled-bilinear (Winograd/SFC) executors live in
//! [`crate::nn::conv`]; this module adds the remaining Table-1/Table-3
//! backends so every catalog row is runnable through the same
//! [`crate::engine::ConvPlan`] interface. Each executor has an `*_into`
//! entry point that runs entirely out of a caller [`Workspace`] — the
//! historical allocating signatures remain as thin wrappers.

use super::desc::Epilogue;
use super::workspace::Workspace;
use crate::algo::fft::fft_inplace;
use crate::algo::ntt::{ntt_inplace, P};
use crate::linalg::gemm::{gemm_packed_f32, PANEL};
use crate::linalg::simd::quantize_i8_slice;
use crate::nn::tensor::Tensor;
use crate::util::par::{num_threads, par_chunks_states};

/// im2col + GEMM convolution into `out`: lower each image to a
/// [OH·OW × (IC/g)·R·R] matrix per group (one workspace panel per
/// worker) and reduce with the shared blocked GEMM directly into the
/// image's output chunk. Supports any stride/pad and any `groups`
/// (weights `[OC, IC/groups, R, R]`, depthwise included); this is the
/// classic GEMM-friendly baseline (cuDNN's `IMPLICIT_GEMM` ancestor).
/// At `groups == 1` it is bit-identical to the historical dense
/// lowering.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_im2col_into(
    x: &Tensor,
    w: &Tensor,
    bias: &[f32],
    stride: usize,
    pad: usize,
    groups: usize,
    ep: Epilogue,
    ws: &mut Workspace,
    out: &mut Tensor,
) {
    conv2d_im2col_dilated_into(x, w, bias, stride, pad, groups, 1, ep, ws, out);
}

/// im2col + GEMM convolution with kernel dilation: the lowering gathers
/// tap `(ky, kx)` from input offset `(ky·dilation, kx·dilation)`; the
/// GEMM reduction (and the `(IC/g)·R·R` lowered-row layout) is
/// untouched, since dilation changes *where* taps read, not how many
/// there are. At `dilation == 1` the gather arithmetic reduces to
/// exactly the undilated lowering, so [`conv2d_im2col_into`] (which
/// delegates here) stays bit-identical to its historical output.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_im2col_dilated_into(
    x: &Tensor,
    w: &Tensor,
    bias: &[f32],
    stride: usize,
    pad: usize,
    groups: usize,
    dilation: usize,
    ep: Epilogue,
    ws: &mut Workspace,
    out: &mut Tensor,
) {
    let (n, ic, h, wid) = x.dims4();
    let (oc, icg, r, r2) = w.dims4();
    assert!(groups >= 1 && oc % groups == 0, "groups {groups} must divide oc {oc}");
    assert_eq!(icg * groups, ic, "weight channels {icg}×{groups} groups vs input {ic}");
    assert_eq!(r, r2, "square kernels only");
    assert!(bias.is_empty() || bias.len() == oc);
    assert!(dilation >= 1, "dilation must be >= 1");
    let ocg = oc / groups;
    let er = (r - 1) * dilation + 1;
    let oh = (h + 2 * pad - er) / stride + 1;
    let ow = (wid + 2 * pad - er) / stride + 1;
    out.assert_dims(&[n, oc, oh, ow]);
    let k = icg * r * r;
    let npix = oh * ow;
    // The lowering panel is built directly in the packed GEMM B layout:
    // 8-pixel column panels, `col[(p/8)·k·8 + kk·8 + p%8]`. Pixels are
    // padded to the panel width; the lowering never writes the pad
    // lanes, the GEMM loads-and-discards them, and their contents stay
    // benign because Workspace checkouts arrive zeroed and later calls
    // only ever leave earlier finite lowering values behind.
    let col_len = npix.div_ceil(PANEL) * k * PANEL;
    // Two nested levels of parallelism, arbitrated by the CoreBudget:
    // across images here (one lowering buffer per worker), and inside
    // each per-group `gemm_packed_f32` call below (the macro-kernel
    // leases whatever lanes remain, so batch-1 shapes still thread the
    // GEMM while large batches keep it serial per worker).
    let workers = num_threads().min(n).max(1);
    let mut states: Vec<Vec<f32>> = (0..workers).map(|_| ws.take_f32(col_len)).collect();
    par_chunks_states(&mut out.data, oc * npix, &mut states, |col, ni, out_img| {
        for gi in 0..groups {
            // 1) lowering: kk = (c_local·R + ky)·R + kx — the same k
            //    order as one row of the group's (OC/g)×((IC/g)·R·R)
            //    weight block, written panel-packed over pixels.
            for il in 0..icg {
                let plane = x.plane(ni, gi * icg + il);
                for oy in 0..oh {
                    for ox in 0..ow {
                        let p = oy * ow + ox;
                        let base = (p / PANEL) * k * PANEL + (il * r * r) * PANEL + p % PANEL;
                        for ky in 0..r {
                            let yy = (oy * stride + ky * dilation) as isize - pad as isize;
                            for kx in 0..r {
                                let xx = (ox * stride + kx * dilation) as isize - pad as isize;
                                col[base + (ky * r + kx) * PANEL] = if yy >= 0
                                    && (yy as usize) < h
                                    && xx >= 0
                                    && (xx as usize) < wid
                                {
                                    plane[yy as usize * wid + xx as usize]
                                } else {
                                    0.0
                                };
                            }
                        }
                    }
                }
            }
            // 2) dispatched packed GEMM straight into this group's
            //    output rows: out[o][p] = Σ_kk W[o][kk]·col[p][kk]
            let wblk = &w.data[gi * ocg * k..(gi + 1) * ocg * k];
            let oblk = &mut out_img[gi * ocg * npix..(gi + 1) * ocg * npix];
            gemm_packed_f32(ocg, npix, k, wblk, col, oblk);
        }
        if !bias.is_empty() || ep != Epilogue::None {
            for o in 0..oc {
                let b = if bias.is_empty() { 0.0 } else { bias[o] };
                for v in &mut out_img[o * npix..(o + 1) * npix] {
                    *v = ep.apply(*v + b);
                }
            }
        }
    });
    for col in states {
        ws.give_f32(col);
    }
}

/// im2col + GEMM convolution (allocating wrapper). The group count is
/// inferred from the weight shape (`groups = IC / weight IC`).
pub fn conv2d_im2col(x: &Tensor, w: &Tensor, bias: &[f32], stride: usize, pad: usize) -> Tensor {
    let (n, ic, h, wid) = x.dims4();
    let (oc, icg, r, _) = w.dims4();
    assert!(icg >= 1 && ic % icg == 0, "weight channels {icg} must divide input channels {ic}");
    let oh = (h + 2 * pad - r) / stride + 1;
    let ow = (wid + 2 * pad - r) / stride + 1;
    let mut out = Tensor::zeros(&[n, oc, oh, ow]);
    let mut ws = Workspace::new();
    conv2d_im2col_into(x, w, bias, stride, pad, ic / icg, Epilogue::None, &mut ws, &mut out);
    out
}

/// 2-D FFT over a row-major `sh`×`sw` complex grid (both powers of two).
/// `cr`/`ci` are caller column scratch of `sh` elements each. The inverse
/// pass does NOT normalize; callers divide by `sh·sw`. Shared with the
/// overlap-save tiled executor ([`super::tiled`]).
#[allow(clippy::too_many_arguments)]
pub(crate) fn fft2d(
    re: &mut [f64],
    im: &mut [f64],
    sh: usize,
    sw: usize,
    inverse: bool,
    cr: &mut [f64],
    ci: &mut [f64],
) {
    for y in 0..sh {
        fft_inplace(&mut re[y * sw..(y + 1) * sw], &mut im[y * sw..(y + 1) * sw], inverse);
    }
    for xcol in 0..sw {
        for y in 0..sh {
            cr[y] = re[y * sw + xcol];
            ci[y] = im[y * sw + xcol];
        }
        fft_inplace(&mut cr[..sh], &mut ci[..sh], inverse);
        for y in 0..sh {
            re[y * sw + xcol] = cr[y];
            im[y * sw + xcol] = ci[y];
        }
    }
}

/// Per-worker scratch for the whole-image FFT path.
struct FftScratch {
    xre: Vec<f64>,
    xim: Vec<f64>,
    acc_re: Vec<f64>,
    acc_im: Vec<f64>,
    cr: Vec<f64>,
    ci: Vec<f64>,
}

/// Float FFT convolution (stride 1) into `out`: whole-image
/// frequency-domain correlation with per-channel accumulation in the
/// frequency domain — the classic related-work baseline (§2). Exact up
/// to f64 roundoff.
pub fn conv2d_fft_into(
    x: &Tensor,
    w: &Tensor,
    bias: &[f32],
    pad: usize,
    ep: Epilogue,
    ws: &mut Workspace,
    out: &mut Tensor,
) {
    let (n, ic, h, wid) = x.dims4();
    let (oc, ic2, r, r2) = w.dims4();
    assert_eq!(ic, ic2, "channel mismatch");
    assert_eq!(r, r2, "square kernels only");
    assert!(bias.is_empty() || bias.len() == oc);
    let (hp, wp) = (h + 2 * pad, wid + 2 * pad);
    let oh = hp - r + 1;
    let ow = wp - r + 1;
    out.assert_dims(&[n, oc, oh, ow]);
    let sh = (hp + r - 1).next_power_of_two();
    let sw = (wp + r - 1).next_power_of_two();
    let s2 = sh * sw;

    // Flipped-kernel FFTs, once for all images: [OC][IC] planes.
    let mut kf_re = ws.take_f64(oc * ic * s2);
    let mut kf_im = ws.take_f64(oc * ic * s2);
    {
        let mut cr = ws.take_f64(sh);
        let mut ci = ws.take_f64(sh);
        for o in 0..oc {
            for c in 0..ic {
                let base = (o * ic + c) * s2;
                let wplane = w.plane(o, c);
                for ky in 0..r {
                    for kx in 0..r {
                        // correlation = convolution with the flipped filter
                        kf_re[base + (r - 1 - ky) * sw + (r - 1 - kx)] = wplane[ky * r + kx] as f64;
                    }
                }
                let kre = &mut kf_re[base..base + s2];
                let kim = &mut kf_im[base..base + s2];
                fft2d(kre, kim, sh, sw, false, &mut cr, &mut ci);
            }
        }
        ws.give_f64(cr);
        ws.give_f64(ci);
    }

    let workers = num_threads().min(n).max(1);
    let mut states: Vec<FftScratch> = (0..workers)
        .map(|_| FftScratch {
            xre: ws.take_f64(ic * s2),
            xim: ws.take_f64(ic * s2),
            acc_re: ws.take_f64(s2),
            acc_im: ws.take_f64(s2),
            cr: ws.take_f64(sh),
            ci: ws.take_f64(sh),
        })
        .collect();
    let inv_scale = 1.0 / s2 as f64;
    par_chunks_states(&mut out.data, oc * oh * ow, &mut states, |st, ni, out_img| {
        st.xre.fill(0.0);
        st.xim.fill(0.0);
        for c in 0..ic {
            let base = c * s2;
            let plane = x.plane(ni, c);
            for yy in 0..h {
                for xx in 0..wid {
                    st.xre[base + (yy + pad) * sw + (xx + pad)] = plane[yy * wid + xx] as f64;
                }
            }
            let xre = &mut st.xre[base..base + s2];
            let xim = &mut st.xim[base..base + s2];
            fft2d(xre, xim, sh, sw, false, &mut st.cr, &mut st.ci);
        }
        for o in 0..oc {
            st.acc_re.fill(0.0);
            st.acc_im.fill(0.0);
            for c in 0..ic {
                let xb = c * s2;
                let kb = (o * ic + c) * s2;
                for i in 0..s2 {
                    let (ar, ai) = (st.xre[xb + i], st.xim[xb + i]);
                    let (br, bi) = (kf_re[kb + i], kf_im[kb + i]);
                    st.acc_re[i] += ar * br - ai * bi;
                    st.acc_im[i] += ar * bi + ai * br;
                }
            }
            fft2d(&mut st.acc_re, &mut st.acc_im, sh, sw, true, &mut st.cr, &mut st.ci);
            let b = if bias.is_empty() { 0.0 } else { bias[o] };
            let plane = &mut out_img[o * oh * ow..(o + 1) * oh * ow];
            for oy in 0..oh {
                for ox in 0..ow {
                    plane[oy * ow + ox] = ep.apply(
                        (st.acc_re[(oy + r - 1) * sw + (ox + r - 1)] * inv_scale) as f32 + b,
                    );
                }
            }
        }
    });
    for st in states {
        ws.give_f64(st.xre);
        ws.give_f64(st.xim);
        ws.give_f64(st.acc_re);
        ws.give_f64(st.acc_im);
        ws.give_f64(st.cr);
        ws.give_f64(st.ci);
    }
    ws.give_f64(kf_re);
    ws.give_f64(kf_im);
}

/// Float FFT convolution (allocating wrapper).
pub fn conv2d_fft(x: &Tensor, w: &Tensor, bias: &[f32], pad: usize) -> Tensor {
    let (n, _, h, wid) = x.dims4();
    let (oc, _, r, _) = w.dims4();
    let oh = h + 2 * pad - r + 1;
    let ow = wid + 2 * pad - r + 1;
    let mut out = Tensor::zeros(&[n, oc, oh, ow]);
    let mut ws = Workspace::new();
    conv2d_fft_into(x, w, bias, pad, Epilogue::None, &mut ws, &mut out);
    out
}

/// 2-D NTT (row-column) over an `sh`×`sw` grid in F_p; `col` is caller
/// column scratch of `sh` elements. The inverse pass of [`ntt_inplace`]
/// normalizes per axis, so a full 2-D round trip is already scaled
/// correctly. Shared with the overlap-save tiled executor
/// ([`super::tiled`]).
pub(crate) fn ntt2d(a: &mut [u64], sh: usize, sw: usize, inverse: bool, col: &mut [u64]) {
    for y in 0..sh {
        ntt_inplace(&mut a[y * sw..(y + 1) * sw], inverse);
    }
    for xcol in 0..sw {
        for y in 0..sh {
            col[y] = a[y * sw + xcol];
        }
        ntt_inplace(&mut col[..sh], inverse);
        for y in 0..sh {
            a[y * sw + xcol] = col[y];
        }
    }
}

/// Lift a signed integer into F_p (canonical residue).
#[inline]
pub(crate) fn ntt_encode(v: i64) -> u64 {
    v.rem_euclid(P as i64) as u64
}

/// Map an F_p residue back to the signed integer of least magnitude.
#[inline]
pub(crate) fn ntt_decode(v: u64) -> i64 {
    if v > P / 2 {
        v as i64 - P as i64
    } else {
        v as i64
    }
}

/// Per-worker scratch for the whole-image NTT path.
struct NttScratch {
    xnt: Vec<u64>,
    acc: Vec<u64>,
    col: Vec<u64>,
}

/// Exact stride-1 integer correlation via 2-D NTT with frequency-domain
/// channel accumulation, written into the `[N][OC][OH][OW]` i64
/// accumulator slice `out`. Bit-identical to the nested-loop integer
/// conv as long as every true output satisfies `|y| < p/2` (int8
/// operands: IC·R² ≤ ~30k). `xq` is NCHW, `wq` is OC×IC×R×R.
#[allow(clippy::too_many_arguments)]
pub fn ntt_corr2d_i8_into(
    xq: &[i8],
    n: usize,
    ic: usize,
    h: usize,
    w: usize,
    wq: &[i8],
    oc: usize,
    r: usize,
    pad: usize,
    ws: &mut Workspace,
    out: &mut [i64],
) {
    assert_eq!(xq.len(), n * ic * h * w);
    assert_eq!(wq.len(), oc * ic * r * r);
    let (hp, wp) = (h + 2 * pad, w + 2 * pad);
    let oh = hp - r + 1;
    let ow = wp - r + 1;
    assert_eq!(out.len(), n * oc * oh * ow, "accumulator slice size mismatch");
    let sh = (hp + r - 1).next_power_of_two();
    let sw = (wp + r - 1).next_power_of_two();
    let s2 = sh * sw;

    // Flipped-kernel NTTs, shared across images.
    let mut knt = ws.take_u64(oc * ic * s2);
    {
        let mut col = ws.take_u64(sh);
        for o in 0..oc {
            for c in 0..ic {
                let base = (o * ic + c) * s2;
                let wplane = &wq[(o * ic + c) * r * r..(o * ic + c + 1) * r * r];
                for ky in 0..r {
                    for kx in 0..r {
                        knt[base + (r - 1 - ky) * sw + (r - 1 - kx)] =
                            ntt_encode(wplane[ky * r + kx] as i64);
                    }
                }
                ntt2d(&mut knt[base..base + s2], sh, sw, false, &mut col);
            }
        }
        ws.give_u64(col);
    }

    let workers = num_threads().min(n).max(1);
    let mut states: Vec<NttScratch> = (0..workers)
        .map(|_| NttScratch {
            xnt: ws.take_u64(ic * s2),
            acc: ws.take_u64(s2),
            col: ws.take_u64(sh),
        })
        .collect();
    par_chunks_states(out, oc * oh * ow, &mut states, |st, ni, img_out| {
        st.xnt.fill(0);
        for c in 0..ic {
            let base = c * s2;
            let plane = &xq[(ni * ic + c) * h * w..(ni * ic + c + 1) * h * w];
            for yy in 0..h {
                for xx in 0..w {
                    st.xnt[base + (yy + pad) * sw + (xx + pad)] =
                        ntt_encode(plane[yy * w + xx] as i64);
                }
            }
            ntt2d(&mut st.xnt[base..base + s2], sh, sw, false, &mut st.col);
        }
        for o in 0..oc {
            st.acc.fill(0);
            for c in 0..ic {
                let xb = c * s2;
                let kb = (o * ic + c) * s2;
                for i in 0..s2 {
                    // operands < p < 2^30 ⇒ the product fits u64
                    st.acc[i] = (st.acc[i] + st.xnt[xb + i] * knt[kb + i] % P) % P;
                }
            }
            ntt2d(&mut st.acc, sh, sw, true, &mut st.col);
            for oy in 0..oh {
                for ox in 0..ow {
                    img_out[o * oh * ow + oy * ow + ox] =
                        ntt_decode(st.acc[(oy + r - 1) * sw + (ox + r - 1)]);
                }
            }
        }
    });
    for st in states {
        ws.give_u64(st.xnt);
        ws.give_u64(st.acc);
        ws.give_u64(st.col);
    }
    ws.give_u64(knt);
}

/// Exact stride-1 integer correlation via 2-D NTT (allocating wrapper):
/// returns `[N][OC][OH][OW]` i64 accumulators.
#[allow(clippy::too_many_arguments)]
pub fn ntt_corr2d_i8(
    xq: &[i8],
    n: usize,
    ic: usize,
    h: usize,
    w: usize,
    wq: &[i8],
    oc: usize,
    r: usize,
    pad: usize,
) -> Vec<i64> {
    let oh = h + 2 * pad - r + 1;
    let ow = w + 2 * pad - r + 1;
    let mut out = vec![0i64; n * oc * oh * ow];
    let mut ws = Workspace::new();
    ntt_corr2d_i8_into(xq, n, ic, h, w, wq, oc, r, pad, &mut ws, &mut out);
    out
}

/// Float-entry NTT convolution (stride 1) into `out`: per-tensor
/// symmetric int8 quantization of both operands, exact integer
/// correlation through the NTT, dequantize. This is the Table-3 NTT
/// accelerator's datapath — the ⊙ operands carry full mod-p word width
/// regardless of the 8-bit inputs, which is exactly the paper's
/// criticism of NTT under low precision.
pub fn conv2d_ntt_int8_into(
    x: &Tensor,
    w: &Tensor,
    bias: &[f32],
    pad: usize,
    ep: Epilogue,
    ws: &mut Workspace,
    out: &mut Tensor,
) {
    let (n, ic, h, wid) = x.dims4();
    let (oc, ic2, r, r2) = w.dims4();
    assert_eq!(ic, ic2, "channel mismatch");
    assert_eq!(r, r2, "square kernels only");
    let oh = h + 2 * pad - r + 1;
    let ow = wid + 2 * pad - r + 1;
    out.assert_dims(&[n, oc, oh, ow]);
    let sx = {
        let m = x.max_abs();
        if m > 0.0 {
            m / 127.0
        } else {
            1.0
        }
    };
    let sw_ = {
        let m = w.max_abs();
        if m > 0.0 {
            m / 127.0
        } else {
            1.0
        }
    };
    let mut xq = ws.take_i8(x.data.len());
    quantize_i8_slice(&x.data, sx, 127, &mut xq);
    let mut wq = ws.take_i8(w.data.len());
    quantize_i8_slice(&w.data, sw_, 127, &mut wq);
    let mut acc = ws.take_i64(n * oc * oh * ow);
    ntt_corr2d_i8_into(&xq, n, ic, h, wid, &wq, oc, r, pad, ws, &mut acc);
    let deq = sx * sw_;
    for ni in 0..n {
        for o in 0..oc {
            let b = if bias.is_empty() { 0.0 } else { bias[o] };
            let src = &acc[(ni * oc + o) * oh * ow..(ni * oc + o + 1) * oh * ow];
            let dst = out.plane_mut(ni, o);
            for (d, &a) in dst.iter_mut().zip(src) {
                *d = ep.apply(a as f32 * deq + b);
            }
        }
    }
    ws.give_i8(xq);
    ws.give_i8(wq);
    ws.give_i64(acc);
}

/// Float-entry NTT convolution (allocating wrapper).
pub fn conv2d_ntt_int8(x: &Tensor, w: &Tensor, bias: &[f32], pad: usize) -> Tensor {
    let (n, _, h, wid) = x.dims4();
    let (oc, _, r, _) = w.dims4();
    let oh = h + 2 * pad - r + 1;
    let ow = wid + 2 * pad - r + 1;
    let mut out = Tensor::zeros(&[n, oc, oh, ow]);
    let mut ws = Workspace::new();
    conv2d_ntt_int8_into(x, w, bias, pad, Epilogue::None, &mut ws, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::conv::conv2d_direct;
    use crate::util::Pcg32;

    fn rand_tensor(dims: &[usize], rng: &mut Pcg32, sigma: f64) -> Tensor {
        let mut t = Tensor::zeros(dims);
        rng.fill_gaussian(&mut t.data, sigma);
        t
    }

    fn rel_mse(got: &Tensor, want: &Tensor) -> f64 {
        let denom = want.data.iter().map(|v| (*v as f64).powi(2)).sum::<f64>()
            / want.len().max(1) as f64;
        got.mse(want) / denom.max(1e-30)
    }

    #[test]
    fn im2col_matches_direct_stride_pad() {
        let mut rng = Pcg32::seeded(11);
        for (stride, pad, r) in [(1usize, 1usize, 3usize), (2, 1, 3), (1, 0, 1), (2, 0, 1), (1, 2, 5)] {
            let x = rand_tensor(&[2, 3, 11, 9], &mut rng, 1.0);
            let w = rand_tensor(&[4, 3, r, r], &mut rng, 0.3);
            let bias = vec![0.1, -0.2, 0.0, 0.5];
            let want = conv2d_direct(&x, &w, &bias, stride, pad);
            let got = conv2d_im2col(&x, &w, &bias, stride, pad);
            assert_eq!(got.dims, want.dims, "s{stride} p{pad} r{r}");
            assert!(got.mse(&want) < 1e-10, "s{stride} p{pad} r{r}: {}", got.mse(&want));
        }
    }

    #[test]
    fn fft_matches_direct() {
        let mut rng = Pcg32::seeded(12);
        for (hh, ww, r, pad) in [(8usize, 8usize, 3usize, 1usize), (11, 13, 3, 1), (12, 12, 5, 2), (9, 9, 3, 0)] {
            let x = rand_tensor(&[2, 3, hh, ww], &mut rng, 1.0);
            let w = rand_tensor(&[2, 3, r, r], &mut rng, 0.3);
            let bias = vec![0.2, -0.4];
            let want = conv2d_direct(&x, &w, &bias, 1, pad);
            let got = conv2d_fft(&x, &w, &bias, pad);
            assert_eq!(got.dims, want.dims);
            assert!(got.mse(&want) < 1e-9, "{hh}x{ww} r{r} p{pad}: {}", got.mse(&want));
        }
    }

    #[test]
    fn ntt_integer_path_is_exact() {
        // int8 inputs → the NTT accumulators must equal the nested-loop
        // integer conv exactly (both are exact integer arithmetic).
        let mut rng = Pcg32::seeded(13);
        let (n, ic, h, w, oc, r, pad) = (1usize, 3usize, 9usize, 8usize, 2usize, 3usize, 1usize);
        let xq: Vec<i8> = (0..n * ic * h * w).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let wq: Vec<i8> = (0..oc * ic * r * r).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let got = ntt_corr2d_i8(&xq, n, ic, h, w, &wq, oc, r, pad);
        let (oh, ow) = (h + 2 * pad - r + 1, w + 2 * pad - r + 1);
        for o in 0..oc {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0i64;
                    for c in 0..ic {
                        for ky in 0..r {
                            for kx in 0..r {
                                let yy = (oy + ky) as isize - pad as isize;
                                let xx = (ox + kx) as isize - pad as isize;
                                if yy >= 0 && (yy as usize) < h && xx >= 0 && (xx as usize) < w {
                                    acc += wq[(o * ic + c) * r * r + ky * r + kx] as i64
                                        * xq[(c * h + yy as usize) * w + xx as usize] as i64;
                                }
                            }
                        }
                    }
                    assert_eq!(got[(o * oh + oy) * ow + ox], acc, "o{o} {oy},{ox}");
                }
            }
        }
    }

    #[test]
    fn ntt_float_entry_close_to_direct() {
        let mut rng = Pcg32::seeded(14);
        let x = rand_tensor(&[1, 4, 10, 10], &mut rng, 1.0);
        let w = rand_tensor(&[3, 4, 3, 3], &mut rng, 0.3);
        let want = conv2d_direct(&x, &w, &[], 1, 1);
        let got = conv2d_ntt_int8(&x, &w, &[], 1);
        assert_eq!(got.dims, want.dims);
        let rel = rel_mse(&got, &want);
        assert!(rel < 1e-2, "int8 NTT relative error {rel}");
    }
}
