//! Additional conv executors owned by the engine layer: im2col+GEMM
//! lowering, float FFT convolution and exact int8 NTT convolution.
//!
//! The direct and tiled-bilinear (Winograd/SFC) executors live in
//! [`crate::nn::conv`]; this module adds the remaining Table-1/Table-3
//! backends so every catalog row is runnable through the same
//! [`crate::engine::ConvPlan`] interface.

use crate::algo::fft::fft_inplace;
use crate::algo::ntt::{ntt_inplace, P};
use crate::nn::tensor::Tensor;
use crate::util::par::{par_for, par_map};
use std::sync::Mutex;

/// im2col + GEMM convolution: lower each image to a [OH·OW × IC·R·R]
/// matrix and multiply by the [OC × IC·R·R] filter matrix. Supports any
/// stride/pad; this is the classic GEMM-friendly baseline (cuDNN's
/// `IMPLICIT_GEMM` ancestor).
pub fn conv2d_im2col(x: &Tensor, w: &Tensor, bias: &[f32], stride: usize, pad: usize) -> Tensor {
    let (n, ic, h, wid) = x.dims4();
    let (oc, ic2, r, r2) = w.dims4();
    assert_eq!(ic, ic2, "channel mismatch");
    assert_eq!(r, r2, "square kernels only");
    assert!(bias.is_empty() || bias.len() == oc);
    let oh = (h + 2 * pad - r) / stride + 1;
    let ow = (wid + 2 * pad - r) / stride + 1;
    let k = ic * r * r;
    let npix = oh * ow;
    let mut out = Tensor::zeros(&[n, oc, oh, ow]);
    let out_mutex = Mutex::new(&mut out);
    par_for(n, |ni| {
        // 1) lowering: col[p][kk], kk = (c·R + ky)·R + kx — the same
        //    layout as one row of the OC×(IC·R·R) weight matrix.
        let mut col = vec![0f32; npix * k];
        for c in 0..ic {
            let plane = x.plane(ni, c);
            for oy in 0..oh {
                for ox in 0..ow {
                    let p = oy * ow + ox;
                    let dst = &mut col[p * k + c * r * r..p * k + (c + 1) * r * r];
                    for ky in 0..r {
                        let yy = (oy * stride + ky) as isize - pad as isize;
                        for kx in 0..r {
                            let xx = (ox * stride + kx) as isize - pad as isize;
                            dst[ky * r + kx] = if yy >= 0
                                && (yy as usize) < h
                                && xx >= 0
                                && (xx as usize) < wid
                            {
                                plane[yy as usize * wid + xx as usize]
                            } else {
                                0.0
                            };
                        }
                    }
                }
            }
        }
        // 2) GEMM: res[o][p] = Σ_kk W[o][kk]·col[p][kk]
        let mut res = vec![0f32; oc * npix];
        for o in 0..oc {
            let wrow = &w.data[o * k..(o + 1) * k];
            let b = if bias.is_empty() { 0.0 } else { bias[o] };
            for p in 0..npix {
                let crow = &col[p * k..(p + 1) * k];
                let mut acc = 0f32;
                for (a, c2) in wrow.iter().zip(crow) {
                    acc += a * c2;
                }
                res[o * npix + p] = acc + b;
            }
        }
        let mut guard = out_mutex.lock().unwrap();
        for o in 0..oc {
            guard.plane_mut(ni, o).copy_from_slice(&res[o * npix..(o + 1) * npix]);
        }
    });
    out
}

/// 2-D FFT over a row-major `sh`×`sw` complex grid (both powers of two).
/// The inverse pass does NOT normalize; callers divide by `sh·sw`.
fn fft2d(re: &mut [f64], im: &mut [f64], sh: usize, sw: usize, inverse: bool) {
    for y in 0..sh {
        fft_inplace(&mut re[y * sw..(y + 1) * sw], &mut im[y * sw..(y + 1) * sw], inverse);
    }
    let mut cr = vec![0f64; sh];
    let mut ci = vec![0f64; sh];
    for xcol in 0..sw {
        for y in 0..sh {
            cr[y] = re[y * sw + xcol];
            ci[y] = im[y * sw + xcol];
        }
        fft_inplace(&mut cr, &mut ci, inverse);
        for y in 0..sh {
            re[y * sw + xcol] = cr[y];
            im[y * sw + xcol] = ci[y];
        }
    }
}

/// Float FFT convolution (stride 1): whole-image frequency-domain
/// correlation with per-channel accumulation in the frequency domain —
/// the classic related-work baseline (§2). Exact up to f64 roundoff.
pub fn conv2d_fft(x: &Tensor, w: &Tensor, bias: &[f32], pad: usize) -> Tensor {
    let (n, ic, h, wid) = x.dims4();
    let (oc, ic2, r, r2) = w.dims4();
    assert_eq!(ic, ic2, "channel mismatch");
    assert_eq!(r, r2, "square kernels only");
    assert!(bias.is_empty() || bias.len() == oc);
    let (hp, wp) = (h + 2 * pad, wid + 2 * pad);
    let oh = hp - r + 1;
    let ow = wp - r + 1;
    let sh = (hp + r - 1).next_power_of_two();
    let sw = (wp + r - 1).next_power_of_two();
    let s2 = sh * sw;

    // Flipped-kernel FFTs, once for all images: [OC][IC] planes.
    let mut kf_re = vec![0f64; oc * ic * s2];
    let mut kf_im = vec![0f64; oc * ic * s2];
    for o in 0..oc {
        for c in 0..ic {
            let base = (o * ic + c) * s2;
            let wplane = w.plane(o, c);
            for ky in 0..r {
                for kx in 0..r {
                    // correlation = convolution with the flipped filter
                    kf_re[base + (r - 1 - ky) * sw + (r - 1 - kx)] = wplane[ky * r + kx] as f64;
                }
            }
            fft2d(&mut kf_re[base..base + s2], &mut kf_im[base..base + s2], sh, sw, false);
        }
    }

    let mut out = Tensor::zeros(&[n, oc, oh, ow]);
    let out_mutex = Mutex::new(&mut out);
    par_for(n, |ni| {
        let mut xre = vec![0f64; ic * s2];
        let mut xim = vec![0f64; ic * s2];
        for c in 0..ic {
            let base = c * s2;
            let plane = x.plane(ni, c);
            for yy in 0..h {
                for xx in 0..wid {
                    xre[base + (yy + pad) * sw + (xx + pad)] = plane[yy * wid + xx] as f64;
                }
            }
            fft2d(&mut xre[base..base + s2], &mut xim[base..base + s2], sh, sw, false);
        }
        let mut acc_re = vec![0f64; s2];
        let mut acc_im = vec![0f64; s2];
        let mut res = vec![0f32; oc * oh * ow];
        let inv_scale = 1.0 / s2 as f64;
        for o in 0..oc {
            acc_re.iter_mut().for_each(|v| *v = 0.0);
            acc_im.iter_mut().for_each(|v| *v = 0.0);
            for c in 0..ic {
                let xb = c * s2;
                let kb = (o * ic + c) * s2;
                for i in 0..s2 {
                    let (ar, ai) = (xre[xb + i], xim[xb + i]);
                    let (br, bi) = (kf_re[kb + i], kf_im[kb + i]);
                    acc_re[i] += ar * br - ai * bi;
                    acc_im[i] += ar * bi + ai * br;
                }
            }
            fft2d(&mut acc_re, &mut acc_im, sh, sw, true);
            let b = if bias.is_empty() { 0.0 } else { bias[o] };
            for oy in 0..oh {
                for ox in 0..ow {
                    res[o * oh * ow + oy * ow + ox] =
                        (acc_re[(oy + r - 1) * sw + (ox + r - 1)] * inv_scale) as f32 + b;
                }
            }
        }
        let mut guard = out_mutex.lock().unwrap();
        for o in 0..oc {
            guard.plane_mut(ni, o).copy_from_slice(&res[o * oh * ow..(o + 1) * oh * ow]);
        }
    });
    out
}

/// 2-D NTT (row-column) over an `sh`×`sw` grid in F_p. The inverse pass
/// of [`ntt_inplace`] normalizes per axis, so a full 2-D round trip is
/// already scaled correctly.
fn ntt2d(a: &mut [u64], sh: usize, sw: usize, inverse: bool) {
    for y in 0..sh {
        ntt_inplace(&mut a[y * sw..(y + 1) * sw], inverse);
    }
    let mut col = vec![0u64; sh];
    for xcol in 0..sw {
        for y in 0..sh {
            col[y] = a[y * sw + xcol];
        }
        ntt_inplace(&mut col, inverse);
        for y in 0..sh {
            a[y * sw + xcol] = col[y];
        }
    }
}

#[inline]
fn ntt_encode(v: i64) -> u64 {
    v.rem_euclid(P as i64) as u64
}

#[inline]
fn ntt_decode(v: u64) -> i64 {
    if v > P / 2 {
        v as i64 - P as i64
    } else {
        v as i64
    }
}

/// Exact stride-1 integer correlation via 2-D NTT with frequency-domain
/// channel accumulation: returns `[N][OC][OH][OW]` i64 accumulators,
/// bit-identical to the nested-loop integer conv as long as every true
/// output satisfies `|y| < p/2` (int8 operands: IC·R² ≤ ~30k). `xq` is
/// NCHW, `wq` is OC×IC×R×R.
#[allow(clippy::too_many_arguments)]
pub fn ntt_corr2d_i8(
    xq: &[i8],
    n: usize,
    ic: usize,
    h: usize,
    w: usize,
    wq: &[i8],
    oc: usize,
    r: usize,
    pad: usize,
) -> Vec<i64> {
    assert_eq!(xq.len(), n * ic * h * w);
    assert_eq!(wq.len(), oc * ic * r * r);
    let (hp, wp) = (h + 2 * pad, w + 2 * pad);
    let oh = hp - r + 1;
    let ow = wp - r + 1;
    let sh = (hp + r - 1).next_power_of_two();
    let sw = (wp + r - 1).next_power_of_two();
    let s2 = sh * sw;

    // Flipped-kernel NTTs, shared across images.
    let mut knt = vec![0u64; oc * ic * s2];
    for o in 0..oc {
        for c in 0..ic {
            let base = (o * ic + c) * s2;
            let wplane = &wq[(o * ic + c) * r * r..(o * ic + c + 1) * r * r];
            for ky in 0..r {
                for kx in 0..r {
                    knt[base + (r - 1 - ky) * sw + (r - 1 - kx)] =
                        ntt_encode(wplane[ky * r + kx] as i64);
                }
            }
            ntt2d(&mut knt[base..base + s2], sh, sw, false);
        }
    }

    let per_image: Vec<Vec<i64>> = par_map(n, |ni| {
        let mut xnt = vec![0u64; ic * s2];
        for c in 0..ic {
            let base = c * s2;
            let plane = &xq[(ni * ic + c) * h * w..(ni * ic + c + 1) * h * w];
            for yy in 0..h {
                for xx in 0..w {
                    xnt[base + (yy + pad) * sw + (xx + pad)] =
                        ntt_encode(plane[yy * w + xx] as i64);
                }
            }
            ntt2d(&mut xnt[base..base + s2], sh, sw, false);
        }
        let mut img_out = vec![0i64; oc * oh * ow];
        let mut acc = vec![0u64; s2];
        for o in 0..oc {
            acc.iter_mut().for_each(|v| *v = 0);
            for c in 0..ic {
                let xb = c * s2;
                let kb = (o * ic + c) * s2;
                for i in 0..s2 {
                    // operands < p < 2^30 ⇒ the product fits u64
                    acc[i] = (acc[i] + xnt[xb + i] * knt[kb + i] % P) % P;
                }
            }
            ntt2d(&mut acc, sh, sw, true);
            for oy in 0..oh {
                for ox in 0..ow {
                    img_out[o * oh * ow + oy * ow + ox] =
                        ntt_decode(acc[(oy + r - 1) * sw + (ox + r - 1)]);
                }
            }
        }
        img_out
    });

    let mut out = Vec::with_capacity(n * oc * oh * ow);
    for img in per_image {
        out.extend_from_slice(&img);
    }
    out
}

/// Float-entry NTT convolution (stride 1): per-tensor symmetric int8
/// quantization of both operands, exact integer correlation through the
/// NTT, dequantize. This is the Table-3 NTT accelerator's datapath — the
/// ⊙ operands carry full mod-p width regardless of the 8-bit inputs,
/// which is exactly the paper's criticism of NTT under low precision.
pub fn conv2d_ntt_int8(x: &Tensor, w: &Tensor, bias: &[f32], pad: usize) -> Tensor {
    let (n, ic, h, wid) = x.dims4();
    let (oc, ic2, r, r2) = w.dims4();
    assert_eq!(ic, ic2, "channel mismatch");
    assert_eq!(r, r2, "square kernels only");
    let sx = {
        let m = x.max_abs();
        if m > 0.0 {
            m / 127.0
        } else {
            1.0
        }
    };
    let sw_ = {
        let m = w.max_abs();
        if m > 0.0 {
            m / 127.0
        } else {
            1.0
        }
    };
    let xq: Vec<i8> = x.data.iter().map(|&v| ((v / sx).round() as i32).clamp(-127, 127) as i8).collect();
    let wq: Vec<i8> = w.data.iter().map(|&v| ((v / sw_).round() as i32).clamp(-127, 127) as i8).collect();
    let acc = ntt_corr2d_i8(&xq, n, ic, h, wid, &wq, oc, r, pad);
    let oh = h + 2 * pad - r + 1;
    let ow = wid + 2 * pad - r + 1;
    let mut out = Tensor::zeros(&[n, oc, oh, ow]);
    let deq = sx * sw_;
    for ni in 0..n {
        for o in 0..oc {
            let b = if bias.is_empty() { 0.0 } else { bias[o] };
            let src = &acc[(ni * oc + o) * oh * ow..(ni * oc + o + 1) * oh * ow];
            let dst = out.plane_mut(ni, o);
            for (d, &a) in dst.iter_mut().zip(src) {
                *d = a as f32 * deq + b;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::conv::conv2d_direct;
    use crate::util::Pcg32;

    fn rand_tensor(dims: &[usize], rng: &mut Pcg32, sigma: f64) -> Tensor {
        let mut t = Tensor::zeros(dims);
        rng.fill_gaussian(&mut t.data, sigma);
        t
    }

    fn rel_mse(got: &Tensor, want: &Tensor) -> f64 {
        let denom = want.data.iter().map(|v| (*v as f64).powi(2)).sum::<f64>()
            / want.len().max(1) as f64;
        got.mse(want) / denom.max(1e-30)
    }

    #[test]
    fn im2col_matches_direct_stride_pad() {
        let mut rng = Pcg32::seeded(11);
        for (stride, pad, r) in [(1usize, 1usize, 3usize), (2, 1, 3), (1, 0, 1), (2, 0, 1), (1, 2, 5)] {
            let x = rand_tensor(&[2, 3, 11, 9], &mut rng, 1.0);
            let w = rand_tensor(&[4, 3, r, r], &mut rng, 0.3);
            let bias = vec![0.1, -0.2, 0.0, 0.5];
            let want = conv2d_direct(&x, &w, &bias, stride, pad);
            let got = conv2d_im2col(&x, &w, &bias, stride, pad);
            assert_eq!(got.dims, want.dims, "s{stride} p{pad} r{r}");
            assert!(got.mse(&want) < 1e-10, "s{stride} p{pad} r{r}: {}", got.mse(&want));
        }
    }

    #[test]
    fn fft_matches_direct() {
        let mut rng = Pcg32::seeded(12);
        for (hh, ww, r, pad) in [(8usize, 8usize, 3usize, 1usize), (11, 13, 3, 1), (12, 12, 5, 2), (9, 9, 3, 0)] {
            let x = rand_tensor(&[2, 3, hh, ww], &mut rng, 1.0);
            let w = rand_tensor(&[2, 3, r, r], &mut rng, 0.3);
            let bias = vec![0.2, -0.4];
            let want = conv2d_direct(&x, &w, &bias, 1, pad);
            let got = conv2d_fft(&x, &w, &bias, pad);
            assert_eq!(got.dims, want.dims);
            assert!(got.mse(&want) < 1e-9, "{hh}x{ww} r{r} p{pad}: {}", got.mse(&want));
        }
    }

    #[test]
    fn ntt_integer_path_is_exact() {
        // int8 inputs → the NTT accumulators must equal the nested-loop
        // integer conv exactly (both are exact integer arithmetic).
        let mut rng = Pcg32::seeded(13);
        let (n, ic, h, w, oc, r, pad) = (1usize, 3usize, 9usize, 8usize, 2usize, 3usize, 1usize);
        let xq: Vec<i8> = (0..n * ic * h * w).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let wq: Vec<i8> = (0..oc * ic * r * r).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let got = ntt_corr2d_i8(&xq, n, ic, h, w, &wq, oc, r, pad);
        let (oh, ow) = (h + 2 * pad - r + 1, w + 2 * pad - r + 1);
        for o in 0..oc {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0i64;
                    for c in 0..ic {
                        for ky in 0..r {
                            for kx in 0..r {
                                let yy = (oy + ky) as isize - pad as isize;
                                let xx = (ox + kx) as isize - pad as isize;
                                if yy >= 0 && (yy as usize) < h && xx >= 0 && (xx as usize) < w {
                                    acc += wq[(o * ic + c) * r * r + ky * r + kx] as i64
                                        * xq[(c * h + yy as usize) * w + xx as usize] as i64;
                                }
                            }
                        }
                    }
                    assert_eq!(got[(o * oh + oy) * ow + ox], acc, "o{o} {oy},{ox}");
                }
            }
        }
    }

    #[test]
    fn ntt_float_entry_close_to_direct() {
        let mut rng = Pcg32::seeded(14);
        let x = rand_tensor(&[1, 4, 10, 10], &mut rng, 1.0);
        let w = rand_tensor(&[3, 4, 3, 3], &mut rng, 0.3);
        let want = conv2d_direct(&x, &w, &[], 1, 1);
        let got = conv2d_ntt_int8(&x, &w, &[], 1);
        assert_eq!(got.dims, want.dims);
        let rel = rel_mse(&got, &want);
        assert!(rel < 1e-2, "int8 NTT relative error {rel}");
    }
}
