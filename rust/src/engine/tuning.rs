//! Persisted autotune results: measure once with `sfc autotune --out
//! tuning.json`, commit the table, and warm every future [`Selector`]
//! (and `sfc serve`) from the file instead of re-running multi-second
//! micro-benchmarks at startup.
//!
//! The table maps a canonical descriptor key ([`desc_key`]: every field
//! that affects engine choice — shape, stride/pad, grouping, epilogue,
//! quantization scheme) to the measured winning engine. Lookups happen
//! at plan time in [`Selector::plan`][crate::engine::Selector::plan]: a
//! hit pins the engine (falling back to the policy if that engine can't
//! take the descriptor — tables survive catalog changes), a miss runs
//! the configured policy as before. The JSON schema is hand-rolled like
//! `exp::perf` (std-only repo; no serde).

use super::desc::ConvDesc;
use crate::linalg::gemm::Blocking;
use crate::quant::Granularity;
use anyhow::{Context, Result};
use std::collections::{BTreeMap, HashMap};
use std::path::Path;
use std::sync::OnceLock;

/// Schema version stamped into tuning files; bump on breaking changes.
///
/// History: v1 = per-descriptor engine entries; v2 adds the table-level
/// `blocking` object (the tuned GEMM macro-kernel Mc/Kc/Nc — see
/// [`crate::linalg::gemm::Blocking`]); v3 adds the table-level
/// `tile_len` field (the tuned overlap-save transform length installed
/// via [`crate::engine::tiled::set_tile_len_override`]); v4 adds the
/// `exec` array of per-(model, batch-size) measured end-to-end ns/call
/// records ([`TuningTable::set_exec_ns`]) that seed the serving
/// scheduler's cost model. Older files still load (they simply carry no
/// blocking / tile length / exec records).
pub const TUNING_SCHEMA_VERSION: u32 = 4;

fn gran_code(g: Granularity) -> &'static str {
    match g {
        Granularity::Tensor => "t",
        Granularity::Channel => "c",
        Granularity::Freq => "f",
        Granularity::ChannelFreq => "cf",
    }
}

/// Canonical string key for a descriptor: every selection-relevant field,
/// stable across runs (no hashing, so files stay human-diffable).
pub fn desc_key(d: &ConvDesc) -> String {
    let mut k = format!(
        "b{}_ic{}_oc{}_h{}x{}_r{}_s{}_p{}_g{}_d{}_e{}",
        d.batch,
        d.ic,
        d.oc,
        d.h,
        d.w,
        d.r,
        d.stride,
        d.pad,
        d.groups,
        d.dilation,
        d.epilogue.name(),
    );
    if let Some(q) = d.quant {
        k.push_str(&format!(
            "_qa{}w{}ga{}gw{}",
            q.a_bits,
            q.w_bits,
            gran_code(q.a_gran),
            gran_code(q.w_gran)
        ));
    }
    k
}

/// One measured choice: the winning engine and its median runtime.
#[derive(Clone, Debug, PartialEq)]
pub struct TunedChoice {
    /// catalog name of the measured winner
    pub engine: String,
    /// measured median nanoseconds per run (informational)
    pub median_ns: f64,
}

/// A persisted autotune table: descriptor key → measured winner, plus
/// an optional table-level tuned GEMM blocking (one per file — the
/// blocking is process-wide, chosen on the machine that ran the sweep).
#[derive(Clone, Debug, Default)]
pub struct TuningTable {
    entries: HashMap<String, TunedChoice>,
    blocking: Option<Blocking>,
    tile_len: Option<usize>,
    /// (model name, batch size) → measured end-to-end ns/call (schema ≥ 4)
    exec: BTreeMap<(String, usize), f64>,
}

impl TuningTable {
    /// An empty table.
    pub fn new() -> TuningTable {
        TuningTable::default()
    }

    /// Number of tuned descriptors.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no descriptors are tuned.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Record the measured winner for a descriptor.
    pub fn insert(&mut self, d: &ConvDesc, engine: &str, median_s: f64) {
        self.entries.insert(
            desc_key(d),
            TunedChoice { engine: engine.to_string(), median_ns: median_s * 1e9 },
        );
    }

    /// The recorded winner for a descriptor, if tuned.
    pub fn lookup(&self, d: &ConvDesc) -> Option<&TunedChoice> {
        self.entries.get(&desc_key(d))
    }

    /// Record the measured-fastest GEMM macro-kernel blocking
    /// (`sfc autotune`'s blocking sweep).
    pub fn set_blocking(&mut self, b: Option<Blocking>) {
        self.blocking = b;
    }

    /// The tuned GEMM blocking carried by this table, if any.
    pub fn blocking(&self) -> Option<Blocking> {
        self.blocking
    }

    /// Record the measured-fastest overlap-save tile length
    /// (`sfc autotune`'s tile sweep; schema ≥ 3).
    pub fn set_tile_len(&mut self, tile: Option<usize>) {
        self.tile_len = tile;
    }

    /// The tuned overlap-save tile length carried by this table, if any.
    pub fn tile_len(&self) -> Option<usize> {
        self.tile_len
    }

    /// Record the measured end-to-end ns/call for `model` at `batch`
    /// (`sfc autotune`'s exec-cost sweep; schema ≥ 4). The serving
    /// scheduler seeds its per-(model, batch-size) cost table from
    /// these records instead of a hard-coded cold-start guess.
    pub fn set_exec_ns(&mut self, model: &str, batch: usize, ns: f64) {
        self.exec.insert((model.to_string(), batch), ns);
    }

    /// The exact measured ns/call for `(model, batch)`, if recorded.
    pub fn exec_ns(&self, model: &str, batch: usize) -> Option<f64> {
        self.exec.get(&(model.to_string(), batch)).copied()
    }

    /// Predicted ns/call for `(model, batch)`: the exact record when
    /// present, otherwise the nearest recorded batch size for the model
    /// scaled linearly by batch ratio (conv work is linear in batch).
    pub fn exec_ns_scaled(&self, model: &str, batch: usize) -> Option<f64> {
        if let Some(ns) = self.exec_ns(model, batch) {
            return Some(ns);
        }
        let mut best: Option<(usize, f64)> = None;
        for ((m, b), ns) in &self.exec {
            if m != model {
                continue;
            }
            let dist = b.abs_diff(batch);
            if best.is_none_or(|(bb, _)| dist < bb.abs_diff(batch)) {
                best = Some((*b, *ns));
            }
        }
        best.map(|(b, ns)| ns * batch as f64 / b.max(1) as f64)
    }

    /// Iterate the recorded `(model, batch, ns)` exec-cost records in
    /// deterministic (sorted) order.
    pub fn exec_entries(&self) -> impl Iterator<Item = (&str, usize, f64)> {
        self.exec.iter().map(|((m, b), ns)| (m.as_str(), *b, *ns))
    }

    /// Render the table as the tuning-file JSON (one entry per line,
    /// keys sorted, so committed files diff cleanly run to run).
    pub fn to_json(&self) -> String {
        let mut body = String::new();
        body.push_str("{\n");
        body.push_str("  \"tuning\": \"sfc-autotune\",\n");
        body.push_str(&format!("  \"schema_version\": {TUNING_SCHEMA_VERSION},\n"));
        body.push_str(&format!("  \"kernel\": \"{}\",\n", crate::linalg::simd::kernel_name()));
        if let Some(b) = self.blocking {
            body.push_str(&format!(
                "  \"blocking\": {{\"mc\": {}, \"kc\": {}, \"nc\": {}}},\n",
                b.mc, b.kc, b.nc
            ));
        }
        if let Some(t) = self.tile_len {
            body.push_str(&format!("  \"tile_len\": {t},\n"));
        }
        if !self.exec.is_empty() {
            // field names deliberately avoid the "desc"/"blocking"/
            // "tile_len" substrings the line-oriented parser scans for
            body.push_str("  \"exec\": [\n");
            for (i, ((m, b), ns)) in self.exec.iter().enumerate() {
                body.push_str(&format!(
                    "    {{\"exec_model\": \"{}\", \"exec_batch\": {}, \"exec_ns\": {:.1}}}{}\n",
                    m,
                    b,
                    ns,
                    if i + 1 < self.exec.len() { "," } else { "" }
                ));
            }
            body.push_str("  ],\n");
        }
        body.push_str("  \"entries\": [\n");
        let mut keys: Vec<&String> = self.entries.keys().collect();
        keys.sort();
        for (i, k) in keys.iter().enumerate() {
            let c = &self.entries[*k];
            body.push_str(&format!(
                "    {{\"desc\": \"{}\", \"engine\": \"{}\", \"median_ns\": {:.1}}}{}\n",
                k,
                c.engine,
                c.median_ns,
                if i + 1 < keys.len() { "," } else { "" }
            ));
        }
        body.push_str("  ]\n}\n");
        body
    }

    /// Parse a tuning file produced by [`TuningTable::to_json`]. The
    /// parser is line-oriented (one entry object per line, the shape we
    /// emit) — not a general JSON parser, by design: the repo is
    /// std-only and the file format is ours.
    pub fn from_json(text: &str) -> Result<TuningTable> {
        anyhow::ensure!(
            text.contains("\"tuning\": \"sfc-autotune\""),
            "not an sfc tuning file (missing the \"tuning\" marker)"
        );
        let version = num_field(text, "schema_version")
            .context("tuning file has no schema_version")? as u32;
        anyhow::ensure!(
            (1..=TUNING_SCHEMA_VERSION).contains(&version),
            "tuning file schema v{version} unsupported (expected v1..=v{TUNING_SCHEMA_VERSION})"
        );
        // the blocking object lives on its own line — parse per-line so
        // num_field's whole-text scan can't collide with entry fields
        let mut blocking = None;
        if let Some(line) = text.lines().find(|l| l.contains("\"blocking\"")) {
            let mc = num_field(line, "mc").context("blocking without mc")? as usize;
            let kc = num_field(line, "kc").context("blocking without kc")? as usize;
            let nc = num_field(line, "nc").context("blocking without nc")? as usize;
            blocking = Some(Blocking { mc, kc, nc });
        }
        // likewise the tile_len line (entries never carry the field)
        let mut tile_len = None;
        if let Some(line) = text.lines().find(|l| l.contains("\"tile_len\"")) {
            tile_len = Some(num_field(line, "tile_len").context("malformed tile_len")? as usize);
        }
        let mut entries = HashMap::new();
        let mut exec = BTreeMap::new();
        for line in text.lines() {
            if let Some(model) = quoted_field(line, "exec_model") {
                let batch = num_field(line, "exec_batch")
                    .with_context(|| format!("exec record without exec_batch: {line}"))?
                    as usize;
                let ns = num_field(line, "exec_ns")
                    .with_context(|| format!("exec record without exec_ns: {line}"))?;
                exec.insert((model.to_string(), batch), ns);
                continue;
            }
            let Some(desc) = quoted_field(line, "desc") else { continue };
            let engine = quoted_field(line, "engine")
                .with_context(|| format!("tuning entry without engine: {line}"))?;
            let median_ns = num_field(line, "median_ns")
                .with_context(|| format!("tuning entry without median_ns: {line}"))?;
            entries.insert(
                desc.to_string(),
                TunedChoice { engine: engine.to_string(), median_ns },
            );
        }
        Ok(TuningTable { entries, blocking, tile_len, exec })
    }

    /// Write the table to `path` as tuning-file JSON.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json())
            .with_context(|| format!("write tuning table {}", path.display()))
    }

    /// Load a tuning table from a file written by [`TuningTable::save`].
    pub fn load(path: &Path) -> Result<TuningTable> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read tuning table {}", path.display()))?;
        TuningTable::from_json(&text)
            .with_context(|| format!("parse tuning table {}", path.display()))
    }
}

/// Extract `"key": "value"` from one line.
fn quoted_field<'a>(line: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("\"{key}\": \"");
    let start = line.find(&pat)? + pat.len();
    let end = line[start..].find('"')?;
    Some(&line[start..start + end])
}

/// Extract `"key": <number>` from one line.
fn num_field(text: &str, key: &str) -> Option<f64> {
    let pat = format!("\"{key}\": ");
    let start = text.find(&pat)? + pat.len();
    let rest = &text[start..];
    let end = rest
        .find(|c: char| !(c.is_ascii_digit() || c == '.' || c == '-' || c == 'e' || c == 'E'))
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

/// The process-wide tuning table, consulted by every selector (after its
/// own table, if any). Installed once, typically by `sfc serve --tuning`.
static GLOBAL_TUNING: OnceLock<TuningTable> = OnceLock::new();

/// Install the process-wide tuning table. Errors if one is already
/// installed (tables are startup configuration, not mutable state).
/// A table that carries a tuned GEMM blocking also applies it
/// process-wide ([`crate::linalg::gemm::set_blocking_override`]), and
/// one that carries a tuned tile length installs it the same way
/// ([`crate::engine::tiled::set_tile_len_override`]) — safe because
/// every blocking is bit-identical and every valid tile length is
/// output-exact, so both are purely performance settings.
pub fn install_global(table: TuningTable) -> Result<()> {
    let blocking = table.blocking();
    let tile_len = table.tile_len();
    GLOBAL_TUNING
        .set(table)
        .map_err(|_| anyhow::anyhow!("a global tuning table is already installed"))?;
    if blocking.is_some() {
        crate::linalg::gemm::set_blocking_override(blocking);
    }
    if tile_len.is_some() {
        crate::engine::tiled::set_tile_len_override(tile_len);
    }
    Ok(())
}

/// Look a descriptor up in the process-wide tuning table, if installed.
pub fn global_lookup(d: &ConvDesc) -> Option<&'static TunedChoice> {
    GLOBAL_TUNING.get().and_then(|t| t.lookup(d))
}

/// Predicted exec ns/call for `(model, batch)` from the process-wide
/// tuning table (exact record or nearest-batch linear scaling), if a
/// table is installed and carries a usable record. The serving
/// scheduler's cold-start seed ([`crate::coordinator::sched`]).
pub fn global_exec_ns(model: &str, batch: usize) -> Option<f64> {
    GLOBAL_TUNING.get().and_then(|t| t.exec_ns_scaled(model, batch))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::QuantSpec;

    #[test]
    fn desc_key_distinguishes_quant_and_shape() {
        let d = ConvDesc::new(8, 3, 16, 32, 32, 3, 1, 1);
        let dq = d.with_quant(QuantSpec::transform_default(8));
        let d5 = ConvDesc::new(8, 3, 16, 32, 32, 5, 1, 2);
        assert_ne!(desc_key(&d), desc_key(&dq));
        assert_ne!(desc_key(&d), desc_key(&d5));
        // shape-identical descriptors share a key (plan-cache property)
        assert_eq!(desc_key(&d), desc_key(&ConvDesc::new(8, 3, 16, 32, 32, 3, 1, 1)));
    }

    #[test]
    fn round_trips_through_json() {
        let d1 = ConvDesc::new(8, 3, 16, 32, 32, 3, 1, 1);
        let d2 = ConvDesc::new(8, 16, 32, 16, 16, 3, 1, 1)
            .with_quant(QuantSpec::transform_default(8));
        let mut t = TuningTable::new();
        t.insert(&d1, "SFC-6(6x6,3x3)", 1.25e-3);
        t.insert(&d2, "direct", 3.5e-4);
        t.set_blocking(Some(Blocking { mc: 64, kc: 512, nc: 256 }));
        t.set_tile_len(Some(32));
        t.set_exec_ns("resnet18", 1, 450_000.0);
        t.set_exec_ns("resnet18", 8, 2_900_000.0);
        t.set_exec_ns("mobilenet", 8, 1_200_000.0);
        let text = t.to_json();
        let back = TuningTable::from_json(&text).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back.lookup(&d1).unwrap().engine, "SFC-6(6x6,3x3)");
        assert_eq!(back.lookup(&d2).unwrap().engine, "direct");
        assert!((back.lookup(&d1).unwrap().median_ns - 1.25e6).abs() < 1.0);
        assert_eq!(back.blocking(), Some(Blocking { mc: 64, kc: 512, nc: 256 }));
        assert_eq!(back.tile_len(), Some(32));
        assert_eq!(back.exec_ns("resnet18", 8), Some(2_900_000.0));
        assert_eq!(back.exec_entries().count(), 3);
        // deterministic rendering (committed files must diff cleanly)
        assert_eq!(text, back.to_json());
    }

    #[test]
    fn exec_ns_scaled_interpolates_by_batch() {
        let mut t = TuningTable::new();
        t.set_exec_ns("m", 2, 1_000.0);
        t.set_exec_ns("m", 8, 4_800.0);
        // exact hit wins
        assert_eq!(t.exec_ns_scaled("m", 8), Some(4_800.0));
        // nearest batch, linearly scaled: 4 is nearest to 2
        assert_eq!(t.exec_ns_scaled("m", 4), Some(2_000.0));
        // extrapolation above the largest recorded batch
        assert_eq!(t.exec_ns_scaled("m", 16), Some(9_600.0));
        // unknown model carries no prediction
        assert_eq!(t.exec_ns_scaled("other", 4), None);
    }

    #[test]
    fn accepts_v3_files_without_exec_records() {
        let v3 = "{\n  \"tuning\": \"sfc-autotune\",\n  \"schema_version\": 3,\n  \
                  \"kernel\": \"scalar\",\n  \"tile_len\": 32,\n  \"entries\": [\n    \
                  {\"desc\": \"b1_ic3_oc16_h32x32_r3_s1_p1_g1_d1_enone\", \
                  \"engine\": \"direct\", \"median_ns\": 100.0}\n  ]\n}\n";
        let t = TuningTable::from_json(v3).unwrap();
        assert_eq!(t.len(), 1);
        assert_eq!(t.tile_len(), Some(32));
        assert_eq!(t.exec_ns_scaled("resnet18", 8), None, "v3 files carry no exec records");
    }

    #[test]
    fn accepts_v1_files_without_blocking() {
        let v1 = "{\n  \"tuning\": \"sfc-autotune\",\n  \"schema_version\": 1,\n  \
                  \"kernel\": \"scalar\",\n  \"entries\": [\n    \
                  {\"desc\": \"b1_ic3_oc16_h32x32_r3_s1_p1_g1_d1_enone\", \
                  \"engine\": \"direct\", \"median_ns\": 100.0}\n  ]\n}\n";
        let t = TuningTable::from_json(v1).unwrap();
        assert_eq!(t.len(), 1);
        assert_eq!(t.blocking(), None, "v1 files carry no blocking");
        assert_eq!(t.tile_len(), None, "v1 files carry no tile length");
    }

    #[test]
    fn accepts_v2_files_without_tile_len() {
        let v2 = "{\n  \"tuning\": \"sfc-autotune\",\n  \"schema_version\": 2,\n  \
                  \"kernel\": \"scalar\",\n  \
                  \"blocking\": {\"mc\": 96, \"kc\": 256, \"nc\": 128},\n  \"entries\": [\n    \
                  {\"desc\": \"b1_ic3_oc16_h32x32_r3_s1_p1_g1_d1_enone\", \
                  \"engine\": \"direct\", \"median_ns\": 100.0}\n  ]\n}\n";
        let t = TuningTable::from_json(v2).unwrap();
        assert_eq!(t.len(), 1);
        assert_eq!(t.blocking(), Some(Blocking { mc: 96, kc: 256, nc: 128 }));
        assert_eq!(t.tile_len(), None, "v2 files carry no tile length");
    }

    #[test]
    fn rejects_foreign_and_versioned_files() {
        assert!(TuningTable::from_json("{\"not\": \"ours\"}").is_err());
        let bad = "{\n  \"tuning\": \"sfc-autotune\",\n  \"schema_version\": 99,\n  \
                   \"entries\": [\n  ]\n}\n";
        assert!(TuningTable::from_json(bad).is_err());
    }
}
