//! Overlap-save tiled frequency-domain executors.
//!
//! The whole-image FFT/NTT executors in [`super::exec`] zero-pad the
//! *entire* padded input up to a power of two, so their transform
//! workspace grows superlinearly with the image (`OC·IC·SH·SW` kernel
//! planes) — the reason those engines decline large images. This module
//! runs the same frequency-domain correlation **per overlapping block**
//! (cuDNN's `FFT_TILING` split): a fixed transform length `S` is chosen
//! from the kernel alone, the output is partitioned into `(S − R + 1)²`
//! blocks, and each block gathers its `S×S` input window (the `R − 1`
//! halo rows/columns overlap the neighbouring windows), transforms,
//! multiplies with the once-precomputed flipped-kernel planes, inverse
//! transforms, and scatters only the valid outputs. Transform workspace
//! is then `O(OC·IC·S²)` — a function of the *kernel*, not the image.
//!
//! Why the valid region is exact: a circular `S`-point convolution of
//! the gathered window with a kernel of support `R` only wraps around
//! in its first `R − 1` output positions. The scatter reads positions
//! `R − 1 … S − 1` — the overlap-*save* discard — where circular and
//! linear convolution agree, so each tiled output equals the
//! whole-image value: bit-identical for the exact NTT arm (both sides
//! are exact integer arithmetic) and within f64 roundoff for the FFT
//! arm. See ENGINE.md §Tiled frequency-domain execution.

use super::desc::Epilogue;
use super::exec::{fft2d, ntt2d, ntt_decode, ntt_encode};
use super::workspace::Workspace;
use crate::algo::ntt::P;
use crate::linalg::simd::quantize_i8_slice;
use crate::nn::tensor::Tensor;
use crate::util::par::{num_threads, par_jobs_states};
use crate::util::pool::SendPtr;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Process-wide tuned tile length (0 = unset). Installed by the
/// autotuner (tuning-table schema ≥ 3) through
/// [`set_tile_len_override`]; consulted by [`default_tile_len`].
static TILE_LEN_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Install (or clear, with `None`) a process-wide tile-length override.
/// The override wins in [`default_tile_len`] only when it is valid for
/// the requested kernel (power of two and ≥ `r`); otherwise the
/// closed-form rule applies, so a table tuned on large kernels can
/// never break small ones.
pub fn set_tile_len_override(tile: Option<usize>) {
    TILE_LEN_OVERRIDE.store(tile.unwrap_or(0), Ordering::Relaxed);
}

/// The currently installed tile-length override, if any.
pub fn tile_len_override() -> Option<usize> {
    match TILE_LEN_OVERRIDE.load(Ordering::Relaxed) {
        0 => None,
        t => Some(t),
    }
}

/// Default transform length for kernel size `r`: the autotuned override
/// when one is installed *and* valid for this kernel (power of two,
/// ≥ `r`), else the smallest power of two ≥ `max(16, 4·(r − 1))` — that
/// closed-form keeps the valid fraction of every block at least ¾ while
/// the per-block transform stays cache-resident.
pub fn default_tile_len(r: usize) -> usize {
    if let Some(t) = tile_len_override() {
        if t.is_power_of_two() && t >= r {
            return t;
        }
    }
    (4 * (r.saturating_sub(1))).max(16).next_power_of_two()
}

/// Per-block gather/scatter geometry shared by both tiled arms.
struct TileGrid {
    /// transform length per axis (power of two)
    s: usize,
    /// valid outputs per block per axis: `s − r + 1`
    step: usize,
    /// output blocks along y / x
    nby: usize,
    nbx: usize,
}

impl TileGrid {
    fn new(tile: usize, r: usize, oh: usize, ow: usize) -> TileGrid {
        assert!(tile.is_power_of_two(), "tile length {tile} must be a power of two");
        assert!(tile >= r, "tile length {tile} must cover the kernel {r}");
        let step = tile - r + 1;
        TileGrid { s: tile, step, nby: oh.div_ceil(step), nbx: ow.div_ceil(step) }
    }
}

/// Float overlap-save tiled FFT convolution (stride 1, dense) into
/// `out`. Same contract as [`super::exec::conv2d_fft_into`] — results
/// agree within f64 roundoff — but the transform workspace is
/// `O(OC·IC·tile²)` independent of the image size.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_fft_tiled_into(
    x: &Tensor,
    w: &Tensor,
    bias: &[f32],
    pad: usize,
    tile: usize,
    ep: Epilogue,
    ws: &mut Workspace,
    out: &mut Tensor,
) {
    let (n, ic, h, wid) = x.dims4();
    let (oc, ic2, r, r2) = w.dims4();
    assert_eq!(ic, ic2, "channel mismatch");
    assert_eq!(r, r2, "square kernels only");
    assert!(bias.is_empty() || bias.len() == oc);
    let (hp, wp) = (h + 2 * pad, wid + 2 * pad);
    let oh = hp - r + 1;
    let ow = wp - r + 1;
    out.assert_dims(&[n, oc, oh, ow]);
    let g = TileGrid::new(tile, r, oh, ow);
    let s = g.s;
    let s2 = s * s;

    // Flipped-kernel FFTs at the tile length, once for all blocks and
    // images: [OC][IC] planes.
    let mut kf_re = ws.take_f64(oc * ic * s2);
    let mut kf_im = ws.take_f64(oc * ic * s2);
    {
        let mut cr = ws.take_f64(s);
        let mut ci = ws.take_f64(s);
        for o in 0..oc {
            for c in 0..ic {
                let base = (o * ic + c) * s2;
                let wplane = w.plane(o, c);
                for ky in 0..r {
                    for kx in 0..r {
                        // correlation = convolution with the flipped filter
                        kf_re[base + (r - 1 - ky) * s + (r - 1 - kx)] = wplane[ky * r + kx] as f64;
                    }
                }
                let kre = &mut kf_re[base..base + s2];
                let kim = &mut kf_im[base..base + s2];
                fft2d(kre, kim, s, s, false, &mut cr, &mut ci);
            }
        }
        ws.give_f64(cr);
        ws.give_f64(ci);
    }

    struct St {
        xre: Vec<f64>,
        xim: Vec<f64>,
        acc_re: Vec<f64>,
        acc_im: Vec<f64>,
        cr: Vec<f64>,
        ci: Vec<f64>,
    }
    // One stealable pool task per (image, block): fine enough that a
    // few large blocks can't serialize the tail, and every task's
    // output cells are disjoint (blocks partition the output plane).
    let njobs = n * g.nby * g.nbx;
    let workers = num_threads().min(njobs).max(1);
    let mut states: Vec<St> = (0..workers)
        .map(|_| St {
            xre: ws.take_f64(ic * s2),
            xim: ws.take_f64(ic * s2),
            acc_re: ws.take_f64(s2),
            acc_im: ws.take_f64(s2),
            cr: ws.take_f64(s),
            ci: ws.take_f64(s),
        })
        .collect();
    let inv_scale = 1.0 / s2 as f64;
    let op = SendPtr::new(out.data.as_mut_ptr());
    par_jobs_states(njobs, &mut states, |st, job| {
        let ni = job / (g.nby * g.nbx);
        let by = (job / g.nbx) % g.nby;
        let bx = job % g.nbx;
        // block output origin; the input window starts at the
        // same coordinate in the *padded* frame and spans S
        // (halo = R − 1 rows/cols shared with the next block)
        let oy0 = by * g.step;
        let ox0 = bx * g.step;
        let vy = g.step.min(oh - oy0);
        let vx = g.step.min(ow - ox0);
        st.xre.fill(0.0);
        st.xim.fill(0.0);
        for c in 0..ic {
            let base = c * s2;
            let plane = x.plane(ni, c);
            for y in 0..s {
                let py = oy0 + y; // padded-frame row
                if py < pad || py >= h + pad {
                    continue;
                }
                let yy = py - pad;
                for xcol in 0..s {
                    let px = ox0 + xcol;
                    if px < pad || px >= wid + pad {
                        continue;
                    }
                    st.xre[base + y * s + xcol] = plane[yy * wid + (px - pad)] as f64;
                }
            }
            let xre = &mut st.xre[base..base + s2];
            let xim = &mut st.xim[base..base + s2];
            fft2d(xre, xim, s, s, false, &mut st.cr, &mut st.ci);
        }
        for o in 0..oc {
            st.acc_re.fill(0.0);
            st.acc_im.fill(0.0);
            for c in 0..ic {
                let xb = c * s2;
                let kb = (o * ic + c) * s2;
                for i in 0..s2 {
                    let (ar, ai) = (st.xre[xb + i], st.xim[xb + i]);
                    let (br, bi) = (kf_re[kb + i], kf_im[kb + i]);
                    st.acc_re[i] += ar * br - ai * bi;
                    st.acc_im[i] += ar * bi + ai * br;
                }
            }
            fft2d(&mut st.acc_re, &mut st.acc_im, s, s, true, &mut st.cr, &mut st.ci);
            let b = if bias.is_empty() { 0.0 } else { bias[o] };
            let pbase = (ni * oc + o) * oh * ow;
            for j in 0..vy {
                for i in 0..vx {
                    // overlap-save: skip the R − 1 wrapped rows/cols
                    let v = st.acc_re[(j + r - 1) * s + (i + r - 1)] * inv_scale;
                    // SAFETY: job (ni, by, bx) exclusively owns the
                    // valid cells of its block in every output plane.
                    unsafe {
                        *op.get().add(pbase + (oy0 + j) * ow + (ox0 + i)) = ep.apply(v as f32 + b);
                    }
                }
            }
        }
    });
    for st in states {
        ws.give_f64(st.xre);
        ws.give_f64(st.xim);
        ws.give_f64(st.acc_re);
        ws.give_f64(st.acc_im);
        ws.give_f64(st.cr);
        ws.give_f64(st.ci);
    }
    ws.give_f64(kf_re);
    ws.give_f64(kf_im);
}

/// Float overlap-save tiled FFT convolution (allocating wrapper).
pub fn conv2d_fft_tiled(x: &Tensor, w: &Tensor, bias: &[f32], pad: usize, tile: usize) -> Tensor {
    let (n, _, h, wid) = x.dims4();
    let (oc, _, r, _) = w.dims4();
    let oh = h + 2 * pad - r + 1;
    let ow = wid + 2 * pad - r + 1;
    let mut out = Tensor::zeros(&[n, oc, oh, ow]);
    let mut ws = Workspace::new();
    conv2d_fft_tiled_into(x, w, bias, pad, tile, Epilogue::None, &mut ws, &mut out);
    out
}

/// Exact overlap-save tiled integer correlation via the NTT, written
/// into the `[N][OC][OH][OW]` i64 accumulator slice `out`. Same
/// exactness contract as [`super::exec::ntt_corr2d_i8_into`]
/// (`|y| < p/2` ⇒ equal to the nested-loop integer conv), and therefore
/// **bit-identical** to the whole-image arm — both compute the same
/// exact integers; only the transform workspace differs
/// (`O(OC·IC·tile²)` vs `O(OC·IC·SH·SW)`).
#[allow(clippy::too_many_arguments)]
pub fn ntt_corr2d_i8_tiled_into(
    xq: &[i8],
    n: usize,
    ic: usize,
    h: usize,
    w: usize,
    wq: &[i8],
    oc: usize,
    r: usize,
    pad: usize,
    tile: usize,
    ws: &mut Workspace,
    out: &mut [i64],
) {
    assert_eq!(xq.len(), n * ic * h * w);
    assert_eq!(wq.len(), oc * ic * r * r);
    let (hp, wp) = (h + 2 * pad, w + 2 * pad);
    let oh = hp - r + 1;
    let ow = wp - r + 1;
    assert_eq!(out.len(), n * oc * oh * ow, "accumulator slice size mismatch");
    let g = TileGrid::new(tile, r, oh, ow);
    let s = g.s;
    let s2 = s * s;

    // Flipped-kernel NTTs at the tile length, shared across blocks/images.
    let mut knt = ws.take_u64(oc * ic * s2);
    {
        let mut col = ws.take_u64(s);
        for o in 0..oc {
            for c in 0..ic {
                let base = (o * ic + c) * s2;
                let wplane = &wq[(o * ic + c) * r * r..(o * ic + c + 1) * r * r];
                for ky in 0..r {
                    for kx in 0..r {
                        knt[base + (r - 1 - ky) * s + (r - 1 - kx)] =
                            ntt_encode(wplane[ky * r + kx] as i64);
                    }
                }
                ntt2d(&mut knt[base..base + s2], s, s, false, &mut col);
            }
        }
        ws.give_u64(col);
    }

    struct St {
        xnt: Vec<u64>,
        acc: Vec<u64>,
        col: Vec<u64>,
    }
    // One stealable pool task per (image, block); tasks write disjoint
    // output cells, and the exact integer result of each block is
    // independent of which worker runs it — the whole-image
    // bit-identity contract is a property of the decomposition alone.
    let njobs = n * g.nby * g.nbx;
    let workers = num_threads().min(njobs).max(1);
    let mut states: Vec<St> = (0..workers)
        .map(|_| St { xnt: ws.take_u64(ic * s2), acc: ws.take_u64(s2), col: ws.take_u64(s) })
        .collect();
    let op = SendPtr::new(out.as_mut_ptr());
    par_jobs_states(njobs, &mut states, |st, job| {
        let ni = job / (g.nby * g.nbx);
        let by = (job / g.nbx) % g.nby;
        let bx = job % g.nbx;
        let oy0 = by * g.step;
        let ox0 = bx * g.step;
        let vy = g.step.min(oh - oy0);
        let vx = g.step.min(ow - ox0);
        st.xnt.fill(0);
        for c in 0..ic {
            let base = c * s2;
            let plane = &xq[(ni * ic + c) * h * w..(ni * ic + c + 1) * h * w];
            for y in 0..s {
                let py = oy0 + y;
                if py < pad || py >= h + pad {
                    continue;
                }
                let yy = py - pad;
                for xcol in 0..s {
                    let px = ox0 + xcol;
                    if px < pad || px >= w + pad {
                        continue;
                    }
                    st.xnt[base + y * s + xcol] = ntt_encode(plane[yy * w + (px - pad)] as i64);
                }
            }
            ntt2d(&mut st.xnt[base..base + s2], s, s, false, &mut st.col);
        }
        for o in 0..oc {
            st.acc.fill(0);
            for c in 0..ic {
                let xb = c * s2;
                let kb = (o * ic + c) * s2;
                for i in 0..s2 {
                    // operands < p < 2^30 ⇒ the product fits u64
                    st.acc[i] = (st.acc[i] + st.xnt[xb + i] * knt[kb + i] % P) % P;
                }
            }
            ntt2d(&mut st.acc, s, s, true, &mut st.col);
            let pbase = (ni * oc + o) * oh * ow;
            for j in 0..vy {
                for i in 0..vx {
                    // SAFETY: job (ni, by, bx) exclusively owns the
                    // valid cells of its block in every output plane.
                    unsafe {
                        *op.get().add(pbase + (oy0 + j) * ow + (ox0 + i)) =
                            ntt_decode(st.acc[(j + r - 1) * s + (i + r - 1)]);
                    }
                }
            }
        }
    });
    for st in states {
        ws.give_u64(st.xnt);
        ws.give_u64(st.acc);
        ws.give_u64(st.col);
    }
    ws.give_u64(knt);
}

/// Exact overlap-save tiled integer correlation (allocating wrapper):
/// returns `[N][OC][OH][OW]` i64 accumulators.
#[allow(clippy::too_many_arguments)]
pub fn ntt_corr2d_i8_tiled(
    xq: &[i8],
    n: usize,
    ic: usize,
    h: usize,
    w: usize,
    wq: &[i8],
    oc: usize,
    r: usize,
    pad: usize,
    tile: usize,
) -> Vec<i64> {
    let oh = h + 2 * pad - r + 1;
    let ow = w + 2 * pad - r + 1;
    let mut out = vec![0i64; n * oc * oh * ow];
    let mut ws = Workspace::new();
    ntt_corr2d_i8_tiled_into(xq, n, ic, h, w, wq, oc, r, pad, tile, &mut ws, &mut out);
    out
}

/// Float-entry overlap-save tiled NTT convolution into `out`:
/// per-tensor symmetric int8 quantization (identical scales to the
/// whole-image arm — both derive them from the full tensors), exact
/// tiled integer correlation, per-element dequantize. Because the
/// integer stage is bit-identical to the whole-image arm and the
/// quantize/dequantize stages are element-wise with the same global
/// scales, the float results are bit-identical too.
pub fn conv2d_ntt_tiled_int8_into(
    x: &Tensor,
    w: &Tensor,
    bias: &[f32],
    pad: usize,
    tile: usize,
    ep: Epilogue,
    ws: &mut Workspace,
    out: &mut Tensor,
) {
    let (n, ic, h, wid) = x.dims4();
    let (oc, ic2, r, r2) = w.dims4();
    assert_eq!(ic, ic2, "channel mismatch");
    assert_eq!(r, r2, "square kernels only");
    let oh = h + 2 * pad - r + 1;
    let ow = wid + 2 * pad - r + 1;
    out.assert_dims(&[n, oc, oh, ow]);
    let sx = {
        let m = x.max_abs();
        if m > 0.0 {
            m / 127.0
        } else {
            1.0
        }
    };
    let sw_ = {
        let m = w.max_abs();
        if m > 0.0 {
            m / 127.0
        } else {
            1.0
        }
    };
    let mut xq = ws.take_i8(x.data.len());
    quantize_i8_slice(&x.data, sx, 127, &mut xq);
    let mut wq = ws.take_i8(w.data.len());
    quantize_i8_slice(&w.data, sw_, 127, &mut wq);
    let mut acc = ws.take_i64(n * oc * oh * ow);
    ntt_corr2d_i8_tiled_into(&xq, n, ic, h, wid, &wq, oc, r, pad, tile, ws, &mut acc);
    let deq = sx * sw_;
    for ni in 0..n {
        for o in 0..oc {
            let b = if bias.is_empty() { 0.0 } else { bias[o] };
            let src = &acc[(ni * oc + o) * oh * ow..(ni * oc + o + 1) * oh * ow];
            let dst = out.plane_mut(ni, o);
            for (d, &a) in dst.iter_mut().zip(src) {
                *d = ep.apply(a as f32 * deq + b);
            }
        }
    }
    ws.give_i8(xq);
    ws.give_i8(wq);
    ws.give_i64(acc);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::exec::{conv2d_fft, ntt_corr2d_i8};
    use crate::util::Pcg32;

    fn rand_tensor(dims: &[usize], rng: &mut Pcg32, sigma: f64) -> Tensor {
        let mut t = Tensor::zeros(dims);
        rng.fill_gaussian(&mut t.data, sigma);
        t
    }

    #[test]
    fn default_tile_len_covers_kernel() {
        for r in [1usize, 3, 5, 7, 11, 13] {
            let s = default_tile_len(r);
            assert!(s.is_power_of_two() && s >= r, "r{r}: tile {s}");
            assert!(s - r + 1 >= s / 2, "r{r}: valid fraction too small ({s})");
        }
    }

    #[test]
    fn tile_len_override_applies_only_when_valid() {
        // Serialize against the selector's tile sweep, which also
        // mutates the process-wide override.
        let _guard = crate::linalg::simd::TEST_OVERRIDE_LOCK
            .lock()
            .unwrap_or_else(|p| p.into_inner());
        // Values chosen so concurrently-running tests that consult
        // `default_tile_len` stay on valid tiles at every step.
        set_tile_len_override(Some(64));
        assert_eq!(default_tile_len(3), 64);
        assert_eq!(default_tile_len(11), 64);
        set_tile_len_override(Some(6)); // not a power of two → ignored
        assert_eq!(default_tile_len(3), 16);
        set_tile_len_override(Some(4)); // valid for r=3, too small for r=11
        assert_eq!(default_tile_len(3), 4);
        assert_eq!(default_tile_len(11), 64);
        set_tile_len_override(None);
        assert_eq!(default_tile_len(3), 16);
        assert_eq!(default_tile_len(11), 64);
    }

    #[test]
    fn tiled_fft_matches_whole_image_fft() {
        let mut rng = Pcg32::seeded(31);
        for (hh, ww, r, pad, tile) in
            [(12usize, 12usize, 3usize, 1usize, 8usize), (20, 17, 5, 2, 16), (9, 9, 3, 0, 16)]
        {
            let x = rand_tensor(&[2, 3, hh, ww], &mut rng, 1.0);
            let w = rand_tensor(&[2, 3, r, r], &mut rng, 0.3);
            let bias = vec![0.2, -0.4];
            let want = conv2d_fft(&x, &w, &bias, pad);
            let got = conv2d_fft_tiled(&x, &w, &bias, pad, tile);
            assert_eq!(got.dims, want.dims);
            assert!(got.mse(&want) < 1e-9, "{hh}x{ww} r{r} p{pad} t{tile}: {}", got.mse(&want));
        }
    }

    #[test]
    fn tiled_ntt_bit_identical_to_whole_image() {
        let mut rng = Pcg32::seeded(32);
        let (n, ic, h, w, oc, r, pad) = (1usize, 3usize, 13usize, 11usize, 2usize, 3usize, 1usize);
        let xq: Vec<i8> =
            (0..n * ic * h * w).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let wq: Vec<i8> =
            (0..oc * ic * r * r).map(|_| (rng.below(255) as i32 - 127) as i8).collect();
        let want = ntt_corr2d_i8(&xq, n, ic, h, w, &wq, oc, r, pad);
        for tile in [4usize, 8, 16, 32] {
            let got = ntt_corr2d_i8_tiled(&xq, n, ic, h, w, &wq, oc, r, pad, tile);
            assert_eq!(got, want, "tile {tile}");
        }
    }
}
