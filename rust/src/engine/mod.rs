//! The unified convolution engine API (cuDNN-style).
//!
//! The paper's central claim is that direct, im2col, Winograd, SFC, FFT
//! and NTT convolution are interchangeable *engines* with different
//! cost/accuracy trade-offs (Tables 1/3). This module is the surface that
//! makes them interchangeable in code:
//!
//! * [`ConvDesc`] — what to compute (shapes, stride/pad, quantization);
//! * [`ConvEngine`] — how one backend computes it (`supports`, `plan`,
//!   `workspace_bytes`, `cost_model`);
//! * [`ConvPlan`] — a ready-to-run, shareable execution plan;
//! * [`PlanCache`] — shape-keyed plan reuse with hit/miss metrics;
//! * [`Selector`] — per-layer engine choice: BOPs-model [`Policy::Heuristic`]
//!   or measured [`Policy::Autotune`] (cuDNN `findAlgorithm` style).
//!
//! Engine instances are seeded from the Table-1 catalog
//! ([`crate::algo::registry`]), so every algorithm the paper evaluates is
//! one `plan_named` away, and `nn`/`quant`/`exp`/CLI all construct conv
//! layers exclusively through descriptors + selector.

pub mod cache;
pub mod desc;
pub mod exec;
pub mod select;
pub mod tiled;
pub mod tuning;
pub mod workspace;

pub use cache::{global as global_plan_cache, PlanCache, PlanKey};
pub use desc::{ConvDesc, ConvDescBuilder, Epilogue, QuantSpec};
pub use select::{default_selector, AutotuneCfg, Policy, Selector, TuneEntry};
pub use tuning::TuningTable;
pub use workspace::{Workspace, WorkspacePool, WsPoolGauges};

use crate::algo::ntt::ntt_odot_bits;
use crate::algo::registry::{catalog, AlgoKind, AlgoSpec};
use crate::bops::{direct_bops_grouped_dilated, fast_bops_grouped, mul_bops};
use crate::linalg::gemm::{packed_b_f32_len, PANEL};
use crate::nn::conv::{
    conv2d_direct_dilated_into, conv2d_fast_into, conv2d_fast_packed_into, pack_fast_weights,
    FastConvPlan, TILE_LANES,
};
use crate::nn::tensor::Tensor;
use crate::quant::Granularity;
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};

/// How a plan executes. The variants map 1:1 onto the executor kernels;
/// `Fast` carries the shared transform matrices (Winograd/SFC), the
/// tiled frequency-domain variants carry their transform length.
pub enum PlanKernel {
    /// nested-loop spatial convolution (grouped and dilated included)
    Direct,
    /// per-group im2col lowering + blocked GEMM (dilated included)
    Im2col,
    /// tiled bilinear fast convolution (Winograd/SFC), with the shared
    /// transform matrices
    Fast(Arc<FastConvPlan>),
    /// whole-image float FFT convolution (dense only)
    Fft,
    /// whole-image exact int8 NTT convolution (dense only)
    Ntt,
    /// overlap-save tiled float FFT convolution (dense only) at the
    /// carried transform length — workspace is `O(OC·IC·tile²)`,
    /// independent of the image size
    FftTiled {
        /// per-axis transform length (power of two ≥ R)
        tile: usize,
    },
    /// overlap-save tiled exact int8 NTT convolution (dense only) at
    /// the carried transform length; bit-identical to [`PlanKernel::Ntt`]
    NttTiled {
        /// per-axis transform length (power of two ≥ R)
        tile: usize,
    },
}

/// A ready-to-run convolution plan: the descriptor it was planned for,
/// the engine that produced it and the executor kernel. Plans are
/// immutable and shared via `Arc` (model graphs, the plan cache and the
/// quantizer all hold references to the same plan).
pub struct ConvPlan {
    /// name of the engine that produced the plan
    pub engine: &'static str,
    /// the problem the plan was built for
    pub desc: ConvDesc,
    /// the executor kernel that runs it
    pub kernel: PlanKernel,
    /// live bytes of pre-packed weight artifacts built from this plan
    /// ([`PackedWeights`] + quantized packed panels), for the
    /// plan-cache / serving memory accounting
    packed_bytes: AtomicUsize,
}

/// Process-wide live bytes held by pre-packed weight artifacts
/// (transform-domain packed panels, float and int8). Mirrored into
/// `coordinator::metrics` and printed by `sfc serve`.
static PACKED_WEIGHT_BYTES: AtomicU64 = AtomicU64::new(0);

/// Live bytes of pre-packed weights across the process (see
/// [`PackedWeights`] and the quantized packed panels in
/// [`crate::quant::qconv::QConvLayer`]).
pub fn packed_weight_bytes() -> u64 {
    PACKED_WEIGHT_BYTES.load(Ordering::Relaxed)
}

/// RAII accounting for pre-packed weight storage: registers the byte
/// count into the process-wide counter and the owning plan's counter,
/// deregisters both on drop.
pub(crate) struct PackedBytesGuard {
    plan: Arc<ConvPlan>,
    bytes: usize,
}

impl PackedBytesGuard {
    pub(crate) fn register(plan: &Arc<ConvPlan>, bytes: usize) -> PackedBytesGuard {
        PACKED_WEIGHT_BYTES.fetch_add(bytes as u64, Ordering::Relaxed);
        plan.packed_bytes.fetch_add(bytes, Ordering::Relaxed);
        PackedBytesGuard { plan: plan.clone(), bytes }
    }
}

impl Drop for PackedBytesGuard {
    fn drop(&mut self) {
        PACKED_WEIGHT_BYTES.fetch_sub(self.bytes as u64, Ordering::Relaxed);
        self.plan.packed_bytes.fetch_sub(self.bytes, Ordering::Relaxed);
    }
}

/// Plan-time pre-packed weights for one conv layer: the weight
/// transform (`G·f·Gᵀ`) and the GEMM panel packing hoisted out of the
/// per-call path, so steady-state [`ConvPlan::run_packed_into`] touches
/// only pre-packed operands. Built once per layer via
/// [`PackedWeights::pack`]; plans stay shape-keyed and shareable — the
/// packed artifact rides with the layer that owns the weights, and its
/// byte cost is visible per plan ([`ConvPlan::packed_bytes`]) and
/// process-wide ([`packed_weight_bytes`]).
pub struct PackedWeights {
    desc: ConvDesc,
    kind: PackedKind,
    _guard: Option<PackedBytesGuard>,
}

enum PackedKind {
    /// pre-transformed + panel-packed weights for a bilinear plan:
    /// per (frequency, group) GEMM B panels, group-major; `tt` pins the
    /// transform-point count (T²) the panels were built for, so panels
    /// cannot silently run under a different bilinear algorithm that
    /// shares the descriptor
    Fast { up: Vec<f32>, oc: usize, icg: usize, tt: usize },
    /// kernels whose weights are already in executor layout (direct,
    /// im2col A-side, FFT/NTT whole-image): use the tensor as-is
    Raw,
}

impl PackedWeights {
    /// Pre-transform and pre-pack `w` for `plan`. For bilinear
    /// (Winograd/SFC) plans this performs the `[T²][OC][IC/g]` weight
    /// transform and packs each (frequency, group) block into the
    /// dispatched GEMM's panel layout; other kernels consume weights
    /// in their natural layout and return a zero-byte passthrough.
    pub fn pack(plan: &Arc<ConvPlan>, w: &Tensor) -> PackedWeights {
        match &plan.kernel {
            PlanKernel::Fast(p) => {
                let (oc, icg, r, _) = w.dims4();
                assert_eq!(r, p.r(), "weight kernel size vs plan");
                assert_eq!(oc, plan.desc.oc, "weight output channels disagree with the plan");
                assert_eq!(
                    icg * plan.desc.groups,
                    plan.desc.ic,
                    "weight grouping disagrees with the plan descriptor"
                );
                let tt = p.t() * p.t();
                let ocg = oc / plan.desc.groups;
                let u = p.transform_weights(&w.data, oc, icg);
                let mut up =
                    vec![0f32; tt * plan.desc.groups * packed_b_f32_len(ocg, icg)];
                pack_fast_weights(&u, oc, icg, plan.desc.groups, tt, &mut up);
                let bytes = up.len() * std::mem::size_of::<f32>();
                PackedWeights {
                    desc: plan.desc,
                    kind: PackedKind::Fast { up, oc, icg, tt },
                    _guard: Some(PackedBytesGuard::register(plan, bytes)),
                }
            }
            _ => PackedWeights { desc: plan.desc, kind: PackedKind::Raw, _guard: None },
        }
    }

    /// Bytes of packed storage this artifact holds (0 for passthrough
    /// kernels).
    pub fn bytes(&self) -> usize {
        match &self.kind {
            PackedKind::Fast { up, .. } => up.len() * std::mem::size_of::<f32>(),
            PackedKind::Raw => 0,
        }
    }
}

impl std::fmt::Debug for PackedWeights {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PackedWeights").field("bytes", &self.bytes()).finish()
    }
}

/// Bytes [`PackedWeights::pack`] would register for this plan, computed
/// *without* building anything — what budget admission checks before
/// deciding whether a layer gets pre-packed. Exact by construction: the
/// same `T²·groups·panel_len(OC/g, IC/g)` sizing the packer allocates.
pub fn packed_bytes_estimate(plan: &ConvPlan) -> usize {
    match &plan.kernel {
        PlanKernel::Fast(p) => {
            let tt = p.t() * p.t();
            let (icg, ocg) = plan.desc.group_channels();
            tt * plan.desc.groups * packed_b_f32_len(ocg, icg) * std::mem::size_of::<f32>()
        }
        _ => 0,
    }
}

/// A byte budget for plan-time packed-weight storage, checked against
/// the process-wide [`packed_weight_bytes`] counter. Layers that don't
/// fit are simply not pre-packed — they fall back to the per-call
/// transform+pack path, which is bit-identical, just slower. A limit of
/// `0` means unlimited (the historical behavior).
#[derive(Clone, Copy, Debug)]
pub struct PackBudget {
    limit_bytes: usize,
}

impl PackBudget {
    /// Budget capped at `limit_bytes` (0 = unlimited).
    pub fn new(limit_bytes: usize) -> PackBudget {
        PackBudget { limit_bytes }
    }

    /// The no-op budget: everything is admitted.
    pub fn unlimited() -> PackBudget {
        PackBudget { limit_bytes: 0 }
    }

    /// The configured cap in bytes (0 = unlimited).
    pub fn limit_bytes(&self) -> usize {
        self.limit_bytes
    }

    /// Would packing `extra` more bytes stay within budget, given
    /// everything already packed process-wide? (A point-in-time check:
    /// admission races only ever over-admit by one layer, and the
    /// registration-time check in `coordinator::sched` backstops it.)
    pub fn try_admit(&self, extra: usize) -> bool {
        self.limit_bytes == 0 || packed_weight_bytes() as usize + extra <= self.limit_bytes
    }
}

impl ConvPlan {
    /// A plan for `desc` executed by `kernel`, produced by `engine`.
    pub fn new(engine: &'static str, desc: ConvDesc, kernel: PlanKernel) -> ConvPlan {
        ConvPlan { engine, desc, kernel, packed_bytes: AtomicUsize::new(0) }
    }

    /// A direct-conv plan for any descriptor (the universal fallback).
    pub fn direct(desc: ConvDesc) -> ConvPlan {
        ConvPlan::new("direct", desc, PlanKernel::Direct)
    }

    /// Live bytes of pre-packed weight artifacts built from this plan
    /// (see [`PackedWeights`]; quantized layers register their packed
    /// panels here too).
    pub fn packed_bytes(&self) -> usize {
        self.packed_bytes.load(Ordering::Relaxed)
    }

    /// The bilinear transform matrices, when this is a Winograd/SFC plan
    /// (the transform-domain quantizer needs them).
    pub fn fast_plan(&self) -> Option<&Arc<FastConvPlan>> {
        match &self.kernel {
            PlanKernel::Fast(p) => Some(p),
            _ => None,
        }
    }

    /// Execute the float path on an NCHW batch. Kernels read the actual
    /// tensor dims; the descriptor supplies stride/pad geometry.
    /// Convenience wrapper over [`ConvPlan::run_with`] with a throwaway
    /// workspace — hot paths should keep a [`Workspace`] alive instead.
    pub fn run(&self, x: &Tensor, w: &Tensor, bias: &[f32]) -> Tensor {
        let mut ws = Workspace::new();
        self.run_with(x, w, bias, &mut ws)
    }

    /// Execute out of a caller workspace, allocating only the output.
    pub fn run_with(&self, x: &Tensor, w: &Tensor, bias: &[f32], ws: &mut Workspace) -> Tensor {
        let mut out = Tensor::zeros(&self.out_dims(x, w));
        self.run_into(x, w, bias, ws, &mut out);
        out
    }

    /// Output shape for an actual input/weight pair (kernels read tensor
    /// dims; the descriptor supplies stride/pad geometry).
    pub fn out_dims(&self, x: &Tensor, w: &Tensor) -> Vec<usize> {
        let (n, _, h, wid) = x.dims4();
        let (oc, _, r, _) = w.dims4();
        let (stride, pad, dilation) = match self.kernel {
            // frequency-domain kernels (whole-image and tiled) are
            // stride-1, undilated by construction
            PlanKernel::Direct | PlanKernel::Im2col => {
                (self.desc.stride, self.desc.pad, self.desc.dilation)
            }
            _ => (1, self.desc.pad, 1),
        };
        let er = (r - 1) * dilation + 1;
        let oh = (h + 2 * pad - er) / stride + 1;
        let ow = (wid + 2 * pad - er) / stride + 1;
        vec![n, oc, oh, ow]
    }

    /// Like [`ConvPlan::run_into`] but with plan-time pre-packed
    /// weights: bilinear (Winograd/SFC) plans skip the per-call weight
    /// transform + panel packing and execute straight over the packed
    /// panels; kernels without a packed form fall through to
    /// [`ConvPlan::run_into`] on the raw tensor. Bit-identical to
    /// [`ConvPlan::run_into`] in all cases (the per-call path packs
    /// into workspace scratch and runs the same core).
    pub fn run_packed_into(
        &self,
        x: &Tensor,
        w: &Tensor,
        packed: &PackedWeights,
        bias: &[f32],
        ws: &mut Workspace,
        out: &mut Tensor,
    ) {
        assert_eq!(
            packed.desc, self.desc,
            "packed weights were built for a different descriptor"
        );
        match (&self.kernel, &packed.kind) {
            (PlanKernel::Fast(p), PackedKind::Fast { up, oc, icg, tt }) => {
                assert_eq!(
                    *tt,
                    p.t() * p.t(),
                    "packed weights were built for a different bilinear algorithm \
                     (transform-point count mismatch)"
                );
                assert_eq!(
                    self.desc.dilation, 1,
                    "bilinear engines decline dilated descriptors via supports()"
                );
                conv2d_fast_packed_into(
                    x,
                    up,
                    *oc,
                    *icg,
                    bias,
                    p,
                    self.desc.pad,
                    self.desc.groups,
                    self.desc.epilogue,
                    ws,
                    out,
                );
            }
            _ => self.run_into(x, w, bias, ws, out),
        }
    }

    /// The zero-alloc entry point: execute out of `ws` straight into
    /// `out` (shape must equal [`ConvPlan::out_dims`]). All kernels
    /// route through here; results are bit-identical to [`ConvPlan::run`]
    /// whether `ws` is fresh or reused across calls and shapes.
    pub fn run_into(
        &self,
        x: &Tensor,
        w: &Tensor,
        bias: &[f32],
        ws: &mut Workspace,
        out: &mut Tensor,
    ) {
        // Only the spatial kernels execute dilation; the fields are
        // public, so re-check before running an undilated kernel on a
        // descriptor someone mutated (engines decline dilated
        // descriptors via supports(), so planned kernels never hit this).
        if !matches!(self.kernel, PlanKernel::Direct | PlanKernel::Im2col) {
            assert_eq!(
                self.desc.dilation, 1,
                "only the direct and im2col kernels execute dilation != 1"
            );
        }
        let ep = self.desc.epilogue;
        match &self.kernel {
            PlanKernel::Direct => conv2d_direct_dilated_into(
                x,
                w,
                bias,
                self.desc.stride,
                self.desc.pad,
                self.desc.groups,
                self.desc.dilation,
                ep,
                out,
            ),
            PlanKernel::Im2col => exec::conv2d_im2col_dilated_into(
                x,
                w,
                bias,
                self.desc.stride,
                self.desc.pad,
                self.desc.groups,
                self.desc.dilation,
                ep,
                ws,
                out,
            ),
            PlanKernel::Fast(p) => {
                conv2d_fast_into(x, w, bias, p, self.desc.pad, self.desc.groups, ep, ws, out)
            }
            // frequency engines (whole-image and tiled) only plan dense
            // stride-1 descriptors
            PlanKernel::Fft => exec::conv2d_fft_into(x, w, bias, self.desc.pad, ep, ws, out),
            PlanKernel::Ntt => exec::conv2d_ntt_int8_into(x, w, bias, self.desc.pad, ep, ws, out),
            PlanKernel::FftTiled { tile } => {
                tiled::conv2d_fft_tiled_into(x, w, bias, self.desc.pad, *tile, ep, ws, out)
            }
            PlanKernel::NttTiled { tile } => {
                tiled::conv2d_ntt_tiled_int8_into(x, w, bias, self.desc.pad, *tile, ep, ws, out)
            }
        }
    }

    /// Scratch bytes one `run_into` call checks out of its workspace for
    /// the planned descriptor (single-image parallelism accounted at the
    /// configured thread count). Intra-op GEMM threads need no extra
    /// accounting: the macro-kernel's workers slice the caller's packed
    /// panels and output rows in place, checking out no scratch of
    /// their own. Callers can pre-warm with [`Workspace::with_capacity`].
    pub fn workspace_bytes(&self) -> usize {
        let d = &self.desc;
        let (oh, ow) = d.out_hw();
        let workers = crate::util::par::num_threads().min(d.batch.max(1));
        // worker-state cap for the per-(image, block) tiled executors:
        // one stealable task per overlap-save block, so up to
        // batch·⌈OH/step⌉·⌈OW/step⌉ states are live (step = S − R + 1)
        let tiled_workers = |tile: usize| {
            let step = (tile + 1).saturating_sub(d.r).max(1);
            let njobs = d.batch.max(1) * oh.div_ceil(step) * ow.div_ceil(step);
            crate::util::par::num_threads().min(njobs).max(1)
        };
        match &self.kernel {
            // direct accumulates in the output planes themselves
            PlanKernel::Direct => 0,
            // one [⌈OH·OW/8⌉·8 × (IC/g)·R·R] packed lowering panel per
            // worker (pixels padded to the GEMM panel width)
            PlanKernel::Im2col => {
                let npix = (oh * ow).div_ceil(PANEL) * PANEL;
                workers * npix * (d.ic / d.groups) * d.r * d.r * 4
            }
            PlanKernel::Fast(p) => {
                let (m, l, t) = (p.m(), p.l(), p.t());
                let tiles = oh.div_ceil(m) * ow.div_ceil(m);
                let tt = t * t;
                let (icg, ocg) = d.group_channels();
                // transformed weights [T²][OC][IC/g] + their packed GEMM
                // panels (the per-call path builds both; run_packed_into
                // needs neither); the V/P blocks cover all groups, so
                // their totals match the dense case. The per-tile
                // transform scratch is lane-batched ×8.
                let shared = tt * d.oc * icg + tt * d.groups * packed_b_f32_len(ocg, icg)
                    + t * d.r
                    + tt;
                let per_worker = tt * tiles * (d.ic + d.oc)
                    + TILE_LANES * (l * l + t * l + 2 * tt + m * t + m * m);
                (shared + workers * per_worker) * 4
            }
            PlanKernel::Fft => {
                let (sh, sw) = padded_pow2(d);
                let s2 = sh * sw;
                let shared = 2 * d.oc * d.ic * s2;
                let per_worker = 2 * d.ic * s2 + 2 * s2 + 2 * sh;
                (shared + workers * per_worker) * 8
            }
            PlanKernel::Ntt => {
                let (sh, sw) = padded_pow2(d);
                let s2 = sh * sw;
                let shared = d.oc * d.ic * s2 + sh; // knt + column scratch
                let per_worker = d.ic * s2 + s2 + sh;
                let quant = d.batch * d.ic * d.h * d.w + d.oc * d.ic * d.r * d.r; // i8
                let acc = d.batch * d.oc * oh * ow; // i64
                (shared + workers * per_worker) * 8 + quant + acc * 8
            }
            // the tiled arms mirror their whole-image twins with the
            // padded power-of-two grid replaced by the fixed tile — the
            // transform scratch no longer grows with the image. They
            // parallelize per (image, block), not per image, so the
            // worker-state count is capped by batch·blocks instead of
            // batch.
            PlanKernel::FftTiled { tile } => {
                let s2 = tile * tile;
                let workers = tiled_workers(*tile);
                let shared = 2 * d.oc * d.ic * s2;
                let per_worker = 2 * d.ic * s2 + 2 * s2 + 2 * tile;
                (shared + workers * per_worker) * 8
            }
            PlanKernel::NttTiled { tile } => {
                let s2 = tile * tile;
                let workers = tiled_workers(*tile);
                let shared = d.oc * d.ic * s2 + tile; // knt + column scratch
                let per_worker = d.ic * s2 + s2 + tile;
                let quant = d.batch * d.ic * d.h * d.w + d.oc * d.ic * d.r * d.r; // i8
                let acc = d.batch * d.oc * oh * ow; // i64
                (shared + workers * per_worker) * 8 + quant + acc * 8
            }
        }
    }
}

impl std::fmt::Debug for ConvPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ConvPlan").field("engine", &self.engine).field("desc", &self.desc).finish()
    }
}

/// One convolution backend. Implementations must be cheap to construct
/// and thread-safe; expensive per-algorithm state (transform matrices) is
/// built lazily and shared.
pub trait ConvEngine: Send + Sync {
    /// Catalog name (also the `plan_named` / CLI handle).
    fn name(&self) -> &'static str;

    /// Can this engine execute the descriptor (shape, stride, quant
    /// scheme) at all?
    fn supports(&self, d: &ConvDesc) -> bool;

    /// Build an execution plan. Contract: only called on descriptors for
    /// which [`ConvEngine::supports`] returns true.
    ///
    /// ```
    /// use sfc::engine::{default_selector, ConvDesc};
    ///
    /// let desc = ConvDesc::new(1, 4, 8, 16, 16, 3, 1, 1);
    /// let sel = default_selector();
    /// // plan through a specific supporting engine...
    /// let engine = sel.candidates(&desc)[0];
    /// let plan = engine.plan(&desc).unwrap();
    /// assert_eq!(plan.desc, desc);
    /// // ...or let the selector choose (and cache) one
    /// assert!(sel.plan(&desc).is_ok());
    /// ```
    fn plan(&self, d: &ConvDesc) -> Result<ConvPlan>;

    /// Scratch bytes the executor checks out of its [`Workspace`] for
    /// one batch. Implementations delegate to
    /// [`ConvPlan::workspace_bytes`] so sizing has one source of truth.
    fn workspace_bytes(&self, d: &ConvDesc) -> usize;

    /// Analytic cost in bit-operations (the §6 BOPs model) for the whole
    /// batch — the heuristic selector ranks engines by this.
    fn cost_model(&self, d: &ConvDesc) -> f64;
}

// ---------------------------------------------------------------------
// Direct
// ---------------------------------------------------------------------

/// Nested-loop spatial convolution; supports every geometry — any
/// stride/pad and any channel grouping including depthwise — plus the
/// spatial int8 quantization scheme. The universal fallback.
pub struct DirectEngine;

impl ConvEngine for DirectEngine {
    fn name(&self) -> &'static str {
        "direct"
    }

    fn supports(&self, d: &ConvDesc) -> bool {
        match d.quant {
            // float direct executes any geometry, dilation included
            None => true,
            // spatial quantization: per-tensor activations × per-channel
            // weights (the implemented Eq.-16 baseline); the quantized
            // spatial executor is undilated
            Some(q) => {
                d.dilation == 1
                    && q.a_gran == Granularity::Tensor
                    && q.w_gran == Granularity::Channel
            }
        }
    }

    fn plan(&self, d: &ConvDesc) -> Result<ConvPlan> {
        Ok(ConvPlan::direct(*d))
    }

    fn workspace_bytes(&self, d: &ConvDesc) -> usize {
        ConvPlan::direct(*d).workspace_bytes() // 0: runs in the output planes
    }

    fn cost_model(&self, d: &ConvDesc) -> f64 {
        let (a, w) = d.odot_bits();
        direct_bops_grouped_dilated(&d.shape(), d.groups as u64, d.dilation as u64, a, w).total()
            as f64
            * d.batch as f64
    }
}

// ---------------------------------------------------------------------
// im2col + GEMM
// ---------------------------------------------------------------------

/// GEMM-lowered convolution. Same arithmetic as direct, better locality;
/// float-only (the spatial quantized path stays on the direct engine).
pub struct Im2colEngine;

impl ConvEngine for Im2colEngine {
    fn name(&self) -> &'static str {
        "im2col-gemm"
    }

    fn supports(&self, d: &ConvDesc) -> bool {
        // float-only; any geometry, dilation included (the lowering
        // simply gathers dilated taps)
        d.quant.is_none()
    }

    fn plan(&self, d: &ConvDesc) -> Result<ConvPlan> {
        Ok(ConvPlan::new(self.name(), *d, PlanKernel::Im2col))
    }

    fn workspace_bytes(&self, d: &ConvDesc) -> usize {
        ConvPlan::new(self.name(), *d, PlanKernel::Im2col).workspace_bytes()
    }

    fn cost_model(&self, d: &ConvDesc) -> f64 {
        // identical MAC count; a fixed GEMM-locality discount makes the
        // heuristic prefer it over nested loops when nothing faster fits
        DirectEngine.cost_model(d) * 0.9
    }
}

// ---------------------------------------------------------------------
// Tiled bilinear (Winograd / SFC)
// ---------------------------------------------------------------------

/// A tiled bilinear fast-convolution engine wrapping one Table-1 row.
/// The exact transform construction runs once (lazily) and is shared by
/// every plan this engine produces.
pub struct BilinearEngine {
    spec: AlgoSpec,
    fast: OnceLock<Arc<FastConvPlan>>,
}

impl BilinearEngine {
    /// Engine wrapping one Winograd/SFC catalog row.
    pub fn new(spec: AlgoSpec) -> BilinearEngine {
        assert!(
            matches!(spec.kind, AlgoKind::Winograd | AlgoKind::Sfc),
            "BilinearEngine wraps Winograd/SFC rows, got {:?}",
            spec.kind
        );
        BilinearEngine { spec, fast: OnceLock::new() }
    }

    fn fast_plan(&self) -> Arc<FastConvPlan> {
        self.fast.get_or_init(|| Arc::new(FastConvPlan::new(self.spec.build()))).clone()
    }
}

impl ConvEngine for BilinearEngine {
    fn name(&self) -> &'static str {
        self.spec.name
    }

    fn supports(&self, d: &ConvDesc) -> bool {
        // any channel grouping: the per-frequency GEMM simply runs one
        // [tiles×IC/g]·[IC/g×OC/g] block per group (depthwise included).
        // Dilation is declined: the bilinear tile algebra (B/A gathers)
        // assumes contiguous taps.
        if d.r != self.spec.r || d.stride != 1 || d.dilation != 1 {
            return false;
        }
        match d.quant {
            None => true,
            // transform-domain quantization (Eq. 17): activation scales
            // are per-tensor or per-frequency; weights any granularity
            Some(q) => matches!(q.a_gran, Granularity::Tensor | Granularity::Freq),
        }
    }

    fn plan(&self, d: &ConvDesc) -> Result<ConvPlan> {
        if !self.supports(d) {
            bail!("{} does not support descriptor {:?}", self.name(), d);
        }
        Ok(ConvPlan::new(self.name(), *d, PlanKernel::Fast(self.fast_plan())))
    }

    fn workspace_bytes(&self, d: &ConvDesc) -> usize {
        ConvPlan::new(self.name(), *d, PlanKernel::Fast(self.fast_plan())).workspace_bytes()
    }

    fn cost_model(&self, d: &ConvDesc) -> f64 {
        let (a, w) = d.odot_bits();
        let p = self.fast_plan();
        fast_bops_grouped(&d.shape(), &p.algo, d.groups as u64, a, w).total() as f64
            * d.batch as f64
    }
}

// ---------------------------------------------------------------------
// FFT
// ---------------------------------------------------------------------

/// Padded spatial size for whole-image FFT/NTT convolution.
fn padded_pow2(d: &ConvDesc) -> (usize, usize) {
    let sh = (d.h + 2 * d.pad + d.r - 1).next_power_of_two();
    let sw = (d.w + 2 * d.pad + d.r - 1).next_power_of_two();
    (sh, sw)
}

/// Keep whole-image frequency-domain kernels bounded: the executors
/// precompute OC×IC transformed filter planes.
const FREQ_KERNEL_ELEMS_MAX: usize = 4_000_000;

/// Whole-image float FFT convolution — the classic related-work baseline.
/// Float-only (irrational twiddles defeat the quantized datapath, §3).
pub struct FftEngine;

impl ConvEngine for FftEngine {
    fn name(&self) -> &'static str {
        "FFT"
    }

    fn supports(&self, d: &ConvDesc) -> bool {
        // dense only: the whole-image kernel planes accumulate over every
        // input channel per output channel (grouped descriptors fall
        // back to the sliced/tiled engines)
        let (sh, sw) = padded_pow2(d);
        d.stride == 1
            && d.groups == 1
            && d.dilation == 1
            && d.quant.is_none()
            && d.oc * d.ic * sh * sw <= FREQ_KERNEL_ELEMS_MAX
    }

    fn plan(&self, d: &ConvDesc) -> Result<ConvPlan> {
        if !self.supports(d) {
            bail!("FFT engine does not support descriptor {:?}", d);
        }
        Ok(ConvPlan::new(self.name(), *d, PlanKernel::Fft))
    }

    fn workspace_bytes(&self, d: &ConvDesc) -> usize {
        ConvPlan::new(self.name(), *d, PlanKernel::Fft).workspace_bytes()
    }

    fn cost_model(&self, d: &ConvDesc) -> f64 {
        let (sh, sw) = padded_pow2(d);
        let s2 = (sh * sw) as f64;
        let lg = s2.log2().max(1.0);
        let b = d.batch as f64;
        let (ic, oc) = (d.ic as f64, d.oc as f64);
        // transforms (input + inverse per image, filters once) + pointwise
        let fft_mults = (b * (ic + oc) + ic * oc) * 2.0 * s2 * lg;
        let pointwise = b * ic * oc * s2 * 3.0; // 3 real mults per complex product
        // ⊙ runs at float width — charge the fp16 proxy like Table 1
        (fft_mults + pointwise) * mul_bops(16) as f64
    }
}

// ---------------------------------------------------------------------
// NTT
// ---------------------------------------------------------------------

/// Whole-image exact integer convolution in F_p. Bit-exact for int8
/// operands, but the ⊙ stage carries full mod-p word width — the paper's
/// §3 criticism, visible directly in this engine's cost model.
pub struct NttEngine;

impl NttEngine {
    /// Output magnitude bound: |y| ≤ qmax²·IC·R² must stay below p/2.
    fn acc_bound_ok(d: &ConvDesc) -> bool {
        d.ic * d.r * d.r <= 16_384
    }
}

impl ConvEngine for NttEngine {
    fn name(&self) -> &'static str {
        "NTT"
    }

    fn supports(&self, d: &ConvDesc) -> bool {
        let (sh, sw) = padded_pow2(d);
        let quant_ok = match d.quant {
            None => true, // float entry runs the int8 fixed-point datapath
            Some(q) => {
                q.a_bits <= 8
                    && q.w_bits <= 8
                    && q.a_gran == Granularity::Tensor
                    && q.w_gran == Granularity::Channel
            }
        };
        // dense only, like the FFT engine: the frequency-domain channel
        // accumulation has no grouped slicing
        d.stride == 1
            && d.groups == 1
            && d.dilation == 1
            && quant_ok
            && Self::acc_bound_ok(d)
            && d.oc * d.ic * sh * sw <= FREQ_KERNEL_ELEMS_MAX
    }

    fn plan(&self, d: &ConvDesc) -> Result<ConvPlan> {
        if !self.supports(d) {
            bail!("NTT engine does not support descriptor {:?}", d);
        }
        Ok(ConvPlan::new(self.name(), *d, PlanKernel::Ntt))
    }

    fn workspace_bytes(&self, d: &ConvDesc) -> usize {
        ConvPlan::new(self.name(), *d, PlanKernel::Ntt).workspace_bytes()
    }

    fn cost_model(&self, d: &ConvDesc) -> f64 {
        let (sh, sw) = padded_pow2(d);
        let s2 = (sh * sw) as f64;
        let lg = s2.log2().max(1.0);
        let b = d.batch as f64;
        let (ic, oc) = (d.ic as f64, d.oc as f64);
        let (a_bits, w_bits) = d.odot_bits();
        // mod-p word width for the ⊙ stage (the §3 point)
        let odot = ntt_odot_bits(a_bits.max(w_bits) as u32, d.ic * d.r * d.r) as u64;
        let transforms = (b * (ic + oc) + ic * oc) * s2 * lg; // butterfly mod-muls
        let pointwise = b * ic * oc * s2;
        (transforms + pointwise) * mul_bops(odot) as f64
    }
}

// ---------------------------------------------------------------------
// Tiled frequency-domain (overlap-save)
// ---------------------------------------------------------------------

/// Per-batch output-block count of the overlap-save grid: each block
/// contributes `tile − r + 1` valid outputs per axis.
fn tiled_block_count(d: &ConvDesc, tile: usize) -> f64 {
    let (oh, ow) = d.out_hw();
    let step = tile - d.r + 1;
    (oh.div_ceil(step) * ow.div_ceil(step)) as f64
}

/// Overlap-save tiled float FFT convolution (cuDNN's `FFT_TILING`
/// split): the whole-image FFT datapath run per overlapping block at a
/// kernel-derived transform length, so workspace stays bounded on
/// images the whole-image engine must decline. Float, stride-1, dense
/// only — same envelope as [`FftEngine`] minus the image-size cap.
pub struct FftTilingEngine;

impl ConvEngine for FftTilingEngine {
    fn name(&self) -> &'static str {
        "FFT-tiled"
    }

    fn supports(&self, d: &ConvDesc) -> bool {
        // the kernel planes are tile-sized, so the cap constrains only
        // channels × kernel-derived tile — never the image
        let tile = tiled::default_tile_len(d.r);
        d.stride == 1
            && d.groups == 1
            && d.dilation == 1
            && d.quant.is_none()
            && d.oc * d.ic * tile * tile <= FREQ_KERNEL_ELEMS_MAX
    }

    fn plan(&self, d: &ConvDesc) -> Result<ConvPlan> {
        if !self.supports(d) {
            bail!("FFT-tiled engine does not support descriptor {:?}", d);
        }
        let tile = tiled::default_tile_len(d.r);
        Ok(ConvPlan::new(self.name(), *d, PlanKernel::FftTiled { tile }))
    }

    fn workspace_bytes(&self, d: &ConvDesc) -> usize {
        let tile = tiled::default_tile_len(d.r);
        ConvPlan::new(self.name(), *d, PlanKernel::FftTiled { tile }).workspace_bytes()
    }

    fn cost_model(&self, d: &ConvDesc) -> f64 {
        let tile = tiled::default_tile_len(d.r);
        let s2 = (tile * tile) as f64;
        let lg = s2.log2().max(1.0);
        let b = d.batch as f64;
        let blocks = tiled_block_count(d, tile);
        let (ic, oc) = (d.ic as f64, d.oc as f64);
        // per-block input + inverse transforms per image; the kernel
        // planes transform once at the tile length
        let fft_mults = (b * blocks * (ic + oc) + ic * oc) * 2.0 * s2 * lg;
        let pointwise = b * blocks * ic * oc * s2 * 3.0;
        (fft_mults + pointwise) * mul_bops(16) as f64
    }
}

/// Overlap-save tiled exact NTT convolution: bit-identical outputs to
/// [`NttEngine`] (both are exact integer arithmetic) with tile-bounded
/// transform workspace. Same quantization envelope as the whole-image
/// engine; the ⊙ stage still carries full mod-p word width — tiling
/// changes the memory story, not the paper's §3 precision criticism.
pub struct NttTilingEngine;

impl ConvEngine for NttTilingEngine {
    fn name(&self) -> &'static str {
        "NTT-tiled"
    }

    fn supports(&self, d: &ConvDesc) -> bool {
        let tile = tiled::default_tile_len(d.r);
        let quant_ok = match d.quant {
            None => true, // float entry runs the int8 fixed-point datapath
            Some(q) => {
                q.a_bits <= 8
                    && q.w_bits <= 8
                    && q.a_gran == Granularity::Tensor
                    && q.w_gran == Granularity::Channel
            }
        };
        d.stride == 1
            && d.groups == 1
            && d.dilation == 1
            && quant_ok
            && NttEngine::acc_bound_ok(d)
            && d.oc * d.ic * tile * tile <= FREQ_KERNEL_ELEMS_MAX
    }

    fn plan(&self, d: &ConvDesc) -> Result<ConvPlan> {
        if !self.supports(d) {
            bail!("NTT-tiled engine does not support descriptor {:?}", d);
        }
        let tile = tiled::default_tile_len(d.r);
        Ok(ConvPlan::new(self.name(), *d, PlanKernel::NttTiled { tile }))
    }

    fn workspace_bytes(&self, d: &ConvDesc) -> usize {
        let tile = tiled::default_tile_len(d.r);
        ConvPlan::new(self.name(), *d, PlanKernel::NttTiled { tile }).workspace_bytes()
    }

    fn cost_model(&self, d: &ConvDesc) -> f64 {
        let tile = tiled::default_tile_len(d.r);
        let s2 = (tile * tile) as f64;
        let lg = s2.log2().max(1.0);
        let b = d.batch as f64;
        let blocks = tiled_block_count(d, tile);
        let (ic, oc) = (d.ic as f64, d.oc as f64);
        let (a_bits, w_bits) = d.odot_bits();
        let odot = ntt_odot_bits(a_bits.max(w_bits) as u32, d.ic * d.r * d.r) as u64;
        let transforms = (b * blocks * (ic + oc) + ic * oc) * s2 * lg;
        let pointwise = b * blocks * ic * oc * s2;
        (transforms + pointwise) * mul_bops(odot) as f64
    }
}

/// The full engine list, seeded from the Table-1 catalog: one universal
/// direct engine, the im2col lowering, one bilinear engine per
/// Winograd/SFC row, and the FFT/NTT engines in both whole-image and
/// overlap-save tiled forms.
pub fn all_engines() -> Vec<Box<dyn ConvEngine>> {
    let mut engines: Vec<Box<dyn ConvEngine>> = vec![Box::new(DirectEngine), Box::new(Im2colEngine)];
    for spec in catalog() {
        match spec.kind {
            AlgoKind::Direct => {} // DirectEngine covers the catalog row
            AlgoKind::Winograd | AlgoKind::Sfc => engines.push(Box::new(BilinearEngine::new(spec))),
            AlgoKind::Fft => {
                engines.push(Box::new(FftEngine));
                engines.push(Box::new(FftTilingEngine));
            }
            AlgoKind::Ntt => {
                engines.push(Box::new(NttEngine));
                engines.push(Box::new(NttTilingEngine));
            }
        }
    }
    engines
}

/// The scenario axes of the ENGINE.md "Engine × scenario support
/// matrix": representative descriptors probing kernel size, stride,
/// channel grouping and quantization scheme.
pub fn support_matrix_scenarios() -> Vec<(&'static str, ConvDesc)> {
    let base = ConvDesc::new(1, 8, 8, 16, 16, 3, 1, 1);
    vec![
        ("3x3 f32", base),
        ("5x5 f32", ConvDesc::new(1, 8, 8, 16, 16, 5, 1, 2)),
        ("7x7 f32", ConvDesc::new(1, 8, 8, 16, 16, 7, 1, 3)),
        ("1x1 f32", ConvDesc::new(1, 8, 8, 16, 16, 1, 1, 0)),
        ("3x3 s2", ConvDesc::new(1, 8, 8, 16, 16, 3, 2, 1)),
        ("3x3 d2", base.with_dilation(2)),
        ("groups=2", base.with_groups(2)),
        ("depthwise", base.with_groups(8)),
        ("int8 transform", base.with_quant(QuantSpec::transform_default(8))),
        ("int8 spatial", base.with_quant(QuantSpec::spatial_default(8))),
    ]
}

/// Render the engine × scenario support matrix as the exact markdown
/// table ENGINE.md embeds. The table is generated from the
/// catalog-seeded [`all_engines`] list and each engine's
/// [`ConvEngine::supports`], and `rust/tests/grouped.rs` asserts
/// ENGINE.md contains it verbatim — so the documentation cannot
/// silently drift from the code.
pub fn support_matrix_markdown() -> String {
    let scenarios = support_matrix_scenarios();
    let mut s = String::from("| engine |");
    for (name, _) in &scenarios {
        s.push_str(&format!(" {name} |"));
    }
    s.push_str("\n|---|");
    for _ in &scenarios {
        s.push_str("---|");
    }
    s.push('\n');
    for e in all_engines() {
        s.push_str(&format!("| {} |", e.name()));
        for (_, d) in &scenarios {
            s.push_str(if e.supports(d) { " ✓ |" } else { " — |" });
        }
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_list_covers_catalog() {
        let engines = all_engines();
        assert!(engines.len() >= 14, "got {}", engines.len());
        let names: Vec<&str> = engines.iter().map(|e| e.name()).collect();
        assert!(names.contains(&"direct"));
        assert!(names.contains(&"im2col-gemm"));
        assert!(names.contains(&"SFC-6(7x7,3x3)"));
        assert!(names.contains(&"Wino(4x4,3x3)"));
        assert!(names.contains(&"FFT"));
        assert!(names.contains(&"FFT-tiled"));
        assert!(names.contains(&"NTT"));
        assert!(names.contains(&"NTT-tiled"));
    }

    #[test]
    fn supports_respects_geometry_and_quant() {
        let engines = all_engines();
        let d33 = ConvDesc::new(1, 8, 8, 16, 16, 3, 1, 1);
        let d11s2 = ConvDesc::new(1, 8, 8, 16, 16, 1, 2, 0);
        let dq = d33.with_quant(QuantSpec::transform_default(8));
        for e in &engines {
            if e.name() == "direct" {
                assert!(e.supports(&d33) && e.supports(&d11s2));
            }
            if e.name() == "SFC-6(7x7,3x3)" {
                assert!(e.supports(&d33) && e.supports(&dq));
                assert!(!e.supports(&d11s2), "fast conv is stride-1 3x3 only");
            }
            if e.name() == "FFT" {
                assert!(e.supports(&d33));
                assert!(!e.supports(&dq), "FFT has no quantized datapath");
            }
        }
    }

    #[test]
    fn grouped_support_envelopes_and_execution() {
        use crate::nn::conv::conv2d_direct_grouped;
        use crate::util::Pcg32;
        let engines = all_engines();
        let g2 = ConvDesc::new(1, 8, 8, 16, 16, 3, 1, 1).with_groups(2);
        let dw = ConvDesc::new(1, 8, 8, 16, 16, 3, 1, 1).with_groups(8);
        for e in &engines {
            match e.name() {
                "direct" | "im2col-gemm" | "SFC-6(7x7,3x3)" | "Wino(4x4,3x3)" => {
                    assert!(e.supports(&g2) && e.supports(&dw), "{}", e.name())
                }
                "FFT" | "NTT" | "FFT-tiled" | "NTT-tiled" => {
                    assert!(!e.supports(&g2) && !e.supports(&dw), "{}", e.name())
                }
                _ => {}
            }
        }
        // grouped plans execute and agree with grouped direct
        let mut rng = Pcg32::seeded(0xD7);
        for d in [g2, dw] {
            let mut x = Tensor::zeros(&[1, d.ic, d.h, d.w]);
            rng.fill_gaussian(&mut x.data, 1.0);
            let mut w = Tensor::zeros(&[d.oc, d.ic / d.groups, d.r, d.r]);
            rng.fill_gaussian(&mut w.data, 0.3);
            let want = conv2d_direct_grouped(&x, &w, &[], 1, 1, d.groups);
            for e in &engines {
                if !e.supports(&d) {
                    continue;
                }
                let y = e.plan(&d).unwrap().run(&x, &w, &[]);
                assert_eq!(y.dims, want.dims, "{} groups {}", e.name(), d.groups);
                assert!(y.mse(&want) < 1e-8, "{} groups {}: {}", e.name(), d.groups, y.mse(&want));
            }
        }
    }

    #[test]
    fn grouped_cost_models_shrink_with_groups() {
        let dense = ConvDesc::new(1, 64, 64, 28, 28, 3, 1, 1);
        let dw = dense.with_groups(64);
        assert!(
            DirectEngine.cost_model(&dw) < DirectEngine.cost_model(&dense) / 32.0,
            "depthwise direct BOPs must collapse"
        );
        let sfc = BilinearEngine::new(crate::algo::registry::by_name("SFC-6(7x7,3x3)").unwrap());
        assert!(sfc.cost_model(&dw) < sfc.cost_model(&dense));
    }

    #[test]
    fn support_matrix_covers_every_engine_and_scenario() {
        let md = support_matrix_markdown();
        let n_engines = all_engines().len();
        let lines: Vec<&str> = md.lines().collect();
        assert_eq!(lines.len(), 2 + n_engines, "header + separator + one row per engine");
        assert!(lines[0].contains("depthwise") && lines[0].contains("int8 transform"));
        assert!(lines[0].contains("3x3 d2"), "dilation scenario present: {}", lines[0]);
        // spot-check rows: direct supports everything except transform int8
        assert!(md.contains("| direct | ✓ | ✓ | ✓ | ✓ | ✓ | ✓ | ✓ | ✓ | — | ✓ |"), "{md}");
        // FFT (whole-image and tiled) is float, stride-1, dense, undilated only
        assert!(md.contains("| FFT | ✓ | ✓ | ✓ | ✓ | — | — | — | — | — | — |"), "{md}");
        assert!(md.contains("| FFT-tiled | ✓ | ✓ | ✓ | ✓ | — | — | — | — | — | — |"), "{md}");
    }

    #[test]
    fn cost_model_prefers_fast_conv_for_3x3() {
        let d = ConvDesc::new(1, 64, 64, 56, 56, 3, 1, 1)
            .with_quant(QuantSpec::transform_default(8));
        let direct = DirectEngine.cost_model(&d);
        let sfc = BilinearEngine::new(
            crate::algo::registry::by_name("SFC-6(7x7,3x3)").unwrap(),
        );
        assert!(sfc.supports(&d));
        assert!(sfc.cost_model(&d) < direct, "SFC must beat direct on BOPs");
        // and the NTT ⊙ width makes it the costliest quantized path
        assert!(NttEngine.cost_model(&d) > sfc.cost_model(&d));
    }

    #[test]
    fn plans_run_and_match_shapes() {
        use crate::util::Pcg32;
        let mut rng = Pcg32::seeded(3);
        let d = ConvDesc::new(1, 2, 3, 10, 10, 3, 1, 1);
        let mut x = Tensor::zeros(&[1, 2, 10, 10]);
        rng.fill_gaussian(&mut x.data, 1.0);
        let mut w = Tensor::zeros(&[3, 2, 3, 3]);
        rng.fill_gaussian(&mut w.data, 0.3);
        for e in all_engines() {
            if !e.supports(&d) {
                continue;
            }
            let plan = e.plan(&d).unwrap();
            let y = plan.run(&x, &w, &[]);
            assert_eq!(y.dims, vec![1, 3, 10, 10], "{}", e.name());
            if e.name() == "direct" {
                assert_eq!(e.workspace_bytes(&d), 0, "direct runs in the output planes");
            } else {
                assert!(e.workspace_bytes(&d) > 0, "{}", e.name());
            }
        }
    }

    #[test]
    fn packed_weights_match_run_into_and_account_bytes() {
        use crate::util::Pcg32;
        let mut rng = Pcg32::seeded(0x51);
        let d = ConvDesc::new(1, 3, 4, 12, 12, 3, 1, 1);
        let mut x = Tensor::zeros(&[1, 3, 12, 12]);
        rng.fill_gaussian(&mut x.data, 1.0);
        let mut w = Tensor::zeros(&[4, 3, 3, 3]);
        rng.fill_gaussian(&mut w.data, 0.3);
        let bias = vec![0.2, -0.1, 0.0, 0.4];
        for e in all_engines() {
            if !e.supports(&d) {
                continue;
            }
            let plan = Arc::new(e.plan(&d).unwrap());
            let want = plan.run(&x, &w, &bias);
            let packed = PackedWeights::pack(&plan, &w);
            let mut ws = Workspace::new();
            let mut out = Tensor::zeros(&plan.out_dims(&x, &w));
            plan.run_packed_into(&x, &w, &packed, &bias, &mut ws, &mut out);
            assert_eq!(out.data, want.data, "{}: packed vs per-call path", e.name());
            // repeat from a warm workspace stays bit-identical + alloc-free
            let warm = ws.heap_allocs();
            out.data.fill(f32::NAN);
            plan.run_packed_into(&x, &w, &packed, &bias, &mut ws, &mut out);
            assert_eq!(out.data, want.data, "{}: warm packed run", e.name());
            assert_eq!(ws.heap_allocs(), warm, "{}: packed steady state allocates", e.name());
            if plan.fast_plan().is_some() {
                assert!(packed.bytes() > 0, "{}: fast plans must pre-pack", e.name());
                assert_eq!(plan.packed_bytes(), packed.bytes(), "{}", e.name());
            } else {
                assert_eq!(packed.bytes(), 0, "{}: passthrough packs nothing", e.name());
            }
            drop(packed);
            assert_eq!(plan.packed_bytes(), 0, "{}: drop must release the bytes", e.name());
        }
    }

    #[test]
    fn run_into_reuses_a_workspace_bit_identically() {
        use crate::util::Pcg32;
        let mut rng = Pcg32::seeded(9);
        let d = ConvDesc::new(1, 3, 4, 12, 12, 3, 1, 1);
        let mut x = Tensor::zeros(&[1, 3, 12, 12]);
        rng.fill_gaussian(&mut x.data, 1.0);
        let mut w = Tensor::zeros(&[4, 3, 3, 3]);
        rng.fill_gaussian(&mut w.data, 0.3);
        for e in all_engines() {
            if !e.supports(&d) {
                continue;
            }
            let plan = e.plan(&d).unwrap();
            let want = plan.run(&x, &w, &[]);
            let mut ws = Workspace::new();
            let mut out = Tensor::zeros(&plan.out_dims(&x, &w));
            plan.run_into(&x, &w, &[], &mut ws, &mut out);
            assert_eq!(out.data, want.data, "{}: fresh workspace", e.name());
            out.data.fill(f32::NAN);
            plan.run_into(&x, &w, &[], &mut ws, &mut out);
            assert_eq!(out.data, want.data, "{}: reused workspace", e.name());
            assert_eq!(ws.in_use_bytes(), 0, "{}: all buffers returned", e.name());
        }
    }
}
