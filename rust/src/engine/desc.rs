//! Convolution problem descriptors — the cuDNN-style "what", decoupled
//! from the "how" (engines) and the "ready-to-run" (plans).
//!
//! A [`ConvDesc`] fully describes one conv layer invocation: tensor
//! shapes, stride/pad geometry and (optionally) the quantization scheme
//! of §5 (bit-widths + scale-group granularity per operand). Descriptors
//! are small, hashable values — they key the [`crate::engine::PlanCache`]
//! and parameterize every engine's `supports`/`plan`/`cost_model`.

use crate::nn::model::ConvShape;
use crate::quant::Granularity;

/// Quantization scheme for a conv (Eq. 17 / Table 4–5 axes): bit-widths
/// and scale-group granularity for weights and activations.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct QuantSpec {
    pub w_bits: u32,
    pub a_bits: u32,
    pub w_gran: Granularity,
    pub a_gran: Granularity,
}

impl QuantSpec {
    /// The paper's SFC/Winograd default: per-frequency activations,
    /// channel×frequency weights.
    pub fn transform_default(bits: u32) -> QuantSpec {
        QuantSpec {
            w_bits: bits,
            a_bits: bits,
            w_gran: Granularity::ChannelFreq,
            a_gran: Granularity::Freq,
        }
    }

    /// The spatial-domain baseline: per-tensor activations, per-channel
    /// weights.
    pub fn spatial_default(bits: u32) -> QuantSpec {
        QuantSpec {
            w_bits: bits,
            a_bits: bits,
            w_gran: Granularity::Channel,
            a_gran: Granularity::Tensor,
        }
    }
}

/// Full description of one 2-D convolution problem (NCHW, square kernel).
///
/// `quant: None` means float execution; `Some(spec)` asks engines for
/// their low-precision path with the given scheme. Shape-identical layers
/// produce equal descriptors, which is what makes plan caching effective
/// across the repeated blocks of ResNet/VGG topologies.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ConvDesc {
    /// batch size the plan is tuned for (kernels accept any batch)
    pub batch: usize,
    pub ic: usize,
    pub oc: usize,
    /// input spatial height/width
    pub h: usize,
    pub w: usize,
    /// square kernel size
    pub r: usize,
    pub stride: usize,
    pub pad: usize,
    pub quant: Option<QuantSpec>,
}

impl ConvDesc {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        batch: usize,
        ic: usize,
        oc: usize,
        h: usize,
        w: usize,
        r: usize,
        stride: usize,
        pad: usize,
    ) -> ConvDesc {
        assert!(stride >= 1, "stride must be >= 1");
        assert!(r >= 1, "kernel must be >= 1");
        assert!(
            h + 2 * pad >= r && w + 2 * pad >= r,
            "kernel {r} exceeds padded input {h}x{w} (pad {pad})"
        );
        ConvDesc { batch, ic, oc, h, w, r, stride, pad, quant: None }
    }

    /// Same problem with a quantization scheme attached.
    pub fn with_quant(mut self, spec: QuantSpec) -> ConvDesc {
        self.quant = Some(spec);
        self
    }

    /// Output spatial size.
    pub fn out_hw(&self) -> (usize, usize) {
        let oh = (self.h + 2 * self.pad - self.r) / self.stride + 1;
        let ow = (self.w + 2 * self.pad - self.r) / self.stride + 1;
        (oh, ow)
    }

    /// Multiply-accumulates for direct execution of the whole batch.
    pub fn macs(&self) -> u64 {
        let (oh, ow) = self.out_hw();
        (self.batch * oh * ow * self.oc * self.ic * self.r * self.r) as u64
    }

    /// The analytical-model shape (BOPs / FPGA layers use this view).
    pub fn shape(&self) -> ConvShape {
        ConvShape {
            ic: self.ic,
            oc: self.oc,
            h: self.h,
            w: self.w,
            r: self.r,
            stride: self.stride,
        }
    }

    /// Descriptor for an analytical [`ConvShape`] (pad chosen "same"-style).
    pub fn from_shape(s: &ConvShape, batch: usize) -> ConvDesc {
        ConvDesc::new(batch, s.ic, s.oc, s.h, s.w, s.r, s.stride, s.r / 2)
    }

    /// Effective ⊙ bit-widths for cost models: the quant scheme's, or a
    /// 16-bit float proxy (Table 1's fp16 ⊙ baseline).
    pub fn odot_bits(&self) -> (u64, u64) {
        match self.quant {
            Some(q) => (q.a_bits as u64, q.w_bits as u64),
            None => (16, 16),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    #[test]
    fn out_hw_matches_conv_arithmetic() {
        let d = ConvDesc::new(1, 3, 16, 32, 32, 3, 1, 1);
        assert_eq!(d.out_hw(), (32, 32));
        let d = ConvDesc::new(1, 16, 32, 32, 32, 3, 2, 1);
        assert_eq!(d.out_hw(), (16, 16));
        let d = ConvDesc::new(1, 16, 32, 32, 32, 1, 2, 0);
        assert_eq!(d.out_hw(), (16, 16));
    }

    #[test]
    fn descriptor_is_a_usable_map_key() {
        let a = ConvDesc::new(1, 3, 16, 32, 32, 3, 1, 1);
        let b = a;
        let c = a.with_quant(QuantSpec::transform_default(8));
        let mut m: HashMap<ConvDesc, u32> = HashMap::new();
        m.insert(a, 1);
        m.insert(c, 2);
        assert_eq!(m[&b], 1);
        assert_eq!(m[&c], 2);
        assert_ne!(a, c);
    }

    #[test]
    fn macs_counts_batch() {
        let d1 = ConvDesc::new(1, 4, 4, 8, 8, 3, 1, 1);
        let d2 = ConvDesc::new(2, 4, 4, 8, 8, 3, 1, 1);
        assert_eq!(d1.macs() * 2, d2.macs());
    }
}
