//! Convolution problem descriptors — the cuDNN-style "what", decoupled
//! from the "how" (engines) and the "ready-to-run" (plans).
//!
//! A [`ConvDesc`] fully describes one conv layer invocation: tensor
//! shapes, stride/pad geometry, channel grouping (dense, grouped or
//! depthwise) and (optionally) the quantization scheme of §5
//! (bit-widths + scale-group granularity per operand). Descriptors are
//! small, hashable values — they key the [`crate::engine::PlanCache`]
//! and parameterize every engine's `supports`/`plan`/`cost_model`.
//! Descriptors with many axes are assembled with [`ConvDescBuilder`]
//! ([`ConvDesc::builder`]) instead of ever-growing positional argument
//! lists.

use crate::nn::model::ConvShape;
use crate::quant::Granularity;

/// A fused output epilogue applied inside the executors' scatter/output
/// loops (the graph compiler's conv+bias+ReLU fusion), instead of as a
/// separate full pass over the activation tensor. Part of [`ConvDesc`]
/// — and therefore of the plan-cache key — so fused and unfused plans
/// for one geometry never collide.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Epilogue {
    /// no epilogue: the executor writes `y + bias` as-is
    #[default]
    None,
    /// clamp negatives at output-write time: `max(0, y + bias)`,
    /// bit-identical to a separate ReLU pass over the unfused output
    Relu,
}

impl Epilogue {
    /// Apply the epilogue to one output value. The ReLU arm uses the
    /// same `v < 0.0` comparison as the graph's standalone ReLU kernel,
    /// so fused and unfused results agree to the bit (including the
    /// `-0.0` corner, which both leave untouched).
    #[inline]
    pub fn apply(self, v: f32) -> f32 {
        match self {
            Epilogue::None => v,
            Epilogue::Relu => {
                if v < 0.0 {
                    0.0
                } else {
                    v
                }
            }
        }
    }

    /// Stable lower-case name for graph dumps and annotations.
    pub fn name(&self) -> &'static str {
        match self {
            Epilogue::None => "-",
            Epilogue::Relu => "relu",
        }
    }
}

/// Quantization scheme for a conv (Eq. 17 / Table 4–5 axes): bit-widths
/// and scale-group granularity for weights and activations.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct QuantSpec {
    /// weight bit-width
    pub w_bits: u32,
    /// activation bit-width
    pub a_bits: u32,
    /// weight scale-group granularity
    pub w_gran: Granularity,
    /// activation scale-group granularity
    pub a_gran: Granularity,
}

impl QuantSpec {
    /// The paper's SFC/Winograd default: per-frequency activations,
    /// channel×frequency weights.
    pub fn transform_default(bits: u32) -> QuantSpec {
        QuantSpec {
            w_bits: bits,
            a_bits: bits,
            w_gran: Granularity::ChannelFreq,
            a_gran: Granularity::Freq,
        }
    }

    /// The spatial-domain baseline: per-tensor activations, per-channel
    /// weights.
    pub fn spatial_default(bits: u32) -> QuantSpec {
        QuantSpec {
            w_bits: bits,
            a_bits: bits,
            w_gran: Granularity::Channel,
            a_gran: Granularity::Tensor,
        }
    }
}

/// Full description of one 2-D convolution problem (NCHW, square kernel).
///
/// `quant: None` means float execution; `Some(spec)` asks engines for
/// their low-precision path with the given scheme. `groups` splits the
/// channel axes into independent convolutions (`groups == ic` is the
/// depthwise case); weight tensors for a grouped descriptor are
/// `[OC, IC/groups, R, R]`. Shape-identical layers produce equal
/// descriptors, which is what makes plan caching effective across the
/// repeated blocks of ResNet/VGG/MobileNet topologies.
///
/// ```
/// use sfc::engine::ConvDesc;
///
/// // dense 3×3 stride-1: 32×32 input stays 32×32 under pad 1
/// let d = ConvDesc::new(1, 16, 32, 32, 32, 3, 1, 1);
/// assert_eq!(d.out_hw(), (32, 32));
///
/// // a depthwise variant of the same geometry, via the builder
/// let dw = ConvDesc::builder(16, 16).hw(32).kernel(3).pad(1).groups(16).build();
/// assert_eq!(dw.group_channels(), (1, 1));
/// assert_eq!(dw.macs(), d.macs() / 16 / 2); // ⁄16 channels, ⁄2 oc
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ConvDesc {
    /// batch size the plan is tuned for (kernels accept any batch)
    pub batch: usize,
    /// input channels (the full tensor's channel count, all groups)
    pub ic: usize,
    /// output channels (the full tensor's channel count, all groups)
    pub oc: usize,
    /// input spatial height
    pub h: usize,
    /// input spatial width
    pub w: usize,
    /// square kernel size
    pub r: usize,
    /// spatial stride
    pub stride: usize,
    /// symmetric zero padding
    pub pad: usize,
    /// channel groups: 1 = dense, `ic` = depthwise; must divide `ic`
    /// and `oc`
    pub groups: usize,
    /// kernel dilation: tap `k` of the kernel reads input offset
    /// `k * dilation`, so the receptive field grows to
    /// [`ConvDesc::effective_r`] without adding weights. Direct and
    /// im2col execute any dilation (dense/grouped/depthwise); transform
    /// engines decline `dilation != 1` via `supports()` (the bilinear /
    /// frequency-domain tile algebra assumes contiguous taps). Part of
    /// the hash, so dilated and undilated plans never collide in the
    /// cache.
    pub dilation: usize,
    /// fused output epilogue applied at output-write time (set by the
    /// graph compiler's conv+ReLU fusion pass; every engine supports it)
    pub epilogue: Epilogue,
    /// quantization scheme (`None` = float execution)
    pub quant: Option<QuantSpec>,
}

impl ConvDesc {
    /// A dense (groups = 1) float descriptor. Descriptors with more
    /// axes (groups, quantization) are assembled with
    /// [`ConvDesc::builder`] or the `with_*` combinators.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        batch: usize,
        ic: usize,
        oc: usize,
        h: usize,
        w: usize,
        r: usize,
        stride: usize,
        pad: usize,
    ) -> ConvDesc {
        let d = ConvDesc {
            batch,
            ic,
            oc,
            h,
            w,
            r,
            stride,
            pad,
            groups: 1,
            dilation: 1,
            epilogue: Epilogue::None,
            quant: None,
        };
        d.validate();
        d
    }

    /// Start a [`ConvDescBuilder`] for the given channel counts.
    pub fn builder(ic: usize, oc: usize) -> ConvDescBuilder {
        ConvDescBuilder::new(ic, oc)
    }

    /// Panic unless the descriptor is internally consistent (divisible
    /// groups, effective kernel within the padded input, dilation ≥ 1).
    fn validate(&self) {
        assert!(self.stride >= 1, "stride must be >= 1");
        assert!(self.r >= 1, "kernel must be >= 1");
        assert!(self.dilation >= 1, "dilation must be >= 1");
        let er = self.effective_r();
        assert!(
            self.h + 2 * self.pad >= er && self.w + 2 * self.pad >= er,
            "effective kernel {} ({}x d{}) exceeds padded input {}x{} (pad {})",
            er,
            self.r,
            self.dilation,
            self.h,
            self.w,
            self.pad
        );
        assert!(self.groups >= 1, "groups must be >= 1");
        assert!(
            self.ic % self.groups == 0 && self.oc % self.groups == 0,
            "groups {} must divide ic {} and oc {}",
            self.groups,
            self.ic,
            self.oc
        );
    }

    /// Receptive-field extent of the dilated kernel along one axis:
    /// `(r − 1) · dilation + 1`. Equals `r` at dilation 1; every output
    /// arithmetic formula (`out_hw`, padding fit, halo sizing) uses
    /// this, not the raw tap count.
    pub fn effective_r(&self) -> usize {
        (self.r - 1) * self.dilation + 1
    }

    /// Same problem with a quantization scheme attached.
    pub fn with_quant(mut self, spec: QuantSpec) -> ConvDesc {
        self.quant = Some(spec);
        self
    }

    /// Same problem with a fused output epilogue (the graph compiler's
    /// conv+ReLU fusion attaches [`Epilogue::Relu`] here; the epilogue
    /// participates in the plan-cache key).
    pub fn with_epilogue(mut self, ep: Epilogue) -> ConvDesc {
        self.epilogue = ep;
        self
    }

    /// Same problem with a channel grouping (`groups == ic` =
    /// depthwise). Panics unless `groups` divides both channel counts.
    pub fn with_groups(mut self, groups: usize) -> ConvDesc {
        self.groups = groups;
        self.validate();
        self
    }

    /// Same problem with a kernel dilation. Panics if the dilated
    /// receptive field no longer fits the padded input.
    pub fn with_dilation(mut self, dilation: usize) -> ConvDesc {
        self.dilation = dilation;
        self.validate();
        self
    }

    /// Per-group channel counts `(ic/groups, oc/groups)` — the GEMM
    /// block shape of grouped execution.
    pub fn group_channels(&self) -> (usize, usize) {
        (self.ic / self.groups, self.oc / self.groups)
    }

    /// Output spatial size (standard conv arithmetic over the
    /// *effective* — i.e. dilated — kernel extent).
    pub fn out_hw(&self) -> (usize, usize) {
        let er = self.effective_r();
        let oh = (self.h + 2 * self.pad - er) / self.stride + 1;
        let ow = (self.w + 2 * self.pad - er) / self.stride + 1;
        (oh, ow)
    }

    /// Multiply-accumulates for direct execution of the whole batch
    /// (each output channel only reduces over its group's `ic/groups`
    /// input channels).
    pub fn macs(&self) -> u64 {
        let (oh, ow) = self.out_hw();
        (self.batch * oh * ow * self.oc * (self.ic / self.groups) * self.r * self.r) as u64
    }

    /// The analytical-model shape (BOPs / FPGA layers use this dense
    /// view; grouped cost models additionally divide by `groups`).
    pub fn shape(&self) -> ConvShape {
        ConvShape {
            ic: self.ic,
            oc: self.oc,
            h: self.h,
            w: self.w,
            r: self.r,
            stride: self.stride,
        }
    }

    /// Descriptor for an analytical [`ConvShape`] (pad chosen "same"-style).
    pub fn from_shape(s: &ConvShape, batch: usize) -> ConvDesc {
        ConvDesc::new(batch, s.ic, s.oc, s.h, s.w, s.r, s.stride, s.r / 2)
    }

    /// Effective ⊙ bit-widths for cost models: the quant scheme's, or a
    /// 16-bit float proxy (Table 1's fp16 ⊙ baseline).
    pub fn odot_bits(&self) -> (u64, u64) {
        match self.quant {
            Some(q) => (q.a_bits as u64, q.w_bits as u64),
            None => (16, 16),
        }
    }
}

/// Fluent construction for [`ConvDesc`] — the growth path for new
/// descriptor axes (`groups` and `dilation` today) without making
/// [`ConvDesc::new`]'s positional argument list any worse.
///
/// Defaults: batch 1, 3×3 kernel, stride 1, pad 0, dense (groups 1),
/// dilation 1, float. The spatial size has no default — call
/// [`ConvDescBuilder::hw`] (or [`ConvDescBuilder::hw2`]) before
/// [`ConvDescBuilder::build`].
///
/// ```
/// use sfc::engine::{ConvDesc, QuantSpec};
///
/// let d = ConvDesc::builder(32, 64)
///     .batch(8)
///     .hw(28)
///     .kernel(3)
///     .pad(1)
///     .groups(4)
///     .quant(QuantSpec::transform_default(8))
///     .build();
/// assert_eq!((d.ic, d.oc, d.groups), (32, 64, 4));
/// assert_eq!(d.out_hw(), (28, 28));
/// ```
#[derive(Clone, Copy, Debug)]
pub struct ConvDescBuilder {
    batch: usize,
    ic: usize,
    oc: usize,
    h: usize,
    w: usize,
    r: usize,
    stride: usize,
    pad: usize,
    groups: usize,
    dilation: usize,
    epilogue: Epilogue,
    quant: Option<QuantSpec>,
}

impl ConvDescBuilder {
    /// Builder for an `ic → oc` convolution (see type-level docs for
    /// the defaults).
    pub fn new(ic: usize, oc: usize) -> ConvDescBuilder {
        ConvDescBuilder {
            batch: 1,
            ic,
            oc,
            h: 0,
            w: 0,
            r: 3,
            stride: 1,
            pad: 0,
            groups: 1,
            dilation: 1,
            epilogue: Epilogue::None,
            quant: None,
        }
    }

    /// Batch size the plan is tuned for.
    pub fn batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }

    /// Square input spatial size.
    pub fn hw(self, hw: usize) -> Self {
        self.hw2(hw, hw)
    }

    /// Rectangular input spatial size.
    pub fn hw2(mut self, h: usize, w: usize) -> Self {
        self.h = h;
        self.w = w;
        self
    }

    /// Square kernel size.
    pub fn kernel(mut self, r: usize) -> Self {
        self.r = r;
        self
    }

    /// Spatial stride.
    pub fn stride(mut self, stride: usize) -> Self {
        self.stride = stride;
        self
    }

    /// Symmetric zero padding.
    pub fn pad(mut self, pad: usize) -> Self {
        self.pad = pad;
        self
    }

    /// Channel groups (`ic` = depthwise).
    pub fn groups(mut self, groups: usize) -> Self {
        self.groups = groups;
        self
    }

    /// Kernel dilation (1 = ordinary dense taps).
    pub fn dilation(mut self, dilation: usize) -> Self {
        self.dilation = dilation;
        self
    }

    /// Attach a quantization scheme.
    pub fn quant(mut self, spec: QuantSpec) -> Self {
        self.quant = Some(spec);
        self
    }

    /// Attach a fused output epilogue.
    pub fn epilogue(mut self, ep: Epilogue) -> Self {
        self.epilogue = ep;
        self
    }

    /// Finish: validates the assembled descriptor (panics on
    /// inconsistent geometry, e.g. a missing `hw` or indivisible
    /// groups).
    pub fn build(self) -> ConvDesc {
        assert!(self.h > 0 && self.w > 0, "ConvDescBuilder: set the spatial size with .hw(..)");
        let d = ConvDesc {
            batch: self.batch,
            ic: self.ic,
            oc: self.oc,
            h: self.h,
            w: self.w,
            r: self.r,
            stride: self.stride,
            pad: self.pad,
            groups: self.groups,
            dilation: self.dilation,
            epilogue: self.epilogue,
            quant: self.quant,
        };
        d.validate();
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    #[test]
    fn out_hw_matches_conv_arithmetic() {
        let d = ConvDesc::new(1, 3, 16, 32, 32, 3, 1, 1);
        assert_eq!(d.out_hw(), (32, 32));
        let d = ConvDesc::new(1, 16, 32, 32, 32, 3, 2, 1);
        assert_eq!(d.out_hw(), (16, 16));
        let d = ConvDesc::new(1, 16, 32, 32, 32, 1, 2, 0);
        assert_eq!(d.out_hw(), (16, 16));
    }

    #[test]
    fn descriptor_is_a_usable_map_key() {
        let a = ConvDesc::new(1, 3, 16, 32, 32, 3, 1, 1);
        let b = a;
        let c = a.with_quant(QuantSpec::transform_default(8));
        let mut m: HashMap<ConvDesc, u32> = HashMap::new();
        m.insert(a, 1);
        m.insert(c, 2);
        assert_eq!(m[&b], 1);
        assert_eq!(m[&c], 2);
        assert_ne!(a, c);
    }

    #[test]
    fn macs_counts_batch() {
        let d1 = ConvDesc::new(1, 4, 4, 8, 8, 3, 1, 1);
        let d2 = ConvDesc::new(2, 4, 4, 8, 8, 3, 1, 1);
        assert_eq!(d1.macs() * 2, d2.macs());
    }

    #[test]
    fn groups_shrink_macs_and_distinguish_descriptors() {
        let dense = ConvDesc::new(1, 8, 8, 16, 16, 3, 1, 1);
        let g2 = dense.with_groups(2);
        let dw = dense.with_groups(8);
        assert_eq!(dense.macs(), 2 * g2.macs());
        assert_eq!(dense.macs(), 8 * dw.macs());
        assert_eq!(dw.group_channels(), (1, 1));
        assert_ne!(dense, g2);
        assert_ne!(g2, dw);
        let mut m: HashMap<ConvDesc, u32> = HashMap::new();
        m.insert(dense, 0);
        m.insert(g2, 1);
        m.insert(dw, 2);
        assert_eq!(m.len(), 3, "groups must participate in the cache key");
    }

    #[test]
    fn builder_round_trips_new() {
        let a = ConvDesc::new(2, 16, 32, 28, 28, 3, 2, 1);
        let b = ConvDesc::builder(16, 32).batch(2).hw(28).kernel(3).stride(2).pad(1).build();
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "must divide")]
    fn indivisible_groups_panic() {
        let _ = ConvDesc::new(1, 6, 8, 16, 16, 3, 1, 1).with_groups(4);
    }

    #[test]
    fn epilogue_distinguishes_descriptors_and_applies_relu() {
        let a = ConvDesc::new(1, 3, 16, 32, 32, 3, 1, 1);
        let b = a.with_epilogue(Epilogue::Relu);
        assert_ne!(a, b, "epilogue must participate in the cache key");
        let mut m: HashMap<ConvDesc, u32> = HashMap::new();
        m.insert(a, 1);
        m.insert(b, 2);
        assert_eq!(m.len(), 2);
        assert_eq!(Epilogue::Relu.apply(-3.0), 0.0);
        assert_eq!(Epilogue::Relu.apply(2.5), 2.5);
        assert_eq!(Epilogue::None.apply(-3.0), -3.0);
        // the -0.0 corner: the standalone ReLU kernel's `v < 0.0` test
        // leaves -0.0 untouched; the fused epilogue must match bitwise
        assert_eq!(Epilogue::Relu.apply(-0.0).to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn dilation_drives_effective_r_and_out_hw() {
        // 3×3 d2 spans 5 pixels: pad 2 keeps the "same"-conv size
        let d = ConvDesc::new(1, 3, 16, 32, 32, 3, 1, 2).with_dilation(2);
        assert_eq!(d.effective_r(), 5);
        assert_eq!(d.out_hw(), (32, 32));
        // d1 is plain conv arithmetic
        assert_eq!(d.with_dilation(1).out_hw(), (34, 34));
        // dilated + strided: (32 + 2·2 − 5)/2 + 1 = 16
        let s2 = ConvDesc::builder(3, 16).hw(32).kernel(3).stride(2).pad(2).dilation(2).build();
        assert_eq!(s2.out_hw(), (16, 16));
        // 1×1 kernels are dilation-invariant
        let p = ConvDesc::builder(3, 16).hw(32).kernel(1).dilation(4).build();
        assert_eq!(p.effective_r(), 1);
        assert_eq!(p.out_hw(), (32, 32));
    }

    #[test]
    fn dilation_distinguishes_descriptors() {
        let a = ConvDesc::new(1, 3, 16, 32, 32, 3, 1, 2);
        let b = a.with_dilation(2);
        assert_ne!(a, b, "dilation must participate in the cache key");
        let mut m: HashMap<ConvDesc, u32> = HashMap::new();
        m.insert(a, 1);
        m.insert(b, 2);
        assert_eq!(m.len(), 2);
    }

    #[test]
    #[should_panic(expected = "effective kernel")]
    fn oversized_dilation_panics() {
        // 3×3 d8 spans 17 > 8 + 2·0
        let _ = ConvDesc::new(1, 3, 16, 8, 8, 3, 1, 0).with_dilation(8);
    }
}
