//! Shape-keyed plan cache (cuDNN-execution-plan style).
//!
//! Planning is cheap for direct conv but real work for the bilinear
//! engines (exact rational transform construction + f32 lowering) and for
//! autotune selection (micro-benchmarks). Serving traffic re-creates
//! models and quantizers with identical layer shapes constantly, so plans
//! are cached behind an interior-mutable map shared via `Arc`. Hit/miss
//! counters are mirrored into [`crate::coordinator::metrics`] so the
//! serving layer reports them alongside latency stats.

use super::desc::ConvDesc;
use super::ConvPlan;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Cache key: the problem descriptor plus the selection mode that
/// produced the plan (an engine name for pinned plans, or a policy tag
/// like "heuristic"/"autotune" — the two policies may legitimately pick
/// different engines for one descriptor).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    /// the convolution problem the plan solves
    pub desc: ConvDesc,
    /// selection mode that produced the plan (engine name or policy tag)
    pub mode: String,
}

impl PlanKey {
    /// Key for `desc` planned under `mode`.
    pub fn new(desc: ConvDesc, mode: &str) -> PlanKey {
        PlanKey { desc, mode: mode.to_string() }
    }
}

/// Interior-mutable, thread-safe plan cache.
pub struct PlanCache {
    map: Mutex<HashMap<PlanKey, Arc<ConvPlan>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl PlanCache {
    /// An empty cache.
    pub fn new() -> PlanCache {
        PlanCache { map: Mutex::new(HashMap::new()), hits: AtomicU64::new(0), misses: AtomicU64::new(0) }
    }

    /// Look up `key`, building and inserting on miss. The build runs
    /// under the cache lock, so concurrent requests for one shape plan it
    /// exactly once (the others wait and then hit).
    pub fn get_or_try_insert<F>(&self, key: PlanKey, build: F) -> Result<Arc<ConvPlan>>
    where
        F: FnOnce() -> Result<Arc<ConvPlan>>,
    {
        let mut map = self.map.lock().unwrap();
        if let Some(p) = map.get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            crate::coordinator::metrics::record_plan_cache(true);
            return Ok(p.clone());
        }
        let plan = build()?;
        map.insert(key, plan.clone());
        self.misses.fetch_add(1, Ordering::Relaxed);
        crate::coordinator::metrics::record_plan_cache(false);
        Ok(plan)
    }

    /// Lookups served from the cache.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Lookups that had to build a plan.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Number of cached plans.
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    /// Live bytes of pre-packed weight artifacts built from the cached
    /// plans ([`ConvPlan::packed_bytes`] summed over every entry) — the
    /// memory cost of plan-time weight pre-packing, reported by
    /// `sfc serve` next to the hit/miss counters.
    pub fn packed_weight_bytes(&self) -> usize {
        self.map.lock().unwrap().values().map(|p| p.packed_bytes()).sum()
    }

    /// True when no plans are cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every cached plan (counters are kept).
    pub fn clear(&self) {
        self.map.lock().unwrap().clear();
    }
}

impl Default for PlanCache {
    fn default() -> Self {
        PlanCache::new()
    }
}

/// The process-wide cache used by the default selector (and anything
/// else that doesn't need isolation).
pub fn global() -> Arc<PlanCache> {
    static GLOBAL: OnceLock<Arc<PlanCache>> = OnceLock::new();
    GLOBAL.get_or_init(|| Arc::new(PlanCache::new())).clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn desc(h: usize) -> ConvDesc {
        ConvDesc::new(1, 3, 8, h, h, 3, 1, 1)
    }

    #[test]
    fn hit_and_miss_counting() {
        let cache = PlanCache::new();
        let build = |d: ConvDesc| move || Ok(Arc::new(ConvPlan::direct(d)));
        let p1 = cache.get_or_try_insert(PlanKey::new(desc(8), "direct"), build(desc(8))).unwrap();
        assert_eq!((cache.hits(), cache.misses()), (0, 1));
        let p2 = cache.get_or_try_insert(PlanKey::new(desc(8), "direct"), build(desc(8))).unwrap();
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
        assert!(Arc::ptr_eq(&p1, &p2));
        cache.get_or_try_insert(PlanKey::new(desc(16), "direct"), build(desc(16))).unwrap();
        assert_eq!((cache.hits(), cache.misses()), (1, 2));
        // same desc, different mode = different entry
        cache.get_or_try_insert(PlanKey::new(desc(8), "heuristic"), build(desc(8))).unwrap();
        assert_eq!((cache.hits(), cache.misses()), (1, 3));
        assert_eq!(cache.len(), 3);
        cache.clear();
        assert!(cache.is_empty());
    }

    #[test]
    fn packed_weight_bytes_sum_over_cached_plans() {
        use crate::engine::{PackedWeights, Selector};
        use crate::nn::tensor::Tensor;
        use crate::util::Pcg32;
        let cache = Arc::new(PlanCache::new());
        let sel = Selector::with_cache(crate::engine::Policy::Heuristic, cache.clone());
        let d = ConvDesc::new(1, 4, 4, 12, 12, 3, 1, 1);
        let plan = sel.plan_named("SFC-6(6x6,3x3)", &d).unwrap();
        assert_eq!(cache.packed_weight_bytes(), 0, "nothing packed yet");
        let mut w = Tensor::zeros(&[4, 4, 3, 3]);
        Pcg32::seeded(1).fill_gaussian(&mut w.data, 0.3);
        let p1 = PackedWeights::pack(&plan, &w);
        let p2 = PackedWeights::pack(&plan, &w);
        assert_eq!(cache.packed_weight_bytes(), p1.bytes() + p2.bytes());
        drop(p1);
        assert_eq!(cache.packed_weight_bytes(), p2.bytes());
        drop(p2);
        assert_eq!(cache.packed_weight_bytes(), 0, "drops release the accounted bytes");
    }

    #[test]
    fn build_error_is_not_cached() {
        let cache = PlanCache::new();
        let err = cache.get_or_try_insert(PlanKey::new(desc(8), "x"), || {
            anyhow::bail!("no engine")
        });
        assert!(err.is_err());
        assert_eq!(cache.len(), 0);
        // a later successful build still works
        cache
            .get_or_try_insert(PlanKey::new(desc(8), "x"), || Ok(Arc::new(ConvPlan::direct(desc(8)))))
            .unwrap();
        assert_eq!(cache.len(), 1);
    }
}
