//! FPGA accelerator model (Table 3's substitute — DESIGN.md §2).
//!
//! Models a fully-pipelined fast-convolution datapath at the paper's
//! design point: parallelism [P_ic × P_oc × tile], 200 MHz, int8.
//! Resources follow DSP48E packing rules (one DSP = two int8 multipliers
//! or one int16 multiplier) and a LUT cost model for the ±1/0 SFT adder
//! networks; throughput comes from a cycle-level pipeline simulation of a
//! conv stack (VGG-16 by default), counting effective GOPs (2·MACs of the
//! *equivalent direct* convolution, the convention all four compared
//! papers use).

pub mod pipeline;

use crate::algo::Bilinear;
use crate::nn::model::ConvShape;

/// Arithmetic style of the accelerator datapath.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Datapath {
    /// direct convolution MAC array
    Direct {
        /// MAC operand width
        bits: u32,
    },
    /// Winograd-style bilinear with `mul_bits` multipliers
    Bilinear {
        /// ⊙ multiplier width
        mul_bits: u32,
    },
    /// NTT butterflies + pointwise mod-p multipliers (high width)
    Ntt {
        /// mod-p word width of the ⊙ multipliers
        word_bits: u32,
    },
}

/// One accelerator configuration (a Table-3 column).
#[derive(Clone, Debug)]
pub struct Accel {
    /// design label (Table-3 row name)
    pub name: String,
    /// arithmetic style of the datapath
    pub datapath: Datapath,
    /// input-channel parallelism
    pub p_ic: usize,
    /// output-channel parallelism
    pub p_oc: usize,
    /// multiplications per (ic, oc) tile-pair per cycle-group:
    /// T² for bilinear, M²·R² for direct, FFT-size for NTT
    pub tile_mults: usize,
    /// output pixels produced per tile per (ic-group completion)
    pub tile_outputs: usize,
    /// equivalent-direct MACs represented by one tile
    pub tile_eq_macs: usize,
    /// adds per input tile for the transforms (per channel)
    pub transform_adds: usize,
    /// design clock in MHz
    pub clock_mhz: f64,
}

/// Resource report (Table 3 rows).
#[derive(Clone, Debug)]
pub struct Resources {
    /// DSP blocks consumed
    pub dsps: u64,
    /// thousands of LUTs consumed
    pub luts_k: f64,
}

impl Accel {
    /// SFC/Winograd accelerator from a bilinear algorithm.
    pub fn from_bilinear(name: &str, algo: &Bilinear, p_ic: usize, p_oc: usize, mul_bits: u32) -> Accel {
        let (bt_adds, _, at_adds) = algo.transform_adds_2d();
        Accel {
            name: name.into(),
            datapath: Datapath::Bilinear { mul_bits },
            p_ic,
            p_oc,
            tile_mults: algo.mults_2d(),
            tile_outputs: algo.m * algo.m,
            tile_eq_macs: algo.m * algo.m * algo.r * algo.r,
            transform_adds: bt_adds + at_adds,
            clock_mhz: 200.0,
        }
    }

    /// Direct int8 MAC-array accelerator producing m×m outputs per tile.
    pub fn direct(name: &str, m: usize, r: usize, p_ic: usize, p_oc: usize, bits: u32) -> Accel {
        Accel {
            name: name.into(),
            datapath: Datapath::Direct { bits },
            p_ic,
            p_oc,
            tile_mults: m * m * r * r,
            tile_outputs: m * m,
            tile_eq_macs: m * m * r * r,
            transform_adds: 0,
            clock_mhz: 200.0,
        }
    }

    /// NTT accelerator: FFT-length L tile computing (L−R+1)² valid outputs
    /// with L² pointwise high-width multiplies (butterflies in LUTs/DSP mix).
    pub fn ntt(name: &str, l: usize, r: usize, p_ic: usize, p_oc: usize, word_bits: u32) -> Accel {
        let m = l - r + 1;
        Accel {
            name: name.into(),
            datapath: Datapath::Ntt { word_bits },
            p_ic,
            p_oc,
            tile_mults: l * l,
            tile_outputs: m * m,
            tile_eq_macs: m * m * r * r,
            transform_adds: 4 * l * l, // butterfly adds per tile (both dirs)
            clock_mhz: 200.0,
        }
    }

    /// DSP and LUT usage of the multiply array + adder networks.
    pub fn resources(&self) -> Resources {
        let mults = (self.p_ic * self.p_oc * self.tile_mults) as u64;
        let (dsp_per_mult, mul_bits) = match self.datapath {
            Datapath::Direct { bits } | Datapath::Bilinear { mul_bits: bits } => {
                if bits <= 8 {
                    (0.5, bits)
                } else if bits <= 18 {
                    (1.0, bits)
                } else {
                    (2.0, bits)
                }
            }
            Datapath::Ntt { word_bits } => (if word_bits <= 18 { 1.0 } else { 2.0 }, word_bits),
        };
        let dsps = (mults as f64 * dsp_per_mult).ceil() as u64;
        // LUT model: transforms (adds at grown width across P_ic lanes,
        // P_oc lanes for output) + accumulators + control overhead.
        let add_bits = (mul_bits + 4) as f64;
        let transform_luts =
            self.transform_adds as f64 * add_bits * (self.p_ic + self.p_oc) as f64 / 2.0;
        let acc_luts = (self.p_oc * self.tile_mults) as f64 * 32.0;
        let ctrl_luts = 30_000.0 + (self.p_ic * self.p_oc) as f64 * 40.0;
        Resources { dsps, luts_k: (transform_luts + acc_luts + ctrl_luts) / 1000.0 }
    }

    /// Peak throughput in equivalent-direct GOPs (2 ops per MAC).
    ///
    /// Each cycle the array performs P_ic·P_oc·tile_mults physical
    /// multiplies = P_ic·P_oc tile-channel-pairs; one complete output tile
    /// (per oc) needs IC/P_ic such cycles, so in steady state the machine
    /// retires P_ic·P_oc·tile_eq_macs equivalent-direct MACs per cycle.
    pub fn peak_gops(&self) -> f64 {
        let macs_per_cycle = (self.p_ic * self.p_oc * self.tile_eq_macs) as f64;
        2.0 * macs_per_cycle * self.clock_mhz * 1e6 / 1e9
    }

    /// Efficiency: GOPs / DSP / GHz — Table 3's headline metric.
    pub fn gops_per_dsp_per_ghz(&self, achieved_gops: f64) -> f64 {
        achieved_gops / self.resources().dsps as f64 / (self.clock_mhz / 1000.0)
    }
}

/// A Table-3 style report row.
#[derive(Clone, Debug)]
pub struct Table3Row {
    /// design label
    pub name: String,
    /// precision label (e.g. "8bit")
    pub precision: String,
    /// thousands of LUTs
    pub luts_k: f64,
    /// DSP blocks
    pub dsps: u64,
    /// design clock in MHz
    pub clock_mhz: f64,
    /// achieved equivalent-direct GOPs
    pub gops: f64,
    /// GOPs / DSP / GHz — the headline efficiency metric
    pub gops_per_dsp_per_clock: f64,
}

/// Run the pipeline simulation of `shapes` on `accel` and produce the row.
pub fn evaluate(accel: &Accel, shapes: &[ConvShape], precision: &str) -> Table3Row {
    let res = accel.resources();
    let sim = pipeline::simulate(accel, shapes);
    Table3Row {
        name: accel.name.clone(),
        precision: precision.into(),
        luts_k: res.luts_k,
        dsps: res.dsps,
        clock_mhz: accel.clock_mhz,
        gops: sim.achieved_gops,
        gops_per_dsp_per_clock: accel.gops_per_dsp_per_ghz(sim.achieved_gops),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{sfc, winograd};
    use crate::nn::model::vgg16_conv_shapes;

    fn sfc_accel() -> Accel {
        // The paper's design point: [4×4×7×7] parallelism, SFC-6(7,3), int8.
        Accel::from_bilinear("SFC", &sfc(6, 7, 3), 4, 4, 8)
    }

    #[test]
    fn sfc_dsp_count_matches_paper() {
        // Paper: 4×4×132×0.5 = 1056 DSPs. Our nested tile has 144 mult
        // lanes (the RTL exploits Hermitian symmetry to implement 132);
        // check we land in the same range and exactly match with the
        // Hermitian count.
        let a = sfc_accel();
        let dsps = a.resources().dsps;
        assert_eq!(dsps, (4.0 * 4.0 * 144.0 * 0.5) as u64);
        let herm = (4.0 * 4.0 * 132.0 * 0.5) as u64;
        assert_eq!(herm, 1056); // the paper's figure
        assert!((dsps as f64 - herm as f64).abs() / (herm as f64) < 0.1);
    }

    #[test]
    fn winograd16_needs_more_dsps_per_mult() {
        // 16-bit multipliers cost a whole DSP each (Liang et al. design).
        let w = Accel::from_bilinear("Wino16", &winograd(4, 3), 4, 4, 16);
        let s = sfc_accel();
        let w_per_mult = w.resources().dsps as f64 / (4.0 * 4.0 * w.tile_mults as f64);
        let s_per_mult = s.resources().dsps as f64 / (4.0 * 4.0 * s.tile_mults as f64);
        assert!(w_per_mult > s_per_mult * 1.9);
    }

    #[test]
    fn efficiency_ranking_matches_table3() {
        // GOPs/DSP/clock: SFC > Winograd > NTT > direct (paper: 10.08 >
        // 5.64 > 3.48 > 1.96).
        let shapes = vgg16_conv_shapes();
        let rows = [
            evaluate(&Accel::from_bilinear("Wino", &winograd(4, 3), 4, 4, 16), &shapes, "16bit"),
            evaluate(&Accel::ntt("NTT", 8, 3, 4, 4, 21), &shapes, "8/21bit"),
            evaluate(&Accel::direct("direct", 7, 3, 4, 4, 8), &shapes, "8bit"),
            evaluate(&sfc_accel(), &shapes, "8bit"),
        ];
        let eff: Vec<f64> = rows.iter().map(|r| r.gops_per_dsp_per_clock).collect();
        let (wino, ntt, direct, sfc_eff) = (eff[0], eff[1], eff[2], eff[3]);
        assert!(sfc_eff > wino, "SFC {sfc_eff} > Wino {wino}");
        assert!(wino > ntt, "Wino {wino} > NTT {ntt}");
        assert!(ntt > direct, "NTT {ntt} > direct {direct}");
    }

    #[test]
    fn throughput_order_of_magnitude() {
        // The paper reports ~2129 GOPs for the SFC accelerator on VGG-16.
        let row = evaluate(&sfc_accel(), &vgg16_conv_shapes(), "8bit");
        assert!(row.gops > 500.0 && row.gops < 6000.0, "GOPs {}", row.gops);
    }
}
