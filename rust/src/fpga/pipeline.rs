//! Cycle-level pipeline simulation of an accelerator over a conv stack.
//!
//! The datapath is fully pipelined (the paper: "all computing stages in
//! fast convolution are designed to operate in a full pipeline
//! architecture"), so the layer time is dominated by multiplier-array
//! occupancy: ⌈IC/P_ic⌉·⌈OC/P_oc⌉·tiles cycles, plus a pipeline fill
//! latency per layer. Utilization losses come from ragged channel/tile
//! edges (e.g. the 3-channel input layer on a P_ic = 4 machine) — exactly
//! the second-order effects that separate "peak" from "achieved" GOPs in
//! Table 3.

use super::Accel;
use crate::nn::model::ConvShape;

/// Pipeline fill latency per layer (transform + multiply + inverse
/// stages; conservative constant).
pub const FILL_CYCLES: u64 = 64;

/// Simulated execution of one conv layer on an accelerator.
#[derive(Clone, Debug)]
pub struct LayerSim {
    /// cycles spent on the layer
    pub cycles: u64,
    /// equivalent-direct MACs the layer represents
    pub eq_macs: u64,
    /// fraction of peak MAC throughput achieved
    pub utilization: f64,
}

/// Whole-network pipeline simulation result.
#[derive(Clone, Debug)]
pub struct SimReport {
    /// per-layer results in network order
    pub layers: Vec<LayerSim>,
    /// total cycles across all layers
    pub total_cycles: u64,
    /// total equivalent-direct MACs
    pub total_eq_macs: u64,
    /// equivalent-direct GOPs at the design clock
    pub achieved_gops: f64,
    /// overall fraction of peak MAC throughput
    pub utilization: f64,
}

/// Tile grid of a layer for an accelerator producing m×m output tiles.
fn tiles_for(accel: &Accel, s: &ConvShape) -> u64 {
    let m = (accel.tile_outputs as f64).sqrt().round() as usize;
    let oh = s.h / s.stride;
    let ow = s.w / s.stride;
    (oh.div_ceil(m) * ow.div_ceil(m)) as u64
}

/// Simulate one layer.
pub fn simulate_layer(accel: &Accel, s: &ConvShape) -> LayerSim {
    let tiles = tiles_for(accel, s);
    let ic_groups = s.ic.div_ceil(accel.p_ic) as u64;
    let oc_groups = s.oc.div_ceil(accel.p_oc) as u64;
    let cycles = ic_groups * oc_groups * tiles + FILL_CYCLES;
    let eq_macs = s.direct_macs();
    // utilization: useful mults / issued mult slots
    let issued = cycles.saturating_sub(FILL_CYCLES)
        * (accel.p_ic * accel.p_oc * accel.tile_mults) as u64;
    let useful = (s.ic * s.oc) as u64 * tiles * accel.tile_mults as u64;
    let utilization = if issued > 0 { useful as f64 / issued as f64 } else { 0.0 };
    LayerSim { cycles, eq_macs, utilization }
}

/// Simulate a conv stack; layers execute back-to-back (single-engine,
/// layer-sequential schedule, as in the compared designs).
pub fn simulate(accel: &Accel, shapes: &[ConvShape]) -> SimReport {
    let layers: Vec<LayerSim> = shapes.iter().map(|s| simulate_layer(accel, s)).collect();
    let total_cycles: u64 = layers.iter().map(|l| l.cycles).sum();
    let total_eq_macs: u64 = layers.iter().map(|l| l.eq_macs).sum();
    let seconds = total_cycles as f64 / (accel.clock_mhz * 1e6);
    let achieved_gops = 2.0 * total_eq_macs as f64 / seconds / 1e9;
    let utilization = achieved_gops / accel.peak_gops();
    SimReport { layers, total_cycles, total_eq_macs, achieved_gops, utilization }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::sfc;
    use crate::nn::model::vgg16_conv_shapes;

    fn accel() -> Accel {
        Accel::from_bilinear("SFC", &sfc(6, 7, 3), 4, 4, 8)
    }

    #[test]
    fn utilization_below_one() {
        let r = simulate(&accel(), &vgg16_conv_shapes());
        assert!(r.utilization > 0.3 && r.utilization <= 1.0, "util {}", r.utilization);
        assert!(r.achieved_gops <= accel().peak_gops());
    }

    #[test]
    fn first_layer_is_underutilized() {
        // IC = 3 on a P_ic = 4 machine: ≤ 75% utilization.
        let shapes = vgg16_conv_shapes();
        let l0 = simulate_layer(&accel(), &shapes[0]);
        assert!(l0.utilization <= 0.76, "util {}", l0.utilization);
        let l1 = simulate_layer(&accel(), &shapes[1]);
        assert!(l1.utilization > 0.9, "deep layers fill the array: {}", l1.utilization);
    }

    #[test]
    fn cycles_scale_with_channels() {
        let a = accel();
        let s1 = ConvShape { ic: 64, oc: 64, h: 28, w: 28, r: 3, stride: 1 };
        let s2 = ConvShape { ic: 128, oc: 64, h: 28, w: 28, r: 3, stride: 1 };
        let c1 = simulate_layer(&a, &s1).cycles;
        let c2 = simulate_layer(&a, &s2).cycles;
        assert!(c2 > c1 && c2 < c1 * 21 / 10, "{c1} -> {c2}");
    }

    #[test]
    fn vgg16_runtime_sane() {
        // One VGG-16 inference (~15.3 G direct MACs) at ~2.8 TOPs peak must
        // land in the 10–30 ms range.
        let r = simulate(&accel(), &vgg16_conv_shapes());
        let ms = r.total_cycles as f64 / (200e6) * 1e3;
        assert!(ms > 5.0 && ms < 50.0, "VGG-16 latency {ms} ms");
    }
}
