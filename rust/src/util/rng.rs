//! PCG32 pseudo-random generator (O'Neill 2014) — deterministic across
//! platforms, used everywhere randomness is needed so every experiment in
//! EXPERIMENTS.md is exactly reproducible from its seed.

/// PCG-XSH-RR 64/32 generator state.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Generator for (seed, stream).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Generator on the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 54)
    }

    #[inline]
    /// Next 32 uniform random bits.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6364136223846793005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    /// Next 64 uniform random bits.
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) single precision.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, bound).
    #[inline]
    pub fn below(&mut self, bound: u32) -> u32 {
        // Lemire's method without bias correction is fine for experiment
        // sampling; keep the debiased variant since it is cheap.
        let mut x = self.next_u32();
        let mut m = (x as u64) * (bound as u64);
        let mut l = m as u32;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (bound as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Standard normal via Box–Muller.
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            let u2 = self.next_f64();
            if u1 > 1e-300 {
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fill a slice with N(0, sigma) samples.
    pub fn fill_gaussian(&mut self, out: &mut [f32], sigma: f64) {
        for v in out.iter_mut() {
            *v = (self.next_gaussian() * sigma) as f32;
        }
    }

    /// Fill a slice with U(lo, hi) samples.
    pub fn fill_uniform(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out.iter_mut() {
            *v = lo + (hi - lo) * self.next_f32();
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Pcg32::seeded(7);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
            let b = r.below(17);
            assert!(b < 17);
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Pcg32::seeded(3);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.next_gaussian();
            s += g;
            s2 += g * g;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(9);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
