//! Data-parallel helpers on std::thread::scope (rayon is not vendored).
//!
//! The engine's hot loops parallelize over independent chunks (image
//! batches, output channels, tile groups); a static chunking over the
//! available cores is enough and keeps the scheduling deterministic.

/// Number of worker threads to use (respects SFC_THREADS, defaults to
/// available parallelism).
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("SFC_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Parallel for over `0..n`: invokes `f(i)` for each index, splitting the
/// range into contiguous chunks across worker threads. `f` must be Sync.
pub fn par_for(n: usize, f: impl Fn(usize) + Sync) {
    let threads = num_threads().min(n.max(1));
    if threads <= 1 || n <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || {
                for i in lo..hi {
                    f(i);
                }
            });
        }
    });
}

/// Parallel map over `0..n` collecting results in index order.
///
/// Results are written once, directly into the output vector's spare
/// capacity through disjoint per-thread chunks — no `Vec<Option<T>>`
/// build-then-unwrap second pass, no per-slot `Option` overhead.
///
/// Panic behavior: if `f` panics, the panic propagates after all
/// workers join and already-computed results are leaked (never
/// dropped), not double-freed — safe, but heap-owning `T`s should not
/// rely on `Drop` running when the map aborts.
pub fn par_map<T: Send>(n: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    let mut out: Vec<T> = Vec::with_capacity(n);
    let threads = num_threads().min(n.max(1));
    if threads <= 1 || n <= 1 {
        out.extend((0..n).map(f));
        return out;
    }
    let chunk = n.div_ceil(threads);
    {
        let slots = &mut out.spare_capacity_mut()[..n];
        std::thread::scope(|s| {
            for (t, slot_chunk) in slots.chunks_mut(chunk).enumerate() {
                let f = &f;
                s.spawn(move || {
                    for (j, slot) in slot_chunk.iter_mut().enumerate() {
                        slot.write(f(t * chunk + j));
                    }
                });
            }
        });
    }
    // SAFETY: the scope joined every worker; together the disjoint chunks
    // cover exactly `out[..n]`, so all n slots are initialized. A worker
    // panic propagates out of the scope above before reaching this line.
    unsafe { out.set_len(n) };
    out
}

/// Process disjoint `chunk_size`-element chunks of `data` in parallel,
/// giving each worker exclusive `&mut` access to one element of
/// `states` — the pattern conv executors use to combine per-worker
/// workspace buffers with direct (mutex-free) output writes. Chunks are
/// distributed contiguously, so which state processes which chunk is
/// deterministic for a fixed thread count.
pub fn par_chunks_states<S: Send, T: Send>(
    data: &mut [T],
    chunk_size: usize,
    states: &mut [S],
    f: impl Fn(&mut S, usize, &mut [T]) + Sync,
) {
    assert!(chunk_size > 0, "chunk_size must be positive");
    assert!(!states.is_empty(), "need at least one worker state");
    let chunks: Vec<(usize, &mut [T])> = data.chunks_mut(chunk_size).enumerate().collect();
    let nc = chunks.len();
    if states.len() <= 1 || nc <= 1 {
        let st = &mut states[0];
        for (i, c) in chunks {
            f(st, i, c);
        }
        return;
    }
    let per = nc.div_ceil(states.len());
    std::thread::scope(|s| {
        let mut iter = chunks.into_iter();
        for st in states.iter_mut() {
            let batch: Vec<(usize, &mut [T])> = iter.by_ref().take(per).collect();
            if batch.is_empty() {
                break;
            }
            let f = &f;
            s.spawn(move || {
                for (i, c) in batch {
                    f(st, i, c);
                }
            });
        }
    });
}

/// Process disjoint mutable chunks of a slice in parallel:
/// `f(chunk_index, chunk)`.
pub fn par_chunks_mut<T: Send>(data: &mut [T], chunk_size: usize, f: impl Fn(usize, &mut [T]) + Sync) {
    assert!(chunk_size > 0);
    std::thread::scope(|s| {
        let threads = num_threads();
        let chunks: Vec<(usize, &mut [T])> = data.chunks_mut(chunk_size).enumerate().collect();
        let n = chunks.len();
        let per_thread = n.div_ceil(threads.max(1));
        let mut iter = chunks.into_iter();
        for _ in 0..threads {
            let batch: Vec<(usize, &mut [T])> = iter.by_ref().take(per_thread).collect();
            if batch.is_empty() {
                break;
            }
            let f = &f;
            s.spawn(move || {
                for (i, c) in batch {
                    f(i, c);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn par_for_covers_all() {
        let count = AtomicUsize::new(0);
        par_for(1000, |_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn par_map_order() {
        let v = par_map(257, |i| i * 3);
        assert_eq!(v.len(), 257);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * 3);
        }
    }

    #[test]
    fn par_chunks_mut_writes() {
        let mut data = vec![0usize; 103];
        par_chunks_mut(&mut data, 10, |ci, chunk| {
            for x in chunk.iter_mut() {
                *x = ci + 1;
            }
        });
        assert!(data.iter().all(|&x| x > 0));
        assert_eq!(data[0], 1);
        assert_eq!(data[102], 11);
    }

    #[test]
    fn empty_and_single() {
        par_for(0, |_| panic!("should not run"));
        let v = par_map(1, |i| i);
        assert_eq!(v, vec![0]);
        let e: Vec<usize> = par_map(0, |i| i);
        assert!(e.is_empty());
    }

    #[test]
    fn par_map_non_copy_results() {
        let v = par_map(97, |i| vec![i; 3]);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, vec![i; 3]);
        }
    }

    #[test]
    fn par_chunks_states_disjoint_and_deterministic() {
        let mut data = vec![0usize; 53];
        let mut states = vec![0usize; 4]; // per-worker chunk counters
        par_chunks_states(&mut data, 5, &mut states, |st, ci, chunk| {
            *st += 1;
            for x in chunk.iter_mut() {
                *x = ci + 1;
            }
        });
        assert!(data.iter().all(|&x| x > 0));
        assert_eq!(data[0], 1);
        assert_eq!(data[52], 11);
        let total: usize = states.iter().sum();
        assert_eq!(total, 11, "every chunk processed exactly once");
    }

    #[test]
    fn par_chunks_states_single_worker() {
        let mut data = vec![0u8; 7];
        let mut states = vec![()];
        par_chunks_states(&mut data, 3, &mut states, |_, ci, chunk| {
            for x in chunk.iter_mut() {
                *x = ci as u8 + 1;
            }
        });
        assert_eq!(data, vec![1, 1, 1, 2, 2, 2, 3]);
    }
}
