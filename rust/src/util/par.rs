//! Data-parallel helpers on std::thread::scope (rayon is not vendored).
//!
//! The engine's hot loops parallelize over independent chunks (image
//! batches, output channels, tile groups); a static chunking over the
//! available cores is enough and keeps the scheduling deterministic.

/// Number of worker threads to use (respects SFC_THREADS, defaults to
/// available parallelism).
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("SFC_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Parallel for over `0..n`: invokes `f(i)` for each index, splitting the
/// range into contiguous chunks across worker threads. `f` must be Sync.
pub fn par_for(n: usize, f: impl Fn(usize) + Sync) {
    let threads = num_threads().min(n.max(1));
    if threads <= 1 || n <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || {
                for i in lo..hi {
                    f(i);
                }
            });
        }
    });
}

/// Parallel map over `0..n` collecting results in index order.
pub fn par_map<T: Send>(n: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let slots = out.as_mut_slice();
        // SAFETY-free approach: split into per-thread disjoint chunks.
        let threads = num_threads().min(n.max(1));
        let chunk = n.div_ceil(threads.max(1));
        std::thread::scope(|s| {
            for (t, slot_chunk) in slots.chunks_mut(chunk).enumerate() {
                let f = &f;
                s.spawn(move || {
                    for (j, slot) in slot_chunk.iter_mut().enumerate() {
                        *slot = Some(f(t * chunk + j));
                    }
                });
            }
        });
    }
    out.into_iter().map(|x| x.unwrap()).collect()
}

/// Process disjoint mutable chunks of a slice in parallel:
/// `f(chunk_index, chunk)`.
pub fn par_chunks_mut<T: Send>(data: &mut [T], chunk_size: usize, f: impl Fn(usize, &mut [T]) + Sync) {
    assert!(chunk_size > 0);
    std::thread::scope(|s| {
        let threads = num_threads();
        let chunks: Vec<(usize, &mut [T])> = data.chunks_mut(chunk_size).enumerate().collect();
        let n = chunks.len();
        let per_thread = n.div_ceil(threads.max(1));
        let mut iter = chunks.into_iter();
        for _ in 0..threads {
            let batch: Vec<(usize, &mut [T])> = iter.by_ref().take(per_thread).collect();
            if batch.is_empty() {
                break;
            }
            let f = &f;
            s.spawn(move || {
                for (i, c) in batch {
                    f(i, c);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn par_for_covers_all() {
        let count = AtomicUsize::new(0);
        par_for(1000, |_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn par_map_order() {
        let v = par_map(257, |i| i * 3);
        assert_eq!(v.len(), 257);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * 3);
        }
    }

    #[test]
    fn par_chunks_mut_writes() {
        let mut data = vec![0usize; 103];
        par_chunks_mut(&mut data, 10, |ci, chunk| {
            for x in chunk.iter_mut() {
                *x = ci + 1;
            }
        });
        assert!(data.iter().all(|&x| x > 0));
        assert_eq!(data[0], 1);
        assert_eq!(data[102], 11);
    }

    #[test]
    fn empty_and_single() {
        par_for(0, |_| panic!("should not run"));
        let v = par_map(1, |i| i);
        assert_eq!(v, vec![0]);
    }
}
