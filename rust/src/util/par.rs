//! Data-parallel helpers on the persistent work-stealing pool
//! ([`crate::util::pool`]; rayon is not vendored), plus the
//! process-wide [`CoreBudget`] that arbitrates cores between the
//! serving layer's per-model workers and the intra-op GEMM threads.
//!
//! The engine's hot loops parallelize over independent chunks (image
//! batches, output channels, tile groups, (frequency, group) GEMM
//! blocks, GEMM row spans); a static chunking over the available cores
//! is enough and keeps the *decomposition* deterministic — which chunk
//! exists and what it writes never depends on scheduling, only which
//! thread happens to execute it does. Every helper sizes its team
//! through the single [`crate::util::pool::team`] entry point
//! (`SFC_THREADS` / [`set_thread_override`] / [`CoreBudget`] lanes all
//! meet there), runs its first chunk on the calling thread, and hands
//! the rest to parked pool workers — so nesting (a model worker running
//! a batch-parallel conv whose GEMM would also like to thread) degrades
//! gracefully to serial inner loops instead of oversubscribing the
//! host, and a helper invocation costs a queue push, not a thread
//! spawn.

use super::pool;
use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Thread-count override slot: 0 = none (env/detection), else the
/// forced count. Mirrors `linalg::simd::OVERRIDE`.
static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

fn env_threads() -> usize {
    if let Ok(v) = std::env::var("SFC_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Number of worker threads to use: the [`set_thread_override`] pin if
/// set, else `SFC_THREADS` (read once and cached — the environment is
/// startup configuration, not mutable state), else the machine's
/// available parallelism.
pub fn num_threads() -> usize {
    match THREAD_OVERRIDE.load(Ordering::Relaxed) {
        0 => {
            static ENV: OnceLock<usize> = OnceLock::new();
            *ENV.get_or_init(env_threads)
        }
        n => n,
    }
}

/// Force the worker-thread count (`None` restores the cached
/// env/detection value). The explicit override hook for tests and the
/// bench harness's single-vs-multi-thread scaling block — mirrors
/// [`crate::linalg::simd::set_kernel_override`]. Takes effect on the
/// next [`num_threads`] call; process-global, so tests that toggle it
/// serialize behind a lock like the kernel-override tests do.
pub fn set_thread_override(n: Option<usize>) {
    THREAD_OVERRIDE.store(n.map_or(0, |v| v.max(1)), Ordering::Relaxed);
}

// ---------------------------------------------------------------------
// CoreBudget: process-wide compute-lane accounting
// ---------------------------------------------------------------------

/// Total-lanes override (0 = follow [`num_threads`]).
static BUDGET_TOTAL: AtomicUsize = AtomicUsize::new(0);
/// Lanes currently leased across the process.
static BUDGET_LEASED: AtomicUsize = AtomicUsize::new(0);
/// High-water mark of `BUDGET_LEASED` (concurrent compute threads).
static BUDGET_PEAK: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// True while the current thread's lane is already counted in
    /// `BUDGET_LEASED` (scheduler worker in EXECUTE, or a par-helper /
    /// GEMM team member) — nested leases must not re-count it.
    static LANE_COUNTED: Cell<bool> = const { Cell::new(false) };
}

/// The process-wide core budget: a fixed number of compute *lanes*
/// (default: [`num_threads`]) that every source of parallelism leases
/// from — `MultiServer` model workers while they execute a batch, the
/// batch-parallel conv helpers, and the intra-op GEMM macro-kernel.
/// Leasing is best-effort and never blocks: a team that can't get extra
/// lanes simply runs on fewer threads (worst case, serial on the
/// caller), so nested parallelism degrades instead of oversubscribing.
/// Observable through [`CoreBudget::snapshot`] /
/// [`crate::coordinator::metrics::core_budget`].
pub struct CoreBudget;

impl CoreBudget {
    /// Total lanes in the budget ([`CoreBudget::set_total`] override,
    /// else [`num_threads`]).
    pub fn total() -> usize {
        match BUDGET_TOTAL.load(Ordering::Relaxed) {
            0 => num_threads(),
            n => n,
        }
    }

    /// Override the total lane count (`None` restores the
    /// [`num_threads`] default) — `sfc serve --cores N` and the
    /// budget tests.
    pub fn set_total(n: Option<usize>) {
        BUDGET_TOTAL.store(n.map_or(0, |v| v.max(1)), Ordering::Relaxed);
    }

    /// (total, leased, peak) lane counts. `peak` is the high-water mark
    /// of concurrently leased lanes — the acceptance metric for "model
    /// workers × intra-op threads never oversubscribe".
    pub fn snapshot() -> (usize, usize, usize) {
        (
            CoreBudget::total(),
            BUDGET_LEASED.load(Ordering::Relaxed),
            BUDGET_PEAK.load(Ordering::Relaxed),
        )
    }

    /// Reset the peak high-water mark (tests measure one scenario).
    pub fn reset_peak() {
        BUDGET_PEAK.store(BUDGET_LEASED.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Lease lanes for a team of up to `want` concurrent compute
    /// threads (including the caller). The caller's own lane is counted
    /// exactly once across nested leases; extra lanes are granted only
    /// while the budget has headroom. [`Lease::threads`] says how many
    /// threads the caller may actually run; dropping the lease returns
    /// the lanes.
    pub fn lease(want: usize) -> Lease {
        let want = want.max(1);
        let already = LANE_COUNTED.with(|c| c.get());
        let have = usize::from(already);
        let mut grabbed;
        let mut cur = BUDGET_LEASED.load(Ordering::Relaxed);
        loop {
            let avail = CoreBudget::total().saturating_sub(cur);
            // the caller runs regardless of headroom: its own lane is
            // grabbed even when the budget is exhausted (honest peak
            // accounting), extra lanes only while lanes remain
            grabbed = (want - have).min(avail).max(1 - have);
            match BUDGET_LEASED.compare_exchange_weak(
                cur,
                cur + grabbed,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(c) => cur = c,
            }
        }
        BUDGET_PEAK.fetch_max(cur + grabbed, Ordering::Relaxed);
        let marked = !already && grabbed > 0;
        if marked {
            LANE_COUNTED.with(|c| c.set(true));
        }
        Lease { grabbed, threads: (have + grabbed).max(1), marked }
    }
}

/// A scoped lane lease from the [`CoreBudget`]; lanes return on drop.
pub struct Lease {
    grabbed: usize,
    threads: usize,
    marked: bool,
}

impl Lease {
    /// How many compute threads (including the caller) this lease
    /// covers. Always ≥ 1.
    pub fn threads(&self) -> usize {
        self.threads
    }
}

impl Drop for Lease {
    fn drop(&mut self) {
        if self.marked {
            LANE_COUNTED.with(|c| c.set(false));
        }
        BUDGET_LEASED.fetch_sub(self.grabbed, Ordering::Relaxed);
    }
}

/// Run `f` with the current thread marked as holding a counted budget
/// lane — par helpers and the GEMM macro-kernel wrap their spawned
/// workers in this so a nested lease on the worker does not re-count
/// the lane its parent team already leased for it.
pub fn counted_lane<R>(f: impl FnOnce() -> R) -> R {
    let prev = LANE_COUNTED.with(|c| c.replace(true));
    let r = f();
    LANE_COUNTED.with(|c| c.set(prev));
    r
}

/// Parallel for over `0..n`: invokes `f(i)` for each index, splitting the
/// range into contiguous chunks across the pool's worker team. `f` must
/// be Sync. The first chunk runs on the calling thread; the team is
/// sized (and its [`CoreBudget`] lanes leased) by
/// [`crate::util::pool::team`].
pub fn par_for(n: usize, f: impl Fn(usize) + Sync) {
    if n <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let team = pool::team(n);
    let threads = team.threads().min(n);
    if threads <= 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    pool::run(n.div_ceil(chunk), threads, |t| {
        let lo = t * chunk;
        let hi = ((t + 1) * chunk).min(n);
        for i in lo..hi {
            f(i);
        }
    });
}

/// Parallel map over `0..n` collecting results in index order.
///
/// Results are written once, directly into the output vector's spare
/// capacity through disjoint per-thread chunks — no `Vec<Option<T>>`
/// build-then-unwrap second pass, no per-slot `Option` overhead. The
/// first chunk is computed on the calling thread.
///
/// Panic behavior: if `f` panics, the panic propagates after all
/// workers join and already-computed results are leaked (never
/// dropped), not double-freed — safe, but heap-owning `T`s should not
/// rely on `Drop` running when the map aborts.
pub fn par_map<T: Send>(n: usize, f: impl Fn(usize) -> T + Sync) -> Vec<T> {
    let mut out: Vec<T> = Vec::with_capacity(n);
    if n <= 1 {
        out.extend((0..n).map(f));
        return out;
    }
    let team = pool::team(n);
    let threads = team.threads().min(n);
    if threads <= 1 {
        out.extend((0..n).map(f));
        return out;
    }
    let chunk = n.div_ceil(threads);
    {
        let slots = &mut out.spare_capacity_mut()[..n];
        let base = pool::SendPtr::new(slots.as_mut_ptr());
        pool::run(n.div_ceil(chunk), threads, |t| {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            for i in lo..hi {
                // SAFETY: task t exclusively owns slots[t*chunk ..
                // (t+1)*chunk).min(n)] — tasks cover disjoint ranges of
                // the spare capacity, each slot written exactly once.
                unsafe {
                    base.get().add(i).write(std::mem::MaybeUninit::new(f(i)));
                }
            }
        });
    }
    // SAFETY: pool::run joined every task; together the disjoint chunks
    // cover exactly `out[..n]`, so all n slots are initialized. A task
    // panic propagates out of pool::run before reaching this line.
    unsafe { out.set_len(n) };
    out
}

/// Process disjoint `chunk_size`-element chunks of `data` in parallel,
/// giving each worker exclusive `&mut` access to one element of
/// `states` — the pattern conv executors use to combine per-worker
/// workspace buffers with direct (mutex-free) output writes. Chunks are
/// distributed contiguously, so which state processes which chunk is
/// deterministic for a fixed worker count; the worker count is
/// `states.len()` capped by the [`CoreBudget`] lanes actually granted
/// (state 0 runs on the calling thread).
pub fn par_chunks_states<S: Send, T: Send>(
    data: &mut [T],
    chunk_size: usize,
    states: &mut [S],
    f: impl Fn(&mut S, usize, &mut [T]) + Sync,
) {
    assert!(chunk_size > 0, "chunk_size must be positive");
    assert!(!states.is_empty(), "need at least one worker state");
    let len = data.len();
    let nc = len.div_ceil(chunk_size);
    let serial = |states: &mut [S]| {
        let st = &mut states[0];
        for (i, c) in data.chunks_mut(chunk_size).enumerate() {
            f(st, i, c);
        }
    };
    let want = states.len().min(nc);
    if want <= 1 {
        serial(states);
        return;
    }
    let team = pool::team(want);
    let threads = team.threads().min(want);
    if threads <= 1 {
        serial(states);
        return;
    }
    let per = nc.div_ceil(threads);
    let dp = pool::SendPtr::new(data.as_mut_ptr());
    let sp = pool::SendPtr::new(states.as_mut_ptr());
    pool::run(nc.div_ceil(per), threads, |b| {
        // SAFETY: task b exclusively owns states[b] (one task per state,
        // nc.div_ceil(per) <= threads <= states.len()) and the disjoint
        // chunk range [b*per, (b+1)*per).min(nc) of `data` — the same
        // contiguous batch-per-state decomposition as the serial path,
        // so which state sees which chunk stays deterministic.
        let st = unsafe { &mut *sp.get().add(b) };
        let lo = b * per;
        let hi = ((b + 1) * per).min(nc);
        for i in lo..hi {
            let c0 = i * chunk_size;
            let c1 = ((i + 1) * chunk_size).min(len);
            let chunk = unsafe { std::slice::from_raw_parts_mut(dp.get().add(c0), c1 - c0) };
            f(st, i, chunk);
        }
    });
}

/// Run `njobs` independent jobs `f(&mut state, job)` across per-worker
/// states: jobs are split into contiguous batches, one batch per state,
/// exactly like [`par_chunks_states`] but over a bare index domain —
/// for loops whose output regions can't be expressed as a slice
/// partition (the tiled engines' per-block scatter writes). Which state
/// runs which job is deterministic for a fixed worker count; the
/// callback owns the proof that distinct jobs write disjoint data.
pub fn par_jobs_states<S: Send>(njobs: usize, states: &mut [S], f: impl Fn(&mut S, usize) + Sync) {
    assert!(!states.is_empty(), "need at least one worker state");
    let want = states.len().min(njobs);
    let serial = |states: &mut [S]| {
        let st = &mut states[0];
        for j in 0..njobs {
            f(st, j);
        }
    };
    if want <= 1 {
        serial(states);
        return;
    }
    let team = pool::team(want);
    let threads = team.threads().min(want);
    if threads <= 1 {
        serial(states);
        return;
    }
    let per = njobs.div_ceil(threads);
    let sp = pool::SendPtr::new(states.as_mut_ptr());
    pool::run(njobs.div_ceil(per), threads, |b| {
        // SAFETY: task b exclusively owns states[b]: one task per
        // state, njobs.div_ceil(per) <= threads <= states.len().
        let st = unsafe { &mut *sp.get().add(b) };
        let lo = b * per;
        let hi = ((b + 1) * per).min(njobs);
        for j in lo..hi {
            f(st, j);
        }
    });
}

/// Process disjoint mutable chunks of a slice in parallel:
/// `f(chunk_index, chunk)`. Each chunk is one stealable pool task (the
/// batched-submit path the per-(frequency, group) GEMM sweeps ride);
/// the first task runs on the calling thread and the team holds leased
/// [`CoreBudget`] lanes via [`crate::util::pool::team`].
pub fn par_chunks_mut<T: Send>(data: &mut [T], chunk_size: usize, f: impl Fn(usize, &mut [T]) + Sync) {
    assert!(chunk_size > 0);
    let len = data.len();
    let nc = len.div_ceil(chunk_size);
    if nc <= 1 {
        for (i, c) in data.chunks_mut(chunk_size).enumerate() {
            f(i, c);
        }
        return;
    }
    let team = pool::team(nc);
    let threads = team.threads().min(nc);
    if threads <= 1 {
        for (i, c) in data.chunks_mut(chunk_size).enumerate() {
            f(i, c);
        }
        return;
    }
    let dp = pool::SendPtr::new(data.as_mut_ptr());
    pool::run(nc, threads, |i| {
        let c0 = i * chunk_size;
        let c1 = ((i + 1) * chunk_size).min(len);
        // SAFETY: task i exclusively owns data[i*chunk_size ..
        // (i+1)*chunk_size).min(len) — chunks partition the slice.
        let chunk = unsafe { std::slice::from_raw_parts_mut(dp.get().add(c0), c1 - c0) };
        f(i, chunk);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;

    /// Serializes tests that toggle the process-global thread override
    /// or budget total (mirrors `simd::TEST_OVERRIDE_LOCK`).
    static PAR_TEST_LOCK: Mutex<()> = Mutex::new(());

    fn lock() -> std::sync::MutexGuard<'static, ()> {
        PAR_TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn par_for_covers_all() {
        let count = AtomicUsize::new(0);
        par_for(1000, |_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn par_map_order() {
        let v = par_map(257, |i| i * 3);
        assert_eq!(v.len(), 257);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * 3);
        }
    }

    #[test]
    fn par_chunks_mut_writes() {
        let mut data = vec![0usize; 103];
        par_chunks_mut(&mut data, 10, |ci, chunk| {
            for x in chunk.iter_mut() {
                *x = ci + 1;
            }
        });
        assert!(data.iter().all(|&x| x > 0));
        assert_eq!(data[0], 1);
        assert_eq!(data[102], 11);
    }

    #[test]
    fn empty_and_single() {
        par_for(0, |_| panic!("should not run"));
        let v = par_map(1, |i| i);
        assert_eq!(v, vec![0]);
        let e: Vec<usize> = par_map(0, |i| i);
        assert!(e.is_empty());
    }

    #[test]
    fn par_map_non_copy_results() {
        let v = par_map(97, |i| vec![i; 3]);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, vec![i; 3]);
        }
    }

    #[test]
    fn par_chunks_states_disjoint_and_deterministic() {
        let mut data = vec![0usize; 53];
        let mut states = vec![0usize; 4]; // per-worker chunk counters
        par_chunks_states(&mut data, 5, &mut states, |st, ci, chunk| {
            *st += 1;
            for x in chunk.iter_mut() {
                *x = ci + 1;
            }
        });
        assert!(data.iter().all(|&x| x > 0));
        assert_eq!(data[0], 1);
        assert_eq!(data[52], 11);
        let total: usize = states.iter().sum();
        assert_eq!(total, 11, "every chunk processed exactly once");
    }

    #[test]
    fn par_jobs_states_covers_every_job_once() {
        let mut states = vec![0usize; 3];
        let hits: Vec<AtomicUsize> = (0..17).map(|_| AtomicUsize::new(0)).collect();
        par_jobs_states(17, &mut states, |st, j| {
            *st += 1;
            hits[j].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        assert_eq!(states.iter().sum::<usize>(), 17, "every job ran on exactly one state");
    }

    #[test]
    fn par_chunks_states_single_worker() {
        let mut data = vec![0u8; 7];
        let mut states = vec![()];
        par_chunks_states(&mut data, 3, &mut states, |_, ci, chunk| {
            for x in chunk.iter_mut() {
                *x = ci as u8 + 1;
            }
        });
        assert_eq!(data, vec![1, 1, 1, 2, 2, 2, 3]);
    }

    #[test]
    fn thread_override_pins_and_restores() {
        let _g = lock();
        set_thread_override(Some(3));
        assert_eq!(num_threads(), 3);
        set_thread_override(Some(1));
        assert_eq!(num_threads(), 1);
        set_thread_override(None);
        assert!(num_threads() >= 1, "cached env/detection value");
    }

    // NOTE: `BUDGET_LEASED`/`BUDGET_PEAK` are process-wide and other
    // tests in this binary lease lanes concurrently (every par helper
    // does) without taking PAR_TEST_LOCK, so these tests only assert
    // properties that hold under arbitrary concurrent leasing: per-lease
    // bounds and the caller's own observed concurrency. Exact global
    // snapshot assertions live in the `threads` integration binary,
    // where every test shares one lock.
    #[test]
    fn budget_lease_grants_within_total() {
        let _g = lock();
        CoreBudget::set_total(Some(3));
        {
            let l = CoreBudget::lease(8);
            let n = l.threads();
            assert!((1..=3).contains(&n), "grant {n} capped by total 3");
            // nested lease(1) on the same (already counted) thread is
            // deterministic: the lane is not re-counted and no extra
            // lane is requested
            let inner = CoreBudget::lease(1);
            assert_eq!(inner.threads(), 1);
            drop(inner);
        }
        let (_, _, peak) = CoreBudget::snapshot();
        assert!(peak >= 1);
        CoreBudget::set_total(None);
    }

    #[test]
    fn budget_never_starves_the_caller() {
        let _g = lock();
        CoreBudget::set_total(Some(1));
        let outer = CoreBudget::lease(1);
        assert_eq!(outer.threads(), 1);
        // a second top-level thread would still get its own lane (the
        // thread runs regardless); simulate via a fresh thread
        let t = std::thread::spawn(|| CoreBudget::lease(4).threads());
        assert_eq!(t.join().unwrap(), 1, "over-budget caller runs serial");
        drop(outer);
        CoreBudget::set_total(None);
    }

    #[test]
    fn par_helpers_respect_budget_total() {
        let _g = lock();
        CoreBudget::set_total(Some(2));
        // measure this call's own concurrency (a global peak assertion
        // would race against other tests' leases)
        let live = AtomicUsize::new(0);
        let high = AtomicUsize::new(0);
        let count = AtomicUsize::new(0);
        par_for(64, |_| {
            let now = live.fetch_add(1, Ordering::SeqCst) + 1;
            high.fetch_max(now, Ordering::SeqCst);
            std::thread::yield_now();
            count.fetch_add(1, Ordering::SeqCst);
            live.fetch_sub(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 64);
        let high = high.load(Ordering::SeqCst);
        assert!(high <= 2, "par_for ran {high} threads under a budget of 2");
        CoreBudget::set_total(None);
    }
}
