//! Software IEEE-754 binary16 rounding.
//!
//! Table 1 of the paper measures fast-convolution numerical error with the
//! element-wise multiply operands rounded to half precision. We only need
//! f32 -> fp16 -> f32 round-tripping (round-to-nearest-even), not fp16
//! arithmetic, so a bit-twiddling conversion is sufficient.

/// Round an f32 to the nearest representable fp16 value and return it as f32.
pub fn round_fp16(x: f32) -> f32 {
    fp16_to_f32(f32_to_fp16(x))
}

/// f32 -> IEEE binary16 bits, round-to-nearest-even, with overflow to inf
/// and gradual underflow to subnormals.
pub fn f32_to_fp16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let mut exp = ((bits >> 23) & 0xff) as i32;
    let mut man = bits & 0x007f_ffff;

    if exp == 0xff {
        // Inf / NaN
        return sign | 0x7c00 | if man != 0 { 0x0200 } else { 0 };
    }
    // Re-bias from 127 to 15.
    exp -= 127 - 15;
    if exp >= 0x1f {
        return sign | 0x7c00; // overflow -> inf
    }
    if exp <= 0 {
        // Subnormal or zero in fp16.
        if exp < -10 {
            return sign; // rounds to zero
        }
        man |= 0x0080_0000; // implicit bit
        let shift = (14 - exp) as u32; // 14..24
        let half = 1u32 << (shift - 1);
        let rounded = (man + half - 1 + ((man >> shift) & 1)) >> shift;
        return sign | rounded as u16;
    }
    // Normal case: keep 10 mantissa bits, round to nearest even on bit 13.
    let half = 0x0000_0fff + ((man >> 13) & 1);
    man += half;
    if man & 0x0080_0000 != 0 {
        man = 0;
        exp += 1;
        if exp >= 0x1f {
            return sign | 0x7c00;
        }
    }
    sign | ((exp as u16) << 10) | ((man >> 13) as u16)
}

/// IEEE binary16 bits -> f32.
pub fn fp16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x03ff) as u32;
    let bits = if exp == 0 {
        if man == 0 {
            sign
        } else {
            // Subnormal: normalize.
            let mut e = -1i32;
            let mut m = man;
            while m & 0x0400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x03ff;
            sign | (((127 - 15 + e + 1) as u32) << 23) | (m << 13)
        }
    } else if exp == 0x1f {
        sign | 0x7f80_0000 | (man << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_small_integers() {
        for i in -2048..=2048 {
            let x = i as f32;
            assert_eq!(round_fp16(x), x, "small ints are exact in fp16: {i}");
        }
    }

    #[test]
    fn known_values() {
        assert_eq!(f32_to_fp16(1.0), 0x3c00);
        assert_eq!(f32_to_fp16(-2.0), 0xc000);
        assert_eq!(f32_to_fp16(65504.0), 0x7bff); // max finite fp16
        assert_eq!(f32_to_fp16(65520.0), 0x7c00); // rounds to inf
        assert_eq!(fp16_to_f32(0x3555), 0.333251953125); // ~1/3
    }

    #[test]
    fn round_trip_error_bound() {
        let mut r = crate::util::Pcg32::seeded(11);
        for _ in 0..100_000 {
            let x = (r.next_f64() as f32 - 0.5) * 100.0;
            let y = round_fp16(x);
            // relative error bounded by 2^-11 for normal range
            assert!((x - y).abs() <= x.abs() * (1.0 / 2048.0) + 1e-7, "{x} -> {y}");
        }
    }

    #[test]
    fn subnormals_round_trip() {
        let tiny = 5.96e-8_f32; // smallest subnormal fp16 ~5.96e-8
        let y = round_fp16(tiny);
        assert!(y > 0.0 && y < 1.3e-7);
        assert_eq!(round_fp16(1e-9), 0.0);
    }

    #[test]
    fn nan_and_inf() {
        assert!(round_fp16(f32::NAN).is_nan());
        assert_eq!(round_fp16(f32::INFINITY), f32::INFINITY);
        assert_eq!(round_fp16(f32::NEG_INFINITY), f32::NEG_INFINITY);
    }
}
