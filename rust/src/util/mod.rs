//! Small self-contained utilities: deterministic PRNG, software fp16
//! rounding, timing helpers, the persistent work-stealing executor
//! pool ([`pool`]) and the data-parallel helpers ([`par`]) that run on
//! it.
//!
//! The build environment vendors only `xla` + `anyhow`, so the usual
//! ecosystem crates (rand, half, rayon, criterion) are reimplemented here in
//! the minimal form the reproduction needs.

pub mod rng;
pub mod fp16;
pub mod timer;
pub mod par;
pub mod pool;

pub use fp16::round_fp16;
pub use rng::Pcg32;
pub use timer::Timer;
