//! Micro-benchmark timing helpers (criterion is not vendored in this
//! environment, so `cargo bench` targets use this harness: warmup + N
//! timed iterations + robust statistics).

use std::time::Instant;

/// Wall-clock stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start timing now.
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Seconds since start.
    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Milliseconds since start.
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }

    /// Microseconds since start.
    pub fn elapsed_us(&self) -> f64 {
        self.elapsed_s() * 1e6
    }
}

/// Statistics over a set of per-iteration timings (seconds).
#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    /// sample count
    pub iters: usize,
    /// arithmetic mean seconds
    pub mean_s: f64,
    /// median seconds
    pub median_s: f64,
    /// fastest sample
    pub min_s: f64,
    /// 95th-percentile seconds
    pub p95_s: f64,
}

impl BenchStats {
    /// Statistics over a non-empty sample set.
    pub fn from_samples(mut samples: Vec<f64>) -> Self {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        BenchStats {
            iters: n,
            mean_s: mean,
            median_s: samples[n / 2],
            min_s: samples[0],
            p95_s: samples[(n as f64 * 0.95) as usize % n.max(1)],
        }
    }
}

/// Run `f` repeatedly: `warmup` untimed runs then timed runs until either
/// `min_iters` iterations AND `min_time_s` seconds elapsed (whichever is
/// later), then return stats. The closure's return value is black-boxed.
pub fn bench<T>(name: &str, warmup: usize, min_iters: usize, min_time_s: f64, mut f: impl FnMut() -> T) -> BenchStats {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut samples = Vec::new();
    let total = Timer::start();
    loop {
        let t = Timer::start();
        black_box(f());
        samples.push(t.elapsed_s());
        if samples.len() >= min_iters && total.elapsed_s() >= min_time_s {
            break;
        }
        if samples.len() > 2_000_000 {
            break;
        }
    }
    let stats = BenchStats::from_samples(samples);
    println!(
        "{name:<44} iters={:<7} mean={:>10.3}us median={:>10.3}us min={:>10.3}us p95={:>10.3}us",
        stats.iters,
        stats.mean_s * 1e6,
        stats.median_s * 1e6,
        stats.min_s * 1e6,
        stats.p95_s * 1e6
    );
    stats
}

/// Prevent the optimizer from eliding a value (stable-rust black box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}
