//! The persistent work-stealing executor pool behind every parallel
//! region in the crate.
//!
//! Before this module existed, each `util::par` helper and the GEMM
//! macro-kernel spawned fresh OS threads through `std::thread::scope`
//! per call — fine for the handful of coarse regions (batch chunks, row
//! bands), but ~20 µs of spawn+join overhead per helper made
//! fine-grained parallelism (the per-(frequency, group) SFC/Winograd
//! GEMM sweep, the per-block tiled transforms) a guaranteed loss, so
//! those loops stayed serial by design. The pool amortizes that cost to
//! a queue push (~1–2 µs, first submit aside): workers are spawned
//! lazily on first demand, then parked on a condvar between batches and
//! reused forever.
//!
//! Structure (classic work-stealing, sized for coarse tasks):
//! * one global **injector** queue for batches submitted from
//!   non-pool threads (model workers, tests, `main`);
//! * one **deque** per worker: a worker that submits a nested batch
//!   pushes to its own deque (LIFO for locality), idle workers steal
//!   from the front (FIFO);
//! * a **park lot** (mutex + condvar): workers with nothing to run
//!   block here; submitters notify it after enqueueing.
//!
//! A submitted [`run`] batch is `total` tasks (indices `0..total`)
//! claimed from a shared atomic cursor, so "stealing" is per *task*,
//! not per contiguous range — a slow worker never strands the tail of
//! its range. What goes on the queues are join tickets (`helpers`
//! clones of one [`Batch`] handle); any parked or idle worker that pops
//! one joins the claim loop until the cursor is exhausted. The caller
//! always executes task 0 itself (the "first chunk on the caller" rule
//! every `util::par` helper documents), keeps claiming while tasks
//! remain, and only then blocks waiting for in-flight helpers — so a
//! batch completes even if every worker is busy elsewhere, and nested
//! submission (a pool task submitting its own batch) cannot deadlock:
//! the nested submitter drains its own cursor too.
//!
//! **Panic isolation:** every task body runs under `catch_unwind`. A
//! panicking task never kills a pool worker (workers are process-lived
//! and shared by every model); the first panic payload is stashed on
//! the batch and re-thrown on the *submitting* thread once the batch
//! has fully drained — by which point no task can still be touching the
//! submitter's borrowed closure.
//!
//! **Sizing** is not the pool's job: [`team`] is the single sizing
//! entry point (`SFC_THREADS` / [`par::set_thread_override`] via
//! [`par::num_threads`], then a [`par::CoreBudget`] lease), and
//! [`run`] is handed the team size it produced. Workers therefore
//! never oversubscribe the host: the lanes a `MultiServer` model
//! worker leases while executing a batch come out of the same budget
//! the pool's active set is sized from. The worker *threads* may
//! outnumber the current budget (they are never torn down), but the
//! excess just stays parked — parked workers cost a few KB of stack
//! and nothing else.
//!
//! Observability: [`gauges`] (delegated by
//! [`crate::coordinator::metrics::pool_gauges`]) reports workers
//! spawned, tasks executed, steals, spawn-avoided count and park/unpark
//! transitions; `sfc serve`, `sfc loadgen` and the BENCH v7 `pool`
//! block print it.

use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

use super::par;

/// Hard backstop on the number of pool workers ever spawned. Demand is
/// bounded by `team()` (≤ `num_threads() - 1` helpers per batch) so
/// this is never the operative limit on sane hosts; it only guards
/// against a runaway `SFC_THREADS` / budget misconfiguration.
const MAX_WORKERS: usize = 64;

// ---------------------------------------------------------------------
// Gauges (process-wide, monotonic)
// ---------------------------------------------------------------------

static TASKS: AtomicU64 = AtomicU64::new(0);
static STEALS: AtomicU64 = AtomicU64::new(0);
static SPAWN_AVOIDED: AtomicU64 = AtomicU64::new(0);
static PARKS: AtomicU64 = AtomicU64::new(0);
static UNPARKS: AtomicU64 = AtomicU64::new(0);
static URGENT_SUBMITS: AtomicU64 = AtomicU64::new(0);

/// A snapshot of the pool's monotonic counters ([`gauges`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolGauges {
    /// live worker threads (spawned once, parked between batches)
    pub workers: usize,
    /// tasks executed, on workers and submitters alike
    pub tasks: u64,
    /// tasks executed by a thread other than the batch's submitter —
    /// parallelism actually realized, not just requested
    pub steals: u64,
    /// helper slots served by an already-live worker instead of a
    /// fresh OS thread — the spawn/join overhead the pool amortized
    pub spawn_avoided: u64,
    /// worker park transitions (idle worker went to sleep)
    pub parks: u64,
    /// worker unpark transitions (sleeping worker woken for work)
    pub unparks: u64,
    /// batches enqueued at the injector *front* by an [`urgent`]
    /// submitter (deadline-critical serving batches jumping the FIFO)
    pub urgent: u64,
}

/// Snapshot the pool gauges.
pub fn gauges() -> PoolGauges {
    PoolGauges {
        workers: pool().workers.lock().unwrap_or_else(|e| e.into_inner()).len(),
        tasks: TASKS.load(Ordering::Relaxed),
        steals: STEALS.load(Ordering::Relaxed),
        spawn_avoided: SPAWN_AVOIDED.load(Ordering::Relaxed),
        parks: PARKS.load(Ordering::Relaxed),
        unparks: UNPARKS.load(Ordering::Relaxed),
        urgent: URGENT_SUBMITS.load(Ordering::Relaxed),
    }
}

// ---------------------------------------------------------------------
// Team: the single pool-sizing entry point
// ---------------------------------------------------------------------

/// A sized (and budget-leased) parallel team: how many threads —
/// caller included — one parallel region may run. Produced by [`team`];
/// the [`par::CoreBudget`] lanes return when the team drops, so keep
/// it alive across the [`run`] call it sizes.
pub struct Team {
    _lease: Option<par::Lease>,
    threads: usize,
}

impl Team {
    /// Threads (caller included) this team covers. Always ≥ 1.
    pub fn threads(&self) -> usize {
        self.threads
    }
}

/// Size a parallel team of up to `want` threads. This is the single
/// sizing entry point every parallel region goes through: the
/// `SFC_THREADS` env var and the [`par::set_thread_override`] hook
/// (both read via [`par::num_threads`]) cap the request, then a
/// [`par::CoreBudget`] lease caps it again by the lanes actually free —
/// so the env var, the override hook and the budget can never disagree
/// about team size. Never blocks and never returns 0: a caller that
/// gets no extra lanes runs serial.
pub fn team(want: usize) -> Team {
    let want = want.clamp(1, par::num_threads().max(1));
    if want <= 1 {
        return Team { _lease: None, threads: 1 };
    }
    let lease = par::CoreBudget::lease(want);
    let threads = lease.threads().min(want);
    Team { _lease: Some(lease), threads }
}

// ---------------------------------------------------------------------
// Batch: one submitted parallel region
// ---------------------------------------------------------------------

/// One submitted parallel region: `total` tasks claimed from `cursor`.
/// The closure reference is lifetime-transmuted to `'static` by
/// [`run`], which is sound because `run` does not return (or unwind)
/// until `done == total`, and no claim can succeed once
/// `cursor >= total` — so the closure is never called after `run`'s
/// frame is gone. Queued clones that outlive the batch are inert join
/// tickets: a worker popping one finds the cursor exhausted and drops
/// it without touching `f`.
struct Batch {
    f: &'static (dyn Fn(usize) + Sync),
    total: usize,
    /// next unclaimed task index (seeded to 1: task 0 is the caller's)
    cursor: AtomicUsize,
    /// tasks finished (success or panic)
    done: AtomicUsize,
    /// the submitting thread — executions elsewhere count as steals
    submitter: std::thread::ThreadId,
    /// first panic payload from any task, re-thrown by the submitter
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
    /// completion latch: `done == total`, guarded for the condvar
    latch: Mutex<()>,
    latch_cv: Condvar,
}

impl Batch {
    /// Claim-and-execute until the cursor is exhausted.
    fn drain(&self) {
        loop {
            let i = self.cursor.fetch_add(1, Ordering::SeqCst);
            if i >= self.total {
                return;
            }
            self.exec(i);
        }
    }

    /// Execute one claimed task under panic isolation, then retire it.
    fn exec(&self, i: usize) {
        TASKS.fetch_add(1, Ordering::Relaxed);
        if std::thread::current().id() != self.submitter {
            STEALS.fetch_add(1, Ordering::Relaxed);
        }
        let f = self.f;
        if let Err(p) = catch_unwind(AssertUnwindSafe(|| f(i))) {
            let mut slot = self.panic.lock().unwrap_or_else(|e| e.into_inner());
            if slot.is_none() {
                *slot = Some(p);
            }
        }
        if self.done.fetch_add(1, Ordering::SeqCst) + 1 == self.total {
            let _g = self.latch.lock().unwrap_or_else(|e| e.into_inner());
            self.latch_cv.notify_all();
        }
    }

    /// Block until every task has retired (the submitter's join).
    fn wait(&self) {
        let mut g = self.latch.lock().unwrap_or_else(|e| e.into_inner());
        while self.done.load(Ordering::SeqCst) < self.total {
            g = self.latch_cv.wait(g).unwrap_or_else(|e| e.into_inner());
        }
    }
}

// ---------------------------------------------------------------------
// The pool proper
// ---------------------------------------------------------------------

#[derive(Default)]
struct WorkerQ {
    q: Mutex<VecDeque<Arc<Batch>>>,
}

struct Pool {
    /// batches from non-pool submitters (FIFO)
    injector: Mutex<VecDeque<Arc<Batch>>>,
    /// one deque per worker; grows, never shrinks
    workers: Mutex<Vec<Arc<WorkerQ>>>,
    /// queued join tickets not yet picked up (park-lot wake condition)
    pending: AtomicUsize,
    /// workers currently blocked in the park lot
    idle: AtomicUsize,
    lot: Mutex<()>,
    lot_cv: Condvar,
}

thread_local! {
    /// This thread's pool-worker index, if it is one (routes nested
    /// submissions to the worker's own deque).
    static WORKER_ID: Cell<Option<usize>> = const { Cell::new(None) };
    /// Batch-priority flag set by [`urgent`]: submissions from this
    /// thread go to the injector *front* instead of the FIFO back.
    static URGENT: Cell<bool> = const { Cell::new(false) };
}

/// Restores the previous urgency flag on drop (panic-safe).
struct UrgentGuard(bool);

impl Drop for UrgentGuard {
    fn drop(&mut self) {
        URGENT.with(|c| c.set(self.0));
    }
}

/// Run `f` with this thread's pool submissions flagged *urgent*:
/// batches it submits are enqueued at the injector front, so their
/// join tickets are picked up before any backlog of ordinary FIFO
/// work. The global batch scheduler wraps deadline-critical batch
/// execution in this so a batch it selected by earliest slack is not
/// then queued behind best-effort pool work it has no deadline for.
/// Nesting is fine (the previous flag is restored on exit), and the
/// flag is per-thread — other submitters are unaffected.
pub fn urgent<R>(f: impl FnOnce() -> R) -> R {
    let _g = UrgentGuard(URGENT.with(|c| c.replace(true)));
    f()
}

fn pool() -> &'static Pool {
    static P: OnceLock<Pool> = OnceLock::new();
    P.get_or_init(|| Pool {
        injector: Mutex::new(VecDeque::new()),
        workers: Mutex::new(Vec::new()),
        pending: AtomicUsize::new(0),
        idle: AtomicUsize::new(0),
        lot: Mutex::new(()),
        lot_cv: Condvar::new(),
    })
}

impl Pool {
    fn worker_loop(&'static self, id: usize, own: Arc<WorkerQ>) {
        WORKER_ID.with(|c| c.set(Some(id)));
        loop {
            match self.find_work(id, &own) {
                Some(b) => par::counted_lane(|| b.drain()),
                None => self.park(),
            }
        }
    }

    /// Own deque (LIFO) → injector (FIFO) → steal others (FIFO).
    fn find_work(&self, id: usize, own: &WorkerQ) -> Option<Arc<Batch>> {
        if let Some(b) = own.q.lock().unwrap_or_else(|e| e.into_inner()).pop_back() {
            self.pending.fetch_sub(1, Ordering::SeqCst);
            return Some(b);
        }
        if let Some(b) = self.injector.lock().unwrap_or_else(|e| e.into_inner()).pop_front() {
            self.pending.fetch_sub(1, Ordering::SeqCst);
            return Some(b);
        }
        let victims: Vec<Arc<WorkerQ>> =
            self.workers.lock().unwrap_or_else(|e| e.into_inner()).clone();
        for (vid, v) in victims.iter().enumerate() {
            if vid == id {
                continue;
            }
            if let Some(b) = v.q.lock().unwrap_or_else(|e| e.into_inner()).pop_front() {
                self.pending.fetch_sub(1, Ordering::SeqCst);
                return Some(b);
            }
        }
        None
    }

    /// Sleep until a submitter enqueues work. The `pending` check under
    /// the lot mutex closes the lost-wakeup race: submitters bump
    /// `pending` before taking the lot to notify, so either this worker
    /// sees the tickets and returns to scan, or the notification
    /// arrives after it is waiting.
    fn park(&self) {
        let g = self.lot.lock().unwrap_or_else(|e| e.into_inner());
        if self.pending.load(Ordering::SeqCst) > 0 {
            return;
        }
        self.idle.fetch_add(1, Ordering::SeqCst);
        PARKS.fetch_add(1, Ordering::Relaxed);
        let mut g = g;
        loop {
            g = self.lot_cv.wait(g).unwrap_or_else(|e| e.into_inner());
            if self.pending.load(Ordering::SeqCst) > 0 {
                break;
            }
        }
        self.idle.fetch_sub(1, Ordering::SeqCst);
        UNPARKS.fetch_add(1, Ordering::Relaxed);
    }

    /// Enqueue `helpers` join tickets for `batch` and wake sleepers.
    fn submit(&'static self, batch: &Arc<Batch>, helpers: usize) {
        let spawned = self.ensure_workers(helpers);
        SPAWN_AVOIDED.fetch_add(helpers.saturating_sub(spawned) as u64, Ordering::Relaxed);
        let own = WORKER_ID
            .with(|c| c.get())
            .and_then(|id| self.workers.lock().unwrap_or_else(|e| e.into_inner()).get(id).cloned());
        match own {
            Some(q) => {
                let mut g = q.q.lock().unwrap_or_else(|e| e.into_inner());
                for _ in 0..helpers {
                    self.pending.fetch_add(1, Ordering::SeqCst);
                    g.push_back(batch.clone());
                }
            }
            None => {
                let front = URGENT.with(|c| c.get());
                if front {
                    URGENT_SUBMITS.fetch_add(1, Ordering::Relaxed);
                }
                let mut g = self.injector.lock().unwrap_or_else(|e| e.into_inner());
                for _ in 0..helpers {
                    self.pending.fetch_add(1, Ordering::SeqCst);
                    if front {
                        g.push_front(batch.clone());
                    } else {
                        g.push_back(batch.clone());
                    }
                }
            }
        }
        let _g = self.lot.lock().unwrap_or_else(|e| e.into_inner());
        if self.idle.load(Ordering::SeqCst) > 0 {
            self.lot_cv.notify_all();
        }
    }

    /// Make sure at least `want` workers exist (lazy spawn, capped by
    /// [`MAX_WORKERS`]); returns how many were freshly spawned.
    fn ensure_workers(&'static self, want: usize) -> usize {
        let mut reg = self.workers.lock().unwrap_or_else(|e| e.into_inner());
        let target = want.min(MAX_WORKERS);
        let mut spawned = 0;
        while reg.len() < target {
            let id = reg.len();
            let q = Arc::new(WorkerQ::default());
            let worker_q = q.clone();
            let ok = std::thread::Builder::new()
                .name(format!("sfc-pool-{id}"))
                .spawn(move || self.worker_loop(id, worker_q))
                .is_ok();
            if !ok {
                break; // thread spawn failed: run with what we have
            }
            reg.push(q);
            spawned += 1;
        }
        spawned
    }
}

/// Execute `total` tasks `f(0..total)` with up to `threads` concurrent
/// executors (the caller plus `threads - 1` pool workers). The caller
/// runs task 0 first, then keeps claiming tasks until the batch cursor
/// is exhausted, then joins the in-flight helpers — so the call always
/// makes progress even when every worker is busy, and returns only when
/// every task has retired. Task-to-thread assignment is dynamic
/// (work-stealing); callers own determinism by making each task's
/// *output* a pure function of its index, which every `util::par`
/// helper and the GEMM row-band decomposition do.
///
/// Panics: if any task panics, the first payload is re-thrown here
/// after the batch drains (workers survive; see module docs). Results
/// a panicking map produced are leaked, not dropped.
pub fn run(total: usize, threads: usize, f: impl Fn(usize) + Sync) {
    if total == 0 {
        return;
    }
    let helpers = threads.min(total).saturating_sub(1);
    if helpers == 0 {
        for i in 0..total {
            f(i);
        }
        return;
    }
    type F<'a> = &'a (dyn Fn(usize) + Sync);
    let fr: F<'_> = &f;
    // SAFETY: pure lifetime erasure. `run` only returns (or unwinds,
    // below) after `wait()` observes `done == total`; a task must be
    // claimed (`cursor.fetch_add < total`) before `f` is touched, and
    // no claim succeeds once the cursor is exhausted — so no worker
    // dereferences this borrow after `run`'s frame ends.
    let fs: F<'static> = unsafe { std::mem::transmute::<F<'_>, F<'static>>(fr) };
    let batch = Arc::new(Batch {
        f: fs,
        total,
        cursor: AtomicUsize::new(1),
        done: AtomicUsize::new(0),
        submitter: std::thread::current().id(),
        panic: Mutex::new(None),
        latch: Mutex::new(()),
        latch_cv: Condvar::new(),
    });
    pool().submit(&batch, helpers);
    batch.exec(0); // the caller's first chunk, guaranteed
    batch.drain();
    batch.wait();
    let payload = batch.panic.lock().unwrap_or_else(|e| e.into_inner()).take();
    if let Some(p) = payload {
        resume_unwind(p);
    }
}

/// `Send + Sync` raw-pointer wrapper for the par helpers: pool tasks
/// write disjoint ranges of one buffer, which shared references can't
/// express — each use site documents its disjointness argument.
pub(crate) struct SendPtr<T>(*mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    pub(crate) fn new(p: *mut T) -> SendPtr<T> {
        SendPtr(p)
    }
    pub(crate) fn get(&self) -> *mut T {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn run_covers_every_index_exactly_once() {
        let hits: Vec<AtomicUsize> = (0..97).map(|_| AtomicUsize::new(0)).collect();
        run(97, 4, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "task {i}");
        }
    }

    #[test]
    fn serial_paths_skip_the_pool() {
        // threads <= 1, total <= 1 and total == 0 all run inline on the
        // caller (gauge deltas are asserted in tests/pool.rs, which can
        // serialize against the process-global counters)
        let n = AtomicUsize::new(0);
        run(8, 1, |_| {
            n.fetch_add(1, Ordering::SeqCst);
        });
        run(0, 4, |_| panic!("no tasks"));
        run(1, 4, |i| {
            assert_eq!(i, 0);
            n.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(n.load(Ordering::SeqCst), 9);
    }

    #[test]
    fn task_panic_reaches_the_submitter_and_workers_survive() {
        let caught = catch_unwind(AssertUnwindSafe(|| {
            run(16, 4, |i| {
                if i == 7 {
                    panic!("task 7 boom");
                }
            });
        }));
        let msg = caught.expect_err("panic must propagate");
        let msg = msg.downcast_ref::<&str>().copied().unwrap_or("<non-str payload>");
        assert!(msg.contains("boom"), "original payload re-thrown, got {msg}");
        // pool still functional afterwards
        let n = AtomicUsize::new(0);
        run(32, 4, |_| {
            n.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(n.load(Ordering::SeqCst), 32);
    }

    #[test]
    fn nested_submission_completes() {
        let n = AtomicUsize::new(0);
        run(4, 4, |_| {
            run(8, 2, |_| {
                n.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(n.load(Ordering::SeqCst), 32);
    }

    #[test]
    fn worker_count_is_bounded() {
        run(64, 8, |_| {});
        let g = gauges();
        assert!(g.workers <= MAX_WORKERS, "{} workers", g.workers);
        assert!(g.tasks >= 64);
    }

    #[test]
    fn urgent_submits_complete_and_restore_the_flag() {
        let n = AtomicUsize::new(0);
        let before = gauges().urgent;
        urgent(|| {
            run(16, 4, |_| {
                n.fetch_add(1, Ordering::SeqCst);
            });
        });
        assert_eq!(n.load(Ordering::SeqCst), 16);
        assert!(gauges().urgent > before, "urgent submit must be counted");
        assert!(!URGENT.with(|c| c.get()), "flag must restore after the scope");
        // panic inside the scope still restores the flag
        let _ = catch_unwind(AssertUnwindSafe(|| urgent(|| panic!("boom"))));
        assert!(!URGENT.with(|c| c.get()), "flag must restore after a panic");
    }
}
