//! # SFC — Symbolic Fourier Convolution
//!
//! Full-system reproduction of *"SFC: Achieve Accurate Fast Convolution
//! under Low-precision Arithmetic"* (He et al., ICML 2024) as a
//! three-layer Rust + JAX + Pallas stack.
//!
//! Layer map (see DESIGN.md):
//! * [`algo`] — the paper's algorithm family: symbolic-DFT fast
//!   convolution with correction terms, plus Winograd/FFT/NTT baselines.
//! * [`linalg`] — exact rational matrices + Jacobi SVD (condition numbers).
//! * [`nn`] / [`quant`] — the quantized inference engine reproducing the
//!   PTQ experiments (§6.1, Tables 2/4/5, Figs. 4/5).
//! * [`data`] — SynthImage dataset (ImageNet stand-in, DESIGN.md §2).
//! * [`util`] — PRNG / fp16 / timing / parallel-for shims.

pub mod algo;
pub mod bops;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod exp;
pub mod fpga;
pub mod linalg;
pub mod runtime;
pub mod nn;
pub mod quant;
pub mod util;
