//! # SFC — Symbolic Fourier Convolution
//!
//! Full-system reproduction of *"SFC: Achieve Accurate Fast Convolution
//! under Low-precision Arithmetic"* (He et al., ICML 2024) as a
//! three-layer Rust + JAX + Pallas stack.
//!
//! Layer map (see DESIGN.md and ENGINE.md):
//! * [`algo`] — the paper's algorithm family built from exact rational
//!   arithmetic: symbolic-DFT fast convolution with correction terms,
//!   Winograd/Toom-Cook, plus the FFT/NTT related-work baselines. Its
//!   [`algo::registry`] catalog (Table 1 + Table 3 rows) is the single
//!   source of algorithm truth.
//! * [`engine`] — the unified convolution API: [`engine::ConvDesc`]
//!   problem descriptors (stride/pad, channel `groups` up to depthwise,
//!   `dilation` executed by direct/im2col, quantization; assembled via
//!   [`engine::ConvDescBuilder`]), the [`engine::ConvEngine`] trait
//!   implemented by direct / im2col / Winograd / SFC / FFT / NTT
//!   backends plus the overlap-save [`engine::tiled`] FFT/NTT engines
//!   with kernel-derived, image-independent workspace bounds (envelopes
//!   documented by the generated ENGINE.md support matrix,
//!   [`engine::support_matrix_markdown`]), shape-keyed
//!   [`engine::PlanCache`] plan reuse, the [`engine::Selector`] with
//!   BOPs-heuristic and measured-autotune policies (`sfc autotune`), and
//!   the [`engine::Workspace`] arena behind the zero-alloc
//!   `ConvPlan::run_into` execution path (see ENGINE.md §Memory model).
//! * [`linalg`] — exact rational matrices + Jacobi SVD (condition
//!   numbers), plus [`linalg::gemm`]: the blocked, register-tiled
//!   `f32`/`i8→i32` GEMM core every executor's ⊙ reduction runs on —
//!   threaded BLIS/Goto-style (B panels packed once and shared, workers
//!   consume disjoint row bands; `SFC_THREADS`), with per-kernel
//!   [`linalg::gemm::Blocking`] (Mc/Kc/Nc) cache blocking the autotuner
//!   can sweep — and [`linalg::simd`]: the runtime-dispatched kernel
//!   layer (one-time CPU detection → AVX2 / NEON microkernels over
//!   packed B panels, scalar fallback, `SFC_FORCE_SCALAR=1` override) —
//!   every arm × every thread count bit-identical to the scalar
//!   reference (see ENGINE.md §Kernel dispatch, §Threading model).
//!   Bilinear plans pre-transform + pre-pack weights at plan time
//!   ([`engine::PackedWeights`], `ConvPlan::run_packed_into`).
//! * [`nn`] / [`quant`] — the CNN inference substrate (ResNet family +
//!   the depthwise-separable [`nn::model::mobilenet_cfg`] topology) and
//!   the PTQ pipeline reproducing §6.1 (Tables 2/4/5, Figs. 4/5); conv
//!   layers execute through engine plans (`Model::forward_ws` recycles
//!   activations through a per-forward workspace), quantized layers
//!   through [`quant::qconv::QConvLayer`] built from the same plans —
//!   grouped and depthwise included. [`nn::passes`] is the graph
//!   compiler ([`nn::Model::compile`], `sfc graph`): conv+bias+ReLU
//!   epilogue fusion (the [`engine::Epilogue`] carried on descriptors
//!   and applied inside executor output loops), Add+ReLU fusion,
//!   dead-node elimination, and the int8-dataflow pass that keeps
//!   activations in int8 ([`quant::QTensor`]) between consecutive
//!   spatially-quantized convs via per-channel fixed-point
//!   requantization ([`quant::Requant`], ENGINE.md §Graph compilation).
//! * [`bops`] / [`error`] / [`fpga`] — the analytical models: §6 BOPs
//!   (feeding the engine cost models), Table-1 numerical error, Table-3
//!   FPGA accelerator comparison.
//! * [`runtime`] / [`coordinator`] — serving: PJRT executor over AOT
//!   artifacts (feature `pjrt`; clean stub otherwise), the pure-Rust
//!   [`runtime::EngineExecutor`] over the engine stack, and the
//!   multi-model scheduler [`coordinator::sched::MultiServer`] —
//!   continuous batching by per-request deadline, priority-based
//!   admission control and typed load shedding, resident models sharing
//!   the plan cache under a packed-weight budget
//!   ([`engine::PackBudget`]). Two dispatch policies (`--sched`):
//!   per-model workers each owning one workspace (zero-alloc steady
//!   state), or the cost-model-driven global batch planner — candidate
//!   batches from every model ranked by cost-aware EDF (predictions
//!   seeded from the tuning table, refined online), speculative batch
//!   splitting, and workspaces leased from the shared byte-accounted
//!   [`engine::WorkspacePool`]. Streaming p50/p99 latency histograms
//!   ([`coordinator::metrics::StreamingHistogram`]) and per-model
//!   gauges. [`coordinator::batcher::Server`] is the single-model shim;
//!   `sfc loadgen` ([`exp::loadgen`]) is the overload measurement
//!   harness with a BENCH_serve.json snapshot writer (ENGINE.md
//!   §Serving & scheduling).
//! * [`data`] — SynthImage dataset (ImageNet stand-in, DESIGN.md §2).
//! * [`exp`] — experiment harnesses regenerating the paper's tables, and
//!   [`exp::perf`]: the `sfc bench --json` perf-snapshot harness
//!   (BENCH_conv.json, tracked across PRs).
//! * [`util`] — PRNG / fp16 / timing shims, [`util::pool`]: the
//!   persistent work-stealing executor pool every parallel region runs
//!   on (lazily spawned process-lived workers, per-worker deques + an
//!   injector queue, gauges via [`coordinator::metrics::pool_gauges`]),
//!   and [`util::par`]: the data-parallel helpers over it plus the
//!   process-wide [`util::par::CoreBudget`] lane budget that keeps
//!   model workers × intra-op GEMM threads from oversubscribing the
//!   host (observable via [`coordinator::metrics::core_budget`], capped
//!   with `sfc serve --cores N`).
#![warn(missing_docs)]

pub mod algo;
pub mod bops;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod error;
pub mod exp;
pub mod fpga;
pub mod linalg;
pub mod nn;
pub mod quant;
pub mod runtime;
pub mod util;
