//! PJRT runtime: load AOT-compiled JAX/Pallas artifacts (HLO text) and
//! execute them from the Rust request path.
//!
//! Interchange is HLO **text** (not serialized protos): jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md and DESIGN.md).
//! Python never runs at serving time — `make artifacts` is the only
//! python invocation.
//!
//! The real executor needs the vendored `xla` crate and is gated behind
//! the `pjrt` feature (add the dependency to Cargo.toml when enabling).
//! Without it a stub with the identical API returns clean errors, so the
//! serving stack (batcher, CLI, benches) builds and tests everywhere.

#[cfg(feature = "pjrt")]
mod pjrt_impl {
    use anyhow::{Context, Result};
    use std::path::Path;

    /// A compiled model executable on the PJRT CPU client.
    pub struct Executor {
        client: xla::PjRtClient,
        exe: xla::PjRtLoadedExecutable,
        /// expected input shape (NCHW)
        pub input_dims: Vec<usize>,
        /// number of classes in the logits output
        pub out_classes: usize,
    }

    impl Executor {
        /// Load an HLO-text artifact and compile it for CPU.
        pub fn load(hlo_path: &Path, input_dims: &[usize], out_classes: usize) -> Result<Executor> {
            let client = xla::PjRtClient::cpu().map_err(anyhow_xla)?;
            let proto = xla::HloModuleProto::from_text_file(
                hlo_path.to_str().context("non-utf8 path")?,
            )
            .map_err(anyhow_xla)
            .with_context(|| format!("parse {}", hlo_path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).map_err(anyhow_xla)?;
            Ok(Executor { client, exe, input_dims: input_dims.to_vec(), out_classes })
        }

        /// PJRT platform name (e.g. "cpu").
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Run one batch: input is NCHW f32 with dims == input_dims;
        /// returns the [N, classes] logits.
        pub fn run(&self, batch: &[f32]) -> Result<Vec<f32>> {
            let expect: usize = self.input_dims.iter().product();
            anyhow::ensure!(
                batch.len() == expect,
                "batch size mismatch: {} vs {}",
                batch.len(),
                expect
            );
            let dims: Vec<i64> = self.input_dims.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(batch).reshape(&dims).map_err(anyhow_xla)?;
            let result = self.exe.execute::<xla::Literal>(&[lit]).map_err(anyhow_xla)?;
            let out = result[0][0].to_literal_sync().map_err(anyhow_xla)?;
            // jax lowering uses return_tuple=True → 1-tuple
            let out = out.to_tuple1().map_err(anyhow_xla)?;
            let v = out.to_vec::<f32>().map_err(anyhow_xla)?;
            Ok(v)
        }

        /// Batch size the artifact was compiled for.
        pub fn batch_size(&self) -> usize {
            self.input_dims[0]
        }
    }

    fn anyhow_xla(e: xla::Error) -> anyhow::Error {
        anyhow::anyhow!("xla: {e}")
    }
}

#[cfg(not(feature = "pjrt"))]
mod pjrt_impl {
    use anyhow::Result;
    use std::path::Path;

    /// Stub executor: same API as the PJRT-backed one, every entry point
    /// returns a clean "feature disabled" error. Artifact-dependent tests
    /// and benches skip on the missing artifacts before reaching it.
    pub struct Executor {
        /// expected input shape (NCHW)
        pub input_dims: Vec<usize>,
        /// number of classes in the logits output
        pub out_classes: usize,
    }

    impl Executor {
        /// Always errors: the `pjrt` feature is disabled.
        pub fn load(hlo_path: &Path, _input_dims: &[usize], _out_classes: usize) -> Result<Executor> {
            anyhow::bail!(
                "PJRT runtime disabled (build with `--features pjrt` and the vendored `xla` \
                 crate); cannot load {}",
                hlo_path.display()
            )
        }

        /// Always "stub".
        pub fn platform(&self) -> String {
            "stub".into()
        }

        /// Always errors: the `pjrt` feature is disabled.
        pub fn run(&self, _batch: &[f32]) -> Result<Vec<f32>> {
            anyhow::bail!("PJRT runtime disabled (build with `--features pjrt`)")
        }

        /// Batch size from the configured input dims.
        pub fn batch_size(&self) -> usize {
            self.input_dims[0]
        }
    }
}

pub use pjrt_impl::Executor;

use crate::engine::{PackBudget, Workspace};
use crate::nn::{Model, PrepackReport, Tensor};
use anyhow::Result;

/// A pure-Rust executor over the engine stack: the same batch-in /
/// logits-out surface as the PJRT [`Executor`], but running the
/// [`Model`] graph through workspace-backed conv plans. This is the
/// serving path that needs no AOT artifacts and no `pjrt` feature —
/// and, given a long-lived [`Workspace`] via
/// [`EngineExecutor::run_with`], does zero workspace heap allocation
/// per batch in steady state.
pub struct EngineExecutor {
    model: Model,
    /// expected input shape (NCHW)
    pub input_dims: Vec<usize>,
    /// number of classes in the logits output
    pub out_classes: usize,
}

impl EngineExecutor {
    /// Executor over a built model (NCHW `input_dims`, index 0 = batch).
    /// The graph is compiled first ([`Model::compile`]: conv+ReLU
    /// epilogue fusion, Add+ReLU fusion, dead-node elimination, and —
    /// for PTQ'd models — the int8-dataflow pass that keeps activations
    /// in int8 between consecutive quantized convs), then weights of
    /// float conv layers are pre-transformed + pre-packed (plan time),
    /// so the serving hot path runs
    /// [`crate::engine::ConvPlan::run_packed_into`] over pre-packed
    /// operands only — bit-identical to the per-call path.
    pub fn from_model(model: Model, input_dims: Vec<usize>, out_classes: usize) -> EngineExecutor {
        EngineExecutor::from_model_budgeted(
            model,
            input_dims,
            out_classes,
            &PackBudget::unlimited(),
        )
        .0
    }

    /// Like [`EngineExecutor::from_model`] but pre-packing under a
    /// [`PackBudget`]: layers that would overrun the process-wide
    /// packed-weight budget are left unpacked (they serve through the
    /// bit-identical per-call path). Returns the executor and the
    /// packed-vs-skipped report, so callers (the multi-model scheduler,
    /// `sfc loadgen`) can surface the budget decision.
    pub fn from_model_budgeted(
        model: Model,
        input_dims: Vec<usize>,
        out_classes: usize,
        budget: &PackBudget,
    ) -> (EngineExecutor, PrepackReport) {
        assert_eq!(input_dims.len(), 4, "NCHW input dims expected, got {input_dims:?}");
        let mut model = model;
        model.compile();
        let report = model.prepack_weights_budgeted(budget);
        (EngineExecutor { model, input_dims, out_classes }, report)
    }

    /// Always "rust-engine".
    pub fn platform(&self) -> String {
        "rust-engine".into()
    }

    /// Batch size from the configured input dims.
    pub fn batch_size(&self) -> usize {
        self.input_dims[0]
    }

    /// Run one batch out of a caller workspace: input is NCHW f32 with
    /// dims == `input_dims`; returns the [N, classes] logits. The batch
    /// is copied once, into an arena buffer the graph's `Input` node
    /// takes ownership of (`forward_ws_owned`). Allocates the returned
    /// logits vector — batch loops that reuse a staging buffer should
    /// call [`EngineExecutor::run_with_into`] instead.
    pub fn run_with(&self, batch: &[f32], ws: &mut Workspace) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.run_with_into(batch, ws, &mut out)?;
        Ok(out)
    }

    /// Like [`EngineExecutor::run_with`] but writing the logits into a
    /// caller buffer (cleared, then extended to [N, classes]): with a
    /// long-lived `out` this path performs **zero** heap allocation per
    /// batch in steady state — the output tensor's arena buffer goes
    /// straight back to the workspace instead of being cloned.
    pub fn run_with_into(
        &self,
        batch: &[f32],
        ws: &mut Workspace,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let expect: usize = self.input_dims.iter().product();
        anyhow::ensure!(batch.len() == expect, "batch size mismatch: {} vs {expect}", batch.len());
        let mut xbuf = ws.take_f32(expect);
        xbuf.copy_from_slice(batch);
        let x = Tensor::from_vec(&self.input_dims, xbuf);
        let y = self.model.forward_ws_owned(x, ws);
        let n = self.input_dims[0];
        anyhow::ensure!(
            y.len() == n * self.out_classes,
            "model produced {} logits, expected {}x{}",
            y.len(),
            n,
            self.out_classes
        );
        out.clear();
        out.extend_from_slice(&y.data);
        ws.give_f32(y.data);
        Ok(())
    }

    /// Run one batch with a throwaway workspace.
    pub fn run(&self, batch: &[f32]) -> Result<Vec<f32>> {
        let mut ws = Workspace::new();
        self.run_with(batch, &mut ws)
    }
}

#[cfg(test)]
mod tests {
    // Executor integration tests live in rust/tests/runtime_e2e.rs (they
    // need the build-time artifacts); here we only check error paths.
    use super::*;
    use std::path::Path;

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let err = Executor::load(Path::new("/nonexistent/model.hlo.txt"), &[1, 3, 32, 32], 10);
        assert!(err.is_err());
    }
}
