//! The bit-operations (BOPs) cost metric of §6.
//!
//! n-bit add = n BOPs; n-bit multiply = n(n−1) BOPs (an n-bit multiply
//! decomposes into n−1 n-bit additions). Fast-algorithm transform
//! additions are charged at their grown bit-width (‖Bᵀ‖∞ growth over the
//! input width), and the ⊙ stage at the transform-domain quantized width.
//! Accumulation across channels is charged as 32-bit adds for every
//! method (the common int32 accumulator).

use crate::algo::Bilinear;
use crate::nn::model::ConvShape;

/// Accumulator width charged for cross-channel reduction (the common
/// int32 accumulator, every method).
pub const ACC_BITS: u64 = 32;

/// Per-stage BOPs of one conv layer under one execution scheme.
#[derive(Clone, Copy, Debug)]
pub struct BopsBreakdown {
    /// input-transform additions (Bᵀ·x·B), at the grown bit-width
    pub transform_in: u64,
    /// output-transform additions (Aᵀ·y·A), at accumulator width
    pub transform_out: u64,
    /// the ⊙ stage's multiplications
    pub multiply: u64,
    /// cross-channel accumulation (int32 adds)
    pub accumulate: u64,
}

impl BopsBreakdown {
    /// Sum of all four stages.
    pub fn total(&self) -> u64 {
        self.transform_in + self.transform_out + self.multiply + self.accumulate
    }
}

/// BOPs of one n-bit multiply (n−1 n-bit additions). Shared by the
/// direct/fast models below and the engine-layer cost models.
pub fn mul_bops(bits: u64) -> u64 {
    bits * (bits.saturating_sub(1))
}

/// BOPs for one conv layer executed directly at `a_bits`×`w_bits`.
pub fn direct_bops(shape: &ConvShape, a_bits: u64, w_bits: u64) -> BopsBreakdown {
    direct_bops_grouped(shape, 1, a_bits, w_bits)
}

/// Grouped-direct BOPs: each output channel reduces over only its
/// group's `ic/groups` input channels, so MACs shrink by `groups`
/// (depthwise = `groups == ic`).
pub fn direct_bops_grouped(
    shape: &ConvShape,
    groups: u64,
    a_bits: u64,
    w_bits: u64,
) -> BopsBreakdown {
    direct_bops_grouped_dilated(shape, groups, 1, a_bits, w_bits)
}

/// Grouped-direct BOPs with dilation. The tap count per output stays
/// `r²` — dilation spreads the taps without adding any — but the
/// effective kernel reach `(r−1)·(dilation−1)` shrinks the output plane
/// under same-padding bookkeeping, so total MACs drop slightly.
/// Reduces exactly to [`direct_bops_grouped`] at `dilation == 1`.
pub fn direct_bops_grouped_dilated(
    shape: &ConvShape,
    groups: u64,
    dilation: u64,
    a_bits: u64,
    w_bits: u64,
) -> BopsBreakdown {
    let stride = (shape.stride as u64).max(1);
    let reach = (shape.r as u64).saturating_sub(1) * dilation.max(1).saturating_sub(1);
    let oh = (shape.h as u64).saturating_sub(reach) / stride;
    let ow = (shape.w as u64).saturating_sub(reach) / stride;
    let macs =
        oh * ow * shape.oc as u64 * shape.ic as u64 * (shape.r * shape.r) as u64 / groups.max(1);
    let mbits = a_bits.max(w_bits);
    BopsBreakdown {
        transform_in: 0,
        transform_out: 0,
        multiply: macs * mul_bops(mbits),
        accumulate: macs * ACC_BITS,
    }
}

/// BOPs for one conv layer executed with a tiled bilinear fast algorithm
/// whose transform-domain operands are quantized to `a_bits`/`w_bits`.
/// The filter transform is amortized (weights transformed once offline).
pub fn fast_bops(shape: &ConvShape, algo: &Bilinear, a_bits: u64, w_bits: u64) -> BopsBreakdown {
    fast_bops_grouped(shape, algo, 1, a_bits, w_bits)
}

/// Grouped tiled-bilinear BOPs. The input/output transforms touch every
/// channel exactly once regardless of grouping, but the per-frequency ⊙
/// reduction runs `groups` independent `[tiles×IC/g]·[IC/g×OC/g]`
/// blocks, so the multiply/accumulate terms shrink by `groups`.
pub fn fast_bops_grouped(
    shape: &ConvShape,
    algo: &Bilinear,
    groups: u64,
    a_bits: u64,
    w_bits: u64,
) -> BopsBreakdown {
    assert_eq!(shape.r, algo.r, "algorithm kernel mismatch");
    assert_eq!(shape.stride, 1, "fast conv is stride-1");
    let m = algo.m as u64;
    let t = algo.t as u64;
    let tiles = (shape.h as u64).div_ceil(m) * (shape.w as u64).div_ceil(m);
    let ic = shape.ic as u64;
    let oc = shape.oc as u64;

    // Input transform: per tile/channel, 2·(Bᵀ nnz−rows) adds at the grown
    // width (input a_bits + log2‖Bᵀ‖∞ growth).
    let bt_adds_1d = algo.bt.add_count() as u64;
    let l = algo.input_len() as u64;
    let in_growth = algo.bt.linf_norm().log2().ceil().max(0.0) as u64;
    let in_bits = a_bits + in_growth;
    // row pass: t rows applied over l columns; col pass over t rows
    let in_adds_per_tile = bt_adds_1d * l + bt_adds_1d * t;
    let transform_in = tiles * ic * in_adds_per_tile * in_bits;

    // ⊙: T² mults per (tile, within-group ic→oc pair) at quantized
    // width + i32 accumulate
    let odot = tiles * ic * oc * t * t / groups.max(1);
    let multiply = odot * mul_bops(a_bits.max(w_bits));
    let accumulate = odot * ACC_BITS;

    // Output transform: per tile/out-channel at accumulator width.
    let at_adds_1d = algo.at.add_count() as u64;
    let out_adds_per_tile = at_adds_1d * t + at_adds_1d * m;
    let transform_out = tiles * oc * out_adds_per_tile * ACC_BITS;

    BopsBreakdown { transform_in, transform_out, multiply, accumulate }
}

/// Total GBOPs for a set of conv layers under a uniform scheme.
pub fn model_gbops(
    shapes: &[(String, ConvShape)],
    algo: Option<&Bilinear>,
    a_bits: u64,
    w_bits: u64,
) -> f64 {
    let mut total = 0u64;
    for (_, s) in shapes {
        let b = match algo {
            Some(a) if s.r == a.r && s.stride == 1 => fast_bops(s, a, a_bits, w_bits),
            _ => direct_bops(s, a_bits, w_bits),
        };
        total += b.total();
    }
    total as f64 / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{sfc, winograd};

    fn shape() -> ConvShape {
        ConvShape { ic: 64, oc: 64, h: 56, w: 56, r: 3, stride: 1 }
    }

    #[test]
    fn mul_bops_formula() {
        assert_eq!(mul_bops(8), 56);
        assert_eq!(mul_bops(4), 12);
        assert_eq!(mul_bops(1), 0);
    }

    #[test]
    fn fast_beats_direct_at_int8() {
        let s = shape();
        let d = direct_bops(&s, 8, 8).total();
        let f = fast_bops(&s, &sfc(6, 7, 3), 8, 8).total();
        assert!(f < d, "SFC {f} < direct {d}");
        // the multiply term alone shrinks by ~the complexity ratio
        let fm = fast_bops(&s, &sfc(6, 7, 3), 8, 8).multiply as f64;
        let dm = direct_bops(&s, 8, 8).multiply as f64;
        assert!((fm / dm - 144.0 / 441.0).abs() < 0.05, "mult ratio {}", fm / dm);
    }

    #[test]
    fn sfc_beats_winograd_at_low_bits() {
        // Fig. 4's x-axis story: at the accuracy-equivalent bit-width SFC
        // spends fewer BOPs. At iso-bits SFC-6(7,3) ≈ Wino(4,3) on ⊙ but
        // Wino needs more bits for iso-accuracy.
        let s = shape();
        let sfc8 = fast_bops(&s, &sfc(6, 7, 3), 8, 8).total() as f64;
        let win8 = fast_bops(&s, &winograd(4, 3), 8, 8).total() as f64;
        assert!((sfc8 / win8) < 1.35, "iso-bit ratio {}", sfc8 / win8);
        // Winograd at the bits it needs for SFC-int6-level accuracy (int8)
        // vs SFC at int6:
        let sfc6 = fast_bops(&s, &sfc(6, 7, 3), 6, 6).total() as f64;
        assert!(sfc6 < win8, "SFC int6 {sfc6} < Wino int8 {win8}");
    }

    #[test]
    fn transforms_are_minor_cost_at_scale() {
        // §3's amortization assumption: with 64→64 channels the transform
        // adds are a small fraction of the ⊙ cost.
        let b = fast_bops(&shape(), &sfc(6, 6, 3), 8, 8);
        let frac = (b.transform_in + b.transform_out) as f64 / b.total() as f64;
        assert!(frac < 0.2, "transform fraction {frac}");
    }

    #[test]
    fn grouped_bops_scale_only_the_odot_terms() {
        let s = shape();
        let dense = direct_bops(&s, 8, 8).total();
        let g4 = direct_bops_grouped(&s, 4, 8, 8).total();
        assert_eq!(dense, 4 * g4, "direct BOPs shrink by the group count");
        let a = sfc(6, 7, 3);
        let f_dense = fast_bops(&s, &a, 8, 8);
        let f_dw = fast_bops_grouped(&s, &a, s.ic as u64, 8, 8);
        assert_eq!(f_dense.transform_in, f_dw.transform_in, "transforms touch every channel");
        assert_eq!(f_dense.transform_out, f_dw.transform_out);
        assert_eq!(f_dense.multiply, f_dw.multiply * s.ic as u64, "⊙ shrinks by groups");
    }

    #[test]
    fn dilated_bops_reduce_to_grouped_at_dilation_one() {
        let s = shape();
        let undilated = direct_bops_grouped(&s, 4, 8, 8);
        let d1 = direct_bops_grouped_dilated(&s, 4, 1, 8, 8);
        assert_eq!(undilated.total(), d1.total(), "dilation 1 is the historical model");
        // dilation shrinks the output plane, never grows the tap count
        let d2 = direct_bops_grouped_dilated(&s, 4, 2, 8, 8);
        assert!(d2.total() < d1.total(), "d2 {} < d1 {}", d2.total(), d1.total());
        assert!(d2.total() > 0);
    }

    #[test]
    fn model_gbops_mixes_algorithms() {
        let shapes = vec![
            ("a".into(), ConvShape { ic: 3, oc: 16, h: 32, w: 32, r: 3, stride: 1 }),
            ("b".into(), ConvShape { ic: 16, oc: 16, h: 32, w: 32, r: 1, stride: 1 }), // 1×1 stays direct
        ];
        let a = sfc(6, 6, 3);
        let g = model_gbops(&shapes, Some(&a), 8, 8);
        assert!(g > 0.0);
        let g_direct = model_gbops(&shapes, None, 8, 8);
        assert!(g < g_direct);
    }
}
