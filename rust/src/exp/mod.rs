//! Experiment harnesses for the weight/data-dependent tables and figures
//! (Table 2/4/5, Fig. 3/4/5). Each `cmd_*` regenerates one paper artifact
//! from `artifacts/` (trained weights + SynthImage splits) and prints the
//! paper's reference values alongside.
//!
//! Sizes are scaled to the substrate (DESIGN.md §2): calibration 128
//! images (paper: 500), evaluation 256 images (paper: 50k val set);
//! override with SFC_CALIB_N / SFC_EVAL_N.

pub mod loadgen;
pub mod perf;

use crate::data::Dataset;
use crate::engine::{default_selector, ConvDesc, QuantSpec};
use crate::nn::model::{model_conv_shapes, resnet18_cfg, resnet34_cfg, resnet50_cfg, resnet_from_weights, ResNetCfg};
use crate::nn::weights::WeightMap;
use crate::nn::{FastConvPlan, Model, Tensor};
use crate::quant::calib::{dequantize_model, layer_mse, quantize_model, QuantConfig};
use crate::quant::Granularity;
use anyhow::{Context, Result};
use std::path::Path;

fn env_n(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Calibration-set size (SFC_CALIB_N override).
pub fn calib_n() -> usize {
    env_n("SFC_CALIB_N", 128)
}

/// Evaluation-set size (SFC_EVAL_N override).
pub fn eval_n() -> usize {
    env_n("SFC_EVAL_N", 256)
}

/// Load a dataset split as one NCHW tensor + labels.
pub fn load_split(data_dir: &str, split: &str, n: usize) -> Result<(Tensor, Vec<u8>)> {
    let ds = Dataset::load(&Path::new(data_dir).join(format!("dataset_{split}.bin")))
        .with_context(|| format!("run `sfc gen-data` / `make artifacts` first"))?;
    let ds = ds.take(n);
    let mut t = Tensor::zeros(&[ds.n, ds.c, ds.h, ds.w]);
    t.data.copy_from_slice(&ds.images);
    Ok((t, ds.labels))
}

/// Load a trained mini-ResNet from the artifacts directory.
pub fn load_model(data_dir: &str, name: &str) -> Result<Model> {
    let cfg: ResNetCfg = match name {
        "resnet18" => resnet18_cfg(),
        "resnet34" => resnet34_cfg(),
        "resnet50" => resnet50_cfg(),
        other => anyhow::bail!("unknown model {other}"),
    };
    let map = WeightMap::load(&Path::new(data_dir).join(format!("{name}.w32")))
        .with_context(|| "run `make artifacts` to train the mini models")?;
    Ok(resnet_from_weights(&cfg, &map, 10))
}

fn eval_acc(model: &Model, images: &Tensor, labels: &[u8]) -> f64 {
    // batch to bound memory
    let n = images.dims[0];
    let bs = 32;
    let mut correct = 0.0;
    for start in (0..n).step_by(bs) {
        let end = (start + bs).min(n);
        let dims = [end - start, images.dims[1], images.dims[2], images.dims[3]];
        let len = dims.iter().product::<usize>();
        let off = start * images.dims[1] * images.dims[2] * images.dims[3];
        let batch = Tensor::from_vec(&dims, images.data[off..off + len].to_vec());
        correct += model.accuracy(&batch, &labels[start..end]) * (end - start) as f64;
    }
    correct / n as f64
}

struct Row {
    method: &'static str,
    algo: &'static str,
    bits: u32,
    acc: f64,
    delta: f64,
}

fn quantize_and_eval(
    model: &mut Model,
    calib: &Tensor,
    images: &Tensor,
    labels: &[u8],
    cfg: &QuantConfig,
) -> f64 {
    quantize_model(model, calib, cfg);
    let acc = eval_acc(model, images, labels);
    dequantize_model(model);
    acc
}

/// Table 2 — PTQ accuracy, Wino(4,3) vs SFC-6(7,3), int8/int6.
pub fn cmd_table2(data_dir: &str, models: &str, bits_list: &str) -> Result<()> {
    let (calib, _) = load_split(data_dir, "train", calib_n())?;
    let (images, labels) = load_split(data_dir, "test", eval_n())?;
    let mut bits: Vec<u32> = Vec::new();
    for b in bits_list.split(',') {
        bits.push(
            b.trim()
                .parse::<u32>()
                .map_err(|e| anyhow::anyhow!("invalid --bits entry '{b}': {e}"))?,
        );
    }
    println!("Table 2 — post-training quantization on SynthImage (ImageNet stand-in)\n");
    println!("paper reference (ImageNet): Wino(4,3) int8 Δ≈−1.6..−2.2, int6 Δ≈−4.5..−5.4;");
    println!("                            SFC-6(7,3) int8 Δ≈−0.12..−0.17, int6 Δ≈−0.6..−1.0\n");
    for model_name in models.split(',') {
        let mut model = load_model(data_dir, model_name)?;
        let fp32 = eval_acc(&model, &images, &labels);
        println!("{model_name}: fp32 top-1 = {:.2}%", fp32 * 100.0);
        let mut rows: Vec<Row> = Vec::new();
        for &b in &bits {
            let wino = quantize_and_eval(
                &mut model, &calib, &images, &labels,
                &QuantConfig::winograd_default(b),
            );
            rows.push(Row { method: "Full Quant.", algo: "Wino(4x4,3x3)", bits: b, acc: wino, delta: wino - fp32 });
            let s = quantize_and_eval(
                &mut model, &calib, &images, &labels,
                &QuantConfig::sfc_default(b),
            );
            rows.push(Row { method: "Ours", algo: "SFC6(7x7,3x3)", bits: b, acc: s, delta: s - fp32 });
        }
        println!(
            "  {:<14} {:<16} {:>5} {:>8} {:>8}",
            "Method", "Algorithm", "Bits", "Top-1", "Δ"
        );
        for r in rows {
            println!(
                "  {:<14} {:<16} {:>5} {:>7.2}% {:>+7.2}%",
                r.method,
                r.algo,
                r.bits,
                r.acc * 100.0,
                r.delta * 100.0
            );
        }
        println!();
    }
    Ok(())
}

/// Table 4 — quantization granularity ablation at int8.
pub fn cmd_table4(data_dir: &str) -> Result<()> {
    let (calib, _) = load_split(data_dir, "train", calib_n())?;
    let (images, labels) = load_split(data_dir, "test", eval_n())?;
    let mut model = load_model(data_dir, "resnet18")?;
    let fp32 = eval_acc(&model, &images, &labels);
    println!("Table 4 — granularity ablation, int8, resnet18 (fp32 = {:.2}%)\n", fp32 * 100.0);
    println!("{:<18} {:<12} {:<16} {:>8}", "Algorithm", "Activation", "Filter", "Top-1");
    let combos: [(&str, &str, Granularity, Granularity); 6] = [
        ("SFC-6(7x7,3x3)", "Tensor/Channel", Granularity::Tensor, Granularity::Channel),
        ("SFC-6(7x7,3x3)", "Freq/Channel", Granularity::Freq, Granularity::Channel),
        ("SFC-6(7x7,3x3)", "Freq/Freq", Granularity::Freq, Granularity::Freq),
        ("SFC-6(7x7,3x3)", "Freq/Chan+Freq", Granularity::Freq, Granularity::ChannelFreq),
        ("Wino(4x4,3x3)", "Tensor/Channel", Granularity::Tensor, Granularity::Channel),
        ("Wino(4x4,3x3)", "Freq/Chan+Freq", Granularity::Freq, Granularity::ChannelFreq),
    ];
    for (algo_name, label, a_gran, w_gran) in combos {
        let cfg = QuantConfig {
            engine: Some(algo_name),
            w_bits: 8,
            a_bits: 8,
            w_gran,
            a_gran,
            adaquant: true,
        };
        let acc = quantize_and_eval(&mut model, &calib, &images, &labels, &cfg);
        let (a_label, w_label) = label.split_once('/').unwrap();
        println!("{:<18} {:<12} {:<16} {:>7.2}%", algo_name, a_label, w_label, acc * 100.0);
    }
    println!("\npaper: SFC barely cares (69.18→69.58); Wino(4,3) collapses at Tensor (57.40 vs 67.62).");
    Ok(())
}

/// Table 5 — granularity × bit-width for SFC-6(7,3).
pub fn cmd_table5(data_dir: &str) -> Result<()> {
    let (calib, _) = load_split(data_dir, "train", calib_n())?;
    let (images, labels) = load_split(data_dir, "test", eval_n())?;
    let mut model = load_model(data_dir, "resnet18")?;
    let fp32 = eval_acc(&model, &images, &labels);
    println!("Table 5 — SFC-6(7x7,3x3) granularity × bit-width, resnet18 (fp32 = {:.2}%)\n", fp32 * 100.0);
    println!("{:<28} {:>8} {:>8} {:>8}", "Quant. granularity", "int8", "int6", "int4");
    let rows: [(&str, Granularity, Granularity); 3] = [
        ("A: Tensor, W: Channel", Granularity::Tensor, Granularity::Channel),
        ("A: Freq,   W: Channel", Granularity::Freq, Granularity::Channel),
        ("A: Freq,   W: Freq+Channel", Granularity::Freq, Granularity::ChannelFreq),
    ];
    for (label, a_gran, w_gran) in rows {
        let mut accs = Vec::new();
        for bits in [8u32, 6, 4] {
            let cfg = QuantConfig {
                engine: Some("SFC-6(7x7,3x3)"),
                w_bits: bits,
                a_bits: bits,
                w_gran,
                a_gran,
                adaquant: true,
            };
            accs.push(quantize_and_eval(&mut model, &calib, &images, &labels, &cfg));
        }
        println!(
            "{:<28} {:>7.2}% {:>7.2}% {:>7.2}%",
            label,
            accs[0] * 100.0,
            accs[1] * 100.0,
            accs[2] * 100.0
        );
    }
    println!("\npaper: finer granularity matters more as bits shrink (17.81 → 55.82 at int4).");
    Ok(())
}

/// Fig. 3 — transform-domain energy distribution of a mid-network layer.
pub fn cmd_fig3(data_dir: &str) -> Result<()> {
    let (images, _) = load_split(data_dir, "test", 64.min(eval_n()))?;
    let model = load_model(data_dir, "resnet18")?;
    let acts = model.forward_all(&images);
    // the paper probes the 9th conv layer of ResNet-18
    let conv_nodes = model.conv_nodes();
    let probe = conv_nodes[8.min(conv_nodes.len() - 1)];
    let input_act = &acts[model.nodes[probe].inputs[0]];
    let (_, ic, h, w) = input_act.dims4();
    let desc = ConvDesc::new(1, ic, ic, h, w, 3, 1, 1)
        .with_quant(QuantSpec::transform_default(8));
    let plan = default_selector()
        .plan_named("SFC-6(7x7,3x3)", &desc)
        .expect("SFC engine supports 3x3 stride-1");
    let plan = plan.fast_plan().expect("bilinear plan");
    let maxima_energy = energy_per_frequency(input_act, plan);
    let t = plan.t();
    println!(
        "Fig. 3 — mean transform-domain energy, layer '{}' input ({}x{} SFT grid)\n",
        model.nodes[probe].name, t, t
    );
    let max = maxima_energy.iter().cloned().fold(0.0f64, f64::max);
    for u in 0..t {
        let row: Vec<String> = (0..t)
            .map(|v| format!("{:>6.3}", maxima_energy[u * t + v] / max))
            .collect();
        println!("  {}", row.join(" "));
    }
    // low-frequency concentration metric (frequencies are ordered
    // [DC, (u1,v1) pairs..., Nyquist] per SFT component layout: row/col 0
    // is DC, the last is the alternating component)
    let dc_corner: f64 = (0..3).flat_map(|u| (0..3).map(move |v| (u, v)))
        .map(|(u, v)| maxima_energy[u * t + v])
        .sum();
    let total: f64 = maxima_energy.iter().sum();
    println!(
        "\nlow-frequency 3×3 corner holds {:.0}% of total energy (paper: 'energy is concentrated in the low frequencies')",
        100.0 * dc_corner / total
    );
    Ok(())
}

fn energy_per_frequency(x: &Tensor, plan: &FastConvPlan) -> Vec<f64> {
    use crate::nn::conv::gather_tile;
    let (n, ic, h, w) = x.dims4();
    let (m, l, t) = (plan.m(), plan.l(), plan.t());
    let tt = t * t;
    let tiles_y = h.div_ceil(m);
    let tiles_x = w.div_ceil(m);
    let mut energy = vec![0f64; tt];
    let mut tile = vec![0f32; l * l];
    let mut scratch = vec![0f32; t * l];
    let mut tv = vec![0f32; tt];
    for ni in 0..n.min(16) {
        for c in 0..ic {
            for ty in 0..tiles_y {
                for tx in 0..tiles_x {
                    gather_tile(x, ni, c, ty, tx, m, l, 1, &mut tile);
                    plan.transform_tile(&tile, &mut scratch, &mut tv);
                    for uv in 0..tt {
                        energy[uv] += (tv[uv] as f64).powi(2);
                    }
                }
            }
        }
    }
    energy
}

/// Fig. 4 — accuracy vs computation cost (GBOPs), int8→int4.
pub fn cmd_fig4(data_dir: &str) -> Result<()> {
    let (calib, _) = load_split(data_dir, "train", calib_n())?;
    let (images, labels) = load_split(data_dir, "test", eval_n())?;
    let mut model = load_model(data_dir, "resnet18")?;
    let fp32 = eval_acc(&model, &images, &labels);
    let shapes = model_conv_shapes(&model, 32);
    println!("Fig. 4 — accuracy vs computation cost, resnet18 (fp32 = {:.2}%)\n", fp32 * 100.0);
    println!("{:<18} {:>5} {:>10} {:>8}", "Algorithm", "Bits", "GBOPs", "Top-1");
    let algo_rows: [(&str, Option<&'static str>); 3] = [
        ("direct", None),
        ("Wino(4x4,3x3)", Some("Wino(4x4,3x3)")),
        ("SFC-6(7x7,3x3)", Some("SFC-6(7x7,3x3)")),
    ];
    let sel = default_selector();
    for (label, engine) in algo_rows {
        for bits in [8u32, 6, 5, 4] {
            let cfg = match engine {
                None => QuantConfig::direct_default(bits),
                Some(nm) => {
                    let mut cfg = QuantConfig::sfc_default(bits);
                    cfg.engine = Some(nm);
                    cfg
                }
            };
            let acc = quantize_and_eval(&mut model, &calib, &images, &labels, &cfg);
            let gbops = sel.model_gbops(&shapes, engine, bits, bits);
            println!("{:<18} {:>5} {:>10.3} {:>7.2}%", label, bits, gbops, acc * 100.0);
        }
    }
    println!("\npaper: SFC curve dominates — 1.6×–2.5× fewer BOPs at equal accuracy.");
    Ok(())
}

/// Fig. 5 — per-layer MSE vs fp32 under int8 PTQ, per algorithm.
pub fn cmd_fig5(data_dir: &str) -> Result<()> {
    let (calib, _) = load_split(data_dir, "train", calib_n())?;
    let (probe, _) = load_split(data_dir, "test", 32)?;
    let mut model = load_model(data_dir, "resnet18")?;
    let fp32_acts = model.forward_all(&probe);
    println!("Fig. 5 — per-layer output MSE vs fp32 under int8 PTQ, resnet18\n");
    let configs: [(&str, QuantConfig); 3] = [
        ("direct", QuantConfig::direct_default(8)),
        ("Wino(4x4,3x3)", QuantConfig::winograd_default(8)),
        ("SFC-6(7x7,3x3)", QuantConfig::sfc_default(8)),
    ];
    let mut per_algo: Vec<(String, Vec<(String, f64)>)> = Vec::new();
    for (label, cfg) in configs {
        quantize_model(&mut model, &calib, &cfg);
        per_algo.push((label.to_string(), layer_mse(&model, &fp32_acts, &probe)));
        dequantize_model(&mut model);
    }
    // union of quantized layers (direct quantizes more nodes: print common)
    let names: Vec<String> = per_algo
        .iter()
        .min_by_key(|(_, v)| v.len())
        .unwrap()
        .1
        .iter()
        .map(|(n, _)| n.clone())
        .collect();
    print!("{:<14}", "layer");
    for (label, _) in &per_algo {
        print!(" {label:>16}");
    }
    println!();
    let mut geo: Vec<f64> = vec![0.0; per_algo.len()];
    for name in &names {
        print!("{name:<14}");
        for (ai, (_, rows)) in per_algo.iter().enumerate() {
            let v = rows.iter().find(|(n, _)| n == name).map(|(_, v)| *v).unwrap_or(f64::NAN);
            geo[ai] += v.max(1e-30).ln();
            print!(" {v:>16.3e}");
        }
        println!();
    }
    print!("{:<14}", "geo-mean");
    for g in &geo {
        print!(" {:>16.3e}", (g / names.len() as f64).exp());
    }
    println!("\n\npaper: Winograd layers sit ~an order of magnitude above direct/SFC (matches κ analysis).");
    Ok(())
}
